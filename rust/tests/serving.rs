//! Serving-equivalence tests: the continuous-batching path must be an
//! invisible optimization — token-identical outputs to the FCFS oracle —
//! while actually exercising batching, prefix sharing, preemption **and
//! multi-threaded SPMD decode**.
//!
//! Thread counts: every differential test runs the batched engine at the
//! counts returned by [`thread_counts`] — `{1, 2, 4}` by default, or the
//! single count pinned by the `PALLAS_TEST_THREADS` env var (the CI
//! matrix runs the suite once per count, so the determinism guarantee is
//! enforced on every push at every matrix point).

use nncase_repro::coordinator::{
    synthetic_workload, Coordinator, Qwen3Engine, Request, ServePolicy, ServeReport,
};
use nncase_repro::model::{Qwen3Config, Qwen3Weights};
use nncase_repro::serving::{ContinuousConfig, KvQuant, TierConfig};

fn coordinator(seed: u64, threads: usize) -> (Qwen3Config, Coordinator) {
    let cfg = Qwen3Config::tiny();
    let w = Qwen3Weights::random(&cfg, seed);
    (cfg.clone(), Coordinator::new(Qwen3Engine::new(w, threads, 128)))
}

/// Batched-engine worker counts under test: `PALLAS_TEST_THREADS` pins a
/// single count (the CI matrix), default is the {1, 2, 4} sweep.
fn thread_counts() -> Vec<usize> {
    match std::env::var("PALLAS_TEST_THREADS") {
        Ok(v) => {
            let t: usize = v
                .trim()
                .parse()
                .expect("PALLAS_TEST_THREADS must be a positive integer");
            assert!(t >= 1, "PALLAS_TEST_THREADS must be >= 1");
            vec![t]
        }
        Err(_) => vec![1, 2, 4],
    }
}

fn serve_continuous(
    seed: u64,
    reqs: &[Request],
    mut cfg: ContinuousConfig,
    threads: usize,
) -> ServeReport {
    let (_, mut c) = coordinator(seed, 1);
    cfg.threads = threads;
    c.serve_with_policy(reqs, ServePolicy::Continuous(cfg))
}

/// Continuous batching produces byte-identical output token ids to the
/// FCFS oracle on the synthetic workload — at every worker count.
#[test]
fn continuous_matches_fcfs_oracle() {
    let (cfg, mut oracle) = coordinator(11, 1);
    let reqs = synthetic_workload(6, 5, 8, cfg.vocab);
    let want = oracle.serve(&reqs);
    for threads in thread_counts() {
        let got = serve_continuous(
            11,
            &reqs,
            ContinuousConfig {
                block_size: 4,
                num_blocks: 64,
                max_batch: 4,
                threads: 1,
                tiering: None,
            },
            threads,
        );
        assert_eq!(
            want.outputs, got.outputs,
            "continuous batching changed outputs at {threads} threads"
        );
        assert_eq!(got.generated_tokens, 6 * 8);
        assert_eq!(got.threads, threads.min(4), "report records the clamped worker count");
        let m = got.serving.expect("continuous metrics");
        assert!(m.batch_size.max() >= 2.0, "the workload must actually batch");
    }
}

/// The SPMD static partition is deterministic: every worker count yields
/// the same token stream, not merely the same as the oracle — pinned by
/// comparing all counts of this run against each other.
#[test]
fn thread_count_never_changes_tokens() {
    let (cfg, _) = coordinator(16, 1);
    let reqs = synthetic_workload(5, 6, 10, cfg.vocab);
    let mut reference: Option<Vec<(u64, Vec<usize>)>> = None;
    for threads in thread_counts() {
        let got = serve_continuous(16, &reqs, ContinuousConfig::default(), threads);
        if let Some(want) = &reference {
            assert_eq!(want, &got.outputs, "worker count {threads} changed the token stream");
        } else {
            reference = Some(got.outputs);
        }
    }
}

/// Equivalence holds across the multi-threaded FCFS engine too (the
/// static partition is numerically identical to 1T).
#[test]
fn continuous_matches_multithreaded_oracle() {
    let (cfg, mut oracle) = coordinator(12, 4);
    let reqs = synthetic_workload(3, 6, 6, cfg.vocab);
    let want = oracle.serve(&reqs);
    for threads in thread_counts() {
        let got = serve_continuous(12, &reqs, ContinuousConfig::default(), threads);
        assert_eq!(want.outputs, got.outputs);
    }
}

/// A pool sized below the working set forces preemption-to-queue; the
/// recomputation must still reproduce the oracle's tokens exactly —
/// including when the recompute runs on the multi-threaded batch engine
/// (preempt → recompute and SPMD decode must compose).
#[test]
fn preemption_is_invisible_in_outputs() {
    let (cfg, mut oracle) = coordinator(13, 1);
    // Two requests, each needing 4 blocks over its lifetime
    // (4 prompt + 12 generated tokens, block_size 4); a 5-block pool
    // cannot host both, so the later one is preempted mid-flight.
    let reqs = synthetic_workload(2, 4, 12, cfg.vocab);
    let want = oracle.serve(&reqs);
    for threads in thread_counts() {
        let got = serve_continuous(
            13,
            &reqs,
            ContinuousConfig {
                block_size: 4,
                num_blocks: 5,
                max_batch: 2,
                threads: 1,
                tiering: None,
            },
            threads,
        );
        assert_eq!(
            want.outputs, got.outputs,
            "preemption/recompute changed outputs at {threads} threads"
        );
        let m = got.serving.expect("continuous metrics");
        assert!(m.preemptions > 0, "the tiny pool must trigger preemption");
    }
}

/// Two requests sharing a long prompt prefix consume fewer pool blocks
/// than two with disjoint prompts, and reach the same outputs as the
/// oracle (shared full blocks hold identical K/V).
#[test]
fn prefix_sharing_reduces_block_pressure() {
    let (cfg, _) = coordinator(14, 1);
    let block_size = 4usize;
    // 9-token prompts: the first 8 tokens (2 full blocks) shared.
    let common: Vec<usize> = (0..8).map(|i| (i * 37 + 11) % cfg.vocab).collect();
    let mut p1 = common.clone();
    p1.push(100);
    let mut p2 = common.clone();
    p2.push(200);
    let shared_reqs = vec![
        Request { id: 0, prompt: p1.clone(), max_new_tokens: 4 },
        Request { id: 1, prompt: p2.clone(), max_new_tokens: 4 },
    ];
    let disjoint_reqs = vec![
        Request { id: 0, prompt: p1, max_new_tokens: 4 },
        Request {
            id: 1,
            prompt: (0..9).map(|i| (i * 53 + 29) % cfg.vocab).collect(),
            max_new_tokens: 4,
        },
    ];
    // max_batch 1 staggers the two requests: the second is admitted
    // after the first has filled (and published) its prompt blocks, so
    // the lookup actually hits the prefix cache.
    let run = |reqs: &[Request]| {
        serve_continuous(
            14,
            reqs,
            ContinuousConfig {
                block_size,
                num_blocks: 32,
                max_batch: 1,
                threads: 1,
                tiering: None,
            },
            1,
        )
    };
    let shared = run(&shared_reqs);
    let disjoint = run(&disjoint_reqs);
    let (ms, md) = (shared.serving.unwrap(), disjoint.serving.unwrap());
    assert!(ms.prefix_hits >= 2, "both full prompt blocks must be shared");
    assert!(
        ms.peak_blocks_in_use < md.peak_blocks_in_use,
        "prefix sharing must reduce peak pool pressure: shared {} vs disjoint {}",
        ms.peak_blocks_in_use,
        md.peak_blocks_in_use
    );

    // And sharing does not change the tokens: FCFS oracle agreement.
    let (_, mut oracle) = coordinator(14, 1);
    let want = oracle.serve(&shared_reqs);
    assert_eq!(want.outputs, shared.outputs);
}

/// A pool sized below the working set with tiering present-but-disabled
/// (`tiering: None` is the default — asserted here explicitly) stays
/// bitwise-identical to the FCFS oracle at every worker count: the
/// tiered subsystem must be invisible until it is switched on.
#[test]
fn tiering_disabled_is_bitwise_identical_under_pressure() {
    let (cfg, mut oracle) = coordinator(21, 1);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle.serve(&reqs);
    for threads in thread_counts() {
        let got = serve_continuous(
            21,
            &reqs,
            ContinuousConfig {
                block_size: 4,
                num_blocks: 7,
                max_batch: 3,
                threads: 1,
                tiering: None,
            },
            threads,
        );
        assert_eq!(
            want.outputs, got.outputs,
            "disabled tiering changed outputs at {threads} threads"
        );
        let m = got.serving.expect("continuous metrics");
        assert!(m.preemptions > 0, "the tiny pool must still preempt");
        assert_eq!(m.swap_preemptions, 0);
        assert!(!m.tiered);
    }
}

/// The lossless tier: f32 swap-based preemption under forced pool
/// pressure is *bitwise* identical to the FCFS oracle while replacing
/// every recompute with a swap — the strongest differential evidence
/// that the spill/fetch plumbing moves KV without corrupting it.
#[test]
fn tiered_f32_swap_is_bitwise_identical_to_oracle() {
    let (cfg, mut oracle) = coordinator(22, 1);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle.serve(&reqs);
    for threads in thread_counts() {
        let got = serve_continuous(
            22,
            &reqs,
            ContinuousConfig {
                block_size: 4,
                num_blocks: 7,
                max_batch: 3,
                threads: 1,
                tiering: Some(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) }),
            },
            threads,
        );
        assert_eq!(
            want.outputs, got.outputs,
            "lossless swap changed outputs at {threads} threads"
        );
        let m = got.serving.expect("continuous metrics");
        assert!(m.swap_preemptions > 0, "forced pressure must swap");
        assert_eq!(m.recompute_preemptions, 0, "swap must fully replace recompute");
        assert_eq!(m.replay_steps, 0, "swapped sequences resume, never replay");
        assert!(m.swap_points.is_empty(), "f32 is lossless: no divergence points");
    }
}

/// The lossy tier: int8 swap under forced pressure finishes every
/// request with zero recompute-preemptions, and each sequence's output
/// may diverge from the oracle only *at or after* its first resume over
/// quantized KV (`swap_points`); sequences never swapped stay exact.
#[test]
fn tiered_int8_swap_diverges_only_after_reread() {
    let (cfg, mut oracle) = coordinator(23, 1);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle.serve(&reqs);
    // Both the fetch path and the direct-read path must honor the bound.
    let tiers = [
        TierConfig::new(16),
        TierConfig { direct_read_min_frac: Some(0.5), ..TierConfig::new(16) },
    ];
    for tier in tiers {
        let direct = tier.direct_read_min_frac.is_some();
        for threads in thread_counts() {
            let got = serve_continuous(
                23,
                &reqs,
                ContinuousConfig {
                    block_size: 4,
                    num_blocks: 7,
                    max_batch: 3,
                    threads: 1,
                    tiering: Some(tier.clone()),
                },
                threads,
            );
            let m = got.serving.as_ref().expect("continuous metrics");
            assert!(m.swap_preemptions > 0, "forced pressure must swap");
            assert_eq!(m.recompute_preemptions, 0, "swap must fully replace recompute");
            assert_eq!(m.replay_steps, 0);
            if direct {
                assert!(m.cold_direct_reads > 0, "direct-read swap-ins must occur");
            }
            for (id, toks) in &got.outputs {
                let oracle_toks =
                    &want.outputs.iter().find(|(i, _)| i == id).expect("same request set").1;
                assert_eq!(toks.len(), 12, "request {id} must finish all tokens");
                match m.swap_points.iter().find(|(i, _)| i == id) {
                    None => assert_eq!(
                        &toks, &oracle_toks,
                        "request {id} never resumed over quantized KV; must stay exact"
                    ),
                    Some(&(_, at)) => assert_eq!(
                        toks[..at],
                        oracle_toks[..at],
                        "request {id} diverged before its first quantized re-read at {at}"
                    ),
                }
            }
        }
    }
}

/// The engine's own generate() agrees with serve() outputs (the report
/// path adds no divergence).
#[test]
fn serve_agrees_with_generate() {
    let (cfg, mut c) = coordinator(15, 1);
    let reqs = synthetic_workload(2, 4, 6, cfg.vocab);
    let rep = c.serve(&reqs);
    for req in &reqs {
        let toks = c.engine.generate(&req.prompt, req.max_new_tokens);
        let served = &rep.outputs.iter().find(|(id, _)| *id == req.id).unwrap().1;
        assert_eq!(&toks, served);
    }
}
