//! Serving-equivalence tests: the continuous-batching path must be an
//! invisible optimization — token-identical outputs to the FCFS oracle —
//! while actually exercising batching, prefix sharing, preemption **and
//! multi-threaded SPMD decode**.
//!
//! Thread counts: every differential test runs the batched engine at the
//! counts returned by [`thread_counts`] — `{1, 2, 4}` by default, or the
//! single count pinned by the `PALLAS_TEST_THREADS` env var (the CI
//! matrix runs the suite once per count, so the determinism guarantee is
//! enforced on every push at every matrix point). Shard counts follow
//! the same shape via [`shard_counts`] / `PALLAS_TEST_SHARDS`.

use nncase_repro::coordinator::{
    argmax, synthetic_workload, Coordinator, Qwen3Engine, Request, ServeOptions, ServeReport,
};
use nncase_repro::cost::MachineSpec;
use nncase_repro::dist::ShardSpec;
use nncase_repro::model::{Qwen3Config, Qwen3Weights};
use nncase_repro::ntt::WeightQuant;
use nncase_repro::serving::{BatchEngine, ContinuousConfig, KvQuant, StepSlot, TierConfig};

fn coordinator(seed: u64, threads: usize) -> (Qwen3Config, Coordinator) {
    let cfg = Qwen3Config::tiny();
    let w = Qwen3Weights::random(&cfg, seed);
    (cfg.clone(), Coordinator::new(Qwen3Engine::new(w, threads, 128)))
}

/// Batched-engine worker counts under test: `PALLAS_TEST_THREADS` pins a
/// single count (the CI matrix), default is the {1, 2, 4} sweep. Parsed
/// through [`nncase_repro::util::env_knob`] — a malformed value warns
/// once and falls back to the sweep instead of panicking, the same
/// lenient policy every other `PALLAS_*` knob follows.
fn thread_counts() -> Vec<usize> {
    nncase_repro::util::env_knob("PALLAS_TEST_THREADS", |t: &usize| *t >= 1)
        .map_or_else(|| vec![1, 2, 4], |t| vec![t])
}

/// Shard-group counts under test: `PALLAS_TEST_SHARDS` pins a single
/// count (the CI matrix), default is the {1, 2, 4} sweep. Same lenient
/// `env_knob` parsing as [`thread_counts`].
fn shard_counts() -> Vec<usize> {
    nncase_repro::util::env_knob("PALLAS_TEST_SHARDS", |s: &usize| *s >= 1)
        .map_or_else(|| vec![1, 2, 4], |s| vec![s])
}

fn serve_continuous(
    seed: u64,
    reqs: &[Request],
    cfg: ContinuousConfig,
    threads: usize,
) -> ServeReport {
    let (_, mut c) = coordinator(seed, 1);
    c.serve(reqs, &ServeOptions::continuous(cfg).threads(threads))
}

/// Continuous batching produces byte-identical output token ids to the
/// FCFS oracle on the synthetic workload — at every worker count.
#[test]
fn continuous_matches_fcfs_oracle() {
    let (cfg, mut oracle) = coordinator(11, 1);
    let reqs = synthetic_workload(6, 5, 8, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    for threads in thread_counts() {
        let got = serve_continuous(
            11,
            &reqs,
            ContinuousConfig::builder().block_size(4).num_blocks(64).max_batch(4).build(),
            threads,
        );
        assert_eq!(
            want.outputs, got.outputs,
            "continuous batching changed outputs at {threads} threads"
        );
        assert_eq!(got.generated_tokens, 6 * 8);
        assert_eq!(got.threads, threads.min(4), "report records the clamped worker count");
        let m = got.serving.expect("continuous metrics");
        assert!(m.batch_size.max() >= 2.0, "the workload must actually batch");
    }
}

/// The SPMD static partition is deterministic: every worker count yields
/// the same token stream, not merely the same as the oracle — pinned by
/// comparing all counts of this run against each other.
#[test]
fn thread_count_never_changes_tokens() {
    let (cfg, _) = coordinator(16, 1);
    let reqs = synthetic_workload(5, 6, 10, cfg.vocab);
    let mut reference: Option<Vec<(u64, Vec<usize>)>> = None;
    for threads in thread_counts() {
        let got = serve_continuous(16, &reqs, ContinuousConfig::default(), threads);
        if let Some(want) = &reference {
            assert_eq!(want, &got.outputs, "worker count {threads} changed the token stream");
        } else {
            reference = Some(got.outputs);
        }
    }
}

/// Equivalence holds across the multi-threaded FCFS engine too (the
/// static partition is numerically identical to 1T).
#[test]
fn continuous_matches_multithreaded_oracle() {
    let (cfg, mut oracle) = coordinator(12, 4);
    let reqs = synthetic_workload(3, 6, 6, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    for threads in thread_counts() {
        let got = serve_continuous(12, &reqs, ContinuousConfig::default(), threads);
        assert_eq!(want.outputs, got.outputs);
    }
}

/// A pool sized below the working set forces preemption-to-queue; the
/// recomputation must still reproduce the oracle's tokens exactly —
/// including when the recompute runs on the multi-threaded batch engine
/// (preempt → recompute and SPMD decode must compose).
#[test]
fn preemption_is_invisible_in_outputs() {
    let (cfg, mut oracle) = coordinator(13, 1);
    // Two requests, each needing 4 blocks over its lifetime
    // (4 prompt + 12 generated tokens, block_size 4); a 5-block pool
    // cannot host both, so the later one is preempted mid-flight.
    let reqs = synthetic_workload(2, 4, 12, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    for threads in thread_counts() {
        let got = serve_continuous(
            13,
            &reqs,
            ContinuousConfig::builder().block_size(4).num_blocks(5).max_batch(2).build(),
            threads,
        );
        assert_eq!(
            want.outputs, got.outputs,
            "preemption/recompute changed outputs at {threads} threads"
        );
        let m = got.serving.expect("continuous metrics");
        assert!(m.preemptions > 0, "the tiny pool must trigger preemption");
    }
}

/// Two requests sharing a long prompt prefix consume fewer pool blocks
/// than two with disjoint prompts, and reach the same outputs as the
/// oracle (shared full blocks hold identical K/V).
#[test]
fn prefix_sharing_reduces_block_pressure() {
    let (cfg, _) = coordinator(14, 1);
    let block_size = 4usize;
    // 9-token prompts: the first 8 tokens (2 full blocks) shared.
    let common: Vec<usize> = (0..8).map(|i| (i * 37 + 11) % cfg.vocab).collect();
    let mut p1 = common.clone();
    p1.push(100);
    let mut p2 = common.clone();
    p2.push(200);
    let shared_reqs = vec![
        Request { id: 0, prompt: p1.clone(), max_new_tokens: 4 },
        Request { id: 1, prompt: p2.clone(), max_new_tokens: 4 },
    ];
    let disjoint_reqs = vec![
        Request { id: 0, prompt: p1, max_new_tokens: 4 },
        Request {
            id: 1,
            prompt: (0..9).map(|i| (i * 53 + 29) % cfg.vocab).collect(),
            max_new_tokens: 4,
        },
    ];
    // max_batch 1 staggers the two requests: the second is admitted
    // after the first has filled (and published) its prompt blocks, so
    // the lookup actually hits the prefix cache.
    let run = |reqs: &[Request]| {
        serve_continuous(
            14,
            reqs,
            ContinuousConfig::builder()
                .block_size(block_size)
                .num_blocks(32)
                .max_batch(1)
                .build(),
            1,
        )
    };
    let shared = run(&shared_reqs);
    let disjoint = run(&disjoint_reqs);
    let (ms, md) = (shared.serving.unwrap(), disjoint.serving.unwrap());
    assert!(ms.prefix_hits >= 2, "both full prompt blocks must be shared");
    assert!(
        ms.peak_blocks_in_use < md.peak_blocks_in_use,
        "prefix sharing must reduce peak pool pressure: shared {} vs disjoint {}",
        ms.peak_blocks_in_use,
        md.peak_blocks_in_use
    );

    // And sharing does not change the tokens: FCFS oracle agreement.
    let (_, mut oracle) = coordinator(14, 1);
    let want = oracle.serve(&shared_reqs, &ServeOptions::fcfs());
    assert_eq!(want.outputs, shared.outputs);
}

/// A pool sized below the working set with tiering present-but-disabled
/// (`tiering: None` is the default — asserted here explicitly) stays
/// bitwise-identical to the FCFS oracle at every worker count: the
/// tiered subsystem must be invisible until it is switched on.
#[test]
fn tiering_disabled_is_bitwise_identical_under_pressure() {
    let (cfg, mut oracle) = coordinator(21, 1);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    for threads in thread_counts() {
        let got = serve_continuous(
            21,
            &reqs,
            ContinuousConfig::builder().block_size(4).num_blocks(7).max_batch(3).build(),
            threads,
        );
        assert_eq!(
            want.outputs, got.outputs,
            "disabled tiering changed outputs at {threads} threads"
        );
        let m = got.serving.expect("continuous metrics");
        assert!(m.preemptions > 0, "the tiny pool must still preempt");
        assert_eq!(m.swap_preemptions, 0);
        assert!(!m.tiered);
    }
}

/// The lossless tier: f32 swap-based preemption under forced pool
/// pressure is *bitwise* identical to the FCFS oracle while replacing
/// every recompute with a swap — the strongest differential evidence
/// that the spill/fetch plumbing moves KV without corrupting it.
#[test]
fn tiered_f32_swap_is_bitwise_identical_to_oracle() {
    let (cfg, mut oracle) = coordinator(22, 1);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    for threads in thread_counts() {
        let got = serve_continuous(
            22,
            &reqs,
            ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(7)
                .max_batch(3)
                .tiering(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) })
                .build(),
            threads,
        );
        assert_eq!(
            want.outputs, got.outputs,
            "lossless swap changed outputs at {threads} threads"
        );
        let m = got.serving.expect("continuous metrics");
        assert!(m.swap_preemptions > 0, "forced pressure must swap");
        assert_eq!(m.recompute_preemptions, 0, "swap must fully replace recompute");
        assert_eq!(m.replay_steps, 0, "swapped sequences resume, never replay");
        assert!(m.swap_points.is_empty(), "f32 is lossless: no divergence points");
    }
}

/// The lossy tier: int8 swap under forced pressure finishes every
/// request with zero recompute-preemptions, and each sequence's output
/// may diverge from the oracle only *at or after* its first resume over
/// quantized KV (`swap_points`); sequences never swapped stay exact.
#[test]
fn tiered_int8_swap_diverges_only_after_reread() {
    let (cfg, mut oracle) = coordinator(23, 1);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    // Both the fetch path and the direct-read path must honor the bound.
    let tiers = [
        TierConfig::new(16),
        TierConfig { direct_read_min_frac: Some(0.5), ..TierConfig::new(16) },
    ];
    for tier in tiers {
        let direct = tier.direct_read_min_frac.is_some();
        for threads in thread_counts() {
            let got = serve_continuous(
                23,
                &reqs,
                ContinuousConfig::builder()
                    .block_size(4)
                    .num_blocks(7)
                    .max_batch(3)
                    .tiering(tier.clone())
                    .build(),
                threads,
            );
            let m = got.serving.as_ref().expect("continuous metrics");
            assert!(m.swap_preemptions > 0, "forced pressure must swap");
            assert_eq!(m.recompute_preemptions, 0, "swap must fully replace recompute");
            assert_eq!(m.replay_steps, 0);
            if direct {
                assert!(m.cold_direct_reads > 0, "direct-read swap-ins must occur");
            }
            for (id, toks) in &got.outputs {
                let oracle_toks =
                    &want.outputs.iter().find(|(i, _)| i == id).expect("same request set").1;
                assert_eq!(toks.len(), 12, "request {id} must finish all tokens");
                match m.swap_points.iter().find(|(i, _)| i == id) {
                    None => assert_eq!(
                        &toks, &oracle_toks,
                        "request {id} never resumed over quantized KV; must stay exact"
                    ),
                    Some(&(_, at)) => assert_eq!(
                        toks[..at],
                        oracle_toks[..at],
                        "request {id} diverged before its first quantized re-read at {at}"
                    ),
                }
            }
        }
    }
}

/// Group-wise quantized weights (`Qwen3Config::weight_quant`): the
/// continuous path over fused dequant-GEMM kernels must be
/// token-identical to *its own* FCFS oracle — the dense engine running
/// the fake-quantized (quantize→dequantize) weights, which are the
/// exact f32 values the fused kernels FMA — at every worker count. And
/// the explicit `WeightQuant::F32` mode must stay bitwise the seed
/// path (same outputs as a default-config run).
#[test]
fn quantized_weight_serve_matches_its_fcfs_oracle() {
    let reqs = synthetic_workload(5, 4, 8, Qwen3Config::tiny().vocab);
    let serve_cont = |cfg: &Qwen3Config, threads: usize| -> ServeReport {
        let w = Qwen3Weights::random(cfg, 31);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 128));
        let ccfg = ContinuousConfig::builder().block_size(4).num_blocks(64).max_batch(4).build();
        c.serve(&reqs, &ServeOptions::continuous(ccfg).threads(threads))
    };
    // F32 weight-quant is the seed path, bitwise: same outputs as the
    // default config (which *is* WeightQuant::F32) and as the oracle.
    let f32_cfg = Qwen3Config::tiny().with_weight_quant(WeightQuant::F32);
    let seed = serve_cont(&Qwen3Config::tiny(), 1);
    assert_eq!(seed.outputs, serve_cont(&f32_cfg, 1).outputs);
    for mode in [WeightQuant::Int8, WeightQuant::Int4] {
        let cfg = Qwen3Config::tiny().with_weight_quant(mode);
        let w = Qwen3Weights::random(&cfg, 31);
        let mut oracle = Coordinator::new(Qwen3Engine::new(w, 1, 128));
        let want = oracle.serve(&reqs, &ServeOptions::fcfs());
        for threads in thread_counts() {
            let got = serve_cont(&cfg, threads);
            assert_eq!(
                want.outputs, got.outputs,
                "{mode:?} fused path diverged from its oracle at {threads} threads"
            );
            assert_eq!(got.generated_tokens, 5 * 8, "quantized runs must finish");
            assert_eq!(got.weight_quant, mode, "report must record the quant mode");
            assert!(
                got.weight_bytes < seed.weight_bytes / 2,
                "quantized footprint must shrink: {} vs {}",
                got.weight_bytes,
                seed.weight_bytes
            );
        }
    }
}

/// The lossy half of the weight-quant contract: an int8-weight run,
/// teacher-forced along the f32 oracle's token stream, keeps every
/// step's logits within a stated max-abs-diff bound of the f32 oracle
/// — at every worker count.
///
/// Bound: per weight the group-affine error is ≤ scale/2 ≈ 1.7e-4 at
/// the tiny model's 0.02-σ init (range of 32 normals ≈ 4.4σ, /255/2).
/// Through a 256-wide projection that is ~0.03 absolute per activation,
/// and KV drift compounds it over 4 layers × 12 positions to roughly
/// 0.05–0.3 on the logits. The random tiny model's logits spread about
/// ±1.1 (N(0, 0.32) over a 4096 vocab), so 0.75 separates "quantization
/// noise" from "wrong computation" with margin on both sides.
#[test]
fn int8_weight_logits_stay_within_bound_of_f32_oracle() {
    const BOUND: f32 = 0.75;
    let cfg_f = Qwen3Config::tiny();
    let cfg_q = Qwen3Config::tiny().with_weight_quant(WeightQuant::Int8);
    let w_q = Qwen3Weights::random(&cfg_q, 41);
    let mut oracle = Qwen3Engine::new(Qwen3Weights::random(&cfg_f, 41), 1, 64);
    // Teacher stream: the f32 oracle's own greedy decode.
    let prompt = [5usize, 999, 42, 7];
    let total = prompt.len() + 8;
    let mut stream: Vec<usize> = prompt.to_vec();
    let mut oracle_logits: Vec<Vec<f32>> = Vec::new();
    for pos in 0..total {
        if pos >= stream.len() {
            stream.push(argmax(oracle_logits.last().expect("previous step")));
        }
        oracle_logits.push(oracle.decode_step(stream[pos], pos));
    }
    let max_abs = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
    };
    let bs = 4usize;
    let table: Vec<u32> = (0..total.div_ceil(bs) as u32).collect();
    for threads in thread_counts() {
        let mut be = BatchEngine::new(&w_q, table.len() + 2, bs);
        let diffs: Vec<f32> = be.run(threads, 1, |stepper| {
            stream
                .iter()
                .enumerate()
                .map(|(pos, tok)| {
                    let slot = StepSlot::hot(std::slice::from_ref(tok), pos, &table, true);
                    let (_, l) = stepper.step_logits(&[slot], true);
                    max_abs(&l, &oracle_logits[pos])
                })
                .collect()
        });
        let worst = diffs.iter().copied().fold(0.0f32, f32::max);
        assert!(worst > 0.0, "int8 weights must actually perturb the logits");
        assert!(
            worst < BOUND,
            "int8-weight logits drifted {worst} > {BOUND} from the f32 oracle \
             (diffs per step: {diffs:?}) at {threads} threads"
        );
    }
}

/// The chunked-prefill differential matrix: continuous serving at every
/// chunk size — 1 (the seed), 3 (NOT a divisor of the block size, so
/// spans straddle block boundaries), block_size, and 4 × block_size
/// (whole prompts in one span) — must be token-identical to the FCFS
/// oracle at every worker count. Chunking changes when prompt positions
/// are computed, never their values.
#[test]
fn chunked_prefill_matches_fcfs_oracle() {
    let (cfg, mut oracle) = coordinator(31, 1);
    // 9-token prompts: chunk 3 packs 3+3+3, chunk 4 packs 4+4+1, chunk
    // 16 swallows whole prompts; all cross block boundaries (bs = 4).
    let reqs = synthetic_workload(5, 9, 6, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    let block_size = 4usize;
    for chunk in [1usize, 3, block_size, 4 * block_size] {
        for threads in thread_counts() {
            let got = serve_continuous(
                31,
                &reqs,
                ContinuousConfig::builder()
                    .block_size(block_size)
                    .num_blocks(64)
                    .max_batch(4)
                    .prefill_chunk(chunk)
                    .build(),
                threads,
            );
            assert_eq!(
                want.outputs, got.outputs,
                "chunk {chunk} changed outputs at {threads} threads"
            );
            let m = got.serving.expect("continuous metrics");
            if chunk > 1 {
                assert!(
                    m.chunk_size.max() > 1.0,
                    "chunk {chunk} must actually pack multi-token spans"
                );
            } else {
                assert_eq!(m.chunk_size.max(), 1.0, "chunk 1 must stay one-token spans");
            }
            assert!(m.prefill_steps >= 5 * 9, "every prompt position must be counted");
        }
    }
}

/// Chunked prefill composed with memory pressure: recompute-preemption
/// (tiering off) replays spans and must stay token-identical; the
/// lossless f32 tier must stay token-identical while swapping spans'
/// blocks across the storage boundary.
#[test]
fn chunked_prefill_survives_preemption_and_tiering() {
    let (cfg, mut oracle) = coordinator(32, 1);
    let reqs = synthetic_workload(3, 8, 10, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    let tiers: [Option<TierConfig>; 2] =
        [None, Some(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) })];
    for tiering in tiers {
        for threads in thread_counts() {
            let mut cfg = ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(8)
                .max_batch(3)
                .prefill_chunk(3)
                .build();
            cfg.tiering = tiering.clone();
            let got = serve_continuous(32, &reqs, cfg, threads);
            assert_eq!(
                want.outputs, got.outputs,
                "chunked prefill under pressure (tier {:?}) changed outputs at {threads} \
                 threads",
                tiering.is_some()
            );
            let m = got.serving.expect("continuous metrics");
            assert!(m.preemptions > 0, "the tiny pool must preempt");
            if tiering.is_some() {
                assert!(m.swap_preemptions > 0, "the f32 tier must swap");
            }
        }
    }
}

/// Chunked prefill over group-wise quantized weights: the multi-token
/// span path drives the fused dequant-GEMM kernels with tall A panels,
/// and must stay token-identical to its own fake-quantized FCFS oracle.
#[test]
fn chunked_prefill_quantized_weights_match_oracle() {
    let reqs = synthetic_workload(4, 9, 6, Qwen3Config::tiny().vocab);
    let cfg = Qwen3Config::tiny().with_weight_quant(WeightQuant::Int8);
    let w = Qwen3Weights::random(&cfg, 33);
    let mut oracle = Coordinator::new(Qwen3Engine::new(w, 1, 128));
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    for threads in thread_counts() {
        let w = Qwen3Weights::random(&cfg, 33);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 128));
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(64)
            .max_batch(4)
            .prefill_chunk(3)
            .build();
        let got = c.serve(&reqs, &ServeOptions::continuous(ccfg).threads(threads));
        assert_eq!(
            want.outputs, got.outputs,
            "chunked int8-weight serving diverged from its oracle at {threads} threads"
        );
    }
}

/// Serve-time autotune is semantics-free: a planner-derived config —
/// chunk, step budget, panel granularity and pool sizing all chosen by
/// the cost model rather than by hand — serves token-identical output
/// to the default-config FCFS oracle at every worker count, and the
/// report records the plan that served.
#[test]
fn autotuned_serve_matches_fcfs_oracle() {
    let (cfg, mut oracle) = coordinator(21, 1);
    let reqs = synthetic_workload(6, 5, 8, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    let machine = MachineSpec::ryzen_5900x();
    let acfg = ContinuousConfig::autotuned(&cfg, &machine, 4);
    let plan = acfg.plan.clone().expect("autotuned config carries its plan");
    for threads in thread_counts() {
        // serve_continuous overrides cfg.threads per matrix point — the
        // rest of the plan (including its panel_rows knob) still drives
        // the batched engine, so this exercises planner panels at every
        // worker count.
        let got = serve_continuous(21, &reqs, acfg.clone(), threads);
        assert_eq!(
            want.outputs, got.outputs,
            "the serve plan changed outputs at {threads} threads — plans must be \
             semantics-free"
        );
        assert_eq!(got.generated_tokens, 6 * 8);
        let got_plan = got.plan.expect("an autotuned run must record its plan");
        assert_eq!(
            got_plan.plan_hash(),
            plan.plan_hash(),
            "the report must carry the plan that actually served"
        );
    }
}

/// The tentpole differential: dist-sharded continuous serving must be
/// token-identical to the FCFS oracle at every (threads × shards) point
/// of the matrix. Sharding partitions each projection GEMM across
/// cooperating worker groups with the layout chosen by
/// `dist::extract_dist`; the combine is disjoint column placement, so
/// outputs stay bitwise those of the seed engine. The report must record
/// the shard count and the dist-chosen per-matrix SBP signature.
#[test]
fn sharded_serve_matches_fcfs_oracle_across_the_matrix() {
    let (cfg, mut oracle) = coordinator(51, 1);
    let reqs = synthetic_workload(5, 6, 8, cfg.vocab);
    let want = oracle.serve(&reqs, &ServeOptions::fcfs());
    let machine = MachineSpec::test_numa();
    for shards in shard_counts() {
        for threads in thread_counts() {
            let (_, mut c) = coordinator(51, 1);
            let ccfg =
                ContinuousConfig::builder().block_size(4).num_blocks(64).max_batch(4).build();
            let opts = ServeOptions::continuous(ccfg)
                .threads(threads)
                .shards(shards)
                .machine(machine.clone());
            let got = c.serve(&reqs, &opts);
            assert_eq!(
                want.outputs, got.outputs,
                "sharded serving changed outputs at {threads} threads x {shards} shards"
            );
            assert_eq!(got.generated_tokens, 5 * 8);
            if shards > 1 {
                let spec = ShardSpec::derive(&cfg, &machine, shards);
                assert_eq!(got.shards, shards, "the report must record the shard count");
                assert_eq!(
                    got.sbp_sig.as_deref(),
                    Some(spec.sig().as_str()),
                    "the report must record the dist-chosen SBP signature"
                );
            } else {
                assert_eq!(got.shards, 1);
                assert!(got.sbp_sig.is_none(), "unsharded runs carry no SBP signature");
            }
        }
    }
}

///// Sharding composed with the rest of the serving machinery: chunked
/// prefill, a pool small enough to preempt, and group-wise quantized
/// weights — still token-identical to each mode's own FCFS oracle at
/// every (threads × shards) matrix point.
#[test]
fn sharded_serve_composes_with_chunking_preemption_and_quant() {
    let reqs = synthetic_workload(3, 8, 10, Qwen3Config::tiny().vocab);
    for mode in [WeightQuant::F32, WeightQuant::Int8] {
        let qcfg = Qwen3Config::tiny().with_weight_quant(mode);
        let w = Qwen3Weights::random(&qcfg, 52);
        let mut oracle = Coordinator::new(Qwen3Engine::new(w, 1, 128));
        let want = oracle.serve(&reqs, &ServeOptions::fcfs());
        for shards in shard_counts() {
            for threads in thread_counts() {
                let w = Qwen3Weights::random(&qcfg, 52);
                let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 128));
                let ccfg = ContinuousConfig::builder()
                    .block_size(4)
                    .num_blocks(8)
                    .max_batch(3)
                    .prefill_chunk(3)
                    .build();
                let opts = ServeOptions::continuous(ccfg)
                    .threads(threads)
                    .shards(shards)
                    .machine(MachineSpec::test_numa());
                let got = c.serve(&reqs, &opts);
                assert_eq!(
                    want.outputs, got.outputs,
                    "sharded {mode:?} serving diverged at {threads} threads x {shards} shards"
                );
                let m = got.serving.expect("continuous metrics");
                assert!(m.preemptions > 0, "the tiny pool must preempt");
            }
        }
    }
}

/// The observability tentpole differential: tracing is timestamps only
/// — a traced serve is bitwise token-identical to the untraced run at
/// every (threads × shards) matrix point, across the plain pool, the
/// lossless tiered pool under forced swap pressure, and chunked
/// prefill. The traced report must additionally carry a non-empty
/// phase/utilization summary with one track per engine worker plus the
/// scheduler's.
#[test]
fn traced_serve_is_bitwise_identical_across_the_matrix() {
    let reqs = synthetic_workload(3, 8, 10, Qwen3Config::tiny().vocab);
    let machine = MachineSpec::test_numa();
    let max_batch = 3usize;
    let configs: [(&str, ContinuousConfig); 3] = [
        (
            "plain",
            ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(64)
                .max_batch(max_batch)
                .build(),
        ),
        (
            "tiered-f32",
            ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(7)
                .max_batch(max_batch)
                .tiering(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) })
                .build(),
        ),
        (
            "chunked",
            ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(64)
                .max_batch(max_batch)
                .prefill_chunk(3)
                .build(),
        ),
    ];
    for (name, ccfg) in &configs {
        let max_rows = ccfg.row_capacity();
        for shards in shard_counts() {
            for threads in thread_counts() {
                let mut run = |trace: bool| {
                    let (_, mut c) = coordinator(61, 1);
                    let mut opts = ServeOptions::continuous(ccfg.clone())
                        .threads(threads)
                        .shards(shards)
                        .machine(machine.clone());
                    if trace {
                        opts = opts.trace();
                    }
                    c.serve(&reqs, &opts)
                };
                let plain = run(false);
                let traced = run(true);
                assert_eq!(
                    plain.outputs, traced.outputs,
                    "tracing changed {name} outputs at {threads} threads x {shards} shards"
                );
                assert!(plain.trace.is_none(), "tracing must be off by default");
                let t = traced.trace.as_ref().expect("traced runs carry a summary");
                assert!(t.events > 0, "{name}: a served workload must record events");
                // One track per engine worker (lanes × shards) plus the
                // scheduler's.
                let lanes = threads.clamp(1, max_rows);
                assert_eq!(
                    t.workers.len(),
                    lanes * shards + 1,
                    "{name} at {threads}T x {shards}S"
                );
                assert_eq!(t.workers.last().unwrap().name, "scheduler");
                assert!(
                    t.phases.iter().any(|p| p.name == "iterate"),
                    "{name}: the scheduler track must record iteration spans"
                );
                if *name == "tiered-f32" {
                    let m = traced.serving.as_ref().unwrap();
                    assert!(m.swap_preemptions > 0, "forced pressure must swap");
                    assert!(
                        t.phases.iter().any(|p| p.name == "tier_spill"),
                        "swapping runs must record tier-spill spans: {:?}",
                        t.phases.iter().map(|p| p.name).collect::<Vec<_>>()
                    );
                }
            }
        }
    }
}

/// `--trace-out`: the exported file is Chrome-trace-event JSON in the
/// object form Perfetto loads, with one `thread_name` metadata record
/// per track, B/E span pairs, and thread-scoped instants for request
/// lifecycle edges.
#[test]
fn trace_out_writes_chrome_json() {
    let (cfg, mut c) = coordinator(62, 1);
    let reqs = synthetic_workload(2, 4, 5, cfg.vocab);
    let path = std::env::temp_dir().join(format!("pallas_trace_{}.json", std::process::id()));
    let ccfg =
        ContinuousConfig::builder().block_size(4).num_blocks(32).max_batch(2).build();
    let rep = c.serve(
        &reqs,
        &ServeOptions::continuous(ccfg).threads(2).trace_out(path.to_str().unwrap()),
    );
    assert!(rep.trace.is_some());
    let body = std::fs::read_to_string(&path).expect("trace file written");
    std::fs::remove_file(&path).ok();
    assert!(body.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["), "{}", &body[..64]);
    assert!(body.ends_with("]}"), "trace must close the object form");
    assert!(body.contains("\"name\":\"thread_name\""), "tracks must be named");
    assert!(body.contains("\"worker 0 (controller)\""));
    assert!(body.contains("\"scheduler\""));
    assert!(body.contains("\"ph\":\"B\"") && body.contains("\"ph\":\"E\""));
    assert!(body.contains("\"ph\":\"i\""), "lifecycle instants must be present");
    assert!(body.contains("\"name\":\"lm_head\""), "phase spans must be present");
    assert!(body.contains("\"name\":\"finish\""), "request lifecycle must be present");
}

/// The machine-readable report schema: `ServeReport::to_json` opens
/// with the schema tag, and a traced run's JSON carries the plan,
/// serving and trace sections as objects (CI parses the real thing
/// with Python's json module via tools/trace_summary.py and
/// tools/bench_compare.py).
#[test]
fn report_to_json_is_stable_and_complete() {
    let (cfg, mut c) = coordinator(63, 1);
    let reqs = synthetic_workload(3, 4, 6, cfg.vocab);
    let machine = MachineSpec::ryzen_5900x();
    let rep = c.serve(&reqs, &ServeOptions::autotuned(3).machine(machine).trace());
    let j = rep.to_json();
    assert!(j.starts_with("{\"schema\":\"serve_report.v1\",\"requests\":3,"), "{j}");
    for key in [
        "\"generated_tokens\":18",
        "\"decode_tok_s\":",
        "\"ttft_p50_s\":",
        "\"plan\":{\"hash\":\"",
        "\"predicted_decode_iter_s\":",
        "\"serving\":{\"iterations\":",
        "\"request_e2e_p50_s\":",
        "\"trace\":{\"events\":",
        "\"wait_frac\":",
    ] {
        assert!(j.contains(key), "missing {key} in {j}");
    }
    let depth = j.chars().fold(0i64, |d, c| d + (c == '{') as i64 - (c == '}') as i64);
    assert_eq!(depth, 0, "{j}");
}

/// True iff some request's greedy stream revisits a token early enough
/// for the self-drafter to act on it: the token generated at step
/// `j - 1` already occurs in `prompt ++ generated[..j-1]` for some
/// planning step `j` with at least two tokens of budget left (the
/// final decode step never drafts — there is no headroom to accept).
/// This is exactly the prompt-lookup drafter's weakest (1-gram) match
/// condition, so whenever it holds, a spec-on run MUST have drafted.
fn stream_has_early_repeat(reqs: &[Request], oracle: &ServeReport) -> bool {
    reqs.iter().any(|r| {
        let g = &oracle.outputs.iter().find(|(id, _)| *id == r.id).expect("same ids").1;
        (1..g.len().saturating_sub(1)).any(|j| {
            let t = g[j - 1];
            r.prompt.contains(&t) || g[..j - 1].contains(&t)
        })
    })
}

/// The speculative-decoding differential matrix: self-drafting
/// (`spec_k > 0`) must be token-identical to the spec-off run AND to
/// the FCFS oracle at every (threads × shards) matrix point, across
/// the plain pool, chunked prefill, int8 weights, and the lossless f32
/// tier under forced swap pressure. Greedy acceptance makes
/// speculation semantics-free by construction — every emitted token is
/// the model's own argmax, whether it arrived drafted or sampled — and
/// this pins that end to end over the real engine.
#[test]
fn speculative_serve_matches_spec_off_and_fcfs_across_the_matrix() {
    // Lookup-friendly prompts: one short motif repeated, so the
    // drafter's n-gram scan has something to mine from step one.
    let vocab = Qwen3Config::tiny().vocab;
    let reqs: Vec<Request> = (0..3usize)
        .map(|i| Request {
            id: i as u64,
            prompt: [7usize, 1031, 299]
                .iter()
                .cycle()
                .take(9)
                .map(|&t| (t + 97 * i) % vocab)
                .collect(),
            max_new_tokens: 10,
        })
        .collect();
    let machine = MachineSpec::test_numa();
    let configs: [(&str, WeightQuant, ContinuousConfig); 4] = [
        (
            "plain",
            WeightQuant::F32,
            ContinuousConfig::builder().block_size(4).num_blocks(64).max_batch(3).build(),
        ),
        (
            "chunked",
            WeightQuant::F32,
            ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(64)
                .max_batch(3)
                .prefill_chunk(3)
                .build(),
        ),
        (
            "int8-weights",
            WeightQuant::Int8,
            ContinuousConfig::builder().block_size(4).num_blocks(64).max_batch(3).build(),
        ),
        (
            "tiered-f32",
            WeightQuant::F32,
            ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(7)
                .max_batch(3)
                .tiering(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) })
                .build(),
        ),
    ];
    for (name, wq, ccfg) in &configs {
        let qcfg = Qwen3Config::tiny().with_weight_quant(*wq);
        let w = Qwen3Weights::random(&qcfg, 71);
        let mut oracle = Coordinator::new(Qwen3Engine::new(w, 1, 128));
        let want = oracle.serve(&reqs, &ServeOptions::fcfs());
        for shards in shard_counts() {
            for threads in thread_counts() {
                let mut run = |spec_k: usize| {
                    let w = Qwen3Weights::random(&qcfg, 71);
                    let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 128));
                    let mut opts = ServeOptions::continuous(ccfg.clone())
                        .threads(threads)
                        .shards(shards)
                        .machine(machine.clone());
                    if spec_k > 0 {
                        opts = opts.spec_k(spec_k);
                    }
                    c.serve(&reqs, &opts)
                };
                let off = run(0);
                let on = run(4);
                assert_eq!(
                    want.outputs, off.outputs,
                    "{name}: spec-off diverged from FCFS at {threads}T x {shards}S"
                );
                assert_eq!(
                    off.outputs, on.outputs,
                    "{name}: speculation changed tokens at {threads}T x {shards}S"
                );
                assert!(off.spec.is_none(), "{name}: spec-off runs carry no summary");
                let sm = on.spec.as_ref().expect("spec-on runs carry the summary");
                assert_eq!(
                    sm.drafted,
                    sm.accepted + sm.rejected,
                    "{name}: the draft ledger must balance"
                );
                // Wherever the emitted stream revisits a token with
                // headroom left, the drafter must have proposed — pin
                // it on the preemption-free config, where every
                // planned draft survives to commit.
                if *name == "plain" && stream_has_early_repeat(&reqs, &want) {
                    assert!(sm.drafted > 0, "{name}: a repeating stream must draft");
                }
            }
        }
    }
}

/// The engine's own generate() agrees with serve() outputs (the report
/// path adds no divergence).
#[test]
fn serve_agrees_with_generate() {
    let (cfg, mut c) = coordinator(15, 1);
    let reqs = synthetic_workload(2, 4, 6, cfg.vocab);
    let rep = c.serve(&reqs, &ServeOptions::fcfs());
    for req in &reqs {
        let toks = c.engine.generate(&req.prompt, req.max_new_tokens);
        let served = &rep.outputs.iter().find(|(id, _)| *id == req.id).unwrap().1;
        assert_eq!(&toks, served);
    }
}
