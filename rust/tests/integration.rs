//! Cross-layer integration tests.
//!
//! These require `make artifacts` to have run (they are skipped with a
//! note otherwise, so `cargo test` works on a fresh checkout too).
//!
//! The key property: the same computation gives the same numbers through
//! all three stacks — L1 Pallas (via the PJRT artifact), the pure-jnp
//! reference (validated by pytest), and the Rust NTT kernels (L3's real
//! execution backend).

use std::path::Path;

use nncase_repro::coordinator::Qwen3Engine;
use nncase_repro::model::{Qwen3Config, Qwen3Weights};
use nncase_repro::ntt::{matmul_blocked, Tensor};
use nncase_repro::runtime::{ArgValue, Manifest, PjrtRuntime};
use nncase_repro::util::Rng;

fn artifacts_dir() -> Option<&'static Path> {
    if !PjrtRuntime::available() {
        eprintln!("skipping: PJRT backend not compiled into this build");
        return None;
    }
    let p = Path::new("artifacts");
    if p.join("manifest.tsv").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn pallas_matmul_artifact_matches_ntt() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir.join("manifest.tsv")).unwrap();
    let mut rt = PjrtRuntime::cpu(dir).unwrap();
    let mut rng = Rng::new(0xA1);
    for (name, m, k, n) in [
        ("matmul_16x16x16", 16usize, 16usize, 16usize),
        ("matmul_64x64x64", 64, 64, 64),
        ("matmul_64x128x32", 64, 128, 32),
    ] {
        let entry = manifest.get(name).expect(name);
        rt.load(name, &entry.path).unwrap();
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let out = rt
            .run_f32(name, &[(&a.data, &[m, k]), (&b.data, &[k, n])])
            .unwrap();
        let want = matmul_blocked(&a, &b);
        let maxdiff = out[0]
            .iter()
            .zip(&want.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(
            maxdiff < 1e-3,
            "{name}: Pallas artifact vs NTT kernel differ by {maxdiff}"
        );
    }
}

#[test]
fn pallas_attention_artifact_matches_ntt_composition() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir.join("manifest.tsv")).unwrap();
    let mut rt = PjrtRuntime::cpu(dir).unwrap();
    let entry = manifest.get("attention_32x64").unwrap();
    rt.load("attn", &entry.path).unwrap();
    let (m, d) = (32usize, 64usize);
    let mut rng = Rng::new(0xB2);
    let q = Tensor::randn(&[m, d], &mut rng, 0.3);
    let k = Tensor::randn(&[d, m], &mut rng, 0.3);
    let v = Tensor::randn(&[m, d], &mut rng, 0.3);
    let out = rt
        .run_f32("attn", &[(&q.data, &[m, d]), (&k.data, &[d, m]), (&v.data, &[m, d])])
        .unwrap();
    // NTT composition: exp(Q@K) @ V.
    let mut s = matmul_blocked(&q, &k);
    nncase_repro::ntt::exp_inplace(&mut s.data);
    let want = matmul_blocked(&s, &v);
    let maxdiff = out[0]
        .iter()
        .zip(&want.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(maxdiff < 1e-2, "fused attention differs by {maxdiff}");
}

/// The flagship parity test: the JAX decode step (weights baked into the
/// HLO) and the Rust NTT engine (weights from weights.bin) produce the
/// same logits for a multi-token greedy decode.
#[test]
fn decode_artifact_matches_ntt_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir.join("manifest.tsv")).unwrap();
    let mut rt = PjrtRuntime::cpu(dir).unwrap();
    let entry = manifest.get("decode_tiny").unwrap();
    rt.load("decode", &entry.path).unwrap();

    let cfg = Qwen3Config::tiny();
    let weights = Qwen3Weights::from_file(&cfg, &dir.join("weights.bin")).unwrap();
    let embedding = weights.embedding.clone();
    let mut engine = Qwen3Engine::new(weights, 2, 16);

    let max_seq = 16usize;
    let kvd = cfg.kv_heads * cfg.head_dim;
    let mut kcache = vec![0.0f32; cfg.layers * max_seq * kvd];
    let mut vcache = vec![0.0f32; cfg.layers * max_seq * kvd];

    // Weight arguments in `weight_specs` order (embedding excluded) —
    // the artifact takes weights positionally because HLO text elides
    // large constants.
    let weight_args = |w: &Qwen3Weights| -> Vec<(Vec<f32>, Vec<usize>)> {
        let mut v = Vec::new();
        for l in &w.layers {
            for t in [
                &l.attn_norm, &l.wq, &l.wk, &l.wv, &l.wo, &l.mlp_norm, &l.w_gate,
                &l.w_up, &l.w_down,
            ] {
                v.push((t.data.clone(), t.shape.0.clone()));
            }
        }
        v.push((w.final_norm.data.clone(), w.final_norm.shape.0.clone()));
        v.push((w.lm_head.data.clone(), w.lm_head.shape.0.clone()));
        v
    };
    let wargs = weight_args(&Qwen3Weights::from_file(&cfg, &dir.join("weights.bin")).unwrap());

    let x_shape = [1usize, cfg.hidden];
    let cache_shape = [cfg.layers, max_seq, kvd];
    let tokens = [5usize, 151, 89, 1023, 7];
    for (pos, &tok) in tokens.iter().enumerate() {
        // PJRT path.
        let x = embedding.row(tok);
        let mut args: Vec<ArgValue> =
            wargs.iter().map(|(d, s)| ArgValue::F32(d, s)).collect();
        args.push(ArgValue::F32(x, &x_shape));
        args.push(ArgValue::F32(&kcache, &cache_shape));
        args.push(ArgValue::F32(&vcache, &cache_shape));
        args.push(ArgValue::I32Scalar(pos as i32));
        let out = rt.run_args("decode", &args).unwrap();
        let (logits_jax, knew, vnew) = (&out[0], &out[1], &out[2]);
        // Write back the cache rows.
        for l in 0..cfg.layers {
            let dst = l * max_seq * kvd + pos * kvd;
            kcache[dst..dst + kvd].copy_from_slice(&knew[l * kvd..(l + 1) * kvd]);
            vcache[dst..dst + kvd].copy_from_slice(&vnew[l * kvd..(l + 1) * kvd]);
        }
        // NTT engine path.
        let logits_ntt = engine.decode_step(tok, pos);
        assert_eq!(logits_jax.len(), logits_ntt.len());
        let maxdiff = logits_jax
            .iter()
            .zip(&logits_ntt)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            maxdiff < 2e-3,
            "pos {pos}: JAX artifact vs NTT engine logits differ by {maxdiff}"
        );
        // Greedy argmax agreement (the user-visible behaviour).
        let am_jax = nncase_repro::coordinator::argmax(logits_jax);
        let am_ntt = nncase_repro::coordinator::argmax(&logits_ntt);
        assert_eq!(am_jax, am_ntt, "pos {pos}: argmax disagrees");
    }
}

/// Full pipeline on the decode graph compiles and the resulting plan is
/// executable-shaped (steps reference valid buffers).
#[test]
fn pipeline_produces_consistent_plan() {
    use nncase_repro::pipeline::{CompileOptions, Compiler};
    let cfg = Qwen3Config::tiny();
    let g = nncase_repro::model::decode_graph(&cfg, 4, Some(2));
    let opts = CompileOptions { sat_extraction: false, ..Default::default() };
    let c = Compiler::new(nncase_repro::cost::MachineSpec::ryzen_5900x(), opts);
    let m = c.compile(&g);
    for step in &m.plan.steps {
        assert!((step.output.0 as usize) < m.plan.bufs.len());
        for b in &step.inputs {
            assert!((b.0 as usize) < m.plan.bufs.len());
        }
    }
    // Memory plan offsets stay inside the arena.
    for (b, &off) in &m.plan.mem.offsets {
        assert!(off + m.plan.bufs.sizes[b.0 as usize] <= m.plan.mem.arena_bytes);
    }
}
