//! Property-based tests over randomized inputs (our own generator-based
//! harness; the offline vendor set has no proptest). Each property runs
//! across many random seeds and asserts an invariant of a subsystem.

use nncase_repro::codegen::{bufferize, plan_memory, Liveness, PlannerKind};
use nncase_repro::cost::MachineSpec;
use nncase_repro::dist::{reshard_cost_bytes, NdSbp, Placement, Sbp};
use nncase_repro::egraph::{extract_greedy, EGraph, Runner, RunnerLimits};
use nncase_repro::ir::{BinaryKind, DType, Graph, NodeId, UnaryKind};
use nncase_repro::model::Qwen3Config;
use nncase_repro::ntt::{
    dequantize_block_i4, dequantize_block_i8, dequantize_groups_i4, dequantize_groups_i8,
    matmul_blocked, matmul_naive, matmul_prepacked, matmul_quant_rows, pack_i4, quantize_block_i4,
    quantize_block_i8, quantize_groups_i4, quantize_groups_i8, unpack_i4, PackedMat, QuantMat,
    Tensor, WeightQuant,
};
use nncase_repro::rewrite::transpose_rules;
use nncase_repro::sim::{simulate_decode, Framework};
use nncase_repro::util::Rng;

/// Random square-tensor DAG of transposes, unaries and binaries.
fn random_graph(rng: &mut Rng, n_ops: usize) -> (Graph, NodeId) {
    let mut g = Graph::new();
    let mut pool: Vec<NodeId> = vec![
        g.input("a", &[16, 16], DType::F32),
        g.input("b", &[16, 16], DType::F32),
    ];
    for _ in 0..n_ops {
        let pick = pool[rng.below(pool.len())];
        let kind = rng.below(4);
        let other = pool[rng.below(pool.len())];
        let id = match kind {
            0 => g.transpose(pick, &[1, 0]),
            1 => g.unary(UnaryKind::Exp, pick),
            2 => g.unary(UnaryKind::Neg, pick),
            _ => g.binary(BinaryKind::Add, pick, other),
        };
        pool.push(id);
    }
    let out = *pool.last().unwrap();
    g.mark_output(out);
    (g, out)
}

/// Saturation + extraction never changes the output type and never
/// *increases* the number of live transposes.
#[test]
fn prop_saturation_preserves_type_and_improves() {
    let mut rng = Rng::new(0xF00D);
    for round in 0..25 {
        let n = 4 + rng.below(8);
        let (g, out) = random_graph(&mut rng, n);
        let want_ty = g.node(out).ty.clone();
        let before = count_transposes(&g);
        let (mut eg, map) = EGraph::from_graph(&g);
        let rules = transpose_rules();
        let refs: Vec<&dyn nncase_repro::egraph::Rewrite> =
            rules.iter().map(|r| r.as_ref()).collect();
        Runner::new(&mut eg)
            .with_limits(RunnerLimits { max_iters: 6, max_nodes: 20_000 })
            .run(&refs);
        let cost = |n: &nncase_repro::egraph::ENode,
                    _: &[&nncase_repro::ir::TensorType],
                    _: &nncase_repro::ir::TensorType|
         -> u64 {
            match n.op {
                nncase_repro::ir::Op::Transpose { .. } => 100,
                _ => 1,
            }
        };
        let ex = extract_greedy(&eg, &[map[out.index()]], &cost);
        let got_ty = &ex.graph.node(*ex.graph.outputs.last().unwrap()).ty;
        assert_eq!(got_ty.shape, want_ty.shape, "round {round}: shape changed");
        assert_eq!(got_ty.dtype, want_ty.dtype);
        let after = count_transposes(&ex.graph);
        assert!(
            after <= before,
            "round {round}: transposes grew {before} -> {after}\n{}",
            ex.graph.dump()
        );
    }
}

fn count_transposes(g: &Graph) -> usize {
    g.live_nodes()
        .iter()
        .filter(|&&id| matches!(g.node(id).op, nncase_repro::ir::Op::Transpose { .. }))
        .count()
}

/// Memory planner invariant: for every planner, lifetime-overlapping
/// buffers never overlap in the arena, and the SAT planner never loses
/// to first-fit.
#[test]
fn prop_memplan_no_overlap_random_graphs() {
    let mut rng = Rng::new(0xBEE);
    for _round in 0..20 {
        let n = 6 + rng.below(10);
        let (g, _) = random_graph(&mut rng, n);
        let bufs = bufferize(&g);
        let live = Liveness::compute(&g, &bufs);
        let ff = plan_memory(&bufs, &live, PlannerKind::FirstFit);
        let sat = plan_memory(&bufs, &live, PlannerKind::SatOptimal);
        assert!(sat.arena_bytes <= ff.arena_bytes);
        for plan in [&ff, &sat] {
            let inter = bufs.intermediates();
            for (i, &a) in inter.iter().enumerate() {
                for &b in inter.iter().skip(i + 1) {
                    if live.overlap(a, b) {
                        let (oa, ob) = (plan.offsets[&a], plan.offsets[&b]);
                        let (sa, sb) = (bufs.sizes[a.0 as usize], bufs.sizes[b.0 as usize]);
                        assert!(oa + sa <= ob || ob + sb <= oa, "overlap in {:?}", plan.kind);
                    }
                }
            }
        }
    }
}

/// Resharding cost properties: identity is free, costs are non-negative,
/// and P->B (all-reduce) dominates S->B (all-gather) at equal size.
#[test]
fn prop_reshard_cost_properties() {
    let ab = nncase_repro::cost::AlphaBeta { alpha_s: 1e-6, beta_bytes_per_s: 20e9 };
    let mut rng = Rng::new(0x5B9);
    for _ in 0..50 {
        let p = Placement::line(2 + rng.below(7));
        let bytes = 1u64 << (10 + rng.below(16));
        let sbps =
            [NdSbp::split1(0), NdSbp::split1(1), NdSbp::broadcast(1), NdSbp(vec![Sbp::Partial])];
        for s in &sbps {
            assert_eq!(reshard_cost_bytes(s, s, bytes, &p, &ab), 0.0, "identity not free");
            for t in &sbps {
                assert!(reshard_cost_bytes(s, t, bytes, &p, &ab) >= 0.0);
            }
        }
        let p2b =
            reshard_cost_bytes(&NdSbp(vec![Sbp::Partial]), &NdSbp::broadcast(1), bytes, &p, &ab);
        let s2b = reshard_cost_bytes(&NdSbp::split1(0), &NdSbp::broadcast(1), bytes, &p, &ab);
        assert!(p2b >= s2b, "all-reduce must dominate all-gather");
    }
}

/// Blocked matmul equals naive matmul on random (including awkward)
/// shapes — the NTT packing path is shape-safe.
#[test]
fn prop_blocked_matmul_random_shapes() {
    let mut rng = Rng::new(0xAB);
    for _ in 0..30 {
        let m = 1 + rng.below(70);
        let k = 1 + rng.below(70);
        let n = 1 + rng.below(70);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let want = matmul_naive(&a, &b);
        let got = matmul_blocked(&a, &b);
        let diff = want.max_abs_diff(&got);
        assert!(diff < 1e-3, "({m},{k},{n}): diff {diff}");
    }
}

/// Simulator monotonicity: more threads never reduce simulated
/// throughput; larger models never increase it; lower precision never
/// decreases it. (These hold for every framework model.)
#[test]
fn prop_simulator_monotonicity() {
    let m = MachineSpec::ryzen_5900x();
    for fw in Framework::all() {
        let tput = |cfg: &Qwen3Config, t: usize| simulate_decode(cfg, t, &fw, &m, 8).tokens_per_s;
        let c06_f16 = Qwen3Config::qwen3_0_6b(DType::F16);
        let c06_f32 = Qwen3Config::qwen3_0_6b(DType::F32);
        let c17 = Qwen3Config::qwen3_1_7b(DType::F16);
        let mut prev = 0.0;
        for t in [1usize, 2, 4, 8, 12] {
            let cur = tput(&c06_f16, t);
            assert!(
                cur >= prev * 0.90,
                "{}: threads {t} dropped throughput {prev} -> {cur}",
                fw.kind.name()
            );
            prev = cur;
        }
        assert!(tput(&c17, 1) < tput(&c06_f16, 1), "bigger model must be slower");
        // F16 halves the weight stream: a clear win for memory-bound
        // frameworks; compute-bound MLC only must not get much worse
        // (the f16->f32 conversion penalty).
        if matches!(
            fw.kind,
            nncase_repro::sim::FrameworkKind::Nncase | nncase_repro::sim::FrameworkKind::LlamaCpp
        ) {
            assert!(tput(&c06_f16, 1) > tput(&c06_f32, 1), "f16 must beat f32");
        } else {
            assert!(tput(&c06_f16, 1) > 0.85 * tput(&c06_f32, 1));
        }
    }
}

/// Cold-tier quantization invariants: for random blocks of random sizes
/// and scales, the int8 per-block round trip is bounded by `scale / 2`
/// per element (affine rounding), and degenerate blocks — constant
/// values, where `scale == 0` — round-trip exactly through the
/// zero-point.
#[test]
fn prop_kv_quant_roundtrip_bounded() {
    let mut rng = Rng::new(0xC01D);
    for round in 0..50 {
        let n = 1 + rng.below(512);
        // Sweep magnitudes across several orders so the bound is
        // exercised on tiny and huge dynamic ranges alike.
        let mag = 10f32.powi(rng.below(7) as i32 - 3);
        let offset = (rng.normal()) * mag;
        let src: Vec<f32> = (0..n).map(|_| rng.normal() * mag + offset).collect();
        let mut q = vec![0i8; n];
        let (scale, zero) = quantize_block_i8(&src, &mut q);
        assert!(scale >= 0.0, "round {round}: negative scale");
        let mut back = vec![0.0f32; n];
        dequantize_block_i8(&q, scale, zero, &mut back);
        // scale/2 from round-to-nearest, plus a whisker of f32 slack on
        // the reconstruction arithmetic itself.
        let bound = scale * 0.5 + (zero.abs() + 256.0 * scale) * 1e-6;
        for (i, (a, b)) in src.iter().zip(&back).enumerate() {
            assert!(
                (a - b).abs() <= bound,
                "round {round} elem {i}: |{a} - {b}| > {bound} (scale {scale})"
            );
        }
        // Constant block of the same magnitude: exact.
        let c = rng.normal() * mag;
        let cst = vec![c; n];
        let mut qc = vec![0i8; n];
        let (s, z) = quantize_block_i8(&cst, &mut qc);
        assert_eq!(s, 0.0, "round {round}: constant block must have scale 0");
        let mut out = vec![0.0f32; n];
        dequantize_block_i8(&qc, s, z, &mut out);
        assert_eq!(out, cst, "round {round}: constant block must round-trip exactly");
    }
}

/// Group-wise weight-quantization invariants, int8 and int4, over
/// random lengths/magnitudes/group sizes: every element round-trips
/// within its *group's* `scale / 2` (plus f32 reconstruction slack),
/// constant groups round-trip exactly through the zero-point, and the
/// int4 nibble pack/unpack is the identity on codes.
#[test]
fn prop_weight_group_quant_roundtrip_bounded() {
    let mut rng = Rng::new(0x6A0);
    for round in 0..40 {
        let n = 1 + rng.below(400);
        let group = [8usize, 32, 64][rng.below(3)];
        let mag = 10f32.powi(rng.below(5) as i32 - 2);
        let offset = rng.normal() * mag;
        let src: Vec<f32> = (0..n).map(|_| rng.normal() * mag + offset).collect();
        let groups = n.div_ceil(group);
        let (mut scales, mut zeros) = (vec![0.0f32; groups], vec![0.0f32; groups]);

        let mut codes = vec![0i8; n];
        quantize_groups_i8(&src, group, &mut codes, &mut scales, &mut zeros);
        let mut back = vec![0.0f32; n];
        dequantize_groups_i8(&codes, group, &scales, &zeros, &mut back);
        for (i, (a, b)) in src.iter().zip(&back).enumerate() {
            let g = i / group;
            let bound = scales[g] * 0.5 + (zeros[g].abs() + 256.0 * scales[g]) * 1e-6;
            assert!(
                (a - b).abs() <= bound,
                "round {round} i8 elem {i}: |{a} - {b}| > {bound} (group {g})"
            );
        }

        let mut packed = vec![0u8; n.div_ceil(2)];
        quantize_groups_i4(&src, group, &mut packed, &mut scales, &mut zeros);
        let mut back4 = vec![0.0f32; n];
        dequantize_groups_i4(&packed, n, group, &scales, &zeros, &mut back4);
        for (i, (a, b)) in src.iter().zip(&back4).enumerate() {
            let g = i / group;
            let bound = scales[g] * 0.5 + (zeros[g].abs() + 16.0 * scales[g]) * 1e-6;
            assert!(
                (a - b).abs() <= bound,
                "round {round} i4 elem {i}: |{a} - {b}| > {bound} (group {g})"
            );
        }

        // Constant input: both widths exact via the zero-point.
        let c = rng.normal() * mag;
        let cst = vec![c; n];
        let mut qc = vec![0i8; n];
        quantize_groups_i8(&cst, group, &mut qc, &mut scales, &mut zeros);
        assert!(scales.iter().all(|&s| s == 0.0), "round {round}: constant scale");
        let mut out = vec![0.0f32; n];
        dequantize_groups_i8(&qc, group, &scales, &zeros, &mut out);
        assert_eq!(out, cst, "round {round}: constant i8 round trip");
        let mut qc4 = vec![0u8; n];
        let (s4, z4) = quantize_block_i4(&cst, &mut qc4);
        assert_eq!(s4, 0.0);
        dequantize_block_i4(&qc4, s4, z4, &mut out);
        assert_eq!(out, cst, "round {round}: constant i4 round trip");

        // pack/unpack identity on random nibble codes.
        let raw: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
        let mut pk = vec![0u8; n.div_ceil(2)];
        pack_i4(&raw, &mut pk);
        let mut un = vec![0u8; n];
        unpack_i4(&pk, n, &mut un);
        assert_eq!(raw, un, "round {round}: pack_i4/unpack_i4 identity");
    }
}

/// The fused dequant-GEMM contract over random shapes: matmul over a
/// `QuantMat` (int8 and int4) is *bit-identical* to `matmul_prepacked`
/// over the dequantized weights — the quantized path changes the bytes
/// streamed, never the arithmetic — and MR-aligned row shards compose
/// bitwise (the SPMD partition contract of the batched engine).
#[test]
fn prop_quant_matmul_bitwise_matches_dequant_oracle() {
    let mut rng = Rng::new(0x6A1);
    for round in 0..15 {
        let rows = 1 + rng.below(20);
        let k = 1 + rng.below(90);
        let n = 1 + rng.below(90);
        let x = Tensor::randn(&[rows, k], &mut rng, 1.0);
        let w = Tensor::randn(&[k, n], &mut rng, 0.05);
        for mode in [WeightQuant::Int8, WeightQuant::Int4] {
            let qm = QuantMat::quantize(&w, mode);
            let pm = PackedMat::pack(&qm.dequantize());
            let mut want = vec![0.0f32; rows * n];
            matmul_prepacked(&x.data, rows, &pm, &mut want);
            let mut scratch = Vec::new();
            let mut got = vec![0.0f32; rows * n];
            matmul_quant_rows(&x.data, rows, &qm, 0, rows, &mut got, &mut scratch);
            assert_eq!(got, want, "round {round} {mode:?} ({rows},{k},{n})");
            let parts = 1 + rng.below(4);
            let shards = nncase_repro::parallel::panel_splits(rows, nncase_repro::ntt::MR, parts);
            let mut sharded = vec![0.0f32; rows * n];
            for &(lo, hi) in &shards {
                matmul_quant_rows(
                    &x.data,
                    rows,
                    &qm,
                    lo,
                    hi,
                    &mut sharded[lo * n..hi * n],
                    &mut scratch,
                );
            }
            assert_eq!(sharded, want, "round {round} {mode:?} {parts}-way shard");
        }
    }
}

/// The in-chunk causal attention contract over random geometries:
/// committing a whole token span to the paged store and then running
/// the fused causal row kernel with window `[0, pos]` per row is
/// **bitwise identical** to sequential single-token steps, where each
/// position's row is computed against a store that only *contains*
/// positions `<= pos`. This is the kernel-level half of the chunked
/// prefill bitwise-identity guarantee (the engine-level half lives in
/// `rust/src/serving/batch_engine.rs` and `tests/serving.rs`).
#[test]
fn prop_causal_span_attention_equals_sequential_steps() {
    use nncase_repro::ntt::{
        attn_context_paged, attn_row_causal_paged, attn_scores_paged, paged_row,
        softmax_inplace,
    };
    let mut rng = Rng::new(0xCA5);
    for round in 0..10 {
        let bs = 2 + rng.below(6);
        let head_dim = 4 + 4 * rng.below(3);
        let width = head_dim * (1 + rng.below(2));
        let head_off = width - head_dim;
        let nblocks = 2 + rng.below(3);
        let span = 1 + rng.below(nblocks * bs);
        let scale = 1.0 / (head_dim as f32).sqrt();
        // Scattered, non-contiguous block table over a larger arena.
        let arena_blocks = nblocks + 3;
        let mut table: Vec<u32> = (0..arena_blocks as u32).collect();
        for i in (1..table.len()).rev() {
            table.swap(i, rng.below(i + 1));
        }
        table.truncate(nblocks);
        // Per-position K/V rows and queries.
        let kv_rows: Vec<(Vec<f32>, Vec<f32>)> = (0..span)
            .map(|_| {
                ((0..width).map(|_| rng.normal()).collect(),
                 (0..width).map(|_| rng.normal()).collect())
            })
            .collect();
        let queries: Vec<Vec<f32>> =
            (0..span).map(|_| (0..head_dim).map(|_| rng.normal()).collect()).collect();

        // Sequential oracle: the store grows one position at a time, so
        // row `p` physically cannot see beyond itself.
        let mut seq_k = Tensor::zeros(&[arena_blocks * bs, width]);
        let mut seq_v = Tensor::zeros(&[arena_blocks * bs, width]);
        let mut want = Vec::new();
        for p in 0..span {
            let row = paged_row(&table, bs, p);
            seq_k.row_mut(row).copy_from_slice(&kv_rows[p].0);
            seq_v.row_mut(row).copy_from_slice(&kv_rows[p].1);
            let mut scores = vec![0.0f32; p + 1];
            attn_scores_paged(
                &queries[p], &seq_k, &table, bs, head_off, head_dim, scale, &mut scores,
            );
            softmax_inplace(&mut scores);
            let mut out = vec![0.0f32; head_dim];
            attn_context_paged(&scores, &seq_v, &table, bs, head_off, head_dim, &mut out);
            want.push(out);
        }

        // Chunked: the WHOLE span is committed first (the engine's
        // phase-4-before-phase-5 order), then every row attends through
        // its causal window.
        let mut chunk_k = Tensor::zeros(&[arena_blocks * bs, width]);
        let mut chunk_v = Tensor::zeros(&[arena_blocks * bs, width]);
        for p in 0..span {
            let row = paged_row(&table, bs, p);
            chunk_k.row_mut(row).copy_from_slice(&kv_rows[p].0);
            chunk_v.row_mut(row).copy_from_slice(&kv_rows[p].1);
        }
        for p in 0..span {
            let mut scores = vec![0.0f32; p + 1];
            let mut out = vec![0.0f32; head_dim];
            attn_row_causal_paged(
                &queries[p], &chunk_k, &chunk_v, &table, bs, head_off, head_dim, scale,
                &mut scores, &mut out,
            );
            assert_eq!(
                out, want[p],
                "round {round}: chunked row {p}/{span} (bs {bs}) diverged from its \
                 sequential step"
            );
        }
    }
}

/// `util::Stats` hardening: across random series — empty, singleton,
/// long, and magnitude-swept — every accessor the `serve_report.v1`
/// JSON and the report renders draw from (mean, min, max, percentiles,
/// stddev) returns a finite number, never NaN or infinity. This is the
/// property that keeps a degenerate run (zero requests, zero decode
/// iterations, all-equal samples) from emitting unparseable JSON.
#[test]
fn prop_stats_accessors_never_yield_nan() {
    use nncase_repro::util::Stats;
    let finite = |name: &str, v: f64, ctx: &str| {
        assert!(v.is_finite(), "{name} yielded non-finite {v} on {ctx}");
    };
    let check = |s: &Stats, ctx: &str| {
        finite("mean", s.mean(), ctx);
        finite("min", s.min(), ctx);
        finite("max", s.max(), ctx);
        finite("sum", s.sum(), ctx);
        finite("stddev", s.stddev(), ctx);
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            finite("percentile", s.percentile(p), ctx);
        }
        finite("p99", s.p99(), ctx);
    };
    check(&Stats::default(), "empty series");
    let mut rng = Rng::new(0x57A7);
    for round in 0..50 {
        let n = rng.below(200); // 0 included: empties keep showing up
        let mag = 10f64.powi(rng.below(13) as i32 - 6);
        let mut s = Stats::default();
        for _ in 0..n {
            s.push(rng.normal() as f64 * mag);
        }
        check(&s, &format!("round {round} (n={n}, mag={mag:e})"));
        assert_eq!(s.len(), n);
        if n > 0 {
            assert!(s.min() <= s.percentile(50.0) && s.percentile(50.0) <= s.max());
        }
    }
    // All-equal series: stddev's variance subtraction cancels to ~0 and
    // must not go negative-then-NaN through the sqrt.
    let mut eq = Stats::default();
    for _ in 0..17 {
        eq.push(3.25e8);
    }
    check(&eq, "all-equal series");
    assert!(eq.stddev() >= 0.0);
    // And the serving render built on these accessors stays NaN-free on
    // a default (all-empty) metrics value — the degenerate-report path.
    let r = nncase_repro::serving::ServingMetrics::default().render();
    assert!(!r.contains("NaN") && !r.contains("inf"), "{r}");
}

/// KV-cache accounting: the config-level bytes-per-token formula matches
/// the engine's actual cache allocation.
#[test]
fn prop_kv_accounting_matches_engine() {
    let cfg = Qwen3Config::tiny();
    let per_token = cfg.kv_bytes_per_token();
    // Engine allocates 2 tensors of [max_seq, kvh*hd] f32 per layer.
    let max_seq = 64;
    let engine_bytes =
        (2 * cfg.layers * max_seq * cfg.kv_heads * cfg.head_dim * 4) as u64;
    assert_eq!(per_token * max_seq as u64, engine_bytes);
}

/// Serve-time autotune: across every machine preset × weight-quant
/// mode, the planner is (a) deterministic — two searches of the same
/// triple return the same plan, (b) legal — every bound of
/// `ServePlan::check_legal` holds, and (c) minimal — the chosen plan's
/// predicted cost is <= every rejected candidate's, so the search
/// really returns the argmin of its own cost model.
#[test]
fn prop_autotune_plan_is_deterministic_legal_and_minimal() {
    use nncase_repro::serving::autotune::{plan_for, search_plan};

    let machines =
        [MachineSpec::ryzen_5900x(), MachineSpec::tpu_like(), MachineSpec::test_numa()];
    for machine in &machines {
        for wq in [WeightQuant::F32, WeightQuant::Int8, WeightQuant::Int4] {
            let model = Qwen3Config::tiny().with_weight_quant(wq);
            for max_batch in [1usize, 8] {
                let a = search_plan(&model, machine, max_batch);
                let b = search_plan(&model, machine, max_batch);
                assert_eq!(
                    a.chosen, b.chosen,
                    "search must be deterministic on {}/{}/b{max_batch}",
                    machine.name,
                    wq.name()
                );
                a.chosen.check_legal(&model).unwrap_or_else(|e| {
                    panic!(
                        "illegal plan on {}/{}/b{max_batch}: {e}",
                        machine.name,
                        wq.name()
                    )
                });
                assert!(
                    !a.rejected.is_empty(),
                    "the search must actually weigh alternatives ({}/{})",
                    machine.name,
                    wq.name()
                );
                for r in &a.rejected {
                    assert!(
                        a.chosen.predicted_cost_s <= r.predicted_cost_s,
                        "{}/{}/b{max_batch}: chosen {:.6}s loses to rejected {:.6}s ({})",
                        machine.name,
                        wq.name(),
                        a.chosen.predicted_cost_s,
                        r.predicted_cost_s,
                        r.render()
                    );
                }
                // The in-process cache must hand back the same decision
                // the raw search makes.
                let cached = plan_for(&model, machine, max_batch);
                assert_eq!(cached.plan_hash(), a.chosen.plan_hash());
            }
        }
    }
}

/// Prompt-lookup drafter invariants over random contexts: every
/// proposal is the verbatim continuation of an earlier occurrence of
/// the context's trailing n-gram (a contiguous subsequence of the
/// context — the drafter invents nothing), its length never exceeds
/// `max_k`, degenerate inputs propose nothing, and proposals are
/// deterministic. Small alphabets force dense repetition, large ones
/// exercise the no-match path.
#[test]
fn prop_spec_drafter_proposes_verbatim_continuations() {
    use nncase_repro::serving::spec::propose;
    let mut rng = Rng::new(0xD8AF7);
    for _ in 0..300 {
        let alphabet = 2 + rng.below(12);
        let len = rng.below(40);
        let context: Vec<usize> = (0..len).map(|_| rng.below(alphabet)).collect();
        let ngram = 1 + rng.below(4);
        let max_k = rng.below(6);
        let drafts = propose(&context, ngram, max_k);
        assert!(drafts.len() <= max_k, "proposal exceeds max_k={max_k}: {drafts:?}");
        assert_eq!(
            drafts,
            propose(&context, ngram, max_k),
            "the drafter must be deterministic"
        );
        if context.len() < 2 || max_k == 0 {
            assert!(drafts.is_empty(), "degenerate inputs must propose nothing");
            continue;
        }
        if drafts.is_empty() {
            continue;
        }
        // The proposal must be the continuation of some earlier
        // occurrence of a trailing n-gram: find a window of the
        // context that ends with a suffix of the context and is
        // followed verbatim by the drafts.
        let ok = (1..=ngram.min(context.len() - 1)).any(|n| {
            let pattern = &context[context.len() - n..];
            (0..context.len() - n).any(|i| {
                &context[i..i + n] == pattern
                    && context[i + n..].starts_with(&drafts)
            })
        });
        assert!(
            ok,
            "proposal {drafts:?} is not a verbatim n-gram continuation of {context:?} \
             (ngram={ngram})"
        );
    }
}
