//! Observability hot-path test: tracing must cost nothing when off and
//! never allocate when on.
//!
//! A counting `#[global_allocator]` wraps the system allocator; the
//! test drives the tracing hooks through both states and asserts a
//! **zero** allocation delta — the acceptance criterion that the
//! disabled path compiles to a branch on a `None` and the enabled ring
//! only ever writes into storage reserved at construction (wrap-around
//! overwrites, it never grows).
//!
//! This file deliberately holds a single `#[test]`: integration tests
//! in one binary run on parallel threads, and any concurrent test's
//! allocations would land in the shared counter and break the
//! zero-delta asserts. Trace-content integration coverage lives in
//! `rust/tests/serving.rs`; ring/merge unit tests live in
//! `rust/src/obs/`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use nncase_repro::obs::{self, Code, Ring, TraceLog, WorkerTrace};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn tracing_hot_path_never_allocates() {
    // Disabled: every hook is one branch on a None — no clock read, no
    // ring write, and (asserted here) no allocation across 10k steps.
    let mut off: Option<&mut Ring> = None;
    let before = allocs();
    for i in 0..10_000u32 {
        let t0 = obs::mark(&off);
        obs::span(&mut off, Code::QkvGemm, t0, i);
        obs::instant(&mut off, Code::Enqueue, i);
        assert_eq!(t0, 0, "the disabled mark must not read the clock");
    }
    assert_eq!(allocs() - before, 0, "disabled tracing hooks must not allocate");

    // Enabled: the ring's storage is reserved once at construction;
    // record/close/instant stay allocation-free far past wrap-around.
    let mut ring = Ring::with_capacity(256, Instant::now());
    let before = allocs();
    for i in 0..2_000u32 {
        let mut on = Some(&mut ring);
        let t0 = obs::mark(&on);
        obs::span(&mut on, Code::Attn, t0, i);
        obs::instant(&mut on, Code::Admit, i);
    }
    assert_eq!(allocs() - before, 0, "ring writes must not allocate, even wrapped");
    assert_eq!(ring.written(), 4_000, "every hook call must have recorded");
    assert!(ring.dropped() > 0, "the 256-slot ring must have wrapped");

    // Failpoint hot path: with no plan installed the engine-side hook is
    // the same single branch-on-None as tracing, and even an *armed*
    // plan's per-step checks are pure atomics — neither may allocate.
    // (The serve path's no-fault acceptance bar — zero allocation and
    // bitwise-identical behaviour with `faults: None` — rests on this.)
    use nncase_repro::serving::FaultPlan;
    let none: Option<&FaultPlan> = None;
    let armed = FaultPlan::new().fail_fetch(1_000_000).corrupt_spill(1_000_000);
    let before = allocs();
    for wi in 0..10_000usize {
        if let Some(fp) = none {
            fp.maybe_panic(Code::Attn, wi);
        }
        armed.begin_iter();
        armed.maybe_panic(Code::Attn, wi % 4);
        let _ = armed.take_fetch_fail();
        let _ = armed.take_corrupt();
        let _ = armed.take_alloc_fail();
    }
    assert_eq!(allocs() - before, 0, "failpoint checks must not allocate");
    assert_eq!(armed.injected(), 0, "distant nth counters must not fire");

    // Cold path (post-run, allowed to allocate): the wrapped ring still
    // yields a well-formed merged timeline and Chrome export.
    let events = ring.events();
    assert_eq!(events.len(), ring.capacity());
    let log = TraceLog {
        workers: vec![WorkerTrace {
            tid: 0,
            name: "worker 0".into(),
            events,
            dropped: ring.dropped(),
        }],
    };
    let json = log.to_chrome_json();
    assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
    assert!(json.ends_with("]}"));
    assert_eq!(
        json.matches("\"ph\":\"B\"").count(),
        json.matches("\"ph\":\"E\"").count(),
        "span opens and closes must balance"
    );
}
