//! Fault-tolerance differential tests: every deterministic failpoint —
//! worker panics mid-phase, cold-tier fetch failures, payload
//! corruption, transient allocation failures — must be *invisible in
//! the tokens*. Recovery rolls interrupted work back to committed KV
//! boundaries and replays it, and greedy argmax is per-request
//! deterministic, so a recovered run is token-identical to the
//! unperturbed FCFS oracle. Each test also pins the zero-leak
//! invariant: the post-panic pool audit must find nothing to reclaim.
//!
//! The `#[ignore]`d test at the bottom is the CI chaos hook: it runs
//! the plain differential under whatever `PALLAS_FAILPOINTS` spec the
//! environment carries (the serve path picks the env spec up when no
//! explicit plan is set; the FCFS oracle never injects).

use nncase_repro::coordinator::{
    synthetic_workload, Coordinator, Qwen3Engine, Request, ServeOptions, ServeReport,
};
use nncase_repro::model::{Qwen3Config, Qwen3Weights};
use nncase_repro::obs::Code;
use nncase_repro::serving::{ContinuousConfig, FaultPlan, KvQuant, TierConfig};

fn coordinator(seed: u64) -> (Qwen3Config, Coordinator) {
    let cfg = Qwen3Config::tiny();
    let w = Qwen3Weights::random(&cfg, seed);
    (cfg.clone(), Coordinator::new(Qwen3Engine::new(w, 1, 128)))
}

/// Worker counts under test (same `PALLAS_TEST_THREADS` pinning as
/// tests/serving.rs, through the lenient env-knob parser).
fn thread_counts() -> Vec<usize> {
    nncase_repro::util::env_knob("PALLAS_TEST_THREADS", |t: &usize| *t >= 1)
        .map_or_else(|| vec![1, 2, 4], |t| vec![t])
}

fn oracle_outputs(seed: u64, reqs: &[Request]) -> ServeReport {
    let (_, mut c) = coordinator(seed);
    c.serve(reqs, &ServeOptions::fcfs())
}

/// Assert the recovered run is token-identical to the oracle and that
/// the recovery audit found no leaked blocks.
fn assert_clean_recovery(want: &ServeReport, got: &ServeReport, ctx: &str) {
    assert_eq!(want.outputs, got.outputs, "{ctx}: recovery changed tokens");
    let m = got.serving.as_ref().expect("continuous metrics");
    assert_eq!(m.fault_leaked_blocks, 0, "{ctx}: recovery audit must find no leaks");
    assert!(got.faults.is_some(), "{ctx}: continuous runs carry the fault ledger");
}

/// The tentpole matrix: an injected worker panic at each SPMD phase, at
/// every worker count, recovers to oracle-identical tokens. `worker:
/// None` arms every participant — the one-shot latch guarantees exactly
/// one fires, whichever thread hits the failpoint first.
#[test]
fn worker_panic_matrix_recovers_to_oracle_tokens() {
    let (cfg, _) = coordinator(71);
    let reqs = synthetic_workload(4, 4, 8, cfg.vocab);
    let want = oracle_outputs(71, &reqs);
    // Attn and MlpGemm run on every step; LmHead needs a sampling step,
    // so its iteration lands well inside decode.
    let sites: [(Code, u32); 3] = [(Code::Attn, 2), (Code::MlpGemm, 3), (Code::LmHead, 8)];
    for (phase, iter) in sites {
        for threads in thread_counts() {
            let (_, mut c) = coordinator(71);
            let ccfg = ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(64)
                .max_batch(4)
                .build();
            let plan = FaultPlan::new().panic_at(phase, iter, None);
            let got = c.serve(
                &reqs,
                &ServeOptions::continuous(ccfg).threads(threads).faults(plan),
            );
            let ctx = format!("panic@{}#{iter} at {threads}T", phase.name());
            assert_clean_recovery(&want, &got, &ctx);
            let f = got.faults.as_ref().unwrap();
            assert_eq!(f.injected, 1, "{ctx}: the one-shot panic fires exactly once");
            assert_eq!(f.recovered, 1, "{ctx}: one epoch restart absorbs it");
            assert!(f.requeued >= 1, "{ctx}: in-flight work must be rolled back");
        }
    }
}

/// Panic recovery composed with the tiered pool under forced swap
/// pressure: the epoch restart must also reset tier state (cold slots,
/// pending tier ops) without leaking either pool.
#[test]
fn worker_panic_recovers_under_tier_pressure() {
    let (cfg, _) = coordinator(72);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle_outputs(72, &reqs);
    for threads in thread_counts() {
        let (_, mut c) = coordinator(72);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(7)
            .max_batch(3)
            .tiering(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) })
            .build();
        let plan = FaultPlan::new().panic_at(Code::Attn, 5, None);
        let got = c.serve(
            &reqs,
            &ServeOptions::continuous(ccfg).threads(threads).faults(plan),
        );
        let ctx = format!("tiered panic at {threads}T");
        assert_clean_recovery(&want, &got, &ctx);
        let f = got.faults.as_ref().unwrap();
        assert_eq!(f.injected, 1, "{ctx}");
        assert_eq!(f.recovered, 1, "{ctx}");
    }
}

/// A corrupted cold payload (bytes flipped after the spill recorded its
/// checksum) must be *detected* at fetch time and the owner reclassified
/// swap -> recompute — never served. Recompute rebuilds exact KV, so the
/// outputs still match the oracle bitwise.
#[test]
fn corrupted_cold_payload_is_detected_and_recomputed() {
    let (cfg, _) = coordinator(73);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle_outputs(73, &reqs);
    for threads in thread_counts() {
        let (_, mut c) = coordinator(73);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(7)
            .max_batch(3)
            .tiering(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) })
            .build();
        let plan = FaultPlan::new().corrupt_spill(0);
        let got = c.serve(
            &reqs,
            &ServeOptions::continuous(ccfg).threads(threads).faults(plan),
        );
        let ctx = format!("corrupt spill at {threads}T");
        assert_clean_recovery(&want, &got, &ctx);
        let f = got.faults.as_ref().unwrap();
        assert_eq!(f.injected, 1, "{ctx}: exactly the 0th spill is corrupted");
        assert!(f.requeued >= 1, "{ctx}: the owner must be reclassified and requeued");
        let m = got.serving.as_ref().unwrap();
        assert!(
            m.cold_checksum_failures >= 1,
            "{ctx}: the checksum failure must be counted"
        );
    }
}

/// A transient cold-tier fetch failure takes the same reclassification
/// path as corruption: the victim recomputes instead of resuming, and
/// tokens stay oracle-identical.
#[test]
fn transient_fetch_failure_falls_back_to_recompute() {
    let (cfg, _) = coordinator(74);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle_outputs(74, &reqs);
    for threads in thread_counts() {
        let (_, mut c) = coordinator(74);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(7)
            .max_batch(3)
            .tiering(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) })
            .build();
        let plan = FaultPlan::new().fail_fetch(0);
        let got = c.serve(
            &reqs,
            &ServeOptions::continuous(ccfg).threads(threads).faults(plan),
        );
        let ctx = format!("fetch fail at {threads}T");
        assert_clean_recovery(&want, &got, &ctx);
        let f = got.faults.as_ref().unwrap();
        assert_eq!(f.injected, 1, "{ctx}: exactly the 0th fetch fails");
        assert!(f.requeued >= 1, "{ctx}: the victim must recompute");
    }
}

/// A transient block-pool allocation failure defers admission for one
/// iteration instead of crashing or mis-accounting — the request is
/// admitted on retry and the tokens stay oracle-identical.
#[test]
fn transient_alloc_failure_defers_admission() {
    let (cfg, _) = coordinator(75);
    let reqs = synthetic_workload(4, 4, 8, cfg.vocab);
    let want = oracle_outputs(75, &reqs);
    for threads in thread_counts() {
        let (_, mut c) = coordinator(75);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(64)
            .max_batch(4)
            .build();
        let plan = FaultPlan::new().fail_alloc(0);
        let got = c.serve(
            &reqs,
            &ServeOptions::continuous(ccfg).threads(threads).faults(plan),
        );
        let ctx = format!("alloc fail at {threads}T");
        assert_clean_recovery(&want, &got, &ctx);
        let f = got.faults.as_ref().unwrap();
        assert_eq!(f.injected, 1, "{ctx}: exactly the 0th allocation fails");
    }
}

/// Independent failpoints compose in one run: a corrupted spill *and* a
/// later worker panic, each recovered by its own mechanism, still land
/// on the oracle's tokens.
#[test]
fn composed_faults_recover_in_one_run() {
    let (cfg, _) = coordinator(76);
    let reqs = synthetic_workload(3, 4, 12, cfg.vocab);
    let want = oracle_outputs(76, &reqs);
    let (_, mut c) = coordinator(76);
    let ccfg = ContinuousConfig::builder()
        .block_size(4)
        .num_blocks(7)
        .max_batch(3)
        .tiering(TierConfig { quant: KvQuant::F32, ..TierConfig::new(16) })
        .build();
    let plan = FaultPlan::new().corrupt_spill(0).panic_at(Code::MlpGemm, 9, None);
    let got = c.serve(&reqs, &ServeOptions::continuous(ccfg).threads(2).faults(plan));
    assert_clean_recovery(&want, &got, "composed faults");
    let f = got.faults.as_ref().unwrap();
    assert_eq!(f.injected, 2, "both failpoints must fire");
    assert_eq!(f.recovered, 1, "the panic costs one epoch restart");
    assert!(f.requeued >= 1);
}

/// Bounded admission is deterministic backpressure: with the whole
/// workload submitted up front and a 2-deep queue, the overflow is
/// rejected with a typed reason, the survivors finish with oracle
/// tokens, and the rejects surface as empty outputs (an answer per
/// request, no special cases downstream).
#[test]
fn bounded_admission_rejects_deterministically() {
    let (cfg, _) = coordinator(77);
    let reqs = synthetic_workload(5, 4, 6, cfg.vocab);
    let want = oracle_outputs(77, &reqs);
    let (_, mut c) = coordinator(77);
    let ccfg =
        ContinuousConfig::builder().block_size(4).num_blocks(64).max_batch(2).build();
    let got = c.serve(&reqs, &ServeOptions::continuous(ccfg).max_queue(2));
    let f = got.faults.as_ref().expect("fault ledger");
    assert!(f.rejected > 0, "a 2-deep queue under a 5-request burst must reject");
    assert_eq!(got.outputs.len(), reqs.len(), "every request gets an answer");
    let mut served = 0usize;
    for (id, toks) in &got.outputs {
        if toks.is_empty() {
            continue; // rejected: empty output, counted in the ledger
        }
        served += 1;
        let oracle_toks = &want.outputs.iter().find(|(i, _)| i == id).unwrap().1;
        assert_eq!(&toks, &oracle_toks, "admitted request {id} must match the oracle");
    }
    assert_eq!(served + f.rejected as usize, reqs.len());
    let m = got.serving.as_ref().unwrap();
    assert_eq!(m.fault_leaked_blocks, 0);
}

/// Speculative decoding composed with fault recovery: a worker panic
/// during a decode iteration — whose spans carry draft rows when
/// `spec_k > 0` — poisons the epoch; recovery must strip the in-flight
/// drafts, roll every sequence back to committed KV, and replay to
/// oracle-identical tokens with zero leaked blocks. Speculation adds
/// rollback state, not new failure modes.
#[test]
fn speculative_decode_survives_worker_panic() {
    let (cfg, _) = coordinator(79);
    // Repetitive prompts (the lookup-friendly shape tests/serving.rs
    // uses) so drafting is plausibly in flight when the panic lands.
    let reqs: Vec<Request> = (0..3usize)
        .map(|i| Request {
            id: i as u64,
            prompt: [7usize, 1031, 299]
                .iter()
                .cycle()
                .take(9)
                .map(|&t| (t + 97 * i) % cfg.vocab)
                .collect(),
            max_new_tokens: 10,
        })
        .collect();
    let want = oracle_outputs(79, &reqs);
    for threads in thread_counts() {
        let (_, mut c) = coordinator(79);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(64)
            .max_batch(3)
            .build();
        // 9 prefill iterations precede decode, and even maximal draft
        // acceptance leaves >= 2 decode iterations, so iteration 10
        // lands inside decode under either counting convention.
        let plan = FaultPlan::new().panic_at(Code::Attn, 10, None);
        let got = c.serve(
            &reqs,
            &ServeOptions::continuous(ccfg).threads(threads).faults(plan).spec_k(4),
        );
        let ctx = format!("spec panic at {threads}T");
        assert_clean_recovery(&want, &got, &ctx);
        let f = got.faults.as_ref().unwrap();
        assert_eq!(f.injected, 1, "{ctx}: the one-shot panic fires exactly once");
        assert_eq!(f.recovered, 1, "{ctx}: one epoch restart absorbs it");
        let sm = got.spec.as_ref().expect("spec-on runs carry the summary");
        assert_eq!(
            sm.drafted,
            sm.accepted + sm.rejected,
            "{ctx}: the draft ledger must balance across the restart"
        );
    }
}

/// The CI chaos hook: run the plain differential under whatever
/// `PALLAS_FAILPOINTS` spec the environment carries. Without the env
/// var this is just the calm differential (it still passes); CI runs it
/// with `-- --ignored` and a panic spec to exercise recovery through
/// the env path end to end.
#[test]
#[ignore = "chaos hook: run with PALLAS_FAILPOINTS set (CI does)"]
fn env_spec_chaos_matches_oracle() {
    let (cfg, _) = coordinator(78);
    let reqs = synthetic_workload(4, 4, 10, cfg.vocab);
    let want = oracle_outputs(78, &reqs);
    for threads in thread_counts() {
        let (_, mut c) = coordinator(78);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(64)
            .max_batch(4)
            .build();
        // No explicit plan: serve_continuous falls back to the env spec.
        let got = c.serve(&reqs, &ServeOptions::continuous(ccfg).threads(threads));
        let ctx = format!("env chaos at {threads}T");
        assert_clean_recovery(&want, &got, &ctx);
        if std::env::var("PALLAS_FAILPOINTS").is_ok() {
            let f = got.faults.as_ref().unwrap();
            assert!(
                f.injected >= 1,
                "{ctx}: the env spec must actually fire (check phase/iter reachability)"
            );
        }
    }
}
