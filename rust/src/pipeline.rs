//! The end-to-end compiler driver: wires the five phases of Fig. 1
//! (ingest → e-graph layout optimization → auto distribution → auto
//! scheduling → codegen) into one call.

use crate::codegen::{emit_ntt_cpp, lower_to_plan, ExecPlan, PlannerKind};
use crate::cost::MachineSpec;
use crate::dist::{build_dist_egraph, extract_dist, DistSolution, Placement};
use crate::egraph::{extract_wpmaxsat, roofline_cost_fn, EGraph, Runner, RunnerLimits};
use crate::ir::Graph;
use crate::rewrite::{all_rules, pack::PackOptions};
use crate::schedule::{autoschedule, subgraph_to_tileops, MctsConfig, ScheduleResult, TiledState};

/// Compilation options.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    pub pack: PackOptions,
    pub saturation_limits: RunnerLimits,
    /// Number of devices ("cores as nodes"); 1 disables Auto Distribution.
    pub devices: usize,
    /// Per-device memory capacity for the distribution constraint.
    pub per_device_capacity: u64,
    /// Run the MCTS+MINLP scheduler on the attention core subgraph.
    pub schedule: bool,
    pub mcts: MctsConfig,
    pub planner: PlannerKind,
    /// Use WPMaxSAT extraction (false = greedy, the ablation baseline).
    pub sat_extraction: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            pack: PackOptions::default(),
            saturation_limits: RunnerLimits { max_iters: 8, max_nodes: 30_000 },
            devices: 1,
            per_device_capacity: u64::MAX / 4,
            schedule: false,
            mcts: MctsConfig::default(),
            planner: PlannerKind::FirstFit,
            sat_extraction: true,
        }
    }
}

/// Per-phase compilation report.
#[derive(Debug, Default)]
pub struct CompileReport {
    pub egraph_nodes: usize,
    pub egraph_classes: usize,
    pub saturation_iters: usize,
    pub saturated: bool,
    pub extraction_cost: u64,
    pub dist_total_ns: Option<u64>,
    pub dist_comm_ns: Option<u64>,
    pub schedule_latency_s: Option<f64>,
}

/// The compiled module.
pub struct CompiledModule {
    pub graph: Graph,
    pub dist: Option<DistSolution>,
    pub schedule: Option<ScheduleResult>,
    pub plan: ExecPlan,
    pub report: CompileReport,
}

impl CompiledModule {
    /// Emit the NTT C++ kernel source (Fig. 8).
    pub fn emit_cpp(&self, name: &str) -> String {
        emit_ntt_cpp(&self.plan, name)
    }
}

/// The compiler.
pub struct Compiler {
    pub machine: MachineSpec,
    pub options: CompileOptions,
}

impl Compiler {
    pub fn new(machine: MachineSpec, options: CompileOptions) -> Self {
        Compiler { machine, options }
    }

    /// Run the full pipeline on `graph`.
    pub fn compile(&self, graph: &Graph) -> CompiledModule {
        let mut report = CompileReport::default();

        // Phase 1+2: e-graph ingestion + saturation with Tables 1 & 2.
        let (mut eg, map) = EGraph::from_graph(graph);
        let rules = all_rules(&self.options.pack);
        let refs: Vec<&dyn crate::egraph::Rewrite> = rules.iter().map(|r| r.as_ref()).collect();
        let rep = Runner::new(&mut eg).with_limits(self.options.saturation_limits).run(&refs);
        report.saturation_iters = rep.iterations;
        report.saturated = rep.saturated;
        report.egraph_nodes = rep.nodes;
        report.egraph_classes = rep.classes;

        // Extraction with the Roofline cost model (WPMaxSAT or greedy).
        let roots: Vec<_> = graph.outputs.iter().map(|o| map[o.index()]).collect();
        let cost = roofline_cost_fn(&self.machine);
        let ex = if self.options.sat_extraction {
            extract_wpmaxsat(&eg, &roots, &cost)
        } else {
            crate::egraph::extract_greedy(&eg, &roots, &cost)
        };
        report.extraction_cost = ex.cost;
        let optimized = ex.graph;

        // Phase 3: Auto Distribution ("cores as distributed nodes").
        let dist = if self.options.devices > 1 {
            let placement = Placement::line(self.options.devices);
            let d = build_dist_egraph(&optimized, &placement);
            match extract_dist(&d, &self.machine, self.options.per_device_capacity, true) {
                Ok(sol) => {
                    report.dist_total_ns = Some(sol.total_ns);
                    report.dist_comm_ns = Some(sol.comm_ns);
                    Some(sol)
                }
                Err(_) => None,
            }
        } else {
            None
        };

        // Phase 4: Auto Schedule on the attention core.
        let schedule = if self.options.schedule {
            let core = crate::model::attention_core_nodes(&optimized);
            if core.len() >= 2 {
                let ops = subgraph_to_tileops(&optimized, &core);
                if !ops.is_empty() {
                    let levels = self.machine.caches.len();
                    let init = TiledState::initial(ops, levels.max(2));
                    autoschedule(init, &self.machine, self.options.mcts.clone()).inspect(|r| {
                        report.schedule_latency_s = Some(r.solution.latency_s);
                    })
                } else {
                    None
                }
            } else {
                None
            }
        } else {
            None
        };

        // Phase 5: codegen (bufferize, liveness, memory plan, steps).
        let plan = lower_to_plan(&optimized, self.options.planner);

        CompiledModule { graph: optimized, dist, schedule, plan, report }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, UnaryKind};
    use crate::model::{decode_graph, Qwen3Config};

    #[test]
    fn full_pipeline_on_attention_subgraph() {
        let mut g = Graph::new();
        let q = g.input("Q", &[64, 64], DType::F32);
        let k = g.input("K", &[64, 64], DType::F32);
        let v = g.input("V", &[64, 64], DType::F32);
        let s = g.matmul(q, k);
        let e = g.unary(UnaryKind::Exp, s);
        let o = g.matmul(e, v);
        g.mark_output(o);

        let c = Compiler::new(MachineSpec::ryzen_5900x(), CompileOptions::default());
        let m = c.compile(&g);
        assert!(m.report.saturated);
        assert!(m.report.extraction_cost > 0);
        // Vectorize keeps the blocked layout through the chain: packed
        // exp present, single unpack.
        let packed_exp = m.graph.live_nodes().iter().any(|&id| {
            let n = m.graph.node(id);
            matches!(n.op, crate::ir::Op::Unary(UnaryKind::Exp)) && n.ty.is_packed()
        });
        assert!(packed_exp, "pipeline must select the pass-through layout:\n{}", m.graph.dump());
        // Codegen produced steps and C++.
        assert!(!m.plan.steps.is_empty());
        let cpp = m.emit_cpp("attn");
        assert!(cpp.contains("ntt::matmul"));
    }

    #[test]
    fn pipeline_with_distribution_and_schedule() {
        let cfg = Qwen3Config::tiny();
        let g = decode_graph(&cfg, 4, Some(1));
        let opts = CompileOptions {
            devices: 2,
            schedule: true,
            mcts: MctsConfig { iterations: 20, ..Default::default() },
            saturation_limits: RunnerLimits { max_iters: 3, max_nodes: 8_000 },
            sat_extraction: false, // large graph: greedy extraction
            ..Default::default()
        };
        let c = Compiler::new(MachineSpec::ryzen_5900x(), opts);
        let m = c.compile(&g);
        assert!(m.dist.is_some(), "distribution must produce a plan");
        assert!(m.report.dist_comm_ns.unwrap() > 0);
        assert!(m.schedule.is_some(), "scheduler must run on the attention core");
        assert!(m.report.schedule_latency_s.unwrap() > 0.0);
    }
}
