//! `repro` — the nncase-repro CLI.
//!
//! Subcommands:
//! * `compile`  — run the full pipeline on a built-in graph and print the
//!   per-phase report.
//! * `inspect`  — dump the optimized graph / emitted NTT C++.
//! * `serve`    — run the tiny-Qwen3 serving workload (real execution).
//! * `sweep`    — regenerate Figure 9 / Figure 10 tables on the simulator.
//! * `artifacts`— smoke-test the PJRT runtime against `artifacts/`.

use nncase_repro::coordinator::{Coordinator, Qwen3Engine, ServeOptions};
use nncase_repro::cost::MachineSpec;
use nncase_repro::ir::DType;
use nncase_repro::model::{decode_graph, Qwen3Config, Qwen3Weights};
use nncase_repro::ntt::WeightQuant;
use nncase_repro::pipeline::{CompileOptions, Compiler};
use nncase_repro::runtime::{Manifest, PjrtRuntime};
use nncase_repro::serving::{ContinuousConfig, KvQuant, TierConfig};
use nncase_repro::sim::figures;

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn usage() -> ! {
    eprintln!(
        "usage: repro <compile|inspect|serve|sweep|artifacts> [options]\n\
         \n\
         compile   [--model tiny|0.6b|1.7b] [--devices N] [--schedule] [--greedy]\n\
         inspect   [--emit-cpp] [--model tiny]\n\
         serve     [--threads N] [--requests N] [--max-new N] [--policy fcfs|continuous]\n\
         \x20          [--max-batch N] [--prefill-chunk N] [--shards N] [--kv-cold-blocks N]\n\
         \x20          [--kv-quant int8|f32] [--weight-quant f32|int8|int4] [--autotune]\n\
         \x20          [--deadline-ms N] [--max-queue N] [--failpoints SPEC] [--spec-k N]\n\
         \x20          [--trace-out trace.json] [--report-json report.json]\n\
         \x20          (--autotune derives chunk/budget/threads/panel/pool from the\n\
         \x20           serve-time planner; --shards partitions the projection GEMMs\n\
         \x20           across dist-planned worker groups; explicit flags override\n\
         \x20           planner knobs; outputs are token-identical either way;\n\
         \x20           --deadline-ms cancels requests past their latency budget,\n\
         \x20           --max-queue bounds admission [both continuous only];\n\
         \x20           --failpoints injects deterministic faults, e.g.\n\
         \x20           'panic@phase=attn,iter=3;fetch@nth=1' — same grammar as the\n\
         \x20           PALLAS_FAILPOINTS env var; recovery keeps outputs\n\
         \x20           token-identical; --spec-k N enables self-drafting\n\
         \x20           speculative decoding: each decode slot verifies up to N\n\
         \x20           prompt-lookup drafts per step [continuous only; outputs\n\
         \x20           token-identical, decode iterations fewer when drafts hit];\n\
         \x20           --trace-out records per-worker phase\n\
         \x20           timelines as Chrome-trace JSON for Perfetto [continuous\n\
         \x20           only], --report-json writes the machine-readable ServeReport)\n\
         sweep     [--figure 9|10]\n\
         artifacts [--dir artifacts]"
    );
    std::process::exit(2)
}

fn model_cfg(args: &[String]) -> Qwen3Config {
    match opt(args, "--model").as_deref() {
        Some("0.6b") => Qwen3Config::qwen3_0_6b(DType::F16),
        Some("1.7b") => Qwen3Config::qwen3_1_7b(DType::F16),
        _ => Qwen3Config::tiny(),
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let machine = MachineSpec::ryzen_5900x();
    match cmd.as_str() {
        "compile" => {
            let cfg = model_cfg(&args);
            let devices: usize =
                opt(&args, "--devices").and_then(|v| v.parse().ok()).unwrap_or(1);
            // Full-scale graphs get one representative layer (strategies
            // replicate across identical layers); tiny compiles whole.
            let layers = if cfg.hidden > 512 { Some(1) } else { None };
            let g = decode_graph(&cfg, 8, layers);
            let opts = CompileOptions {
                devices,
                schedule: flag(&args, "--schedule"),
                sat_extraction: !flag(&args, "--greedy") && g.len() < 300,
                ..Default::default()
            };
            let c = Compiler::new(machine, opts);
            let m = c.compile(&g);
            println!("model: {}", cfg.name);
            println!("graph: {} nodes ({} live)", m.graph.len(), m.graph.live_nodes().len());
            println!(
                "egraph: {} nodes, {} classes, {} iters (saturated={})",
                m.report.egraph_nodes,
                m.report.egraph_classes,
                m.report.saturation_iters,
                m.report.saturated
            );
            println!("extraction cost: {} ns (roofline)", m.report.extraction_cost);
            if let Some(d) = &m.dist {
                println!(
                    "distribution: total {} ns, comm {} ns, weights/device {}",
                    d.total_ns,
                    d.comm_ns,
                    nncase_repro::util::human_bytes(d.weight_bytes_per_device as usize)
                );
            }
            if let Some(s) = &m.schedule {
                println!(
                    "schedule: {:.3} us over {} MCTS evals\n{}",
                    s.solution.latency_s * 1e6,
                    s.evaluations,
                    s.state.notation()
                );
            }
            println!("plan: {}", m.plan.summary());
        }
        "inspect" => {
            let cfg = model_cfg(&args);
            let g = decode_graph(&cfg, 4, Some(1));
            let c = Compiler::new(machine, CompileOptions::default());
            let m = c.compile(&g);
            if flag(&args, "--emit-cpp") {
                println!("{}", m.emit_cpp("decode_layer"));
            } else {
                println!("{}", m.graph.dump());
            }
        }
        "serve" => {
            let threads_flag: Option<usize> = opt(&args, "--threads").and_then(|v| v.parse().ok());
            let threads: usize = threads_flag.unwrap_or(4);
            let n_req: usize =
                opt(&args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(8);
            let max_new: usize =
                opt(&args, "--max-new").and_then(|v| v.parse().ok()).unwrap_or(32);
            // Weight-plane storage: f32 (seed), or group-wise int8/int4
            // streamed through the fused dequant-GEMM kernels. Applies
            // to both policies (the FCFS engine runs the fake-quantized
            // oracle weights, so the two stay differentially testable).
            let wq = match opt(&args, "--weight-quant") {
                Some(q) => WeightQuant::parse(&q)
                    .unwrap_or_else(|| panic!("bad --weight-quant {q:?}")),
                None => WeightQuant::F32,
            };
            let cfg = Qwen3Config::tiny().with_weight_quant(wq);
            println!(
                "serving {} ({} params, {} weights [{}], {} threads)",
                cfg.name,
                cfg.param_count(),
                nncase_repro::util::human_bytes(cfg.weight_bytes() as usize),
                cfg.weight_quant.name(),
                threads
            );
            let w = Qwen3Weights::random(&cfg, 42);
            let mut c = Coordinator::new(Qwen3Engine::new(w, threads, 512));
            let reqs = nncase_repro::coordinator::serve::synthetic_workload(
                n_req, 8, max_new, cfg.vocab,
            );
            let max_batch: usize =
                opt(&args, "--max-batch").and_then(|v| v.parse().ok()).unwrap_or(8);
            let rep = if opt(&args, "--policy").as_deref() == Some("continuous") {
                // --autotune: every knob from the serve-time planner
                // (schedule::tile candidates scored by the cost
                // rooflines, cached per model/machine/quant/batch).
                // Otherwise the machine memory/core fallback. Explicit
                // flags become ServeOptions overrides, applied on top of
                // whichever base config the mode resolves to.
                let mut opts = if flag(&args, "--autotune") {
                    ServeOptions::autotuned(max_batch)
                } else {
                    ServeOptions::continuous(ContinuousConfig::for_machine(
                        &cfg, &machine, max_batch,
                    ))
                }
                .machine(machine.clone());
                if let Some(t) = threads_flag {
                    opts = opts.threads(t);
                }
                // Chunked prefill: feed up to N prompt tokens per
                // sequence per iteration (1 = the default
                // one-token-per-slot behaviour; outputs are
                // token-identical at any value, TTFT is not).
                if let Some(chunk) =
                    opt(&args, "--prefill-chunk").and_then(|v| v.parse::<usize>().ok())
                {
                    opts = opts.prefill_chunk(chunk);
                }
                // Dist-sharded worker groups: the projection GEMMs are
                // partitioned across N groups with split-vs-broadcast
                // layouts chosen by the dist cost model. Token-identical
                // at any count.
                if let Some(s) = opt(&args, "--shards").and_then(|v| v.parse::<usize>().ok()) {
                    opts = opts.shards(s);
                }
                // Tiered cold KV storage: --kv-cold-blocks enables a
                // cold tier of N blocks, --kv-quant picks the format
                // (int8 default; f32 = lossless swap). The swap
                // policy is the machine-derived cost model.
                let cold_blocks =
                    opt(&args, "--kv-cold-blocks").and_then(|v| v.parse::<usize>().ok());
                if let Some(n) = cold_blocks {
                    let quant = match opt(&args, "--kv-quant") {
                        Some(q) => KvQuant::parse(&q)
                            .unwrap_or_else(|| panic!("bad --kv-quant {q:?}")),
                        None => KvQuant::Int8,
                    };
                    opts = opts.tiering(TierConfig::for_machine(
                        n,
                        quant,
                        &machine,
                        &cfg,
                        threads_flag.unwrap_or(threads),
                    ));
                }
                // Robustness knobs: request deadlines (cancel past the
                // latency budget), bounded admission (typed rejection
                // when the queue is full), and deterministic failpoint
                // injection (--failpoints wins over PALLAS_FAILPOINTS;
                // recovery keeps outputs token-identical).
                if let Some(ms) = opt(&args, "--deadline-ms").and_then(|v| v.parse::<u64>().ok())
                {
                    opts = opts.deadline_ms(ms);
                }
                if let Some(q) = opt(&args, "--max-queue").and_then(|v| v.parse::<usize>().ok())
                {
                    opts = opts.max_queue(q);
                }
                if let Some(spec) = opt(&args, "--failpoints") {
                    let plan = nncase_repro::serving::FaultPlan::parse(&spec)
                        .unwrap_or_else(|e| panic!("bad --failpoints {spec:?}: {e}"));
                    opts = opts.faults(plan);
                }
                // Self-drafting speculative decoding: each decode slot
                // drafts up to N tokens from its own context (prompt
                // lookup) and the engine verifies them in one span
                // step. Token-identical at any depth; fewer decode
                // iterations when the workload repeats itself.
                if let Some(k) = opt(&args, "--spec-k").and_then(|v| v.parse::<usize>().ok()) {
                    opts = opts.spec_k(k);
                }
                // Serve-path tracing: per-worker phase timelines into
                // pre-allocated rings, exported as Chrome-trace JSON
                // (open in Perfetto). Continuous only — validate()
                // rejects it on FCFS.
                let trace_out = opt(&args, "--trace-out");
                if let Some(path) = &trace_out {
                    opts = opts.trace_out(path.clone());
                }
                println!("policy: continuous");
                let rep = c.serve(&reqs, &opts);
                if let Some(p) = &rep.plan {
                    println!("autotune plan: {}", p.render());
                }
                if let Some(path) = &trace_out {
                    println!("trace -> {path} (open in https://ui.perfetto.dev)");
                }
                rep
            } else {
                println!("policy: fcfs");
                c.serve(&reqs, &ServeOptions::fcfs())
            };
            println!("{}", rep.render());
            // The machine-readable report (ServeReport::to_json): the
            // schema benches and tools/bench_compare.py consume.
            if let Some(path) = opt(&args, "--report-json") {
                std::fs::write(&path, rep.to_json())?;
                println!("report json -> {path}");
            }
        }
        "sweep" => {
            let fig = opt(&args, "--figure").unwrap_or_else(|| "9".into());
            match fig.as_str() {
                "9" => println!(
                    "{}",
                    figures::render(&figures::fig9_table(&machine), "Figure 9 (1T)")
                ),
                "10" => println!(
                    "{}",
                    figures::render(&figures::fig10_table(&machine), "Figure 10 (4T/8T)")
                ),
                _ => usage(),
            }
        }
        "artifacts" => {
            let dir = opt(&args, "--dir").unwrap_or_else(|| "artifacts".into());
            let manifest =
                Manifest::load(std::path::Path::new(&dir).join("manifest.tsv").as_path())?;
            let mut rt = PjrtRuntime::cpu(&dir)?;
            println!("platform: {}", rt.platform());
            for e in &manifest.entries {
                rt.load(&e.name, &e.path)?;
                println!("loaded {} <- {}", e.name, e.path);
            }
            println!("{} artifacts compiled OK", manifest.entries.len());
        }
        _ => usage(),
    }
    Ok(())
}
