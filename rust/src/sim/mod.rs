//! Machine simulator and baseline framework models (§4).
//!
//! The paper's evaluation platform (Ryzen 9 5900X + DDR4-3600) is not
//! available here, and neither are the competitor binaries, so the
//! experiments of Figures 9 and 10 run on a Roofline-based performance
//! simulator ([`decode`]) in which each framework is represented by its
//! *strategy* ([`baselines`]): kernel efficiency, layout behaviour,
//! threading model, dispatch overheads. The parameters are derived from
//! first principles (documented per framework), not fitted to the
//! paper's numbers; the claim reproduced is the *shape* of the results —
//! orderings, rough factors, crossovers — per DESIGN.md §2.
//!
//! [`figures`] regenerates the two evaluation figures as printed tables
//! with the paper's reference values alongside.

pub mod baselines;
pub mod decode;
pub mod figures;

pub use baselines::{Framework, FrameworkKind};
pub use decode::{simulate_decode, DecodeSim};
pub use figures::{fig10_table, fig9_table, FigureRow};
