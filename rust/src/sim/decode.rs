//! Roofline decode simulator.
//!
//! Walks the real decode-step IR graph of the model (so op counts, weight
//! shapes and KV traffic are structural, not hand-waved) and accumulates
//! per-op times under a framework's strategy parameters:
//!
//! `t_op = max(flops / (peak·kernel_eff·t), bytes·bytes_factor /
//!            (bw(t)·bw_eff)) + sync(t) + dispatch`
//!
//! Decode throughput is `1 / Σ t_op`. The memory term uses the machine's
//! thread-dependent DRAM bandwidth, which saturates around 2–3 cores on
//! the 5900X — the "memory wall" that flattens Figure 10's 8T columns.

use crate::cost::MachineSpec;
use crate::ir::{Op, TensorType};
use crate::model::{decode_graph, Qwen3Config};

use super::Framework;

/// Simulation result for one (model, framework, threads) cell.
#[derive(Debug, Clone)]
pub struct DecodeSim {
    pub tokens_per_s: f64,
    pub t_mem_s: f64,
    pub t_comp_s: f64,
    pub t_overhead_s: f64,
    pub ops: usize,
}

/// Simulate decode throughput. `ctx` is the KV context length (the paper
/// uses an 8-token prompt; decode happens at short context, so KV traffic
/// is negligible next to weights — we default to 64 to include it).
pub fn simulate_decode(
    cfg: &Qwen3Config,
    threads: usize,
    fw: &Framework,
    machine: &MachineSpec,
    ctx: usize,
) -> DecodeSim {
    let g = decode_graph(cfg, ctx, None);
    let dtype_bytes = cfg.dtype.size_bytes();
    // Compute peak uses f32 FMA lanes: F16 on AVX2 is converted to f32 in
    // registers, so FLOP peak does not double, only the memory stream
    // halves (this matches llama.cpp's F16 behaviour on Zen 3).
    let peak1 = machine.peak_flops(1, 4);
    let dyn_penalty = if threads > 1 { 1.0 - fw.dyn_sched_bw_penalty } else { 1.0 };
    // F16/BF16 weights must be widened to f32 in registers on AVX2; the
    // conversion interleaves with the load stream and costs ~13% of the
    // achievable bandwidth (why the paper's F16 gain is ~59%, not 2x).
    let convert_penalty = if cfg.dtype == crate::ir::DType::F32 { 1.0 } else { 0.87 };
    let bw = machine.dram_bw(threads) * fw.bw_eff * dyn_penalty * convert_penalty;

    let (mut t_mem, mut t_comp, mut t_ovh) = (0.0f64, 0.0f64, 0.0f64);
    let mut ops = 0usize;
    for id in g.live_nodes() {
        let n = g.node(id);
        if n.op.is_leaf() || n.op.is_view() {
            continue;
        }
        let in_tys: Vec<&TensorType> = n.inputs.iter().map(|&i| &g.node(i).ty).collect();
        let flops = crate::cost::op_flops(&n.op, &in_tys, &n.ty) as f64;
        let bytes = crate::cost::op_bytes(&n.op, &in_tys, &n.ty) as f64;
        let _ = dtype_bytes;
        ops += 1;
        // Parallelizable fraction: matmuls and big elementwise ops scale;
        // tiny vector ops (norms over h elements) stay single-thread.
        let scalable = matches!(n.op, Op::MatMul) || bytes > 256.0 * 1024.0;
        let t_eff = if scalable { threads } else { 1 };
        let comp = flops / (peak1 * fw.kernel_eff * t_eff as f64);
        let mem = bytes * fw.bytes_factor
            / if scalable { bw } else { machine.dram_bw(1) * fw.bw_eff };
        // Roofline: overlap compute and memory, take the max.
        let t_op = comp.max(mem);
        t_comp += comp;
        t_mem += mem;
        t_ovh += fw.dispatch_s + if scalable { fw.sync_s(threads) } else { 0.0 };
        // Accumulate the max into whichever bucket dominated for the
        // total; we track buckets separately for reporting and use the
        // roofline sum for throughput below via max-accounting:
        let _ = t_op;
    }
    // Roofline at the token level: weights stream once per token, compute
    // overlaps; token time = max(total mem, total comp) + overheads.
    let token_s = t_mem.max(t_comp) + t_ovh;
    DecodeSim {
        tokens_per_s: 1.0 / token_s,
        t_mem_s: t_mem,
        t_comp_s: t_comp,
        t_overhead_s: t_ovh,
        ops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;
    use crate::sim::Framework;

    fn ryzen() -> MachineSpec {
        MachineSpec::ryzen_5900x()
    }

    #[test]
    fn single_core_hierarchy_matches_paper() {
        // Fig. 9: llama.cpp > nncase > IPEX >> MLC, all models.
        for cfg in [
            Qwen3Config::qwen3_0_6b(DType::F32),
            Qwen3Config::qwen3_0_6b(DType::F16),
            Qwen3Config::qwen3_1_7b(DType::F16),
        ] {
            let tput = |f: &Framework| simulate_decode(&cfg, 1, f, &ryzen(), 8).tokens_per_s;
            let l = tput(&Framework::llamacpp());
            let n = tput(&Framework::nncase());
            let i = tput(&Framework::ipex());
            let m = tput(&Framework::mlc());
            assert!(l > n, "{}: llama.cpp {l} > nncase {n}", cfg.name);
            assert!(n > i, "{}: nncase {n} > IPEX {i}", cfg.name);
            assert!(i > 2.0 * m, "{}: IPEX {i} >> MLC {m}", cfg.name);
        }
    }

    #[test]
    fn absolute_numbers_in_paper_ballpark() {
        // Fig. 9 reference values (tokens/s, 1T): nncase 8.7 (0.6B F32),
        // 13.87 (0.6B F16), 5.09 (1.7B F16); llama.cpp 10.61 / 17.21.
        // The simulator must land within 2x of each.
        let close = |got: f64, want: f64| {
            assert!(
                got > want * 0.5 && got < want * 2.0,
                "simulated {got:.2} vs paper {want:.2}"
            );
        };
        let m = ryzen();
        let nn = Framework::nncase();
        let lc = Framework::llamacpp();
        close(
            simulate_decode(&Qwen3Config::qwen3_0_6b(DType::F32), 1, &nn, &m, 8).tokens_per_s,
            8.7,
        );
        close(
            simulate_decode(&Qwen3Config::qwen3_0_6b(DType::F16), 1, &nn, &m, 8).tokens_per_s,
            13.87,
        );
        close(
            simulate_decode(&Qwen3Config::qwen3_1_7b(DType::F16), 1, &nn, &m, 8).tokens_per_s,
            5.09,
        );
        close(
            simulate_decode(&Qwen3Config::qwen3_0_6b(DType::F32), 1, &lc, &m, 8).tokens_per_s,
            10.61,
        );
    }

    #[test]
    fn f16_speedup_over_f32() {
        // Paper: F16 gives ~59% over F32 on 0.6B (memory-bound halving,
        // minus compute floor).
        let m = ryzen();
        let nn = Framework::nncase();
        let f32t =
            simulate_decode(&Qwen3Config::qwen3_0_6b(DType::F32), 1, &nn, &m, 8).tokens_per_s;
        let f16t =
            simulate_decode(&Qwen3Config::qwen3_0_6b(DType::F16), 1, &nn, &m, 8).tokens_per_s;
        let gain = f16t / f32t;
        assert!((1.3..2.05).contains(&gain), "F16 gain {gain}");
    }

    #[test]
    fn multicore_crossover_nncase_overtakes_llamacpp() {
        // Fig. 10: at 4T/8T nncase ≥ llama.cpp (static partitioning vs
        // fork-join overhead).
        let m = ryzen();
        for cfg in
            [Qwen3Config::qwen3_0_6b(DType::F16), Qwen3Config::qwen3_1_7b(DType::F16)]
        {
            for t in [4usize, 8] {
                let n = simulate_decode(&cfg, t, &Framework::nncase(), &m, 8).tokens_per_s;
                let l = simulate_decode(&cfg, t, &Framework::llamacpp(), &m, 8).tokens_per_s;
                assert!(
                    n > l,
                    "{} {t}T: nncase {n:.2} must beat llama.cpp {l:.2}",
                    cfg.name
                );
            }
        }
    }

    #[test]
    fn bandwidth_wall_flattens_8t() {
        // Fig. 10: 8T barely improves over 4T (socket bandwidth wall).
        let m = ryzen();
        let cfg = Qwen3Config::qwen3_0_6b(DType::F16);
        let t4 = simulate_decode(&cfg, 4, &Framework::nncase(), &m, 8).tokens_per_s;
        let t8 = simulate_decode(&cfg, 8, &Framework::nncase(), &m, 8).tokens_per_s;
        assert!(t8 >= t4 * 0.95 && t8 <= t4 * 1.25, "4T {t4} vs 8T {t8}");
    }

    #[test]
    fn scaling_efficiency_nncase_beats_llamacpp_17b() {
        // Fig. 10: 1T->4T gain 74% (nncase) vs 32% (llama.cpp) on 1.7B.
        let m = ryzen();
        let cfg = Qwen3Config::qwen3_1_7b(DType::F16);
        let gain = |f: &Framework| {
            simulate_decode(&cfg, 4, f, &m, 8).tokens_per_s
                / simulate_decode(&cfg, 1, f, &m, 8).tokens_per_s
        };
        let gn = gain(&Framework::nncase());
        let gl = gain(&Framework::llamacpp());
        assert!(gn > gl, "nncase scaling {gn:.2} must beat llama.cpp {gl:.2}");
    }

    #[test]
    fn memory_bound_regime() {
        // Decode on CPUs is memory-bound for every competent framework.
        let m = ryzen();
        let s = simulate_decode(
            &Qwen3Config::qwen3_0_6b(DType::F32),
            1,
            &Framework::nncase(),
            &m,
            8,
        );
        assert!(s.t_mem_s > s.t_comp_s, "decode must be memory bound");
    }
}
