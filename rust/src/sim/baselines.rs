//! Analytic models of the compared frameworks (§4's competitors).
//!
//! Every model is a set of strategy parameters with a first-principles
//! justification. None of them is fitted to the paper's reported
//! numbers; see EXPERIMENTS.md for the resulting deviations.

/// Which framework a model stands for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FrameworkKind {
    Nncase,
    LlamaCpp,
    Ipex,
    Mlc,
}

impl FrameworkKind {
    pub fn name(self) -> &'static str {
        match self {
            FrameworkKind::Nncase => "nncase",
            FrameworkKind::LlamaCpp => "llama.cpp",
            FrameworkKind::Ipex => "Intel IPEX",
            FrameworkKind::Mlc => "MLC LLM",
        }
    }
}

/// Strategy parameters of one framework.
#[derive(Debug, Clone)]
pub struct Framework {
    pub kind: FrameworkKind,
    /// Fraction of peak FLOP/s the GEMM/GEMV inner loops reach.
    pub kernel_eff: f64,
    /// Fraction of stream bandwidth achieved on the weight stream.
    pub bw_eff: f64,
    /// Multiplier on memory traffic from layout behaviour (1.0 = weights
    /// streamed once; >1 = re-reads from packing/unpacking/copies).
    pub bytes_factor: f64,
    /// Per-parallel-region synchronization cost at `t` threads, seconds.
    /// OpenMP-style fork-join grows with threads; static partitioning
    /// pays one lightweight barrier.
    pub sync_base_s: f64,
    pub sync_per_thread_s: f64,
    /// Per-operator dispatch overhead (graph interpreter / VM), seconds.
    pub dispatch_s: f64,
    /// Multi-thread bandwidth derating from *dynamic* work scheduling:
    /// fork-join runtimes hand threads interleaved weight chunks, so the
    /// per-channel streams stop being sequential and the effective DRAM
    /// bandwidth drops. Static compile-time partitioning (nncase's
    /// "cores as nodes") keeps each core on a contiguous shard — no
    /// penalty. Applied as `bw *= 1 - penalty` when threads > 1.
    pub dyn_sched_bw_penalty: f64,
}

impl Framework {
    pub fn sync_s(&self, threads: usize) -> f64 {
        if threads <= 1 {
            return 0.0;
        }
        self.sync_base_s + self.sync_per_thread_s * threads as f64
    }

    /// nncase: NTT μkernels (≈ the packed-matmul Roofline efficiency of
    /// our cost model), e-graph global layout (weights pre-packed at
    /// compile time — no runtime conversion), compile-time static
    /// partitioning ("cores as distributed nodes") with deterministic
    /// point-to-point sync instead of fork-join barriers.
    pub fn nncase() -> Self {
        Framework {
            kind: FrameworkKind::Nncase,
            kernel_eff: 0.85,
            bw_eff: 0.86,
            bytes_factor: 1.0,
            sync_base_s: 1.0e-6,
            sync_per_thread_s: 0.2e-6,
            dispatch_s: 0.3e-6,
            dyn_sched_bw_penalty: 0.0,
        }
    }

    /// llama.cpp: hand-written AVX2 kernels (the ceiling: ~0.92 of peak,
    /// ~0.93 of stream), weights stored pre-packed in GGUF (factor 1.0),
    /// but OpenMP-style thread-pool barriers per op (ggml graph executes
    /// with a spin-barrier per node).
    pub fn llamacpp() -> Self {
        Framework {
            kind: FrameworkKind::LlamaCpp,
            kernel_eff: 0.92,
            bw_eff: 0.93,
            bytes_factor: 1.0,
            sync_base_s: 3.0e-6,
            sync_per_thread_s: 1.5e-6,
            dispatch_s: 0.2e-6,
            dyn_sched_bw_penalty: 0.10,
        }
    }

    /// Intel IPEX: oneDNN kernels are good (0.8 of peak) but the
    /// kernel-level packing strategy re-packs activations/weights at
    /// operator boundaries (§2.1 "layout thrashing"): ~25% extra traffic;
    /// OpenMP parallel regions per op.
    pub fn ipex() -> Self {
        Framework {
            kind: FrameworkKind::Ipex,
            kernel_eff: 0.80,
            bw_eff: 0.80,
            bytes_factor: 1.25,
            sync_base_s: 5.0e-6,
            sync_per_thread_s: 2.0e-6,
            dispatch_s: 1.0e-6,
            dyn_sched_bw_penalty: 0.12,
        }
    }

    /// MLC LLM: TVM/Relax VM on CPU without tuned schedules for this
    /// target — F16 GEMV falls back to near-scalar loops with element
    /// conversions (≈1-2% of peak), intermediate tensors materialize
    /// through memory (×3 traffic), and the VM dispatches per op.
    /// This is the structural explanation the paper gives for MLC's
    /// collapse (0.2 tok/s on Qwen3-1.7B).
    pub fn mlc() -> Self {
        Framework {
            kind: FrameworkKind::Mlc,
            kernel_eff: 0.012,
            bw_eff: 0.50,
            bytes_factor: 3.0,
            sync_base_s: 8.0e-6,
            sync_per_thread_s: 3.0e-6,
            dispatch_s: 20.0e-6,
            dyn_sched_bw_penalty: 0.10,
        }
    }

    pub fn all() -> Vec<Framework> {
        vec![Self::llamacpp(), Self::nncase(), Self::ipex(), Self::mlc()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_scales_with_threads() {
        let f = Framework::llamacpp();
        assert_eq!(f.sync_s(1), 0.0);
        assert!(f.sync_s(8) > f.sync_s(4));
        // nncase's static partition syncs cheaper than OpenMP models.
        assert!(Framework::nncase().sync_s(8) < Framework::ipex().sync_s(8));
    }

    #[test]
    fn kernel_quality_ordering() {
        // The paper's single-core hierarchy stems from kernel quality:
        // llama.cpp > nncase > IPEX >> MLC.
        let (l, n, i, m) = (
            Framework::llamacpp().kernel_eff,
            Framework::nncase().kernel_eff,
            Framework::ipex().kernel_eff,
            Framework::mlc().kernel_eff,
        );
        assert!(l > n && n > i && i > 10.0 * m);
    }

    #[test]
    fn layout_traffic_ordering() {
        assert_eq!(Framework::nncase().bytes_factor, 1.0, "pass-through layout");
        assert!(Framework::ipex().bytes_factor > 1.0, "kernel-local packing re-reads");
        assert!(Framework::mlc().bytes_factor > Framework::ipex().bytes_factor);
    }
}
