//! Regeneration of the paper's evaluation figures as printed tables.

use crate::cost::MachineSpec;
use crate::ir::DType;
use crate::model::Qwen3Config;

use super::{simulate_decode, Framework};

/// One (model, framework, threads) cell with the paper's reference value
/// where it is stated in §4.
#[derive(Debug, Clone)]
pub struct FigureRow {
    pub model: String,
    pub framework: &'static str,
    pub threads: usize,
    pub tokens_per_s: f64,
    pub paper_tokens_per_s: Option<f64>,
}

/// Reference values quoted in §4.1 / §4.2 of the paper.
fn paper_ref(model: &str, fw: &str, threads: usize) -> Option<f64> {
    match (model, fw, threads) {
        ("Qwen3-0.6B-f32", "nncase", 1) => Some(8.7),
        ("Qwen3-0.6B-f32", "llama.cpp", 1) => Some(10.61),
        ("Qwen3-0.6B-f32", "Intel IPEX", 1) => Some(7.58),
        ("Qwen3-0.6B-f16", "nncase", 1) => Some(13.87),
        ("Qwen3-0.6B-f16", "llama.cpp", 1) => Some(17.21),
        ("Qwen3-0.6B-f16", "Intel IPEX", 1) => Some(10.22),
        ("Qwen3-1.7B-f16", "nncase", 1) => Some(5.09),
        ("Qwen3-1.7B-f16", "MLC LLM", 1) => Some(0.2),
        ("Qwen3-0.6B-f16", "nncase", 4) => Some(23.5),
        ("Qwen3-0.6B-f16", "llama.cpp", 4) => Some(23.2),
        ("Qwen3-0.6B-f16", "Intel IPEX", 4) => Some(15.52),
        ("Qwen3-0.6B-f16", "nncase", 8) => Some(23.98),
        ("Qwen3-1.7B-f16", "nncase", 4) => Some(8.85),
        ("Qwen3-1.7B-f16", "llama.cpp", 4) => Some(8.34),
        ("Qwen3-1.7B-f16", "Intel IPEX", 4) => Some(6.93),
        _ => None,
    }
}

fn eval_cell(cfg: &Qwen3Config, fw: &Framework, threads: usize, m: &MachineSpec) -> FigureRow {
    let sim = simulate_decode(cfg, threads, fw, m, 8);
    FigureRow {
        model: cfg.name.clone(),
        framework: fw.kind.name(),
        threads,
        tokens_per_s: sim.tokens_per_s,
        paper_tokens_per_s: paper_ref(&cfg.name, fw.kind.name(), threads),
    }
}

fn models() -> Vec<Qwen3Config> {
    vec![
        Qwen3Config::qwen3_0_6b(DType::F32),
        Qwen3Config::qwen3_0_6b(DType::F16),
        Qwen3Config::qwen3_1_7b(DType::F16),
    ]
}

/// Figure 9 — single-core (1T) token throughput.
pub fn fig9_table(m: &MachineSpec) -> Vec<FigureRow> {
    let mut rows = Vec::new();
    for cfg in models() {
        for fw in Framework::all() {
            rows.push(eval_cell(&cfg, &fw, 1, m));
        }
    }
    rows
}

/// Figure 10 — multi-core (4T/8T) token throughput.
pub fn fig10_table(m: &MachineSpec) -> Vec<FigureRow> {
    let mut rows = Vec::new();
    for cfg in models() {
        for threads in [4usize, 8] {
            for fw in Framework::all() {
                rows.push(eval_cell(&cfg, &fw, threads, m));
            }
        }
    }
    rows
}

/// Render rows as an aligned text table.
pub fn render(rows: &[FigureRow], title: &str) -> String {
    let mut s = format!("== {title} ==\n");
    s.push_str(&format!(
        "{:<18} {:<12} {:>3}  {:>10}  {:>10}  {:>7}\n",
        "model", "framework", "T", "sim tok/s", "paper", "ratio"
    ));
    for r in rows {
        let (paper, ratio) = match r.paper_tokens_per_s {
            Some(p) => (format!("{p:.2}"), format!("{:.2}x", r.tokens_per_s / p)),
            None => ("-".into(), "-".into()),
        };
        s.push_str(&format!(
            "{:<18} {:<12} {:>3}  {:>10.2}  {:>10}  {:>7}\n",
            r.model, r.framework, r.threads, r.tokens_per_s, paper, ratio
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_has_all_cells() {
        let rows = fig9_table(&MachineSpec::ryzen_5900x());
        assert_eq!(rows.len(), 3 * 4, "3 models x 4 frameworks");
        assert!(rows.iter().all(|r| r.threads == 1));
        assert!(rows.iter().all(|r| r.tokens_per_s > 0.0));
    }

    #[test]
    fn fig10_has_all_cells() {
        let rows = fig10_table(&MachineSpec::ryzen_5900x());
        assert_eq!(rows.len(), 3 * 2 * 4, "3 models x {{4T,8T}} x 4 frameworks");
    }

    #[test]
    fn paper_refs_attached_where_known() {
        let rows = fig9_table(&MachineSpec::ryzen_5900x());
        let with_ref = rows.iter().filter(|r| r.paper_tokens_per_s.is_some()).count();
        assert!(with_ref >= 7, "known §4.1 references must be attached");
    }

    #[test]
    fn render_contains_headline_cells() {
        let rows = fig9_table(&MachineSpec::ryzen_5900x());
        let s = render(&rows, "Figure 9");
        assert!(s.contains("nncase"));
        assert!(s.contains("llama.cpp"));
        assert!(s.contains("Qwen3-0.6B-f32"));
    }
}
