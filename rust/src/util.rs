//! Small shared utilities: deterministic RNG, timers, statistics.

/// Deterministic xoshiro256** PRNG — used by MCTS, workload generators and
/// weight initialization so every run is reproducible without pulling in
/// an external crate on the hot path.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 seeding.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard-normal sample (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.below(xs.len())])
        }
    }
}

/// Wall-clock stopwatch returning seconds.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Simple descriptive statistics over f64 samples.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Sum of all samples (0.0 when empty).
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum::<f64>()
    }

    /// Mean; 0.0 on an empty sample set (metrics code calls this on
    /// possibly-empty series, e.g. preemption stats — never NaN).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.sum() / self.samples.len() as f64
    }

    /// Minimum; 0.0 on an empty sample set (not +inf).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Maximum; 0.0 on an empty sample set (not -inf).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// `p`-th percentile (nearest-rank). 0.0 on an empty sample set;
    /// `p` is clamped to [0, 100] and NaN `p` maps to the median.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let p = if p.is_nan() { 50.0 } else { p.clamp(0.0, 100.0) };
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    /// 99th percentile — the tail-latency number SLO reporting keys on
    /// (shorthand for `percentile(99.0)`; 0.0 on an empty set).
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Population standard deviation. 0.0 with fewer than two samples
    /// (a single measurement has no spread to report).
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let var = self.samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt()
    }
}

/// Lenient env-knob parsing, shared by every `PALLAS_*` knob
/// (`PALLAS_TRACE_EVENTS`, `PALLAS_TEST_THREADS`, `PALLAS_TEST_SHARDS`,
/// `PALLAS_FAILPOINTS`, ...): an unset variable returns `None`
/// silently; a set-but-malformed value (unparseable, or rejected by
/// `valid`) prints ONE stderr warning and returns `None` so the
/// caller's default wins. A misspelled knob must degrade a run, never
/// kill it.
pub fn env_knob<T: std::str::FromStr>(name: &str, valid: fn(&T) -> bool) -> Option<T> {
    let raw = std::env::var(name).ok()?;
    match raw.trim().parse::<T>() {
        Ok(v) if valid(&v) => Some(v),
        _ => {
            eprintln!("warning: ignoring malformed {name}={raw:?}; using the default");
            None
        }
    }
}

/// Human-readable byte count (KiB/MiB/GiB).
pub fn human_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            let i = r.below(10);
            assert!(i < 10);
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let samples: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f32>() / n as f32;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn stats_percentiles() {
        let mut s = Stats::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.mean(), 50.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert!((s.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((s.percentile(99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn empty_stats_are_zero_not_nan() {
        let s = Stats::default();
        assert_eq!(s.len(), 0);
        assert!(s.is_empty());
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.sum(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
        assert!(!s.mean().is_nan() && !s.percentile(0.0).is_nan());
    }

    #[test]
    fn percentile_clamps_degenerate_p() {
        let mut s = Stats::default();
        for i in 1..=10 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(-5.0), 1.0);
        assert_eq!(s.percentile(250.0), 10.0);
        assert_eq!(s.percentile(f64::NAN), s.percentile(50.0));
    }

    #[test]
    fn p99_and_stddev() {
        let mut s = Stats::default();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.p99(), s.percentile(99.0));
        // Population stddev of 1..=100: sqrt((100^2 - 1) / 12).
        let expect = ((100.0f64 * 100.0 - 1.0) / 12.0).sqrt();
        assert!((s.stddev() - expect).abs() < 1e-9, "stddev {}", s.stddev());
        // Degenerate sets report zero spread, never NaN.
        assert_eq!(Stats::default().stddev(), 0.0);
        let mut one = Stats::default();
        one.push(5.0);
        assert_eq!(one.stddev(), 0.0);
        assert_eq!(one.p99(), 5.0);
    }

    #[test]
    fn sum_accumulates() {
        let mut s = Stats::default();
        s.push(1.5);
        s.push(2.5);
        assert_eq!(s.sum(), 4.0);
    }

    #[test]
    fn env_knob_is_lenient() {
        // Unique variable names per case: the test harness shares one
        // process environment across threads.
        assert_eq!(env_knob::<usize>("PALLAS_UTIL_TEST_UNSET", |_| true), None);
        std::env::set_var("PALLAS_UTIL_TEST_OK", " 42 ");
        assert_eq!(env_knob::<usize>("PALLAS_UTIL_TEST_OK", |_| true), Some(42));
        std::env::set_var("PALLAS_UTIL_TEST_BAD", "not-a-number");
        assert_eq!(env_knob::<usize>("PALLAS_UTIL_TEST_BAD", |_| true), None);
        std::env::set_var("PALLAS_UTIL_TEST_ZERO", "0");
        // A validator rejection degrades to the default too.
        assert_eq!(env_knob::<usize>("PALLAS_UTIL_TEST_ZERO", |v| *v >= 1), None);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
