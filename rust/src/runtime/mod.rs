//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Python runs once at build time (`make artifacts`): L2 (JAX model) and
//! L1 (Pallas kernels, `interpret=True`) lower to **HLO text**
//! (`artifacts/*.hlo.txt` — text, not serialized proto: xla_extension
//! 0.5.1 rejects jax≥0.5's 64-bit-id protos). This module loads the
//! artifacts through the `xla` crate's PJRT CPU client and executes them
//! from the Rust request path, with a per-path executable cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A loaded artifact manifest: name -> relative HLO path plus metadata.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub path: String,
    /// "k=v" metadata pairs from the manifest (shapes, dtypes).
    pub meta: HashMap<String, String>,
}

impl Manifest {
    /// Parse the simple line-oriented manifest `aot.py` writes:
    /// `name<TAB>path<TAB>k=v<TAB>k=v...` (comments with `#`).
    pub fn parse(text: &str) -> Manifest {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split('\t');
            let (Some(name), Some(path)) = (parts.next(), parts.next()) else {
                continue;
            };
            let mut meta = HashMap::new();
            for kv in parts {
                if let Some((k, v)) = kv.split_once('=') {
                    meta.insert(k.to_string(), v.to_string());
                }
            }
            entries.push(ManifestEntry {
                name: name.to_string(),
                path: path.to_string(),
                meta,
            });
        }
        Manifest { entries }
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Ok(Self::parse(&text))
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// The PJRT runtime with an executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(PjrtRuntime { client, artifacts_dir: artifacts_dir.into(), cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by name).
    pub fn load(&mut self, name: &str, rel_path: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.artifacts_dir.join(rel_path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a cached executable on f32 inputs; returns the flat f32
    /// outputs of the (single-tuple) result.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.cache.get(name).context("artifact not loaded")?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims_i64)
                .map_err(|e| anyhow::anyhow!("reshape input: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True; unpack all elements.
        let tuple = result.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mut out = Vec::with_capacity(tuple.len());
        for t in tuple {
            out.push(t.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?);
        }
        Ok(out)
    }

    /// Execute with mixed arguments (f32 tensors + i32 scalars), in the
    /// artifact's positional order.
    pub fn run_args(&self, name: &str, args: &[ArgValue<'_>]) -> Result<Vec<Vec<f32>>> {
        let exe = self.cache.get(name).context("artifact not loaded")?;
        let mut literals = Vec::with_capacity(args.len());
        for a in args {
            literals.push(match a {
                ArgValue::F32(data, dims) => {
                    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&dims_i64)
                        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?
                }
                ArgValue::I32Scalar(v) => xla::Literal::scalar(*v),
            });
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync {name}: {e:?}"))?;
        let tuple = result.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mut out = Vec::with_capacity(tuple.len());
        for t in tuple {
            out.push(t.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?);
        }
        Ok(out)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(|s| s.as_str()).collect()
    }
}

/// One positional argument for [`PjrtRuntime::run_args`].
pub enum ArgValue<'a> {
    F32(&'a [f32], &'a [usize]),
    I32Scalar(i32),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_lines_and_meta() {
        let m = Manifest::parse(
            "# comment\nmatmul\tkernels/matmul.hlo.txt\tm=64\tn=64\n\ndecode\tdecode.hlo.txt\n",
        );
        assert_eq!(m.entries.len(), 2);
        let e = m.get("matmul").unwrap();
        assert_eq!(e.path, "kernels/matmul.hlo.txt");
        assert_eq!(e.meta["m"], "64");
        assert!(m.get("missing").is_none());
    }

    #[test]
    fn manifest_ignores_malformed() {
        let m = Manifest::parse("justaname\n");
        assert!(m.entries.is_empty());
    }
}
