//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Python runs once at build time (`make artifacts`): L2 (JAX model) and
//! L1 (Pallas kernels, `interpret=True`) lower to **HLO text**
//! (`artifacts/*.hlo.txt`). In a full build this module loads the
//! artifacts through the `xla` crate's PJRT CPU client; the offline
//! build environment has no vendored third-party crates, so the client
//! here is a stub that reports the backend as unavailable. The
//! [`Manifest`] parsing (and everything downstream that only needs
//! artifact metadata) is fully functional; `PjrtRuntime` methods return
//! [`RuntimeError`] until the `xla`-backed client is restored (see the
//! seed revision of this file for the original implementation).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Runtime-layer error (IO or unavailable backend).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "runtime: {}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// A loaded artifact manifest: name -> relative HLO path plus metadata.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub path: String,
    /// "k=v" metadata pairs from the manifest (shapes, dtypes).
    pub meta: HashMap<String, String>,
}

impl Manifest {
    /// Parse the simple line-oriented manifest `aot.py` writes:
    /// `name<TAB>path<TAB>k=v<TAB>k=v...` (comments with `#`).
    pub fn parse(text: &str) -> Manifest {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split('\t');
            let (Some(name), Some(path)) = (parts.next(), parts.next()) else {
                continue;
            };
            let mut meta = HashMap::new();
            for kv in parts {
                if let Some((k, v)) = kv.split_once('=') {
                    meta.insert(k.to_string(), v.to_string());
                }
            }
            entries.push(ManifestEntry {
                name: name.to_string(),
                path: path.to_string(),
                meta,
            });
        }
        Manifest { entries }
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RuntimeError(format!("reading manifest {}: {e}", path.display())))?;
        Ok(Self::parse(&text))
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

const UNAVAILABLE: &str =
    "PJRT backend unavailable: this build has no `xla` crate (offline environment); \
     restore the xla-backed client to execute HLO artifacts";

/// The PJRT runtime with an executable cache (stubbed, see module docs).
pub struct PjrtRuntime {
    #[allow(dead_code)]
    artifacts_dir: PathBuf,
    cache: HashMap<String, PathBuf>,
}

impl PjrtRuntime {
    /// Whether a real PJRT backend is compiled in. Callers that would
    /// otherwise `unwrap()` a client (artifact-gated tests, examples)
    /// must check this and skip when false — the artifacts existing on
    /// disk does not mean this build can execute them.
    pub fn available() -> bool {
        false
    }

    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn cpu(_artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        Err(RuntimeError(UNAVAILABLE.into()))
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    /// Load + compile an HLO text artifact (cached by name).
    pub fn load(&mut self, _name: &str, _rel_path: &str) -> Result<()> {
        Err(RuntimeError(UNAVAILABLE.into()))
    }

    /// Execute a cached executable on f32 inputs; returns the flat f32
    /// outputs of the (single-tuple) result.
    pub fn run_f32(
        &self,
        _name: &str,
        _inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        Err(RuntimeError(UNAVAILABLE.into()))
    }

    /// Execute with mixed arguments (f32 tensors + i32 scalars), in the
    /// artifact's positional order.
    pub fn run_args(&self, _name: &str, _args: &[ArgValue<'_>]) -> Result<Vec<Vec<f32>>> {
        Err(RuntimeError(UNAVAILABLE.into()))
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(|s| s.as_str()).collect()
    }
}

/// One positional argument for [`PjrtRuntime::run_args`].
pub enum ArgValue<'a> {
    F32(&'a [f32], &'a [usize]),
    I32Scalar(i32),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_lines_and_meta() {
        let m = Manifest::parse(
            "# comment\nmatmul\tkernels/matmul.hlo.txt\tm=64\tn=64\n\ndecode\tdecode.hlo.txt\n",
        );
        assert_eq!(m.entries.len(), 2);
        let e = m.get("matmul").unwrap();
        assert_eq!(e.path, "kernels/matmul.hlo.txt");
        assert_eq!(e.meta["m"], "64");
        assert!(m.get("missing").is_none());
    }

    #[test]
    fn manifest_ignores_malformed() {
        let m = Manifest::parse("justaname\n");
        assert!(m.entries.is_empty());
    }

    #[test]
    fn stub_backend_reports_unavailable() {
        assert!(PjrtRuntime::cpu("artifacts").is_err());
    }
}
