//! Auto Scheduling (§3.2).
//!
//! The design space of a computation kernel is decoupled into two
//! orthogonal dimensions (Fig. 7):
//!
//! * **Structural part** — the Tiered Tile Graph: which ops fuse at which
//!   memory level and in which loop order. Explored with Monte Carlo Tree
//!   Search ([`mcts`]) whose actions are `merge(src, dst, level)` and
//!   `reorder(op, level, loops)`.
//! * **Parametric part** — tile sizes and buffer placement. Solved per
//!   candidate structure by the analytical MINLP model ([`minlp`]):
//!   static analysis Eqs. 6–9, constraints Eqs. 10–14, objective
//!   `min max(T_mem, T_comp)` Eqs. 15–16 over divisor-valued integer
//!   variables with branch-and-bound.
//!
//! MCTS simulation is *deterministic*: instead of random rollouts, each
//! leaf is evaluated by the MINLP solver (§3.2.1 "Analytical
//! Simulation").

mod mcts;
mod minlp;
mod tile;

pub use mcts::{autoschedule, Mcts, MctsConfig, ScheduleResult};
pub use minlp::{solve_parametric, MinlpConfig, ParametricSolution};
pub use tile::{subgraph_to_tileops, Action, BufferAccess, TileOp, TiledState};
