//! MCTS-based structural search (§3.2.1).
//!
//! Nodes of the search tree are [`TiledState`]s, edges are
//! merge / reorder [`Action`]s. Selection uses UCB1; *simulation* is the
//! deterministic MINLP evaluation of the leaf (no random rollouts —
//! "Analytical Simulation").

use super::minlp::{solve_parametric, MinlpConfig, ParametricSolution};
use super::tile::{Action, TiledState};
use crate::cost::MachineSpec;
use crate::util::Rng;

/// MCTS configuration.
#[derive(Debug, Clone)]
pub struct MctsConfig {
    pub iterations: usize,
    /// UCB1 exploration constant.
    pub exploration: f64,
    /// Maximum action-sequence depth.
    pub max_depth: usize,
    pub seed: u64,
    pub minlp: MinlpConfig,
}

impl Default for MctsConfig {
    fn default() -> Self {
        MctsConfig {
            iterations: 120,
            exploration: 1.2,
            max_depth: 6,
            seed: 0x5EED,
            minlp: MinlpConfig::default(),
        }
    }
}

struct TreeNode {
    state: TiledState,
    parent: Option<usize>,
    /// Untried actions.
    untried: Vec<Action>,
    children: Vec<(Action, usize)>,
    visits: f64,
    /// Sum of rewards (reward = -latency in μs).
    reward_sum: f64,
    /// Best latency ever observed under this node.
    best_latency: f64,
}

/// The search driver.
pub struct Mcts {
    nodes: Vec<TreeNode>,
    cfg: MctsConfig,
    rng: Rng,
}

/// The chosen schedule: structure + parameters + estimated latency.
#[derive(Debug)]
pub struct ScheduleResult {
    pub state: TiledState,
    pub solution: ParametricSolution,
    pub actions: Vec<Action>,
    pub evaluations: usize,
}

impl Mcts {
    pub fn new(root: TiledState, cfg: MctsConfig) -> Self {
        let untried = root.legal_actions();
        let rng = Rng::new(cfg.seed);
        Mcts {
            nodes: vec![TreeNode {
                state: root,
                parent: None,
                untried,
                children: Vec::new(),
                visits: 0.0,
                reward_sum: 0.0,
                best_latency: f64::INFINITY,
            }],
            cfg,
            rng,
        }
    }

    fn ucb_child(&self, id: usize) -> Option<usize> {
        let n = &self.nodes[id];
        if n.children.is_empty() {
            return None;
        }
        let ln_n = n.visits.max(1.0).ln();
        n.children
            .iter()
            .map(|&(_, c)| {
                let ch = &self.nodes[c];
                let mean = ch.reward_sum / ch.visits.max(1.0);
                let ucb = mean + self.cfg.exploration * (ln_n / ch.visits.max(1.0)).sqrt();
                (c, ucb)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(c, _)| c)
    }

    fn depth(&self, mut id: usize) -> usize {
        let mut d = 0;
        while let Some(p) = self.nodes[id].parent {
            id = p;
            d += 1;
        }
        d
    }

    /// Run the search on `machine`; returns the best schedule found.
    pub fn run(mut self, machine: &MachineSpec) -> Option<ScheduleResult> {
        let mut best: Option<(usize, ParametricSolution)> = None;
        let mut evaluations = 0usize;

        for _ in 0..self.cfg.iterations {
            // Selection: descend while fully expanded.
            let mut cur = 0usize;
            while self.nodes[cur].untried.is_empty() && !self.nodes[cur].children.is_empty() {
                match self.ucb_child(cur) {
                    Some(c) => cur = c,
                    None => break,
                }
            }
            // Expansion: pop one untried action (if depth allows).
            if !self.nodes[cur].untried.is_empty() && self.depth(cur) < self.cfg.max_depth {
                let idx = self.rng.below(self.nodes[cur].untried.len());
                let action = self.nodes[cur].untried.swap_remove(idx);
                let state = self.nodes[cur].state.apply(&action);
                let untried = if self.depth(cur) + 1 < self.cfg.max_depth {
                    state.legal_actions()
                } else {
                    vec![]
                };
                let child = self.nodes.len();
                self.nodes.push(TreeNode {
                    state,
                    parent: Some(cur),
                    untried,
                    children: Vec::new(),
                    visits: 0.0,
                    reward_sum: 0.0,
                    best_latency: f64::INFINITY,
                });
                self.nodes[cur].children.push((action, child));
                cur = child;
            }
            // Simulation: deterministic MINLP evaluation of the state.
            evaluations += 1;
            let latency = match solve_parametric(&self.nodes[cur].state, machine, &self.cfg.minlp)
            {
                Some(sol) => {
                    let l = sol.latency_s;
                    let better = best
                        .as_ref()
                        .map(|(_, b)| l < b.latency_s)
                        .unwrap_or(true);
                    if better {
                        best = Some((cur, sol));
                    }
                    l
                }
                None => f64::INFINITY,
            };
            // Backpropagation: reward = -latency in microseconds.
            let reward = if latency.is_finite() { -latency * 1e6 } else { -1e12 };
            let mut up = Some(cur);
            while let Some(id) = up {
                let n = &mut self.nodes[id];
                n.visits += 1.0;
                n.reward_sum += reward;
                n.best_latency = n.best_latency.min(latency);
                up = n.parent;
            }
        }

        let (best_id, solution) = best?;
        // Recover the action sequence.
        let mut actions = Vec::new();
        let mut cur = best_id;
        while let Some(p) = self.nodes[cur].parent {
            let (a, _) = self.nodes[p]
                .children
                .iter()
                .find(|&&(_, c)| c == cur)
                .expect("child link")
                .clone();
            actions.push(a);
            cur = p;
        }
        actions.reverse();
        Some(ScheduleResult {
            state: self.nodes[best_id].state.clone(),
            solution,
            actions,
            evaluations,
        })
    }
}

/// One-call driver: schedule `state` on `machine`.
pub fn autoschedule(
    state: TiledState,
    machine: &MachineSpec,
    cfg: MctsConfig,
) -> Option<ScheduleResult> {
    Mcts::new(state, cfg).run(machine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::tile::tests::attention_ops;
    use crate::schedule::{solve_parametric, MinlpConfig};

    #[test]
    fn mcts_finds_schedule_at_least_as_good_as_initial() {
        let m = MachineSpec::ryzen_5900x();
        let init = TiledState::initial(attention_ops(), 3);
        let base = solve_parametric(&init, &m, &MinlpConfig::default()).unwrap();
        let cfg = MctsConfig { iterations: 60, ..Default::default() };
        let res = autoschedule(init, &m, cfg).unwrap();
        assert!(
            res.solution.latency_s <= base.latency_s * 1.0001,
            "MCTS {} must not lose to the initial structure {}",
            res.solution.latency_s,
            base.latency_s
        );
        assert!(res.evaluations >= 60);
    }

    #[test]
    fn mcts_discovers_fusion() {
        // On the attention kernel the best structures fuse at least one
        // producer into its consumer (keeping T1/T2 on-chip).
        let m = MachineSpec::ryzen_5900x();
        let init = TiledState::initial(attention_ops(), 3);
        let cfg = MctsConfig { iterations: 150, seed: 7, ..Default::default() };
        let res = autoschedule(init, &m, cfg).unwrap();
        let fused_any = res.state.fused_at.iter().any(|f| f.is_some());
        assert!(
            fused_any,
            "best schedule should fuse; actions: {:?}",
            res.actions
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let m = MachineSpec::ryzen_5900x();
        let cfg = MctsConfig { iterations: 40, ..Default::default() };
        let r1 =
            autoschedule(TiledState::initial(attention_ops(), 3), &m, cfg.clone()).unwrap();
        let r2 = autoschedule(TiledState::initial(attention_ops(), 3), &m, cfg).unwrap();
        assert_eq!(r1.actions, r2.actions);
        assert_eq!(r1.solution.latency_s, r2.solution.latency_s);
    }
}
