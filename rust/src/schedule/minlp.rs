//! Parametric optimization (§3.2.2): tile sizes and buffer placement.
//!
//! The analytical model follows Eqs. 6–16:
//! * **Extent** (Eq. 6): per-dim tile extents `E[l][d]` form a divisor
//!   chain `E[0] | E[1] | ... | E[levels] = full extent`.
//! * **Buffer size** (Eq. 7): product of the access-relation extents.
//! * **Trip count** (Eq. 8): `trip_d(l) = E[l][d] / E[l-1][d]`.
//! * **Data traffic** (Eq. 9): `Φ = Place × Size × Trip`, split into the
//!   DRAM→placement leg (distinct tiles only — non-access dims reuse the
//!   resident copy) and the placement→compute streaming leg.
//! * **Constraints** (Eqs. 10–14): domain coverage by construction,
//!   placement capacity with double buffering, fused intermediates pinned
//!   at or below their fusion level.
//! * **Objective** (Eqs. 15–16): `min max(T_mem, T_comp)` with the
//!   μkernel linear-regression time model (`μKT = overhead + flops/peak`).
//!
//! The discrete program is solved by coordinate descent over per-dim
//! divisor chains from multiple warm starts, with optimal greedy buffer
//! placement per candidate — a branch-and-bound-equivalent for this
//! monotone objective that keeps MCTS simulations fast (§3.2.1).

use std::collections::HashMap;

use super::tile::{TiledState};
use crate::cost::MachineSpec;

/// Solver configuration.
#[derive(Debug, Clone)]
pub struct MinlpConfig {
    /// Double-buffering factor applied to capacity checks.
    pub buffering: f64,
    /// μkernel call overhead in ns (the intercept of the μKT regression).
    pub ukernel_overhead_ns: f64,
    /// Fraction of machine peak the μkernel inner loop achieves.
    pub ukernel_efficiency: f64,
}

impl Default for MinlpConfig {
    fn default() -> Self {
        MinlpConfig { buffering: 2.0, ukernel_overhead_ns: 40.0, ukernel_efficiency: 0.85 }
    }
}

/// A solved parametric configuration.
#[derive(Debug, Clone)]
pub struct ParametricSolution {
    /// Tile extents per level per dim: `extents[l][d]` (level 0 =
    /// register/μkernel tile; last level = full extent).
    pub extents: Vec<HashMap<char, usize>>,
    /// Buffer placement: memory level where each buffer's tile resides.
    pub placement: HashMap<String, usize>,
    pub t_comp_s: f64,
    pub t_mem_s: f64,
    /// The objective: `max(T_mem, T_comp)`.
    pub latency_s: f64,
    /// Bytes crossing each cache boundary, innermost first.
    pub traffic_bytes: Vec<f64>,
}

/// Candidate divisors of `n`, thinned to at most ~10 well-spread values.
fn divisors(n: usize) -> Vec<usize> {
    let mut d: Vec<usize> = (1..=n).filter(|k| n % k == 0).collect();
    if d.len() > 10 {
        // Keep 1, n, and geometrically spaced interior points.
        let keep: Vec<usize> = (0..10)
            .map(|i| {
                let idx = ((i as f64 / 9.0) * (d.len() - 1) as f64).round() as usize;
                d[idx]
            })
            .collect();
        d = keep;
        d.dedup();
    }
    d
}

struct Model<'a> {
    state: &'a TiledState,
    machine: &'a MachineSpec,
    cfg: &'a MinlpConfig,
    dims: Vec<(char, usize)>,
    /// (buffer, op, dims, elem, write, intermediate, max_place_level)
    buffers: Vec<BufInfo>,
}

#[derive(Debug, Clone)]
struct BufInfo {
    name: String,
    op: usize,
    dims: Vec<char>,
    elem: usize,
    write: bool,
    /// Min/max level the buffer may be placed at (fusion constraint,
    /// Eq. 13). Unfused intermediates are pinned to `levels` (the whole
    /// tensor materializes between the two kernels: DRAM round-trip);
    /// fused intermediates are pinned at or below their fusion level and
    /// never touch DRAM.
    min_level: usize,
    max_level: usize,
    /// True if the buffer is produced on-chip by a fused producer (no
    /// DRAM fetch leg).
    on_chip: bool,
}

impl<'a> Model<'a> {
    fn new(state: &'a TiledState, machine: &'a MachineSpec, cfg: &'a MinlpConfig) -> Self {
        // Union of dims with extents (shared by name across ops).
        let mut dims: Vec<(char, usize)> = Vec::new();
        for op in state.ops.iter() {
            for &(d, e) in &op.loops {
                if !dims.iter().any(|(x, _)| *x == d) {
                    dims.push((d, e));
                }
            }
        }
        // Buffer table. A buffer is *intermediate* if some op writes it
        // and another reads it. Fused intermediates must live at or below
        // the fusion level; unfused intermediates round-trip DRAM.
        let levels = state.levels;
        let mut buffers: Vec<BufInfo> = Vec::new();
        for (oi, op) in state.ops.iter().enumerate() {
            for b in &op.buffers {
                let produced_by = state.ops.iter().position(|p| {
                    p.buffers.iter().any(|x| x.write && x.buffer == b.buffer)
                });
                let consumed = state
                    .ops
                    .iter()
                    .any(|p| p.buffers.iter().any(|x| !x.write && x.buffer == b.buffer));
                let (min_level, max_level, on_chip) = match produced_by {
                    Some(src) if consumed => match state.fused_at[src] {
                        // Fused: resident at/below the fusion level,
                        // produced on-chip (no DRAM leg).
                        Some((_, fl)) => (1, fl.max(1), true),
                        // Not fused: the whole tensor materializes
                        // between kernels — forced DRAM round trip.
                        None => (levels, levels, false),
                    },
                    _ => (1, levels, false),
                };
                buffers.push(BufInfo {
                    name: b.buffer.clone(),
                    op: oi,
                    dims: b.dims.clone(),
                    elem: b.elem_bytes,
                    write: b.write,
                    min_level,
                    max_level,
                    on_chip,
                });
            }
        }
        Model { state, machine, cfg, dims, buffers }
    }

    fn extent(&self, ext: &[HashMap<char, usize>], l: usize, d: char) -> usize {
        if l >= ext.len() {
            self.dims.iter().find(|(x, _)| *x == d).map(|(_, e)| *e).unwrap_or(1)
        } else {
            ext[l].get(&d).copied().unwrap_or(1)
        }
    }

    fn tile_bytes(&self, ext: &[HashMap<char, usize>], b: &BufInfo, l: usize) -> f64 {
        let mut s = b.elem as f64;
        for &d in &b.dims {
            s *= self.extent(ext, l, d) as f64;
        }
        s
    }

    /// trip_d at level l for the op owning dims (Eq. 8).
    fn trip(&self, ext: &[HashMap<char, usize>], l: usize, d: char) -> f64 {
        self.extent(ext, l, d) as f64 / self.extent(ext, l.wrapping_sub(1), d) as f64
    }

    /// Distinct-tile fetch count from DRAM to placement level `p`
    /// (non-access dims reuse the resident copy — the Eq. 9 Φ with
    /// placement).
    fn distinct_fetches(&self, ext: &[HashMap<char, usize>], b: &BufInfo, p: usize) -> f64 {
        let mut n = 1.0;
        let levels = self.state.levels;
        for l in (p + 1)..=levels {
            for &d in &b.dims {
                n *= self.trip(ext, l, d);
            }
        }
        n
    }

    /// Total level-0 tile loads of the owning op (streaming leg).
    fn leaf_loads(&self, ext: &[HashMap<char, usize>], b: &BufInfo) -> f64 {
        let op = &self.state.ops[b.op];
        let mut n = 1.0;
        for l in 1..=self.state.levels {
            for &(d, _) in &op.loops {
                n *= self.trip(ext, l, d);
            }
        }
        n * self.tile_bytes(ext, b, 0)
    }

    /// Evaluate a complete extent assignment: optimal greedy placement +
    /// objective. Returns None if even DRAM placement violates capacity.
    fn evaluate(&self, ext: &[HashMap<char, usize>]) -> Option<ParametricSolution> {
        let levels = self.state.levels;
        // Capacity per level (per core; level index 1..=levels-1 are
        // caches; `levels` = DRAM, unconstrained here).
        let cap = |l: usize| -> f64 {
            self.machine
                .caches
                .get(l - 1)
                .map(|c| c.size_bytes as f64 / self.cfg.buffering)
                .unwrap_or(f64::INFINITY)
        };
        let bw = |l: usize| -> f64 {
            if l >= levels {
                self.machine.dram_bw(1)
            } else {
                self.machine.caches[l - 1].bw_gbps * 1e9
            }
        };

        // Greedy placement: for each buffer pick the level minimizing its
        // modeled traffic cost, subject to remaining capacity. Buffers
        // with the largest traffic benefit are placed first.
        let mut used = vec![0.0f64; levels + 1];
        let mut placement: HashMap<String, usize> = HashMap::new();
        // Deduplicate buffers by name (multiple accessors share residency).
        let mut by_name: HashMap<String, Vec<&BufInfo>> = HashMap::new();
        for b in &self.buffers {
            by_name.entry(b.name.clone()).or_default().push(b);
        }
        let cost_at = |b: &BufInfo, p: usize| -> f64 {
            // DRAM leg: skipped for on-chip (fused) intermediates.
            let dram = if b.on_chip {
                0.0
            } else {
                self.tile_bytes(ext, b, p) * self.distinct_fetches(ext, b, p) / bw(levels)
            };
            let stream = self.leaf_loads(ext, b) / bw(p.min(levels));
            // Unfused intermediates at DRAM pay write + read.
            let w = if b.write { 2.0 } else { 1.0 };
            dram * if p == levels { w } else { 1.0 } + stream
        };
        let mut names: Vec<String> = by_name.keys().cloned().collect();
        names.sort();
        // Order by potential benefit (biggest streamers first).
        names.sort_by(|a, b| {
            let la: f64 = by_name[a].iter().map(|bi| self.leaf_loads(ext, bi)).sum();
            let lb: f64 = by_name[b].iter().map(|bi| self.leaf_loads(ext, bi)).sum();
            lb.partial_cmp(&la).unwrap()
        });
        let mut t_mem = 0.0;
        for name in &names {
            let accs = &by_name[name];
            let max_level = accs.iter().map(|b| b.max_level).min().unwrap();
            let min_level = accs.iter().map(|b| b.min_level).max().unwrap();
            if min_level > max_level {
                return None; // contradictory fusion constraints
            }
            let mut best: Option<(usize, f64, f64)> = None; // (level, cost, size)
            for p in min_level..=max_level {
                let size: f64 =
                    accs.iter().map(|b| self.tile_bytes(ext, b, p)).fold(0.0, f64::max);
                if p < levels && used[p] + size > cap(p) {
                    continue;
                }
                let cost: f64 = accs.iter().map(|b| cost_at(b, p)).sum();
                if best.map(|(_, c, _)| cost < c).unwrap_or(true) {
                    best = Some((p, cost, size));
                }
            }
            let (p, cost, size) = best?;
            if p < levels {
                used[p] += size;
            }
            placement.insert(name.clone(), p);
            t_mem += cost;
        }

        // T_comp (Eq. 15): leaf μkernel calls × (overhead + tile flops/peak).
        let peak =
            self.machine.peak_flops(1, 4) * self.cfg.ukernel_efficiency;
        let mut t_comp = 0.0;
        for op in self.state.ops.iter() {
            let mut calls = 1.0;
            let mut tile_flops = op.flops_per_point as f64;
            for &(d, _) in &op.loops {
                for l in 1..=levels {
                    calls *= self.trip(ext, l, d);
                }
                tile_flops *= self.extent(ext, 0, d) as f64;
            }
            t_comp += calls * (self.cfg.ukernel_overhead_ns * 1e-9 + tile_flops / peak);
        }

        // Traffic per boundary for reporting.
        let mut traffic = vec![0.0; levels + 1];
        for name in &names {
            let accs = &by_name[name];
            let p = placement[name];
            for b in accs {
                traffic[p.min(levels)] += self.tile_bytes(ext, b, p)
                    * self.distinct_fetches(ext, b, p);
            }
        }

        Some(ParametricSolution {
            extents: ext.to_vec(),
            placement,
            t_comp_s: t_comp,
            t_mem_s: t_mem,
            latency_s: t_comp.max(t_mem),
            traffic_bytes: traffic,
        })
    }
}

/// Solve the parametric part for a structural state. Returns the best
/// configuration found (coordinate descent over divisor chains from
/// several warm starts).
pub fn solve_parametric(
    state: &TiledState,
    machine: &MachineSpec,
    cfg: &MinlpConfig,
) -> Option<ParametricSolution> {
    let model = Model::new(state, machine, cfg);
    let levels = state.levels;
    let dim_divs: Vec<(char, Vec<usize>)> =
        model.dims.iter().map(|&(d, e)| (d, divisors(e))).collect();

    // Warm starts: small tiles, medium, full-extent tiles.
    let starts: Vec<Vec<HashMap<char, usize>>> = [0.0f64, 0.5, 1.0]
        .iter()
        .map(|&frac| {
            (0..levels)
                .map(|l| {
                    let level_frac = frac * (l + 1) as f64 / levels as f64;
                    dim_divs
                        .iter()
                        .map(|(d, divs)| {
                            let idx =
                                ((divs.len() - 1) as f64 * level_frac).round() as usize;
                            (*d, divs[idx])
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    let mut best: Option<ParametricSolution> = None;
    for start in starts {
        let mut ext = start;
        // Repair monotonicity: E[l] must divide E[l+1] (and full extent).
        for (d, divs) in &dim_divs {
            let full = *divs.last().unwrap();
            let mut prev = 1;
            for l in 0..levels {
                let e = ext[l].get_mut(d).unwrap();
                // Round down to a divisor of full that is a multiple of prev.
                let cand = divs
                    .iter()
                    .rev()
                    .find(|&&v| v <= *e && v % prev == 0 && full % v == 0)
                    .copied()
                    .unwrap_or(prev);
                *e = cand;
                prev = cand;
            }
        }
        let mut cur = model.evaluate(&ext);
        // Coordinate descent until fixpoint.
        for _pass in 0..6 {
            let mut improved = false;
            for (d, divs) in &dim_divs {
                for l in 0..levels {
                    let orig = ext[l][d];
                    let below = if l == 0 { 1 } else { ext[l - 1][d] };
                    let above = if l + 1 < levels {
                        ext[l + 1][d]
                    } else {
                        *divs.last().unwrap()
                    };
                    for &v in divs {
                        if v == orig || v % below != 0 || above % v != 0 {
                            continue;
                        }
                        ext[l].insert(*d, v);
                        let cand = model.evaluate(&ext);
                        let better = match (&cand, &cur) {
                            (Some(c), Some(b)) => c.latency_s < b.latency_s,
                            (Some(_), None) => true,
                            _ => false,
                        };
                        if better {
                            cur = cand;
                            improved = true;
                        } else {
                            ext[l].insert(*d, orig);
                        }
                    }
                }
            }
            if !improved {
                break;
            }
        }
        if let Some(c) = cur {
            if best.as_ref().map(|b| c.latency_s < b.latency_s).unwrap_or(true) {
                best = Some(c);
            }
        }
    }
    // Attach the full-extent top level for reporting.
    best.map(|mut b| {
        let top: HashMap<char, usize> = model.dims.iter().cloned().collect();
        b.extents.push(top);
        b
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::tile::tests::attention_ops;
    use crate::schedule::Action;

    fn machine() -> MachineSpec {
        MachineSpec::ryzen_5900x()
    }

    #[test]
    fn solves_initial_attention() {
        let s = TiledState::initial(attention_ops(), 3);
        let sol = solve_parametric(&s, &machine(), &MinlpConfig::default()).unwrap();
        assert!(sol.latency_s > 0.0);
        assert!(sol.latency_s < 1.0, "128x64 attention must be far below 1s");
        // Level-0 tiles divide full extents.
        for (d, e0) in &sol.extents[0] {
            let full = sol.extents.last().unwrap()[d];
            assert_eq!(full % e0, 0, "tile {e0} of dim {d} must divide {full}");
        }
    }

    #[test]
    fn capacity_respected() {
        let s = TiledState::initial(attention_ops(), 3);
        let cfg = MinlpConfig::default();
        let m = machine();
        let sol = solve_parametric(&s, &m, &cfg).unwrap();
        // Sum of resident tiles per cache level within capacity.
        let mut used = vec![0.0f64; s.levels + 1];
        let model_dims: Vec<char> = sol.extents[0].keys().copied().collect();
        let _ = model_dims;
        for op in s.ops.iter() {
            for b in &op.buffers {
                if let Some(&p) = sol.placement.get(&b.buffer) {
                    if p < s.levels {
                        let bytes: usize = b
                            .dims
                            .iter()
                            .map(|d| sol.extents[p][d])
                            .product::<usize>()
                            * b.elem_bytes;
                        used[p] = used[p].max(used[p] + bytes as f64); // accumulate
                    }
                }
            }
        }
        for (l, u) in used.iter().enumerate().skip(1) {
            if l - 1 < m.caches.len() {
                // Allow the shared-residency dedup slack (same buffer
                // counted once in the solver, multiple accesses here).
                assert!(
                    *u <= 4.0 * m.caches[l - 1].size_bytes as f64,
                    "level {l} usage {u} overflows"
                );
            }
        }
    }

    #[test]
    fn fusion_reduces_memory_time() {
        // Fusing Exp into the consumer at a cache level keeps T2 on-chip;
        // the unfused schedule round-trips it through DRAM.
        let base = TiledState::initial(attention_ops(), 3);
        let cfg = MinlpConfig::default();
        let m = machine();
        let unfused = solve_parametric(&base, &m, &cfg).unwrap();
        let fused = base
            .apply(&Action::Merge { src: 0, dst: 1, level: 2 })
            .apply(&Action::Merge { src: 1, dst: 2, level: 2 });
        let fsol = solve_parametric(&fused, &m, &cfg).unwrap();
        assert!(
            fsol.t_mem_s <= unfused.t_mem_s,
            "fused T_mem {} must not exceed unfused {}",
            fsol.t_mem_s,
            unfused.t_mem_s
        );
    }

    #[test]
    fn tiny_tiles_are_worse() {
        // Fig. 7 bottom: the [1,1,1] configuration loses to the solved
        // one because of per-call overhead and poor reuse.
        let s = TiledState::initial(attention_ops(), 3);
        let cfg = MinlpConfig::default();
        let m = machine();
        let solved = solve_parametric(&s, &m, &cfg).unwrap();
        // Build the all-ones extents manually and evaluate.
        let model = Model::new(&s, &m, &cfg);
        let ones: Vec<HashMap<char, usize>> = (0..s.levels)
            .map(|_| model.dims.iter().map(|&(d, _)| (d, 1usize)).collect())
            .collect();
        let bad = model.evaluate(&ones).unwrap();
        assert!(
            solved.latency_s < bad.latency_s,
            "solved {} must beat all-ones {}",
            solved.latency_s,
            bad.latency_s
        );
    }
}
