//! Tiered tile graphs: ops, loop dims, buffer access relations, and the
//! structural state with its `merge` / `reorder` actions (Eq. 3).

use std::collections::HashMap;

use crate::ir::{Graph, NodeId, Op};

/// One buffer access of a [`TileOp`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BufferAccess {
    pub buffer: String,
    pub write: bool,
    /// The loop dims (by name) indexing this buffer — the access relation
    /// 𝒜 of Eq. 7.
    pub dims: Vec<char>,
    /// Element size in bytes.
    pub elem_bytes: usize,
}

/// One operator of the kernel subgraph, as a loop nest over named dims.
#[derive(Debug, Clone)]
pub struct TileOp {
    pub name: String,
    /// (dim name, full extent) — the iteration domain.
    pub loops: Vec<(char, usize)>,
    pub buffers: Vec<BufferAccess>,
    /// FLOPs per iteration-space point (2 for FMA in matmul).
    pub flops_per_point: u64,
}

impl TileOp {
    pub fn extent(&self, d: char) -> Option<usize> {
        self.loops.iter().find(|(n, _)| *n == d).map(|(_, e)| *e)
    }

    pub fn total_points(&self) -> u64 {
        self.loops.iter().map(|(_, e)| *e as u64).product()
    }
}

/// Structural actions (§3.2.1 "Search Mechanics").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// merge(src, dst, level): fuse `src` into `dst` at memory `level`.
    Merge { src: usize, dst: usize, level: usize },
    /// reorder(op, level, loops): set the loop order of `op` at `level`.
    Reorder { op: usize, level: usize, order: Vec<char> },
}

/// The structural state: a Tiered Tile Graph in the tile-centric notation
/// of Eq. 3. `fused_at[i] = Some((j, l))` means op i's subtree lives under
/// op j at level l (intermediate results stay within level l and below —
/// the green box of Fig. 7). `order[l][i]` is op i's loop order at level
/// l.
#[derive(Debug, Clone)]
pub struct TiledState {
    pub ops: std::rc::Rc<Vec<TileOp>>,
    /// Fusion assignment: op -> (host op, fusion level).
    pub fused_at: Vec<Option<(usize, usize)>>,
    /// Loop order per level per op.
    pub order: Vec<Vec<Vec<char>>>,
    /// Number of memory levels (level 0 = registers/L1 μkernel tile,
    /// level `levels` = top/DRAM).
    pub levels: usize,
}

impl TiledState {
    /// Initial state: no fusion, natural loop order at every level.
    pub fn initial(ops: Vec<TileOp>, levels: usize) -> Self {
        let order: Vec<Vec<Vec<char>>> = (0..=levels)
            .map(|_| ops.iter().map(|op| op.loops.iter().map(|(d, _)| *d).collect()).collect())
            .collect();
        let n = ops.len();
        TiledState { ops: std::rc::Rc::new(ops), fused_at: vec![None; n], order, levels }
    }

    /// Producer-consumer pairs: (producer, consumer) where consumer reads
    /// a buffer the producer writes.
    pub fn dependencies(&self) -> Vec<(usize, usize)> {
        let mut deps = Vec::new();
        for (pi, p) in self.ops.iter().enumerate() {
            for pb in p.buffers.iter().filter(|b| b.write) {
                for (ci, c) in self.ops.iter().enumerate() {
                    if ci != pi
                        && c.buffers.iter().any(|b| !b.write && b.buffer == pb.buffer)
                    {
                        deps.push((pi, ci));
                    }
                }
            }
        }
        deps
    }

    /// Legal actions from this state. Merges follow producer-consumer
    /// edges; reorders are adjacent-swaps of each op's per-level order
    /// (keeping the branching factor tractable).
    pub fn legal_actions(&self) -> Vec<Action> {
        let mut acts = Vec::new();
        for (src, dst) in self.dependencies() {
            if self.fused_at[src].is_none() {
                // Fusing at level l means the intermediate buffer lives at
                // level l and below. Level 0 fusion = register fusion.
                for level in 1..self.levels {
                    acts.push(Action::Merge { src, dst, level });
                }
            }
        }
        for op in 0..self.ops.len() {
            for level in 1..=self.levels {
                let ord = &self.order[level][op];
                for i in 0..ord.len().saturating_sub(1) {
                    let mut next = ord.clone();
                    next.swap(i, i + 1);
                    acts.push(Action::Reorder { op, level, order: next });
                }
            }
        }
        acts
    }

    /// Apply an action, returning the successor state.
    pub fn apply(&self, action: &Action) -> TiledState {
        let mut s = self.clone();
        match action {
            Action::Merge { src, dst, level } => {
                s.fused_at[*src] = Some((*dst, *level));
            }
            Action::Reorder { op, level, order } => {
                s.order[*level][*op] = order.clone();
            }
        }
        s
    }

    /// Render the state in the tile-centric notation of Eq. 3.
    pub fn notation(&self) -> String {
        let mut out = String::new();
        for level in (0..=self.levels).rev() {
            out.push_str(&format!("Level {level}: "));
            let mut first = true;
            for (i, op) in self.ops.iter().enumerate() {
                // Fused ops do not appear above their fusion level.
                if let Some((_, fl)) = self.fused_at[i] {
                    if level > fl {
                        continue;
                    }
                }
                if !first {
                    out.push_str(", ");
                }
                first = false;
                let loops: Vec<String> = self.order[level][i]
                    .iter()
                    .map(|d| format!("{d}^{level}"))
                    .collect();
                let children: Vec<String> = if level == 0 {
                    vec![op.name.clone()]
                } else {
                    let mut ch = vec![format!("Op_{i}^{}", level - 1)];
                    // Fused children at this level.
                    for (j, f) in self.fused_at.iter().enumerate() {
                        if let Some((host, fl)) = f {
                            if *host == i && *fl == level {
                                ch.insert(0, format!("Op_{j}^{}", level - 1));
                            }
                        }
                    }
                    ch
                };
                out.push_str(&format!(
                    "Op_{i}^{level} = {{{}}}({})",
                    loops.join(","),
                    children.join(", ")
                ));
            }
            out.push('\n');
        }
        out
    }
}

/// Convert a fusable IR subgraph (matmul / element-wise / softmax chain)
/// into [`TileOp`]s with shared loop-dim names, following Fig. 7's
/// convention (the first matmul gets dims i,k,l; consumers inherit the
/// producer's output dims).
pub fn subgraph_to_tileops(g: &Graph, nodes: &[NodeId]) -> Vec<TileOp> {
    let mut next_dim = b'i';
    let mut fresh = || {
        let d = next_dim as char;
        next_dim += 1;
        d
    };
    // Output dims of each emitted node.
    let mut out_dims: HashMap<NodeId, Vec<char>> = HashMap::new();
    let mut ops = Vec::new();

    for &id in nodes {
        let node = g.node(id);
        let elem = node.ty.dtype.size_bytes();
        let bufname = |nid: NodeId| format!("t{}", nid.0);
        match &node.op {
            Op::MatMul => {
                let (a, b) = (node.inputs[0], node.inputs[1]);
                let a_dims = out_dims.get(&a).cloned().unwrap_or_else(|| {
                    let r = g.node(a).ty.shape.rank();
                    (0..r).map(|_| fresh()).collect()
                });
                // B: k dim shared with A's last dim; fresh n dim.
                let k = *a_dims.last().unwrap();
                let n = fresh();
                let m = a_dims[a_dims.len() - 2];
                let a_shape = &g.node(a).ty.shape;
                let b_shape = &g.node(b).ty.shape;
                let loops = vec![
                    (m, a_shape.0[a_shape.rank() - 2]),
                    (k, a_shape.0[a_shape.rank() - 1]),
                    (n, b_shape.0[b_shape.rank() - 1]),
                ];
                let my_out = vec![m, n];
                ops.push(TileOp {
                    name: format!("matmul_{}", id.0),
                    loops,
                    buffers: vec![
                        BufferAccess {
                            buffer: bufname(a),
                            write: false,
                            dims: vec![m, k],
                            elem_bytes: elem,
                        },
                        BufferAccess {
                            buffer: bufname(b),
                            write: false,
                            dims: vec![k, n],
                            elem_bytes: elem,
                        },
                        BufferAccess {
                            buffer: bufname(id),
                            write: true,
                            dims: my_out.clone(),
                            elem_bytes: elem,
                        },
                    ],
                    flops_per_point: 2,
                });
                out_dims.insert(id, my_out);
            }
            Op::Unary(_) | Op::Softmax { .. } | Op::Binary(_) => {
                let x = node.inputs[0];
                let dims = out_dims.get(&x).cloned().unwrap_or_else(|| {
                    let r = g.node(x).ty.shape.rank();
                    (0..r).map(|_| fresh()).collect()
                });
                let shape = &g.node(x).ty.shape;
                let loops: Vec<(char, usize)> =
                    dims.iter().zip(&shape.0).map(|(&d, &e)| (d, e)).collect();
                let mut buffers = vec![BufferAccess {
                    buffer: bufname(x),
                    write: false,
                    dims: dims.clone(),
                    elem_bytes: elem,
                }];
                if node.inputs.len() > 1 {
                    buffers.push(BufferAccess {
                        buffer: bufname(node.inputs[1]),
                        write: false,
                        dims: dims.clone(),
                        elem_bytes: elem,
                    });
                }
                buffers.push(BufferAccess {
                    buffer: bufname(id),
                    write: true,
                    dims: dims.clone(),
                    elem_bytes: elem,
                });
                let fpp = match &node.op {
                    Op::Unary(crate::ir::UnaryKind::Exp) => 8,
                    Op::Softmax { .. } => 12,
                    _ => 1,
                };
                ops.push(TileOp {
                    name: format!("{}_{}", node.op.mnemonic(), id.0),
                    loops,
                    buffers,
                    flops_per_point: fpp,
                });
                out_dims.insert(id, dims);
            }
            _ => { /* leaves and views contribute no loop nest */ }
        }
    }
    ops
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::ir::{DType, Graph, UnaryKind};

    /// Fig. 7's kernel: T1 = MatMul(Q,K); T2 = Exp(T1); O = MatMul(T2,V).
    pub(crate) fn attention_ops() -> Vec<TileOp> {
        let mut g = Graph::new();
        let q = g.input("Q", &[128, 64], DType::F32);
        let k = g.input("K", &[64, 128], DType::F32);
        let v = g.input("V", &[128, 64], DType::F32);
        let t1 = g.matmul(q, k);
        let t2 = g.unary(UnaryKind::Exp, t1);
        let o = g.matmul(t2, v);
        g.mark_output(o);
        let nodes = g.live_nodes();
        subgraph_to_tileops(&g, &nodes)
    }

    #[test]
    fn dims_are_shared_across_ops() {
        let ops = attention_ops();
        assert_eq!(ops.len(), 3);
        // Exp inherits matmul0's output dims; matmul2 reads them.
        let mm0_out: Vec<char> =
            ops[0].buffers.iter().find(|b| b.write).unwrap().dims.clone();
        let exp_in: Vec<char> =
            ops[1].buffers.iter().find(|b| !b.write).unwrap().dims.clone();
        assert_eq!(mm0_out, exp_in, "Exp must read the dims MatMul writes");
        let mm2_in: Vec<char> = ops[2].buffers[0].dims.clone();
        assert_eq!(exp_in, mm2_in);
        // Loop extents match the shapes.
        assert_eq!(ops[0].extent(mm0_out[0]), Some(128));
    }

    #[test]
    fn initial_state_and_deps() {
        let ops = attention_ops();
        let s = TiledState::initial(ops, 2);
        let deps = s.dependencies();
        assert!(deps.contains(&(0, 1)), "matmul0 -> exp");
        assert!(deps.contains(&(1, 2)), "exp -> matmul2");
        assert!(!deps.contains(&(0, 2)));
    }

    #[test]
    fn merge_changes_notation() {
        let ops = attention_ops();
        let s = TiledState::initial(ops, 2);
        let before = s.notation();
        let s2 = s.apply(&Action::Merge { src: 1, dst: 2, level: 2 });
        let after = s2.notation();
        assert_ne!(before, after);
        // After merge(1,2,2), Op_2^2 hosts Op_1^1 (the Eq. 3 example).
        assert!(after.contains("Op_1^1, Op_2^1"), "notation:\n{after}");
    }

    #[test]
    fn legal_actions_nonempty_and_apply() {
        let ops = attention_ops();
        let s = TiledState::initial(ops, 2);
        let acts = s.legal_actions();
        assert!(acts.iter().any(|a| matches!(a, Action::Merge { .. })));
        assert!(acts.iter().any(|a| matches!(a, Action::Reorder { .. })));
        for a in acts.iter().take(8) {
            let _ = s.apply(a);
        }
    }

    #[test]
    fn merged_op_not_offered_again() {
        let ops = attention_ops();
        let s = TiledState::initial(ops, 2);
        let s2 = s.apply(&Action::Merge { src: 1, dst: 2, level: 1 });
        assert!(!s2
            .legal_actions()
            .iter()
            .any(|a| matches!(a, Action::Merge { src: 1, .. })));
    }
}
