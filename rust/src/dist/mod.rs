//! Auto Distribution (§3.1.3): the SBP abstraction, the distributed
//! e-graph of Fig. 5, and memory-constrained strategy extraction.
//!
//! Following OneFlow's SBP formalism (which the paper adopts), every
//! tensor on a device mesh carries a distribution signature:
//!
//! * `S(d)` — **Split**: the tensor is partitioned along axis `d`; each
//!   device holds a `1/p` shard.
//! * `B` — **Broadcast**: every device holds a full replica.
//! * `P` — **Partial**: every device holds a full-shape partial sum;
//!   the true value is the element-wise sum over devices (produced by
//!   inner-dimension-split matmuls).
//!
//! The distributed e-graph gives every logical node an *e-cluster*: one
//! e-class per legal SBP signature of its output, with explicit
//! [`Op::Boxing`] e-nodes bridging the signatures ("nodes with
//! consistent SBP attributes are equivalent", §3.1.3). Extraction picks
//! one signature per node minimizing `compute + reshard` time under the
//! alpha-beta communication model, subject to the per-device memory
//! capacity constraint of Observation 2 (weights resident in every
//! demanded form must fit).

use std::collections::HashMap;

use crate::cost::{collective_time_s, enode_cost, AlphaBeta, Collective, MachineSpec};
use crate::egraph::{ClassId, EGraph, ENode};
use crate::ir::{Graph, NodeId, Op, TensorType};

pub mod serve;
pub use serve::{MatShard, ShardSpec};

/// One axis of an SBP signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sbp {
    /// Split along tensor axis `d`.
    Split(usize),
    /// Full replica on every device.
    Broadcast,
    /// Element-wise partial sum across devices.
    Partial,
}

impl std::fmt::Display for Sbp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Sbp::Split(d) => write!(f, "S({d})"),
            Sbp::Broadcast => write!(f, "B"),
            Sbp::Partial => write!(f, "P"),
        }
    }
}

/// An n-dimensional SBP signature (one [`Sbp`] per mesh axis). All the
/// placements used here are 1-D ([`Placement::line`]), so signatures are
/// usually a single component.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct NdSbp(pub Vec<Sbp>);

impl NdSbp {
    /// 1-D mesh, split along tensor axis `axis`.
    pub fn split1(axis: usize) -> Self {
        NdSbp(vec![Sbp::Split(axis)])
    }

    /// Broadcast over a `mesh_rank`-dimensional mesh.
    pub fn broadcast(mesh_rank: usize) -> Self {
        NdSbp(vec![Sbp::Broadcast; mesh_rank.max(1)])
    }

    /// 1-D mesh, partial sum.
    pub fn partial1() -> Self {
        NdSbp(vec![Sbp::Partial])
    }

    /// True if every mesh axis is Broadcast.
    pub fn is_broadcast(&self) -> bool {
        self.0.iter().all(|s| matches!(s, Sbp::Broadcast))
    }

    /// True if any mesh axis splits the tensor.
    pub fn is_split(&self) -> bool {
        self.0.iter().any(|s| matches!(s, Sbp::Split(_)))
    }
}

impl std::fmt::Display for NdSbp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0.len() == 1 {
            return write!(f, "{}", self.0[0]);
        }
        write!(f, "(")?;
        for (i, s) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{s}")?;
        }
        write!(f, ")")
    }
}

/// A device mesh ("cores as distributed nodes", §4.2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// Mesh extents; `[p]` is a 1-D line of `p` devices.
    pub dims: Vec<usize>,
}

impl Placement {
    /// 1-D line placement of `devices` devices.
    pub fn line(devices: usize) -> Self {
        Placement { dims: vec![devices.max(1)] }
    }

    pub fn num_devices(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// Time (seconds) to convert a tensor of `bytes` logical bytes from
/// signature `from` to `to` on `p`, under the alpha-beta link `ab`.
/// This is the cost of the [`Op::Boxing`] node the conversion lowers to.
/// Per mesh axis:
///
/// * identity — free
/// * `P -> B` — ring all-reduce
/// * `S -> B` — all-gather
/// * `P -> S` — reduce-scatter
/// * `S(i) -> S(j)` — all-to-all
/// * `B -> S` / `B -> P` / `S -> P` — local slice / reinterpret, free
///
/// Multi-dimensional meshes compose axis-sequentially (the standard
/// boxing lowering): axis `i`'s collective runs within lines of
/// `p.dims[i]` devices over the tensor fraction a line holds, which is
/// `bytes` divided by the extent of every *other* axis that currently
/// splits the tensor (Partial axes hold full-shape terms, so they do
/// not shrink the footprint). Axes are converted in ascending order,
/// so axes `< i` are already in their target state when axis `i` runs.
/// Signatures shorter than the mesh rank are padded with Broadcast;
/// longer signatures are a caller bug (`debug_assert`).
pub fn reshard_cost_bytes(
    from: &NdSbp,
    to: &NdSbp,
    bytes: u64,
    p: &Placement,
    ab: &AlphaBeta,
) -> f64 {
    if from == to {
        return 0.0;
    }
    let rank = p.dims.len();
    debug_assert!(
        from.0.len() <= rank && to.0.len() <= rank,
        "SBP signature wider than the {rank}-D mesh: {from} -> {to}"
    );
    let axis = |s: &NdSbp, i: usize| s.0.get(i).copied().unwrap_or(Sbp::Broadcast);
    // Rolling per-axis state: target form for converted axes, source
    // form for the rest — determines the live footprint at each step.
    let mut cur: Vec<Sbp> = (0..rank).map(|i| axis(from, i)).collect();
    let mut total = 0.0f64;
    for i in 0..rank {
        let (f, t) = (cur[i], axis(to, i));
        if f == t {
            continue;
        }
        let coll = match (f, t) {
            (a, b) if a == b => Collective::Identity,
            (Sbp::Partial, Sbp::Broadcast) => Collective::AllReduce,
            (Sbp::Split(_), Sbp::Broadcast) => Collective::AllGather,
            (Sbp::Partial, Sbp::Split(_)) => Collective::ReduceScatter,
            (Sbp::Split(_), Sbp::Split(_)) => Collective::AllToAll,
            // A replica can be sliced locally, and a shard (or replica)
            // can be reinterpreted as one term of a partial sum with
            // zero fill. (Equal-variant pairs are caught by the first
            // arm at runtime; this arm keeps the match exhaustive
            // without guards.)
            (Sbp::Broadcast, _) | (_, Sbp::Partial) => Collective::Identity,
        };
        // Bytes a line of `dims[i]` devices collectively holds: the
        // other Split axes partition the tensor across lines.
        let mut line_bytes = bytes as f64;
        for (j, s) in cur.iter().enumerate() {
            if j != i && matches!(s, Sbp::Split(_)) {
                line_bytes /= p.dims[j].max(1) as f64;
            }
        }
        total += collective_time_s(coll, line_bytes.ceil() as u64, p.dims[i], ab);
        cur[i] = t;
    }
    total
}

/// One candidate strategy of a logical node: the output signature and
/// the signature required of each input.
#[derive(Debug, Clone)]
pub struct Strategy {
    pub out: NdSbp,
    pub ins: Vec<NdSbp>,
}

/// One extracted per-node decision.
#[derive(Debug, Clone)]
pub struct DistChoice {
    pub node: NodeId,
    pub sbp: NdSbp,
}

/// The extracted distribution plan.
#[derive(Debug, Clone)]
pub struct DistSolution {
    /// Estimated per-token step time: compute + communication, ns.
    pub total_ns: u64,
    /// Communication (boxing + output gather) share of `total_ns`.
    pub comm_ns: u64,
    /// Bytes of weight shards resident on each device (every demanded
    /// SBP form of every constant counted).
    pub weight_bytes_per_device: u64,
    pub choices: Vec<DistChoice>,
}

/// Extraction failure.
#[derive(Debug)]
pub enum DistError {
    /// Even the most aggressively sharded strategy does not fit.
    OutOfMemory { required_bytes: u64, capacity_bytes: u64 },
}

impl std::fmt::Display for DistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DistError::OutOfMemory { required_bytes, capacity_bytes } => write!(
                f,
                "distribution needs {required_bytes} bytes/device, capacity {capacity_bytes}"
            ),
        }
    }
}

impl std::error::Error for DistError {}

/// The distributed e-graph: the logical graph plus, per node, an
/// e-cluster mapping each legal SBP signature to its e-class (Fig. 5/6).
pub struct DistGraph {
    pub graph: Graph,
    pub placement: Placement,
    pub egraph: EGraph,
    /// Node index -> signature -> e-class of that distributed variant.
    pub clusters: Vec<HashMap<NdSbp, ClassId>>,
    /// Node index -> candidate strategies (extraction search space).
    pub strategies: Vec<Vec<Strategy>>,
}

/// Options restricting the strategy space of [`build_dist_egraph_opts`].
#[derive(Debug, Clone, Copy)]
pub struct DistOptions {
    /// Admit inner-split (`P`-output) matmul strategies. The offline
    /// compiler prices them; the *serving* lowering excludes them: a
    /// Partial output needs a cross-device sum, which changes the
    /// floating-point accumulation order and can never be bitwise
    /// identical to the single-device FCFS oracle. With Partial off,
    /// every extracted strategy keeps each output element's full-K
    /// accumulation on one worker, which the sharded engine executes
    /// bit-exactly ([`serve::ShardSpec`]).
    pub allow_partial: bool,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions { allow_partial: true }
    }
}

/// Legal SBP strategies of `id` on a 1-D mesh of `p` devices. Split
/// requires the split axis to be divisible by `p` (shards stay uniform
/// and boxing stays a pure collective). A Broadcast strategy is always
/// included, so every node has at least one candidate and an all-B
/// solution always exists.
fn candidates(g: &Graph, id: NodeId, p: usize, opts: DistOptions) -> Vec<Strategy> {
    let node = g.node(id);
    let dims = node.ty.shape.dims().to_vec();
    let rank = dims.len();
    let divisible = |d: usize| dims.get(d).map_or(false, |&n| n >= p && n % p == 0);
    let b = NdSbp::broadcast(1);
    let mut out: Vec<Strategy> = Vec::new();

    match &node.op {
        Op::Input(_) | Op::Const(_) => {
            out.push(Strategy { out: b.clone(), ins: vec![] });
            for d in 0..rank {
                if divisible(d) {
                    out.push(Strategy { out: NdSbp::split1(d), ins: vec![] });
                }
            }
        }
        Op::Scalar(_) => out.push(Strategy { out: b.clone(), ins: vec![] }),
        Op::MatMul => {
            let a = &g.node(node.inputs[0]).ty;
            let bt = &g.node(node.inputs[1]).ty;
            let (ar, br) = (a.shape.rank(), bt.shape.rank());
            if ar == 2 && br == 2 {
                let (m, k) = (a.shape.0[0], a.shape.0[1]);
                let n = bt.shape.0[1];
                // Column-parallel (Megatron S(1)): weight sharded, listed
                // first so ties prefer the memory-friendly form.
                if n >= p && n % p == 0 {
                    out.push(Strategy {
                        out: NdSbp::split1(1),
                        ins: vec![b.clone(), NdSbp::split1(1)],
                    });
                }
                // Row-parallel over the batch/sequence axis.
                if m >= p && m % p == 0 {
                    out.push(Strategy {
                        out: NdSbp::split1(0),
                        ins: vec![NdSbp::split1(0), b.clone()],
                    });
                }
                // Inner split: both operands sharded on k, partial output.
                if opts.allow_partial && k >= p && k % p == 0 {
                    out.push(Strategy {
                        out: NdSbp::partial1(),
                        ins: vec![NdSbp::split1(1), NdSbp::split1(0)],
                    });
                }
            } else if ar == br && ar >= 3 && a.shape.0[0] == bt.shape.0[0] {
                // Batched matmul: shard the leading batch axis (e.g. the
                // kv-head axis of grouped-query attention).
                let batch = a.shape.0[0];
                if batch >= p && batch % p == 0 {
                    out.push(Strategy {
                        out: NdSbp::split1(0),
                        ins: vec![NdSbp::split1(0), NdSbp::split1(0)],
                    });
                }
            }
            out.push(Strategy { out: b.clone(), ins: vec![b.clone(), b.clone()] });
        }
        Op::Unary(_) => {
            for d in 0..rank {
                if divisible(d) {
                    out.push(Strategy { out: NdSbp::split1(d), ins: vec![NdSbp::split1(d)] });
                }
            }
            out.push(Strategy { out: b.clone(), ins: vec![b.clone()] });
        }
        Op::Rope { .. } => {
            // RoPE rotates within the last axis; only earlier axes split.
            for d in 0..rank.saturating_sub(1) {
                if divisible(d) {
                    out.push(Strategy { out: NdSbp::split1(d), ins: vec![NdSbp::split1(d)] });
                }
            }
            out.push(Strategy { out: b.clone(), ins: vec![b.clone()] });
        }
        Op::Binary(_) => {
            for d in 0..rank {
                if !divisible(d) {
                    continue;
                }
                let mut ins = Vec::with_capacity(2);
                let mut ok = true;
                for &inp in &node.inputs {
                    let t = &g.node(inp).ty;
                    if t.shape == node.ty.shape {
                        ins.push(NdSbp::split1(d));
                    } else if t.shape.numel() == 1 {
                        // Scalar-like broadcast operand stays replicated.
                        ins.push(b.clone());
                    } else {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    out.push(Strategy { out: NdSbp::split1(d), ins });
                }
            }
            out.push(Strategy { out: b.clone(), ins: vec![b.clone(); node.inputs.len()] });
        }
        Op::RmsNorm { .. } => {
            // Normalizes over the last axis; the [h] weight replicates.
            for d in 0..rank.saturating_sub(1) {
                if divisible(d) {
                    out.push(Strategy {
                        out: NdSbp::split1(d),
                        ins: vec![NdSbp::split1(d), b.clone()],
                    });
                }
            }
            out.push(Strategy { out: b.clone(), ins: vec![b.clone(), b.clone()] });
        }
        Op::Softmax { axis } => {
            for d in 0..rank {
                if d != *axis && divisible(d) {
                    out.push(Strategy { out: NdSbp::split1(d), ins: vec![NdSbp::split1(d)] });
                }
            }
            out.push(Strategy { out: b.clone(), ins: vec![b.clone()] });
        }
        Op::Transpose { perm } => {
            for d in 0..rank {
                if divisible(d) {
                    out.push(Strategy {
                        out: NdSbp::split1(d),
                        ins: vec![NdSbp::split1(perm[d])],
                    });
                }
            }
            out.push(Strategy { out: b.clone(), ins: vec![b.clone()] });
        }
        // Shape-changing / gather / pack ops: replicate (conservative).
        _ => {
            out.push(Strategy { out: b.clone(), ins: vec![b.clone(); node.inputs.len()] });
        }
    }
    out
}

/// Build the distributed e-graph of Fig. 5: one e-cluster per live
/// logical node with an e-class per legal SBP signature, bridged by
/// [`Op::Boxing`] e-nodes. Full strategy space ([`DistOptions`]
/// defaults); the serving path uses [`build_dist_egraph_opts`] with
/// `allow_partial = false`.
pub fn build_dist_egraph(g: &Graph, placement: &Placement) -> DistGraph {
    build_dist_egraph_opts(g, placement, DistOptions::default())
}

/// [`build_dist_egraph`] with an explicitly restricted strategy space.
pub fn build_dist_egraph_opts(g: &Graph, placement: &Placement, opts: DistOptions) -> DistGraph {
    let p = placement.num_devices();
    let mut eg = EGraph::new();
    let mut clusters: Vec<HashMap<NdSbp, ClassId>> = vec![HashMap::new(); g.len()];
    let mut strategies: Vec<Vec<Strategy>> = vec![Vec::new(); g.len()];

    for id in g.live_nodes() {
        let node = g.node(id);
        let cands = candidates(g, id, p, opts);
        let mut cluster: HashMap<NdSbp, ClassId> = HashMap::new();
        let mut kept: Vec<Strategy> = Vec::new();

        if node.op.is_leaf() {
            // Host-resident base value; each device form is a Boxing of it
            // (the initial scatter/replication, free at setup time).
            let base = eg.add_leaf(node.op.clone(), node.ty.clone());
            for st in cands {
                let cls = eg.add(ENode {
                    op: Op::Boxing { to: Some(st.out.clone()) },
                    children: vec![base],
                });
                cluster.insert(st.out.clone(), cls);
                kept.push(st);
            }
        } else {
            for st in cands {
                let mut children = Vec::with_capacity(node.inputs.len());
                let mut ok = true;
                for (inp, need) in node.inputs.iter().zip(&st.ins) {
                    match clusters[inp.index()].get(need) {
                        Some(&c) => children.push(c),
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    continue;
                }
                let ty = node.ty.with_sbp(Some(st.out.clone()));
                let cls = eg.add_with_type(ENode { op: node.op.clone(), children }, ty);
                cluster.insert(st.out.clone(), cls);
                kept.push(st);
            }
        }

        // Boxing bridges between every pair of signatures in the cluster.
        let keys: Vec<NdSbp> = cluster.keys().cloned().collect();
        for from in &keys {
            for to in &keys {
                if from == to {
                    continue;
                }
                let src = cluster[from];
                let dst = cluster[to];
                let bx = eg.add(ENode {
                    op: Op::Boxing { to: Some(to.clone()) },
                    children: vec![src],
                });
                if eg.find(bx) != eg.find(dst) {
                    eg.union(bx, dst);
                }
            }
        }

        clusters[id.index()] = cluster;
        strategies[id.index()] = kept;
    }
    eg.rebuild();
    for cluster in &mut clusters {
        for cls in cluster.values_mut() {
            *cls = eg.find(*cls);
        }
    }
    DistGraph {
        graph: g.clone(),
        placement: placement.clone(),
        egraph: eg,
        clusters,
        strategies,
    }
}

/// Extract a distribution strategy.
///
/// `sat = true` selects per node the candidate minimizing
/// `compute + reshard-from-producers` (the objective the WPMaxSAT
/// formulation optimizes), re-running with Broadcast-resident weights
/// forbidden when the first pass exceeds `capacity_bytes`. `sat = false`
/// is the greedy ablation baseline: compute cost only, communication
/// falls where it may.
pub fn extract_dist(
    d: &DistGraph,
    machine: &MachineSpec,
    capacity_bytes: u64,
    sat: bool,
) -> Result<DistSolution, DistError> {
    let ab = AlphaBeta::from_machine(machine);
    let sol = select(d, machine, &ab, sat, false);
    if sol.weight_bytes_per_device <= capacity_bytes {
        return Ok(sol);
    }
    let tight = select(d, machine, &ab, sat, true);
    if tight.weight_bytes_per_device <= capacity_bytes {
        return Ok(tight);
    }
    Err(DistError::OutOfMemory {
        required_bytes: tight.weight_bytes_per_device,
        capacity_bytes,
    })
}

fn select(
    d: &DistGraph,
    machine: &MachineSpec,
    ab: &AlphaBeta,
    sat: bool,
    shard_weights: bool,
) -> DistSolution {
    let g = &d.graph;
    let p = d.placement.num_devices() as f64;
    let live = g.live_nodes();
    // Chosen candidate index per node (compute nodes only).
    let mut chosen: HashMap<usize, usize> = HashMap::new();
    let mut compute_s = 0.0f64;
    let mut comm_s = 0.0f64;

    for &id in &live {
        let node = g.node(id);
        if node.op.is_leaf() {
            continue;
        }
        let cands = &d.strategies[id.index()];
        if cands.is_empty() {
            continue;
        }
        // Under memory pressure, refuse strategies that keep a constant
        // input Broadcast when some candidate shards it.
        let viable: Vec<usize> = if shard_weights {
            let filtered: Vec<usize> = (0..cands.len())
                .filter(|&ci| {
                    cands[ci].ins.iter().enumerate().all(|(ii, s)| {
                        let inp = node.inputs[ii];
                        if !matches!(g.node(inp).op, Op::Const(_)) || s.is_split() {
                            return true;
                        }
                        !cands.iter().any(|o| o.ins[ii].is_split())
                    })
                })
                .collect();
            if filtered.is_empty() {
                (0..cands.len()).collect()
            } else {
                filtered
            }
        } else {
            (0..cands.len()).collect()
        };

        let in_tys: Vec<&TensorType> = node.inputs.iter().map(|&i| &g.node(i).ty).collect();
        let full_ns = enode_cost(&node.op, &in_tys, &node.ty, machine).ns as f64;

        let mut best: Option<(f64, f64, f64, usize)> = None; // (score, compute, comm, idx)
        for &ci in &viable {
            let st = &cands[ci];
            let shard = if st.out.is_broadcast() { 1.0 } else { 1.0 / p };
            let compute = full_ns * shard * 1e-9;
            let mut comm = 0.0f64;
            for (inp, need) in node.inputs.iter().zip(&st.ins) {
                let prod = g.node(*inp);
                if prod.op.is_leaf() {
                    continue; // initial shard/replication is setup-time
                }
                let have = &d.strategies[inp.index()][chosen[&inp.index()]].out;
                comm += reshard_cost_bytes(
                    have,
                    need,
                    prod.ty.size_bytes() as u64,
                    &d.placement,
                    ab,
                );
            }
            let score = if sat { compute + comm } else { compute };
            let better = match &best {
                None => true,
                Some((s, ..)) => score < *s,
            };
            if better {
                best = Some((score, compute, comm, ci));
            }
        }
        let (_, compute, comm, ci) = best.expect("every node keeps a Broadcast candidate");
        chosen.insert(id.index(), ci);
        compute_s += compute;
        comm_s += comm;
    }

    // Unshard every graph output back to the host (Boxing to None).
    for &out in &g.outputs {
        if let Some(&ci) = chosen.get(&out.index()) {
            let sbp = &d.strategies[out.index()][ci].out;
            let bytes = g.node(out).ty.size_bytes() as u64;
            let coll = match sbp.0.first() {
                Some(Sbp::Partial) => Some(Collective::AllReduce),
                Some(Sbp::Split(_)) => Some(Collective::Gather),
                _ => None,
            };
            if let Some(c) = coll {
                comm_s += collective_time_s(c, bytes, d.placement.num_devices(), ab);
            }
        }
    }

    // Weight residency: every SBP form a constant is demanded in must be
    // resident on each device (Observation 2's hard constraint).
    let mut weight_bytes = 0u64;
    let users = g.users();
    for &id in &live {
        let node = g.node(id);
        if !matches!(node.op, Op::Const(_)) {
            continue;
        }
        let mut demanded: Vec<NdSbp> = Vec::new();
        for &u in &users[id.index()] {
            let Some(&ci) = chosen.get(&u.index()) else { continue };
            let st = &d.strategies[u.index()][ci];
            for (inp, need) in g.node(u).inputs.iter().zip(&st.ins) {
                if *inp == id && !demanded.contains(need) {
                    demanded.push(need.clone());
                }
            }
        }
        if demanded.is_empty() {
            demanded.push(NdSbp::broadcast(1));
        }
        for sbp in demanded {
            weight_bytes += node
                .ty
                .with_sbp(Some(sbp))
                .local_size_bytes(&d.placement.dims) as u64;
        }
    }

    // Report choices for every live node; leaves report their primary
    // demanded form (or B).
    let mut choices = Vec::with_capacity(live.len());
    for &id in &live {
        let sbp = if let Some(&ci) = chosen.get(&id.index()) {
            d.strategies[id.index()][ci].out.clone()
        } else {
            let mut primary = NdSbp::broadcast(1);
            'outer: for &u in &users[id.index()] {
                if let Some(&ci) = chosen.get(&u.index()) {
                    let st = &d.strategies[u.index()][ci];
                    for (inp, need) in g.node(u).inputs.iter().zip(&st.ins) {
                        if *inp == id {
                            primary = need.clone();
                            break 'outer;
                        }
                    }
                }
            }
            primary
        };
        choices.push(DistChoice { node: id, sbp });
    }

    DistSolution {
        total_ns: ((compute_s + comm_s) * 1e9).ceil() as u64 + 1,
        comm_ns: (comm_s * 1e9).ceil() as u64,
        weight_bytes_per_device: weight_bytes,
        choices,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, UnaryKind};

    fn mlp(batch: usize, hidden: usize, inter: usize) -> Graph {
        let mut g = Graph::new();
        let x = g.input("x", &[batch, hidden], DType::F32);
        let w1 = g.constant("w1", &[hidden, inter], DType::F32);
        let w2 = g.constant("w2", &[inter, hidden], DType::F32);
        let h = g.matmul(x, w1);
        let a = g.unary(UnaryKind::Silu, h);
        let o = g.matmul(a, w2);
        g.mark_output(o);
        g
    }

    #[test]
    fn sbp_display() {
        assert_eq!(NdSbp::split1(1).to_string(), "S(1)");
        assert_eq!(NdSbp::broadcast(1).to_string(), "B");
        assert_eq!(NdSbp::partial1().to_string(), "P");
        assert_eq!(NdSbp(vec![Sbp::Split(0), Sbp::Broadcast]).to_string(), "(S(0),B)");
    }

    #[test]
    fn reshard_identity_free_and_ordering() {
        let ab = AlphaBeta { alpha_s: 1e-6, beta_bytes_per_s: 20e9 };
        let p = Placement::line(4);
        let n = 1u64 << 20;
        let s0 = NdSbp::split1(0);
        assert_eq!(reshard_cost_bytes(&s0, &s0, n, &p, &ab), 0.0);
        let p2b = reshard_cost_bytes(&NdSbp::partial1(), &NdSbp::broadcast(1), n, &p, &ab);
        let s2b = reshard_cost_bytes(&s0, &NdSbp::broadcast(1), n, &p, &ab);
        assert!(p2b >= s2b && s2b > 0.0);
        // Local slice is free.
        assert_eq!(reshard_cost_bytes(&NdSbp::broadcast(1), &s0, n, &p, &ab), 0.0);
    }

    #[test]
    fn reshard_composes_per_mesh_axis() {
        // Satellite fix: a 2-D signature used to be silently priced as
        // its first axis only. Now each mesh axis contributes its own
        // collective over the bytes its device lines actually hold.
        let ab = AlphaBeta { alpha_s: 1e-6, beta_bytes_per_s: 20e9 };
        let mesh = Placement { dims: vec![2, 4] };
        let n = 1u64 << 20;
        let b2 = NdSbp(vec![Sbp::Broadcast, Sbp::Broadcast]);
        let p2 = NdSbp(vec![Sbp::Partial, Sbp::Partial]);
        // Two all-reduces, one per axis — strictly more than pricing
        // only axis 0 (the old behaviour).
        let both = reshard_cost_bytes(&p2, &b2, n, &mesh, &ab);
        let axis0_only = reshard_cost_bytes(
            &NdSbp(vec![Sbp::Partial, Sbp::Broadcast]),
            &b2,
            n,
            &mesh,
            &ab,
        );
        assert!(both > axis0_only && axis0_only > 0.0);
        // An axis whose signature does not change is free; the changing
        // axis-1 all-gather runs over halved bytes (axis 0 still splits
        // the tensor across its lines).
        let s0s1 = NdSbp(vec![Sbp::Split(0), Sbp::Split(1)]);
        let s0b = NdSbp(vec![Sbp::Split(0), Sbp::Broadcast]);
        let half = reshard_cost_bytes(&s0s1, &s0b, n, &mesh, &ab);
        let full = reshard_cost_bytes(
            &NdSbp(vec![Sbp::Broadcast, Sbp::Split(1)]),
            &b2,
            n,
            &mesh,
            &ab,
        );
        assert!(half > 0.0 && half < full, "split axis 0 must halve axis 1's bytes");
        // Short signatures pad with B: on a 1-D mesh nothing changed.
        assert_eq!(
            reshard_cost_bytes(&NdSbp::split1(0), &NdSbp::split1(0), n, &Placement::line(4), &ab),
            0.0
        );
    }

    #[test]
    fn partial_free_egraph_has_no_partial_strategies() {
        let g = mlp(8, 64, 128);
        let d = build_dist_egraph_opts(
            &g,
            &Placement::line(2),
            DistOptions { allow_partial: false },
        );
        for sts in &d.strategies {
            for st in sts {
                assert!(
                    !st.out.0.contains(&Sbp::Partial),
                    "serve-side strategy space must stay bitwise-executable"
                );
            }
        }
        // Extraction still succeeds (B always present, splits still on).
        let m = MachineSpec::ryzen_5900x();
        let sol = extract_dist(&d, &m, u64::MAX / 4, true).unwrap();
        assert_eq!(sol.choices.len(), g.live_nodes().len());
    }

    #[test]
    fn dist_egraph_has_clusters_and_boxing() {
        let g = mlp(8, 64, 128);
        let d = build_dist_egraph(&g, &Placement::line(2));
        let mm = g
            .live_nodes()
            .into_iter()
            .find(|&id| matches!(g.node(id).op, Op::MatMul))
            .unwrap();
        let cluster = &d.clusters[mm.index()];
        assert!(cluster.len() >= 3, "matmul cluster: {:?}", cluster.keys().collect::<Vec<_>>());
        assert!(cluster.contains_key(&NdSbp::broadcast(1)));
        assert!(cluster.contains_key(&NdSbp::split1(1)));
        assert!(d.egraph.n_nodes > g.live_nodes().len());
    }

    #[test]
    fn extraction_splits_and_accounts_memory() {
        let m = MachineSpec::ryzen_5900x();
        let g = mlp(8, 512, 2048);
        let d = build_dist_egraph(&g, &Placement::line(4));
        let sol = extract_dist(&d, &m, u64::MAX / 4, true).unwrap();
        let full: u64 = 2 * 512 * 2048 * 4;
        assert!(sol.weight_bytes_per_device <= full);
        assert!(sol.total_ns > 0);
        assert!(sol.comm_ns > 0, "split strategies must pay boxing/gather");
        assert_eq!(sol.choices.len(), g.live_nodes().len());
    }

    #[test]
    fn capacity_forces_sharding_then_oom() {
        let m = MachineSpec::ryzen_5900x();
        let g = mlp(8, 1024, 3072);
        let d = build_dist_egraph(&g, &Placement::line(2));
        // Full weights are 24 MiB; 16 MiB/device forces splits.
        let capped = extract_dist(&d, &m, 16 << 20, true).unwrap();
        assert!(capped.weight_bytes_per_device <= 16 << 20);
        match extract_dist(&d, &m, 1 << 20, true) {
            Err(DistError::OutOfMemory { required_bytes, capacity_bytes }) => {
                assert!(required_bytes > capacity_bytes);
            }
            other => panic!("expected OOM, got {other:?}"),
        }
    }

    #[test]
    fn greedy_and_sat_both_extract() {
        let m = MachineSpec::ryzen_5900x();
        let g = mlp(8, 512, 2048);
        let d = build_dist_egraph(&g, &Placement::line(4));
        let sat = extract_dist(&d, &m, u64::MAX / 4, true).unwrap();
        let greedy = extract_dist(&d, &m, u64::MAX / 4, false).unwrap();
        // The comm-aware objective never loses to compute-only greedy.
        assert!(sat.total_ns <= greedy.total_ns);
    }
}
