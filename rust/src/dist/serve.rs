//! Serve-side Auto Distribution: pick an *executable* per-weight-matrix
//! SBP layout for the sharded continuous-batching engine.
//!
//! The serving engine shards each transformer layer's projection GEMMs
//! across cooperating worker groups ("cores as distributed nodes",
//! §4.2 — one group per NUMA node on real machines). Unlike the
//! offline compiler, the serving path owes the FCFS oracle **bitwise**
//! identical tokens at every `(threads × shards)`, which restricts the
//! strategy space to signatures whose execution keeps every output
//! element's full-K accumulation on a single worker:
//!
//! * `B` — the matrix is replicated; token rows split across all
//!   workers (the seed engine's layout).
//! * `S(1)` — Megatron column-parallel: each shard group owns a
//!   contiguous range of NR-column panels; rows split across the
//!   group's lanes. Every `(row, column)` output element is still
//!   computed whole, in the same k-ascending order, by exactly one
//!   worker — the "combine" is a disjoint fixed-position writeback
//!   ([`crate::parallel`]'s SharedVec contract), not a sum.
//!
//! Inner-split (`P`) strategies need a cross-shard reduction that
//! reorders floating-point accumulation, so [`ShardSpec::derive`]
//! builds the distributed e-graph with
//! [`DistOptions::allow_partial`]` = false` and lets
//! [`extract_dist`] choose split-vs-broadcast per weight matrix under
//! the machine's alpha-beta reshard costs — the layout is cost-driven,
//! not hardcoded, and the chosen signature is recorded verbatim in the
//! serve plan hash and `ServeReport`.

use std::collections::HashMap;

use super::{build_dist_egraph_opts, extract_dist, DistOptions, Placement, Sbp};
use crate::cost::MachineSpec;
use crate::ir::Op;
use crate::model::{decode_graph, Qwen3Config};

/// Executable layout of one weight matrix under the serving engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatShard {
    /// Full replica in every shard group (`B`): token rows split across
    /// all workers, all columns on each.
    Replicated,
    /// Column-parallel (`S(1)`): each group owns a contiguous range of
    /// NR-column panels; rows split across the group's lanes.
    ColumnShard,
}

impl MatShard {
    /// The SBP signature this layout executes.
    pub fn sbp_str(self) -> &'static str {
        match self {
            MatShard::Replicated => "B",
            MatShard::ColumnShard => "S(1)",
        }
    }
}

/// The dist-extracted per-matrix layout of a sharded serve run.
/// Strategies replicate across identical layers, so one decision per
/// matrix name covers every layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Cooperating worker groups (1 = the seed unsharded engine).
    pub shards: usize,
    pub wq: MatShard,
    pub wk: MatShard,
    pub wv: MatShard,
    pub wo: MatShard,
    pub w_gate: MatShard,
    pub w_up: MatShard,
    pub w_down: MatShard,
    pub lm_head: MatShard,
}

impl ShardSpec {
    /// The unsharded layout: one group, every matrix replicated.
    /// `BatchEngine` under this spec is the seed engine, bit for bit.
    pub fn single() -> Self {
        ShardSpec {
            shards: 1,
            wq: MatShard::Replicated,
            wk: MatShard::Replicated,
            wv: MatShard::Replicated,
            wo: MatShard::Replicated,
            w_gate: MatShard::Replicated,
            w_up: MatShard::Replicated,
            w_down: MatShard::Replicated,
            lm_head: MatShard::Replicated,
        }
    }

    pub fn is_sharded(&self) -> bool {
        self.shards > 1
    }

    /// `(name, layout)` in engine phase order.
    pub fn matrices(&self) -> [(&'static str, MatShard); 8] {
        [
            ("wq", self.wq),
            ("wk", self.wk),
            ("wv", self.wv),
            ("wo", self.wo),
            ("w_gate", self.w_gate),
            ("w_up", self.w_up),
            ("w_down", self.w_down),
            ("lm_head", self.lm_head),
        ]
    }

    /// Canonical per-matrix SBP signature string, e.g.
    /// `"wq=S(1),wk=S(1),...,lm_head=B"`. Folded into the serve plan
    /// hash so two runs under one hash served the same layout; `"-"`
    /// for the unsharded spec.
    pub fn sig(&self) -> String {
        if !self.is_sharded() {
            return "-".into();
        }
        self.matrices()
            .iter()
            .map(|(n, m)| format!("{n}={}", m.sbp_str()))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Let the dist cost model pick the layout: build the partial-free
    /// distributed e-graph of one decode step (one layer — strategies
    /// replicate across identical layers) on a 1-D line of `shards`
    /// device groups, extract under the machine's memory capacity and
    /// alpha-beta link, and read back the SBP form demanded of each
    /// weight constant. Deterministic for a given
    /// `(model, machine, shards)` triple.
    pub fn derive(model: &Qwen3Config, machine: &MachineSpec, shards: usize) -> Self {
        let shards = shards.max(1);
        if shards == 1 {
            return ShardSpec::single();
        }
        let g = decode_graph(model, 7, Some(1));
        let placement = Placement::line(shards);
        let d = build_dist_egraph_opts(&g, &placement, DistOptions { allow_partial: false });
        let sol = extract_dist(&d, machine, machine.mem_capacity_bytes as u64, true)
            .or_else(|_| extract_dist(&d, machine, u64::MAX / 4, true))
            .expect("an all-Broadcast solution always exists");
        let mut by_name: HashMap<String, MatShard> = HashMap::new();
        for c in &sol.choices {
            if let Op::Const(name) = &d.graph.node(c.node).op {
                let short = name.strip_prefix("l0.").unwrap_or(name);
                let layout = match c.sbp.0.first() {
                    Some(Sbp::Split(1)) => MatShard::ColumnShard,
                    _ => MatShard::Replicated,
                };
                by_name.insert(short.to_string(), layout);
            }
        }
        let get = |k: &str| by_name.get(k).copied().unwrap_or(MatShard::Replicated);
        ShardSpec {
            shards,
            wq: get("wq"),
            wk: get("wk"),
            wv: get("wv"),
            wo: get("wo"),
            w_gate: get("w_gate"),
            w_up: get("w_up"),
            w_down: get("w_down"),
            lm_head: get("lm_head"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_spec_is_fully_replicated() {
        let s = ShardSpec::single();
        assert!(!s.is_sharded());
        assert_eq!(s.sig(), "-");
        assert!(s.matrices().iter().all(|(_, m)| *m == MatShard::Replicated));
    }

    #[test]
    fn derive_is_cost_driven_and_deterministic() {
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::test_numa();
        for shards in [2usize, 4] {
            let a = ShardSpec::derive(&model, &machine, shards);
            let b = ShardSpec::derive(&model, &machine, shards);
            assert_eq!(a, b, "extraction must be deterministic");
            assert_eq!(a.shards, shards);
            // The extractor must actually shard something: every
            // projection axis of the tiny model divides 2 and 4, and a
            // 1/p compute share beats a full replica under the
            // alpha-beta model, so an all-Replicated answer would mean
            // the cost model never ran.
            assert!(
                a.matrices().iter().any(|(_, m)| *m == MatShard::ColumnShard),
                "dist chose nothing to shard: {}",
                a.sig()
            );
            let sig = a.sig();
            assert!(sig.contains("wq="), "{sig}");
            assert!(sig.contains("lm_head="), "{sig}");
        }
    }

    #[test]
    fn derive_clamps_degenerate_shard_counts() {
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::test_numa();
        assert_eq!(ShardSpec::derive(&model, &machine, 0), ShardSpec::single());
        assert_eq!(ShardSpec::derive(&model, &machine, 1), ShardSpec::single());
    }

    #[test]
    fn indivisible_axes_fall_back_to_replicas() {
        // A shard count that divides no projection axis leaves only
        // Broadcast strategies for the weight matmuls.
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::test_numa();
        let s = ShardSpec::derive(&model, &machine, 7);
        assert_eq!(s.shards, 7);
        assert!(s.matrices().iter().all(|(_, m)| *m == MatShard::Replicated), "{}", s.sig());
    }
}
