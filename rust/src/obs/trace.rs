//! The cold-path half of `obs`: merging per-worker rings into one
//! timeline, exporting Chrome-trace-event JSON (Perfetto /
//! `chrome://tracing`), and deriving the [`TraceSummary`] that lands
//! in `ServeReport` — per-phase time breakdown, per-phase barrier-wait
//! fraction (the load-imbalance signal), and per-worker busy/wait
//! utilization.

use super::ring::{Code, Event, CODE_COUNT};

/// One worker's (or the scheduler track's) recorded timeline.
pub struct WorkerTrace {
    /// Chrome `tid` — engine workers are `0..t` (0 = controller), the
    /// scheduler track comes after.
    pub tid: u32,
    pub name: String,
    /// Events in record order (oldest surviving first).
    pub events: Vec<Event>,
    /// Events this ring lost to wrap-around.
    pub dropped: u64,
}

/// All timelines of one serve run, merged post-run (the hot path never
/// touches this).
pub struct TraceLog {
    pub workers: Vec<WorkerTrace>,
}

impl TraceLog {
    /// Every event across all workers as `(tid, event)`, sorted by
    /// `(t0, tid, seq)` — the stable global merge order.
    pub fn merged(&self) -> Vec<(u32, Event)> {
        let mut all: Vec<(u32, Event)> = self
            .workers
            .iter()
            .flat_map(|w| w.events.iter().map(move |&e| (w.tid, e)))
            .collect();
        all.sort_by_key(|&(tid, e)| (e.t0, tid, e.seq));
        all
    }

    /// Total surviving events.
    pub fn events(&self) -> u64 {
        self.workers.iter().map(|w| w.events.len() as u64).sum()
    }

    /// Total events lost to ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.workers.iter().map(|w| w.dropped).sum()
    }

    /// Render the run as Chrome trace event format JSON — the object
    /// form (`{"traceEvents": [...]}`), loadable in Perfetto. Spans
    /// become `B`/`E` pairs, lifecycle edges become thread-scoped `i`
    /// instants, and each track gets a `thread_name` metadata record.
    /// Within a track, points are ordered so nesting is always valid:
    /// at equal timestamps an `E` precedes a `B` (close the finished
    /// span before opening a sibling), ties between `B`s open the
    /// longer span first, and ties between `E`s close the
    /// later-started (inner) span first.
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::with_capacity(256 + 96 * self.events() as usize);
        out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        let mut first = true;
        let mut emit = |s: String, out: &mut String| {
            if !std::mem::take(&mut first) {
                out.push(',');
            }
            out.push_str(&s);
        };
        for w in &self.workers {
            emit(
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{},\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    w.tid,
                    json_escape(&w.name)
                ),
                &mut out,
            );
        }
        // Per-track point list: (ts, open-order, tie-break, seq, kind).
        // kind 0 = E, 1 = B, 2 = instant.
        for w in &self.workers {
            let mut pts: Vec<(u64, u8, u64, u32, u8, &Event)> = Vec::new();
            for ev in &w.events {
                if ev.code.is_instant() {
                    pts.push((ev.t0, 1, 0, ev.seq, 2, ev));
                } else {
                    pts.push((ev.t0, 1, u64::MAX - ev.t1, ev.seq, 1, ev));
                    pts.push((ev.t1, 0, u64::MAX - ev.t0, ev.seq, 0, ev));
                }
            }
            pts.sort_by_key(|&(ts, ord, tie, seq, _, _)| (ts, ord, tie, seq));
            for (ts, _, _, _, kind, ev) in pts {
                let rec = match kind {
                    0 => {
                        format!("{{\"ph\":\"E\",\"pid\":0,\"tid\":{},\"ts\":{}}}", w.tid, ts_us(ts))
                    }
                    1 => {
                        let args = if ev.code == Code::Barrier {
                            let closes = Code::from_u16(ev.arg as u16)
                                .map(Code::name)
                                .unwrap_or("unknown");
                            format!(",\"args\":{{\"closes\":\"{closes}\"}}")
                        } else {
                            format!(",\"args\":{{\"arg\":{}}}", ev.arg)
                        };
                        format!(
                            "{{\"name\":\"{}\",\"ph\":\"B\",\"pid\":0,\"tid\":{},\"ts\":{}{}}}",
                            ev.code.name(),
                            w.tid,
                            ts_us(ts),
                            args
                        )
                    }
                    _ => format!(
                        "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":0,\"tid\":{},\
                         \"ts\":{},\"args\":{{\"req\":{}}}}}",
                        ev.code.name(),
                        w.tid,
                        ts_us(ts),
                        ev.arg
                    ),
                };
                emit(rec, &mut out);
            }
        }
        out.push_str("]}");
        out
    }
}

/// Chrome trace `ts` is microseconds; keep nanosecond precision as a
/// 3-decimal fraction.
fn ts_us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Escape a string for embedding in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A finite JSON number for an `f64` (non-finite values collapse to
/// 0.0 — JSON has no NaN/Infinity). Always carries a decimal point so
/// readers keep the float type.
pub fn json_f64(x: f64) -> String {
    if !x.is_finite() {
        return "0.0".to_string();
    }
    let s = format!("{x}");
    if s.contains('.') || s.contains('e') || s.contains('E') {
        s
    } else {
        format!("{s}.0")
    }
}

/// Aggregate time in one phase across all workers.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub name: &'static str,
    /// Summed span seconds across workers.
    pub total_s: f64,
    pub count: u64,
    /// Summed barrier-wait seconds attributed to this phase (barrier
    /// events carry the closed phase in `arg`).
    pub barrier_wait_s: f64,
}

impl PhaseStat {
    /// Barrier wait as a fraction of the phase's wall contribution —
    /// high values mean the phase's work is imbalanced across workers.
    pub fn wait_frac(&self) -> f64 {
        let denom = self.total_s + self.barrier_wait_s;
        if denom <= 0.0 {
            0.0
        } else {
            self.barrier_wait_s / denom
        }
    }
}

/// One worker's busy/wait utilization split.
#[derive(Debug, Clone)]
pub struct WorkerStat {
    pub tid: u32,
    pub name: String,
    /// Seconds in work spans (phases, tier ops, scheduler spans).
    pub busy_s: f64,
    /// Seconds in wait spans (phase barriers + inter-step park).
    pub wait_s: f64,
}

impl WorkerStat {
    pub fn wait_frac(&self) -> f64 {
        let denom = self.busy_s + self.wait_s;
        if denom <= 0.0 {
            0.0
        } else {
            self.wait_s / denom
        }
    }
}

/// What `ServeReport` keeps from a traced run: the per-phase
/// breakdown, the per-worker utilization split, and the ring
/// bookkeeping. Derived once post-run from the merged [`TraceLog`].
#[derive(Debug, Clone)]
pub struct TraceSummary {
    /// Phases with any recorded time, heaviest first.
    pub phases: Vec<PhaseStat>,
    /// Engine workers (and the scheduler track) in tid order.
    pub workers: Vec<WorkerStat>,
    /// Surviving events across all rings.
    pub events: u64,
    /// Events lost to ring wrap-around (0 unless the run outgrew
    /// `PALLAS_TRACE_EVENTS`).
    pub dropped: u64,
}

impl TraceSummary {
    pub fn from_log(log: &TraceLog) -> Self {
        let mut total = [0.0f64; CODE_COUNT];
        let mut count = [0u64; CODE_COUNT];
        let mut bwait = [0.0f64; CODE_COUNT];
        let mut workers = Vec::with_capacity(log.workers.len());
        for w in &log.workers {
            let (mut busy, mut wait) = (0.0f64, 0.0f64);
            for ev in &w.events {
                if ev.code.is_instant() {
                    continue;
                }
                let dur = ev.t1.saturating_sub(ev.t0) as f64 * 1e-9;
                if ev.code.is_wait() {
                    wait += dur;
                    if ev.code == Code::Barrier {
                        if let Some(phase) = Code::from_u16(ev.arg as u16) {
                            bwait[phase as usize] += dur;
                        }
                    }
                } else {
                    busy += dur;
                    total[ev.code as usize] += dur;
                    count[ev.code as usize] += 1;
                }
            }
            workers.push(WorkerStat {
                tid: w.tid,
                name: w.name.clone(),
                busy_s: busy,
                wait_s: wait,
            });
        }
        let mut phases: Vec<PhaseStat> = (0..CODE_COUNT)
            .filter(|&c| count[c] > 0)
            .map(|c| PhaseStat {
                name: Code::from_u16(c as u16).expect("dense discriminants").name(),
                total_s: total[c],
                count: count[c],
                barrier_wait_s: bwait[c],
            })
            .collect();
        phases.sort_by(|a, b| b.total_s.total_cmp(&a.total_s));
        TraceSummary { phases, workers, events: log.events(), dropped: log.dropped() }
    }

    /// Aggregate barrier-wait fraction across the engine workers —
    /// the one-number load-imbalance signal.
    pub fn wait_frac(&self) -> f64 {
        let busy: f64 = self.workers.iter().map(|w| w.busy_s).sum();
        let wait: f64 = self.workers.iter().map(|w| w.wait_s).sum();
        if busy + wait <= 0.0 {
            0.0
        } else {
            wait / (busy + wait)
        }
    }

    /// Compact single-line form for `ServeReport::render`: event
    /// counts, the heaviest phases with their per-phase barrier-wait
    /// fraction, and the aggregate wait fraction.
    pub fn render(&self) -> String {
        let mut s = format!("ev={}", self.events);
        if self.dropped > 0 {
            s.push_str(&format!(" drop={}", self.dropped));
        }
        for p in self.phases.iter().take(4) {
            s.push_str(&format!(" {}={:.2}ms", p.name, p.total_s * 1e3));
            if p.barrier_wait_s > 0.0 {
                s.push_str(&format!("/w{:.0}%", p.wait_frac() * 100.0));
            }
        }
        s.push_str(&format!(" wait={:.0}%", self.wait_frac() * 100.0));
        s
    }

    /// The summary as a JSON object (stable key order, dependency-free
    /// — the `trace` field of `ServeReport::to_json`).
    pub fn to_json(&self) -> String {
        let phases: Vec<String> = self
            .phases
            .iter()
            .map(|p| {
                format!(
                    "{{\"name\":\"{}\",\"total_s\":{},\"count\":{},\"barrier_wait_s\":{},\
                     \"wait_frac\":{}}}",
                    json_escape(p.name),
                    json_f64(p.total_s),
                    p.count,
                    json_f64(p.barrier_wait_s),
                    json_f64(p.wait_frac())
                )
            })
            .collect();
        let workers: Vec<String> = self
            .workers
            .iter()
            .map(|w| {
                format!(
                    "{{\"tid\":{},\"name\":\"{}\",\"busy_s\":{},\"wait_s\":{}}}",
                    w.tid,
                    json_escape(&w.name),
                    json_f64(w.busy_s),
                    json_f64(w.wait_s)
                )
            })
            .collect();
        format!(
            "{{\"events\":{},\"dropped\":{},\"wait_frac\":{},\"phases\":[{}],\"workers\":[{}]}}",
            self.events,
            self.dropped,
            json_f64(self.wait_frac()),
            phases.join(","),
            workers.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::ring::Ring;
    use super::*;
    use std::time::Instant;

    fn log_of(events: Vec<Vec<Event>>) -> TraceLog {
        TraceLog {
            workers: events
                .into_iter()
                .enumerate()
                .map(|(i, evs)| WorkerTrace {
                    tid: i as u32,
                    name: format!("worker {i}"),
                    events: evs,
                    dropped: 0,
                })
                .collect(),
        }
    }

    fn ev(code: Code, t0: u64, t1: u64, arg: u32, seq: u32) -> Event {
        Event { t0, t1, code, arg, seq }
    }

    #[test]
    fn merge_orders_by_time_then_tid_then_seq() {
        let log = log_of(vec![
            vec![ev(Code::Attn, 50, 60, 0, 0), ev(Code::Attn, 100, 110, 0, 1)],
            vec![ev(Code::Attn, 50, 55, 0, 0), ev(Code::Attn, 10, 20, 0, 1)],
        ]);
        let merged = log.merged();
        let order: Vec<(u64, u32, u32)> =
            merged.iter().map(|&(tid, e)| (e.t0, tid, e.seq)).collect();
        assert_eq!(order, vec![(10, 1, 1), (50, 0, 0), (50, 1, 0), (100, 0, 1)]);
    }

    #[test]
    fn chrome_json_balances_and_orders_be_pairs() {
        // Outer span [0, 100] encloses inner [0, 40]; a sibling opens
        // at 40 exactly when the inner closes. Valid nesting requires
        // B(outer) before B(inner) at ts 0, and E(inner) before
        // B(sibling) at ts 40.
        let log = log_of(vec![vec![
            ev(Code::Iterate, 0, 100, 0, 0),
            ev(Code::QkvGemm, 0, 40, 0, 1),
            ev(Code::Attn, 40, 100, 0, 2),
        ]]);
        let js = log.to_chrome_json();
        assert!(js.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(js.ends_with("]}"));
        let b_iter = js.find("\"name\":\"iterate\",\"ph\":\"B\"").unwrap();
        let b_qkv = js.find("\"name\":\"qkv_gemm\",\"ph\":\"B\"").unwrap();
        let b_attn = js.find("\"name\":\"attn\",\"ph\":\"B\"").unwrap();
        assert!(b_iter < b_qkv, "outer span must open before the inner one");
        // E at ts 40 (inner close) must precede B at ts 40 (sibling).
        let e40 = js.find("\"ph\":\"E\",\"pid\":0,\"tid\":0,\"ts\":0.040").unwrap();
        assert!(e40 < b_attn, "close must precede the sibling open at the same ts");
        assert_eq!(js.matches("\"ph\":\"B\"").count(), js.matches("\"ph\":\"E\"").count());
    }

    #[test]
    fn chrome_json_instants_and_barrier_args() {
        let log = log_of(vec![vec![
            ev(Code::Barrier, 10, 30, Code::QkvGemm as u32, 0),
            ev(Code::Admit, 35, 35, 7, 1),
        ]]);
        let js = log.to_chrome_json();
        assert!(js.contains("\"args\":{\"closes\":\"qkv_gemm\"}"));
        assert!(js.contains("\"name\":\"admit\",\"ph\":\"i\",\"s\":\"t\""));
        assert!(js.contains("\"args\":{\"req\":7}"));
        assert!(js.contains("\"name\":\"thread_name\""));
    }

    #[test]
    fn json_escape_covers_controls_and_quotes() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\n\t\r"), "x\\n\\t\\r");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn json_f64_is_finite_and_typed() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(2.0), "2.0");
        assert_eq!(json_f64(0.0), "0.0");
        assert_eq!(json_f64(f64::NAN), "0.0");
        assert_eq!(json_f64(f64::INFINITY), "0.0");
    }

    #[test]
    fn summary_splits_busy_wait_and_attributes_barrier_to_phase() {
        let log = log_of(vec![vec![
            ev(Code::QkvGemm, 0, 30_000_000, 0, 0),
            ev(Code::Barrier, 30_000_000, 40_000_000, Code::QkvGemm as u32, 1),
            ev(Code::Attn, 40_000_000, 50_000_000, 0, 2),
            ev(Code::Finish, 50_000_000, 50_000_000, 1, 3),
        ]]);
        let sum = TraceSummary::from_log(&log);
        assert_eq!(sum.events, 4);
        assert_eq!(sum.dropped, 0);
        let w = &sum.workers[0];
        assert!((w.busy_s - 0.040).abs() < 1e-9, "busy {}", w.busy_s);
        assert!((w.wait_s - 0.010).abs() < 1e-9, "wait {}", w.wait_s);
        let qkv = sum.phases.iter().find(|p| p.name == "qkv_gemm").unwrap();
        assert!((qkv.total_s - 0.030).abs() < 1e-9);
        assert!((qkv.barrier_wait_s - 0.010).abs() < 1e-9);
        assert!((qkv.wait_frac() - 0.25).abs() < 1e-9);
        // The heaviest phase leads.
        assert_eq!(sum.phases[0].name, "qkv_gemm");
        let r = sum.render();
        assert!(r.contains("ev=4"));
        assert!(r.contains("qkv_gemm"));
        let js = sum.to_json();
        assert!(js.starts_with("{\"events\":4,\"dropped\":0,"));
        assert!(js.contains("\"phases\":["));
        assert!(js.contains("\"workers\":["));
    }

    #[test]
    fn summary_survives_ring_wrap() {
        let epoch = Instant::now();
        let mut ring = Ring::with_capacity(4, epoch);
        for i in 0..20u64 {
            ring.record(Code::Attn, i * 10, i * 10 + 5, 0);
        }
        let log = TraceLog {
            workers: vec![WorkerTrace {
                tid: 0,
                name: "worker 0".into(),
                events: ring.events(),
                dropped: ring.dropped(),
            }],
        };
        let sum = TraceSummary::from_log(&log);
        assert_eq!(sum.events + sum.dropped, 20);
        assert!(sum.dropped > 0);
    }
}
