//! Serve-path observability: low-overhead tracing for the SPMD
//! serving engine.
//!
//! Two halves:
//!
//! - [`ring`] — the hot path. Pre-allocated per-worker [`Ring`]s
//!   record phase spans, barrier waits, tier ops, scheduler decisions,
//!   and per-request lifecycle edges with no locks and no allocation;
//!   the disabled path (`Option::None`) is a single branch.
//! - [`trace`] — the cold path. Post-run merge of all rings into a
//!   [`TraceLog`], Chrome-trace-event JSON export for Perfetto
//!   (`repro serve --trace-out trace.json`), and the [`TraceSummary`]
//!   (per-phase breakdown, barrier-wait fractions, per-worker
//!   busy/wait split) recorded in `ServeReport`.
//!
//! Tracing never changes what the engine computes: rings record
//! timestamps only, so a traced run is bitwise identical to an
//! untraced one (pinned by differential tests in
//! `rust/tests/serving.rs`).

pub mod ring;
pub mod trace;

pub use ring::{instant, mark, span, Code, Event, Ring, CODE_COUNT};
pub use trace::{
    json_escape, json_f64, PhaseStat, TraceLog, TraceSummary, WorkerStat, WorkerTrace,
};
