//! The hot-path half of `obs`: fixed-size per-worker event rings.
//!
//! A [`Ring`] is pre-allocated once at serve setup (capacity via
//! `PALLAS_TRACE_EVENTS`, default 65536 events per worker) and then
//! records [`Event`]s with **no locks and no allocation**: a record is
//! two monotonic-clock reads plus one slot write, wrapping over the
//! oldest events when full (`dropped()` reports how many fell off).
//! Every ring of a run shares one epoch `Instant`, so timestamps from
//! different workers merge onto one timeline.
//!
//! The disabled path must cost nothing: every engine hook takes an
//! `Option<&mut Ring>` and the free functions below ([`mark`],
//! [`span`], [`instant`]) compile to a branch on `None` — no clock
//! read, no allocation, nothing (pinned by the counting-allocator test
//! in `rust/tests/obs.rs`).

use std::time::Instant;

/// What an event records. Duration codes are phases of the serving
/// path (spans with `t0 < t1`); instant codes are per-request
/// lifecycle edges (`t0 == t1`, `arg` = request id).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u16)]
pub enum Code {
    /// Embedding gather (SPMD phase 0).
    Embed = 0,
    /// RMSNorm / residual / elementwise per-row phases.
    Norm = 1,
    /// Batched Q/K/V projection GEMMs.
    QkvGemm = 2,
    /// RoPE rotation.
    Rope = 3,
    /// Single-writer KV span commit.
    KvCommit = 4,
    /// Paged (causal / hybrid-cold) attention.
    Attn = 5,
    /// Attention output projection GEMM.
    OGemm = 6,
    /// SwiGLU gate/up/down GEMMs.
    MlpGemm = 7,
    /// LM-head projection.
    LmHead = 8,
    /// Time spent waiting at a phase barrier; `arg` is the `Code` of
    /// the phase the barrier closes (the load-imbalance signal).
    Barrier = 9,
    /// Worker parked between steps (controller is scheduling).
    Park = 10,
    /// Cold-tier spill batch (`arg` = op count).
    TierSpill = 11,
    /// Cold-tier fetch batch (`arg` = op count).
    TierFetch = 12,
    /// One whole scheduler iteration (`arg` = batch size).
    Iterate = 13,
    /// One `schedule()` call (`arg` = running-set size).
    Schedule = 14,
    /// Request entered the queue.
    Enqueue = 15,
    /// Request admitted to the running set.
    Admit = 16,
    /// Request sampled its first token.
    FirstToken = 17,
    /// Request preempted (recompute path).
    Preempt = 18,
    /// Request swapped out to the cold tier.
    SwapOut = 19,
    /// Swapped request re-admitted.
    SwapIn = 20,
    /// Request finished.
    Finish = 21,
    /// A failpoint fired (`arg` = fault-site tag: 0 panic, 1 fetch
    /// failure, 2 corruption, 3 alloc failure).
    FaultInject = 22,
    /// The serve loop recovered from a poisoned SPMD epoch
    /// (`arg` = sequences requeued by the recovery audit).
    Recover = 23,
    /// Request cancelled because its deadline passed (`arg` =
    /// request id).
    DeadlineMiss = 24,
    /// Request rejected at submission (`arg` = request id).
    Reject = 25,
    /// Self-drafter appended draft tokens to a decode span (`arg` =
    /// draft count).
    Draft = 26,
    /// A speculative span was verified (`arg` = drafts accepted).
    Verify = 27,
    /// Rejected drafts rolled back out of the token stream and KV
    /// (`arg` = drafts rejected).
    Rollback = 28,
}

/// Number of distinct codes (`Code` discriminants are `0..COUNT`).
pub const CODE_COUNT: usize = 29;

impl Code {
    pub fn name(self) -> &'static str {
        match self {
            Code::Embed => "embed",
            Code::Norm => "norm",
            Code::QkvGemm => "qkv_gemm",
            Code::Rope => "rope",
            Code::KvCommit => "kv_commit",
            Code::Attn => "attn",
            Code::OGemm => "o_gemm",
            Code::MlpGemm => "mlp_gemm",
            Code::LmHead => "lm_head",
            Code::Barrier => "barrier",
            Code::Park => "park",
            Code::TierSpill => "tier_spill",
            Code::TierFetch => "tier_fetch",
            Code::Iterate => "iterate",
            Code::Schedule => "schedule",
            Code::Enqueue => "enqueue",
            Code::Admit => "admit",
            Code::FirstToken => "first_token",
            Code::Preempt => "preempt",
            Code::SwapOut => "swap_out",
            Code::SwapIn => "swap_in",
            Code::Finish => "finish",
            Code::FaultInject => "fault_inject",
            Code::Recover => "recover",
            Code::DeadlineMiss => "deadline_miss",
            Code::Reject => "reject",
            Code::Draft => "draft",
            Code::Verify => "verify",
            Code::Rollback => "rollback",
        }
    }

    /// Lifecycle edges are instants (`ph: "i"` in the Chrome trace);
    /// everything else is a duration span (`B`/`E` pair).
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            Code::Enqueue
                | Code::Admit
                | Code::FirstToken
                | Code::Preempt
                | Code::SwapOut
                | Code::SwapIn
                | Code::Finish
                | Code::FaultInject
                | Code::Recover
                | Code::DeadlineMiss
                | Code::Reject
                | Code::Draft
                | Code::Verify
                | Code::Rollback
        )
    }

    /// Wait-class spans (barrier + park) — counted as idle, not busy,
    /// in the per-worker utilization split.
    pub fn is_wait(self) -> bool {
        matches!(self, Code::Barrier | Code::Park)
    }

    /// Inverse of `code as u16` (for `Barrier` events, whose `arg`
    /// carries the closed phase's code).
    pub fn from_u16(c: u16) -> Option<Code> {
        Some(match c {
            0 => Code::Embed,
            1 => Code::Norm,
            2 => Code::QkvGemm,
            3 => Code::Rope,
            4 => Code::KvCommit,
            5 => Code::Attn,
            6 => Code::OGemm,
            7 => Code::MlpGemm,
            8 => Code::LmHead,
            9 => Code::Barrier,
            10 => Code::Park,
            11 => Code::TierSpill,
            12 => Code::TierFetch,
            13 => Code::Iterate,
            14 => Code::Schedule,
            15 => Code::Enqueue,
            16 => Code::Admit,
            17 => Code::FirstToken,
            18 => Code::Preempt,
            19 => Code::SwapOut,
            20 => Code::SwapIn,
            21 => Code::Finish,
            22 => Code::FaultInject,
            23 => Code::Recover,
            24 => Code::DeadlineMiss,
            25 => Code::Reject,
            26 => Code::Draft,
            27 => Code::Verify,
            28 => Code::Rollback,
            _ => return None,
        })
    }
}

/// One recorded event: a span `[t0, t1]` (or an instant with
/// `t0 == t1`) in nanoseconds since the run's epoch. `seq` is the
/// ring-local record index, the tie-break that keeps merge ordering
/// stable when timestamps collide at clock granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub t0: u64,
    pub t1: u64,
    pub code: Code,
    pub arg: u32,
    pub seq: u32,
}

/// Fixed-capacity event ring. All storage is allocated up front; a
/// full ring overwrites its oldest events (newest always survive).
pub struct Ring {
    epoch: Instant,
    buf: Vec<Event>,
    written: u64,
}

impl Ring {
    /// A ring holding up to `capacity` events, stamped against `epoch`
    /// (share one epoch across every ring of a run so timelines merge).
    pub fn with_capacity(capacity: usize, epoch: Instant) -> Self {
        Ring { epoch, buf: Vec::with_capacity(capacity.max(1)), written: 0 }
    }

    /// Nanoseconds since the run epoch (monotonic).
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record a span (or instant when `t0 == t1`). No allocation: the
    /// buffer was reserved at construction, and a full ring overwrites
    /// its oldest slot.
    #[inline]
    pub fn record(&mut self, code: Code, t0: u64, t1: u64, arg: u32) {
        let ev = Event { t0, t1, code, arg, seq: self.written as u32 };
        let cap = self.buf.capacity();
        if self.buf.len() < cap {
            self.buf.push(ev);
        } else {
            self.buf[(self.written % cap as u64) as usize] = ev;
        }
        self.written += 1;
    }

    /// Record a span that started at `t0` and ends now.
    #[inline]
    pub fn close(&mut self, code: Code, t0: u64, arg: u32) {
        let t1 = self.now_ns();
        self.record(code, t0, t1, arg);
    }

    /// Record an instant event stamped now.
    #[inline]
    pub fn instant(&mut self, code: Code, arg: u32) {
        let t = self.now_ns();
        self.record(code, t, t, arg);
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn written(&self) -> u64 {
        self.written
    }

    /// Event slots available before wrap-around (the actual reserve —
    /// at least the requested capacity).
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Events lost to wrap-around (oldest-first).
    pub fn dropped(&self) -> u64 {
        self.written.saturating_sub(self.buf.len() as u64)
    }

    /// The run epoch this ring stamps against.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Surviving events in record order (oldest surviving first) —
    /// the post-run merge input.
    pub fn events(&self) -> Vec<Event> {
        let len = self.buf.len();
        if self.written <= len as u64 {
            return self.buf.clone();
        }
        let head = (self.written % self.buf.capacity() as u64) as usize;
        let mut out = Vec::with_capacity(len);
        out.extend_from_slice(&self.buf[head..]);
        out.extend_from_slice(&self.buf[..head]);
        out
    }
}

/// Read the clock iff tracing is on. Returns 0 when `tr` is `None` —
/// the disabled hook is exactly one branch.
#[inline]
pub fn mark(tr: &Option<&mut Ring>) -> u64 {
    match tr {
        Some(r) => r.now_ns(),
        None => 0,
    }
}

/// Close a span opened with [`mark`]. A no-op branch when disabled.
#[inline]
pub fn span(tr: &mut Option<&mut Ring>, code: Code, t0: u64, arg: u32) {
    if let Some(r) = tr {
        r.close(code, t0, arg);
    }
}

/// Record an instant event. A no-op branch when disabled.
#[inline]
pub fn instant(tr: &mut Option<&mut Ring>, code: Code, arg: u32) {
    if let Some(r) = tr {
        r.instant(code, arg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_in_order_without_wrap() {
        let mut r = Ring::with_capacity(8, Instant::now());
        for i in 0..5u64 {
            r.record(Code::Iterate, i * 10, i * 10 + 5, i as u32);
        }
        assert_eq!(r.written(), 5);
        assert_eq!(r.dropped(), 0);
        let evs = r.events();
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].t0, 0);
        assert_eq!(evs[4].t0, 40);
        assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn ring_wrap_overwrites_oldest_and_counts_drops() {
        let mut r = Ring::with_capacity(4, Instant::now());
        let cap = r.capacity() as u64; // actual reserve may exceed the request
        let n = cap + 3;
        for i in 0..n {
            r.record(Code::Attn, i, i + 1, 0);
        }
        assert_eq!(r.written(), n);
        assert_eq!(r.dropped(), n - r.events().len() as u64);
        let evs = r.events();
        // Newest `capacity` events survive, oldest first.
        assert_eq!(evs.last().unwrap().t0, n - 1);
        assert!(evs.windows(2).all(|w| w[0].t0 + 1 == w[1].t0), "chronological after wrap");
        assert!(evs[0].t0 > 0, "the oldest events were overwritten");
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let mut r = Ring::with_capacity(0, Instant::now());
        r.instant(Code::Finish, 7);
        assert_eq!(r.events().len(), 1);
    }

    #[test]
    fn disabled_hooks_are_inert() {
        let mut tr: Option<&mut Ring> = None;
        let t0 = mark(&tr);
        assert_eq!(t0, 0);
        span(&mut tr, Code::Iterate, t0, 0);
        instant(&mut tr, Code::Admit, 1);
        // Nothing to observe — the point is that this compiles to
        // branches and the counting-allocator integration test pins
        // the zero-allocation claim.
    }

    #[test]
    fn code_round_trips_through_u16() {
        for c in 0..CODE_COUNT as u16 {
            let code = Code::from_u16(c).expect("dense discriminants");
            assert_eq!(code as u16, c);
            assert!(!code.name().is_empty());
        }
        assert_eq!(Code::from_u16(CODE_COUNT as u16), None);
    }
}
