//! SPMD execution primitives shared by the dense decode engine
//! ([`crate::coordinator::engine`]) and the batched paged-attention
//! engine ([`crate::serving::batch_engine`]).
//!
//! Both engines follow the paper's "multi-core as multi-node" design
//! (§4.2): a *static* work partition decided at plan time, executed by
//! persistent worker threads that move together through barrier-
//! separated phases. No work stealing, no dynamic scheduling — which is
//! exactly what makes the partition deterministic: every output element
//! is computed by one statically-known worker with the same arithmetic
//! (and the same accumulation order) as the single-threaded path, so
//! thread count never changes results.
//!
//! The safety story is concentrated here instead of being scattered
//! across raw `UnsafeCell` pokes:
//!
//! * [`SpinBarrier`] — sense-reversing spin barrier; its Release/Acquire
//!   pair is the happens-before edge every phase transition relies on.
//! * [`splits`] / [`panel_splits`] — the deterministic static partition.
//! * [`SharedVec`] — scratch written by disjoint ranges between barriers.
//! * [`SharedCell`] — a single value written only while every other
//!   participant is parked at a barrier (work descriptors).
//! * [`KvCell`] — single-writer commit window for KV-cache state, with
//!   the barrier invariant turned into a deterministic `debug_assert`
//!   panic instead of a silent data race.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Sense-reversing spin barrier: ~100 ns per wait vs several μs for the
/// mutex/condvar `std::sync::Barrier` (§Perf L3 — a decode step passes
/// tens of barriers per token, so this matters on small models).
///
/// The barrier is *poisonable*: a participant that panics mid-phase
/// calls [`SpinBarrier::poison`] before unwinding, and every other
/// participant's `wait` then panics instead of spinning forever on a
/// straggler that will never arrive. Without this, one panicking worker
/// turns the whole SPMD region into a silent deadlock (the scope join
/// blocks on threads parked at the barrier) — with it, the panic
/// cascades, every thread unwinds, and the original payload propagates.
///
/// Poison is *permanent*: there is deliberately no un-poison. Fault
/// recovery (the serve path's epoch restart) must tear the scope down
/// and build a fresh barrier rather than resuscitate this one — a
/// half-poisoned barrier racing late wakers against a reset flag is
/// exactly the kind of recovery bug the audit in
/// `coordinator::serve` exists to rule out.
pub struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
    poisoned: AtomicBool,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Number of participants.
    pub fn parties(&self) -> usize {
        self.n
    }

    /// Mark the barrier dead. Call before unwinding out of an SPMD
    /// region; all current and future `wait`s will panic.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
    }

    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }

    /// Wait for all participants. Panics if the barrier is (or becomes)
    /// poisoned — a sibling participant panicked and will never arrive.
    pub fn wait(&self) {
        if self.n <= 1 {
            return;
        }
        if self.is_poisoned() {
            panic!("SpinBarrier poisoned: a sibling SPMD participant panicked");
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            self.count.store(0, Ordering::Relaxed);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
        } else {
            // Spin briefly, then yield: on oversubscribed machines (or a
            // 1-CPU container) pure spinning burns whole scheduler quanta
            // while the straggler cannot run.
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                if self.is_poisoned() {
                    panic!("SpinBarrier poisoned: a sibling SPMD participant panicked");
                }
                spins += 1;
                if spins < 512 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// Poisons a barrier if the owning scope unwinds: take one at the top of
/// every SPMD worker body so a panic anywhere in the phased region kills
/// the whole parallel section loudly (each sibling's next `wait` panics)
/// instead of deadlocking it. A normal return drops the guard silently.
pub struct PoisonGuard<'a>(&'a SpinBarrier);

impl<'a> PoisonGuard<'a> {
    pub fn new(barrier: &'a SpinBarrier) -> Self {
        PoisonGuard(barrier)
    }
}

impl Drop for PoisonGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.poison();
        }
    }
}

/// Contiguous ranges statically assigned to each worker: `n` items split
/// into `parts` ranges whose sizes differ by at most one, in order. The
/// partition depends only on `(n, parts)`, never on runtime state — the
/// determinism contract of every SPMD phase. When `parts > n`, trailing
/// ranges are empty (callers guard against that with an upper thread
/// clamp; empty ranges are still safe no-ops).
pub fn splits(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        let sz = base + usize::from(p < rem);
        out.push((lo, lo + sz));
        lo += sz;
    }
    out
}

/// [`splits`] over `panel`-aligned groups: `n` rows are divided into
/// `ceil(n / panel)` panels, the panels are split across `parts`, and
/// each range is returned in row units (lo `panel`-aligned, hi clipped
/// to `n`). This is the GEMM partition: register-tiled kernels own whole
/// MR-row panels, so shard boundaries must not cut through a panel.
pub fn panel_splits(n: usize, panel: usize, parts: usize) -> Vec<(usize, usize)> {
    splits(n.div_ceil(panel), parts)
        .into_iter()
        .map(|(a, b)| ((a * panel).min(n), (b * panel).min(n)))
        .collect()
}

/// Shared mutable scratch written by disjoint ranges from worker threads.
///
/// Contract: between two barriers, each element is written through at
/// most one [`SharedVec::slice_mut`] range, and no element inside any
/// live mutable range is read (readers use [`SharedVec::read`] on
/// elements no writer currently owns). The barrier's Release/Acquire
/// pair publishes one phase's writes to the next phase's readers.
pub struct SharedVec(UnsafeCell<Vec<f32>>);

// SAFETY: all aliasing is governed by the disjoint-range contract above;
// the data is plain `f32`.
unsafe impl Sync for SharedVec {}

impl SharedVec {
    pub fn new(n: usize) -> Self {
        SharedVec(UnsafeCell::new(vec![0.0; n]))
    }

    /// Mutable view of `[lo, hi)`.
    ///
    /// # Safety
    ///
    /// Between two barriers, callers must hold mutable views of disjoint
    /// ranges only, and no participant may read elements inside another
    /// worker's live mutable range.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [f32] {
        let v: &mut Vec<f32> = unsafe { &mut *self.0.get() };
        &mut v[lo..hi]
    }

    /// Shared read of the whole buffer. Elements inside another worker's
    /// live mutable range must not be touched (phase discipline).
    pub fn read(&self) -> &[f32] {
        unsafe { &*self.0.get() }
    }

    /// Serial overwrite of the whole buffer (single-writer phases only).
    pub fn write_all(&self, src: &[f32]) {
        unsafe { (*self.0.get()).copy_from_slice(src) }
    }
}

/// A single shared value written only while every other participant is
/// parked at a barrier — the work descriptor of a persistent-worker
/// loop (the controller publishes the next step's inputs, then releases
/// the workers through the barrier).
pub struct SharedCell<T>(UnsafeCell<T>);

// SAFETY: access is serialized by the caller's barrier protocol.
unsafe impl<T: Send + Sync> Sync for SharedCell<T> {}

impl<T> SharedCell<T> {
    pub fn new(v: T) -> Self {
        SharedCell(UnsafeCell::new(v))
    }

    /// Exclusive view.
    ///
    /// # Safety
    ///
    /// No other thread may be reading or writing — every other
    /// participant must be parked at a barrier.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self) -> &mut T {
        unsafe { &mut *self.0.get() }
    }

    /// Shared view.
    ///
    /// # Safety
    ///
    /// No concurrent writer; every write must be separated from this
    /// read by a barrier.
    pub unsafe fn read(&self) -> &T {
        unsafe { &*self.0.get() }
    }
}

/// Single-writer handoff cell for KV-cache commits.
///
/// Invariant (checked with `debug_assert!`s): only worker 0 calls
/// [`KvCell::commit`], and every `commit` is separated from every
/// [`KvCell::read`] by a barrier — commit-phase writes happen-before
/// read-phase reads via the barrier's Release/Acquire pair. The
/// `writers` counter turns a violated invariant into a deterministic
/// debug panic instead of a silent data race; block tables in the paged
/// serving path make the aliasing rules stricter, so the contract is
/// enforced here rather than at each call site.
pub struct KvCell<'a, T> {
    kv: UnsafeCell<&'a mut T>,
    writers: AtomicUsize,
}

// SAFETY: the single-writer/barrier protocol above serializes all access;
// `T: Send + Sync` keeps the underlying data sound to touch from any of
// the scoped worker threads.
unsafe impl<T: Send + Sync> Sync for KvCell<'_, T> {}

impl<'a, T> KvCell<'a, T> {
    pub fn new(kv: &'a mut T) -> Self {
        KvCell { kv: UnsafeCell::new(kv), writers: AtomicUsize::new(0) }
    }

    /// Exclusive commit window. SAFETY: caller must be the single writer
    /// (worker 0) inside a barrier-separated phase.
    pub fn commit(&self, worker: usize, f: impl FnOnce(&mut T)) {
        debug_assert_eq!(worker, 0, "only worker 0 may commit the KV cache");
        let prev = self.writers.fetch_add(1, Ordering::AcqRel);
        debug_assert_eq!(prev, 0, "concurrent KV commit: barrier invariant violated");
        let _ = prev;
        // SAFETY: single writer by contract (debug-checked above); all
        // readers are on the other side of a barrier.
        f(unsafe { &mut **self.kv.get() });
        self.writers.fetch_sub(1, Ordering::AcqRel);
    }

    /// Shared read. SAFETY: must be barrier-separated from any commit.
    pub fn read(&self) -> &T {
        debug_assert_eq!(
            self.writers.load(Ordering::Acquire),
            0,
            "KV read overlapping a commit: barrier invariant violated"
        );
        // SAFETY: no writer is active (debug-checked above); the commit
        // phase happened-before this read via the barrier.
        unsafe { &**self.kv.get() }
    }
}

/// Fixed-order cross-shard reduction: sum per-segment partial vectors
/// into `out` in **ascending segment index**, regardless of which shard
/// produced which segment.
///
/// This is the deterministic combine tree of the sharded serving
/// engine's staged inner-split (`P`) lowering: partial outputs are
/// produced at a *fixed* K-segment granularity (chosen once, never a
/// function of the shard count), each shard computes some subset of
/// segments, and the combiner adds them in segment order. Because both
/// the segment boundaries and the summation order are shard-count-
/// independent, the reduced bits are identical at any `(threads ×
/// shards)` — the property test below pins this. (The current engine's
/// executable layouts — `B` and column-parallel `S(1)` — need no
/// reduction at all; this primitive is what makes a future `P` layout
/// admissible under the same bitwise contract.)
///
/// `parts` entries are `(segment_index, partial)`; every partial must
/// be `out.len()` long. Duplicate segment indices are a caller bug
/// (`debug_assert`) — each segment contributes exactly once.
pub fn combine_fixed_order(out: &mut [f32], parts: &mut Vec<(usize, Vec<f32>)>) {
    parts.sort_by_key(|(seg, _)| *seg);
    debug_assert!(
        parts.windows(2).all(|w| w[0].0 != w[1].0),
        "duplicate segment in fixed-order combine"
    );
    for (_, partial) in parts.iter() {
        assert_eq!(partial.len(), out.len(), "partial length mismatch");
        for (o, p) in out.iter_mut().zip(partial) {
            *o += p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_cover_and_balance() {
        for &(n, parts) in &[(10usize, 3usize), (7, 7), (16, 4), (5, 8), (0, 3), (100, 12)] {
            let s = splits(n, parts);
            assert_eq!(s.len(), parts);
            assert_eq!(s[0].0, 0);
            assert_eq!(s[parts - 1].1, n);
            let mut total = 0;
            for (i, &(lo, hi)) in s.iter().enumerate() {
                assert!(lo <= hi);
                total += hi - lo;
                if i > 0 {
                    assert_eq!(s[i - 1].1, lo, "ranges must be contiguous");
                }
            }
            assert_eq!(total, n);
            let max = s.iter().map(|(lo, hi)| hi - lo).max().unwrap();
            let min = s.iter().map(|(lo, hi)| hi - lo).min().unwrap();
            assert!(max - min <= 1, "shards must differ by at most one");
        }
    }

    #[test]
    fn splits_deterministic() {
        assert_eq!(splits(10, 3), splits(10, 3));
        assert_eq!(splits(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
    }

    #[test]
    fn panel_splits_align_and_cover() {
        // 10 rows, panel 4 -> 3 panels; 2 parts -> panels [0,2) and [2,3).
        assert_eq!(panel_splits(10, 4, 2), vec![(0, 8), (8, 10)]);
        // Every lo is panel-aligned; the union covers [0, n).
        for &(n, panel, parts) in &[(16usize, 4usize, 4usize), (17, 4, 3), (3, 4, 2), (0, 4, 2)] {
            let s = panel_splits(n, panel, parts);
            assert_eq!(s.len(), parts);
            assert_eq!(s.last().unwrap().1, n);
            for (i, &(lo, hi)) in s.iter().enumerate() {
                assert!(lo <= hi && hi <= n);
                assert!(lo == n || lo % panel == 0, "lo must be panel-aligned");
                if i > 0 {
                    assert_eq!(s[i - 1].1, lo);
                }
            }
        }
        // Oversubscribed: trailing shards are empty, never out of range.
        let s = panel_splits(3, 4, 8);
        assert_eq!(s[0], (0, 3));
        assert!(s[1..].iter().all(|&(lo, hi)| lo == hi));
    }

    #[test]
    fn barrier_separates_phases() {
        // Each of 4 threads bumps a counter, waits, and checks that all
        // bumps of the phase are visible — 50 rounds.
        let t = 4usize;
        let rounds = 50usize;
        let barrier = SpinBarrier::new(t);
        assert_eq!(barrier.parties(), t);
        let counter = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..t {
                s.spawn(|| {
                    for r in 1..=rounds {
                        counter.fetch_add(1, Ordering::AcqRel);
                        barrier.wait();
                        assert_eq!(counter.load(Ordering::Acquire), r * t);
                        barrier.wait();
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Acquire), rounds * t);
    }

    #[test]
    fn shared_vec_disjoint_writes_compose() {
        let n = 64usize;
        let t = 4usize;
        let v = SharedVec::new(n);
        let barrier = SpinBarrier::new(t);
        std::thread::scope(|s| {
            for wi in 0..t {
                let (v, barrier) = (&v, &barrier);
                s.spawn(move || {
                    let (lo, hi) = splits(n, t)[wi];
                    // SAFETY: ranges from `splits` are disjoint.
                    let seg = unsafe { v.slice_mut(lo, hi) };
                    for (off, x) in seg.iter_mut().enumerate() {
                        *x = (lo + off) as f32;
                    }
                    barrier.wait();
                    // Post-barrier, every worker sees the full buffer.
                    let all = v.read();
                    for (i, &x) in all.iter().enumerate() {
                        assert_eq!(x, i as f32);
                    }
                });
            }
        });
    }

    #[test]
    fn poisoned_barrier_panics_instead_of_hanging() {
        let barrier = SpinBarrier::new(2);
        std::thread::scope(|s| {
            let waiter = s.spawn(|| {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| barrier.wait())).is_err()
            });
            // The sibling "panics" instead of arriving: poison.
            barrier.poison();
            assert!(waiter.join().unwrap(), "waiter must panic, not spin forever");
        });
        assert!(barrier.is_poisoned());
        // Later waits die immediately too.
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| barrier.wait())).is_err());
    }

    #[test]
    fn poison_is_permanent_across_would_be_reuse() {
        // The epoch-restart recovery contract: once poisoned, a barrier
        // never serves another phase — every wait dies, including after
        // the participant count's worth of waits that would have
        // "cycled" a healthy barrier. Recovery must build a fresh
        // barrier (a fresh SPMD scope), never reuse this one.
        let b = SpinBarrier::new(2);
        b.poison();
        for _ in 0..4 {
            assert!(
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| b.wait())).is_err(),
                "poisoned barrier must stay dead"
            );
        }
        assert!(b.is_poisoned());
    }

    #[test]
    fn poison_guard_poisons_on_unwind_only() {
        let b = SpinBarrier::new(2);
        {
            let _g = PoisonGuard::new(&b);
        }
        assert!(!b.is_poisoned(), "clean drop must not poison");
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = PoisonGuard::new(&b);
            panic!("boom");
        }));
        assert!(b.is_poisoned(), "unwinding past the guard must poison");
    }

    #[test]
    fn kv_cell_commit_then_read() {
        let mut state = vec![0usize; 4];
        let cell = KvCell::new(&mut state);
        cell.commit(0, |s| s[2] = 7);
        assert_eq!(cell.read()[2], 7);
    }

    #[test]
    fn shared_cell_roundtrip() {
        let c = SharedCell::new(vec![1usize, 2]);
        // SAFETY: single-threaded test, no concurrent access.
        unsafe {
            c.get_mut().push(3);
            assert_eq!(c.read().as_slice(), &[1, 2, 3]);
        }
    }

    #[test]
    fn fixed_order_combine_is_bitwise_shard_count_independent() {
        // Property (ISSUE 7 satellite): partials produced at a fixed
        // segment granularity reduce to the same bits no matter how the
        // segments were distributed across shards. Magnitudes are
        // spread over ~2^40 so float addition is maximally
        // non-associative — any order dependence would show.
        let mut rng = crate::util::Rng::new(0xD157);
        let width = 33usize;
        let segments = 16usize;
        let mut parts_master: Vec<(usize, Vec<f32>)> = (0..segments)
            .map(|s| {
                let scale = 2.0f32.powi((s as i32 % 8) * 5 - 20);
                (s, (0..width).map(|_| (rng.below(2000) as f32 - 1000.0) * scale).collect())
            })
            .collect();
        // Element 0 is a crafted cancellation: ascending order gives
        // (1 + 1e8) + (-1e8) = 0.0 (the 1 is absorbed), any order that
        // sums -1e8 + 1e8 first gives 1.0 — so the control below is
        // guaranteed, not probabilistic.
        for (s, p) in parts_master.iter_mut() {
            p[0] = match *s {
                0 => 1.0,
                1 => 1e8,
                2 => -1e8,
                _ => 0.0,
            };
        }
        let reduce = |shards: usize| -> Vec<u32> {
            // Shard s owns segments `splits(segments, shards)[s]`; each
            // shard hands its segments to the combiner independently
            // (simulating per-group production order).
            let mut parts: Vec<(usize, Vec<f32>)> = Vec::new();
            for (lo, hi) in splits(segments, shards) {
                // Reverse within the shard: arrival order must not
                // matter, only the fixed segment order.
                for s in (lo..hi).rev() {
                    parts.push(parts_master[s].clone());
                }
            }
            let mut out = vec![0.0f32; width];
            combine_fixed_order(&mut out, &mut parts);
            out.iter().map(|v| v.to_bits()).collect()
        };
        let base = reduce(1);
        for shards in [2usize, 3, 4, 7, 16] {
            assert_eq!(reduce(shards), base, "combine diverged at {shards} shards");
        }
        // Control: summing at *shard* granularity (a per-shard running
        // sum, then shard-order combine) is the layout this primitive
        // exists to avoid — verify the fixed-segment order actually
        // differs from at least one such variable-granularity order,
        // i.e. the test would catch a wrong implementation.
        let per_shard = |shards: usize| -> Vec<u32> {
            let mut out = vec![0.0f32; width];
            for (lo, hi) in splits(segments, shards) {
                let mut acc = vec![0.0f32; width];
                for s in (lo..hi).rev() {
                    for (a, p) in acc.iter_mut().zip(&parts_master[s].1) {
                        *a += p;
                    }
                }
                for (o, a) in out.iter_mut().zip(&acc) {
                    *o += a;
                }
            }
            out.iter().map(|v| v.to_bits()).collect()
        };
        assert_ne!(
            per_shard(3),
            base,
            "control failed: pick inputs where order dependence is visible"
        );
    }
}
