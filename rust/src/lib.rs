//! # nncase-repro
//!
//! Reproduction of *"nncase: An End-to-End Compiler for Efficient LLM
//! Deployment on Heterogeneous Storage Architectures"* (Canaan Inc.,
//! CS.DC 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains the full compiler pipeline the paper describes:
//!
//! * [`ir`] — the tensor-level intermediate representation.
//! * [`egraph`] — e-graph with equality saturation and cost-based
//!   extraction (greedy and Weighted-Partial-MaxSAT, §3.1.1).
//! * [`rewrite`] — rewrite rules: Table 1 (transpose), Table 2
//!   (`MetaPackOperation` / `FoldNopPack`, §3.1.2) and a destructive
//!   greedy rewriter used as the phase-ordering baseline (Fig. 2).
//! * [`sat`] — a self-contained CDCL SAT solver plus WPMaxSAT and
//!   pseudo-boolean layers used by extraction and memory planning.
//! * [`cost`] — Roofline cost model, alpha-beta communication model and
//!   machine descriptions (§3.1.1, §3.1.3).
//! * [`dist`] — Auto Distribution: SBP abstraction and the distributed
//!   e-graph construction of Fig. 5 (§3.1.3).
//! * [`schedule`] — Auto Schedule: tiered tile graphs, MCTS structural
//!   search and the MINLP parametric optimizer (§3.2).
//! * [`codegen`] — bufferization, alias analysis, liveness, bin-packing
//!   memory planning and NTT-style C++ emission (§3.3).
//! * [`ntt`] — the Rust analog of the nncase Tensor Template library:
//!   register-blocked μkernels used by the real execution backend.
//! * [`model`] — Qwen3-family graph builders (0.6B / 1.7B / tiny).
//! * [`sim`] — the machine simulator and the analytic baseline models
//!   (llama.cpp / IPEX / MLC) used to regenerate Figures 9 and 10.
//! * [`runtime`] — PJRT (xla crate) artifact loading and execution.
//! * [`coordinator`] — the serving layer: request batching, KV cache and
//!   the multi-core "cores as distributed nodes" decode engine (§4.2).
//! * [`parallel`] — SPMD execution primitives (spin barrier, static
//!   partitioning, disjoint-range scratch, single-writer KV handoff)
//!   shared by the dense and batched decode engines.
//! * [`serving`] — the paged KV-cache block pool and continuous-batching
//!   scheduler behind `ServeOptions::continuous` (docs/serving.md).
//! * [`obs`] — serve-path tracing: per-worker event rings, Perfetto
//!   (Chrome-trace) export and the phase/utilization summary in
//!   `ServeReport`.

pub mod cost;
pub mod codegen;
pub mod coordinator;
pub mod dist;
pub mod egraph;
pub mod ir;
pub mod model;
pub mod ntt;
pub mod obs;
pub mod parallel;
pub mod pipeline;
pub mod rewrite;
pub mod runtime;
pub mod sat;
pub mod schedule;
pub mod serving;
pub mod sim;
pub mod util;

