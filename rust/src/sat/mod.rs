//! Self-contained SAT stack.
//!
//! The paper leans on a SAT solver in three places: e-graph extraction is
//! a Weighted Partial MaxSAT problem (§3.1.1), Auto Distribution's
//! extraction adds hard memory-capacity constraints (§3.1.3), and the
//! memory planner solves bin packing with SAT (§3.3.1). We implement the
//! whole stack from scratch:
//!
//! * [`cdcl`] — a CDCL solver with two-watched-literal propagation, 1UIP
//!   conflict analysis, VSIDS-style activity and Luby restarts.
//! * [`pb`] — pseudo-boolean `Σ wᵢ·xᵢ ≤ k` constraints encoded with a
//!   sequential weighted counter.
//! * [`maxsat`] — Weighted Partial MaxSAT via iterative cost-bound
//!   tightening (SAT-UNSAT linear + binary search over the PB bound).

mod cdcl;
mod maxsat;
mod pb;

pub use cdcl::{Lit, SatResult, Solver, Var};
pub use maxsat::{MaxSatResult, WpmsSolver};
pub use pb::encode_pb_leq;
