//! Weighted Partial MaxSAT on top of the CDCL core.
//!
//! Hard clauses must hold; each soft clause carries a weight and the
//! solver minimizes the total weight of *violated* soft clauses. This is
//! the form e-graph extraction takes in §3.1.1 (select e-nodes with
//! minimal total Roofline cost subject to the well-formedness constraints)
//! — WPMAXSAT per He et al.
//!
//! Algorithm: relax every soft clause with a fresh selector `rᵢ`
//! (`clause ∨ rᵢ`), find any model, then binary-search the optimal cost
//! with a sequential-weighted-counter bound `Σ wᵢ·rᵢ ≤ k` re-encoded per
//! probe. Instances here are small (hundreds of soft clauses), so probe
//! re-encoding is cheaper than incremental totalizers.

use super::{encode_pb_leq, Lit, SatResult, Solver};

/// Result of a WPMaxSAT solve.
#[derive(Debug, Clone)]
pub struct MaxSatResult {
    /// Model over the original variables.
    pub model: Vec<bool>,
    /// Total weight of violated soft clauses.
    pub cost: u64,
}

/// Weighted Partial MaxSAT solver (one-shot builder).
#[derive(Default)]
pub struct WpmsSolver {
    nvars: u32,
    hard: Vec<Vec<Lit>>,
    soft: Vec<(Vec<Lit>, u64)>,
    /// Hard pseudo-boolean constraints `Σ wᵢ·lᵢ ≤ k` (used for the Auto
    /// Distribution memory-capacity constraint, Observation 2).
    pb_hard: Vec<(Vec<(Lit, u64)>, u64)>,
}

impl WpmsSolver {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn new_var(&mut self) -> u32 {
        let v = self.nvars;
        self.nvars += 1;
        v
    }

    /// Reserve variables 0..n (idempotent).
    pub fn ensure_vars(&mut self, n: u32) {
        self.nvars = self.nvars.max(n);
    }

    pub fn add_hard(&mut self, lits: &[Lit]) {
        self.hard.push(lits.to_vec());
    }

    /// Add a soft clause with `weight`; violating it costs `weight`.
    pub fn add_soft(&mut self, lits: &[Lit], weight: u64) {
        if weight == 0 {
            return;
        }
        self.soft.push((lits.to_vec(), weight));
    }

    /// Add a hard pseudo-boolean constraint `Σ wᵢ·lᵢ ≤ bound`.
    pub fn add_pb_leq(&mut self, terms: &[(Lit, u64)], bound: u64) {
        self.pb_hard.push((terms.to_vec(), bound));
    }

    /// Quantize weights so their total is at most `max_total`. Keeps the
    /// pseudo-boolean encodings polynomial for Roofline-scale (ns) weights
    /// at the price of a bounded relative error (≤ n/max_total).
    fn quantize(weights: &[u64], max_total: u64) -> (Vec<u64>, u64) {
        let total: u64 = weights.iter().sum();
        let q = (total / max_total).max(1);
        (weights.iter().map(|&w| (w / q).max(1)).collect(), q)
    }

    fn build(&self, quant_weights: &[u64], cost_bound: Option<u64>) -> (Solver, Vec<(Lit, u64)>) {
        let mut s = Solver::new();
        for _ in 0..self.nvars {
            s.new_var();
        }
        for c in &self.hard {
            s.add_clause(c);
        }
        for (terms, bound) in &self.pb_hard {
            // Quantize hard PB constraints conservatively (round weights
            // up, bound down) so the true constraint is never violated.
            let q = (*bound / 1024).max(1);
            let qterms: Vec<(Lit, u64)> =
                terms.iter().map(|&(l, w)| (l, w.div_ceil(q))).collect();
            encode_pb_leq(&mut s, &qterms, bound / q);
        }
        let mut selectors = Vec::with_capacity(self.soft.len());
        for ((c, _), qw) in self.soft.iter().zip(quant_weights) {
            let r = Lit::pos(s.new_var());
            let mut cl = c.clone();
            cl.push(r);
            s.add_clause(&cl);
            selectors.push((r, *qw));
        }
        if let Some(k) = cost_bound {
            encode_pb_leq(&mut s, &selectors, k);
        }
        (s, selectors)
    }

    fn model_cost_with(&self, model: &[bool], weights: &[u64]) -> u64 {
        self.soft
            .iter()
            .zip(weights)
            .map(|((c, _), w)| {
                let sat = c.iter().any(|l| {
                    let v = model[l.var() as usize];
                    if l.is_neg() {
                        !v
                    } else {
                        v
                    }
                });
                if sat {
                    0
                } else {
                    *w
                }
            })
            .sum()
    }

    fn model_cost(&self, model: &[bool]) -> u64 {
        let weights: Vec<u64> = self.soft.iter().map(|(_, w)| *w).collect();
        self.model_cost_with(model, &weights)
    }

    /// Solve. Returns `None` if the hard clauses are UNSAT. The search is
    /// exact for small total weights; for large (Roofline-scale) weights
    /// it optimizes the quantized objective (≤ 0.1% per-soft-clause error).
    pub fn solve(&self) -> Option<MaxSatResult> {
        let weights: Vec<u64> = self.soft.iter().map(|(_, w)| *w).collect();
        let (qweights, _q) = Self::quantize(&weights, 1024);

        // Initial feasibility probe (no bound).
        let (mut s, _) = self.build(&qweights, None);
        let model = match s.solve() {
            SatResult::Sat(m) => m,
            SatResult::Unsat => return None,
        };
        let mut best_model = model[..self.nvars as usize].to_vec();
        let mut best_qcost = self.model_cost_with(&best_model, &qweights);

        // Binary search over the quantized cost bound.
        let mut lo = 0u64;
        while lo < best_qcost {
            let mid = lo + (best_qcost - lo) / 2;
            let (mut s, _) = self.build(&qweights, Some(mid));
            match s.solve() {
                SatResult::Sat(m) => {
                    let cand = m[..self.nvars as usize].to_vec();
                    let c = self.model_cost_with(&cand, &qweights);
                    debug_assert!(c <= mid);
                    best_qcost = c;
                    best_model = cand;
                }
                SatResult::Unsat => {
                    lo = mid + 1;
                }
            }
        }
        let cost = self.model_cost(&best_model);
        Some(MaxSatResult { model: best_model, cost })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pure_soft_prefers_high_weight() {
        // x and ¬x both soft: keep the heavier one.
        let mut w = WpmsSolver::new();
        let x = w.new_var();
        w.add_soft(&[Lit::pos(x)], 5);
        w.add_soft(&[Lit::neg(x)], 3);
        let r = w.solve().unwrap();
        assert!(r.model[x as usize]);
        assert_eq!(r.cost, 3);
    }

    #[test]
    fn hard_overrides_soft() {
        let mut w = WpmsSolver::new();
        let x = w.new_var();
        w.add_hard(&[Lit::neg(x)]);
        w.add_soft(&[Lit::pos(x)], 1000);
        let r = w.solve().unwrap();
        assert!(!r.model[x as usize]);
        assert_eq!(r.cost, 1000);
    }

    #[test]
    fn unsat_hard_returns_none() {
        let mut w = WpmsSolver::new();
        let x = w.new_var();
        w.add_hard(&[Lit::pos(x)]);
        w.add_hard(&[Lit::neg(x)]);
        assert!(w.solve().is_none());
    }

    #[test]
    fn min_vertex_cover_triangle() {
        // Triangle graph min vertex cover = 2. Soft: ¬v (prefer few
        // vertices, weight 1 each); hard: every edge covered.
        let mut w = WpmsSolver::new();
        let vs: Vec<u32> = (0..3).map(|_| w.new_var()).collect();
        for &(a, b) in &[(0, 1), (1, 2), (0, 2)] {
            w.add_hard(&[Lit::pos(vs[a]), Lit::pos(vs[b])]);
        }
        for &v in &vs {
            w.add_soft(&[Lit::neg(v)], 1);
        }
        let r = w.solve().unwrap();
        assert_eq!(r.cost, 2);
        let chosen = r.model.iter().filter(|&&b| b).count();
        assert_eq!(chosen, 2);
    }

    #[test]
    fn weighted_selection_exact() {
        // Choose exactly one of three options (hard), each option's
        // rejection is free but selecting option i costs w_i via a soft
        // clause preferring ¬o_i. Optimal picks the min-weight option.
        let weights = [7u64, 3, 9];
        let mut w = WpmsSolver::new();
        let os: Vec<u32> = (0..3).map(|_| w.new_var()).collect();
        w.add_hard(&[Lit::pos(os[0]), Lit::pos(os[1]), Lit::pos(os[2])]);
        for i in 0..3 {
            for j in (i + 1)..3 {
                w.add_hard(&[Lit::neg(os[i]), Lit::neg(os[j])]);
            }
        }
        for (i, &wt) in weights.iter().enumerate() {
            w.add_soft(&[Lit::neg(os[i])], wt);
        }
        let r = w.solve().unwrap();
        assert_eq!(r.cost, 3);
        assert!(r.model[os[1] as usize]);
    }

    #[test]
    fn randomized_against_bruteforce() {
        let mut rng = crate::util::Rng::new(99);
        for round in 0..10 {
            let nv = 6;
            let mut w = WpmsSolver::new();
            for _ in 0..nv {
                w.new_var();
            }
            // A few random hard 2-clauses (keep satisfiable by
            // including one all-positive clause set).
            let mut hard: Vec<Vec<i64>> = Vec::new();
            for _ in 0..3 {
                let a = rng.below(nv) as i64 + 1;
                let b = rng.below(nv) as i64 + 1;
                hard.push(vec![a, if rng.next_f64() < 0.5 { b } else { -b }]);
            }
            let mut soft: Vec<(Vec<i64>, u64)> = Vec::new();
            for _ in 0..5 {
                let a = rng.below(nv) as i64 + 1;
                let lit = if rng.next_f64() < 0.5 { a } else { -a };
                soft.push((vec![lit], 1 + rng.below(10) as u64));
            }
            let to_lit = |v: i64| {
                if v > 0 {
                    Lit::pos((v - 1) as u32)
                } else {
                    Lit::neg((-v - 1) as u32)
                }
            };
            for c in &hard {
                let ls: Vec<Lit> = c.iter().map(|&v| to_lit(v)).collect();
                w.add_hard(&ls);
            }
            for (c, wt) in &soft {
                let ls: Vec<Lit> = c.iter().map(|&v| to_lit(v)).collect();
                w.add_soft(&ls, *wt);
            }
            // Brute force optimum.
            let eval = |m: u32, c: &[i64]| {
                c.iter().any(|&l| {
                    let v = (l.unsigned_abs() - 1) as usize;
                    let val = (m >> v) & 1 == 1;
                    if l > 0 {
                        val
                    } else {
                        !val
                    }
                })
            };
            let mut best: Option<u64> = None;
            for m in 0u32..(1 << nv) {
                if hard.iter().all(|c| eval(m, c)) {
                    let cost: u64 =
                        soft.iter().filter(|(c, _)| !eval(m, c)).map(|(_, w)| *w).sum();
                    best = Some(best.map_or(cost, |b: u64| b.min(cost)));
                }
            }
            let got = w.solve();
            match best {
                None => assert!(got.is_none(), "round {round}"),
                Some(b) => assert_eq!(got.unwrap().cost, b, "round {round}"),
            }
        }
    }
}
