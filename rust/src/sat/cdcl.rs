//! A CDCL SAT solver (two-watched literals, 1UIP learning, VSIDS-lite
//! activities, Luby restarts, assumption interface).

/// Variable index (0-based).
pub type Var = u32;

/// A literal: variable + polarity, encoded as `var * 2 + (neg as u32)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Lit(pub u32);

impl Lit {
    pub fn pos(v: Var) -> Lit {
        Lit(v * 2)
    }

    pub fn neg(v: Var) -> Lit {
        Lit(v * 2 + 1)
    }

    pub fn var(self) -> Var {
        self.0 / 2
    }

    pub fn is_neg(self) -> bool {
        self.0 & 1 == 1
    }

    pub fn negate(self) -> Lit {
        Lit(self.0 ^ 1)
    }

    fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::ops::Not for Lit {
    type Output = Lit;
    fn not(self) -> Lit {
        self.negate()
    }
}

/// Tri-state assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Assign {
    Unset,
    True,
    False,
}

/// Result of a solve call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SatResult {
    /// Satisfiable; the model maps each var to its value.
    Sat(Vec<bool>),
    Unsat,
}

impl SatResult {
    pub fn is_sat(&self) -> bool {
        matches!(self, SatResult::Sat(_))
    }

    pub fn model(&self) -> Option<&[bool]> {
        match self {
            SatResult::Sat(m) => Some(m),
            SatResult::Unsat => None,
        }
    }
}

const REASON_NONE: u32 = u32::MAX;
const REASON_ASSUMPTION: u32 = u32::MAX - 1;

/// The CDCL solver.
pub struct Solver {
    nvars: u32,
    /// Clause arena; clause i occupies `clauses[i]`.
    clauses: Vec<Vec<Lit>>,
    /// For each literal, the clauses watching it.
    watches: Vec<Vec<u32>>,
    assign: Vec<Assign>,
    /// Decision level per variable.
    level: Vec<u32>,
    /// Reason clause per variable (index into `clauses`), REASON_NONE for
    /// decisions/unset, REASON_ASSUMPTION for assumptions.
    reason: Vec<u32>,
    trail: Vec<Lit>,
    trail_lim: Vec<usize>,
    prop_head: usize,
    /// VSIDS-style activity.
    activity: Vec<f64>,
    act_inc: f64,
    /// Saved phases for phase-saving.
    phase: Vec<bool>,
    /// Unit input clauses, asserted at level 0 at the start of solve.
    units: Vec<(Lit, u32)>,
    /// Set true if an empty clause was added.
    trivially_unsat: bool,
    /// Statistics.
    pub conflicts: u64,
    pub propagations: u64,
}

impl Default for Solver {
    fn default() -> Self {
        Self::new()
    }
}

impl Solver {
    pub fn new() -> Self {
        Solver {
            nvars: 0,
            clauses: Vec::new(),
            watches: Vec::new(),
            assign: Vec::new(),
            level: Vec::new(),
            reason: Vec::new(),
            trail: Vec::new(),
            trail_lim: Vec::new(),
            prop_head: 0,
            activity: Vec::new(),
            act_inc: 1.0,
            phase: Vec::new(),
            units: Vec::new(),
            trivially_unsat: false,
            conflicts: 0,
            propagations: 0,
        }
    }

    /// Allocate a fresh variable.
    pub fn new_var(&mut self) -> Var {
        let v = self.nvars;
        self.nvars += 1;
        self.watches.push(Vec::new());
        self.watches.push(Vec::new());
        self.assign.push(Assign::Unset);
        self.level.push(0);
        self.reason.push(REASON_NONE);
        self.activity.push(0.0);
        self.phase.push(false);
        v
    }

    pub fn num_vars(&self) -> u32 {
        self.nvars
    }

    fn value(&self, l: Lit) -> Assign {
        match self.assign[l.var() as usize] {
            Assign::Unset => Assign::Unset,
            Assign::True => {
                if l.is_neg() {
                    Assign::False
                } else {
                    Assign::True
                }
            }
            Assign::False => {
                if l.is_neg() {
                    Assign::True
                } else {
                    Assign::False
                }
            }
        }
    }

    /// Add a clause (empty clause makes the instance trivially UNSAT).
    /// Must be called before `solve`; the solver is not incremental across
    /// learnt state but may be re-solved with different assumptions.
    pub fn add_clause(&mut self, lits: &[Lit]) {
        // Deduplicate; drop tautologies.
        let mut ls: Vec<Lit> = lits.to_vec();
        ls.sort();
        ls.dedup();
        for w in ls.windows(2) {
            if w[0].var() == w[1].var() {
                return; // x ∨ ¬x: tautology
            }
        }
        match ls.len() {
            0 => self.trivially_unsat = true,
            _ => {
                let ci = self.clauses.len() as u32;
                self.watches[ls[0].index()].push(ci);
                if ls.len() > 1 {
                    self.watches[ls[1].index()].push(ci);
                } else {
                    // Unit clauses are asserted at level 0 when solving.
                    self.units.push((ls[0], ci));
                }
                self.clauses.push(ls);
            }
        }
    }

    fn enqueue(&mut self, l: Lit, reason: u32) -> bool {
        match self.value(l) {
            Assign::True => true,
            Assign::False => false,
            Assign::Unset => {
                let v = l.var() as usize;
                self.assign[v] = if l.is_neg() { Assign::False } else { Assign::True };
                self.level[v] = self.trail_lim.len() as u32;
                self.reason[v] = reason;
                self.phase[v] = !l.is_neg();
                self.trail.push(l);
                true
            }
        }
    }

    /// Unit propagation. Returns a conflicting clause index or None.
    fn propagate(&mut self) -> Option<u32> {
        while self.prop_head < self.trail.len() {
            let l = self.trail[self.prop_head];
            self.prop_head += 1;
            self.propagations += 1;
            let falsified = !l;
            let mut i = 0;
            // Take the watch list for the falsified literal.
            let mut watch_list = std::mem::take(&mut self.watches[falsified.index()]);
            while i < watch_list.len() {
                let ci = watch_list[i];
                // Ensure the falsified literal is at position 1.
                {
                    let c = &mut self.clauses[ci as usize];
                    if c.len() > 1 && c[0] == falsified {
                        c.swap(0, 1);
                    }
                }
                let first = self.clauses[ci as usize][0];
                if self.value(first) == Assign::True {
                    i += 1;
                    continue; // clause already satisfied
                }
                // Look for a new literal to watch.
                let mut moved = false;
                let clen = self.clauses[ci as usize].len();
                for k in 2..clen {
                    let lk = self.clauses[ci as usize][k];
                    if self.value(lk) != Assign::False {
                        self.clauses[ci as usize].swap(1, k);
                        self.watches[lk.index()].push(ci);
                        // Remove from current list.
                        watch_list.swap_remove(i);
                        moved = true;
                        break;
                    }
                }
                if moved {
                    continue;
                }
                // Clause is unit or conflicting.
                if !self.enqueue(first, ci) {
                    // Conflict: restore remaining watches.
                    self.watches[falsified.index()].extend_from_slice(&watch_list);
                    return Some(ci);
                }
                i += 1;
            }
            self.watches[falsified.index()].extend_from_slice(&watch_list);
        }
        None
    }

    fn bump(&mut self, v: Var) {
        self.activity[v as usize] += self.act_inc;
        if self.activity[v as usize] > 1e100 {
            for a in &mut self.activity {
                *a *= 1e-100;
            }
            self.act_inc *= 1e-100;
        }
    }

    /// 1UIP conflict analysis (MiniSat-style). Returns (learnt clause,
    /// backjump level).
    fn analyze(&mut self, confl: u32) -> (Vec<Lit>, u32) {
        let cur_level = self.trail_lim.len() as u32;
        let mut learnt: Vec<Lit> = Vec::new();
        let mut seen = vec![false; self.nvars as usize];
        let mut counter = 0usize;
        let mut confl = confl;
        let mut idx = self.trail.len();
        let mut resolve_var: Option<Var> = None;
        let uip;

        loop {
            for q in self.clauses[confl as usize].clone() {
                // Skip the literal we are resolving on.
                if Some(q.var()) == resolve_var {
                    continue;
                }
                let v = q.var() as usize;
                if !seen[v] && self.level[v] > 0 {
                    seen[v] = true;
                    self.bump(q.var());
                    if self.level[v] >= cur_level {
                        counter += 1;
                    } else {
                        learnt.push(q);
                    }
                }
            }
            // Walk the trail backwards to the next seen literal.
            loop {
                idx -= 1;
                if seen[self.trail[idx].var() as usize] {
                    break;
                }
            }
            let l = self.trail[idx];
            seen[l.var() as usize] = false;
            counter -= 1;
            if counter == 0 {
                uip = !l;
                break;
            }
            // counter > 0 implies another current-level literal sits above
            // the decision, so l cannot be the decision: it has a reason.
            confl = self.reason[l.var() as usize];
            debug_assert!(confl != REASON_NONE && confl != REASON_ASSUMPTION);
            resolve_var = Some(l.var());
        }
        learnt.insert(0, uip);
        // Backjump level = max level among the non-UIP literals.
        let bj = learnt[1..]
            .iter()
            .map(|l| self.level[l.var() as usize])
            .max()
            .unwrap_or(0);
        (learnt, bj)
    }

    fn backtrack(&mut self, to_level: u32) {
        while self.trail_lim.len() as u32 > to_level {
            let lim = self.trail_lim.pop().unwrap();
            while self.trail.len() > lim {
                let l = self.trail.pop().unwrap();
                let v = l.var() as usize;
                self.assign[v] = Assign::Unset;
                self.reason[v] = REASON_NONE;
            }
        }
        self.prop_head = self.trail.len().min(self.prop_head);
        self.prop_head = self.trail.len();
    }

    fn decide(&mut self) -> Option<Lit> {
        let mut best: Option<(Var, f64)> = None;
        for v in 0..self.nvars {
            if self.assign[v as usize] == Assign::Unset {
                let a = self.activity[v as usize];
                if best.map(|(_, ba)| a > ba).unwrap_or(true) {
                    best = Some((v, a));
                }
            }
        }
        best.map(|(v, _)| {
            if self.phase[v as usize] {
                Lit::pos(v)
            } else {
                Lit::neg(v)
            }
        })
    }

    fn luby(i: u64) -> u64 {
        // Luby sequence (1-indexed): 1 1 2 1 1 2 4 1 1 2 1 1 2 4 8 ...
        let mut i = i + 1;
        loop {
            let mut k = 1u64;
            while (1u64 << k) - 1 < i {
                k += 1;
            }
            if (1u64 << k) - 1 == i {
                return 1u64 << (k - 1);
            }
            i -= (1u64 << (k - 1)) - 1;
        }
    }

    /// Solve without assumptions.
    pub fn solve(&mut self) -> SatResult {
        self.solve_with(&[])
    }

    /// Solve under `assumptions` (each forced true at level >= 1).
    pub fn solve_with(&mut self, assumptions: &[Lit]) -> SatResult {
        if self.trivially_unsat {
            return SatResult::Unsat;
        }
        self.backtrack(0);
        // Assert all unit input clauses at level 0.
        for (lit, ci) in self.units.clone() {
            if !self.enqueue(lit, ci) {
                return SatResult::Unsat;
            }
        }
        if self.propagate().is_some() {
            return SatResult::Unsat;
        }
        let mut restart_count = 0u64;
        let mut conflicts_until_restart = 100 * Self::luby(restart_count);

        loop {
            // (Re-)apply assumptions above the current level.
            while (self.trail_lim.len()) < assumptions.len() {
                let a = assumptions[self.trail_lim.len()];
                match self.value(a) {
                    Assign::True => {
                        // Already implied: open an empty decision level to
                        // keep the level <-> assumption indexing aligned.
                        self.trail_lim.push(self.trail.len());
                    }
                    Assign::False => return SatResult::Unsat,
                    Assign::Unset => {
                        self.trail_lim.push(self.trail.len());
                        self.enqueue(a, REASON_ASSUMPTION);
                    }
                }
                if let Some(confl) = self.propagate() {
                    // Conflict directly under assumptions.
                    let lvl = self.trail_lim.len() as u32;
                    if lvl <= assumptions.len() as u32 {
                        // Cannot learn past assumptions in this simple
                        // scheme: check whether the conflict is at level 0.
                        let all_assumed = self.clauses[confl as usize]
                            .iter()
                            .all(|l| self.level[l.var() as usize] <= assumptions.len() as u32);
                        let _ = all_assumed;
                        return SatResult::Unsat;
                    }
                }
            }

            match self.propagate() {
                Some(confl) => {
                    self.conflicts += 1;
                    let cur = self.trail_lim.len() as u32;
                    if cur == 0 {
                        return SatResult::Unsat;
                    }
                    if cur <= assumptions.len() as u32 {
                        return SatResult::Unsat;
                    }
                    let (learnt, bj) = self.analyze(confl);
                    let bj = bj.max(assumptions.len() as u32);
                    self.backtrack(bj);
                    let ci = self.clauses.len() as u32;
                    let unit = learnt[0];
                    // Install watches on the learnt clause.
                    self.watches[learnt[0].index()].push(ci);
                    if learnt.len() > 1 {
                        self.watches[learnt[1].index()].push(ci);
                    }
                    self.clauses.push(learnt);
                    if !self.enqueue(unit, ci) {
                        return SatResult::Unsat;
                    }
                    self.act_inc *= 1.05;
                    if self.conflicts % conflicts_until_restart == 0 {
                        restart_count += 1;
                        conflicts_until_restart = 100 * Self::luby(restart_count);
                        self.backtrack(assumptions.len() as u32);
                    }
                }
                None => match self.decide() {
                    Some(l) => {
                        self.trail_lim.push(self.trail.len());
                        self.enqueue(l, REASON_NONE);
                    }
                    None => {
                        let model: Vec<bool> = self
                            .assign
                            .iter()
                            .map(|a| *a == Assign::True)
                            .collect();
                        return SatResult::Sat(model);
                    }
                },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lit(v: i32) -> Lit {
        if v > 0 {
            Lit::pos((v - 1) as u32)
        } else {
            Lit::neg((-v - 1) as u32)
        }
    }

    fn solver_with(nvars: u32, clauses: &[&[i32]]) -> Solver {
        let mut s = Solver::new();
        for _ in 0..nvars {
            s.new_var();
        }
        for c in clauses {
            let ls: Vec<Lit> = c.iter().map(|&v| lit(v)).collect();
            s.add_clause(&ls);
        }
        s
    }

    #[test]
    fn trivial_sat() {
        let mut s = solver_with(2, &[&[1, 2], &[-1, 2]]);
        let r = s.solve();
        assert!(r.is_sat());
        let m = r.model().unwrap();
        assert!(m[1], "x2 must be true");
    }

    #[test]
    fn trivial_unsat() {
        let mut s = solver_with(1, &[&[1], &[-1]]);
        assert_eq!(s.solve(), SatResult::Unsat);
    }

    #[test]
    fn empty_clause_unsat() {
        let mut s = Solver::new();
        s.new_var();
        s.add_clause(&[]);
        assert_eq!(s.solve(), SatResult::Unsat);
    }

    #[test]
    fn tautology_removed() {
        let mut s = solver_with(1, &[&[1, -1]]);
        assert!(s.solve().is_sat());
    }

    #[test]
    fn chain_implications() {
        // x1 -> x2 -> x3 -> ... -> x10, x1 forced.
        let mut s = Solver::new();
        for _ in 0..10 {
            s.new_var();
        }
        s.add_clause(&[Lit::pos(0)]);
        for i in 0..9 {
            s.add_clause(&[Lit::neg(i), Lit::pos(i + 1)]);
        }
        let r = s.solve();
        let m = r.model().unwrap();
        assert!(m.iter().all(|&b| b));
    }

    #[test]
    fn pigeonhole_3_into_2_unsat() {
        // PHP(3,2): 3 pigeons, 2 holes. p_{i,j} = pigeon i in hole j.
        // var index = i*2 + j.
        let mut s = Solver::new();
        for _ in 0..6 {
            s.new_var();
        }
        for i in 0..3u32 {
            s.add_clause(&[Lit::pos(i * 2), Lit::pos(i * 2 + 1)]);
        }
        for j in 0..2u32 {
            for i1 in 0..3u32 {
                for i2 in (i1 + 1)..3u32 {
                    s.add_clause(&[Lit::neg(i1 * 2 + j), Lit::neg(i2 * 2 + j)]);
                }
            }
        }
        assert_eq!(s.solve(), SatResult::Unsat);
    }

    #[test]
    fn assumptions_flip_result() {
        let mut s = solver_with(2, &[&[1, 2]]);
        assert!(s.solve_with(&[lit(-1)]).is_sat());
        assert!(s.solve_with(&[lit(-1), lit(-2)]) == SatResult::Unsat);
        // Solver is reusable after an UNSAT-under-assumptions call.
        assert!(s.solve().is_sat());
    }

    #[test]
    fn model_satisfies_all_clauses_random() {
        // Randomized 3-SAT instances cross-checked by direct evaluation.
        let mut rng = crate::util::Rng::new(0xC0FFEE);
        for round in 0..30 {
            let nv = 8 + (round % 5);
            let nc = 20 + (round % 17);
            let mut clauses: Vec<Vec<i32>> = Vec::new();
            for _ in 0..nc {
                let mut c = Vec::new();
                for _ in 0..3 {
                    let v = rng.below(nv) as i32 + 1;
                    c.push(if rng.next_f64() < 0.5 { v } else { -v });
                }
                clauses.push(c);
            }
            let refs: Vec<&[i32]> = clauses.iter().map(|c| c.as_slice()).collect();
            let mut s = solver_with(nv as u32, &refs);
            // Brute-force reference.
            let mut brute_sat = false;
            'outer: for m in 0u32..(1 << nv) {
                for c in &clauses {
                    if !c.iter().any(|&l| {
                        let v = (l.unsigned_abs() - 1) as usize;
                        let val = (m >> v) & 1 == 1;
                        if l > 0 {
                            val
                        } else {
                            !val
                        }
                    }) {
                        continue 'outer;
                    }
                }
                brute_sat = true;
                break;
            }
            let r = s.solve();
            assert_eq!(r.is_sat(), brute_sat, "round {round}");
            if let SatResult::Sat(m) = r {
                for c in &clauses {
                    assert!(
                        c.iter().any(|&l| {
                            let v = (l.unsigned_abs() - 1) as usize;
                            if l > 0 {
                                m[v]
                            } else {
                                !m[v]
                            }
                        }),
                        "model must satisfy clause {c:?}"
                    );
                }
            }
        }
    }
}
