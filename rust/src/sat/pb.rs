//! Pseudo-boolean constraints `Σ wᵢ·xᵢ ≤ k` via the sequential weighted
//! counter encoding (Hölldobler/Manthey/Steinke). Used for the WPMaxSAT
//! cost bound and the Auto Distribution memory-capacity constraint.

use super::{Lit, Solver};

/// Encode `Σ wᵢ·lᵢ ≤ bound` into `solver`. `terms` are (literal, weight)
/// pairs with weights > 0. Auxiliary variables are allocated inside.
///
/// The sequential weighted counter builds s[i][j] = "after the first i
/// terms, the running sum is ≥ j" for j in 1..=bound+1, with the clause
/// `¬s[n][bound+1]` closing the constraint. To keep the encoding small
/// for large weights, weights are first divided by their GCD.
pub fn encode_pb_leq(solver: &mut Solver, terms: &[(Lit, u64)], bound: u64) {
    let terms: Vec<(Lit, u64)> = terms.iter().filter(|(_, w)| *w > 0).cloned().collect();
    if terms.is_empty() {
        return;
    }
    // Normalize by GCD.
    let g = terms.iter().fold(0u64, |g, &(_, w)| gcd(g, w)).max(1);
    let bound = bound / g;
    let terms: Vec<(Lit, u64)> = terms.iter().map(|&(l, w)| (l, w / g)).collect();

    // Terms whose weight alone exceeds the bound must be false.
    let mut active: Vec<(Lit, u64)> = Vec::new();
    for &(l, w) in &terms {
        if w > bound {
            solver.add_clause(&[!l]);
        } else {
            active.push((l, w));
        }
    }
    if active.is_empty() || bound == 0 {
        return;
    }
    let total: u64 = active.iter().map(|&(_, w)| w).sum();
    if total <= bound {
        return; // constraint is vacuous
    }

    let n = active.len();
    let k = bound as usize;
    // s[i][j], i in 0..n, j in 0..k  ("sum of first i+1 terms >= j+1").
    let mut s = vec![vec![None::<Lit>; k]; n];
    for (i, row) in s.iter_mut().enumerate() {
        for (j, slot) in row.iter_mut().enumerate() {
            // Registers only need to track up to min(prefix sum, k).
            let prefix: u64 = active[..=i].iter().map(|&(_, w)| w).sum();
            if (j as u64) < prefix.min(bound) {
                *slot = Some(Lit::pos(solver.new_var()));
            }
        }
    }
    let get = |s: &Vec<Vec<Option<Lit>>>, i: usize, j: i64| -> Option<Lit> {
        if j < 0 {
            None // trivially true level
        } else {
            s[i].get(j as usize).copied().flatten()
        }
    };

    for i in 0..n {
        let (xi, wi) = active[i];
        let wi = wi as i64;
        for j in 0..k as i64 {
            let sij = match get(&s, i, j) {
                Some(l) => l,
                None => continue,
            };
            // x_i ∧ (s[i-1][j-w] or j-w<0)  ->  s[i][j]
            if i == 0 {
                if j < wi {
                    solver.add_clause(&[!xi, sij]);
                }
            } else {
                // carry: s[i-1][j] -> s[i][j]
                if let Some(prev) = get(&s, i - 1, j) {
                    solver.add_clause(&[!prev, sij]);
                }
                // add: x_i ∧ s[i-1][j-wi] -> s[i][j]
                if j - wi < 0 {
                    solver.add_clause(&[!xi, sij]);
                } else if let Some(prev) = get(&s, i - 1, j - wi) {
                    solver.add_clause(&[!xi, !prev, sij]);
                }
            }
        }
        // Overflow: x_i ∧ s[i-1][k-wi] -> ⊥  (sum would exceed bound)
        if i > 0 {
            let jo = k as i64 - wi;
            if jo < 0 {
                // handled above (w > bound filtered), unreachable
            } else if let Some(prev) = get(&s, i - 1, jo) {
                solver.add_clause(&[!xi, !prev]);
            }
        }
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sat::SatResult;

    /// Exhaustively check the encoding: every model of the encoded
    /// formula satisfies the PB constraint and every assignment
    /// satisfying the constraint extends to a model.
    fn check_pb(weights: &[u64], bound: u64) {
        let n = weights.len();
        for forced in 0u32..(1 << n) {
            let mut solver = Solver::new();
            let vars: Vec<_> = (0..n).map(|_| solver.new_var()).collect();
            let terms: Vec<(Lit, u64)> =
                vars.iter().zip(weights).map(|(&v, &w)| (Lit::pos(v), w)).collect();
            encode_pb_leq(&mut solver, &terms, bound);
            // Force the assignment.
            for (i, &v) in vars.iter().enumerate() {
                if (forced >> i) & 1 == 1 {
                    solver.add_clause(&[Lit::pos(v)]);
                } else {
                    solver.add_clause(&[Lit::neg(v)]);
                }
            }
            let sum: u64 = weights
                .iter()
                .enumerate()
                .filter(|(i, _)| (forced >> i) & 1 == 1)
                .map(|(_, &w)| w)
                .sum();
            let expect_sat = sum <= bound;
            let got = solver.solve();
            assert_eq!(
                got.is_sat(),
                expect_sat,
                "weights={weights:?} bound={bound} forced={forced:b} sum={sum}"
            );
            if let SatResult::Sat(_) = got {
                assert!(sum <= bound);
            }
        }
    }

    #[test]
    fn unit_weights_cardinality() {
        check_pb(&[1, 1, 1, 1], 2);
    }

    #[test]
    fn mixed_weights() {
        check_pb(&[3, 5, 7, 2], 9);
        check_pb(&[1, 2, 4, 8], 7);
    }

    #[test]
    fn gcd_normalization() {
        check_pb(&[10, 20, 30], 30);
    }

    #[test]
    fn zero_bound_forces_all_false() {
        check_pb(&[2, 3], 0);
    }

    #[test]
    fn vacuous_constraint() {
        check_pb(&[1, 1], 10);
    }

    #[test]
    fn single_huge_weight() {
        check_pb(&[100, 1], 1);
    }
}
