//! The executable plan: a flat list of μkernel steps with resolved
//! buffer bindings. Consumed by the performance simulator (every step
//! carries its FLOP/byte footprint) and by the C++ emitter.

use std::collections::HashMap;

use super::{bufferize, plan_memory, BufferId, BufferTable, Liveness, MemPlan, PlannerKind};
use crate::ir::{Graph, NodeId, Op, TensorType};

/// One executable step.
#[derive(Debug, Clone)]
pub struct Step {
    pub node: NodeId,
    pub op: Op,
    pub inputs: Vec<BufferId>,
    pub output: BufferId,
    pub out_ty: TensorType,
    pub flops: u64,
    pub bytes: u64,
}

/// A lowered module: steps + buffer table + memory plan.
#[derive(Debug)]
pub struct ExecPlan {
    pub steps: Vec<Step>,
    pub bufs: BufferTable,
    pub mem: MemPlan,
    /// Weight bytes (const buffers, pre-pinned per §3.3.1).
    pub const_bytes: u64,
}

impl ExecPlan {
    pub fn total_flops(&self) -> u64 {
        self.steps.iter().map(|s| s.flops).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.bytes).sum()
    }

    /// Human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{} steps, {:.2} MFLOP, {} traffic, arena {}, weights {}",
            self.steps.len(),
            self.total_flops() as f64 / 1e6,
            crate::util::human_bytes(self.total_bytes() as usize),
            crate::util::human_bytes(self.mem.arena_bytes),
            crate::util::human_bytes(self.const_bytes as usize),
        )
    }
}

/// Lower a graph to an [`ExecPlan`]: bufferize, liveness, memory plan,
/// then emit one step per non-leaf non-view node.
pub fn lower_to_plan(g: &Graph, planner: PlannerKind) -> ExecPlan {
    let bufs = bufferize(g);
    let live = Liveness::compute(g, &bufs);
    let mem = plan_memory(&bufs, &live, planner);
    let mut steps = Vec::new();
    for id in g.live_nodes() {
        let node = g.node(id);
        if node.op.is_leaf() || node.op.is_view() {
            continue;
        }
        let in_tys: Vec<&TensorType> =
            node.inputs.iter().map(|&i| &g.node(i).ty).collect();
        steps.push(Step {
            node: id,
            op: node.op.clone(),
            inputs: node.inputs.iter().map(|&i| bufs.of_node[&i]).collect(),
            output: bufs.of_node[&id],
            out_ty: node.ty.clone(),
            flops: crate::cost::op_flops(&node.op, &in_tys, &node.ty),
            bytes: crate::cost::op_bytes(&node.op, &in_tys, &node.ty),
        });
    }
    let const_bytes = bufs
        .sizes
        .iter()
        .zip(&bufs.is_const)
        .filter(|(_, &c)| c)
        .map(|(&s, _)| s as u64)
        .sum();
    ExecPlan { steps, bufs, mem, const_bytes }
}

/// Map each step's output to its arena offset (None for I/O and consts).
pub fn step_offsets(plan: &ExecPlan) -> HashMap<NodeId, Option<usize>> {
    plan.steps
        .iter()
        .map(|s| (s.node, plan.mem.offsets.get(&s.output).copied()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, Graph, UnaryKind};
    use crate::model::{decode_graph, Qwen3Config};

    #[test]
    fn plan_covers_all_compute_nodes() {
        let mut g = Graph::new();
        let a = g.input("a", &[8, 8], DType::F32);
        let w = g.constant("w", &[8, 8], DType::F32);
        let m = g.matmul(a, w);
        let e = g.unary(UnaryKind::Exp, m);
        let r = g.reshape(e, &[64]);
        g.mark_output(r);
        let plan = lower_to_plan(&g, PlannerKind::FirstFit);
        assert_eq!(plan.steps.len(), 2, "matmul + exp (reshape is a view)");
        assert_eq!(plan.const_bytes, 8 * 8 * 4);
        assert!(plan.total_flops() > 0);
    }

    #[test]
    fn decode_step_plan_scales_with_model() {
        let tiny = decode_graph(&Qwen3Config::tiny(), 7, None);
        let plan = lower_to_plan(&tiny, PlannerKind::FirstFit);
        // Per layer: 8 matmuls + 2 norms + rope x2 + softmax + residuals...
        assert!(plan.steps.len() > 4 * 10);
        // Weight bytes close to config estimate (graph excludes embedding).
        let cfg = Qwen3Config::tiny();
        let expected = cfg.weight_bytes()
            - (cfg.vocab * cfg.hidden * cfg.dtype.size_bytes()) as u64; // embedding outside
        let got = plan.const_bytes;
        let ratio = got as f64 / expected as f64;
        assert!(
            (0.8..1.2).contains(&ratio),
            "plan const bytes {got} vs expected {expected}"
        );
    }

    #[test]
    fn arena_much_smaller_than_total_intermediates() {
        let g = decode_graph(&Qwen3Config::tiny(), 3, None);
        let plan = lower_to_plan(&g, PlannerKind::FirstFit);
        let total: usize = plan
            .bufs
            .intermediates()
            .iter()
            .map(|b| plan.bufs.sizes[b.0 as usize])
            .sum();
        assert!(
            plan.mem.arena_bytes * 3 < total,
            "liveness reuse should shrink the arena: {} vs {total}",
            plan.mem.arena_bytes
        );
    }
}
