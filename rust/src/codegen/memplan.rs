//! Memory planning (§3.3.1): assign arena offsets to intermediate
//! buffers, maximizing reuse by overlapping buffers that are never live
//! simultaneously. Modeled as bin packing / 2-D strip packing:
//!
//! * [`PlannerKind::FirstFit`] — first-fit-decreasing over the interval
//!   conflict graph (fast, the production default — and the bump-
//!   allocator ablation baseline lives here too).
//! * [`PlannerKind::SatOptimal`] — for small instances, binary-search the
//!   arena size with a SAT feasibility probe over discretized offset
//!   slots (the paper's "SAT solver … optimal arrangement").

use std::collections::HashMap;

use super::{BufferId, BufferTable, Liveness};
use crate::sat::{Lit, SatResult, Solver};

/// Planner selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerKind {
    /// No reuse at all: every buffer gets fresh space (ablation baseline).
    Bump,
    /// First-fit decreasing with lifetime-overlap constraints.
    FirstFit,
    /// SAT-optimal (falls back to FirstFit above `max_sat_buffers`).
    SatOptimal,
}

/// The memory plan.
#[derive(Debug)]
pub struct MemPlan {
    /// Arena offsets for intermediate buffers.
    pub offsets: HashMap<BufferId, usize>,
    /// Total arena size in bytes.
    pub arena_bytes: usize,
    /// Which planner produced it.
    pub kind: PlannerKind,
}

const ALIGN: usize = 64;
const MAX_SAT_BUFFERS: usize = 14;

fn align_up(x: usize) -> usize {
    x.div_ceil(ALIGN) * ALIGN
}

/// Plan arena offsets for the intermediates of `bufs`.
pub fn plan_memory(bufs: &BufferTable, live: &Liveness, kind: PlannerKind) -> MemPlan {
    let inter = bufs.intermediates();
    match kind {
        PlannerKind::Bump => {
            let mut offsets = HashMap::new();
            let mut cur = 0usize;
            for b in inter {
                offsets.insert(b, cur);
                cur += align_up(bufs.sizes[b.0 as usize]);
            }
            MemPlan { offsets, arena_bytes: cur, kind }
        }
        PlannerKind::FirstFit => first_fit(bufs, live, &inter),
        PlannerKind::SatOptimal => {
            let ff = first_fit(bufs, live, &inter);
            if inter.len() > MAX_SAT_BUFFERS {
                return ff;
            }
            sat_refine(bufs, live, &inter, ff)
        }
    }
}

/// First-fit decreasing: place big buffers first at the lowest offset
/// that does not collide with an already-placed, lifetime-overlapping
/// buffer.
fn first_fit(bufs: &BufferTable, live: &Liveness, inter: &[BufferId]) -> MemPlan {
    let mut order: Vec<BufferId> = inter.to_vec();
    order.sort_by_key(|b| std::cmp::Reverse(bufs.sizes[b.0 as usize]));
    let mut placed: Vec<(BufferId, usize, usize)> = Vec::new(); // (buf, off, size)
    let mut offsets = HashMap::new();
    let mut arena = 0usize;
    for &b in &order {
        let size = align_up(bufs.sizes[b.0 as usize]).max(ALIGN);
        // Collect forbidden intervals from overlapping-lifetime buffers.
        let mut blocked: Vec<(usize, usize)> = placed
            .iter()
            .filter(|(o, _, _)| live.overlap(b, *o))
            .map(|&(_, off, sz)| (off, off + sz))
            .collect();
        blocked.sort();
        let mut cand = 0usize;
        for &(s, e) in &blocked {
            if cand + size <= s {
                break;
            }
            cand = cand.max(e);
        }
        offsets.insert(b, cand);
        placed.push((b, cand, size));
        arena = arena.max(cand + size);
    }
    MemPlan { offsets, arena_bytes: arena, kind: PlannerKind::FirstFit }
}

/// Binary-search the arena size with SAT feasibility probes. Offsets are
/// discretized to `gran`-sized slots; buffers occupy contiguous slot
/// ranges and lifetime-overlapping buffers must not share slots.
fn sat_refine(
    bufs: &BufferTable,
    live: &Liveness,
    inter: &[BufferId],
    ff: MemPlan,
) -> MemPlan {
    if inter.is_empty() {
        return MemPlan { kind: PlannerKind::SatOptimal, ..ff };
    }
    let gran = inter
        .iter()
        .map(|b| align_up(bufs.sizes[b.0 as usize]).max(ALIGN))
        .min()
        .unwrap_or(ALIGN);
    let lower = inter
        .iter()
        .map(|b| align_up(bufs.sizes[b.0 as usize]))
        .max()
        .unwrap_or(0);
    let mut best = ff;
    let mut lo = lower.div_ceil(gran);
    let mut hi = best.arena_bytes.div_ceil(gran);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        match sat_feasible(bufs, live, inter, mid, gran) {
            Some(offsets) => {
                let arena = offsets
                    .iter()
                    .map(|(b, &o)| o + align_up(bufs.sizes[b.0 as usize]))
                    .max()
                    .unwrap_or(0);
                if arena <= best.arena_bytes {
                    best =
                        MemPlan { offsets, arena_bytes: arena, kind: PlannerKind::SatOptimal };
                }
                hi = mid;
            }
            None => lo = mid + 1,
        }
    }
    best.kind = PlannerKind::SatOptimal;
    best
}

/// SAT probe: can all buffers be placed within `slots * gran` bytes?
fn sat_feasible(
    bufs: &BufferTable,
    live: &Liveness,
    inter: &[BufferId],
    slots: usize,
    gran: usize,
) -> Option<HashMap<BufferId, usize>> {
    let mut solver = Solver::new();
    // pos[b][s]: buffer b starts at slot s.
    let nslots = |b: BufferId| align_up(bufs.sizes[b.0 as usize]).div_ceil(gran);
    let mut pos: HashMap<(usize, usize), u32> = HashMap::new();
    for (bi, &b) in inter.iter().enumerate() {
        let need = nslots(b);
        if need > slots {
            return None;
        }
        let starts: Vec<u32> =
            (0..=(slots - need)).map(|s| {
                let v = solver.new_var();
                pos.insert((bi, s), v);
                v
            }).collect();
        // Exactly one start.
        let lits: Vec<Lit> = starts.iter().map(|&v| Lit::pos(v)).collect();
        solver.add_clause(&lits);
        for i in 0..starts.len() {
            for j in (i + 1)..starts.len() {
                solver.add_clause(&[Lit::neg(starts[i]), Lit::neg(starts[j])]);
            }
        }
    }
    // Non-overlap for lifetime-conflicting pairs.
    for (bi, &b1) in inter.iter().enumerate() {
        for (bj, &b2) in inter.iter().enumerate().skip(bi + 1) {
            if !live.overlap(b1, b2) {
                continue;
            }
            let (n1, n2) = (nslots(b1), nslots(b2));
            for s1 in 0..=(slots.saturating_sub(n1)) {
                for s2 in 0..=(slots.saturating_sub(n2)) {
                    let disjoint = s1 + n1 <= s2 || s2 + n2 <= s1;
                    if !disjoint {
                        if let (Some(&v1), Some(&v2)) =
                            (pos.get(&(bi, s1)), pos.get(&(bj, s2)))
                        {
                            solver.add_clause(&[Lit::neg(v1), Lit::neg(v2)]);
                        }
                    }
                }
            }
        }
    }
    match solver.solve() {
        SatResult::Sat(model) => {
            let mut offsets = HashMap::new();
            for (bi, &b) in inter.iter().enumerate() {
                for s in 0..slots {
                    if let Some(&v) = pos.get(&(bi, s)) {
                        if model[v as usize] {
                            offsets.insert(b, s * gran);
                            break;
                        }
                    }
                }
            }
            Some(offsets)
        }
        SatResult::Unsat => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::bufferize;
    use crate::ir::{DType, Graph, UnaryKind};

    fn chain_graph(n: usize) -> Graph {
        let mut g = Graph::new();
        let mut x = g.input("a", &[1024], DType::F32);
        for _ in 0..n {
            x = g.unary(UnaryKind::Exp, x);
        }
        g.mark_output(x);
        g
    }

    #[test]
    fn firstfit_reuses_dead_buffers() {
        // In a chain, only two intermediates are ever live at once.
        let g = chain_graph(8);
        let bufs = bufferize(&g);
        let live = Liveness::compute(&g, &bufs);
        let bump = plan_memory(&bufs, &live, PlannerKind::Bump);
        let ff = plan_memory(&bufs, &live, PlannerKind::FirstFit);
        assert!(
            ff.arena_bytes <= 2 * 4096 + 128,
            "chain needs ~2 slots, got {}",
            ff.arena_bytes
        );
        assert!(ff.arena_bytes < bump.arena_bytes, "reuse must beat bump");
    }

    #[test]
    fn no_overlapping_live_buffers_share_memory() {
        let g = chain_graph(6);
        let bufs = bufferize(&g);
        let live = Liveness::compute(&g, &bufs);
        for kind in [PlannerKind::FirstFit, PlannerKind::SatOptimal] {
            let plan = plan_memory(&bufs, &live, kind);
            let inter = bufs.intermediates();
            for (i, &a) in inter.iter().enumerate() {
                for &b in inter.iter().skip(i + 1) {
                    if live.overlap(a, b) {
                        let (oa, ob) = (plan.offsets[&a], plan.offsets[&b]);
                        let (sa, sb) =
                            (bufs.sizes[a.0 as usize], bufs.sizes[b.0 as usize]);
                        assert!(
                            oa + sa <= ob || ob + sb <= oa,
                            "{kind:?}: live-overlapping buffers collide"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sat_no_worse_than_firstfit() {
        let g = chain_graph(5);
        let bufs = bufferize(&g);
        let live = Liveness::compute(&g, &bufs);
        let ff = plan_memory(&bufs, &live, PlannerKind::FirstFit);
        let sat = plan_memory(&bufs, &live, PlannerKind::SatOptimal);
        assert!(sat.arena_bytes <= ff.arena_bytes);
    }

    #[test]
    fn empty_graph_plans_empty() {
        let mut g = Graph::new();
        let a = g.input("a", &[4], DType::F32);
        g.mark_output(a);
        let bufs = bufferize(&g);
        let live = Liveness::compute(&g, &bufs);
        let plan = plan_memory(&bufs, &live, PlannerKind::SatOptimal);
        assert_eq!(plan.arena_bytes, 0);
    }
}
