//! Code generation (§3.3): buffer scheduling and kernel instantiation.
//!
//! * [`bufferize`] — logical-to-physical mapping with **alias analysis**:
//!   view ops (Reshape/Slice) share their input's storage (zero-copy).
//! * [`liveness`] — per-buffer live intervals over the topological
//!   schedule.
//! * [`memplan`] — address assignment. Overlapping-lifetime buffers must
//!   not overlap in memory; the planner minimizes the arena size (a bin
//!   packing problem): first-fit-decreasing heuristic always, plus a
//!   SAT-based optimality refinement for small instances (§3.3.1).
//! * [`plan`] — the executable [`ExecPlan`]: a flat step list binding
//!   μkernels to buffer offsets.
//! * [`ntt_emit`] — NTT-style C++ source emission (Fig. 8), showing the
//!   kernel the real nncase would hand to GCC/Clang.

mod bufferize;
mod memplan;
mod ntt_emit;
mod plan;

pub use bufferize::{bufferize, BufferId, BufferTable, Liveness};
pub use memplan::{plan_memory, MemPlan, PlannerKind};
pub use ntt_emit::emit_ntt_cpp;
pub use plan::{lower_to_plan, step_offsets, ExecPlan, Step};
