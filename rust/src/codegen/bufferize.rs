//! Bufferization, alias analysis and liveness (§3.3.1).

use std::collections::HashMap;

use crate::ir::{Graph, NodeId};

/// Physical buffer id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BufferId(pub u32);

/// The logical-to-physical mapping produced by bufferization.
#[derive(Debug)]
pub struct BufferTable {
    /// node -> physical buffer (views alias their producer's buffer).
    pub of_node: HashMap<NodeId, BufferId>,
    /// buffer -> size in bytes.
    pub sizes: Vec<usize>,
    /// buffer -> true if it is a weight/constant (pre-allocated, pinned).
    pub is_const: Vec<bool>,
    /// buffer -> true if graph input/output (externally owned).
    pub is_io: Vec<bool>,
}

impl BufferTable {
    pub fn len(&self) -> usize {
        self.sizes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sizes.is_empty()
    }

    /// Intermediate (plannable) buffers: not const, not I/O.
    pub fn intermediates(&self) -> Vec<BufferId> {
        (0..self.sizes.len() as u32)
            .map(BufferId)
            .filter(|b| !self.is_const[b.0 as usize] && !self.is_io[b.0 as usize])
            .collect()
    }
}

/// Assign physical buffers to every live node. View ops (Reshape, Slice)
/// are marked as aliases of their input — *zero-copy* shape
/// transformations.
pub fn bufferize(g: &Graph) -> BufferTable {
    let mut of_node: HashMap<NodeId, BufferId> = HashMap::new();
    let mut sizes = Vec::new();
    let mut is_const = Vec::new();
    let mut is_io = Vec::new();
    for id in g.live_nodes() {
        let node = g.node(id);
        if node.op.is_view() {
            // Alias: share the producer's buffer.
            let src = of_node[&node.inputs[0]];
            of_node.insert(id, src);
            // A view marked as output promotes its storage to I/O.
            if g.outputs.contains(&id) {
                is_io[src.0 as usize] = true;
            }
            continue;
        }
        let b = BufferId(sizes.len() as u32);
        sizes.push(node.ty.size_bytes());
        is_const.push(matches!(node.op, crate::ir::Op::Const(_) | crate::ir::Op::Scalar(_)));
        is_io.push(
            matches!(node.op, crate::ir::Op::Input(_)) || g.outputs.contains(&id),
        );
        of_node.insert(id, b);
    }
    BufferTable { of_node, sizes, is_const, is_io }
}

/// Live interval per buffer over the topological schedule: `[def, last_use]`.
#[derive(Debug)]
pub struct Liveness {
    /// buffer -> (first def position, last use position).
    pub interval: HashMap<BufferId, (usize, usize)>,
}

impl Liveness {
    /// True if two buffers' lifetimes overlap.
    pub fn overlap(&self, a: BufferId, b: BufferId) -> bool {
        match (self.interval.get(&a), self.interval.get(&b)) {
            (Some(&(s1, e1)), Some(&(s2, e2))) => s1 <= e2 && s2 <= e1,
            _ => false,
        }
    }

    /// Compute liveness for `g` under its topological node order.
    pub fn compute(g: &Graph, bufs: &BufferTable) -> Liveness {
        let order = g.live_nodes();
        let pos: HashMap<NodeId, usize> =
            order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
        let mut interval: HashMap<BufferId, (usize, usize)> = HashMap::new();
        for (&node, &buf) in &bufs.of_node {
            let p = pos[&node];
            let e = interval.entry(buf).or_insert((p, p));
            e.0 = e.0.min(p);
            e.1 = e.1.max(p);
        }
        // Extend to last use by consumers.
        for &id in &order {
            let p = pos[&id];
            for &inp in &g.node(id).inputs {
                if let Some(&b) = bufs.of_node.get(&inp) {
                    let e = interval.get_mut(&b).unwrap();
                    e.1 = e.1.max(p);
                }
            }
        }
        // Outputs live to the end.
        for &o in &g.outputs {
            if let Some(&b) = bufs.of_node.get(&o) {
                interval.get_mut(&b).unwrap().1 = order.len();
            }
        }
        Liveness { interval }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, Graph, UnaryKind};

    #[test]
    fn views_alias_zero_copy() {
        let mut g = Graph::new();
        let a = g.input("a", &[4, 6], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        let r = g.reshape(e, &[24]);
        let r2 = g.reshape(r, &[2, 12]);
        let n = g.unary(UnaryKind::Neg, r2);
        g.mark_output(n);
        let bufs = bufferize(&g);
        assert_eq!(bufs.of_node[&r], bufs.of_node[&e], "reshape aliases");
        assert_eq!(bufs.of_node[&r2], bufs.of_node[&e], "reshape chain aliases");
        assert_ne!(bufs.of_node[&n], bufs.of_node[&e]);
        // 3 buffers total: a, e, n.
        assert_eq!(bufs.len(), 3);
    }

    #[test]
    fn output_view_promotes_io() {
        let mut g = Graph::new();
        let a = g.input("a", &[4], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        let r = g.reshape(e, &[2, 2]);
        g.mark_output(r);
        let bufs = bufferize(&g);
        let b = bufs.of_node[&e];
        assert!(bufs.is_io[b.0 as usize], "aliased output storage must be IO");
    }

    #[test]
    fn liveness_intervals_and_overlap() {
        let mut g = Graph::new();
        let a = g.input("a", &[8], DType::F32);
        let e1 = g.unary(UnaryKind::Exp, a); // dies after e2
        let e2 = g.unary(UnaryKind::Neg, e1);
        let e3 = g.unary(UnaryKind::Sqrt, e2);
        g.mark_output(e3);
        let bufs = bufferize(&g);
        let live = Liveness::compute(&g, &bufs);
        let (b1, b2, b3) = (bufs.of_node[&e1], bufs.of_node[&e2], bufs.of_node[&e3]);
        assert!(live.overlap(b1, b2), "producer overlaps its consumer");
        assert!(
            !live.overlap(b1, b3),
            "e1 is dead before e3 is written: intervals {:?} {:?}",
            live.interval[&b1],
            live.interval[&b3]
        );
    }

    #[test]
    fn intermediates_exclude_io_and_const() {
        let mut g = Graph::new();
        let a = g.input("a", &[8], DType::F32);
        let w = g.constant("w", &[8], DType::F32);
        let s = g.binary(crate::ir::BinaryKind::Add, a, w);
        let t = g.unary(UnaryKind::Exp, s);
        g.mark_output(t);
        let bufs = bufferize(&g);
        let inter = bufs.intermediates();
        assert_eq!(inter.len(), 1, "only s is an intermediate");
        assert_eq!(inter[0], bufs.of_node[&s]);
    }
}
