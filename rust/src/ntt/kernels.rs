//! Register-blocked μkernels (the NTT primitive set).
//!
//! The matmul follows the GotoBLAS decomposition the paper's packing
//! story builds on: pack A into row-major MR-blocked panels, B into
//! column-major NR-blocked panels, then drive an MR×NR register μkernel
//! over K. `MR = 4, NR = 16` keeps the accumulator tile (4×16 f32 = two
//! AVX2 registers per row) inside the 16 ymm registers; the inner loops
//! are written so LLVM auto-vectorizes them to FMA sequences.

use super::Tensor;

/// Register tile rows of the matmul μkernel.
pub const MR: usize = 4;
/// Register tile columns (two AVX2 f32 vectors).
pub const NR: usize = 16;

/// `C[m,n] = A[m,k] @ B[k,n]` — naive triple loop (correctness oracle
/// and the "no packing" baseline the MLC/generic path models).
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (kb, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb);
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for p in 0..k {
            let av = a.data[i * k + p];
            let brow = &b.data[p * n..(p + 1) * n];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Pack `rows x cols` of A (row-major) into MR-row panels.
pub fn pack_a(a: &[f32], m: usize, k: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(m.div_ceil(MR) * MR * k);
    for ib in (0..m).step_by(MR) {
        for p in 0..k {
            for i in ib..(ib + MR) {
                out.push(if i < m { a[i * k + p] } else { 0.0 });
            }
        }
    }
}

/// Pack B (k x n row-major) into NR-column panels.
pub fn pack_b(b: &[f32], k: usize, n: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(n.div_ceil(NR) * NR * k);
    for jb in (0..n).step_by(NR) {
        for p in 0..k {
            for j in jb..(jb + NR) {
                out.push(if j < n { b[p * n + j] } else { 0.0 });
            }
        }
    }
}

/// MR×NR register μkernel: C_tile += A_panel × B_panel over `k`.
///
/// Fixed-size row views (`&[f32; MR]` / `&[f32; NR]`) eliminate bounds
/// checks in the inner loop so LLVM lowers it to unrolled FMA vector ops
/// (§Perf L3: +2.3x over the slice version).
#[inline]
fn ukernel(apan: &[f32], bpan: &[f32], k: usize, c: &mut [f32; MR * NR]) {
    for p in 0..k {
        let arow: &[f32; MR] = apan[p * MR..p * MR + MR].try_into().unwrap();
        let brow: &[f32; NR] = bpan[p * NR..p * NR + NR].try_into().unwrap();
        for i in 0..MR {
            let av = arow[i];
            let base = i * NR;
            for j in 0..NR {
                c[base + j] += av * brow[j];
            }
        }
    }
}

/// Blocked matmul over pre-packed panels, writing rows `[row_lo, row_hi)`
/// of C. `row_lo`/`row_hi` let the coordinator statically partition the M
/// dimension across cores ("cores as distributed nodes", §4.2).
#[allow(clippy::too_many_arguments)]
pub fn matmul_packed_range(
    apacked: &[f32],
    bpacked: &[f32],
    m: usize,
    k: usize,
    n: usize,
    row_lo: usize,
    row_hi: usize,
    c: &mut [f32],
) {
    let mut acc = [0.0f32; MR * NR];
    let mb0 = row_lo / MR;
    let mb1 = row_hi.div_ceil(MR);
    for ib in mb0..mb1 {
        let apan = &apacked[ib * MR * k..(ib + 1) * MR * k];
        for jb in 0..n.div_ceil(NR) {
            let bpan = &bpacked[jb * NR * k..(jb + 1) * NR * k];
            acc.fill(0.0);
            ukernel(apan, bpan, k, &mut acc);
            // Write back the tile (bounds-clipped).
            for i in 0..MR {
                let row = ib * MR + i;
                if row < row_lo || row >= row_hi || row >= m {
                    continue;
                }
                for j in 0..NR {
                    let col = jb * NR + j;
                    if col < n {
                        c[row * n + col] = acc[i * NR + j];
                    }
                }
            }
        }
    }
}

/// `C = A @ B` with packing (single-threaded convenience wrapper).
pub fn matmul_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (kb, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb);
    let mut ap = Vec::new();
    let mut bp = Vec::new();
    pack_a(&a.data, m, k, &mut ap);
    pack_b(&b.data, k, n, &mut bp);
    let mut c = Tensor::zeros(&[m, n]);
    matmul_packed_range(&ap, &bp, m, k, n, 0, m, &mut c.data);
    c
}

/// `y = x @ W` where `W` is [k, n] and x is a single row — the decode
/// hot path (GEMV). Walks W row-wise so the weight stream is sequential
/// (memory-bandwidth optimal, which is what decode throughput is bound
/// by, §4).
pub fn gemv(x: &[f32], w: &Tensor, y: &mut [f32]) {
    let (k, n) = (w.dim(0), w.dim(1));
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), n);
    y.fill(0.0);
    for p in 0..k {
        let xv = x[p];
        if xv == 0.0 {
            continue;
        }
        let wrow = &w.data[p * n..(p + 1) * n];
        for j in 0..n {
            y[j] += xv * wrow[j];
        }
    }
}

/// `gemv` over a column range `[lo, hi)` of W — the static column
/// partition used by tensor-parallel decode.
pub fn gemv_cols(x: &[f32], w: &Tensor, lo: usize, hi: usize, y: &mut [f32]) {
    let (k, n) = (w.dim(0), w.dim(1));
    assert_eq!(y.len(), hi - lo);
    y.fill(0.0);
    for p in 0..k {
        let xv = x[p];
        let wrow = &w.data[p * n + lo..p * n + hi];
        for (yj, wj) in y.iter_mut().zip(wrow) {
            *yj += xv * wj;
        }
    }
}

/// Dot product with a sequential accumulation order (the order every
/// attention path in the repo shares, so paged and dense attention are
/// bit-identical).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// A weight matrix pre-packed into NR-column panels, for GEMMs where the
/// same `W` is streamed every decode step (continuous batching: pack
/// once at engine build, then each batched step reads the panels exactly
/// once instead of once per sequence — the weight-stream saving that
/// makes iteration-level batching pay on memory-bound decode).
#[derive(Debug, Clone)]
pub struct PackedMat {
    pub k: usize,
    pub n: usize,
    panels: Vec<f32>,
}

impl PackedMat {
    /// Pack a `[k, n]` weight tensor.
    pub fn pack(w: &Tensor) -> Self {
        let (k, n) = (w.dim(0), w.dim(1));
        let mut panels = Vec::new();
        pack_b(&w.data, k, n, &mut panels);
        PackedMat { k, n, panels }
    }

    pub fn bytes(&self) -> usize {
        self.panels.len() * 4
    }
}

/// `C[rows, n] = X[rows, k] @ W` over a pre-packed `W`. Per-element
/// accumulation runs over `k` in ascending order, matching [`gemv`] /
/// [`gemv_cols`], so batched and per-sequence decode agree bitwise.
pub fn matmul_prepacked(x: &[f32], rows: usize, w: &PackedMat, c: &mut [f32]) {
    let mut scratch = Vec::new();
    matmul_prepacked_into(x, rows, w, c, &mut scratch);
}

/// [`matmul_prepacked`] with a caller-owned A-pack scratch buffer, for
/// hot loops (the batched decode path calls this 7 times per layer per
/// iteration — re-allocating the pack buffer each time is pure
/// overhead).
pub fn matmul_prepacked_into(
    x: &[f32],
    rows: usize,
    w: &PackedMat,
    c: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    matmul_prepacked_rows(x, rows, w, 0, rows, c, scratch);
}

/// Rows `[row_lo, row_hi)` of `C = X @ W` over a pre-packed `W`, written
/// into `c_rows` (length `(row_hi - row_lo) * w.n`, i.e. the caller's
/// own disjoint slice of C) — the static M-partition of the SPMD batched
/// decode path: each worker packs and computes only its own MR-row
/// panels, so no shared A-pack pass (and no extra barrier) is needed.
///
/// `row_lo` must be MR-aligned (use [`crate::parallel::panel_splits`]);
/// `row_hi` is either MR-aligned or equal to `rows`. Per-element
/// arithmetic is the register μkernel over ascending `k`, bit-identical
/// to [`matmul_prepacked`] for the covered rows at any partitioning.
pub fn matmul_prepacked_rows(
    x: &[f32],
    rows: usize,
    w: &PackedMat,
    row_lo: usize,
    row_hi: usize,
    c_rows: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let (k, n) = (w.k, w.n);
    assert!(row_lo <= row_hi && row_hi <= rows, "bad row range");
    assert_eq!(x.len(), rows * k, "X shape mismatch");
    assert_eq!(c_rows.len(), (row_hi - row_lo) * n, "C shape mismatch");
    if row_lo == row_hi {
        // Empty shard (oversubscribed partition): nothing to compute —
        // and `row_lo` need not be aligned in this case.
        return;
    }
    assert_eq!(row_lo % MR, 0, "row_lo must be MR-aligned");
    // Pack this shard's rows into MR-row panels: same layout and zero
    // padding as the matching slice of `pack_a`'s output.
    let panels = (row_hi - row_lo).div_ceil(MR);
    scratch.clear();
    scratch.reserve(panels * MR * k);
    for ib in 0..panels {
        for p in 0..k {
            for i in 0..MR {
                let row = row_lo + ib * MR + i;
                scratch.push(if row < rows { x[row * k + p] } else { 0.0 });
            }
        }
    }
    let mut acc = [0.0f32; MR * NR];
    for ib in 0..panels {
        let apan = &scratch[ib * MR * k..(ib + 1) * MR * k];
        for jb in 0..n.div_ceil(NR) {
            let bpan = &w.panels[jb * NR * k..(jb + 1) * NR * k];
            acc.fill(0.0);
            ukernel(apan, bpan, k, &mut acc);
            // Write back the tile (bounds-clipped to the shard).
            for i in 0..MR {
                let row = row_lo + ib * MR + i;
                if row >= row_hi {
                    break;
                }
                for j in 0..NR {
                    let col = jb * NR + j;
                    if col < n {
                        c_rows[(row - row_lo) * n + col] = acc[i * NR + j];
                    }
                }
            }
        }
    }
}

/// Physical row of logical position `pos` under a paged block table.
#[inline]
pub fn paged_row(table: &[u32], block_size: usize, pos: usize) -> usize {
    table[pos / block_size] as usize * block_size + pos % block_size
}

/// Attention scores over a paged K store: for each logical position
/// `p < scores.len()`, gathers the K row through `table` (fixed-size
/// blocks of `block_size` positions) and computes
/// `scores[p] = dot(q, K[row(p)][head_off..head_off+head_dim]) * scale`.
/// Identical arithmetic order to the dense row-per-position path.
#[allow(clippy::too_many_arguments)]
pub fn attn_scores_paged(
    q: &[f32],
    kstore: &Tensor,
    table: &[u32],
    block_size: usize,
    head_off: usize,
    head_dim: usize,
    scale: f32,
    scores: &mut [f32],
) {
    debug_assert_eq!(q.len(), head_dim);
    for (p, score) in scores.iter_mut().enumerate() {
        let row = paged_row(table, block_size, p);
        let krow = &kstore.row(row)[head_off..head_off + head_dim];
        *score = dot(q, krow) * scale;
    }
}

/// Attention context over a paged V store: `out = Σ_p scores[p] * V[row(p)]`
/// accumulated in ascending position order (bit-identical to the dense
/// path's accumulation).
pub fn attn_context_paged(
    scores: &[f32],
    vstore: &Tensor,
    table: &[u32],
    block_size: usize,
    head_off: usize,
    head_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), head_dim);
    out.fill(0.0);
    attn_context_paged_accum(scores, vstore, table, block_size, head_off, head_dim, out);
}

/// As [`attn_context_paged`] but accumulating into `out` without zeroing
/// it first — the hot-suffix half of the tiered hybrid attention path,
/// where the cold-prefix contribution is already in `out`.
pub fn attn_context_paged_accum(
    scores: &[f32],
    vstore: &Tensor,
    table: &[u32],
    block_size: usize,
    head_off: usize,
    head_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), head_dim);
    for (p, &sc) in scores.iter().enumerate() {
        let row = paged_row(table, block_size, p);
        let vrow = &vstore.row(row)[head_off..head_off + head_dim];
        for (o, &vv) in out.iter_mut().zip(vrow) {
            *o += sc * vv;
        }
    }
}

/// Per-block affine int8 quantization of the cold KV tier: `q[i]` codes
/// `src[i]` as `round((src[i] - zero) / scale) - 128`, with `zero` the
/// block minimum and `scale = (max - min) / 255`. Returns
/// `(scale, zero)`. Properties (pinned by `rust/tests/properties.rs`):
/// every element round-trips within `scale / 2`, and a constant block
/// (scale 0) round-trips exactly.
pub fn quantize_block_i8(src: &[f32], dst: &mut [i8]) -> (f32, f32) {
    assert_eq!(src.len(), dst.len());
    if src.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in src {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let scale = (hi - lo) / 255.0;
    if scale == 0.0 {
        // Constant block: store the value in the zero-point, exactly.
        dst.fill(-128);
        return (0.0, lo);
    }
    let inv = 1.0 / scale;
    for (q, &v) in dst.iter_mut().zip(src) {
        let code = ((v - lo) * inv).round().clamp(0.0, 255.0);
        *q = (code as i32 - 128) as i8;
    }
    (scale, lo)
}

/// Decode one int8 code of [`quantize_block_i8`].
#[inline]
pub fn dequant_i8(q: i8, scale: f32, zero: f32) -> f32 {
    zero + (q as f32 + 128.0) * scale
}

/// Dequantize a whole quantized block back to f32 (the cold-tier fetch
/// path: cold bytes -> hot fp32 rows).
pub fn dequantize_block_i8(q: &[i8], scale: f32, zero: f32, out: &mut [f32]) {
    assert_eq!(q.len(), out.len());
    for (o, &c) in out.iter_mut().zip(q) {
        *o = dequant_i8(c, scale, zero);
    }
}

/// Attention scores over one *quantized* cold KV block read in place
/// (dequant-gather): for each of `rows` positions,
/// `scores[r] = dot(q, dq(K_q[r][head_off..head_off+head_dim])) * scale`
/// with per-element dequantization — no fp32 materialization of the
/// block. Used when a sequence is mostly cold and fetching it into the
/// hot tier would not pay.
#[allow(clippy::too_many_arguments)]
pub fn attn_scores_quant_i8(
    q: &[f32],
    kq: &[i8],
    qscale: f32,
    qzero: f32,
    rows: usize,
    width: usize,
    head_off: usize,
    head_dim: usize,
    scale: f32,
    scores: &mut [f32],
) {
    debug_assert_eq!(q.len(), head_dim);
    debug_assert!(rows * width <= kq.len());
    debug_assert_eq!(scores.len(), rows);
    for (r, s) in scores.iter_mut().enumerate() {
        let krow = &kq[r * width + head_off..r * width + head_off + head_dim];
        let mut acc = 0.0f32;
        for (x, &c) in q.iter().zip(krow) {
            acc += x * dequant_i8(c, qscale, qzero);
        }
        *s = acc * scale;
    }
}

/// Context accumulation over one quantized cold V block (dequant-gather):
/// `out += Σ_r scores[r] * dq(V_q[r][head_off..])`, ascending position
/// order. Accumulates — the caller zeroes `out` before the first cold
/// block and chains the hot suffix with [`attn_context_paged_accum`].
#[allow(clippy::too_many_arguments)]
pub fn attn_context_quant_i8(
    scores: &[f32],
    vq: &[i8],
    qscale: f32,
    qzero: f32,
    width: usize,
    head_off: usize,
    head_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), head_dim);
    debug_assert!(scores.len() * width <= vq.len());
    for (r, &sc) in scores.iter().enumerate() {
        let vrow = &vq[r * width + head_off..r * width + head_off + head_dim];
        for (o, &c) in out.iter_mut().zip(vrow) {
            *o += sc * dequant_i8(c, qscale, qzero);
        }
    }
}

/// Element-wise exp (vector-friendly loop).
pub fn exp_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.exp();
    }
}

/// SiLU: x * sigmoid(x).
pub fn silu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
}

/// Row-wise softmax over the last axis.
pub fn softmax_rows(x: &mut Tensor) {
    let cols = *x.shape.0.last().unwrap();
    let rows = x.numel() / cols;
    for r in 0..rows {
        let row = &mut x.data[r * cols..(r + 1) * cols];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Softmax over a slice (single row).
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm over the last axis: `x / rms(x) * w`.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(w) {
        *o = v * inv * g;
    }
}

/// Rotary position embedding on one head row (interleaved-half
/// convention, matching the JAX reference in python/compile/ref.py).
pub fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let freq = 1.0 / theta.powf(2.0 * i as f32 / d as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

/// Embedding row gather.
pub fn gather_rows(table: &Tensor, ids: &[usize]) -> Tensor {
    let h = table.dim(1);
    let mut out = Tensor::zeros(&[ids.len(), h]);
    for (r, &id) in ids.iter().enumerate() {
        out.row_mut(r).copy_from_slice(table.row(id));
    }
    out
}

/// `out += x` elementwise.
pub fn add_inplace(out: &mut [f32], x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o += v;
    }
}

/// `out *= x` elementwise.
pub fn mul_inplace(out: &mut [f32], x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o *= v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(4, 4, 4), (7, 13, 5), (64, 64, 64), (33, 17, 49)] {
            let a = Tensor::randn(&[m, k], &mut rng, 1.0);
            let b = Tensor::randn(&[k, n], &mut rng, 1.0);
            let c0 = matmul_naive(&a, &b);
            let c1 = matmul_blocked(&a, &b);
            assert!(
                c0.max_abs_diff(&c1) < 1e-4,
                "blocked vs naive mismatch at ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn range_partition_composes() {
        // Computing [0,m) in two halves equals the full result.
        let mut rng = Rng::new(3);
        let (m, k, n) = (16, 24, 32);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let (mut ap, mut bp) = (Vec::new(), Vec::new());
        pack_a(&a.data, m, k, &mut ap);
        pack_b(&b.data, k, n, &mut bp);
        let mut c = Tensor::zeros(&[m, n]);
        matmul_packed_range(&ap, &bp, m, k, n, 0, 8, &mut c.data);
        matmul_packed_range(&ap, &bp, m, k, n, 8, 16, &mut c.data);
        let want = matmul_naive(&a, &b);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Rng::new(9);
        let (k, n) = (48, 40);
        let x = Tensor::randn(&[1, k], &mut rng, 1.0);
        let w = Tensor::randn(&[k, n], &mut rng, 1.0);
        let want = matmul_naive(&x, &w);
        let mut y = vec![0.0; n];
        gemv(&x.data, &w, &mut y);
        for (a, b) in y.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4);
        }
        // Column-partitioned variant composes.
        let mut y1 = vec![0.0; 16];
        let mut y2 = vec![0.0; n - 16];
        gemv_cols(&x.data, &w, 0, 16, &mut y1);
        gemv_cols(&x.data, &w, 16, n, &mut y2);
        let joined: Vec<f32> = y1.into_iter().chain(y2).collect();
        for (a, b) in joined.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn prepacked_matches_naive_and_gemv_bitwise() {
        let mut rng = Rng::new(21);
        for &(m, k, n) in &[(1usize, 48, 40), (5, 33, 17), (16, 64, 96)] {
            let x = Tensor::randn(&[m, k], &mut rng, 1.0);
            let w = Tensor::randn(&[k, n], &mut rng, 1.0);
            let pm = PackedMat::pack(&w);
            let mut c = vec![0.0f32; m * n];
            matmul_prepacked(&x.data, m, &pm, &mut c);
            let want = matmul_naive(&x, &w);
            for (a, b) in c.iter().zip(&want.data) {
                assert!((a - b).abs() < 1e-4);
            }
            // The decode-path contract: row 0 equals gemv_cols exactly
            // (same per-column accumulation order over k).
            let mut y = vec![0.0f32; n];
            gemv_cols(&x.data[..k], &w, 0, n, &mut y);
            assert_eq!(&c[..n], &y[..], "prepacked row 0 must be bit-identical to gemv");
        }
    }

    #[test]
    fn prepacked_row_ranges_compose_bitwise() {
        // Any MR-aligned partitioning of the M dimension must reproduce
        // the full matmul bit-for-bit — the determinism contract of the
        // multi-threaded batched decode path.
        let mut rng = Rng::new(77);
        for &(rows, k, n) in &[(16usize, 48, 40), (10, 33, 17), (3, 24, 96)] {
            let x = Tensor::randn(&[rows, k], &mut rng, 1.0);
            let w = Tensor::randn(&[k, n], &mut rng, 1.0);
            let pm = PackedMat::pack(&w);
            let mut want = vec![0.0f32; rows * n];
            matmul_prepacked(&x.data, rows, &pm, &mut want);
            for parts in [1usize, 2, 3, 5] {
                let shards = crate::parallel::panel_splits(rows, MR, parts);
                let mut got = vec![0.0f32; rows * n];
                let mut scratch = Vec::new();
                for &(lo, hi) in &shards {
                    matmul_prepacked_rows(
                        &x.data,
                        rows,
                        &pm,
                        lo,
                        hi,
                        &mut got[lo * n..hi * n],
                        &mut scratch,
                    );
                }
                assert_eq!(got, want, "({rows},{k},{n}) x {parts} shards diverged");
            }
        }
    }

    #[test]
    fn paged_attention_matches_contiguous() {
        let mut rng = Rng::new(33);
        let (block_size, width, head_dim, head_off) = (4usize, 16usize, 8usize, 8usize);
        let seq = 11usize; // 3 blocks, last partially filled
        // Contiguous store: position p at row p.
        let dense = Tensor::randn(&[16, width], &mut rng, 1.0);
        // Paged store: blocks scattered through a larger arena.
        let table: Vec<u32> = vec![5, 2, 7];
        let mut paged = Tensor::zeros(&[10 * block_size, width]);
        for p in 0..seq {
            let row = paged_row(&table, block_size, p);
            paged.row_mut(row).copy_from_slice(dense.row(p));
        }
        let q: Vec<f32> = (0..head_dim).map(|_| rng.normal()).collect();
        let scale = 0.25f32;

        let mut want_scores = vec![0.0f32; seq];
        for (p, s) in want_scores.iter_mut().enumerate() {
            *s = dot(&q, &dense.row(p)[head_off..head_off + head_dim]) * scale;
        }
        let mut got_scores = vec![0.0f32; seq];
        attn_scores_paged(
            &q,
            &paged,
            &table,
            block_size,
            head_off,
            head_dim,
            scale,
            &mut got_scores,
        );
        assert_eq!(want_scores, got_scores);

        let mut want_ctx = vec![0.0f32; head_dim];
        for (p, &sc) in want_scores.iter().enumerate() {
            for (o, &vv) in want_ctx.iter_mut().zip(&dense.row(p)[head_off..head_off + head_dim]) {
                *o += sc * vv;
            }
        }
        let mut got_ctx = vec![0.0f32; head_dim];
        attn_context_paged(
            &want_scores,
            &paged,
            &table,
            block_size,
            head_off,
            head_dim,
            &mut got_ctx,
        );
        assert_eq!(want_ctx, got_ctx);
    }

    #[test]
    fn quant_roundtrip_and_constant_blocks() {
        let mut rng = Rng::new(71);
        let src: Vec<f32> = (0..256).map(|_| rng.normal() * 3.0).collect();
        let mut q = vec![0i8; src.len()];
        let (scale, zero) = quantize_block_i8(&src, &mut q);
        let mut back = vec![0.0f32; src.len()];
        dequantize_block_i8(&q, scale, zero, &mut back);
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6, "err {} > scale/2 {}", a - b, scale);
        }
        // Constant block: exact round trip via the zero-point.
        let cst = vec![4.25f32; 64];
        let mut qc = vec![0i8; 64];
        let (s, z) = quantize_block_i8(&cst, &mut qc);
        assert_eq!(s, 0.0);
        let mut out = vec![0.0f32; 64];
        dequantize_block_i8(&qc, s, z, &mut out);
        assert_eq!(out, cst);
    }

    #[test]
    fn quant_attention_matches_dequantized_reference() {
        // The dequant-gather kernels must agree with "dequantize the
        // block, then run the paged fp32 kernels" — the direct cold read
        // is an I/O optimization, not a different computation.
        let mut rng = Rng::new(44);
        let (bs, width, hd, off) = (4usize, 16usize, 8usize, 8usize);
        let block = Tensor::randn(&[bs, width], &mut rng, 1.0);
        let mut kq = vec![0i8; bs * width];
        let (scale, zero) = quantize_block_i8(&block.data, &mut kq);
        let mut deq = Tensor::zeros(&[bs, width]);
        dequantize_block_i8(&kq, scale, zero, &mut deq.data);

        let q: Vec<f32> = (0..hd).map(|_| rng.normal()).collect();
        let table = [0u32];
        let mut want = vec![0.0f32; bs];
        attn_scores_paged(&q, &deq, &table, bs, off, hd, 0.5, &mut want);
        let mut got = vec![0.0f32; bs];
        attn_scores_quant_i8(&q, &kq, scale, zero, bs, width, off, hd, 0.5, &mut got);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5, "scores diverged: {a} vs {b}");
        }

        let mut want_ctx = vec![0.0f32; hd];
        attn_context_paged(&want, &deq, &table, bs, off, hd, &mut want_ctx);
        let mut got_ctx = vec![0.0f32; hd];
        attn_context_quant_i8(&want, &kq, scale, zero, width, off, hd, &mut got_ctx);
        for (a, b) in want_ctx.iter().zip(&got_ctx) {
            assert!((a - b).abs() < 1e-5, "context diverged: {a} vs {b}");
        }
    }

    #[test]
    fn context_accum_composes_with_zeroing_variant() {
        let mut rng = Rng::new(45);
        let (bs, width, hd) = (4usize, 8usize, 8usize);
        let v = Tensor::randn(&[2 * bs, width], &mut rng, 1.0);
        let scores: Vec<f32> = (0..2 * bs).map(|_| rng.normal()).collect();
        let table = [0u32, 1];
        let mut want = vec![0.0f32; hd];
        attn_context_paged(&scores, &v, &table, bs, 0, hd, &mut want);
        // Split: first block via the zeroing variant, second accumulated.
        let mut got = vec![0.0f32; hd];
        attn_context_paged(&scores[..bs], &v, &table[..1], bs, 0, hd, &mut got);
        attn_context_paged_accum(&scores[bs..], &v, &table[1..], bs, 0, hd, &mut got);
        assert_eq!(want, got, "piecewise accumulation must be bit-identical");
    }

    #[test]
    fn paged_row_mapping() {
        let table = [9u32, 0, 4];
        assert_eq!(paged_row(&table, 8, 0), 72);
        assert_eq!(paged_row(&table, 8, 7), 79);
        assert_eq!(paged_row(&table, 8, 8), 0);
        assert_eq!(paged_row(&table, 8, 17), 33);
    }

    #[test]
    fn softmax_normalizes() {
        let mut t = Tensor::from_vec(&[2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]);
        softmax_rows(&mut t);
        for r in 0..2 {
            let s: f32 = t.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(t.row(r).iter().all(|&v| v > 0.0));
        }
        // Monotone: bigger logit, bigger prob.
        assert!(t.data[3] > t.data[2]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32; 8];
        let w = vec![1.0f32; 8];
        let mut out = vec![0.0; 8];
        rmsnorm(&x, &w, 1e-6, &mut out);
        // rms(x) == 3, so out ≈ 1.
        for v in out {
            assert!((v - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        rope_inplace(&mut x, 0, 10000.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6, "pos 0 must be identity");
        }
        let mut y = orig.clone();
        rope_inplace(&mut y, 17, 10000.0);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = y.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4, "rotation preserves norm");
    }

    #[test]
    fn gather_and_elementwise() {
        let table = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let g = gather_rows(&table, &[2, 0]);
        assert_eq!(g.data, vec![20., 21., 0., 1.]);
        let mut a = vec![1.0, 2.0];
        add_inplace(&mut a, &[10.0, 20.0]);
        assert_eq!(a, vec![11.0, 22.0]);
        mul_inplace(&mut a, &[2.0, 0.5]);
        assert_eq!(a, vec![22.0, 11.0]);
        let mut s = vec![0.5f32, -0.5];
        silu_inplace(&mut s);
        assert!((s[0] - 0.5 / (1.0 + (-0.5f32).exp())).abs() < 1e-6);
    }
}
