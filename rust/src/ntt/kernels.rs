//! Register-blocked μkernels (the NTT primitive set).
//!
//! The matmul follows the GotoBLAS decomposition the paper's packing
//! story builds on: pack A into row-major MR-blocked panels, B into
//! column-major NR-blocked panels, then drive an MR×NR register μkernel
//! over K. `MR = 4, NR = 16` keeps the accumulator tile (4×16 f32 = two
//! AVX2 registers per row) inside the 16 ymm registers; the inner loops
//! are written so LLVM auto-vectorizes them to FMA sequences.

use super::Tensor;

/// Register tile rows of the matmul μkernel.
pub const MR: usize = 4;
/// Register tile columns (two AVX2 f32 vectors).
pub const NR: usize = 16;

/// `C[m,n] = A[m,k] @ B[k,n]` — naive triple loop (correctness oracle
/// and the "no packing" baseline the MLC/generic path models).
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (kb, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb);
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for p in 0..k {
            let av = a.data[i * k + p];
            let brow = &b.data[p * n..(p + 1) * n];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Pack `rows x cols` of A (row-major) into MR-row panels.
pub fn pack_a(a: &[f32], m: usize, k: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(m.div_ceil(MR) * MR * k);
    for ib in (0..m).step_by(MR) {
        for p in 0..k {
            for i in ib..(ib + MR) {
                out.push(if i < m { a[i * k + p] } else { 0.0 });
            }
        }
    }
}

/// Pack B (k x n row-major) into NR-column panels.
pub fn pack_b(b: &[f32], k: usize, n: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(n.div_ceil(NR) * NR * k);
    for jb in (0..n).step_by(NR) {
        for p in 0..k {
            for j in jb..(jb + NR) {
                out.push(if j < n { b[p * n + j] } else { 0.0 });
            }
        }
    }
}

/// MR×NR register μkernel: C_tile += A_panel × B_panel over `k`.
///
/// Fixed-size row views (`&[f32; MR]` / `&[f32; NR]`) eliminate bounds
/// checks in the inner loop so LLVM lowers it to unrolled FMA vector ops
/// (§Perf L3: +2.3x over the slice version).
#[inline]
fn ukernel(apan: &[f32], bpan: &[f32], k: usize, c: &mut [f32; MR * NR]) {
    for p in 0..k {
        let arow: &[f32; MR] = apan[p * MR..p * MR + MR].try_into().unwrap();
        let brow: &[f32; NR] = bpan[p * NR..p * NR + NR].try_into().unwrap();
        for i in 0..MR {
            let av = arow[i];
            let base = i * NR;
            for j in 0..NR {
                c[base + j] += av * brow[j];
            }
        }
    }
}

/// Blocked matmul over pre-packed panels, writing rows `[row_lo, row_hi)`
/// of C. `row_lo`/`row_hi` let the coordinator statically partition the M
/// dimension across cores ("cores as distributed nodes", §4.2).
pub fn matmul_packed_range(
    apacked: &[f32],
    bpacked: &[f32],
    m: usize,
    k: usize,
    n: usize,
    row_lo: usize,
    row_hi: usize,
    c: &mut [f32],
) {
    let mut acc = [0.0f32; MR * NR];
    let mb0 = row_lo / MR;
    let mb1 = row_hi.div_ceil(MR);
    for ib in mb0..mb1 {
        let apan = &apacked[ib * MR * k..(ib + 1) * MR * k];
        for jb in 0..n.div_ceil(NR) {
            let bpan = &bpacked[jb * NR * k..(jb + 1) * NR * k];
            acc.fill(0.0);
            ukernel(apan, bpan, k, &mut acc);
            // Write back the tile (bounds-clipped).
            for i in 0..MR {
                let row = ib * MR + i;
                if row < row_lo || row >= row_hi || row >= m {
                    continue;
                }
                for j in 0..NR {
                    let col = jb * NR + j;
                    if col < n {
                        c[row * n + col] = acc[i * NR + j];
                    }
                }
            }
        }
    }
}

/// `C = A @ B` with packing (single-threaded convenience wrapper).
pub fn matmul_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (kb, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb);
    let mut ap = Vec::new();
    let mut bp = Vec::new();
    pack_a(&a.data, m, k, &mut ap);
    pack_b(&b.data, k, n, &mut bp);
    let mut c = Tensor::zeros(&[m, n]);
    matmul_packed_range(&ap, &bp, m, k, n, 0, m, &mut c.data);
    c
}

/// `y = x @ W` where `W` is [k, n] and x is a single row — the decode
/// hot path (GEMV). Walks W row-wise so the weight stream is sequential
/// (memory-bandwidth optimal, which is what decode throughput is bound
/// by, §4).
pub fn gemv(x: &[f32], w: &Tensor, y: &mut [f32]) {
    let (k, n) = (w.dim(0), w.dim(1));
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), n);
    y.fill(0.0);
    for p in 0..k {
        let xv = x[p];
        if xv == 0.0 {
            continue;
        }
        let wrow = &w.data[p * n..(p + 1) * n];
        for j in 0..n {
            y[j] += xv * wrow[j];
        }
    }
}

/// `gemv` over a column range `[lo, hi)` of W — the static column
/// partition used by tensor-parallel decode.
pub fn gemv_cols(x: &[f32], w: &Tensor, lo: usize, hi: usize, y: &mut [f32]) {
    let (k, n) = (w.dim(0), w.dim(1));
    assert_eq!(y.len(), hi - lo);
    y.fill(0.0);
    for p in 0..k {
        let xv = x[p];
        let wrow = &w.data[p * n + lo..p * n + hi];
        for (yj, wj) in y.iter_mut().zip(wrow) {
            *yj += xv * wj;
        }
    }
}

/// Element-wise exp (vector-friendly loop).
pub fn exp_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.exp();
    }
}

/// SiLU: x * sigmoid(x).
pub fn silu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
}

/// Row-wise softmax over the last axis.
pub fn softmax_rows(x: &mut Tensor) {
    let cols = *x.shape.0.last().unwrap();
    let rows = x.numel() / cols;
    for r in 0..rows {
        let row = &mut x.data[r * cols..(r + 1) * cols];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Softmax over a slice (single row).
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm over the last axis: `x / rms(x) * w`.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(w) {
        *o = v * inv * g;
    }
}

/// Rotary position embedding on one head row (interleaved-half
/// convention, matching the JAX reference in python/compile/ref.py).
pub fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let freq = 1.0 / theta.powf(2.0 * i as f32 / d as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

/// Embedding row gather.
pub fn gather_rows(table: &Tensor, ids: &[usize]) -> Tensor {
    let h = table.dim(1);
    let mut out = Tensor::zeros(&[ids.len(), h]);
    for (r, &id) in ids.iter().enumerate() {
        out.row_mut(r).copy_from_slice(table.row(id));
    }
    out
}

/// `out += x` elementwise.
pub fn add_inplace(out: &mut [f32], x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o += v;
    }
}

/// `out *= x` elementwise.
pub fn mul_inplace(out: &mut [f32], x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o *= v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(4, 4, 4), (7, 13, 5), (64, 64, 64), (33, 17, 49)] {
            let a = Tensor::randn(&[m, k], &mut rng, 1.0);
            let b = Tensor::randn(&[k, n], &mut rng, 1.0);
            let c0 = matmul_naive(&a, &b);
            let c1 = matmul_blocked(&a, &b);
            assert!(
                c0.max_abs_diff(&c1) < 1e-4,
                "blocked vs naive mismatch at ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn range_partition_composes() {
        // Computing [0,m) in two halves equals the full result.
        let mut rng = Rng::new(3);
        let (m, k, n) = (16, 24, 32);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let (mut ap, mut bp) = (Vec::new(), Vec::new());
        pack_a(&a.data, m, k, &mut ap);
        pack_b(&b.data, k, n, &mut bp);
        let mut c = Tensor::zeros(&[m, n]);
        matmul_packed_range(&ap, &bp, m, k, n, 0, 8, &mut c.data);
        matmul_packed_range(&ap, &bp, m, k, n, 8, 16, &mut c.data);
        let want = matmul_naive(&a, &b);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Rng::new(9);
        let (k, n) = (48, 40);
        let x = Tensor::randn(&[1, k], &mut rng, 1.0);
        let w = Tensor::randn(&[k, n], &mut rng, 1.0);
        let want = matmul_naive(&x, &w);
        let mut y = vec![0.0; n];
        gemv(&x.data, &w, &mut y);
        for (a, b) in y.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4);
        }
        // Column-partitioned variant composes.
        let mut y1 = vec![0.0; 16];
        let mut y2 = vec![0.0; n - 16];
        gemv_cols(&x.data, &w, 0, 16, &mut y1);
        gemv_cols(&x.data, &w, 16, n, &mut y2);
        let joined: Vec<f32> = y1.into_iter().chain(y2).collect();
        for (a, b) in joined.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn softmax_normalizes() {
        let mut t = Tensor::from_vec(&[2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]);
        softmax_rows(&mut t);
        for r in 0..2 {
            let s: f32 = t.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(t.row(r).iter().all(|&v| v > 0.0));
        }
        // Monotone: bigger logit, bigger prob.
        assert!(t.data[3] > t.data[2]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32; 8];
        let w = vec![1.0f32; 8];
        let mut out = vec![0.0; 8];
        rmsnorm(&x, &w, 1e-6, &mut out);
        // rms(x) == 3, so out ≈ 1.
        for v in out {
            assert!((v - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        rope_inplace(&mut x, 0, 10000.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6, "pos 0 must be identity");
        }
        let mut y = orig.clone();
        rope_inplace(&mut y, 17, 10000.0);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = y.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4, "rotation preserves norm");
    }

    #[test]
    fn gather_and_elementwise() {
        let table = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let g = gather_rows(&table, &[2, 0]);
        assert_eq!(g.data, vec![20., 21., 0., 1.]);
        let mut a = vec![1.0, 2.0];
        add_inplace(&mut a, &[10.0, 20.0]);
        assert_eq!(a, vec![11.0, 22.0]);
        mul_inplace(&mut a, &[2.0, 0.5]);
        assert_eq!(a, vec![22.0, 11.0]);
        let mut s = vec![0.5f32, -0.5];
        silu_inplace(&mut s);
        assert!((s[0] - 0.5 / (1.0 + (-0.5f32).exp())).abs() < 1e-6);
    }
}
