//! Register-blocked μkernels (the NTT primitive set).
//!
//! The matmul follows the GotoBLAS decomposition the paper's packing
//! story builds on: pack A into row-major MR-blocked panels, B into
//! column-major NR-blocked panels, then drive an MR×NR register μkernel
//! over K. `MR = 4, NR = 16` keeps the accumulator tile (4×16 f32 = two
//! AVX2 registers per row) inside the 16 ymm registers; the inner loops
//! are written so LLVM auto-vectorizes them to FMA sequences.

use super::Tensor;

/// Register tile rows of the matmul μkernel.
pub const MR: usize = 4;
/// Register tile columns (two AVX2 f32 vectors).
pub const NR: usize = 16;

/// `C[m,n] = A[m,k] @ B[k,n]` — naive triple loop (correctness oracle
/// and the "no packing" baseline the MLC/generic path models).
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (kb, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb);
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for p in 0..k {
            let av = a.data[i * k + p];
            let brow = &b.data[p * n..(p + 1) * n];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Pack `rows x cols` of A (row-major) into MR-row panels.
pub fn pack_a(a: &[f32], m: usize, k: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(m.div_ceil(MR) * MR * k);
    for ib in (0..m).step_by(MR) {
        for p in 0..k {
            for i in ib..(ib + MR) {
                out.push(if i < m { a[i * k + p] } else { 0.0 });
            }
        }
    }
}

/// Pack B (k x n row-major) into NR-column panels.
pub fn pack_b(b: &[f32], k: usize, n: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(n.div_ceil(NR) * NR * k);
    for jb in (0..n).step_by(NR) {
        for p in 0..k {
            for j in jb..(jb + NR) {
                out.push(if j < n { b[p * n + j] } else { 0.0 });
            }
        }
    }
}

/// MR×NR register μkernel: C_tile += A_panel × B_panel over `k`.
///
/// Fixed-size row views (`&[f32; MR]` / `&[f32; NR]`) eliminate bounds
/// checks in the inner loop so LLVM lowers it to unrolled FMA vector ops
/// (§Perf L3: +2.3x over the slice version).
#[inline]
fn ukernel(apan: &[f32], bpan: &[f32], k: usize, c: &mut [f32; MR * NR]) {
    for p in 0..k {
        let arow: &[f32; MR] = apan[p * MR..p * MR + MR].try_into().unwrap();
        let brow: &[f32; NR] = bpan[p * NR..p * NR + NR].try_into().unwrap();
        for i in 0..MR {
            let av = arow[i];
            let base = i * NR;
            for j in 0..NR {
                c[base + j] += av * brow[j];
            }
        }
    }
}

/// Blocked matmul over pre-packed panels, writing rows `[row_lo, row_hi)`
/// of C. `row_lo`/`row_hi` let the coordinator statically partition the M
/// dimension across cores ("cores as distributed nodes", §4.2).
#[allow(clippy::too_many_arguments)]
pub fn matmul_packed_range(
    apacked: &[f32],
    bpacked: &[f32],
    m: usize,
    k: usize,
    n: usize,
    row_lo: usize,
    row_hi: usize,
    c: &mut [f32],
) {
    let mut acc = [0.0f32; MR * NR];
    let mb0 = row_lo / MR;
    let mb1 = row_hi.div_ceil(MR);
    for ib in mb0..mb1 {
        let apan = &apacked[ib * MR * k..(ib + 1) * MR * k];
        for jb in 0..n.div_ceil(NR) {
            let bpan = &bpacked[jb * NR * k..(jb + 1) * NR * k];
            acc.fill(0.0);
            ukernel(apan, bpan, k, &mut acc);
            // Write back the tile (bounds-clipped).
            for i in 0..MR {
                let row = ib * MR + i;
                if row < row_lo || row >= row_hi || row >= m {
                    continue;
                }
                for j in 0..NR {
                    let col = jb * NR + j;
                    if col < n {
                        c[row * n + col] = acc[i * NR + j];
                    }
                }
            }
        }
    }
}

/// `C = A @ B` with packing (single-threaded convenience wrapper).
pub fn matmul_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dim(0), a.dim(1));
    let (kb, n) = (b.dim(0), b.dim(1));
    assert_eq!(k, kb);
    let mut ap = Vec::new();
    let mut bp = Vec::new();
    pack_a(&a.data, m, k, &mut ap);
    pack_b(&b.data, k, n, &mut bp);
    let mut c = Tensor::zeros(&[m, n]);
    matmul_packed_range(&ap, &bp, m, k, n, 0, m, &mut c.data);
    c
}

/// `y = x @ W` where `W` is [k, n] and x is a single row — the decode
/// hot path (GEMV). Walks W row-wise so the weight stream is sequential
/// (memory-bandwidth optimal, which is what decode throughput is bound
/// by, §4).
pub fn gemv(x: &[f32], w: &Tensor, y: &mut [f32]) {
    let (k, n) = (w.dim(0), w.dim(1));
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), n);
    y.fill(0.0);
    for p in 0..k {
        let xv = x[p];
        if xv == 0.0 {
            continue;
        }
        let wrow = &w.data[p * n..(p + 1) * n];
        for j in 0..n {
            y[j] += xv * wrow[j];
        }
    }
}

/// `gemv` over a column range `[lo, hi)` of W — the static column
/// partition used by tensor-parallel decode.
pub fn gemv_cols(x: &[f32], w: &Tensor, lo: usize, hi: usize, y: &mut [f32]) {
    let (k, n) = (w.dim(0), w.dim(1));
    assert_eq!(y.len(), hi - lo);
    y.fill(0.0);
    for p in 0..k {
        let xv = x[p];
        let wrow = &w.data[p * n + lo..p * n + hi];
        for (yj, wj) in y.iter_mut().zip(wrow) {
            *yj += xv * wj;
        }
    }
}

/// Dot product with a sequential accumulation order (the order every
/// attention path in the repo shares, so paged and dense attention are
/// bit-identical).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// A weight matrix pre-packed into NR-column panels, for GEMMs where the
/// same `W` is streamed every decode step (continuous batching: pack
/// once at engine build, then each batched step reads the panels exactly
/// once instead of once per sequence — the weight-stream saving that
/// makes iteration-level batching pay on memory-bound decode).
#[derive(Debug, Clone)]
pub struct PackedMat {
    pub k: usize,
    pub n: usize,
    panels: Vec<f32>,
}

impl PackedMat {
    /// Pack a `[k, n]` weight tensor.
    pub fn pack(w: &Tensor) -> Self {
        let (k, n) = (w.dim(0), w.dim(1));
        let mut panels = Vec::new();
        pack_b(&w.data, k, n, &mut panels);
        PackedMat { k, n, panels }
    }

    pub fn bytes(&self) -> usize {
        self.panels.len() * 4
    }
}

/// `C[rows, n] = X[rows, k] @ W` over a pre-packed `W`. Per-element
/// accumulation runs over `k` in ascending order, matching [`gemv`] /
/// [`gemv_cols`], so batched and per-sequence decode agree bitwise.
pub fn matmul_prepacked(x: &[f32], rows: usize, w: &PackedMat, c: &mut [f32]) {
    let mut scratch = Vec::new();
    matmul_prepacked_into(x, rows, w, c, &mut scratch);
}

/// [`matmul_prepacked`] with a caller-owned A-pack scratch buffer, for
/// hot loops (the batched decode path calls this 7 times per layer per
/// iteration — re-allocating the pack buffer each time is pure
/// overhead).
pub fn matmul_prepacked_into(
    x: &[f32],
    rows: usize,
    w: &PackedMat,
    c: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    matmul_prepacked_rows(x, rows, w, 0, rows, c, scratch);
}

/// Rows `[row_lo, row_hi)` of `C = X @ W` over a pre-packed `W`, written
/// into `c_rows` (length `(row_hi - row_lo) * w.n`, i.e. the caller's
/// own disjoint slice of C) — the static M-partition of the SPMD batched
/// decode path: each worker packs and computes only its own MR-row
/// panels, so no shared A-pack pass (and no extra barrier) is needed.
///
/// `row_lo` must be MR-aligned (use [`crate::parallel::panel_splits`]);
/// `row_hi` is either MR-aligned or equal to `rows`. Per-element
/// arithmetic is the register μkernel over ascending `k`, bit-identical
/// to [`matmul_prepacked`] for the covered rows at any partitioning.
pub fn matmul_prepacked_rows(
    x: &[f32],
    rows: usize,
    w: &PackedMat,
    row_lo: usize,
    row_hi: usize,
    c_rows: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    matmul_prepacked_rows_cols(x, rows, w, row_lo, row_hi, 0, w.n.div_ceil(NR), c_rows, scratch)
}

/// Rows `[row_lo, row_hi)` × NR-column panels `[colpan_lo, colpan_hi)`
/// of `C = X @ W` — the 2-D shard of the column-parallel (`S(1)`)
/// serving layout: a shard group owns a contiguous column-panel range,
/// its lanes split the rows. `c_rows` is the compact
/// `(row_hi - row_lo) × ncols` local buffer (`ncols` = the covered
/// columns, clipped to `w.n` on the last panel); the caller copies rows
/// into the full-width shared buffer at fixed positions (a disjoint
/// writeback, not a reduction). Column panels are independent in this
/// kernel — each output element still accumulates over ascending `k` in
/// full, so any panel range is bit-identical to the same columns of
/// [`matmul_prepacked`]. The full-width entry points delegate here with
/// the full panel range.
#[allow(clippy::too_many_arguments)]
pub fn matmul_prepacked_rows_cols(
    x: &[f32],
    rows: usize,
    w: &PackedMat,
    row_lo: usize,
    row_hi: usize,
    colpan_lo: usize,
    colpan_hi: usize,
    c_rows: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let (k, n) = (w.k, w.n);
    let npan = n.div_ceil(NR);
    assert!(row_lo <= row_hi && row_hi <= rows, "bad row range");
    assert!(colpan_lo <= colpan_hi && colpan_hi <= npan, "bad column-panel range");
    assert_eq!(x.len(), rows * k, "X shape mismatch");
    let col0 = colpan_lo * NR;
    let ncols = (colpan_hi * NR).min(n).saturating_sub(col0);
    assert_eq!(c_rows.len(), (row_hi - row_lo) * ncols, "C shape mismatch");
    if row_lo == row_hi || ncols == 0 {
        // Empty shard (oversubscribed partition): nothing to compute —
        // and `row_lo` need not be aligned in this case.
        return;
    }
    assert_eq!(row_lo % MR, 0, "row_lo must be MR-aligned");
    let panels = (row_hi - row_lo).div_ceil(MR);
    pack_a_shard(x, rows, k, row_lo, panels, scratch);
    let mut acc = [0.0f32; MR * NR];
    for ib in 0..panels {
        let apan = &scratch[ib * MR * k..(ib + 1) * MR * k];
        for jb in colpan_lo..colpan_hi {
            let bpan = &w.panels[jb * NR * k..(jb + 1) * NR * k];
            acc.fill(0.0);
            ukernel(apan, bpan, k, &mut acc);
            // Write back the tile (bounds-clipped to the shard).
            for i in 0..MR {
                let row = row_lo + ib * MR + i;
                if row >= row_hi {
                    break;
                }
                for j in 0..NR {
                    let col = jb * NR + j;
                    if col < n {
                        c_rows[(row - row_lo) * ncols + (col - col0)] = acc[i * NR + j];
                    }
                }
            }
        }
    }
}

/// Group length (along K) of the group-wise affine weight quantization:
/// one scale/zero pair per `QGROUP` consecutive K elements of a column.
/// 32 matches the llama.cpp/MNN-LLM ballpark — small enough that one
/// outlier cannot blow up a whole column's scale, large enough that the
/// scale/zero overhead stays at 8 bytes per 32 (int8) or 16 (int4)
/// payload bytes.
pub const QGROUP: usize = 32;

/// Storage format of the engine's packed weight plane (the GEMM
/// matrices: per-layer projections + LM head). Threaded from
/// `Qwen3Config::weight_quant` through engine build; `F32` is the
/// unquantized seed path (`PackedMat`), the quantized modes store
/// group-wise affine codes (`QuantMat`) that the fused dequant-GEMM
/// kernels expand one panel group at a time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightQuant {
    /// Unquantized native-dtype weights (the seed behaviour, bitwise).
    F32,
    /// Group-wise affine int8: 1 byte/element + scale/zero per group.
    Int8,
    /// Group-wise affine int4: 2 elements/byte + scale/zero per group.
    Int4,
}

impl WeightQuant {
    pub fn name(&self) -> &'static str {
        match self {
            WeightQuant::F32 => "f32",
            WeightQuant::Int8 => "int8",
            WeightQuant::Int4 => "int4",
        }
    }

    pub fn parse(s: &str) -> Option<WeightQuant> {
        match s {
            "f32" | "fp32" | "none" => Some(WeightQuant::F32),
            "int8" | "i8" => Some(WeightQuant::Int8),
            "int4" | "i4" => Some(WeightQuant::Int4),
            _ => None,
        }
    }

    /// True for the lossy (quantized) modes.
    pub fn is_quantized(&self) -> bool {
        !matches!(self, WeightQuant::F32)
    }

    /// Stored bytes of a `[k, n]` weight matrix in this format
    /// (payload + per-`(column, QGROUP-group)` scale/zero overhead;
    /// excludes panel padding). `native_bytes` prices the `F32`
    /// (unquantized) mode, so F16-dtype *models* keep their 2-byte
    /// accounting.
    pub fn matrix_bytes(&self, k: usize, n: usize, native_bytes: usize) -> u64 {
        let elems = (k * n) as u64;
        let group_overhead = (k.div_ceil(QGROUP) * n * 2 * 4) as u64;
        match self {
            WeightQuant::F32 => elems * native_bytes as u64,
            WeightQuant::Int8 => elems + group_overhead,
            WeightQuant::Int4 => elems.div_ceil(2) + group_overhead,
        }
    }
}

/// Group-wise affine quantized weight matrix, stored in the same
/// NR-column panel layout as [`PackedMat`]: panel `jb` covers columns
/// `[jb*NR, (jb+1)*NR)`, and within a panel row `p` (a K index) holds
/// the NR codes of that K row. Quantization is per `(column, K-group)`
/// — group `g` covers K rows `[g*QGROUP, (g+1)*QGROUP)` — with the same
/// affine convention as the KV cold tier (`quantize_block_i8`): int8
/// codes decode as `zero + (code + 128) * scale`, int4 codes (two per
/// byte, low nibble = even panel column) as `zero + code * scale`.
/// Columns padding the last panel quantize as constant zeros (scale 0),
/// so they decode to exactly 0.0 and the writeback clip discards them.
#[derive(Debug, Clone)]
pub struct QuantMat {
    pub k: usize,
    pub n: usize,
    /// Group length along K (== [`QGROUP`]; last group may be shorter).
    pub group: usize,
    codes: QuantCodes,
    /// Per `(panel, group, panel column)` scale, index
    /// `(jb * groups + g) * NR + j`.
    scales: Vec<f32>,
    /// Zero-points, same layout as `scales`.
    zeros: Vec<f32>,
}

#[derive(Debug, Clone)]
enum QuantCodes {
    /// One i8 per element, `(jb * k + p) * NR + j`.
    I8(Vec<i8>),
    /// Two 4-bit codes per byte packed along the panel column axis:
    /// byte `(jb * k + p) * NR/2 + j/2` holds columns `2*(j/2)` (low
    /// nibble) and `2*(j/2) + 1` (high nibble).
    I4(Vec<u8>),
}

impl QuantMat {
    /// Quantize a `[k, n]` weight tensor. `mode` must be a quantized
    /// variant (`F32` weights stay in [`PackedMat`]; see [`WeightMat`]).
    pub fn quantize(w: &Tensor, mode: WeightQuant) -> Self {
        let (k, n) = (w.dim(0), w.dim(1));
        let group = QGROUP;
        let groups = k.div_ceil(group);
        let npan = n.div_ceil(NR);
        let mut scales = vec![0.0f32; npan * groups * NR];
        let mut zeros = vec![0.0f32; npan * groups * NR];
        let mut strip = [0.0f32; QGROUP];
        let mut codes = match mode {
            WeightQuant::Int8 => QuantCodes::I8(vec![0i8; npan * k * NR]),
            WeightQuant::Int4 => QuantCodes::I4(vec![0u8; npan * k * (NR / 2)]),
            WeightQuant::F32 => panic!("QuantMat::quantize needs a quantized mode"),
        };
        for jb in 0..npan {
            for jj in 0..NR {
                let col = jb * NR + jj;
                for g in 0..groups {
                    let k0 = g * group;
                    let glen = (k - k0).min(group);
                    if col < n {
                        for (p, s) in strip[..glen].iter_mut().enumerate() {
                            *s = w.data[(k0 + p) * n + col];
                        }
                    } else {
                        strip[..glen].fill(0.0);
                    }
                    let si = (jb * groups + g) * NR + jj;
                    match &mut codes {
                        QuantCodes::I8(c) => {
                            let mut cbuf = [0i8; QGROUP];
                            let (s, z) = quantize_block_i8(&strip[..glen], &mut cbuf[..glen]);
                            scales[si] = s;
                            zeros[si] = z;
                            for p in 0..glen {
                                c[(jb * k + k0 + p) * NR + jj] = cbuf[p];
                            }
                        }
                        QuantCodes::I4(c) => {
                            let mut cbuf = [0u8; QGROUP];
                            let (s, z) = quantize_block_i4(&strip[..glen], &mut cbuf[..glen]);
                            scales[si] = s;
                            zeros[si] = z;
                            let shift = (jj % 2) * 4;
                            for p in 0..glen {
                                c[(jb * k + k0 + p) * (NR / 2) + jj / 2] |= cbuf[p] << shift;
                            }
                        }
                    }
                }
            }
        }
        QuantMat { k, n, group, codes, scales, zeros }
    }

    /// Number of K groups.
    pub fn groups(&self) -> usize {
        self.k.div_ceil(self.group)
    }

    /// Stored bytes (codes + scales + zeros).
    pub fn bytes(&self) -> usize {
        let payload = match &self.codes {
            QuantCodes::I8(c) => c.len(),
            QuantCodes::I4(c) => c.len(),
        };
        payload + (self.scales.len() + self.zeros.len()) * 4
    }

    /// Dequantize panel `jb`'s K group `g` into `wbuf` (row `p` of the
    /// group at `wbuf[p*NR..]`, same layout as a [`PackedMat`] panel
    /// slice). Returns the group's row count. This is the *only* f32
    /// materialization of quantized weights on the GEMM path, and it is
    /// one panel group (≤ `QGROUP * NR` floats, 2 KB) at a time.
    #[inline]
    fn dequant_panel_group(&self, jb: usize, g: usize, wbuf: &mut [f32; QGROUP * NR]) -> usize {
        let k0 = g * self.group;
        let glen = (self.k - k0).min(self.group);
        let sbase = (jb * self.groups() + g) * NR;
        let scales = &self.scales[sbase..sbase + NR];
        let zeros = &self.zeros[sbase..sbase + NR];
        match &self.codes {
            QuantCodes::I8(c) => {
                for p in 0..glen {
                    let row = &c[(jb * self.k + k0 + p) * NR..][..NR];
                    let out = &mut wbuf[p * NR..(p + 1) * NR];
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = dequant_i8(row[j], scales[j], zeros[j]);
                    }
                }
            }
            QuantCodes::I4(c) => {
                for p in 0..glen {
                    let row = &c[(jb * self.k + k0 + p) * (NR / 2)..][..NR / 2];
                    let out = &mut wbuf[p * NR..(p + 1) * NR];
                    for (b, &byte) in row.iter().enumerate() {
                        out[2 * b] = dequant_i4(byte & 0x0F, scales[2 * b], zeros[2 * b]);
                        out[2 * b + 1] =
                            dequant_i4(byte >> 4, scales[2 * b + 1], zeros[2 * b + 1]);
                    }
                }
            }
        }
        glen
    }

    /// Decode the whole matrix back to a `[k, n]` f32 tensor — exactly
    /// the values the fused kernel FMAs (same `dequant_*` expressions),
    /// which makes a dense engine over this tensor a *bit-exact* oracle
    /// for [`matmul_quant_rows`] (`Qwen3Weights::fake_quantized`).
    pub fn dequantize(&self) -> Tensor {
        let mut t = Tensor::zeros(&[self.k, self.n]);
        let mut wbuf = [0.0f32; QGROUP * NR];
        for jb in 0..self.n.div_ceil(NR) {
            for g in 0..self.groups() {
                let glen = self.dequant_panel_group(jb, g, &mut wbuf);
                let k0 = g * self.group;
                for p in 0..glen {
                    for j in 0..NR {
                        let col = jb * NR + j;
                        if col < self.n {
                            t.data[(k0 + p) * self.n + col] = wbuf[p * NR + j];
                        }
                    }
                }
            }
        }
        t
    }
}

/// Rows `[row_lo, row_hi)` of `C = X @ dq(Wq)` over a group-quantized
/// weight matrix — the fused dequant-GEMM mirror of
/// [`matmul_prepacked_rows`] (same shard contract: MR-aligned `row_lo`,
/// caller-owned disjoint `c_rows`, shared `scratch`).
///
/// Per `(column panel, K group)` the codes are dequantized **once**
/// into a 2 KB stack buffer and FMAd into the accumulator tiles of
/// every MR-row panel of the shard, so the weight stream is the
/// quantized bytes (¼ / ⅛ of f32) and no full f32 weight matrix ever
/// exists. Accumulation stays ascending-k per output element (groups
/// ascending, rows ascending within a group), so the result is
/// bit-identical to [`matmul_prepacked_rows`] over
/// `PackedMat::pack(&wq.dequantize())` at any shard partitioning.
pub fn matmul_quant_rows(
    x: &[f32],
    rows: usize,
    w: &QuantMat,
    row_lo: usize,
    row_hi: usize,
    c_rows: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    matmul_quant_rows_cols(x, rows, w, row_lo, row_hi, 0, w.n.div_ceil(NR), c_rows, scratch)
}

/// Rows × NR-column-panel shard of the fused dequant-GEMM — the
/// quantized mirror of [`matmul_prepacked_rows_cols`] (same compact
/// `c_rows` contract). Column panels are independent here too (each
/// panel's groups dequantize and accumulate ascending-k regardless of
/// which other panels run), so any panel range is bit-identical to the
/// same columns of the full-width kernel, which delegates here.
#[allow(clippy::too_many_arguments)]
pub fn matmul_quant_rows_cols(
    x: &[f32],
    rows: usize,
    w: &QuantMat,
    row_lo: usize,
    row_hi: usize,
    colpan_lo: usize,
    colpan_hi: usize,
    c_rows: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let (k, n) = (w.k, w.n);
    let npan = n.div_ceil(NR);
    assert!(row_lo <= row_hi && row_hi <= rows, "bad row range");
    assert!(colpan_lo <= colpan_hi && colpan_hi <= npan, "bad column-panel range");
    assert_eq!(x.len(), rows * k, "X shape mismatch");
    let col0 = colpan_lo * NR;
    let ncols = (colpan_hi * NR).min(n).saturating_sub(col0);
    assert_eq!(c_rows.len(), (row_hi - row_lo) * ncols, "C shape mismatch");
    if row_lo == row_hi || ncols == 0 {
        return;
    }
    assert_eq!(row_lo % MR, 0, "row_lo must be MR-aligned");
    let panels = (row_hi - row_lo).div_ceil(MR);
    // scratch = the shard's A panels (same `pack_a_shard` layout as
    // `matmul_prepacked_rows`) followed by one accumulator tile per
    // A panel for the current column panel.
    pack_a_shard(x, rows, k, row_lo, panels, scratch);
    scratch.resize(panels * MR * k + panels * MR * NR, 0.0);
    let (apack, accs) = scratch.split_at_mut(panels * MR * k);
    let mut wbuf = [0.0f32; QGROUP * NR];
    for jb in colpan_lo..colpan_hi {
        accs.fill(0.0);
        for g in 0..w.groups() {
            let glen = w.dequant_panel_group(jb, g, &mut wbuf);
            let k0 = g * w.group;
            for ib in 0..panels {
                let apan = &apack[(ib * k + k0) * MR..(ib * k + k0 + glen) * MR];
                let acc: &mut [f32; MR * NR] =
                    (&mut accs[ib * MR * NR..(ib + 1) * MR * NR]).try_into().unwrap();
                ukernel(apan, &wbuf[..glen * NR], glen, acc);
            }
        }
        // Write back this column panel's tiles (bounds-clipped).
        for ib in 0..panels {
            for i in 0..MR {
                let row = row_lo + ib * MR + i;
                if row >= row_hi {
                    break;
                }
                for j in 0..NR {
                    let col = jb * NR + j;
                    if col < n {
                        c_rows[(row - row_lo) * ncols + (col - col0)] =
                            accs[ib * MR * NR + i * NR + j];
                    }
                }
            }
        }
    }
}

/// The engine weight plane: an unquantized [`PackedMat`] or a
/// group-quantized [`QuantMat`] behind one dispatch, so the batched
/// engine shards its GEMMs identically in every `WeightQuant` mode
/// (same row-shard contract, same accumulation order per element).
#[derive(Debug, Clone)]
pub enum WeightMat {
    F32(PackedMat),
    Quant(QuantMat),
}

impl WeightMat {
    /// Pack (or quantize) a `[k, n]` weight tensor for `mode`.
    pub fn prepare(w: &Tensor, mode: WeightQuant) -> Self {
        match mode {
            WeightQuant::F32 => WeightMat::F32(PackedMat::pack(w)),
            _ => WeightMat::Quant(QuantMat::quantize(w, mode)),
        }
    }

    pub fn n(&self) -> usize {
        match self {
            WeightMat::F32(m) => m.n,
            WeightMat::Quant(m) => m.n,
        }
    }

    pub fn k(&self) -> usize {
        match self {
            WeightMat::F32(m) => m.k,
            WeightMat::Quant(m) => m.k,
        }
    }

    /// Stored bytes of the packed/quantized panels.
    pub fn bytes(&self) -> usize {
        match self {
            WeightMat::F32(m) => m.bytes(),
            WeightMat::Quant(m) => m.bytes(),
        }
    }

    /// Row-shard matmul: [`matmul_prepacked_rows`] or
    /// [`matmul_quant_rows`] (identical shard + determinism contract).
    pub fn matmul_rows(
        &self,
        x: &[f32],
        rows: usize,
        row_lo: usize,
        row_hi: usize,
        c_rows: &mut [f32],
        scratch: &mut Vec<f32>,
    ) {
        match self {
            WeightMat::F32(m) => matmul_prepacked_rows(x, rows, m, row_lo, row_hi, c_rows, scratch),
            WeightMat::Quant(m) => matmul_quant_rows(x, rows, m, row_lo, row_hi, c_rows, scratch),
        }
    }

    /// Number of NR-column panels (the unit the column-parallel serving
    /// layout shards: a `ShardSpec` group owns a contiguous panel range).
    pub fn col_panels(&self) -> usize {
        self.n().div_ceil(NR)
    }

    /// 2-D shard matmul: rows × NR-column-panel range into a compact
    /// local buffer ([`matmul_prepacked_rows_cols`] /
    /// [`matmul_quant_rows_cols`] — identical contract in both modes).
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_rows_cols(
        &self,
        x: &[f32],
        rows: usize,
        row_lo: usize,
        row_hi: usize,
        colpan_lo: usize,
        colpan_hi: usize,
        c_rows: &mut [f32],
        scratch: &mut Vec<f32>,
    ) {
        match self {
            WeightMat::F32(m) => matmul_prepacked_rows_cols(
                x, rows, m, row_lo, row_hi, colpan_lo, colpan_hi, c_rows, scratch,
            ),
            WeightMat::Quant(m) => matmul_quant_rows_cols(
                x, rows, m, row_lo, row_hi, colpan_lo, colpan_hi, c_rows, scratch,
            ),
        }
    }
}

/// Pack rows `[row_lo, row_lo + panels*MR)` of X (row-major
/// `[rows, k]`) into MR-row A panels: same layout and zero padding as
/// the matching slice of [`pack_a`]'s output. Shared by
/// [`matmul_prepacked_rows`] and [`matmul_quant_rows`] — both kernels'
/// bitwise shard-composition contract depends on this exact layout, so
/// it must not fork.
fn pack_a_shard(
    x: &[f32],
    rows: usize,
    k: usize,
    row_lo: usize,
    panels: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.reserve(panels * MR * k);
    for ib in 0..panels {
        for p in 0..k {
            for i in 0..MR {
                let row = row_lo + ib * MR + i;
                out.push(if row < rows { x[row * k + p] } else { 0.0 });
            }
        }
    }
}

/// Physical row of logical position `pos` under a paged block table.
#[inline]
pub fn paged_row(table: &[u32], block_size: usize, pos: usize) -> usize {
    table[pos / block_size] as usize * block_size + pos % block_size
}

/// Attention scores over a paged K store: for each logical position
/// `p < scores.len()`, gathers the K row through `table` (fixed-size
/// blocks of `block_size` positions) and computes
/// `scores[p] = dot(q, K[row(p)][head_off..head_off+head_dim]) * scale`.
/// Identical arithmetic order to the dense row-per-position path.
#[allow(clippy::too_many_arguments)]
pub fn attn_scores_paged(
    q: &[f32],
    kstore: &Tensor,
    table: &[u32],
    block_size: usize,
    head_off: usize,
    head_dim: usize,
    scale: f32,
    scores: &mut [f32],
) {
    debug_assert_eq!(q.len(), head_dim);
    for (p, score) in scores.iter_mut().enumerate() {
        let row = paged_row(table, block_size, p);
        let krow = &kstore.row(row)[head_off..head_off + head_dim];
        *score = dot(q, krow) * scale;
    }
}

/// Attention context over a paged V store: `out = Σ_p scores[p] * V[row(p)]`
/// accumulated in ascending position order (bit-identical to the dense
/// path's accumulation).
pub fn attn_context_paged(
    scores: &[f32],
    vstore: &Tensor,
    table: &[u32],
    block_size: usize,
    head_off: usize,
    head_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), head_dim);
    out.fill(0.0);
    attn_context_paged_accum(scores, vstore, table, block_size, head_off, head_dim, out);
}

/// As [`attn_context_paged`] but accumulating into `out` without zeroing
/// it first — the hot-suffix half of the tiered hybrid attention path,
/// where the cold-prefix contribution is already in `out`.
pub fn attn_context_paged_accum(
    scores: &[f32],
    vstore: &Tensor,
    table: &[u32],
    block_size: usize,
    head_off: usize,
    head_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), head_dim);
    for (p, &sc) in scores.iter().enumerate() {
        let row = paged_row(table, block_size, p);
        let vrow = &vstore.row(row)[head_off..head_off + head_dim];
        for (o, &vv) in out.iter_mut().zip(vrow) {
            *o += sc * vv;
        }
    }
}

/// Fused single-row paged attention over the **causal window**
/// `[0, scores.len())` — the in-chunk hot path of chunked prefill. One
/// step may commit a whole span of a sequence's rows to the paged store
/// before attention runs (phase order: commit, then attend), so the
/// store can hold positions *beyond* a given row's own; causality is
/// enforced structurally by sizing `scores` to the row's window
/// (`pos + 1` positions) — later rows of the chunk are never gathered,
/// because the kernel walks exactly `scores.len()` positions.
///
/// Arithmetic is `attn_scores_paged` → `softmax_inplace` →
/// `attn_context_paged`, each accumulating in ascending position /
/// ascending `k` order — so one chunked row is **bitwise identical** to
/// the same position computed by a sequential single-token step
/// (`rust/tests/properties.rs` pins both the equality and the
/// beyond-window blindness).
#[allow(clippy::too_many_arguments)]
pub fn attn_row_causal_paged(
    q: &[f32],
    kstore: &Tensor,
    vstore: &Tensor,
    table: &[u32],
    block_size: usize,
    head_off: usize,
    head_dim: usize,
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    debug_assert!(
        !scores.is_empty() && scores.len() <= table.len() * block_size,
        "causal window must be non-empty and inside the block table"
    );
    attn_scores_paged(q, kstore, table, block_size, head_off, head_dim, scale, scores);
    softmax_inplace(scores);
    attn_context_paged(scores, vstore, table, block_size, head_off, head_dim, out);
}

/// Per-block affine int8 quantization of the cold KV tier: `q[i]` codes
/// `src[i]` as `round((src[i] - zero) / scale) - 128`, with `zero` the
/// block minimum and `scale = (max - min) / 255`. Returns
/// `(scale, zero)`. Properties (pinned by `rust/tests/properties.rs`):
/// every element round-trips within `scale / 2`, and a constant block
/// (scale 0) round-trips exactly.
pub fn quantize_block_i8(src: &[f32], dst: &mut [i8]) -> (f32, f32) {
    assert_eq!(src.len(), dst.len());
    if src.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in src {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let scale = (hi - lo) / 255.0;
    if scale == 0.0 {
        // Constant block: store the value in the zero-point, exactly.
        dst.fill(-128);
        return (0.0, lo);
    }
    let inv = 1.0 / scale;
    for (q, &v) in dst.iter_mut().zip(src) {
        let code = ((v - lo) * inv).round().clamp(0.0, 255.0);
        *q = (code as i32 - 128) as i8;
    }
    (scale, lo)
}

/// Decode one int8 code of [`quantize_block_i8`].
#[inline]
pub fn dequant_i8(q: i8, scale: f32, zero: f32) -> f32 {
    zero + (q as f32 + 128.0) * scale
}

/// Dequantize a whole quantized block back to f32 (the cold-tier fetch
/// path: cold bytes -> hot fp32 rows).
pub fn dequantize_block_i8(q: &[i8], scale: f32, zero: f32, out: &mut [f32]) {
    assert_eq!(q.len(), out.len());
    for (o, &c) in out.iter_mut().zip(q) {
        *o = dequant_i8(c, scale, zero);
    }
}

/// Group-wise affine int8 over a flat slice: chunks of `group` elements
/// quantized independently through [`quantize_block_i8`] (last chunk
/// may be shorter). `scales`/`zeros` hold one pair per group
/// (`src.len().div_ceil(group)` groups). Properties (pinned by
/// `rust/tests/properties.rs`): per-group round trip within
/// `scales[g] / 2`, constant groups exact.
pub fn quantize_groups_i8(
    src: &[f32],
    group: usize,
    codes: &mut [i8],
    scales: &mut [f32],
    zeros: &mut [f32],
) {
    assert!(group > 0, "group must be positive");
    let groups = src.len().div_ceil(group);
    assert_eq!(codes.len(), src.len());
    assert_eq!(scales.len(), groups);
    assert_eq!(zeros.len(), groups);
    for g in 0..groups {
        let lo = g * group;
        let hi = (lo + group).min(src.len());
        let (s, z) = quantize_block_i8(&src[lo..hi], &mut codes[lo..hi]);
        scales[g] = s;
        zeros[g] = z;
    }
}

/// Inverse of [`quantize_groups_i8`].
pub fn dequantize_groups_i8(
    codes: &[i8],
    group: usize,
    scales: &[f32],
    zeros: &[f32],
    out: &mut [f32],
) {
    assert!(group > 0, "group must be positive");
    assert_eq!(codes.len(), out.len());
    let groups = codes.len().div_ceil(group);
    assert_eq!(scales.len(), groups);
    assert_eq!(zeros.len(), groups);
    for g in 0..groups {
        let lo = g * group;
        let hi = (lo + group).min(codes.len());
        dequantize_block_i8(&codes[lo..hi], scales[g], zeros[g], &mut out[lo..hi]);
    }
}

/// Affine int4 quantization of one block: codes `0..=15` (one per `dst`
/// byte, *unpacked* — see [`pack_i4`]), `zero` = block minimum,
/// `scale = (max - min) / 15`, value decodes as `zero + code * scale`.
/// Same contract as [`quantize_block_i8`]: round trip within
/// `scale / 2`, constant blocks (scale 0) exact via the zero-point.
pub fn quantize_block_i4(src: &[f32], dst: &mut [u8]) -> (f32, f32) {
    assert_eq!(src.len(), dst.len());
    if src.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in src {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let scale = (hi - lo) / 15.0;
    if scale == 0.0 {
        dst.fill(0);
        return (0.0, lo);
    }
    let inv = 1.0 / scale;
    for (q, &v) in dst.iter_mut().zip(src) {
        *q = ((v - lo) * inv).round().clamp(0.0, 15.0) as u8;
    }
    (scale, lo)
}

/// Decode one int4 code of [`quantize_block_i4`].
#[inline]
pub fn dequant_i4(q: u8, scale: f32, zero: f32) -> f32 {
    zero + q as f32 * scale
}

/// Dequantize a whole block of unpacked int4 codes back to f32.
pub fn dequantize_block_i4(q: &[u8], scale: f32, zero: f32, out: &mut [f32]) {
    assert_eq!(q.len(), out.len());
    for (o, &c) in out.iter_mut().zip(q) {
        *o = dequant_i4(c, scale, zero);
    }
}

/// Pack unpacked int4 codes (`0..=15`, one per byte) two per byte:
/// `out[b] = codes[2b] | codes[2b+1] << 4` (odd tail leaves the high
/// nibble 0). `out.len() == codes.len().div_ceil(2)`. [`unpack_i4`]
/// inverts this exactly (pinned by `rust/tests/properties.rs`).
pub fn pack_i4(codes: &[u8], out: &mut [u8]) {
    assert_eq!(out.len(), codes.len().div_ceil(2));
    for (b, o) in out.iter_mut().enumerate() {
        debug_assert!(codes[2 * b] < 16, "int4 code out of range");
        let hi = if 2 * b + 1 < codes.len() { codes[2 * b + 1] } else { 0 };
        debug_assert!(hi < 16, "int4 code out of range");
        *o = codes[2 * b] | (hi << 4);
    }
}

/// Unpack `n` int4 codes packed by [`pack_i4`] back to one byte each.
pub fn unpack_i4(packed: &[u8], n: usize, out: &mut [u8]) {
    assert_eq!(packed.len(), n.div_ceil(2));
    assert_eq!(out.len(), n);
    for (i, o) in out.iter_mut().enumerate() {
        let byte = packed[i / 2];
        *o = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
    }
}

/// Group-wise affine int4 over a flat slice, packed two codes per byte
/// per group ([`pack_i4`] per group, so groups stay independently
/// addressable). `group` must be even so group payloads stay
/// byte-aligned; `packed.len() == src.len().div_ceil(2)`.
pub fn quantize_groups_i4(
    src: &[f32],
    group: usize,
    packed: &mut [u8],
    scales: &mut [f32],
    zeros: &mut [f32],
) {
    assert!(group > 0 && group % 2 == 0, "group must be positive and even");
    let groups = src.len().div_ceil(group);
    assert_eq!(packed.len(), src.len().div_ceil(2));
    assert_eq!(scales.len(), groups);
    assert_eq!(zeros.len(), groups);
    let mut cbuf = vec![0u8; group];
    for g in 0..groups {
        let lo = g * group;
        let hi = (lo + group).min(src.len());
        let (s, z) = quantize_block_i4(&src[lo..hi], &mut cbuf[..hi - lo]);
        scales[g] = s;
        zeros[g] = z;
        pack_i4(&cbuf[..hi - lo], &mut packed[lo / 2..lo / 2 + (hi - lo).div_ceil(2)]);
    }
}

/// Inverse of [`quantize_groups_i4`].
pub fn dequantize_groups_i4(
    packed: &[u8],
    n: usize,
    group: usize,
    scales: &[f32],
    zeros: &[f32],
    out: &mut [f32],
) {
    assert!(group > 0 && group % 2 == 0, "group must be positive and even");
    assert_eq!(packed.len(), n.div_ceil(2));
    assert_eq!(out.len(), n);
    let groups = n.div_ceil(group);
    assert_eq!(scales.len(), groups);
    assert_eq!(zeros.len(), groups);
    let mut cbuf = vec![0u8; group];
    for g in 0..groups {
        let lo = g * group;
        let hi = (lo + group).min(n);
        unpack_i4(&packed[lo / 2..lo / 2 + (hi - lo).div_ceil(2)], hi - lo, &mut cbuf[..hi - lo]);
        dequantize_block_i4(&cbuf[..hi - lo], scales[g], zeros[g], &mut out[lo..hi]);
    }
}

/// Attention scores over one *quantized* cold KV block read in place
/// (dequant-gather): for each of `rows` positions,
/// `scores[r] = dot(q, dq(K_q[r][head_off..head_off+head_dim])) * scale`
/// with per-element dequantization — no fp32 materialization of the
/// block. Used when a sequence is mostly cold and fetching it into the
/// hot tier would not pay.
#[allow(clippy::too_many_arguments)]
pub fn attn_scores_quant_i8(
    q: &[f32],
    kq: &[i8],
    qscale: f32,
    qzero: f32,
    rows: usize,
    width: usize,
    head_off: usize,
    head_dim: usize,
    scale: f32,
    scores: &mut [f32],
) {
    debug_assert_eq!(q.len(), head_dim);
    debug_assert!(rows * width <= kq.len());
    debug_assert_eq!(scores.len(), rows);
    for (r, s) in scores.iter_mut().enumerate() {
        let krow = &kq[r * width + head_off..r * width + head_off + head_dim];
        let mut acc = 0.0f32;
        for (x, &c) in q.iter().zip(krow) {
            acc += x * dequant_i8(c, qscale, qzero);
        }
        *s = acc * scale;
    }
}

/// Context accumulation over one quantized cold V block (dequant-gather):
/// `out += Σ_r scores[r] * dq(V_q[r][head_off..])`, ascending position
/// order. Accumulates — the caller zeroes `out` before the first cold
/// block and chains the hot suffix with [`attn_context_paged_accum`].
#[allow(clippy::too_many_arguments)]
pub fn attn_context_quant_i8(
    scores: &[f32],
    vq: &[i8],
    qscale: f32,
    qzero: f32,
    width: usize,
    head_off: usize,
    head_dim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), head_dim);
    debug_assert!(scores.len() * width <= vq.len());
    for (r, &sc) in scores.iter().enumerate() {
        let vrow = &vq[r * width + head_off..r * width + head_off + head_dim];
        for (o, &c) in out.iter_mut().zip(vrow) {
            *o += sc * dequant_i8(c, qscale, qzero);
        }
    }
}

/// Element-wise exp (vector-friendly loop).
pub fn exp_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.exp();
    }
}

/// SiLU: x * sigmoid(x).
pub fn silu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
}

/// Row-wise softmax over the last axis.
pub fn softmax_rows(x: &mut Tensor) {
    let cols = *x.shape.0.last().unwrap();
    let rows = x.numel() / cols;
    for r in 0..rows {
        let row = &mut x.data[r * cols..(r + 1) * cols];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Softmax over a slice (single row).
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm over the last axis: `x / rms(x) * w`.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), w.len());
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &g) in out.iter_mut().zip(x).zip(w) {
        *o = v * inv * g;
    }
}

/// Rotary position embedding on one head row (interleaved-half
/// convention, matching the JAX reference in python/compile/ref.py).
pub fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let freq = 1.0 / theta.powf(2.0 * i as f32 / d as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

/// Embedding row gather.
pub fn gather_rows(table: &Tensor, ids: &[usize]) -> Tensor {
    let h = table.dim(1);
    let mut out = Tensor::zeros(&[ids.len(), h]);
    for (r, &id) in ids.iter().enumerate() {
        out.row_mut(r).copy_from_slice(table.row(id));
    }
    out
}

/// `out += x` elementwise.
pub fn add_inplace(out: &mut [f32], x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o += v;
    }
}

/// `out *= x` elementwise.
pub fn mul_inplace(out: &mut [f32], x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o *= v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn rows_cols_shard_is_bitwise_equal_to_full_width() {
        // The column-parallel serving layout: any (row range × column-
        // panel range) tiling must reassemble into exactly the bits of
        // the full-width kernel, for both weight-plane modes.
        let mut rng = Rng::new(21);
        let (rows, k, n) = (9, 64, 72); // n = 4.5 NR panels: clipped tail
        let x = Tensor::randn(&[rows, k], &mut rng, 1.0);
        let wt = Tensor::randn(&[k, n], &mut rng, 1.0);
        for mode in [WeightQuant::F32, WeightQuant::Int8] {
            let w = WeightMat::prepare(&wt, mode);
            let mut scratch = Vec::new();
            let mut full = vec![0.0f32; rows * n];
            w.matmul_rows(&x.data, rows, 0, rows, &mut full, &mut scratch);
            let npan = w.col_panels();
            for shards in [1usize, 2, 3, 4] {
                for lanes in [1usize, 2] {
                    let mut got = vec![f32::NAN; rows * n];
                    for g in 0..shards {
                        let (cp0, cp1) = crate::parallel::splits(npan, shards)[g];
                        let col0 = cp0 * NR;
                        let ncols = (cp1 * NR).min(n).saturating_sub(col0);
                        for l in 0..lanes {
                            let (r0, r1) = crate::parallel::panel_splits(rows, MR, lanes)[l];
                            let mut local = vec![0.0f32; (r1 - r0) * ncols];
                            w.matmul_rows_cols(
                                &x.data,
                                rows,
                                r0,
                                r1,
                                cp0,
                                cp1,
                                &mut local,
                                &mut scratch,
                            );
                            for r in r0..r1 {
                                got[r * n + col0..r * n + col0 + ncols].copy_from_slice(
                                    &local[(r - r0) * ncols..(r - r0 + 1) * ncols],
                                );
                            }
                        }
                    }
                    assert!(
                        got.iter().zip(&full).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "2-D shard diverged at shards={shards} lanes={lanes} mode={}",
                        mode.name()
                    );
                }
            }
        }
    }

    #[test]
    fn rows_cols_empty_ranges_are_noops() {
        let mut rng = Rng::new(5);
        let (rows, k, n) = (4, 16, 32);
        let x = Tensor::randn(&[rows, k], &mut rng, 1.0);
        let wt = Tensor::randn(&[k, n], &mut rng, 1.0);
        let w = WeightMat::prepare(&wt, WeightQuant::F32);
        let mut scratch = Vec::new();
        let mut empty: Vec<f32> = Vec::new();
        // Empty column-panel range; unaligned row_lo is legal when empty.
        w.matmul_rows_cols(&x.data, rows, 1, 1, 1, 1, &mut empty, &mut scratch);
        w.matmul_rows_cols(&x.data, rows, 0, rows, 2, 2, &mut empty, &mut scratch);
        assert!(empty.is_empty());
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(4, 4, 4), (7, 13, 5), (64, 64, 64), (33, 17, 49)] {
            let a = Tensor::randn(&[m, k], &mut rng, 1.0);
            let b = Tensor::randn(&[k, n], &mut rng, 1.0);
            let c0 = matmul_naive(&a, &b);
            let c1 = matmul_blocked(&a, &b);
            assert!(
                c0.max_abs_diff(&c1) < 1e-4,
                "blocked vs naive mismatch at ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn range_partition_composes() {
        // Computing [0,m) in two halves equals the full result.
        let mut rng = Rng::new(3);
        let (m, k, n) = (16, 24, 32);
        let a = Tensor::randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::randn(&[k, n], &mut rng, 1.0);
        let (mut ap, mut bp) = (Vec::new(), Vec::new());
        pack_a(&a.data, m, k, &mut ap);
        pack_b(&b.data, k, n, &mut bp);
        let mut c = Tensor::zeros(&[m, n]);
        matmul_packed_range(&ap, &bp, m, k, n, 0, 8, &mut c.data);
        matmul_packed_range(&ap, &bp, m, k, n, 8, 16, &mut c.data);
        let want = matmul_naive(&a, &b);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gemv_matches_matmul() {
        let mut rng = Rng::new(9);
        let (k, n) = (48, 40);
        let x = Tensor::randn(&[1, k], &mut rng, 1.0);
        let w = Tensor::randn(&[k, n], &mut rng, 1.0);
        let want = matmul_naive(&x, &w);
        let mut y = vec![0.0; n];
        gemv(&x.data, &w, &mut y);
        for (a, b) in y.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4);
        }
        // Column-partitioned variant composes.
        let mut y1 = vec![0.0; 16];
        let mut y2 = vec![0.0; n - 16];
        gemv_cols(&x.data, &w, 0, 16, &mut y1);
        gemv_cols(&x.data, &w, 16, n, &mut y2);
        let joined: Vec<f32> = y1.into_iter().chain(y2).collect();
        for (a, b) in joined.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn prepacked_matches_naive_and_gemv_bitwise() {
        let mut rng = Rng::new(21);
        for &(m, k, n) in &[(1usize, 48, 40), (5, 33, 17), (16, 64, 96)] {
            let x = Tensor::randn(&[m, k], &mut rng, 1.0);
            let w = Tensor::randn(&[k, n], &mut rng, 1.0);
            let pm = PackedMat::pack(&w);
            let mut c = vec![0.0f32; m * n];
            matmul_prepacked(&x.data, m, &pm, &mut c);
            let want = matmul_naive(&x, &w);
            for (a, b) in c.iter().zip(&want.data) {
                assert!((a - b).abs() < 1e-4);
            }
            // The decode-path contract: row 0 equals gemv_cols exactly
            // (same per-column accumulation order over k).
            let mut y = vec![0.0f32; n];
            gemv_cols(&x.data[..k], &w, 0, n, &mut y);
            assert_eq!(&c[..n], &y[..], "prepacked row 0 must be bit-identical to gemv");
        }
    }

    #[test]
    fn prepacked_row_ranges_compose_bitwise() {
        // Any MR-aligned partitioning of the M dimension must reproduce
        // the full matmul bit-for-bit — the determinism contract of the
        // multi-threaded batched decode path.
        let mut rng = Rng::new(77);
        for &(rows, k, n) in &[(16usize, 48, 40), (10, 33, 17), (3, 24, 96)] {
            let x = Tensor::randn(&[rows, k], &mut rng, 1.0);
            let w = Tensor::randn(&[k, n], &mut rng, 1.0);
            let pm = PackedMat::pack(&w);
            let mut want = vec![0.0f32; rows * n];
            matmul_prepacked(&x.data, rows, &pm, &mut want);
            for parts in [1usize, 2, 3, 5] {
                let shards = crate::parallel::panel_splits(rows, MR, parts);
                let mut got = vec![0.0f32; rows * n];
                let mut scratch = Vec::new();
                for &(lo, hi) in &shards {
                    matmul_prepacked_rows(
                        &x.data,
                        rows,
                        &pm,
                        lo,
                        hi,
                        &mut got[lo * n..hi * n],
                        &mut scratch,
                    );
                }
                assert_eq!(got, want, "({rows},{k},{n}) x {parts} shards diverged");
            }
        }
    }

    #[test]
    fn paged_attention_matches_contiguous() {
        let mut rng = Rng::new(33);
        let (block_size, width, head_dim, head_off) = (4usize, 16usize, 8usize, 8usize);
        let seq = 11usize; // 3 blocks, last partially filled
        // Contiguous store: position p at row p.
        let dense = Tensor::randn(&[16, width], &mut rng, 1.0);
        // Paged store: blocks scattered through a larger arena.
        let table: Vec<u32> = vec![5, 2, 7];
        let mut paged = Tensor::zeros(&[10 * block_size, width]);
        for p in 0..seq {
            let row = paged_row(&table, block_size, p);
            paged.row_mut(row).copy_from_slice(dense.row(p));
        }
        let q: Vec<f32> = (0..head_dim).map(|_| rng.normal()).collect();
        let scale = 0.25f32;

        let mut want_scores = vec![0.0f32; seq];
        for (p, s) in want_scores.iter_mut().enumerate() {
            *s = dot(&q, &dense.row(p)[head_off..head_off + head_dim]) * scale;
        }
        let mut got_scores = vec![0.0f32; seq];
        attn_scores_paged(
            &q,
            &paged,
            &table,
            block_size,
            head_off,
            head_dim,
            scale,
            &mut got_scores,
        );
        assert_eq!(want_scores, got_scores);

        let mut want_ctx = vec![0.0f32; head_dim];
        for (p, &sc) in want_scores.iter().enumerate() {
            for (o, &vv) in want_ctx.iter_mut().zip(&dense.row(p)[head_off..head_off + head_dim]) {
                *o += sc * vv;
            }
        }
        let mut got_ctx = vec![0.0f32; head_dim];
        attn_context_paged(
            &want_scores,
            &paged,
            &table,
            block_size,
            head_off,
            head_dim,
            &mut got_ctx,
        );
        assert_eq!(want_ctx, got_ctx);
    }

    #[test]
    fn quant_matmul_is_bitwise_identical_to_dequant_oracle() {
        // The fused dequant-GEMM contract: matmul over a QuantMat must
        // equal matmul_prepacked over PackedMat::pack(dequantize()) bit
        // for bit — the quantized path changes the weight *bytes
        // streamed*, never the arithmetic — and any MR-aligned row
        // partition must compose bitwise (the SPMD shard contract).
        let mut rng = Rng::new(91);
        for mode in [WeightQuant::Int8, WeightQuant::Int4] {
            for &(rows, k, n) in &[(1usize, 48, 40), (5, 33, 17), (16, 64, 96), (10, 100, 24)] {
                let x = Tensor::randn(&[rows, k], &mut rng, 1.0);
                let w = Tensor::randn(&[k, n], &mut rng, 0.05);
                let qm = QuantMat::quantize(&w, mode);
                let pm = PackedMat::pack(&qm.dequantize());
                let mut want = vec![0.0f32; rows * n];
                matmul_prepacked(&x.data, rows, &pm, &mut want);
                let mut scratch = Vec::new();
                let mut got = vec![0.0f32; rows * n];
                matmul_quant_rows(&x.data, rows, &qm, 0, rows, &mut got, &mut scratch);
                assert_eq!(got, want, "{mode:?} ({rows},{k},{n}) fused != dequant oracle");
                for parts in [2usize, 3] {
                    let shards = crate::parallel::panel_splits(rows, MR, parts);
                    let mut sharded = vec![0.0f32; rows * n];
                    for &(lo, hi) in &shards {
                        matmul_quant_rows(
                            &x.data,
                            rows,
                            &qm,
                            lo,
                            hi,
                            &mut sharded[lo * n..hi * n],
                            &mut scratch,
                        );
                    }
                    assert_eq!(sharded, want, "{mode:?} {parts}-way shard diverged");
                }
            }
        }
    }

    #[test]
    fn quant_mat_bytes_shrink_with_mode() {
        let mut rng = Rng::new(92);
        let w = Tensor::randn(&[128, 96], &mut rng, 0.05);
        let f32b = WeightMat::prepare(&w, WeightQuant::F32).bytes();
        let i8b = WeightMat::prepare(&w, WeightQuant::Int8).bytes();
        let i4b = WeightMat::prepare(&w, WeightQuant::Int4).bytes();
        assert!(i8b * 3 < f32b, "int8 panels must be well under a third of f32: {i8b}/{f32b}");
        assert!(i4b < i8b, "int4 panels must be under int8: {i4b}/{i8b}");
        // The config-level accounting agrees on the ordering too.
        let m8 = WeightQuant::Int8.matrix_bytes(128, 96, 4);
        let m4 = WeightQuant::Int4.matrix_bytes(128, 96, 4);
        assert_eq!(WeightQuant::F32.matrix_bytes(128, 96, 4), 128 * 96 * 4);
        assert!(m4 < m8 && m8 * 3 < 128 * 96 * 4);
    }

    #[test]
    fn int4_pack_unpack_identity_and_roundtrip() {
        let mut rng = Rng::new(93);
        for n in [1usize, 2, 7, 32, 63] {
            let codes: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
            let mut packed = vec![0u8; n.div_ceil(2)];
            pack_i4(&codes, &mut packed);
            let mut back = vec![0u8; n];
            unpack_i4(&packed, n, &mut back);
            assert_eq!(codes, back, "pack/unpack must be the identity at n={n}");
        }
        // Affine round trip within scale/2; constant block exact.
        let src: Vec<f32> = (0..96).map(|_| rng.normal() * 0.5).collect();
        let mut q = vec![0u8; src.len()];
        let (scale, zero) = quantize_block_i4(&src, &mut q);
        let mut out = vec![0.0f32; src.len()];
        dequantize_block_i4(&q, scale, zero, &mut out);
        for (a, b) in src.iter().zip(&out) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6, "err {} > scale/2 {scale}", a - b);
        }
        let cst = vec![-1.5f32; 10];
        let mut qc = vec![0u8; 10];
        let (s, z) = quantize_block_i4(&cst, &mut qc);
        assert_eq!(s, 0.0);
        let mut back = vec![0.0f32; 10];
        dequantize_block_i4(&qc, s, z, &mut back);
        assert_eq!(back, cst);
    }

    #[test]
    fn group_quant_helpers_roundtrip() {
        let mut rng = Rng::new(94);
        let src: Vec<f32> = (0..100).map(|_| rng.normal() * 2.0).collect();
        let groups = src.len().div_ceil(QGROUP);
        let (mut scales, mut zeros) = (vec![0.0f32; groups], vec![0.0f32; groups]);
        let mut codes = vec![0i8; src.len()];
        quantize_groups_i8(&src, QGROUP, &mut codes, &mut scales, &mut zeros);
        let mut back = vec![0.0f32; src.len()];
        dequantize_groups_i8(&codes, QGROUP, &scales, &zeros, &mut back);
        for (g, (a, b)) in src.iter().zip(&back).enumerate() {
            let bound = scales[g / QGROUP] * 0.5 + 1e-5;
            assert!((a - b).abs() <= bound, "elem {g}: |{a}-{b}| > {bound}");
        }
        let mut packed = vec![0u8; src.len().div_ceil(2)];
        quantize_groups_i4(&src, QGROUP, &mut packed, &mut scales, &mut zeros);
        let mut back4 = vec![0.0f32; src.len()];
        dequantize_groups_i4(&packed, src.len(), QGROUP, &scales, &zeros, &mut back4);
        for (g, (a, b)) in src.iter().zip(&back4).enumerate() {
            let bound = scales[g / QGROUP] * 0.5 + 1e-5;
            assert!((a - b).abs() <= bound, "int4 elem {g}: |{a}-{b}| > {bound}");
        }
    }

    #[test]
    fn quant_roundtrip_and_constant_blocks() {
        let mut rng = Rng::new(71);
        let src: Vec<f32> = (0..256).map(|_| rng.normal() * 3.0).collect();
        let mut q = vec![0i8; src.len()];
        let (scale, zero) = quantize_block_i8(&src, &mut q);
        let mut back = vec![0.0f32; src.len()];
        dequantize_block_i8(&q, scale, zero, &mut back);
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6, "err {} > scale/2 {}", a - b, scale);
        }
        // Constant block: exact round trip via the zero-point.
        let cst = vec![4.25f32; 64];
        let mut qc = vec![0i8; 64];
        let (s, z) = quantize_block_i8(&cst, &mut qc);
        assert_eq!(s, 0.0);
        let mut out = vec![0.0f32; 64];
        dequantize_block_i8(&qc, s, z, &mut out);
        assert_eq!(out, cst);
    }

    #[test]
    fn quant_attention_matches_dequantized_reference() {
        // The dequant-gather kernels must agree with "dequantize the
        // block, then run the paged fp32 kernels" — the direct cold read
        // is an I/O optimization, not a different computation.
        let mut rng = Rng::new(44);
        let (bs, width, hd, off) = (4usize, 16usize, 8usize, 8usize);
        let block = Tensor::randn(&[bs, width], &mut rng, 1.0);
        let mut kq = vec![0i8; bs * width];
        let (scale, zero) = quantize_block_i8(&block.data, &mut kq);
        let mut deq = Tensor::zeros(&[bs, width]);
        dequantize_block_i8(&kq, scale, zero, &mut deq.data);

        let q: Vec<f32> = (0..hd).map(|_| rng.normal()).collect();
        let table = [0u32];
        let mut want = vec![0.0f32; bs];
        attn_scores_paged(&q, &deq, &table, bs, off, hd, 0.5, &mut want);
        let mut got = vec![0.0f32; bs];
        attn_scores_quant_i8(&q, &kq, scale, zero, bs, width, off, hd, 0.5, &mut got);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5, "scores diverged: {a} vs {b}");
        }

        let mut want_ctx = vec![0.0f32; hd];
        attn_context_paged(&want, &deq, &table, bs, off, hd, &mut want_ctx);
        let mut got_ctx = vec![0.0f32; hd];
        attn_context_quant_i8(&want, &kq, scale, zero, width, off, hd, &mut got_ctx);
        for (a, b) in want_ctx.iter().zip(&got_ctx) {
            assert!((a - b).abs() < 1e-5, "context diverged: {a} vs {b}");
        }
    }

    #[test]
    fn context_accum_composes_with_zeroing_variant() {
        let mut rng = Rng::new(45);
        let (bs, width, hd) = (4usize, 8usize, 8usize);
        let v = Tensor::randn(&[2 * bs, width], &mut rng, 1.0);
        let scores: Vec<f32> = (0..2 * bs).map(|_| rng.normal()).collect();
        let table = [0u32, 1];
        let mut want = vec![0.0f32; hd];
        attn_context_paged(&scores, &v, &table, bs, 0, hd, &mut want);
        // Split: first block via the zeroing variant, second accumulated.
        let mut got = vec![0.0f32; hd];
        attn_context_paged(&scores[..bs], &v, &table[..1], bs, 0, hd, &mut got);
        attn_context_paged_accum(&scores[bs..], &v, &table[1..], bs, 0, hd, &mut got);
        assert_eq!(want, got, "piecewise accumulation must be bit-identical");
    }

    #[test]
    fn causal_row_kernel_is_blind_beyond_its_window() {
        // Chunked prefill commits a whole span before attention runs, so
        // the paged store holds positions past a given row's own. The
        // fused causal row kernel must (a) equal the scores → softmax →
        // context composition bitwise, and (b) produce the same result
        // whether or not the store holds data beyond the window.
        let mut rng = Rng::new(55);
        let (bs, width, hd, off) = (4usize, 16usize, 8usize, 8usize);
        let table = [2u32, 0, 3];
        let chunk_end = 10usize; // positions 0..10 are "committed"
        let mut store_k = Tensor::zeros(&[4 * bs, width]);
        let mut store_v = Tensor::zeros(&[4 * bs, width]);
        for p in 0..chunk_end {
            let row = paged_row(&table, bs, p);
            for c in 0..width {
                store_k.row_mut(row)[c] = rng.normal();
                store_v.row_mut(row)[c] = rng.normal();
            }
        }
        let q: Vec<f32> = (0..hd).map(|_| rng.normal()).collect();
        for seq in 1..=chunk_end {
            let mut scores = vec![0.0f32; seq];
            let mut out = vec![0.0f32; hd];
            attn_row_causal_paged(
                &q, &store_k, &store_v, &table, bs, off, hd, 0.5, &mut scores, &mut out,
            );
            // (a) bitwise equal to the composed sequential-step path.
            let mut want_scores = vec![0.0f32; seq];
            attn_scores_paged(&q, &store_k, &table, bs, off, hd, 0.5, &mut want_scores);
            softmax_inplace(&mut want_scores);
            let mut want_out = vec![0.0f32; hd];
            attn_context_paged(&want_scores, &store_v, &table, bs, off, hd, &mut want_out);
            assert_eq!(out, want_out, "fused causal row != composition at seq {seq}");
            // (b) clobbering every position >= seq changes nothing: the
            // window, not the store contents, bounds the gather.
            let (mut k2, mut v2) = (store_k.clone(), store_v.clone());
            for p in seq..table.len() * bs {
                let row = paged_row(&table, bs, p);
                k2.row_mut(row).fill(f32::MAX);
                v2.row_mut(row).fill(f32::MAX);
            }
            let mut scores2 = vec![0.0f32; seq];
            let mut out2 = vec![0.0f32; hd];
            attn_row_causal_paged(
                &q, &k2, &v2, &table, bs, off, hd, 0.5, &mut scores2, &mut out2,
            );
            assert_eq!(out, out2, "future positions leaked into the causal window at {seq}");
        }
    }

    #[test]
    fn paged_row_mapping() {
        let table = [9u32, 0, 4];
        assert_eq!(paged_row(&table, 8, 0), 72);
        assert_eq!(paged_row(&table, 8, 7), 79);
        assert_eq!(paged_row(&table, 8, 8), 0);
        assert_eq!(paged_row(&table, 8, 17), 33);
    }

    #[test]
    fn softmax_normalizes() {
        let mut t = Tensor::from_vec(&[2, 4], vec![1., 2., 3., 4., -1., 0., 1., 2.]);
        softmax_rows(&mut t);
        for r in 0..2 {
            let s: f32 = t.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(t.row(r).iter().all(|&v| v > 0.0));
        }
        // Monotone: bigger logit, bigger prob.
        assert!(t.data[3] > t.data[2]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32; 8];
        let w = vec![1.0f32; 8];
        let mut out = vec![0.0; 8];
        rmsnorm(&x, &w, 1e-6, &mut out);
        // rms(x) == 3, so out ≈ 1.
        for v in out {
            assert!((v - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn rope_preserves_norm_and_pos0_identity() {
        let mut rng = Rng::new(1);
        let orig: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        rope_inplace(&mut x, 0, 10000.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6, "pos 0 must be identity");
        }
        let mut y = orig.clone();
        rope_inplace(&mut y, 17, 10000.0);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = y.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4, "rotation preserves norm");
    }

    #[test]
    fn gather_and_elementwise() {
        let table = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let g = gather_rows(&table, &[2, 0]);
        assert_eq!(g.data, vec![20., 21., 0., 1.]);
        let mut a = vec![1.0, 2.0];
        add_inplace(&mut a, &[10.0, 20.0]);
        assert_eq!(a, vec![11.0, 22.0]);
        mul_inplace(&mut a, &[2.0, 0.5]);
        assert_eq!(a, vec![22.0, 11.0]);
        let mut s = vec![0.5f32, -0.5];
        silu_inplace(&mut s);
        assert!((s[0] - 0.5 / (1.0 + (-0.5f32).exp())).abs() < 1e-6);
    }
}
