//! The nncase Tensor Template library analog (§3.3.2).
//!
//! The C++20 NTT library of the paper supplies register-level μkernels
//! that the generated code instantiates. Here the same role is played by
//! a small Rust kernel library:
//!
//! * [`tensor`] — a dense f32 tensor with shape/strides (the hybrid
//!   static/dynamic shape system collapses to dynamic shapes in Rust;
//!   the static-inference side lives in the L1 Pallas kernel where block
//!   shapes are compile-time constants).
//! * [`kernels`] — blocked/packed matmul (GotoBLAS-style register
//!   tiling), exp/silu, softmax, RMSNorm, RoPE, pack/unpack and gather.
//! * [`ukt`] — the μKernelTime linear-regression model (Eq. 15) with a
//!   runtime calibration hook.
//!
//! These kernels are the *real execution* backend of the coordinator; the
//! same computation is validated against the JAX reference through the
//! PJRT artifacts (python/tests + rust/tests).

mod kernels;
mod tensor;
mod ukt;

pub use kernels::*;
pub use tensor::Tensor;
pub use ukt::{calibrate_ukt, UKernelModel};
