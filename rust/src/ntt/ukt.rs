//! μKernelTime: the linear-regression μkernel time model of Eq. 15,
//! `μKT(op) = overhead + flops / effective_rate`, with an optional
//! runtime calibration pass that fits both coefficients from measured
//! blocked matmuls.

use super::{matmul_blocked, Tensor};
use crate::util::Rng;

/// Linear μkernel time model.
#[derive(Debug, Clone, Copy)]
pub struct UKernelModel {
    /// Per-call overhead, seconds.
    pub overhead_s: f64,
    /// Effective FLOP/s of the inner loop.
    pub flops_per_s: f64,
}

impl UKernelModel {
    /// Predicted time of a μkernel call doing `flops` FLOPs.
    pub fn time_s(&self, flops: u64) -> f64 {
        self.overhead_s + flops as f64 / self.flops_per_s
    }

    /// A conservative default for machines we cannot measure on.
    pub fn default_for(machine: &crate::cost::MachineSpec) -> Self {
        UKernelModel { overhead_s: 40e-9, flops_per_s: machine.peak_flops(1, 4) * 0.5 }
    }
}

/// Calibrate the model by timing blocked matmuls of increasing size and
/// least-squares fitting `t = a + b * flops`.
pub fn calibrate_ukt(reps: usize) -> UKernelModel {
    let sizes = [8usize, 16, 32, 64, 96, 128];
    let mut rng = Rng::new(0xCAFE);
    let mut xs = Vec::new(); // flops
    let mut ys = Vec::new(); // seconds per call
    for &s in &sizes {
        let a = Tensor::randn(&[s, s], &mut rng, 1.0);
        let b = Tensor::randn(&[s, s], &mut rng, 1.0);
        // Warm up.
        let _ = matmul_blocked(&a, &b);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(matmul_blocked(&a, &b));
        }
        let per_call = t0.elapsed().as_secs_f64() / reps as f64;
        xs.push((2 * s * s * s) as f64);
        ys.push(per_call);
    }
    // Least squares.
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(&ys).map(|(x, y)| x * y).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let intercept = (sy - slope * sx) / n;
    UKernelModel {
        overhead_s: intercept.max(1e-9),
        flops_per_s: (1.0 / slope.max(1e-15)).clamp(1e8, 1e13),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_predicts_monotone_times() {
        let m = UKernelModel { overhead_s: 1e-7, flops_per_s: 1e10 };
        assert!(m.time_s(1000) < m.time_s(1_000_000));
        assert!((m.time_s(0) - 1e-7).abs() < 1e-12);
    }

    #[test]
    fn calibration_returns_sane_coefficients() {
        let m = calibrate_ukt(2);
        assert!(m.overhead_s > 0.0 && m.overhead_s < 1e-3);
        assert!(m.flops_per_s > 1e7, "rate {} too low", m.flops_per_s);
    }
}
