//! Dense f32 tensors for the NTT execution backend.

use crate::ir::Shape;
use crate::util::Rng;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::of(dims);
        Tensor { data: vec![0.0; shape.numel()], shape }
    }

    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Self {
        let shape = Shape::of(dims);
        assert_eq!(shape.numel(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    /// Deterministic random-normal tensor scaled like typical weight init.
    pub fn randn(dims: &[usize], rng: &mut Rng, scale: f32) -> Self {
        let shape = Shape::of(dims);
        let data = (0..shape.numel()).map(|_| rng.normal() * scale).collect();
        Tensor { shape, data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn dim(&self, i: usize) -> usize {
        self.shape.0[i]
    }

    /// Last-axis row view.
    pub fn row(&self, r: usize) -> &[f32] {
        let cols = *self.shape.0.last().unwrap();
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let cols = *self.shape.0.last().unwrap();
        &mut self.data[r * cols..(r + 1) * cols]
    }

    /// Reshape view (copy-free since data is owned contiguous).
    pub fn reshaped(mut self, dims: &[usize]) -> Tensor {
        let shape = Shape::of(dims);
        assert_eq!(shape.numel(), self.numel());
        self.shape = shape;
        self
    }

    /// Max |a - b| between two tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_rows() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = Tensor::randn(&[16], &mut r1, 0.02);
        let b = Tensor::randn(&[16], &mut r2, 0.02);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
