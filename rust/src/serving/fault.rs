//! Deterministic, seeded failpoint registry for the serving path.
//!
//! A [`FaultPlan`] names up to one fault per injection site; every
//! trigger is a *one-shot* (it fires once and disarms) so a recovered
//! run converges instead of dying in a crash loop. Sites:
//!
//! * **Worker panic** — a participant of the SPMD phase loop panics at
//!   phase `P` of engine iteration `N` (optionally pinned to worker
//!   `W`). The poisonable `SpinBarrier` turns this into a loud
//!   crash of the whole scope; `coordinator/serve.rs` catches it,
//!   audits the scheduler/pool invariants and restarts the epoch.
//! * **Cold-tier fetch failure** — the `nth` cold→hot fetch reports a
//!   transient I/O-style failure; the owning sequence is reclassified
//!   swap→recompute through the existing preemption fallback.
//! * **Cold-tier corruption** — the payload of the `nth` hot→cold
//!   spill is flipped after its FNV-1a checksum was recorded, so the
//!   next integrity check (fetch or direct-read audit) trips.
//! * **Transient allocation failure** — the `nth` admission round is
//!   treated as if the block pool momentarily had no free block;
//!   admission retries on the next scheduler iteration.
//!
//! Configured via `ServeOptions::faults(..)` or the `PALLAS_FAILPOINTS`
//! env spec (the explicit option wins). Grammar — `;`-separated
//! clauses, `,`-separated keys:
//!
//! ```text
//! panic@phase=<name|u16>,iter=<n>[,worker=<w>]
//! fetch@nth=<n>
//! corrupt@nth=<n>
//! alloc@nth=<n>
//! seed=<u64>
//! ```
//!
//! e.g. `PALLAS_FAILPOINTS="panic@phase=attn,iter=3;corrupt@nth=0"`.
//! Phase names are the `obs::Code` span names (`embed`, `norm`,
//! `qkv_gemm`, `rope`, `kv_commit`, `attn`, `o_gemm`, `mlp_gemm`,
//! `lm_head`).
//!
//! **The unset path costs nothing.** Every hook takes an
//! `Option<&FaultPlan>` (or an `Option<Arc<FaultPlan>>` field) and
//! compiles to a single branch on `None` — no clock, no allocation —
//! pinned by the counting-allocator test in `rust/tests/obs.rs`, which
//! runs with no plan installed.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

use crate::obs::Code;
use crate::util::Rng;

/// Worker-panic trigger: phase code × engine iteration, optionally
/// pinned to one SPMD participant index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PanicSpec {
    /// `obs::Code` discriminant of the phase barrier to die at.
    pub phase: u16,
    /// 1-based engine iteration (`BatchStepper::step` call) to fire on.
    pub iter: u32,
    /// SPMD participant to fire on; `None` = first participant to
    /// reach the armed phase barrier.
    pub worker: Option<usize>,
}

/// One-shot failpoint registry. Interior mutability is all atomic so a
/// single plan can be shared (`Arc`) between the scheduler, the serve
/// driver and every SPMD worker thread.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    panic_at: Option<PanicSpec>,
    fetch_fail_nth: Option<u32>,
    corrupt_nth: Option<u32>,
    alloc_fail_nth: Option<u32>,
    /// Current engine iteration (bumped by the controller before each
    /// step; workers read it behind the step barrier).
    iter: AtomicU32,
    panic_armed: AtomicBool,
    fetches_seen: AtomicU32,
    spills_seen: AtomicU32,
    allocs_seen: AtomicU32,
    injected: AtomicU32,
}

impl Clone for FaultPlan {
    fn clone(&self) -> Self {
        FaultPlan {
            seed: self.seed,
            panic_at: self.panic_at,
            fetch_fail_nth: self.fetch_fail_nth,
            corrupt_nth: self.corrupt_nth,
            alloc_fail_nth: self.alloc_fail_nth,
            iter: AtomicU32::new(self.iter.load(Ordering::Relaxed)),
            panic_armed: AtomicBool::new(self.panic_armed.load(Ordering::Relaxed)),
            fetches_seen: AtomicU32::new(self.fetches_seen.load(Ordering::Relaxed)),
            spills_seen: AtomicU32::new(self.spills_seen.load(Ordering::Relaxed)),
            allocs_seen: AtomicU32::new(self.allocs_seen.load(Ordering::Relaxed)),
            injected: AtomicU32::new(self.injected.load(Ordering::Relaxed)),
        }
    }
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0x5EED,
            panic_at: None,
            fetch_fail_nth: None,
            corrupt_nth: None,
            alloc_fail_nth: None,
            iter: AtomicU32::new(0),
            panic_armed: AtomicBool::new(true),
            fetches_seen: AtomicU32::new(0),
            spills_seen: AtomicU32::new(0),
            allocs_seen: AtomicU32::new(0),
            injected: AtomicU32::new(0),
        }
    }
}

fn parse_phase(v: &str) -> Result<u16, String> {
    if let Ok(n) = v.parse::<u16>() {
        return match Code::from_u16(n) {
            Some(_) => Ok(n),
            None => Err(format!("phase code {n} out of range")),
        };
    }
    for c in 0..crate::obs::CODE_COUNT as u16 {
        let code = Code::from_u16(c).expect("dense discriminants");
        if code.name() == v {
            return Ok(c);
        }
    }
    Err(format!("unknown phase {v:?}"))
}

fn parse_kv<'a>(kv: &'a str, clause: &str) -> Result<(&'a str, &'a str), String> {
    kv.split_once('=')
        .map(|(k, v)| (k.trim(), v.trim()))
        .ok_or_else(|| format!("expected key=value in clause {clause:?}, got {kv:?}"))
}

fn parse_nth(clause: &str, body: &str) -> Result<u32, String> {
    let (k, v) = parse_kv(body, clause)?;
    if k != "nth" {
        return Err(format!("clause {clause:?} takes nth=<n>, got {k:?}"));
    }
    v.parse::<u32>().map_err(|_| format!("bad nth in {clause:?}: {v:?}"))
}

impl FaultPlan {
    /// A plan with no failpoints armed (useful as a builder base).
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse a `PALLAS_FAILPOINTS`-style spec (grammar in the module
    /// docs). Errors describe the offending clause.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        let mut any = false;
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(v) = clause.strip_prefix("seed=") {
                plan.seed =
                    v.trim().parse().map_err(|_| format!("bad seed: {v:?}"))?;
                continue;
            }
            let (site, body) = clause
                .split_once('@')
                .ok_or_else(|| format!("expected site@args, got {clause:?}"))?;
            match site.trim() {
                "panic" => {
                    let mut spec = PanicSpec { phase: u16::MAX, iter: 0, worker: None };
                    for kv in body.split(',') {
                        let (k, v) = parse_kv(kv, clause)?;
                        match k {
                            "phase" => spec.phase = parse_phase(v)?,
                            "iter" => {
                                spec.iter = v
                                    .parse()
                                    .map_err(|_| format!("bad iter: {v:?}"))?
                            }
                            "worker" => {
                                spec.worker = Some(
                                    v.parse()
                                        .map_err(|_| format!("bad worker: {v:?}"))?,
                                )
                            }
                            _ => return Err(format!("unknown panic key {k:?}")),
                        }
                    }
                    if spec.phase == u16::MAX || spec.iter == 0 {
                        return Err(
                            "panic@ needs phase=<name|u16> and iter=<n> (1-based)".into()
                        );
                    }
                    plan.panic_at = Some(spec);
                }
                "fetch" => plan.fetch_fail_nth = Some(parse_nth(clause, body)?),
                "corrupt" => plan.corrupt_nth = Some(parse_nth(clause, body)?),
                "alloc" => plan.alloc_fail_nth = Some(parse_nth(clause, body)?),
                s => return Err(format!("unknown failpoint site {s:?}")),
            }
            any = true;
        }
        if !any && plan.seed == 0x5EED {
            return Err("empty failpoint spec".into());
        }
        Ok(plan)
    }

    /// Read `PALLAS_FAILPOINTS`. Unset → `None`; malformed → one-line
    /// stderr warning and `None` (the serve call proceeds unfaulted),
    /// matching the lenient env-knob policy in [`crate::util::env_knob`].
    pub fn from_env() -> Option<FaultPlan> {
        let spec = std::env::var("PALLAS_FAILPOINTS").ok()?;
        match FaultPlan::parse(&spec) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!(
                    "warning: ignoring malformed PALLAS_FAILPOINTS={spec:?}: {e}"
                );
                None
            }
        }
    }

    /// Builder: worker panic at `phase` (an `obs::Code`) on 1-based
    /// engine iteration `iter`.
    pub fn panic_at(mut self, phase: Code, iter: u32, worker: Option<usize>) -> Self {
        self.panic_at = Some(PanicSpec { phase: phase as u16, iter, worker });
        self
    }

    /// Builder: the `nth` (0-based) cold-tier fetch fails transiently.
    pub fn fail_fetch(mut self, nth: u32) -> Self {
        self.fetch_fail_nth = Some(nth);
        self
    }

    /// Builder: corrupt the payload of the `nth` (0-based) spill.
    pub fn corrupt_spill(mut self, nth: u32) -> Self {
        self.corrupt_nth = Some(nth);
        self
    }

    /// Builder: the `nth` (0-based) admission round sees a transient
    /// block-allocation failure.
    pub fn fail_alloc(mut self, nth: u32) -> Self {
        self.alloc_fail_nth = Some(nth);
        self
    }

    /// Builder: seed for the corruption byte-flip position.
    pub fn seeded(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Deterministic RNG for payload corruption, keyed on the plan
    /// seed and the victim slot.
    pub fn corruption_rng(&self, slot: u32) -> Rng {
        Rng::new(self.seed ^ ((slot as u64) << 32 | 0x0BAD))
    }

    /// Controller hook: advance the engine-iteration counter before a
    /// step's phase barriers open (workers observe it behind the step
    /// barrier, so no stronger ordering than `Relaxed` is needed).
    #[inline]
    pub fn begin_iter(&self) {
        self.iter.fetch_add(1, Ordering::Relaxed);
    }

    /// Phase-barrier hook: panic here iff this is the armed
    /// (phase, iter) pair — and, when the spec pins a worker, this
    /// participant. One-shot: the swap disarms before unwinding so the
    /// restarted epoch runs clean.
    #[inline]
    pub fn maybe_panic(&self, phase: Code, wi: usize) {
        if let Some(p) = self.panic_at {
            if p.phase == phase as u16
                && self.iter.load(Ordering::Relaxed) == p.iter
                && p.worker.map_or(true, |w| w == wi)
                && self.panic_armed.swap(false, Ordering::Relaxed)
            {
                self.injected.fetch_add(1, Ordering::Relaxed);
                panic!(
                    "injected fault: worker {wi} panic at phase {} iter {}",
                    phase.name(),
                    p.iter
                );
            }
        }
    }

    /// Cold-tier hook: should this fetch fail transiently?
    #[inline]
    pub fn take_fetch_fail(&self) -> bool {
        match self.fetch_fail_nth {
            Some(n) => {
                let k = self.fetches_seen.fetch_add(1, Ordering::Relaxed);
                if k == n {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    true
                } else {
                    false
                }
            }
            None => false,
        }
    }

    /// Cold-tier hook: should this spill's payload be corrupted?
    #[inline]
    pub fn take_corrupt(&self) -> bool {
        match self.corrupt_nth {
            Some(n) => {
                let k = self.spills_seen.fetch_add(1, Ordering::Relaxed);
                if k == n {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    true
                } else {
                    false
                }
            }
            None => false,
        }
    }

    /// Block-pool hook: should this admission round see a transient
    /// allocation failure?
    #[inline]
    pub fn take_alloc_fail(&self) -> bool {
        match self.alloc_fail_nth {
            Some(n) => {
                let k = self.allocs_seen.fetch_add(1, Ordering::Relaxed);
                if k == n {
                    self.injected.fetch_add(1, Ordering::Relaxed);
                    true
                } else {
                    false
                }
            }
            None => false,
        }
    }

    /// Faults fired so far (any site).
    pub fn injected(&self) -> u32 {
        self.injected.load(Ordering::Relaxed)
    }

    /// True when no site is armed (a no-op plan).
    pub fn is_empty(&self) -> bool {
        self.panic_at.is_none()
            && self.fetch_fail_nth.is_none()
            && self.corrupt_nth.is_none()
            && self.alloc_fail_nth.is_none()
    }
}

/// Why a request was refused at submission, instead of queued.
/// Surfaced per request so callers can retry, shed, or re-route —
/// a typed contract, not a panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded admission queue is at `limit` waiting requests.
    QueueFull { limit: usize },
    /// The request's deadline had already expired at submission.
    DeadlineExpired,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull { limit } => {
                write!(f, "admission queue full ({limit} waiting)")
            }
            RejectReason::DeadlineExpired => write!(f, "deadline already expired"),
        }
    }
}

/// The `faults` section of a `ServeReport`: what was injected, what
/// the run did about it, and what request-level policy refused.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Failpoints that fired (all sites).
    pub injected: u32,
    /// Epoch restarts that brought the SPMD scope back after a panic.
    pub recovered: u32,
    /// Sequences rolled back to a committed boundary and requeued
    /// (epoch recovery + cold-integrity reclassification).
    pub requeued: u32,
    /// Requests refused at submission (queue full / dead on arrival).
    pub rejected: u32,
    /// Requests cancelled because their deadline passed.
    pub deadline_missed: u32,
}

impl FaultReport {
    pub fn any(&self) -> bool {
        self.injected > 0
            || self.recovered > 0
            || self.requeued > 0
            || self.rejected > 0
            || self.deadline_missed > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse(
            "panic@phase=attn,iter=3,worker=1;fetch@nth=2;corrupt@nth=0;alloc@nth=4;seed=9",
        )
        .unwrap();
        assert_eq!(
            p.panic_at,
            Some(PanicSpec { phase: Code::Attn as u16, iter: 3, worker: Some(1) })
        );
        assert_eq!(p.fetch_fail_nth, Some(2));
        assert_eq!(p.corrupt_nth, Some(0));
        assert_eq!(p.alloc_fail_nth, Some(4));
        assert_eq!(p.seed, 9);
        assert!(!p.is_empty());
    }

    #[test]
    fn parse_numeric_phase_and_spaces() {
        let p = FaultPlan::parse(" panic@ phase = 5 , iter = 1 ").unwrap();
        assert_eq!(p.panic_at.unwrap().phase, Code::Attn as u16);
        assert_eq!(p.panic_at.unwrap().worker, None);
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "",
            "panic@phase=attn",          // missing iter
            "panic@phase=nope,iter=1",   // unknown phase name
            "panic@phase=999,iter=1",    // phase code out of range
            "panic@phase=attn,iter=x",   // non-numeric iter
            "warp@nth=1",                // unknown site
            "corrupt@n=1",               // wrong key
            "fetch@nth=minus",           // bad nth
            "seed=zebra",                // bad seed
            "panicphase=1",              // no @
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn panic_trigger_is_one_shot_and_iter_gated() {
        let p = FaultPlan::new().panic_at(Code::Attn, 2, None);
        p.begin_iter(); // iter 1
        p.maybe_panic(Code::Attn, 0); // wrong iter — no fire
        p.begin_iter(); // iter 2
        p.maybe_panic(Code::Norm, 0); // wrong phase — no fire
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.maybe_panic(Code::Attn, 1)
        }));
        assert!(r.is_err(), "armed (phase, iter) must fire");
        assert_eq!(p.injected(), 1);
        // Disarmed: the same (phase, iter) no longer fires.
        p.maybe_panic(Code::Attn, 1);
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn worker_pinned_panic_ignores_other_participants() {
        let p = FaultPlan::new().panic_at(Code::Rope, 1, Some(2));
        p.begin_iter();
        p.maybe_panic(Code::Rope, 0);
        p.maybe_panic(Code::Rope, 1);
        assert_eq!(p.injected(), 0);
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.maybe_panic(Code::Rope, 2)
        }))
        .is_err());
    }

    #[test]
    fn nth_counters_fire_once() {
        let p = FaultPlan::new().fail_fetch(1).corrupt_spill(0).fail_alloc(2);
        assert!(!p.take_fetch_fail()); // fetch 0
        assert!(p.take_fetch_fail()); // fetch 1 — fires
        assert!(!p.take_fetch_fail()); // fetch 2
        assert!(p.take_corrupt()); // spill 0 — fires
        assert!(!p.take_corrupt());
        assert!(!p.take_alloc_fail());
        assert!(!p.take_alloc_fail());
        assert!(p.take_alloc_fail()); // round 2 — fires
        assert_eq!(p.injected(), 3);
    }

    #[test]
    fn corruption_rng_is_deterministic_per_slot() {
        let p = FaultPlan::new().seeded(7);
        let a: Vec<u64> = (0..4).map(|_| p.corruption_rng(3).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]));
        assert_ne!(p.corruption_rng(3).next_u64(), p.corruption_rng(4).next_u64());
    }

    #[test]
    fn reject_reason_renders() {
        assert_eq!(
            RejectReason::QueueFull { limit: 8 }.to_string(),
            "admission queue full (8 waiting)"
        );
        assert_eq!(RejectReason::DeadlineExpired.to_string(), "deadline already expired");
    }

    #[test]
    fn fault_report_any() {
        assert!(!FaultReport::default().any());
        assert!(FaultReport { rejected: 1, ..Default::default() }.any());
    }
}
