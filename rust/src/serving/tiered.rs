//! Tiered KV-cache storage: a quantized cold tier behind the hot fp32
//! block pool, with swap-based preemption.
//!
//! The paper's headline is efficient LLM deployment on *heterogeneous
//! storage architectures*; PR 1's serving stack still treated KV storage
//! as one flat fp32 pool, and under pressure the scheduler preempted by
//! throwing KV away and recomputing. This module adds the second tier:
//!
//! * [`ColdKv`] — the engine-side data plane: `cold_blocks` slots, each
//!   holding one block's K and V rows for every layer, stored either as
//!   per-block affine **int8** (per-`(block, layer, K/V)` scale and
//!   zero-point, `ntt::quantize_block_i8`) or as raw **f32** (lossless
//!   swap, 4x the bytes). Spill quantizes hot rows into a slot; fetch
//!   dequantizes a slot back into hot rows.
//! * [`TierState`] — the scheduler-side control plane: cold-slot
//!   allocation with per-slot owner + last-touch LRU bookkeeping, the
//!   pending [`TierOp`] list the driver hands to the engine each
//!   iteration, and byte/simulated-cost accounting.
//! * [`TierCostModel`] — the swap-vs-recompute rule: spill + fetch bytes
//!   over the cold tier's bandwidth/latency ([`crate::cost::MachineSpec`]
//!   `cold_bw_gbps` / `cold_alpha_s`) against the FLOPs of recomputing
//!   the victim's positions from scratch. [`SwapPolicy::Always`] /
//!   [`SwapPolicy::Never`] force either arm (tests, ablations).
//!
//! The tier boundary is the repo's first lossy/lossless storage
//! boundary: int8 swap may change a sequence's tokens *after* a spilled
//! block is re-read (never before, and never for other sequences — the
//! scheduler taints swapped-in sequences so their blocks stay out of the
//! prefix cache), while f32 swap is bitwise invisible. Tiering is off by
//! default (`ContinuousConfig::tiering = None`), and the disabled path
//! is bitwise-identical to the pre-tiering scheduler — the FCFS
//! differential oracle in `rust/tests/serving.rs` pins both properties.

use super::batch_engine::PagedKv;
use crate::cost::MachineSpec;
use crate::model::Qwen3Config;
use crate::ntt::{dequantize_block_i8, quantize_block_i8};
use crate::util::Rng;

/// FNV-1a 64-bit over a byte stream — the cold tier's per-slot payload
/// checksum. Dependency-free and byte-order-stable; collision
/// resistance is not the goal (this detects storage corruption, not
/// adversaries).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Storage format of the cold tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvQuant {
    /// Per-block affine int8 (1 byte/value + per-layer scale/zero).
    /// Lossy: a swapped-back sequence may diverge from the oracle.
    Int8,
    /// Raw f32 (4 bytes/value). Lossless: swap is bitwise invisible.
    F32,
}

impl KvQuant {
    pub fn bytes_per_value(&self) -> usize {
        match self {
            KvQuant::Int8 => 1,
            KvQuant::F32 => 4,
        }
    }

    /// True when a cold round trip can change values.
    pub fn lossy(&self) -> bool {
        matches!(self, KvQuant::Int8)
    }

    pub fn name(&self) -> &'static str {
        match self {
            KvQuant::Int8 => "int8",
            KvQuant::F32 => "f32",
        }
    }

    pub fn parse(s: &str) -> Option<KvQuant> {
        match s {
            "int8" | "i8" => Some(KvQuant::Int8),
            "f32" | "fp32" => Some(KvQuant::F32),
            _ => None,
        }
    }
}

/// The swap-vs-recompute cost model (simulated seconds; the tier's
/// bandwidth/latency come from the machine description, matching how
/// every other cost in `crate::cost` is modeled).
#[derive(Debug, Clone)]
pub struct TierCostModel {
    /// Sustained cold-tier bandwidth, bytes/s.
    pub cold_bw_bytes_per_s: f64,
    /// Per-transfer latency of the cold tier, seconds.
    pub cold_alpha_s: f64,
    /// Sustained recompute rate, FLOP/s.
    pub recompute_flops_per_s: f64,
    /// Forward FLOPs per recomputed token (~2 x params).
    pub flops_per_token: f64,
}

impl TierCostModel {
    pub fn for_machine(machine: &MachineSpec, model: &Qwen3Config, threads: usize) -> Self {
        TierCostModel {
            cold_bw_bytes_per_s: machine.cold_bw_gbps * 1e9,
            cold_alpha_s: machine.cold_alpha_s,
            // Peak at the model's dtype width (the old hard-coded `4`
            // was dtype-blind: F16 models recompute with twice the
            // lanes, which tilts the rule toward recompute).
            recompute_flops_per_s: machine.peak_flops(threads, model.dtype.size_bytes()),
            flops_per_token: 2.0 * model.param_count() as f64,
        }
    }

    /// Seconds to move `bytes` across the tier boundary (one transfer).
    pub fn transfer_s(&self, bytes: u64) -> f64 {
        self.cold_alpha_s + bytes as f64 / self.cold_bw_bytes_per_s.max(1.0)
    }

    /// Seconds to replay `tokens` positions from scratch.
    pub fn recompute_s(&self, tokens: usize) -> f64 {
        tokens as f64 * self.flops_per_token / self.recompute_flops_per_s.max(1.0)
    }

    /// The swap-vs-recompute rule: spill now + fetch later vs replaying
    /// the victim's `tokens` positions on re-admission.
    pub fn should_swap(&self, spill_bytes: u64, fetch_bytes: u64, tokens: usize) -> bool {
        self.transfer_s(spill_bytes) + self.transfer_s(fetch_bytes) < self.recompute_s(tokens)
    }
}

/// How preemption victims are handled when tiering is on.
#[derive(Debug, Clone)]
pub enum SwapPolicy {
    /// Always swap to the cold tier (tests / benches: deterministic).
    Always,
    /// Never swap — tiering machinery on, recompute semantics (ablation
    /// baseline).
    Never,
    /// Swap iff the cost model says moving bytes beats redoing FLOPs.
    Cost(TierCostModel),
}

/// Configuration of the tiered KV store
/// (`ContinuousConfig::tiering: Option<TierConfig>`; `None` keeps the
/// flat fp32 pool, bitwise-identical to the pre-tiering scheduler).
#[derive(Debug, Clone)]
pub struct TierConfig {
    /// Cold-tier capacity in blocks.
    pub cold_blocks: usize,
    pub quant: KvQuant,
    pub policy: SwapPolicy,
    /// Direct cold reads: when a swapped sequence re-enters with at
    /// least this fraction of its blocks full, the full blocks stay cold
    /// and attention reads them in place through the dequant-gather
    /// kernels instead of fetching them into hot blocks. `None` always
    /// fetches. Int8 only (the f32 tier always fetches).
    pub direct_read_min_frac: Option<f64>,
}

impl TierConfig {
    /// Int8 cold tier of `cold_blocks` blocks, always-swap policy.
    pub fn new(cold_blocks: usize) -> Self {
        TierConfig {
            cold_blocks,
            quant: KvQuant::Int8,
            policy: SwapPolicy::Always,
            direct_read_min_frac: None,
        }
    }

    /// Cost-model policy derived from the machine + model descriptions.
    pub fn for_machine(
        cold_blocks: usize,
        quant: KvQuant,
        machine: &MachineSpec,
        model: &Qwen3Config,
        threads: usize,
    ) -> Self {
        TierConfig {
            cold_blocks,
            quant,
            policy: SwapPolicy::Cost(TierCostModel::for_machine(machine, model, threads)),
            direct_read_min_frac: None,
        }
    }

    /// One-line description for `ServeReport::render`.
    pub fn describe(&self) -> String {
        let policy = match &self.policy {
            SwapPolicy::Always => "always",
            SwapPolicy::Never => "never",
            SwapPolicy::Cost(_) => "cost",
        };
        let direct = match self.direct_read_min_frac {
            Some(f) => format!(" direct>={f:.2}"),
            None => String::new(),
        };
        format!("cold={}x{} swap={policy}{direct}", self.cold_blocks, self.quant.name())
    }
}

/// One data-movement command for the engine, produced by the scheduler
/// and executed by the controller while the SPMD workers are parked
/// (`BatchStepper::tier_ops`). All spills of an iteration execute before
/// all fetches: a fetch may target a hot block vacated by a spill in the
/// same iteration, and the spill must read the old contents first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierOp {
    /// Quantize rows `[0, filled)` of hot block `hot` (every layer) into
    /// cold slot `cold`.
    Spill { hot: u32, cold: u32, filled: usize },
    /// Dequantize cold slot `cold` back into hot block `hot`. `seq` is
    /// the owning sequence (same-iteration preemption of a just-admitted
    /// sequence reverts its fetches instead of spilling unwritten
    /// blocks).
    Fetch { cold: u32, hot: u32, seq: u64 },
}

/// Engine-side cold-tier arena: the quantized (or raw-f32) twin of
/// [`PagedKv`]. Slot `s` holds one block's K and V rows for every layer;
/// per-`(slot, layer)` scale/zero pairs cover K and V separately.
pub struct ColdKv {
    pub quant: KvQuant,
    pub block_size: usize,
    pub width: usize,
    pub layers: usize,
    /// Int8 payloads, `[slot][layer][block_size * width]`.
    qk: Vec<i8>,
    qv: Vec<i8>,
    /// F32 payloads (same layout) when `quant == F32`.
    fk: Vec<f32>,
    fv: Vec<f32>,
    /// Per-(slot, layer) quantization parameters.
    k_scale: Vec<f32>,
    k_zero: Vec<f32>,
    v_scale: Vec<f32>,
    v_zero: Vec<f32>,
    /// Rows holding real data per slot (partial tail blocks).
    filled: Vec<usize>,
    /// Per-slot FNV-1a checksum of the payload (+ quant params),
    /// recorded at spill time and verified before the data is trusted
    /// again (fetch, or the direct-read audit on swap-in).
    sum: Vec<u64>,
}

impl ColdKv {
    pub fn new(
        cold_blocks: usize,
        block_size: usize,
        layers: usize,
        width: usize,
        quant: KvQuant,
    ) -> Self {
        let vals = cold_blocks * layers * block_size * width;
        let params = cold_blocks * layers;
        let (qn, fnn) = match quant {
            KvQuant::Int8 => (vals, 0),
            KvQuant::F32 => (0, vals),
        };
        ColdKv {
            quant,
            block_size,
            width,
            layers,
            qk: vec![0; qn],
            qv: vec![0; qn],
            fk: vec![0.0; fnn],
            fv: vec![0.0; fnn],
            k_scale: vec![0.0; params],
            k_zero: vec![0.0; params],
            v_scale: vec![0.0; params],
            v_zero: vec![0.0; params],
            filled: vec![0; cold_blocks],
            sum: vec![0; cold_blocks],
        }
    }

    pub fn slots(&self) -> usize {
        self.filled.len()
    }

    /// Payload bytes of one fully-filled slot (both K and V, all layers,
    /// plus the per-layer scale/zero pairs) — the unit of the byte
    /// counters and the simulated transfer cost.
    pub fn slot_bytes(&self) -> u64 {
        slot_payload_bytes(self.layers, self.width, self.quant, self.block_size)
    }

    #[inline]
    fn base(&self, slot: u32, layer: usize) -> usize {
        (slot as usize * self.layers + layer) * self.block_size * self.width
    }

    #[inline]
    fn pidx(&self, slot: u32, layer: usize) -> usize {
        slot as usize * self.layers + layer
    }

    pub fn filled(&self, slot: u32) -> usize {
        self.filled[slot as usize]
    }

    /// Quantized K payload + scale/zero of `(slot, layer)` (Int8 only).
    pub fn k_block(&self, slot: u32, layer: usize) -> (&[i8], f32, f32) {
        debug_assert_eq!(self.quant, KvQuant::Int8, "direct cold reads are int8-only");
        let b = self.base(slot, layer);
        let p = self.pidx(slot, layer);
        (&self.qk[b..b + self.block_size * self.width], self.k_scale[p], self.k_zero[p])
    }

    /// Quantized V payload + scale/zero of `(slot, layer)` (Int8 only).
    pub fn v_block(&self, slot: u32, layer: usize) -> (&[i8], f32, f32) {
        debug_assert_eq!(self.quant, KvQuant::Int8, "direct cold reads are int8-only");
        let b = self.base(slot, layer);
        let p = self.pidx(slot, layer);
        (&self.qv[b..b + self.block_size * self.width], self.v_scale[p], self.v_zero[p])
    }

    /// Spill rows `[0, filled)` of hot block `hot_block` (every layer)
    /// into `slot`. Reads the hot arena, writes only the cold arena.
    pub fn spill(&mut self, slot: u32, hot: &PagedKv, hot_block: u32, filled: usize) {
        debug_assert!(filled <= self.block_size);
        let bs = self.block_size;
        let w = self.width;
        let row0 = hot_block as usize * bs;
        self.filled[slot as usize] = filled;
        for l in 0..self.layers {
            let k_src = &hot.k[l].data[row0 * w..(row0 + filled) * w];
            let v_src = &hot.v[l].data[row0 * w..(row0 + filled) * w];
            let b = self.base(slot, l);
            let p = self.pidx(slot, l);
            match self.quant {
                KvQuant::Int8 => {
                    let (s, z) = quantize_block_i8(k_src, &mut self.qk[b..b + filled * w]);
                    self.k_scale[p] = s;
                    self.k_zero[p] = z;
                    let (s, z) = quantize_block_i8(v_src, &mut self.qv[b..b + filled * w]);
                    self.v_scale[p] = s;
                    self.v_zero[p] = z;
                }
                KvQuant::F32 => {
                    self.fk[b..b + filled * w].copy_from_slice(k_src);
                    self.fv[b..b + filled * w].copy_from_slice(v_src);
                }
            }
        }
        self.sum[slot as usize] = self.checksum(slot);
    }

    /// FNV-1a over the slot's live payload rows and (in the int8 tier)
    /// its scale/zero parameters. The row count is folded in so a
    /// truncated slot can't pass as a shorter valid one.
    fn checksum(&self, slot: u32) -> u64 {
        let filled = self.filled[slot as usize];
        let w = self.width;
        let mut h = fnv1a(FNV_OFFSET, &(filled as u64).to_le_bytes());
        for l in 0..self.layers {
            let b = self.base(slot, l);
            let p = self.pidx(slot, l);
            match self.quant {
                KvQuant::Int8 => {
                    for &q in &self.qk[b..b + filled * w] {
                        h = fnv1a(h, &[q as u8]);
                    }
                    for &q in &self.qv[b..b + filled * w] {
                        h = fnv1a(h, &[q as u8]);
                    }
                    for v in
                        [self.k_scale[p], self.k_zero[p], self.v_scale[p], self.v_zero[p]]
                    {
                        h = fnv1a(h, &v.to_le_bytes());
                    }
                }
                KvQuant::F32 => {
                    for &v in &self.fk[b..b + filled * w] {
                        h = fnv1a(h, &v.to_le_bytes());
                    }
                    for &v in &self.fv[b..b + filled * w] {
                        h = fnv1a(h, &v.to_le_bytes());
                    }
                }
            }
        }
        h
    }

    /// Does the slot's payload still match the checksum recorded when
    /// it was spilled? Called before a fetch dequantizes the slot and
    /// by the direct-read audit on swap-in; `false` means the cold
    /// copy must not be trusted (the owner recomputes instead).
    pub fn verify(&self, slot: u32) -> bool {
        self.sum[slot as usize] == self.checksum(slot)
    }

    /// Fault injection only: flip bits in the slot's payload *without*
    /// updating the recorded checksum, simulating storage corruption
    /// between spill and re-read. The flipped position comes from the
    /// caller's seeded RNG so chaos runs reproduce.
    pub fn corrupt_slot(&mut self, slot: u32, rng: &mut Rng) {
        let filled = self.filled[slot as usize];
        if filled == 0 || self.layers == 0 {
            return;
        }
        let l = rng.below(self.layers);
        let idx = self.base(slot, l) + rng.below(filled * self.width);
        match self.quant {
            KvQuant::Int8 => self.qk[idx] = (self.qk[idx] as u8 ^ 0x55) as i8,
            KvQuant::F32 => {
                self.fk[idx] = f32::from_bits(self.fk[idx].to_bits() ^ (1 << 20))
            }
        }
    }

    /// Fetch `slot` back into hot block `hot_block` (every layer),
    /// dequantizing in the Int8 tier. Returns the restored row count.
    pub fn fetch(&self, slot: u32, hot: &mut PagedKv, hot_block: u32) -> usize {
        let filled = self.filled[slot as usize];
        let bs = self.block_size;
        let w = self.width;
        let row0 = hot_block as usize * bs;
        for l in 0..self.layers {
            let b = self.base(slot, l);
            let p = self.pidx(slot, l);
            let k_dst = &mut hot.k[l].data[row0 * w..(row0 + filled) * w];
            let v_dst = &mut hot.v[l].data[row0 * w..(row0 + filled) * w];
            match self.quant {
                KvQuant::Int8 => {
                    dequantize_block_i8(
                        &self.qk[b..b + filled * w],
                        self.k_scale[p],
                        self.k_zero[p],
                        k_dst,
                    );
                    dequantize_block_i8(
                        &self.qv[b..b + filled * w],
                        self.v_scale[p],
                        self.v_zero[p],
                        v_dst,
                    );
                }
                KvQuant::F32 => {
                    k_dst.copy_from_slice(&self.fk[b..b + filled * w]);
                    v_dst.copy_from_slice(&self.fv[b..b + filled * w]);
                }
            }
        }
        filled
    }
}

/// Payload bytes of `filled` rows of one cold block: K + V across all
/// layers, plus 16 bytes of scale/zero per layer in the int8 tier.
fn slot_payload_bytes(layers: usize, width: usize, quant: KvQuant, filled: usize) -> u64 {
    let payload = (2 * layers * filled * width * quant.bytes_per_value()) as u64;
    let params = if quant.lossy() { (16 * layers) as u64 } else { 0 };
    payload + params
}

/// Scheduler-side control plane of the cold tier: slot allocation with
/// owner + last-touch LRU bookkeeping and the pending op list.
pub struct TierState {
    pub config: TierConfig,
    /// Geometry for byte accounting (0 until `set_geometry`; unit tests
    /// that never talk to an engine can skip it).
    layers: usize,
    width: usize,
    free: Vec<u32>,
    owner: Vec<Option<u64>>,
    touch: Vec<u64>,
    filled: Vec<usize>,
    clock: u64,
    /// Ops for the engine, drained once per iteration by
    /// `ContinuousScheduler::take_tier_ops`.
    pub pending: Vec<TierOp>,
    /// Cold slots consumed by fetches this iteration: their data must
    /// stay intact until the engine has executed the op, so they are
    /// returned to the free list only after the step (`flush_releases`).
    pending_release: Vec<u32>,
    /// High-water mark of slots in use.
    pub max_in_use: usize,
}

impl TierState {
    pub fn new(config: TierConfig) -> Self {
        let n = config.cold_blocks;
        TierState {
            config,
            layers: 0,
            width: 0,
            free: (0..n as u32).rev().collect(),
            owner: vec![None; n],
            touch: vec![0; n],
            filled: vec![0; n],
            clock: 0,
            pending: Vec::new(),
            pending_release: Vec::new(),
            max_in_use: 0,
        }
    }

    /// Wire in the model geometry so byte counters and the cost model
    /// see real sizes (called by the serving coordinator).
    pub fn set_geometry(&mut self, layers: usize, width: usize) {
        self.layers = layers;
        self.width = width;
    }

    pub fn slots(&self) -> usize {
        self.owner.len()
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    pub fn in_use(&self) -> usize {
        self.owner.len() - self.free.len()
    }

    /// Bytes of `filled` rows of one slot under the configured format.
    pub fn payload_bytes(&self, filled: usize) -> u64 {
        slot_payload_bytes(self.layers, self.width, self.config.quant, filled)
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Allocate a slot for `owner` (LRU-stamped now).
    pub fn alloc(&mut self, owner: u64, filled: usize) -> Option<u32> {
        let s = self.free.pop()?;
        debug_assert!(self.owner[s as usize].is_none());
        self.owner[s as usize] = Some(owner);
        self.filled[s as usize] = filled;
        self.touch[s as usize] = self.tick();
        self.max_in_use = self.max_in_use.max(self.in_use());
        Some(s)
    }

    pub fn filled(&self, slot: u32) -> usize {
        self.filled[slot as usize]
    }

    /// Return a slot to the free list immediately (owner finished or was
    /// evicted — its cold data is dead).
    pub fn release(&mut self, slot: u32) {
        debug_assert!(self.owner[slot as usize].is_some(), "release of a free cold slot");
        self.owner[slot as usize] = None;
        self.free.push(slot);
    }

    /// Mark a fetched slot for release after the engine executes this
    /// iteration's ops (the fetch still has to read it).
    pub fn release_after_ops(&mut self, slot: u32) {
        self.pending_release.push(slot);
    }

    /// Un-mark slots queued by [`TierState::release_after_ops`] (fetch
    /// reverted by a same-iteration preemption).
    pub fn cancel_release(&mut self, slot: u32) {
        if let Some(i) = self.pending_release.iter().position(|&s| s == slot) {
            self.pending_release.swap_remove(i);
        }
    }

    /// Free every slot whose fetch op has now executed.
    pub fn flush_releases(&mut self) {
        let slots: Vec<u32> = self.pending_release.drain(..).collect();
        for s in slots {
            self.release(s);
        }
    }

    /// The sequence owning `slot`, if any (maps a failed fetch or a
    /// tripped checksum back to the sequence that must recompute).
    pub fn owner_of(&self, slot: u32) -> Option<u64> {
        self.owner[slot as usize]
    }

    /// Recovery path: drop every pending op and deferred release and
    /// free every slot. Used after a panicked SPMD epoch, when partial
    /// tier-op execution may have left the control plane out of sync
    /// with the engine arena — all swapped state rolls back to
    /// recompute, so no cold data stays live. Returns how many slots
    /// were in use.
    pub fn reset(&mut self) -> usize {
        let n = self.in_use();
        self.pending.clear();
        self.pending_release.clear();
        self.owner.iter_mut().for_each(|o| *o = None);
        self.free = (0..self.owner.len() as u32).rev().collect();
        n
    }

    /// Release all slots owned by `owner`; returns how many were freed.
    pub fn release_owned(&mut self, owner: u64) -> usize {
        let mut n = 0;
        for s in 0..self.owner.len() as u32 {
            if self.owner[s as usize] == Some(owner) {
                self.release(s);
                n += 1;
            }
        }
        n
    }

    /// Least-recently-touched owner among `candidates` (queued swapped
    /// sequences — a running sequence's cold prefix is never evictable).
    pub fn lru_owner(&self, candidates: &[u64]) -> Option<u64> {
        self.owner
            .iter()
            .zip(&self.touch)
            .filter_map(|(&o, &t)| o.filter(|id| candidates.contains(id)).map(|id| (t, id)))
            .min()
            .map(|(_, id)| id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_rule() {
        let m = TierCostModel {
            cold_bw_bytes_per_s: 1e9,
            cold_alpha_s: 1e-6,
            recompute_flops_per_s: 1e11,
            flops_per_token: 1e9, // 10 ms of recompute per token
        };
        // Moving 1 MB both ways (~2 ms) beats recomputing 1 token (10 ms).
        assert!(m.should_swap(1 << 20, 1 << 20, 1));
        // Recomputing nothing is free; any transfer loses.
        assert!(!m.should_swap(1 << 20, 1 << 20, 0));
    }

    #[test]
    fn cold_roundtrip_f32_is_exact() {
        let (bs, layers, width) = (4usize, 2usize, 6usize);
        let mut hot = PagedKv::new(layers, 4, bs, width);
        for l in 0..layers {
            for (i, v) in hot.k[l].data.iter_mut().enumerate() {
                *v = (l * 1000 + i) as f32 * 0.25;
            }
            for (i, v) in hot.v[l].data.iter_mut().enumerate() {
                *v = -((l * 1000 + i) as f32) * 0.5;
            }
        }
        let snapshot_k: Vec<Vec<f32>> = hot.k.iter().map(|t| t.data.clone()).collect();
        let mut cold = ColdKv::new(2, bs, layers, width, KvQuant::F32);
        cold.spill(1, &hot, 2, bs);
        // Clobber the hot block, then fetch it back.
        for l in 0..layers {
            for v in &mut hot.k[l].data[2 * bs * width..3 * bs * width] {
                *v = f32::NAN;
            }
        }
        assert_eq!(cold.fetch(1, &mut hot, 2), bs);
        for l in 0..layers {
            assert_eq!(hot.k[l].data, snapshot_k[l], "f32 tier must round-trip exactly");
        }
    }

    #[test]
    fn cold_roundtrip_i8_is_bounded_and_partial_blocks_skip_garbage() {
        let (bs, layers, width) = (4usize, 2usize, 8usize);
        let mut hot = PagedKv::new(layers, 2, bs, width);
        // Fill 3 of 4 rows of block 0 with signal; row 3 holds a huge
        // garbage value that must NOT skew the quantization scale.
        for l in 0..layers {
            for r in 0..bs {
                for c in 0..width {
                    hot.k[l].data[r * width + c] =
                        if r < 3 { (r * width + c) as f32 * 0.1 - 1.0 } else { 1e9 };
                    hot.v[l].data[r * width + c] =
                        if r < 3 { -((r * width + c) as f32) * 0.2 } else { -1e9 };
                }
            }
        }
        let want_k = hot.k[0].data[..3 * width].to_vec();
        let mut cold = ColdKv::new(1, bs, layers, width, KvQuant::Int8);
        cold.spill(0, &hot, 0, 3);
        assert_eq!(cold.filled(0), 3);
        for l in 0..layers {
            hot.k[l].data.fill(0.0);
            hot.v[l].data.fill(0.0);
        }
        assert_eq!(cold.fetch(0, &mut hot, 0), 3);
        // Bounded error: the garbage row was excluded, so the scale is
        // small and the signal rows survive tightly.
        let range = want_k.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            - want_k.iter().cloned().fold(f32::INFINITY, f32::min);
        let bound = range / 255.0 * 0.5 + 1e-6;
        for (a, b) in want_k.iter().zip(&hot.k[0].data[..3 * width]) {
            assert!((a - b).abs() <= bound, "{a} vs {b} exceeds {bound}");
        }
        // The unfilled row stays untouched by the fetch.
        assert!(hot.k[0].data[3 * width..4 * width].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn tier_state_alloc_release_lru() {
        let mut t = TierState::new(TierConfig::new(3));
        t.set_geometry(2, 8);
        assert_eq!(t.free_slots(), 3);
        let a = t.alloc(10, 4).unwrap();
        let b = t.alloc(11, 4).unwrap();
        let c = t.alloc(12, 2).unwrap();
        assert!(t.alloc(13, 4).is_none(), "capacity is 3");
        assert_eq!(t.in_use(), 3);
        assert_eq!(t.max_in_use, 3);
        assert_eq!(t.filled(c), 2);
        // LRU: slot `a` was touched first.
        assert_eq!(t.lru_owner(&[10, 11, 12]), Some(10));
        assert_eq!(t.lru_owner(&[11, 12]), Some(11), "candidates filter applies");
        assert_eq!(t.lru_owner(&[99]), None);
        assert_eq!(t.release_owned(10), 1);
        assert_eq!(t.free_slots(), 1);
        // Deferred release: slot stays allocated until the flush.
        t.release_after_ops(b);
        assert_eq!(t.in_use(), 2);
        t.flush_releases();
        assert_eq!(t.in_use(), 1);
        let _ = a;
    }

    #[test]
    fn checksum_detects_corruption_in_both_formats() {
        let (bs, layers, width) = (4usize, 2usize, 6usize);
        let mut hot = PagedKv::new(layers, 2, bs, width);
        for l in 0..layers {
            for (i, v) in hot.k[l].data.iter_mut().enumerate() {
                *v = (l * 100 + i) as f32 * 0.3 - 2.0;
            }
            for (i, v) in hot.v[l].data.iter_mut().enumerate() {
                *v = -((l * 100 + i) as f32) * 0.7;
            }
        }
        for quant in [KvQuant::Int8, KvQuant::F32] {
            let mut cold = ColdKv::new(2, bs, layers, width, quant);
            cold.spill(0, &hot, 1, bs);
            cold.spill(1, &hot, 0, 2); // partial block
            assert!(cold.verify(0), "{quant:?}: fresh spill must verify");
            assert!(cold.verify(1));
            let mut rng = Rng::new(0xC0FFEE);
            cold.corrupt_slot(0, &mut rng);
            assert!(!cold.verify(0), "{quant:?}: corruption must trip the checksum");
            assert!(cold.verify(1), "{quant:?}: other slots stay intact");
            // Re-spilling the slot heals it (fresh payload, fresh sum).
            cold.spill(0, &hot, 1, bs);
            assert!(cold.verify(0));
        }
    }

    #[test]
    fn corruption_is_deterministic_under_one_seed() {
        let (bs, layers, width) = (2usize, 1usize, 4usize);
        let mut hot = PagedKv::new(layers, 1, bs, width);
        for (i, v) in hot.k[0].data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let make = || {
            let mut c = ColdKv::new(1, bs, layers, width, KvQuant::F32);
            c.spill(0, &hot, 0, bs);
            c.corrupt_slot(0, &mut Rng::new(9));
            c.fk.clone()
        };
        assert_eq!(make(), make(), "same seed, same flipped bit");
    }

    #[test]
    fn tier_state_owner_lookup_and_reset() {
        let mut t = TierState::new(TierConfig::new(3));
        let a = t.alloc(10, 4).unwrap();
        let b = t.alloc(11, 4).unwrap();
        assert_eq!(t.owner_of(a), Some(10));
        assert_eq!(t.owner_of(b), Some(11));
        t.pending.push(TierOp::Fetch { cold: a, hot: 0, seq: 10 });
        t.release_after_ops(b);
        assert_eq!(t.reset(), 2);
        assert_eq!(t.in_use(), 0);
        assert_eq!(t.free_slots(), 3);
        assert!(t.pending.is_empty());
        assert_eq!(t.owner_of(a), None);
        // The control plane is reusable after the reset.
        assert!(t.alloc(12, 1).is_some());
    }

    #[test]
    fn payload_bytes_by_format() {
        let mut t = TierState::new(TierConfig::new(1));
        t.set_geometry(3, 8); // 3 layers, width 8
        // Int8: 2 (K,V) * 3 layers * filled * 8 B + 16 B scale/zero per layer.
        assert_eq!(t.payload_bytes(4), (2 * 3 * 4 * 8 + 16 * 3) as u64);
        let mut f = TierState::new(TierConfig { quant: KvQuant::F32, ..TierConfig::new(1) });
        f.set_geometry(3, 8);
        assert_eq!(f.payload_bytes(4), (2 * 3 * 4 * 8 * 4) as u64);
    }

    #[test]
    fn config_parse_and_describe() {
        assert_eq!(KvQuant::parse("int8"), Some(KvQuant::Int8));
        assert_eq!(KvQuant::parse("f32"), Some(KvQuant::F32));
        assert_eq!(KvQuant::parse("q4"), None);
        let c = TierConfig::new(64);
        assert_eq!(c.describe(), "cold=64xint8 swap=always");
        let d = TierConfig {
            direct_read_min_frac: Some(0.75),
            quant: KvQuant::F32,
            policy: SwapPolicy::Never,
            ..TierConfig::new(8)
        };
        assert_eq!(d.describe(), "cold=8xf32 swap=never direct>=0.75");
    }
}
