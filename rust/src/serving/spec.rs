//! Self-drafting (prompt-lookup / n-gram) speculative decoding: the
//! drafter half of the spec-decode path.
//!
//! No second model, no auxiliary state: a decode sequence drafts its
//! own continuation by looking the tail of its context (prompt +
//! committed generation) up **inside that same context**. Repetitive
//! and shared-prefix traffic — exactly what the prefix cache already
//! serves — repeats n-grams constantly, and greedy decode loves to fall
//! into loops, so the tokens after an earlier occurrence of the current
//! suffix are a cheap, surprisingly accurate draft. The scheduler
//! appends the drafts to the step span, the engine verifies all of them
//! in one tall GEMM ([`super::batch_engine::BatchStepper::step_verify`]),
//! and commit keeps the longest causally-matched prefix
//! ([`super::ContinuousScheduler::commit_verified`]) — so the output
//! stream is **token-identical** to non-speculative greedy decode by
//! construction: every emitted token is the model's own argmax.
//!
//! The drafter is pure and deterministic: same context in, same drafts
//! out, across threads, shards and runs. It allocates only its return
//! vector and scans O(`ngram` × context) in the worst case — a few
//! microseconds against a step that streams the whole weight plane.

/// Propose up to `max_k` draft continuation tokens for a sequence whose
/// committed context is `context` (prompt + generated, oldest first).
///
/// Matching: for `n` from `ngram` down to 1, take the context's final
/// `n` tokens as the pattern and find its **most recent** earlier
/// occurrence; on a hit, return the tokens that followed that
/// occurrence, verbatim, capped at `max_k`. Longer patterns win over
/// recency because they carry more evidence; among equal-length
/// matches, recency wins because generation drifts.
///
/// Returns an empty vector when nothing matches (the scheduler then
/// plans a plain 1-row decode span — drafting is an optimization,
/// never a requirement). Every returned token is a verbatim element of
/// `context`, a property the test suite pins.
pub fn propose(context: &[usize], ngram: usize, max_k: usize) -> Vec<usize> {
    let len = context.len();
    // Need at least one pattern token and one continuation token.
    if len < 2 || max_k == 0 || ngram == 0 {
        return Vec::new();
    }
    for n in (1..=ngram.min(len - 1)).rev() {
        let pattern = &context[len - n..];
        // Earlier occurrences only (i + n < len keeps at least one
        // continuation token and excludes the suffix matching itself),
        // scanned right-to-left so the most recent wins.
        for i in (0..len - n).rev() {
            if &context[i..i + n] == pattern {
                let start = i + n;
                let end = (start + max_k).min(len);
                return context[start..end].to_vec();
            }
        }
    }
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_ngram_proposes_its_continuation() {
        // Suffix [1,2,3] occurred at the start; the tokens after it are
        // the draft, capped at max_k.
        let ctx = [1, 2, 3, 4, 5, 1, 2, 3];
        assert_eq!(propose(&ctx, 3, 4), vec![4, 5, 1, 2]);
        assert_eq!(propose(&ctx, 3, 2), vec![4, 5], "max_k caps the draft");
        assert_eq!(propose(&ctx, 3, 1), vec![4]);
    }

    #[test]
    fn longer_patterns_win_over_shorter() {
        // The unigram [2] has a more recent match (index 4 -> continues
        // with 9), but the bigram [1,2] at index 0 carries more
        // evidence and must win: its continuation is 7.
        let ctx = [1, 2, 7, 8, 2, 9, 1, 2];
        assert_eq!(propose(&ctx, 3, 1), vec![7]);
        // With ngram capped at 1 the recent unigram match wins instead.
        assert_eq!(propose(&ctx, 1, 1), vec![9]);
    }

    #[test]
    fn most_recent_occurrence_wins_at_equal_length() {
        // [5] occurs at 0 (-> 7) and at 2 (-> 9): recency picks 9.
        let ctx = [5, 7, 5, 9, 5];
        assert_eq!(propose(&ctx, 1, 1), vec![9]);
    }

    #[test]
    fn no_match_and_degenerate_inputs_return_empty() {
        assert!(propose(&[1, 2, 3, 4], 3, 4).is_empty(), "all-distinct context");
        assert!(propose(&[], 3, 4).is_empty());
        assert!(propose(&[7], 3, 4).is_empty(), "no room for a continuation");
        assert!(propose(&[7, 7, 7], 0, 4).is_empty(), "ngram 0 disables matching");
        assert!(propose(&[7, 7, 7], 3, 0).is_empty(), "max_k 0 disables drafting");
    }

    #[test]
    fn draft_never_runs_past_the_context() {
        // The match sits one token from the end: the draft is that one
        // token, however large max_k is.
        let ctx = [3, 1, 4, 1];
        assert_eq!(propose(&ctx, 1, 16), vec![4]);
    }

    #[test]
    fn periodic_context_drafts_the_period() {
        // A period-4 loop (what greedy decode converges into): the
        // drafter reads the next period verbatim.
        let ctx: Vec<usize> = [10, 20, 30, 40].repeat(4);
        let draft = propose(&ctx, 3, 4);
        assert_eq!(draft, vec![10, 20, 30, 40]);
    }

    #[test]
    fn deterministic_across_calls() {
        let ctx: Vec<usize> = (0..32).map(|i| i % 5).collect();
        assert_eq!(propose(&ctx, 3, 8), propose(&ctx, 3, 8));
    }
}
