//! The serving subsystem: paged KV-cache management and continuous
//! batching (see `docs/serving.md`).
//!
//! The FCFS path in [`crate::coordinator::serve`] processes one request
//! at a time over a dense per-request KV cache — correct, and kept as
//! the differential-testing oracle, but throughput collapses under
//! concurrent load because every request re-streams the full weight set
//! per token. This module treats KV storage as a first-class managed
//! resource instead:
//!
//! * [`blocks`] — fixed-size KV block pool: free-list allocation,
//!   per-sequence block tables, refcounted prefix sharing.
//! * [`scheduler`] — continuous-batching scheduler: admission control,
//!   iteration-level batching of prefill and decode, preemption to the
//!   queue when the pool is exhausted.
//! * [`batch_engine`] — the batched decode path: one GEMM per projection
//!   over pre-packed weights for the whole batch, attention gathered
//!   through block tables, executed SPMD by persistent worker threads
//!   (one `thread::scope` per serve run) with a deterministic static
//!   partition — thread count never changes outputs.
//! * [`metrics`] — TTFT/TPOT, queue depth, pool occupancy, preemption
//!   and tier-traffic counters ([`crate::coordinator::ServeReport`]
//!   extension).
//! * [`autotune`] — the serve-time planner: derives a [`ServePlan`]
//!   (panel granularity, chunk, budget, threads, pool sizing, swap
//!   threshold) per `(model, machine, quant)` triple from
//!   `schedule::tile` tilings scored by the `cost` rooflines, instead
//!   of hand-picked constants. Plans are pure perf artifacts — any
//!   plan serves token-identical output.
//! * [`spec`] — self-drafting (prompt-lookup / n-gram) speculative
//!   decoding: a decode sequence drafts its own continuation from its
//!   context, the engine verifies all drafts in one tall span step,
//!   and commit keeps the longest matched causal prefix. Greedy
//!   acceptance keeps outputs token-identical to spec-off — the knob
//!   ([`ContinuousConfig`]`::spec_k`) is pure performance.
//! * [`tiered`] — the quantized cold storage tier: per-block int8 (or
//!   lossless f32) spill targets, the swap-vs-recompute cost model, and
//!   the scheduler-side cold-slot control plane. Swap-based preemption
//!   moves KV across the tier boundary instead of recomputing it.
//!   Every cold slot carries an FNV-1a payload checksum, verified on
//!   fetch and on direct-read resume; a mismatch reclassifies the
//!   owner swap→recompute instead of serving corrupt KV.
//! * [`fault`] — deterministic seeded failpoint registry
//!   ([`FaultPlan`], `PALLAS_FAILPOINTS`) plus the typed
//!   request-rejection ([`RejectReason`]) and fault-report
//!   ([`FaultReport`]) contracts. The serve loop in
//!   [`crate::coordinator::serve`] pairs it with panic-isolated run
//!   epochs: a poisoned SPMD scope is audited, rolled back to
//!   committed boundaries, requeued and restarted.
//!
//! Selected via [`crate::coordinator::ServeOptions`]; outputs are
//! token-identical to the FCFS oracle (`rust/tests/serving.rs`) whenever
//! tiering is off or the cold tier is lossless.

pub mod autotune;
pub mod batch_engine;
pub mod blocks;
pub mod fault;
pub mod metrics;
pub mod scheduler;
pub mod spec;
pub mod tiered;

pub use autotune::ServePlan;
pub use batch_engine::{BatchEngine, BatchStepper, PagedKv, StepSlot};
pub use blocks::{BlockAudit, BlockPool, BlockTable, KvBlockManager};
pub use fault::{FaultPlan, FaultReport, RejectReason};
pub use metrics::{ServingMetrics, SpecSummary};
pub use scheduler::{
    ContinuousConfig, ContinuousConfigBuilder, ContinuousScheduler, SeqState, Sequence,
};
pub use tiered::{ColdKv, KvQuant, SwapPolicy, TierConfig, TierCostModel, TierOp, TierState};
