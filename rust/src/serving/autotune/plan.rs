//! The [`ServePlan`] artifact: every serving knob the planner derives,
//! plus the shared pool-sizing heuristic and the plan's identity hash.
//!
//! A plan is a **pure perf artifact**: every knob it carries (panel
//! granularity, chunk, budget, threads, pool sizing, swap threshold)
//! changes only *when* and *where* token positions are computed, never
//! their values — the same contract the SPMD engine and the chunked
//! scheduler already honor, pinned by the FCFS differential oracle in
//! `rust/tests/serving.rs`. Any plan, including a pessimal one, serves
//! token-identical output.

use crate::cost::MachineSpec;
use crate::model::Qwen3Config;
use crate::ntt::{WeightQuant, MR};

/// The knobs the serve-time autotune pass picks once per
/// `(Qwen3Config, MachineSpec, WeightQuant)` triple (plus the
/// workload's batch cap). Built by [`super::search::search_plan`],
/// cached by [`super::cache::plan_for`], installed via
/// [`crate::serving::ContinuousConfig::autotuned`] and recorded in
/// [`crate::coordinator::ServeReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct ServePlan {
    /// Model the plan was derived for (`Qwen3Config::name`).
    pub model: String,
    /// Machine the plan was derived for (`MachineSpec::name`).
    pub machine: String,
    /// Weight-plane storage mode the cost model priced.
    pub weight_quant: WeightQuant,
    /// Batch cap the plan was sized for (workload input, clamped ≥ 1).
    pub max_batch: usize,
    /// Token positions per KV block (pool sizing, [`pool_sizing`]).
    pub block_size: usize,
    /// Physical KV blocks in the pool (pool sizing, [`pool_sizing`]).
    pub num_blocks: usize,
    /// SPMD worker threads (legal bound: `1 ..= partition_width`,
    /// further capped at the machine's core count).
    pub decode_threads: usize,
    /// Prompt positions per prefilling sequence per iteration (≥ 1).
    pub prefill_chunk: usize,
    /// Token rows per iteration across the batch
    /// (≥ `max(max_batch, prefill_chunk)` so every running sequence
    /// always advances).
    pub step_token_budget: usize,
    /// GEMM shard granularity in token rows, fed to
    /// [`crate::parallel::panel_splits`]. Always a multiple of the
    /// μkernel height [`MR`], so worker shard boundaries stay on the MR
    /// grid and the packed-tile arithmetic — hence every output bit —
    /// is unchanged at any value.
    pub panel_rows: usize,
    /// Smallest preemption-victim length (tokens) at which spilling to
    /// the cold tier beats recomputing, under the machine's
    /// [`crate::serving::TierCostModel`]. `None`: recompute always wins
    /// (swap never pays on this triple).
    pub swap_break_even_tokens: Option<usize>,
    /// Level-1 loop order of the winning `schedule::tile` tiling the
    /// panel granularity was derived from (Eq. 3 notation fragment).
    pub tiling: String,
    /// Shard groups the plan serves under (1 = unsharded; set by
    /// `ServeOptions::shards` at resolve time, not by the search).
    pub shards: usize,
    /// The dist-extracted per-matrix SBP signature the sharded run
    /// executes (`ShardSpec::sig`; `"-"` when unsharded). Part of the
    /// plan's identity: two runs under one hash served the same layout.
    pub sbp_sig: String,
    /// Self-drafting speculative depth the plan serves under (0 = off;
    /// set by `ServeOptions::spec_k` at resolve time, not by the
    /// search). Part of the plan's identity: speculative spans change
    /// the decode GEMM shape, so two runs under one hash drafted the
    /// same depth.
    pub spec_k: usize,
    /// Roofline-predicted seconds of one decode iteration under this
    /// plan (diagnostic; floors from `cost::decode_weight_stream_s`).
    pub predicted_decode_iter_s: f64,
    /// Roofline-predicted seconds of one prefill iteration under this
    /// plan (diagnostic; floors from `cost::prefill_flops_s`).
    pub predicted_prefill_iter_s: f64,
    /// Total predicted cost of the nominal serving episode the search
    /// minimized — comparable only across candidates of one search.
    pub predicted_cost_s: f64,
}

impl ServePlan {
    /// Stable identity of the plan's *decision* (knobs + the triple it
    /// was derived for; predicted costs are diagnostics and excluded).
    /// FNV-1a over the canonical knob string — two runs served under
    /// the same hash ran the same configuration, which is what
    /// `tools/bench_compare.py` keys on.
    pub fn plan_hash(&self) -> u64 {
        let s = format!(
            "{}|{}|{}|b{}|bs{}|nb{}|t{}|c{}|tb{}|p{}|s{}|{}|sh{}|{}|k{}",
            self.model,
            self.machine,
            self.weight_quant.name(),
            self.max_batch,
            self.block_size,
            self.num_blocks,
            self.decode_threads,
            self.prefill_chunk,
            self.step_token_budget,
            self.panel_rows,
            self.swap_break_even_tokens.map_or(-1i64, |t| t as i64),
            self.tiling,
            self.shards.max(1),
            self.sbp_sig,
            self.spec_k,
        );
        let mut h: u64 = 0xcbf29ce484222325;
        for b in s.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// One-line description for `ServeReport::render` and the CLI.
    pub fn render(&self) -> String {
        let swap = match self.swap_break_even_tokens {
            Some(t) => format!("swap>={t}tok"),
            None => "swap=never".into(),
        };
        let sharded = if self.shards > 1 {
            format!(" shards={} sbp[{}]", self.shards, self.sbp_sig)
        } else {
            String::new()
        };
        format!(
            "{:#018x} threads={} chunk={} budget={} panel={}r pool={}x{} batch={}{} {} \
             pred(decode={:.3}ms prefill={:.3}ms)",
            self.plan_hash(),
            self.decode_threads,
            self.prefill_chunk,
            self.step_token_budget,
            self.panel_rows,
            self.num_blocks,
            self.block_size,
            self.max_batch,
            sharded,
            swap,
            self.predicted_decode_iter_s * 1e3,
            self.predicted_prefill_iter_s * 1e3,
        )
    }

    /// Legality bounds every emitted plan must satisfy (asserted by the
    /// search and by the planner property test in
    /// `rust/tests/properties.rs`).
    pub fn check_legal(&self, model: &Qwen3Config) -> Result<(), String> {
        let pw = model.partition_width();
        if self.decode_threads == 0 || self.decode_threads > pw {
            return Err(format!(
                "threads {} outside [1, partition_width {pw}]",
                self.decode_threads
            ));
        }
        if self.prefill_chunk == 0 {
            return Err("prefill_chunk must be >= 1".into());
        }
        let max_row = self.max_batch.max(self.prefill_chunk);
        if self.step_token_budget < max_row {
            return Err(format!(
                "budget {} below max row need {max_row}",
                self.step_token_budget
            ));
        }
        if self.panel_rows < MR || self.panel_rows % MR != 0 {
            return Err(format!("panel_rows {} not a positive multiple of MR={MR}", self.panel_rows));
        }
        if self.block_size == 0 || self.num_blocks == 0 {
            return Err("degenerate KV pool".into());
        }
        if self.shards == 0 {
            return Err("shards must be >= 1 (1 = unsharded)".into());
        }
        Ok(())
    }
}

/// KV-pool sizing from the machine's memory model — the single source
/// of truth shared by the planner and the `--autotune`-off fallback
/// [`crate::serving::ContinuousConfig::for_machine`]: blocks get what
/// is left after the resident weights
/// ([`MachineSpec::kv_block_budget`]), capped in proportion to the
/// batch (64 blocks — 1024 positions at the default block size — per
/// concurrent sequence) so a small demo on a big machine does not zero
/// a multi-hundred-megabyte arena it will never touch. Returns
/// `(block_size, num_blocks)`.
pub fn pool_sizing(
    model: &Qwen3Config,
    machine: &MachineSpec,
    max_batch: usize,
) -> (usize, usize) {
    let block_size = 16usize;
    let block_bytes = model.kv_bytes_per_token() * block_size as u64;
    let budget = machine.kv_block_budget(model.weight_bytes(), block_bytes);
    let workload_cap = (max_batch.max(1) * 64) as u64;
    (block_size, budget.min(workload_cap).max(1) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_plan() -> ServePlan {
        ServePlan {
            model: "m".into(),
            machine: "M".into(),
            weight_quant: WeightQuant::F32,
            max_batch: 8,
            block_size: 16,
            num_blocks: 512,
            decode_threads: 2,
            prefill_chunk: 32,
            step_token_budget: 256,
            panel_rows: MR,
            swap_break_even_tokens: Some(64),
            tiling: "i,j,k".into(),
            shards: 1,
            sbp_sig: "-".into(),
            spec_k: 0,
            predicted_decode_iter_s: 1e-3,
            predicted_prefill_iter_s: 2e-3,
            predicted_cost_s: 0.5,
        }
    }

    #[test]
    fn hash_ignores_diagnostics_but_not_knobs() {
        let a = demo_plan();
        let mut b = a.clone();
        b.predicted_cost_s = 99.0;
        b.predicted_decode_iter_s = 99.0;
        assert_eq!(a.plan_hash(), b.plan_hash(), "costs are diagnostics");
        let mut c = a.clone();
        c.prefill_chunk = 1;
        assert_ne!(a.plan_hash(), c.plan_hash(), "knobs are identity");
        // The shard layout is identity too: a sharded run under a
        // different dist-chosen SBP signature must hash differently.
        let mut d = a.clone();
        d.shards = 2;
        d.sbp_sig = "wq=S(1),lm_head=B".into();
        assert_ne!(a.plan_hash(), d.plan_hash(), "shard layout is identity");
        let mut e = d.clone();
        e.sbp_sig = "wq=B,lm_head=B".into();
        assert_ne!(d.plan_hash(), e.plan_hash(), "sbp signature is identity");
        // The speculative depth is identity too: spec spans change the
        // decode GEMM shape the plan's predictions describe.
        let mut f = a.clone();
        f.spec_k = 4;
        assert_ne!(a.plan_hash(), f.plan_hash(), "speculative depth is identity");
    }

    #[test]
    fn render_carries_the_knobs() {
        let r = demo_plan().render();
        assert!(r.contains("threads=2"), "{r}");
        assert!(r.contains("chunk=32"), "{r}");
        assert!(r.contains("panel=4r"), "{r}");
        assert!(r.contains("swap>=64tok"), "{r}");
        assert!(r.starts_with("0x"), "{r}");
    }

    #[test]
    fn legality_bounds_reject_bad_plans() {
        let model = Qwen3Config::tiny(); // partition_width = 2
        assert!(demo_plan().check_legal(&model).is_ok());
        let mut p = demo_plan();
        p.decode_threads = 3;
        assert!(p.check_legal(&model).is_err(), "threads above partition width");
        let mut p = demo_plan();
        p.prefill_chunk = 0;
        assert!(p.check_legal(&model).is_err());
        let mut p = demo_plan();
        p.step_token_budget = 4;
        assert!(p.check_legal(&model).is_err(), "budget below batch");
        let mut p = demo_plan();
        p.panel_rows = MR + 1;
        assert!(p.check_legal(&model).is_err(), "panel off the MR grid");
    }

    #[test]
    fn pool_sizing_matches_the_for_machine_fallback() {
        // Satellite: one source of truth — the fallback delegates here,
        // and the values are the pre-planner ones.
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::ryzen_5900x();
        let (bs, nb) = pool_sizing(&model, &machine, 8);
        assert_eq!(bs, 16);
        assert_eq!(nb, 512, "8 seqs x 64 blocks, memory-rich machine");
        let cfg = crate::serving::ContinuousConfig::for_machine(&model, &machine, 8);
        assert_eq!((cfg.block_size, cfg.num_blocks), (bs, nb));
    }
}
