//! Serve-time autotune: the bridge that lets the compile-side
//! schedule/cost machinery drive the serving kernels (see
//! `docs/serving.md` § "Serve-time autotune").
//!
//! The compile pipeline (`rust/src/{schedule,cost}`) can rank tilings
//! and data-movement strategies per [`crate::cost::MachineSpec`], yet
//! the serving hot path historically ran on hand-picked constants
//! (`ContinuousConfig::for_machine`). This subsystem closes that loop:
//!
//! * [`plan`] — the [`ServePlan`] artifact: GEMM panel granularity (a
//!   multiple of the μkernel height `MR`, fed to
//!   [`crate::parallel::panel_splits`]), prefill chunk + step token
//!   budget, decode thread count, KV-pool sizing, and the tier
//!   swap-vs-recompute break-even; plus the plan hash
//!   `bench_compare` keys on.
//! * [`search`] — deterministic enumeration of candidates from
//!   `schedule::tile` legal tilings, scored with the existing
//!   rooflines (`cost::{prefill_flops_s, decode_weight_stream_s,
//!   roofline_time_s}`) and the serving
//!   [`crate::serving::TierCostModel`].
//! * [`cache`] — one search per `(model, machine, quant, batch)`
//!   triple, in-process.
//!
//! **Bitwise guarantee.** A plan changes only scheduling — which rows
//! run together, how GEMMs shard, how many workers spin — never
//! arithmetic: panel granularity stays on the MR grid so packed-tile
//! accumulation order is unchanged, and chunk/budget/threads are
//! exactly the knobs the FCFS differential oracle already pins. Any
//! plan, good or bad, serves token-identical output; `--autotune` is
//! pure performance.

pub mod cache;
pub mod plan;
pub mod search;

pub use cache::{cached_plan_count, plan_for, plan_key};
pub use plan::{pool_sizing, ServePlan};
pub use search::{search_plan, spec_iter_time_s, SearchResult};
