//! The planner's search: enumerate candidate plans from
//! `schedule::tile` legal tilings, score each with the existing
//! rooflines (`cost::{prefill_flops_s, decode_weight_stream_s,
//! roofline_time_s}`) plus the [`TierCostModel`], pick the cheapest.
//!
//! Everything here is pure arithmetic over the
//! `(Qwen3Config, MachineSpec, max_batch)` inputs — no clocks, no
//! randomness, no measurement — so the chosen plan is deterministic
//! across calls and processes, which is what lets the differential
//! tests pin `--autotune` output against the untuned oracle.
//!
//! ## Search space
//!
//! * **Panel granularity** — derived from the level-1 (cache-panel)
//!   loop orders reachable in the [`TiledState`] of the serving step's
//!   projection GEMM: the further the token-row dim `m` is hoisted out
//!   of the panel loop nest, the taller the row panel each SPMD shard
//!   owns (`MR` × 2^hoist). All values stay on the MR grid, so
//!   [`crate::parallel::panel_splits`] keeps shard boundaries on packed
//!   μkernel tiles and outputs are bitwise unchanged.
//! * **Threads** — `1 ..= min(cores, partition_width)` (powers of two
//!   plus the cap itself).
//! * **Prefill chunk** — `{1, 8, 16, 32, 64}`.
//! * **Step token budget** — full (`max_batch × chunk`) and a halved
//!   decode-priority variant, both ≥ every legal row need.
//!
//! ## Scoring
//!
//! A nominal serving episode (`max_batch` sequences × 512 prompt + 128
//! decode tokens) priced per iteration: the roofline over the step's
//! FLOPs and streamed weight bytes, derated for panel-quantized load
//! imbalance, plus a barrier-sync term (`sync_alpha_s × threads` per
//! barrier — barrier entry costs time even solo, so every iteration
//! carries a fixed dispatch floor) and a per-panel-unit setup term.
//! The terms pull against the roofline: more threads raise the
//! FLOP/bandwidth roofs but pay more sync; taller panels amortize
//! setup but idle workers when the step has fewer row-panels than
//! threads; and the dispatch floor makes fewer, fuller iterations win
//! where the roofline alone would tie.

use crate::cost::{
    decode_weight_stream_s, prefill_flops_s, roofline_time_s, MachineSpec,
};
use crate::ir::{Graph, UnaryKind};
use crate::model::Qwen3Config;
use crate::ntt::MR;
use crate::schedule::{subgraph_to_tileops, Action, TiledState};
use crate::serving::tiered::TierCostModel;

use super::plan::{pool_sizing, ServePlan};

/// Prompt/decode lengths of the nominal episode the search prices.
/// Arbitrary but fixed: only the *ordering* of candidate costs matters,
/// and it is stable over a wide range of episode shapes.
const NOMINAL_PROMPT: usize = 512;
const NOMINAL_DECODE: usize = 128;
/// Packed-GEMM efficiency, matching `cost::enode_cost`'s packed matmul.
const GEMM_EFF: f64 = 0.85;

/// Outcome of one planner search: the winner plus every scored loser
/// (the property test asserts `chosen.predicted_cost_s` ≤ each of
/// them).
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub chosen: ServePlan,
    pub rejected: Vec<ServePlan>,
}

/// The serving step's GEMM + element-wise tail as [`TileOp`]s
/// (`schedule::tile`): token rows × hidden through one projection
/// matrix, activation fused behind it — the loop nest every
/// `matmul_rows` phase of `spmd_step` executes.
fn step_tileops(model: &Qwen3Config, rows: usize) -> Vec<crate::schedule::TileOp> {
    let mut g = Graph::new();
    let x = g.input("X", &[rows.max(1), model.hidden], model.dtype);
    let w = g.input("W", &[model.hidden, model.intermediate], model.dtype);
    let proj = g.matmul(x, w);
    let act = g.unary(UnaryKind::Exp, proj); // the SwiGLU activation tail
    g.mark_output(act);
    let nodes = g.live_nodes();
    subgraph_to_tileops(&g, &nodes)
}

/// Panel-granularity candidates from the legal tilings of the step
/// GEMM: breadth-first over `TiledState::legal_actions` reorders of the
/// GEMM's level-1 loop order (depth 2 reaches every position of the
/// row dim `m`). Returns `(panel_rows, level-1 order)` pairs, deduped,
/// panel ascending.
fn panel_candidates(model: &Qwen3Config) -> Vec<(usize, String)> {
    let ops = step_tileops(model, NOMINAL_PROMPT);
    let init = TiledState::initial(ops, 2);
    // The GEMM is op 0 and its row dim is the first loop char of its
    // natural order (subgraph_to_tileops names it `i`).
    let m_dim = init.order[1][0][0];
    let mut frontier = vec![init];
    let mut out: Vec<(usize, String)> = Vec::new();
    for _depth in 0..=2 {
        let mut next = Vec::new();
        for st in &frontier {
            let ord = &st.order[1][0];
            let inner_dist = ord.len() - 1 - ord.iter().position(|&c| c == m_dim).unwrap();
            let panel = MR << inner_dist.min(2);
            if !out.iter().any(|(p, _)| *p == panel) {
                let order_s: String =
                    ord.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",");
                out.push((panel, order_s));
            }
            for a in st.legal_actions() {
                if matches!(a, Action::Reorder { op: 0, level: 1, .. }) {
                    next.push(st.apply(&a));
                }
            }
        }
        frontier = next;
    }
    out.sort_by_key(|(p, _)| *p);
    out
}

/// Thread-count candidates: powers of two up to the legal cap
/// (`min(cores, partition_width)`), plus the cap itself.
fn thread_candidates(model: &Qwen3Config, machine: &MachineSpec) -> Vec<usize> {
    let cap = machine.cores.min(model.partition_width()).max(1);
    let mut out: Vec<usize> = [1usize, 2, 4, 8, 16, 32]
        .iter()
        .copied()
        .filter(|&t| t <= cap)
        .collect();
    if !out.contains(&cap) {
        out.push(cap);
    }
    out
}

/// Predicted seconds of one engine iteration carrying `rows` token
/// rows: roofline over the step's FLOPs and its streamed weight plane,
/// with panel-quantized load balance, barrier sync and per-panel setup.
fn iter_time_s(
    model: &Qwen3Config,
    machine: &MachineSpec,
    threads: usize,
    panel_rows: usize,
    rows: usize,
) -> f64 {
    let rows = rows.max(1);
    // Panel-quantized parallelism: workers own whole row panels, so a
    // step with fewer panels than threads leaves workers idle through
    // the GEMM phases.
    let units = rows.div_ceil(panel_rows);
    let eff_threads = threads.min(units).max(1);
    let flops = rows as u64 * 2 * model.param_count();
    let bytes = model.decode_stream_bytes();
    let roof = roofline_time_s(
        flops,
        bytes,
        machine,
        eff_threads,
        model.dtype.size_bytes(),
        GEMM_EFF,
    );
    // Barrier sync: ~8 phase barriers per layer plus embedding / final
    // norm / LM head, each costing alpha per participant — entering a
    // barrier (and the scheduler pass around the step) costs time even
    // solo, so every iteration carries a fixed dispatch floor. That
    // floor is what makes fewer-iteration plans strictly cheaper on
    // machines where the roofline alone would tie (pure compute-bound
    // prefill is linear in rows, so chunk 1 and chunk 64 move identical
    // FLOPs).
    let barriers = (model.layers * 8 + 3) as f64;
    let sync = barriers * machine.sync_alpha_s * threads as f64;
    // Per-panel-unit setup (A-panel pack + loop prologue) across the 7
    // projections per layer + LM head, divided over the workers.
    let gemms = (model.layers * 7 + 1) as f64;
    let setup = gemms * units.div_ceil(threads.max(1)) as f64 * machine.sync_alpha_s;
    roof + sync + setup
}

/// Smallest preemption-victim length (tokens) at which spill + fetch
/// beats recompute under the machine's [`TierCostModel`] (int8 cold
/// payload, scale overhead ignored). Closed form of
/// `TierCostModel::should_swap` with both transfers ~= the victim's KV
/// bytes: swap pays iff
/// `2α + 2·t·b/bw < t·f/F  ⇔  t > 2α / (f/F − 2b/bw)`.
fn swap_break_even_tokens(
    model: &Qwen3Config,
    machine: &MachineSpec,
    threads: usize,
) -> Option<usize> {
    let tcm = TierCostModel::for_machine(machine, model, threads);
    // Int8 cold payload: one byte per stored KV value.
    let bytes_per_token = (2 * model.layers * model.kv_heads * model.head_dim) as f64;
    let recompute_per_token = tcm.flops_per_token / tcm.recompute_flops_per_s.max(1.0);
    let transfer_per_token = 2.0 * bytes_per_token / tcm.cold_bw_bytes_per_s.max(1.0);
    let gain = recompute_per_token - transfer_per_token;
    if gain <= 0.0 {
        return None; // moving bytes never beats redoing FLOPs here
    }
    Some(((2.0 * tcm.cold_alpha_s / gain).ceil() as usize).max(1))
}

/// Enumerate and score every candidate, returning the cheapest plan
/// plus the scored rejects. Ties break deterministically: lower
/// predicted cost first (`f64::total_cmp`), then fewer threads, smaller
/// chunk, smaller panel, smaller budget.
pub fn search_plan(
    model: &Qwen3Config,
    machine: &MachineSpec,
    max_batch: usize,
) -> SearchResult {
    let batch = max_batch.max(1);
    let (block_size, num_blocks) = pool_sizing(model, machine, max_batch);
    let panels = panel_candidates(model);
    let threads = thread_candidates(model, machine);
    let chunks = [1usize, 8, 16, 32, 64];

    let mut candidates: Vec<ServePlan> = Vec::new();
    for &(panel_rows, ref tiling) in &panels {
        for &t in &threads {
            for &chunk in &chunks {
                let full = batch * chunk;
                let half = (full / 2).max(batch).max(chunk);
                let mut budgets = vec![full];
                if half != full {
                    budgets.push(half);
                }
                for budget in budgets {
                    let prefill_iter = iter_time_s(model, machine, t, panel_rows, budget);
                    let decode_iter = iter_time_s(model, machine, t, panel_rows, batch);
                    // Episode cost: every prompt token through prefill
                    // iterations of `budget` rows, then lockstep decode.
                    let prefill_iters = (NOMINAL_PROMPT * batch).div_ceil(budget);
                    let cost = prefill_iters as f64 * prefill_iter
                        + NOMINAL_DECODE as f64 * decode_iter;
                    candidates.push(ServePlan {
                        model: model.name.clone(),
                        machine: machine.name.clone(),
                        weight_quant: model.weight_quant,
                        max_batch: batch,
                        block_size,
                        num_blocks,
                        decode_threads: t,
                        prefill_chunk: chunk,
                        step_token_budget: budget,
                        panel_rows,
                        swap_break_even_tokens: swap_break_even_tokens(model, machine, t),
                        tiling: tiling.clone(),
                        // Sharding is a serve-options decision, not a
                        // search axis: ServeOptions::resolve stamps the
                        // dist-extracted layout in before the run.
                        shards: 1,
                        sbp_sig: "-".into(),
                        // Speculation is a serve-options decision too:
                        // its payoff depends on workload repetitiveness,
                        // which pure (model, machine) arithmetic cannot
                        // see. Resolve stamps the depth in.
                        spec_k: 0,
                        predicted_decode_iter_s: decode_iter,
                        predicted_prefill_iter_s: prefill_iter,
                        predicted_cost_s: cost,
                    });
                }
            }
        }
    }

    candidates.sort_by(|a, b| {
        a.predicted_cost_s
            .total_cmp(&b.predicted_cost_s)
            .then(a.decode_threads.cmp(&b.decode_threads))
            .then(a.prefill_chunk.cmp(&b.prefill_chunk))
            .then(a.panel_rows.cmp(&b.panel_rows))
            .then(a.step_token_budget.cmp(&b.step_token_budget))
    });
    let chosen = candidates.remove(0);
    debug_assert!(chosen.check_legal(model).is_ok(), "planner emitted an illegal plan");
    SearchResult { chosen, rejected: candidates }
}

/// Predicted seconds of one *speculative* decode iteration under
/// `plan` with depth `spec_k`: every decode slot carries `1 + spec_k`
/// token rows (the sampled token plus its drafts) through one tall
/// GEMM. The roofline prices this far below `1 + spec_k` sequential
/// decode iterations — decode is weight-stream-bound, and the extra
/// rows ride the same streamed weight plane — which is exactly the
/// amortization speculative decoding banks on. Diagnostic, like
/// [`plan_floors`]: the scheduler never gates drafting on it.
pub fn spec_iter_time_s(
    model: &Qwen3Config,
    machine: &MachineSpec,
    plan: &ServePlan,
    spec_k: usize,
) -> f64 {
    iter_time_s(
        model,
        machine,
        plan.decode_threads,
        plan.panel_rows,
        plan.max_batch * (1 + spec_k),
    )
}

/// Consistency handles the docs and tests lean on: the floors the
/// score is built from, re-exported per plan for diagnostics.
pub fn plan_floors(
    model: &Qwen3Config,
    machine: &MachineSpec,
    plan: &ServePlan,
) -> (f64, f64) {
    (
        prefill_flops_s(model, machine, plan.decode_threads),
        decode_weight_stream_s(model, machine, plan.decode_threads),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panel_candidates_cover_the_mr_grid() {
        let model = Qwen3Config::tiny();
        let panels = panel_candidates(&model);
        let values: Vec<usize> = panels.iter().map(|(p, _)| *p).collect();
        assert_eq!(values, vec![MR, 2 * MR, 4 * MR], "depth-2 reorders reach all m positions");
        for (_, order) in &panels {
            assert!(order.contains('i'), "order must name the row dim: {order}");
        }
    }

    #[test]
    fn search_is_deterministic_and_minimal() {
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::ryzen_5900x();
        let a = search_plan(&model, &machine, 8);
        let b = search_plan(&model, &machine, 8);
        assert_eq!(a.chosen, b.chosen);
        for r in &a.rejected {
            assert!(
                a.chosen.predicted_cost_s <= r.predicted_cost_s,
                "chosen {} beaten by rejected {}",
                a.chosen.predicted_cost_s,
                r.predicted_cost_s
            );
        }
        assert!(!a.rejected.is_empty(), "a one-candidate search proves nothing");
    }

    #[test]
    fn chunked_prefill_wins_on_compute_rich_machines() {
        // On every preset the prefill compute floor sits below the
        // weight-stream floor (cost::roofline tests), so the planner
        // must never keep GEMV-shaped prefill.
        for machine in
            [MachineSpec::ryzen_5900x(), MachineSpec::tpu_like(), MachineSpec::test_numa()]
        {
            let plan = search_plan(&Qwen3Config::tiny(), &machine, 8).chosen;
            assert!(plan.prefill_chunk > 1, "{}: chunk {}", machine.name, plan.prefill_chunk);
        }
    }

    #[test]
    fn threads_respect_the_partition_width() {
        let model = Qwen3Config::tiny(); // partition_width = 2
        let plan = search_plan(&model, &MachineSpec::ryzen_5900x(), 8).chosen;
        assert!(plan.decode_threads <= model.partition_width());
        assert!(plan.decode_threads >= 1);
    }

    #[test]
    fn floors_bound_the_iteration_predictions() {
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::ryzen_5900x();
        let plan = search_plan(&model, &machine, 8).chosen;
        let (prefill_floor, decode_floor) = plan_floors(&model, &machine, &plan);
        // One decode iteration streams the weight plane at least once.
        assert!(plan.predicted_decode_iter_s >= decode_floor * 0.99);
        // A prefill iteration of `budget` rows costs at least the
        // compute floor of those rows at full efficiency.
        assert!(
            plan.predicted_prefill_iter_s
                >= prefill_floor * plan.step_token_budget as f64 * 0.5
        );
    }

    #[test]
    fn speculative_iterations_amortize_the_weight_stream() {
        // The cost-model case for self-drafting: verifying k drafts in
        // one tall iteration must be priced well below running 1 + k
        // weight-stream-bound decode iterations.
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::ryzen_5900x();
        let plan = search_plan(&model, &machine, 8).chosen;
        let base = plan.predicted_decode_iter_s;
        for k in [1usize, 2, 4, 8] {
            let spec = spec_iter_time_s(&model, &machine, &plan, k);
            assert!(spec >= base, "extra rows cannot be free: k={k}");
            assert!(
                spec < (1 + k) as f64 * base,
                "k={k}: one tall iteration ({spec:.6}s) must beat {} sequential \
                 iterations ({:.6}s)",
                1 + k,
                (1 + k) as f64 * base
            );
        }
    }

    #[test]
    fn swap_break_even_is_finite_where_recompute_is_slow() {
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::ryzen_5900x();
        let be = swap_break_even_tokens(&model, &machine, 1);
        // Tiny recompute is cheap but the closed form must still agree
        // with TierCostModel::should_swap around its own threshold.
        if let Some(t) = be {
            let tcm = TierCostModel::for_machine(&machine, &model, 1);
            let bpt = (2 * model.layers * model.kv_heads * model.head_dim) as u64;
            assert!(
                tcm.should_swap(4 * t as u64 * bpt, 4 * t as u64 * bpt, 4 * t),
                "well past break-even ({t} tokens) swap must pay"
            );
        }
    }
}
