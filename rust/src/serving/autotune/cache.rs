//! In-process plan cache: one search per
//! `(Qwen3Config, MachineSpec, WeightQuant, max_batch)` key.
//!
//! Neither `Qwen3Config` nor `MachineSpec` implements `Eq`/`Hash`
//! (both carry floats), so the key is a canonical formatted string of
//! every field the search reads — two configs that render the same key
//! are planned identically by construction, because the search is a
//! pure function of exactly these fields. The search itself is
//! deterministic, so a racing double-insert is harmless: both threads
//! computed the same plan.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::cost::MachineSpec;
use crate::model::Qwen3Config;

use super::plan::ServePlan;
use super::search::search_plan;

static CACHE: OnceLock<Mutex<HashMap<String, ServePlan>>> = OnceLock::new();

/// Canonical cache key: every model / machine / workload field the
/// search consumes (the planning triple plus the batch cap).
pub fn plan_key(model: &Qwen3Config, machine: &MachineSpec, max_batch: usize) -> String {
    format!(
        "{name}|h{h}l{l}q{q}kv{kv}hd{hd}i{i}v{v}|{dt:?}|{wq}|\
         {mname}|c{c}vb{vb}fu{fu}f{f}bwc{bwc}bwt{bwt}sa{sa}mem{mem}cbw{cbw}ca{ca}|b{b}",
        name = model.name,
        h = model.hidden,
        l = model.layers,
        q = model.heads,
        kv = model.kv_heads,
        hd = model.head_dim,
        i = model.intermediate,
        v = model.vocab,
        dt = model.dtype,
        wq = model.weight_quant.name(),
        mname = machine.name,
        c = machine.cores,
        vb = machine.vector_bits,
        fu = machine.fma_units,
        f = machine.freq_ghz,
        bwc = machine.dram_bw_core_gbps,
        bwt = machine.dram_bw_total_gbps,
        sa = machine.sync_alpha_s,
        mem = machine.mem_capacity_bytes,
        cbw = machine.cold_bw_gbps,
        ca = machine.cold_alpha_s,
        b = max_batch,
    )
}

/// The planner's front door: return the cached plan for the triple, or
/// run [`search_plan`] once and cache its winner.
pub fn plan_for(model: &Qwen3Config, machine: &MachineSpec, max_batch: usize) -> ServePlan {
    let key = plan_key(model, machine, max_batch);
    let cache = CACHE.get_or_init(Default::default);
    if let Some(p) = cache.lock().unwrap().get(&key) {
        return p.clone();
    }
    // Search outside the lock: it is pure and deterministic, so a
    // concurrent duplicate computes the identical plan.
    let plan = search_plan(model, machine, max_batch).chosen;
    cache.lock().unwrap().entry(key).or_insert(plan).clone()
}

/// Number of distinct triples planned so far (test hook).
pub fn cached_plan_count() -> usize {
    CACHE.get().map_or(0, |c| c.lock().unwrap().len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_round_trip_is_stable() {
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::ryzen_5900x();
        let a = plan_for(&model, &machine, 8);
        let n = cached_plan_count();
        let b = plan_for(&model, &machine, 8);
        assert_eq!(a, b, "cache hit must return the identical plan");
        assert_eq!(cached_plan_count(), n, "second call must not re-insert");
        assert_eq!(a, search_plan(&model, &machine, 8).chosen, "cache is transparent");
    }

    #[test]
    fn key_separates_the_triple() {
        let model = Qwen3Config::tiny();
        let machine = MachineSpec::ryzen_5900x();
        let base = plan_key(&model, &machine, 8);
        assert_ne!(base, plan_key(&model, &machine, 4), "batch cap is part of the key");
        let quant = model.clone().with_weight_quant(crate::ntt::WeightQuant::Int8);
        assert_ne!(base, plan_key(&quant, &machine, 8), "weight quant is part of the key");
        assert_ne!(
            base,
            plan_key(&model, &MachineSpec::test_numa(), 8),
            "machine is part of the key"
        );
    }
}
