//! Batched decode over paged KV storage, executed SPMD by persistent
//! worker threads.
//!
//! One [`BatchStepper::step`] advances *every* scheduled sequence by one
//! position — iteration-level batching. The win over per-request decode
//! is in the weight stream: decode is memory-bound on weights, and the
//! FCFS path re-reads every projection matrix once per sequence per
//! token. Here the projections of all `B` batched rows run as one GEMM
//! over weights pre-packed at engine build ([`WeightMat`]: f32 NR
//! panels, or group-quantized int8/int4 codes streamed through the
//! fused dequant-GEMM kernels when `Qwen3Config::weight_quant` asks for
//! them — ¼/⅛ of the f32 weight bytes per iteration), so the weight
//! stream is paid once per iteration instead of `B` times.
//!
//! **Threading.** [`BatchEngine::run`] opens one `thread::scope` per
//! serve run — not per step — and parks `threads - 1` persistent workers
//! on the shared [`SpinBarrier`]. Each step, the controller publishes
//! the slot list, releases the workers through the barrier, and joins
//! them as worker 0. The step body is barrier-separated SPMD phases with
//! a *static, deterministic* partition ([`crate::parallel::splits`] /
//! [`panel_splits`]): per-sequence work (RMSNorm, RoPE, paged attention)
//! shards by batch row, the packed GEMMs shard by MR-row panel
//! ([`matmul_prepacked_rows`]), and the KV commit stays a single-writer
//! phase behind [`KvCell`] exactly like the dense engine. Every output
//! element is computed by one statically-known worker with the same
//! accumulation order as the single-threaded path, so outputs are
//! token-identical to the dense FCFS oracle at **any** thread count
//! (`rust/tests/serving.rs` pins this down for 1, 2 and 4).
//!
//! K/V rows are gathered through per-sequence block tables
//! ([`attn_scores_paged`] / [`attn_context_paged`]) instead of
//! contiguous rows; every kernel shares its accumulation order with the
//! dense single-sequence engine.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::tiered::{ColdKv, KvQuant, TierOp};
use crate::coordinator::argmax;
use crate::model::{Qwen3Config, Qwen3Weights};
use crate::ntt::{
    add_inplace, attn_context_paged, attn_context_paged_accum, attn_context_quant_i8,
    attn_scores_paged, attn_scores_quant_i8, mul_inplace, paged_row, rmsnorm, rope_inplace,
    silu_inplace, softmax_inplace, Tensor, WeightMat, MR,
};
use crate::parallel::{
    panel_splits, splits, KvCell, PoisonGuard, SharedCell, SharedVec, SpinBarrier,
};

/// Paged KV arena: per layer, `num_blocks * block_size` rows of width
/// `kv_heads * head_dim`. Physical block `b` owns the same row range in
/// every layer.
pub struct PagedKv {
    pub block_size: usize,
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
}

impl PagedKv {
    pub fn new(layers: usize, num_blocks: usize, block_size: usize, width: usize) -> Self {
        let rows = num_blocks * block_size;
        PagedKv {
            block_size,
            k: (0..layers).map(|_| Tensor::zeros(&[rows, width])).collect(),
            v: (0..layers).map(|_| Tensor::zeros(&[rows, width])).collect(),
        }
    }

    /// Bytes of the whole arena (both K and V, all layers).
    pub fn arena_bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|t| t.numel() * 4).sum()
    }
}

/// One layer's packed weight plane. Each matrix is a [`WeightMat`]:
/// f32 NR panels or group-quantized codes per `Qwen3Config::weight_quant`
/// — the GEMM phases shard and accumulate identically in either mode,
/// so quantization never touches the SPMD partition, the bitwise
/// thread-count determinism, or the `KvCell` commit protocol.
struct PackedLayer {
    wq: WeightMat,
    wk: WeightMat,
    wv: WeightMat,
    wo: WeightMat,
    w_gate: WeightMat,
    w_up: WeightMat,
    w_down: WeightMat,
}

/// One sequence's slot in a batched iteration.
pub struct StepSlot<'t> {
    /// Token to feed at `pos`.
    pub token: usize,
    /// Logical position of `token` in the sequence.
    pub pos: usize,
    /// The sequence's *hot* block table, covering logical blocks after
    /// the cold prefix; together with `cold` it must cover `pos`.
    pub table: &'t [u32],
    /// Cold-tier slots of the sequence's leading logical blocks (direct
    /// dequant-gather reads). Empty on the untiered path — attention
    /// then takes the exact pre-tiering code path.
    pub cold: &'t [u32],
    /// Sample an output token from this row's logits (the sequence is
    /// at its frontier: last prompt token or a decode step).
    pub sample: bool,
}

impl<'t> StepSlot<'t> {
    /// A slot with no cold prefix (the flat-pool path).
    pub fn hot(token: usize, pos: usize, table: &'t [u32], sample: bool) -> Self {
        StepSlot { token, pos, table, cold: &[], sample }
    }
}

/// Owned copy of a [`StepSlot`] (block tables cloned), published to the
/// persistent workers so they never borrow the scheduler's state.
struct OwnedSlot {
    token: usize,
    pos: usize,
    table: Vec<u32>,
    cold: Vec<u32>,
    sample: bool,
}

/// Shared per-run state of one SPMD serve run: the published work
/// descriptor plus the activation buffers, all sized at `max_batch`
/// capacity and written by disjoint row ranges between barriers.
struct StepState {
    slots: SharedCell<Vec<OwnedSlot>>,
    x: SharedVec,
    xn: SharedVec,
    q: SharedVec,
    kvec: SharedVec,
    vvec: SharedVec,
    ctx: SharedVec,
    attn: SharedVec,
    gate: SharedVec,
    up: SharedVec,
    down: SharedVec,
    logits: SharedVec,
}

impl StepState {
    fn new(cfg: &Qwen3Config, max_batch: usize) -> Self {
        let (h, hd) = (cfg.hidden, cfg.head_dim);
        let (qdim, kvdim) = (cfg.heads * hd, cfg.kv_heads * hd);
        StepState {
            slots: SharedCell::new(Vec::new()),
            x: SharedVec::new(max_batch * h),
            xn: SharedVec::new(max_batch * h),
            q: SharedVec::new(max_batch * qdim),
            kvec: SharedVec::new(max_batch * kvdim),
            vvec: SharedVec::new(max_batch * kvdim),
            ctx: SharedVec::new(max_batch * qdim),
            attn: SharedVec::new(max_batch * h),
            gate: SharedVec::new(max_batch * cfg.intermediate),
            up: SharedVec::new(max_batch * cfg.intermediate),
            down: SharedVec::new(max_batch * h),
            logits: SharedVec::new(max_batch * cfg.vocab),
        }
    }
}

const CMD_STEP: usize = 0;
const CMD_EXIT: usize = 1;

/// One barrier-separated SPMD step, executed by all `t` participants
/// (the controller as worker 0, plus the parked workers released into
/// it). Per-sequence phases shard batch rows with `splits`; GEMM phases
/// shard MR-row panels with `panel_splits`. Both partitions depend only
/// on `(batch, t)`, and every element keeps the single-threaded
/// accumulation order, so results are identical at any thread count.
#[allow(clippy::too_many_arguments)]
fn spmd_step(
    wi: usize,
    t: usize,
    weights: &Qwen3Weights,
    packed: &[PackedLayer],
    packed_lm_head: &WeightMat,
    kv_cell: &KvCell<'_, PagedKv>,
    cold_cell: Option<&KvCell<'_, ColdKv>>,
    st: &StepState,
    barrier: &SpinBarrier,
    scratch: &mut Vec<f32>,
) {
    // SAFETY: the controller wrote this step's slots before releasing
    // the workers through the barrier, and rewrites them only after the
    // final barrier below has parked everyone again.
    let slots: &[OwnedSlot] = unsafe { st.slots.read() };
    let b = slots.len();
    let cfg = &weights.cfg;
    let h = cfg.hidden;
    let hd = cfg.head_dim;
    let heads = cfg.heads;
    let kvh = cfg.kv_heads;
    let qdim = heads * hd;
    let kvdim = kvh * hd;
    let inter = cfg.intermediate;
    let vocab = cfg.vocab;
    let group = heads / kvh;
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    let bs = kv_cell.read().block_size;
    // This worker's static shards.
    let (r0, r1) = splits(b, t)[wi];
    let (p0, p1) = panel_splits(b, MR, t)[wi];

    // Phase 0: embedding gather, per-sequence shard.
    for i in r0..r1 {
        unsafe { st.x.slice_mut(i * h, (i + 1) * h) }
            .copy_from_slice(weights.embedding.row(slots[i].token % vocab));
    }
    barrier.wait();

    for l in 0..cfg.layers {
        let w = &weights.layers[l];
        let pw = &packed[l];
        // Phase 1: attention RMSNorm, per-sequence shard.
        for i in r0..r1 {
            unsafe {
                rmsnorm(
                    &st.x.read()[i * h..(i + 1) * h],
                    &w.attn_norm.data,
                    cfg.rms_eps,
                    st.xn.slice_mut(i * h, (i + 1) * h),
                );
            }
        }
        barrier.wait();
        // Phase 2: batched QKV projections, MR-panel shard — each worker
        // streams the packed weights once for its rows of the batch.
        unsafe {
            let xn = &st.xn.read()[..b * h];
            let qs = st.q.slice_mut(p0 * qdim, p1 * qdim);
            pw.wq.matmul_rows(xn, b, p0, p1, qs, scratch);
            let ks = st.kvec.slice_mut(p0 * kvdim, p1 * kvdim);
            pw.wk.matmul_rows(xn, b, p0, p1, ks, scratch);
            let vs = st.vvec.slice_mut(p0 * kvdim, p1 * kvdim);
            pw.wv.matmul_rows(xn, b, p0, p1, vs, scratch);
        }
        barrier.wait();
        // Phase 3: RoPE, per-sequence shard (positions differ per row).
        for i in r0..r1 {
            let pos = slots[i].pos;
            for head in 0..heads {
                let o = i * qdim + head * hd;
                unsafe { rope_inplace(st.q.slice_mut(o, o + hd), pos, cfg.rope_theta) };
            }
            for head in 0..kvh {
                let o = i * kvdim + head * hd;
                unsafe { rope_inplace(st.kvec.slice_mut(o, o + hd), pos, cfg.rope_theta) };
            }
        }
        barrier.wait();
        // Phase 4 (serial): commit every slot's K/V row through its
        // block table. Distinct slots never alias (a frontier position
        // always lives in a privately-held tail block), but the commit
        // stays a single-writer KvCell window so the invariant is
        // enforced, not assumed.
        if wi == 0 {
            kv_cell.commit(wi, |kv| {
                let kvec = st.kvec.read();
                let vvec = st.vvec.read();
                for (i, s) in slots.iter().enumerate() {
                    // The hot table starts after the cold prefix; the
                    // frontier row always lives in a hot block.
                    let row = paged_row(&s.table, bs, s.pos - s.cold.len() * bs);
                    kv.k[l].row_mut(row).copy_from_slice(&kvec[i * kvdim..(i + 1) * kvdim]);
                    kv.v[l].row_mut(row).copy_from_slice(&vvec[i * kvdim..(i + 1) * kvdim]);
                }
            });
        }
        barrier.wait();
        // Phase 5: paged GQA attention, per-sequence shard. Slots with a
        // cold prefix take the hybrid path: the leading full blocks are
        // read *in place* from the quantized cold tier (dequant-gather
        // kernels), the hot suffix through the block table — positions
        // stay in ascending order, so softmax and the context
        // accumulation see the same sequence order as the dense path.
        // Slots without one take the exact pre-tiering code path.
        let kv = kv_cell.read();
        for i in r0..r1 {
            let s = &slots[i];
            let seq = s.pos + 1;
            let cold_toks = s.cold.len() * bs;
            let cstore = (cold_toks > 0).then(|| {
                cold_cell
                    .expect("slot has a cold prefix but the engine has no cold tier")
                    .read()
            });
            let q = st.q.read();
            let ctx_row = unsafe { st.ctx.slice_mut(i * qdim, (i + 1) * qdim) };
            let mut scores = vec![0.0f32; seq];
            for head in 0..heads {
                let kvhead = head / group;
                let qo = i * qdim + head * hd;
                if cold_toks == 0 {
                    attn_scores_paged(
                        &q[qo..qo + hd],
                        &kv.k[l],
                        &s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        inv_sqrt,
                        &mut scores,
                    );
                    softmax_inplace(&mut scores);
                    attn_context_paged(
                        &scores,
                        &kv.v[l],
                        &s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        &mut ctx_row[head * hd..(head + 1) * hd],
                    );
                } else {
                    let cold = cstore.expect("Some whenever cold_toks > 0");
                    for (bi, &slot) in s.cold.iter().enumerate() {
                        let (kq, sc, zp) = cold.k_block(slot, l);
                        attn_scores_quant_i8(
                            &q[qo..qo + hd],
                            kq,
                            sc,
                            zp,
                            bs,
                            kvdim,
                            kvhead * hd,
                            hd,
                            inv_sqrt,
                            &mut scores[bi * bs..(bi + 1) * bs],
                        );
                    }
                    attn_scores_paged(
                        &q[qo..qo + hd],
                        &kv.k[l],
                        &s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        inv_sqrt,
                        &mut scores[cold_toks..],
                    );
                    softmax_inplace(&mut scores);
                    let out = &mut ctx_row[head * hd..(head + 1) * hd];
                    out.fill(0.0);
                    for (bi, &slot) in s.cold.iter().enumerate() {
                        let (vq, sc, zp) = cold.v_block(slot, l);
                        attn_context_quant_i8(
                            &scores[bi * bs..(bi + 1) * bs],
                            vq,
                            sc,
                            zp,
                            kvdim,
                            kvhead * hd,
                            hd,
                            out,
                        );
                    }
                    attn_context_paged_accum(
                        &scores[cold_toks..],
                        &kv.v[l],
                        &s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        out,
                    );
                }
            }
        }
        barrier.wait();
        // Phase 6: output projection, MR-panel shard.
        unsafe {
            let ctx = &st.ctx.read()[..b * qdim];
            let os = st.attn.slice_mut(p0 * h, p1 * h);
            pw.wo.matmul_rows(ctx, b, p0, p1, os, scratch);
        }
        barrier.wait();
        // Phase 7: residual + MLP RMSNorm, per-sequence shard.
        for i in r0..r1 {
            unsafe {
                add_inplace(
                    st.x.slice_mut(i * h, (i + 1) * h),
                    &st.attn.read()[i * h..(i + 1) * h],
                );
                rmsnorm(
                    &st.x.read()[i * h..(i + 1) * h],
                    &w.mlp_norm.data,
                    cfg.rms_eps,
                    st.xn.slice_mut(i * h, (i + 1) * h),
                );
            }
        }
        barrier.wait();
        // Phase 8: SwiGLU gate/up, MR-panel shard (the elementwise tail
        // runs on the same rows this worker just computed).
        unsafe {
            let xn = &st.xn.read()[..b * h];
            let gs = st.gate.slice_mut(p0 * inter, p1 * inter);
            pw.w_gate.matmul_rows(xn, b, p0, p1, gs, scratch);
            let us = st.up.slice_mut(p0 * inter, p1 * inter);
            pw.w_up.matmul_rows(xn, b, p0, p1, us, scratch);
            let g = st.gate.slice_mut(p0 * inter, p1 * inter);
            silu_inplace(g);
            mul_inplace(g, &st.up.read()[p0 * inter..p1 * inter]);
        }
        barrier.wait();
        // Phase 9: down projection, MR-panel shard.
        unsafe {
            let gate = &st.gate.read()[..b * inter];
            let ds = st.down.slice_mut(p0 * h, p1 * h);
            pw.w_down.matmul_rows(gate, b, p0, p1, ds, scratch);
        }
        barrier.wait();
        // Phase 10: residual, per-sequence shard.
        for i in r0..r1 {
            unsafe {
                add_inplace(
                    st.x.slice_mut(i * h, (i + 1) * h),
                    &st.down.read()[i * h..(i + 1) * h],
                );
            }
        }
        barrier.wait();
    }
    // Final norm (per-sequence shard) + LM head (MR-panel shard).
    for i in r0..r1 {
        unsafe {
            rmsnorm(
                &st.x.read()[i * h..(i + 1) * h],
                &weights.final_norm.data,
                cfg.rms_eps,
                st.xn.slice_mut(i * h, (i + 1) * h),
            );
        }
    }
    barrier.wait();
    unsafe {
        let xn = &st.xn.read()[..b * h];
        let ls = st.logits.slice_mut(p0 * vocab, p1 * vocab);
        packed_lm_head.matmul_rows(xn, b, p0, p1, ls, scratch);
    }
    // Final barrier: publishes every logits shard to the controller and
    // parks the workers for the next step.
    barrier.wait();
}

/// The batched paged-attention decode engine.
pub struct BatchEngine<'w> {
    pub weights: &'w Qwen3Weights,
    packed: Vec<PackedLayer>,
    packed_lm_head: WeightMat,
    pub kv: PagedKv,
    /// Cold-tier arena (`Some` after [`BatchEngine::enable_tier`]).
    pub cold: Option<ColdKv>,
}

/// Controller handle of a live SPMD serve run (see [`BatchEngine::run`]):
/// issues steps to the parked persistent workers and participates as
/// worker 0.
pub struct BatchStepper<'a, 'kv> {
    weights: &'a Qwen3Weights,
    packed: &'a [PackedLayer],
    packed_lm_head: &'a WeightMat,
    kv_cell: &'a KvCell<'kv, PagedKv>,
    cold_cell: Option<&'a KvCell<'kv, ColdKv>>,
    st: &'a StepState,
    barrier: &'a SpinBarrier,
    threads: usize,
    max_batch: usize,
    scratch: Vec<f32>,
}

impl BatchStepper<'_, '_> {
    /// Effective worker count of this run (after the batch-width clamp).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute the scheduler's tier ops for this iteration: all spills,
    /// then all fetches (a fetch may target a hot block a spill vacated
    /// in the same iteration, so the spill must read first). Runs on the
    /// controller while every worker is parked at the start barrier —
    /// the barrier release publishes the moved rows to the step.
    pub fn tier_ops(&mut self, ops: &[TierOp]) {
        if ops.is_empty() {
            return;
        }
        let cold_cell = self.cold_cell.expect("tier ops on an engine without a cold tier");
        cold_cell.commit(0, |cold| {
            self.kv_cell.commit(0, |kv| {
                for op in ops {
                    if let TierOp::Spill { hot, cold: slot, filled } = *op {
                        cold.spill(slot, kv, hot, filled);
                    }
                }
                for op in ops {
                    if let TierOp::Fetch { cold: slot, hot, .. } = *op {
                        cold.fetch(slot, kv, hot);
                    }
                }
            });
        });
    }

    /// Advance every slot one position; returns the argmax token for
    /// slots with `sample = true`.
    pub fn step(&mut self, slots: &[StepSlot]) -> Vec<Option<usize>> {
        self.step_logits(slots, false).0
    }

    /// As [`BatchStepper::step`]; with `keep_logits` the `[B * vocab]`
    /// logits buffer of the iteration is returned too (white-box tests).
    pub fn step_logits(
        &mut self,
        slots: &[StepSlot],
        keep_logits: bool,
    ) -> (Vec<Option<usize>>, Vec<f32>) {
        let b = slots.len();
        assert!(b <= self.max_batch, "batch {b} exceeds run capacity {}", self.max_batch);
        if b == 0 {
            return (Vec::new(), Vec::new());
        }
        debug_assert!(
            {
                let bs = self.kv_cell.read().block_size;
                slots.iter().all(|s| (s.cold.len() + s.table.len()) * bs > s.pos)
            },
            "a slot's block tables do not cover its position"
        );
        // Publish this step's work descriptor. SAFETY: every worker is
        // parked at the start barrier; the release below hands them a
        // happens-before view of these writes.
        unsafe {
            let owned = self.st.slots.get_mut();
            owned.clear();
            owned.extend(slots.iter().map(|s| OwnedSlot {
                token: s.token,
                pos: s.pos,
                table: s.table.to_vec(),
                cold: s.cold.to_vec(),
                sample: s.sample,
            }));
        }
        // Release the workers into the step and join as worker 0. The
        // final barrier inside `spmd_step` publishes all logits shards.
        self.barrier.wait();
        spmd_step(
            0,
            self.threads,
            self.weights,
            self.packed,
            self.packed_lm_head,
            self.kv_cell,
            self.cold_cell,
            self.st,
            self.barrier,
            &mut self.scratch,
        );
        let vocab = self.weights.cfg.vocab;
        let logits = self.st.logits.read();
        let samples = slots
            .iter()
            .enumerate()
            .map(|(i, s)| s.sample.then(|| argmax(&logits[i * vocab..(i + 1) * vocab])))
            .collect();
        (samples, if keep_logits { logits[..b * vocab].to_vec() } else { Vec::new() })
    }
}

impl<'w> BatchEngine<'w> {
    pub fn new(weights: &'w Qwen3Weights, num_blocks: usize, block_size: usize) -> Self {
        let cfg = &weights.cfg;
        // Pack (or group-quantize) the weight plane once at engine
        // build, per the model's `weight_quant` mode.
        let mode = cfg.weight_quant;
        let packed = weights
            .layers
            .iter()
            .map(|l| PackedLayer {
                wq: WeightMat::prepare(&l.wq, mode),
                wk: WeightMat::prepare(&l.wk, mode),
                wv: WeightMat::prepare(&l.wv, mode),
                wo: WeightMat::prepare(&l.wo, mode),
                w_gate: WeightMat::prepare(&l.w_gate, mode),
                w_up: WeightMat::prepare(&l.w_up, mode),
                w_down: WeightMat::prepare(&l.w_down, mode),
            })
            .collect();
        let kv = PagedKv::new(cfg.layers, num_blocks, block_size, cfg.kv_heads * cfg.head_dim);
        BatchEngine {
            weights,
            packed,
            packed_lm_head: WeightMat::prepare(&weights.lm_head, mode),
            kv,
            cold: None,
        }
    }

    /// Stored bytes of the packed/quantized weight plane (all layers +
    /// LM head) — what one batched decode iteration streams.
    pub fn weight_bytes(&self) -> usize {
        let per_layer: usize = self
            .packed
            .iter()
            .map(|p| {
                p.wq.bytes()
                    + p.wk.bytes()
                    + p.wv.bytes()
                    + p.wo.bytes()
                    + p.w_gate.bytes()
                    + p.w_up.bytes()
                    + p.w_down.bytes()
            })
            .sum();
        per_layer + self.packed_lm_head.bytes()
    }

    /// Attach a cold-tier arena of `cold_blocks` slots (call before
    /// [`BatchEngine::run`]; the serving coordinator does this when
    /// `ContinuousConfig::tiering` is set).
    pub fn enable_tier(&mut self, cold_blocks: usize, quant: KvQuant) {
        let cfg = &self.weights.cfg;
        self.cold = Some(ColdKv::new(
            cold_blocks,
            self.kv.block_size,
            cfg.layers,
            cfg.kv_heads * cfg.head_dim,
            quant,
        ));
    }

    /// Open one SPMD serve run: spawn `threads - 1` persistent workers
    /// (one `thread::scope` for the whole run, not per step), hand the
    /// driver a [`BatchStepper`], and shut the workers down when it
    /// returns. `threads` is clamped to `[1, max_batch]` — workers own
    /// whole batch rows, so counts beyond the batch capacity would only
    /// produce empty shards (the same guard `Qwen3Engine::new` applies
    /// at the model's partition width).
    pub fn run<R>(
        &mut self,
        threads: usize,
        max_batch: usize,
        driver: impl FnOnce(&mut BatchStepper<'_, '_>) -> R,
    ) -> R {
        let max_batch = max_batch.max(1);
        let t = threads.clamp(1, max_batch);
        let st = StepState::new(&self.weights.cfg, max_batch);
        let barrier = SpinBarrier::new(t);
        let cmd = AtomicUsize::new(CMD_STEP);
        let weights = self.weights;
        let packed: &[PackedLayer] = &self.packed;
        let packed_lm_head = &self.packed_lm_head;
        let kv_cell = KvCell::new(&mut self.kv);
        let cold_cell = self.cold.as_mut().map(KvCell::new);
        std::thread::scope(|s| {
            for wi in 1..t {
                let (st, barrier, cmd, kv_cell) = (&st, &barrier, &cmd, &kv_cell);
                let cold_cell = cold_cell.as_ref();
                s.spawn(move || {
                    // A panicking worker poisons the barrier so the
                    // controller and its sibling workers unwind instead
                    // of spinning forever (see SpinBarrier).
                    let _poison = PoisonGuard::new(barrier);
                    let mut scratch = Vec::new();
                    loop {
                        // Park until the controller publishes the next
                        // step (or shutdown).
                        barrier.wait();
                        if cmd.load(Ordering::Acquire) == CMD_EXIT {
                            break;
                        }
                        spmd_step(
                            wi,
                            t,
                            weights,
                            packed,
                            packed_lm_head,
                            kv_cell,
                            cold_cell,
                            st,
                            barrier,
                            &mut scratch,
                        );
                    }
                });
            }
            let mut stepper = BatchStepper {
                weights,
                packed,
                packed_lm_head,
                kv_cell: &kv_cell,
                cold_cell: cold_cell.as_ref(),
                st: &st,
                barrier: &barrier,
                threads: t,
                max_batch,
                scratch: Vec::new(),
            };
            // Workers stay parked between steps; if the driver unwinds
            // (scheduler panics, test assertions, a panic inside the
            // controller's own share of a step) they must still be made
            // to exit, or `thread::scope`'s implicit join would block
            // forever on parked/stuck workers.
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| driver(&mut stepper)));
            cmd.store(CMD_EXIT, Ordering::Release);
            match result {
                Ok(r) => {
                    // Clean shutdown: release the parked workers so they
                    // observe CMD_EXIT and break.
                    barrier.wait();
                    r
                }
                Err(payload) => {
                    // The driver unwound — workers may be parked at the
                    // start barrier or stuck at a phase barrier mid-step.
                    // Poisoning makes every wait panic, so all of them
                    // unwind instead of deadlocking the scope join; the
                    // original payload then takes precedence.
                    barrier.poison();
                    std::panic::resume_unwind(payload)
                }
            }
        })
    }

    /// Advance every slot one position; returns the argmax token for
    /// slots with `sample = true`. One-shot single-threaded convenience
    /// wrapper over [`BatchEngine::run`] — serving drives `run` directly
    /// so the workers persist across steps.
    pub fn step(&mut self, slots: &[StepSlot]) -> Vec<Option<usize>> {
        self.step_logits(slots, false).0
    }

    /// As [`BatchEngine::step`]; with `keep_logits` the `[B * vocab]`
    /// logits buffer of the iteration is returned too.
    pub fn step_logits(
        &mut self,
        slots: &[StepSlot],
        keep_logits: bool,
    ) -> (Vec<Option<usize>>, Vec<f32>) {
        let cap = slots.len().max(1);
        self.run(1, cap, |stepper| stepper.step_logits(slots, keep_logits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Qwen3Engine;
    use crate::model::{Qwen3Config, Qwen3Weights};

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
    }

    #[test]
    fn paged_batch_of_one_matches_dense_engine() {
        let cfg = Qwen3Config::tiny();
        let w_dense = Qwen3Weights::random(&cfg, 99);
        let w_paged = Qwen3Weights::random(&cfg, 99);
        let mut dense = Qwen3Engine::new(w_dense, 1, 32);
        let mut be = BatchEngine::new(&w_paged, 8, 4);
        // Non-contiguous table: 3 blocks out of order.
        let table: Vec<u32> = vec![3, 0, 6];
        let tokens = [7usize, 300, 5, 42, 9, 1000];
        for (pos, &tok) in tokens.iter().enumerate() {
            let dense_logits = dense.decode_step(tok, pos);
            let slot = StepSlot::hot(tok, pos, &table, true);
            let (samples, paged_logits) = be.step_logits(&[slot], true);
            let diff = max_abs_diff(&dense_logits, &paged_logits);
            assert!(diff < 1e-6, "pos {pos}: paged vs dense logits differ by {diff}");
            assert_eq!(
                samples[0].unwrap(),
                crate::coordinator::argmax(&dense_logits),
                "pos {pos}: sampled token diverged"
            );
        }
    }

    #[test]
    fn batched_rows_do_not_interfere() {
        let cfg = Qwen3Config::tiny();
        let w_a = Qwen3Weights::random(&cfg, 5);
        let w_b = Qwen3Weights::random(&cfg, 5);
        let mut solo = BatchEngine::new(&w_a, 16, 4);
        let mut duo = BatchEngine::new(&w_b, 16, 4);
        let t1: Vec<u32> = vec![0, 1];
        let t2: Vec<u32> = vec![2, 3];
        let seq1 = [11usize, 22, 33];
        let seq2 = [500usize, 600, 700];
        // Solo: run seq1 alone.
        let mut solo_logits = Vec::new();
        for (pos, &tok) in seq1.iter().enumerate() {
            let (_, l) = solo.step_logits(&[StepSlot::hot(tok, pos, &t1, true)], true);
            solo_logits = l;
        }
        // Duo: run seq1 batched with an unrelated seq2.
        let mut duo_logits = Vec::new();
        for pos in 0..seq1.len() {
            let slots = [
                StepSlot::hot(seq1[pos], pos, &t1, true),
                StepSlot::hot(seq2[pos], pos, &t2, true),
            ];
            let (_, l) = duo.step_logits(&slots, true);
            duo_logits = l;
        }
        let vocab = cfg.vocab;
        let diff = max_abs_diff(&solo_logits[..vocab], &duo_logits[..vocab]);
        assert!(diff < 1e-6, "batch companion changed a row's logits by {diff}");
    }

    #[test]
    fn threaded_run_is_bit_identical_to_single_thread() {
        // The tentpole contract: the persistent-worker SPMD step must
        // reproduce the single-threaded batched step bit for bit at any
        // worker count, because the static partition never changes an
        // element's accumulation order.
        let cfg = Qwen3Config::tiny();
        let w1 = Qwen3Weights::random(&cfg, 321);
        let w2 = Qwen3Weights::random(&cfg, 321);
        let nseq = 6usize;
        let steps = 5usize;
        let tables: Vec<Vec<u32>> =
            (0..nseq).map(|i| vec![2 * i as u32, 2 * i as u32 + 1]).collect();
        let run_with = |w: &Qwen3Weights, threads: usize| -> Vec<Vec<f32>> {
            let mut be = BatchEngine::new(w, 16, 4);
            be.run(threads, nseq, |stepper| {
                (0..steps)
                    .map(|pos| {
                        let slots: Vec<StepSlot> = (0..nseq)
                            .map(|i| {
                                StepSlot::hot((i * 31 + pos * 7) % cfg.vocab, pos, &tables[i], true)
                            })
                            .collect();
                        stepper.step_logits(&slots, true).1
                    })
                    .collect()
            })
        };
        let want = run_with(&w1, 1);
        for t in [2usize, 4, 6] {
            let got = run_with(&w2, t);
            assert_eq!(want, got, "thread count {t} changed batched logits");
        }
    }

    #[test]
    fn persistent_workers_survive_varying_batches() {
        // One run, four steps with batch sizes 1 -> 2 -> 2 -> 1, driven
        // with an oversubscribed thread request (clamped to max_batch).
        let cfg = Qwen3Config::tiny();
        let w_ref = Qwen3Weights::random(&cfg, 9);
        let w_thr = Qwen3Weights::random(&cfg, 9);
        let t1: Vec<u32> = vec![0, 1];
        let t2: Vec<u32> = vec![2, 3];
        let script: Vec<Vec<(usize, usize, &[u32])>> = vec![
            vec![(11, 0, &t1)],
            vec![(22, 1, &t1), (500, 0, &t2)],
            vec![(33, 2, &t1), (600, 1, &t2)],
            vec![(700, 2, &t2)],
        ];
        let mut reference = BatchEngine::new(&w_ref, 8, 4);
        let mut want = Vec::new();
        for step in &script {
            let slots: Vec<StepSlot> = step
                .iter()
                .map(|&(token, pos, table)| StepSlot::hot(token, pos, table, true))
                .collect();
            want.push(reference.step_logits(&slots, true).1);
        }
        let mut threaded = BatchEngine::new(&w_thr, 8, 4);
        let got = threaded.run(64, 2, |stepper| {
            assert_eq!(stepper.threads(), 2, "threads must clamp at max_batch");
            script
                .iter()
                .map(|step| {
                    let slots: Vec<StepSlot> = step
                        .iter()
                        .map(|&(token, pos, table)| StepSlot::hot(token, pos, table, true))
                        .collect();
                    stepper.step_logits(&slots, true).1
                })
                .collect::<Vec<_>>()
        });
        assert_eq!(want, got, "persistent-worker run diverged from one-shot steps");
    }

    #[test]
    fn driver_panic_releases_parked_workers() {
        // A panic inside the driver must propagate out of run() — the
        // parked persistent workers are poisoned awake and the scope
        // join completes instead of deadlocking.
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 3);
        let mut be = BatchEngine::new(&w, 4, 4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            be.run(2, 2, |_stepper| panic!("driver exploded mid-run"));
        }));
        assert!(result.is_err(), "panic must propagate, not hang the scope join");
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 1);
        let mut be = BatchEngine::new(&w, 2, 4);
        assert!(be.step(&[]).is_empty());
        be.run(2, 4, |stepper| {
            assert!(stepper.step(&[]).is_empty());
        });
    }

    #[test]
    fn quantized_weights_match_fake_quant_oracle_bitwise() {
        // The weight-quant contract: a batched engine over group-wise
        // quantized weights (fused dequant-GEMM kernels) must produce
        // exactly the logits of a plain f32 batched engine running over
        // the *fake-quantized* weights (quantize→dequantize round trip)
        // — the quantized path changes the bytes streamed, never the
        // values FMAd or their accumulation order — at any worker count.
        use crate::ntt::WeightQuant;
        for mode in [WeightQuant::Int8, WeightQuant::Int4] {
            let cfg_q = Qwen3Config::tiny().with_weight_quant(mode);
            let w_q = Qwen3Weights::random(&cfg_q, 77);
            // Same seed, f32 config, matrices round-tripped by hand.
            let w_f = Qwen3Weights::random(&Qwen3Config::tiny(), 77).fake_quantized(mode);
            let tables: Vec<Vec<u32>> = vec![vec![0, 1], vec![2, 3]];
            let script: Vec<Vec<usize>> = vec![vec![7, 500], vec![42, 600], vec![9, 700]];
            let run = |w: &Qwen3Weights, threads: usize| -> Vec<Vec<f32>> {
                let mut be = BatchEngine::new(w, 8, 4);
                be.run(threads, 2, |stepper| {
                    script
                        .iter()
                        .enumerate()
                        .map(|(pos, toks)| {
                            let slots: Vec<StepSlot> = toks
                                .iter()
                                .enumerate()
                                .map(|(i, &t)| StepSlot::hot(t, pos, &tables[i], true))
                                .collect();
                            stepper.step_logits(&slots, true).1
                        })
                        .collect()
                })
            };
            let want = run(&w_f, 1);
            for threads in [1usize, 2] {
                let got = run(&w_q, threads);
                assert_eq!(want, got, "{mode:?} fused path diverged at {threads} threads");
            }
        }
    }

    #[test]
    fn quantized_engine_streams_fewer_weight_bytes() {
        use crate::ntt::WeightQuant;
        let cfg = Qwen3Config::tiny();
        let w_f = Qwen3Weights::random(&cfg, 5);
        let w_8 = Qwen3Weights::random(&cfg.clone().with_weight_quant(WeightQuant::Int8), 5);
        let w_4 = Qwen3Weights::random(&cfg.clone().with_weight_quant(WeightQuant::Int4), 5);
        let f = BatchEngine::new(&w_f, 2, 4).weight_bytes();
        let q8 = BatchEngine::new(&w_8, 2, 4).weight_bytes();
        let q4 = BatchEngine::new(&w_4, 2, 4).weight_bytes();
        assert!(q8 * 3 < f, "int8 plane must be well under a third of f32: {q8}/{f}");
        assert!(q4 < q8, "int4 plane must be under int8: {q4}/{q8}");
    }

    #[test]
    fn f32_tier_swap_roundtrip_is_bit_identical() {
        // Decode a sequence, spill its blocks to an f32 cold tier,
        // clobber + refetch through stepper.tier_ops, and keep decoding:
        // logits must match an uninterrupted run bit for bit.
        let cfg = Qwen3Config::tiny();
        let w_ref = Qwen3Weights::random(&cfg, 27);
        let w_tier = Qwen3Weights::random(&cfg, 27);
        let table: Vec<u32> = vec![1, 3];
        let tokens = [9usize, 42, 300, 7, 15, 88];
        let mut reference = BatchEngine::new(&w_ref, 8, 4);
        let mut want = Vec::new();
        for (pos, &tok) in tokens.iter().enumerate() {
            want.push(reference.step_logits(&[StepSlot::hot(tok, pos, &table, true)], true).1);
        }
        let mut be = BatchEngine::new(&w_tier, 8, 4);
        be.enable_tier(4, KvQuant::F32);
        let got = be.run(1, 1, |stepper| {
            let mut out = Vec::new();
            for (pos, &tok) in tokens.iter().enumerate() {
                if pos == 5 {
                    // Swap out both blocks (block 1 holds 4 rows, block
                    // 3 holds one), then swap them back into *different*
                    // hot blocks — the paged indirection must not care.
                    stepper.tier_ops(&[
                        TierOp::Spill { hot: 1, cold: 0, filled: 4 },
                        TierOp::Spill { hot: 3, cold: 2, filled: 1 },
                    ]);
                    stepper.tier_ops(&[
                        TierOp::Fetch { cold: 0, hot: 6, seq: 0 },
                        TierOp::Fetch { cold: 2, hot: 0, seq: 0 },
                    ]);
                    let new_table: Vec<u32> = vec![6, 0];
                    let slot = StepSlot::hot(tok, pos, &new_table, true);
                    out.push(stepper.step_logits(&[slot], true).1);
                } else {
                    let slot = StepSlot::hot(tok, pos, &table, true);
                    out.push(stepper.step_logits(&[slot], true).1);
                }
            }
            out
        });
        assert_eq!(want, got, "f32 swap round trip changed logits");
    }

    #[test]
    fn direct_cold_read_matches_fetched_dequant() {
        // The hybrid attention path (leading blocks read in place from
        // the int8 tier) must produce exactly what a full fetch +
        // dequantize into hot blocks produces: same quantized values,
        // two different read paths.
        let cfg = Qwen3Config::tiny();
        let w_a = Qwen3Weights::random(&cfg, 63);
        let w_b = Qwen3Weights::random(&cfg, 63);
        let bs = 4usize;
        let prefix = [3usize, 19, 250, 40]; // one full block
        let tail = [77usize, 501];

        // Run A: fill block 0, spill+fetch it (quantize round trip into
        // hot), continue on the hot path.
        let mut fetched = BatchEngine::new(&w_a, 8, bs);
        fetched.enable_tier(2, KvQuant::Int8);
        let want = fetched.run(1, 1, |stepper| {
            let table: Vec<u32> = vec![0, 1];
            for (pos, &tok) in prefix.iter().enumerate() {
                stepper.step(&[StepSlot::hot(tok, pos, &table, false)]);
            }
            stepper.tier_ops(&[TierOp::Spill { hot: 0, cold: 1, filled: bs }]);
            stepper.tier_ops(&[TierOp::Fetch { cold: 1, hot: 0, seq: 0 }]);
            let mut out = Vec::new();
            for (i, &tok) in tail.iter().enumerate() {
                let pos = prefix.len() + i;
                out.push(stepper.step_logits(&[StepSlot::hot(tok, pos, &table, true)], true).1);
            }
            out
        });

        // Run B: same prefix, spill block 0 and keep it cold — the tail
        // steps read it through the dequant-gather kernels.
        let mut direct = BatchEngine::new(&w_b, 8, bs);
        direct.enable_tier(2, KvQuant::Int8);
        let got = direct.run(1, 1, |stepper| {
            let table: Vec<u32> = vec![0, 1];
            for (pos, &tok) in prefix.iter().enumerate() {
                stepper.step(&[StepSlot::hot(tok, pos, &table, false)]);
            }
            stepper.tier_ops(&[TierOp::Spill { hot: 0, cold: 1, filled: bs }]);
            let cold: Vec<u32> = vec![1];
            let hot_tail: Vec<u32> = vec![1];
            let mut out = Vec::new();
            for (i, &tok) in tail.iter().enumerate() {
                let pos = prefix.len() + i;
                let slot =
                    StepSlot { token: tok, pos, table: &hot_tail, cold: &cold, sample: true };
                out.push(stepper.step_logits(&[slot], true).1);
            }
            out
        });
        assert_eq!(want, got, "direct cold reads diverged from fetch+dequantize");
    }
}
