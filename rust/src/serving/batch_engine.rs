//! Batched decode + chunked prefill over paged KV storage, executed
//! SPMD by persistent worker threads.
//!
//! One [`BatchStepper::step`] advances every scheduled sequence by a
//! **token span** — decode sequences contribute one row, prefilling
//! sequences contribute up to `prefill_chunk` prompt rows — so prompt
//! ingestion runs as genuinely tall GEMMs (`M` = total step tokens)
//! instead of thousands of batch-of-one GEMV-shaped steps. Decode stays
//! memory-bound on the weight stream (paid once per iteration instead
//! of once per sequence per token); chunked prefill pushes the prompt
//! side toward the *compute* roof (`cost::prefill_flops_s`), which is
//! the prefill/decode asymmetry the step-span API exists to exploit.
//!
//! **Ragged row map.** A step's work is the concatenation of every
//! slot's span: row `r` maps to `(slot, offset)`; its token is
//! `slot.tokens[offset]` at logical position `slot.pos + offset`. The
//! controller publishes the map with the slot list, and all SPMD phases
//! shard by **token row** (per-row work: RMSNorm, RoPE, attention,
//! residuals) or by **MR-row panel over all rows** (the GEMMs via
//! [`WeightMat::matmul_rows`] with `M` = total rows). Every row's
//! arithmetic is independent of its step companions (GEMM rows
//! accumulate over their own A row only), so a span is **bitwise
//! identical** to feeding the same tokens one step at a time — chunked
//! prefill at any chunk size and any thread count reproduces the
//! `chunk = 1` seed behaviour token for token.
//!
//! **In-chunk causality.** The KV commit (phase 4) writes the whole
//! span to the paged store — single-writer, ascending position order,
//! behind [`KvCell`] — *before* attention runs, so a chunk row's
//! attention window `[0, pos]` is fully committed: earlier chunk rows
//! of the same sequence are read back through the block table exactly
//! like previously-committed positions. Causality is structural: the
//! fused row kernel ([`attn_row_causal_paged`]) walks exactly
//! `pos + 1` positions, so later rows of the chunk (already in the
//! store) are never gathered. The cold/int8 hybrid path composes the
//! same way — cold prefix blocks sit strictly below any chunk, so only
//! the hot-suffix window length changes.
//!
//! **Threading.** [`BatchEngine::run`] opens one `thread::scope` per
//! serve run — not per step — and parks `threads - 1` persistent
//! workers on the shared [`SpinBarrier`]. Each step, the controller
//! publishes the slot list + row map, releases the workers through the
//! barrier, and joins them as worker 0. The static partition
//! ([`crate::parallel::splits`] / [`panel_splits`]) depends only on
//! `(rows, threads)` and every output element keeps the
//! single-threaded accumulation order, so outputs are token-identical
//! to the dense FCFS oracle at **any** thread count
//! (`rust/tests/serving.rs` pins the full chunk × thread matrix).
//!
//! **Sharding.** With a [`ShardSpec`] installed
//! ([`BatchEngine::set_sharding`]), the run spawns `shards × threads`
//! workers organized as `shards` cooperating groups of `threads` lanes
//! — per-NUMA-node weight shards or replicas on real machines. Each
//! projection GEMM executes under the layout the dist cost model chose
//! for its matrix ([`crate::dist::ShardSpec::derive`]): `Replicated`
//! (`B`) partitions token rows across *all* workers exactly like the
//! unsharded engine; `ColumnShard` (Megatron column-parallel `S(1)`)
//! gives each group a contiguous range of NR-column panels with rows
//! split across the group's lanes. Either way every output element's
//! full-K accumulation runs whole on one statically-known worker, and
//! the cross-shard "combine" is a disjoint fixed-position writeback
//! into the shared activation buffer — never a floating-point
//! reduction — so sharded outputs are **bitwise identical** to the
//! unsharded engine (hence to the FCFS oracle) at any
//! `(threads × shards)`. A `shards = 1` spec reduces to the seed
//! engine exactly: same worker count, same partitions, same barriers
//! per GEMM phase.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::fault::FaultPlan;
use super::tiered::{ColdKv, KvQuant, TierOp};
use crate::coordinator::argmax;
use crate::dist::{MatShard, ShardSpec};
use crate::model::{Qwen3Config, Qwen3Weights};
use crate::obs::{self, Code, Ring, TraceLog, WorkerTrace};
use crate::ntt::{
    add_inplace, attn_context_paged_accum, attn_context_quant_i8, attn_row_causal_paged,
    attn_scores_paged, attn_scores_quant_i8, mul_inplace, paged_row, rmsnorm, rope_inplace,
    silu_inplace, softmax_inplace, Tensor, WeightMat, MR, NR,
};
use crate::parallel::{
    panel_splits, splits, KvCell, PoisonGuard, SharedCell, SharedVec, SpinBarrier,
};

/// Paged KV arena: per layer, `num_blocks * block_size` rows of width
/// `kv_heads * head_dim`. Physical block `b` owns the same row range in
/// every layer.
pub struct PagedKv {
    pub block_size: usize,
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
}

impl PagedKv {
    pub fn new(layers: usize, num_blocks: usize, block_size: usize, width: usize) -> Self {
        let rows = num_blocks * block_size;
        PagedKv {
            block_size,
            k: (0..layers).map(|_| Tensor::zeros(&[rows, width])).collect(),
            v: (0..layers).map(|_| Tensor::zeros(&[rows, width])).collect(),
        }
    }

    /// Bytes of the whole arena (both K and V, all layers).
    pub fn arena_bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|t| t.numel() * 4).sum()
    }
}

/// One layer's packed weight plane. Each matrix is a [`WeightMat`]:
/// f32 NR panels or group-quantized codes per `Qwen3Config::weight_quant`
/// — the GEMM phases shard and accumulate identically in either mode,
/// so quantization never touches the SPMD partition, the bitwise
/// thread-count determinism, or the `KvCell` commit protocol.
struct PackedLayer {
    wq: WeightMat,
    wk: WeightMat,
    wv: WeightMat,
    wo: WeightMat,
    w_gate: WeightMat,
    w_up: WeightMat,
    w_down: WeightMat,
}

/// One sequence's slot in a batched iteration: a **token span**, not a
/// single token. `tokens[i]` is fed at logical position `pos + i`.
pub struct StepSlot<'t> {
    /// The span to feed this step (non-empty; ragged across the batch).
    /// Decode slots carry one token, chunked prefill up to the
    /// scheduler's `prefill_chunk`.
    pub tokens: &'t [usize],
    /// Logical position of `tokens[0]` in the sequence.
    pub pos: usize,
    /// The sequence's *hot* block table, covering logical blocks after
    /// the cold prefix; together with `cold` it must cover the span's
    /// final position `pos + tokens.len() - 1`.
    pub table: &'t [u32],
    /// Cold-tier slots of the sequence's leading logical blocks (direct
    /// dequant-gather reads). Empty on the untiered path — attention
    /// then takes the exact pre-tiering code path.
    pub cold: &'t [u32],
    /// Sample an output token from the span's **final** row's logits
    /// (the span reaches the sequence frontier: last prompt token or a
    /// decode step).
    pub sample: bool,
}

impl<'t> StepSlot<'t> {
    /// A slot with no cold prefix (the flat-pool path).
    pub fn hot(tokens: &'t [usize], pos: usize, table: &'t [u32], sample: bool) -> Self {
        StepSlot { tokens, pos, table, cold: &[], sample }
    }
}

/// Owned copy of a [`StepSlot`] (spans and block tables cloned),
/// published to the persistent workers so they never borrow the
/// scheduler's state. `sample` stays controller-side: workers compute
/// every row's logits, and the controller argmaxes the sampling rows.
struct OwnedSlot {
    tokens: Vec<usize>,
    pos: usize,
    table: Vec<u32>,
    cold: Vec<u32>,
}

/// Shared per-run state of one SPMD serve run: the published work
/// descriptor (slot list + ragged row map) plus the activation buffers,
/// all sized at `max_rows` token-row capacity and written by disjoint
/// row ranges between barriers.
struct StepState {
    slots: SharedCell<Vec<OwnedSlot>>,
    /// Row `r` of the step -> `(slot index, offset into its span)`.
    rows: SharedCell<Vec<(u32, u32)>>,
    x: SharedVec,
    xn: SharedVec,
    q: SharedVec,
    kvec: SharedVec,
    vvec: SharedVec,
    ctx: SharedVec,
    attn: SharedVec,
    gate: SharedVec,
    up: SharedVec,
    down: SharedVec,
    logits: SharedVec,
}

impl StepState {
    fn new(cfg: &Qwen3Config, max_rows: usize) -> Self {
        let (h, hd) = (cfg.hidden, cfg.head_dim);
        let (qdim, kvdim) = (cfg.heads * hd, cfg.kv_heads * hd);
        StepState {
            slots: SharedCell::new(Vec::new()),
            rows: SharedCell::new(Vec::new()),
            x: SharedVec::new(max_rows * h),
            xn: SharedVec::new(max_rows * h),
            q: SharedVec::new(max_rows * qdim),
            kvec: SharedVec::new(max_rows * kvdim),
            vvec: SharedVec::new(max_rows * kvdim),
            ctx: SharedVec::new(max_rows * qdim),
            attn: SharedVec::new(max_rows * h),
            gate: SharedVec::new(max_rows * cfg.intermediate),
            up: SharedVec::new(max_rows * cfg.intermediate),
            down: SharedVec::new(max_rows * h),
            logits: SharedVec::new(max_rows * cfg.vocab),
        }
    }
}

const CMD_STEP: usize = 0;
const CMD_EXIT: usize = 1;

/// One worker's coordinates in the run's `shards × lanes` topology,
/// plus the GEMM dispatch that executes a projection under the layout
/// the dist cost model chose for its matrix. All fields derive
/// statically from `(wi, lanes, shards)`, fixed for the whole run, so
/// every partition below is deterministic.
struct ShardCtx {
    /// Total workers in the run (`lanes * shards`).
    t: usize,
    /// Lanes per shard group (the run's `threads` after the clamp).
    lanes: usize,
    /// Shard group count.
    shards: usize,
    /// This worker's global index (`group * lanes + lane`).
    wi: usize,
    /// `wi / lanes`: which shard group this worker belongs to.
    group: usize,
    /// `wi % lanes`: which lane within the group.
    lane: usize,
    /// GEMM row-panel granularity (multiple of [`MR`]).
    panel: usize,
}

impl ShardCtx {
    /// Execute this worker's share of one `[n, width]` projection GEMM
    /// under `layout`, writing a disjoint region of `out`.
    ///
    /// `Replicated` (`B`): the matrix is whole in every group — token
    /// rows shard as MR panels across **all** `t` workers at full
    /// output width, exactly the unsharded engine's partition.
    ///
    /// `ColumnShard` (`S(1)`): this worker's group owns a contiguous
    /// range of NR-column panels, and token rows shard across the
    /// group's `lanes`. The kernel produces a compact `[rows, ncols]`
    /// block in `colbuf`, which is then copied row-by-row into its
    /// fixed position in the shared full-width buffer. That placement
    /// **is** the deterministic cross-shard combine: every output
    /// element was accumulated whole (full K, ascending) by exactly
    /// one statically-known worker, and assembling the row is
    /// disjoint writes, never a floating-point reduction — so the
    /// result is bitwise independent of `(lanes, shards)`.
    ///
    /// # Safety
    /// Caller must be inside a barrier-separated phase in which no
    /// other worker touches this worker's `out` region (the
    /// [`SharedVec`] contract); the partitions above guarantee
    /// disjointness across workers.
    #[allow(clippy::too_many_arguments)]
    unsafe fn gemm(
        &self,
        wmat: &WeightMat,
        layout: MatShard,
        src: &[f32],
        n: usize,
        out: &SharedVec,
        width: usize,
        scratch: &mut Vec<f32>,
        colbuf: &mut Vec<f32>,
    ) {
        debug_assert_eq!(width, wmat.n(), "output width must match the matrix");
        match layout {
            MatShard::Replicated => {
                let (p0, p1) = panel_splits(n, self.panel, self.t)[self.wi];
                let os = unsafe { out.slice_mut(p0 * width, p1 * width) };
                wmat.matmul_rows(src, n, p0, p1, os, scratch);
            }
            MatShard::ColumnShard => {
                let (p0, p1) = panel_splits(n, self.panel, self.lanes)[self.lane];
                let (cp0, cp1) = splits(wmat.col_panels(), self.shards)[self.group];
                let col0 = cp0 * NR;
                let ncols = (cp1 * NR).min(width).saturating_sub(col0);
                let rows = p1 - p0;
                if rows == 0 || ncols == 0 {
                    return;
                }
                colbuf.resize(rows * ncols, 0.0);
                wmat.matmul_rows_cols(src, n, p0, p1, cp0, cp1, colbuf, scratch);
                for (i, r) in (p0..p1).enumerate() {
                    unsafe { out.slice_mut(r * width + col0, r * width + col0 + ncols) }
                        .copy_from_slice(&colbuf[i * ncols..(i + 1) * ncols]);
                }
            }
        }
    }
}

/// Barrier wait with optional tracing: records a [`Code::Barrier`]
/// span covering the wait, with `arg` naming the phase the barrier
/// closes — per-phase barrier time is the load-imbalance signal the
/// trace summary reports. The untraced arm is exactly
/// `barrier.wait()` behind one untaken failpoint branch.
#[inline]
fn traced_wait(
    barrier: &SpinBarrier,
    tr: &mut Option<&mut Ring>,
    phase: Code,
    fp: Option<&FaultPlan>,
    wi: usize,
) {
    // Failpoint: an injected worker panic fires here, *before* the
    // wait — a panicking worker's PoisonGuard (or, for the controller,
    // the driver catch_unwind in `run_traced`) poisons the barrier, so
    // every other participant unwinds instead of spinning forever.
    if let Some(fp) = fp {
        fp.maybe_panic(phase, wi);
    }
    match tr {
        None => barrier.wait(),
        Some(r) => {
            let t0 = r.now_ns();
            barrier.wait();
            r.close(Code::Barrier, t0, phase as u32);
        }
    }
}

/// One barrier-separated SPMD step, executed by all `t` participants
/// (the controller as worker 0, plus the parked workers released into
/// it). Per-row phases shard token rows with `splits`; GEMM phases
/// shard `panel`-row panels with `panel_splits` (`panel` is a multiple
/// of the μkernel height [`MR`], default `MR`, chosen by the serve
/// plan — any multiple keeps shard boundaries on the MR grid, so the
/// packed-tile arithmetic is unchanged). Both partitions depend only
/// on `(rows, panel, t)`, fixed for the whole run, and every element
/// keeps the single-threaded accumulation order, so results are
/// identical at any thread count and any panel granularity — and every
/// row's arithmetic is independent of its step companions, so results
/// are also identical at any span packing (chunked == chunk-1).
#[allow(clippy::too_many_arguments)]
fn spmd_step(
    wi: usize,
    t: usize,
    lanes: usize,
    panel: usize,
    sharding: &ShardSpec,
    weights: &Qwen3Weights,
    packed: &[PackedLayer],
    packed_lm_head: &WeightMat,
    kv_cell: &KvCell<'_, PagedKv>,
    cold_cell: Option<&KvCell<'_, ColdKv>>,
    st: &StepState,
    barrier: &SpinBarrier,
    scratch: &mut Vec<f32>,
    colbuf: &mut Vec<f32>,
    tr: &mut Option<&mut Ring>,
    fp: Option<&FaultPlan>,
) {
    // SAFETY: the controller wrote this step's slots + row map before
    // releasing the workers through the barrier, and rewrites them only
    // after the final barrier below has parked everyone again.
    let slots: &[OwnedSlot] = unsafe { st.slots.read() };
    let rows: &[(u32, u32)] = unsafe { st.rows.read() };
    let n = rows.len();
    let cfg = &weights.cfg;
    let h = cfg.hidden;
    let hd = cfg.head_dim;
    let heads = cfg.heads;
    let kvh = cfg.kv_heads;
    let qdim = heads * hd;
    let kvdim = kvh * hd;
    let inter = cfg.intermediate;
    let vocab = cfg.vocab;
    let group = heads / kvh;
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    let bs = kv_cell.read().block_size;
    // This worker's static shards (token rows / panel-rows of rows)
    // and its coordinates in the `shards × lanes` GEMM topology.
    let (r0, r1) = splits(n, t)[wi];
    let (p0, p1) = panel_splits(n, panel, t)[wi];
    let shard = ShardCtx {
        t,
        lanes,
        shards: sharding.shards,
        wi,
        group: wi / lanes,
        lane: wi % lanes,
        panel,
    };
    // With both SwiGLU matrices replicated (always true unsharded),
    // each worker can run the elementwise tail fused on the rows it
    // just produced; column-sharded gate/up need the assembled
    // full-width rows published first. Elementwise either way, so the
    // choice is bitwise-neutral.
    let fused_mlp =
        sharding.w_gate == MatShard::Replicated && sharding.w_up == MatShard::Replicated;

    // Phase 0: embedding gather, per-row shard.
    let t_ph = obs::mark(tr);
    for r in r0..r1 {
        let (si, off) = rows[r];
        let token = slots[si as usize].tokens[off as usize];
        unsafe { st.x.slice_mut(r * h, (r + 1) * h) }
            .copy_from_slice(weights.embedding.row(token % vocab));
    }
    obs::span(tr, Code::Embed, t_ph, 0);
    traced_wait(barrier, tr, Code::Embed, fp, wi);

    for l in 0..cfg.layers {
        let w = &weights.layers[l];
        let pw = &packed[l];
        // Phase 1: attention RMSNorm, per-row shard.
        let t_ph = obs::mark(tr);
        for r in r0..r1 {
            unsafe {
                rmsnorm(
                    &st.x.read()[r * h..(r + 1) * h],
                    &w.attn_norm.data,
                    cfg.rms_eps,
                    st.xn.slice_mut(r * h, (r + 1) * h),
                );
            }
        }
        obs::span(tr, Code::Norm, t_ph, 0);
        traced_wait(barrier, tr, Code::Norm, fp, wi);
        // Phase 2: batched QKV projections under each matrix's
        // dist-chosen layout — with chunked prefill these are genuinely
        // tall GEMMs (M = total step tokens), each worker streaming its
        // weight share once for its row panels.
        let t_ph = obs::mark(tr);
        unsafe {
            let xn = &st.xn.read()[..n * h];
            shard.gemm(&pw.wq, sharding.wq, xn, n, &st.q, qdim, scratch, colbuf);
            shard.gemm(&pw.wk, sharding.wk, xn, n, &st.kvec, kvdim, scratch, colbuf);
            shard.gemm(&pw.wv, sharding.wv, xn, n, &st.vvec, kvdim, scratch, colbuf);
        }
        obs::span(tr, Code::QkvGemm, t_ph, 0);
        traced_wait(barrier, tr, Code::QkvGemm, fp, wi);
        // Phase 3: RoPE, per-row shard (positions differ per row).
        let t_ph = obs::mark(tr);
        for r in r0..r1 {
            let (si, off) = rows[r];
            let pos = slots[si as usize].pos + off as usize;
            for head in 0..heads {
                let o = r * qdim + head * hd;
                unsafe { rope_inplace(st.q.slice_mut(o, o + hd), pos, cfg.rope_theta) };
            }
            for head in 0..kvh {
                let o = r * kvdim + head * hd;
                unsafe { rope_inplace(st.kvec.slice_mut(o, o + hd), pos, cfg.rope_theta) };
            }
        }
        obs::span(tr, Code::Rope, t_ph, 0);
        traced_wait(barrier, tr, Code::Rope, fp, wi);
        // Phase 4 (serial): commit every row's K/V through its slot's
        // block table, in ascending row order — which is ascending
        // position order within each slot (the row map is span-major).
        // Distinct rows never alias (each (sequence, position) pair is
        // unique and span/tail blocks are privately held), but the
        // commit stays a single-writer KvCell window so the invariant
        // is enforced, not assumed. Committing the WHOLE span before
        // attention is what makes in-chunk causal attention a plain
        // windowed read.
        if wi == 0 {
            let t_ph = obs::mark(tr);
            kv_cell.commit(wi, |kv| {
                let kvec = st.kvec.read();
                let vvec = st.vvec.read();
                for (r, &(si, off)) in rows.iter().enumerate() {
                    let s = &slots[si as usize];
                    // The hot table starts after the cold prefix; span
                    // rows always live in hot blocks.
                    let row =
                        paged_row(&s.table, bs, s.pos + off as usize - s.cold.len() * bs);
                    kv.k[l].row_mut(row).copy_from_slice(&kvec[r * kvdim..(r + 1) * kvdim]);
                    kv.v[l].row_mut(row).copy_from_slice(&vvec[r * kvdim..(r + 1) * kvdim]);
                }
            });
            obs::span(tr, Code::KvCommit, t_ph, 0);
        }
        traced_wait(barrier, tr, Code::KvCommit, fp, wi);
        // Phase 5: paged GQA attention, per-row shard, causal window
        // `[0, pos]` per row. Rows with a cold prefix take the hybrid
        // path: the leading full blocks are read *in place* from the
        // quantized cold tier (dequant-gather kernels), the hot suffix
        // through the block table — positions stay in ascending order,
        // so softmax and the context accumulation see the same sequence
        // order as the dense path. Rows without one take the fused
        // causal row kernel (the exact pre-tiering arithmetic).
        let kv = kv_cell.read();
        let t_ph = obs::mark(tr);
        for r in r0..r1 {
            let (si, off) = rows[r];
            let s = &slots[si as usize];
            let pos = s.pos + off as usize;
            let seq = pos + 1;
            let cold_toks = s.cold.len() * bs;
            let cstore = (cold_toks > 0).then(|| {
                cold_cell
                    .expect("slot has a cold prefix but the engine has no cold tier")
                    .read()
            });
            let q = st.q.read();
            let ctx_row = unsafe { st.ctx.slice_mut(r * qdim, (r + 1) * qdim) };
            let mut scores = vec![0.0f32; seq];
            for head in 0..heads {
                let kvhead = head / group;
                let qo = r * qdim + head * hd;
                if cold_toks == 0 {
                    attn_row_causal_paged(
                        &q[qo..qo + hd],
                        &kv.k[l],
                        &kv.v[l],
                        &s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        inv_sqrt,
                        &mut scores,
                        &mut ctx_row[head * hd..(head + 1) * hd],
                    );
                } else {
                    let cold = cstore.expect("Some whenever cold_toks > 0");
                    for (bi, &slot) in s.cold.iter().enumerate() {
                        let (kq, sc, zp) = cold.k_block(slot, l);
                        attn_scores_quant_i8(
                            &q[qo..qo + hd],
                            kq,
                            sc,
                            zp,
                            bs,
                            kvdim,
                            kvhead * hd,
                            hd,
                            inv_sqrt,
                            &mut scores[bi * bs..(bi + 1) * bs],
                        );
                    }
                    attn_scores_paged(
                        &q[qo..qo + hd],
                        &kv.k[l],
                        &s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        inv_sqrt,
                        &mut scores[cold_toks..],
                    );
                    softmax_inplace(&mut scores);
                    let out = &mut ctx_row[head * hd..(head + 1) * hd];
                    out.fill(0.0);
                    for (bi, &slot) in s.cold.iter().enumerate() {
                        let (vq, sc, zp) = cold.v_block(slot, l);
                        attn_context_quant_i8(
                            &scores[bi * bs..(bi + 1) * bs],
                            vq,
                            sc,
                            zp,
                            kvdim,
                            kvhead * hd,
                            hd,
                            out,
                        );
                    }
                    attn_context_paged_accum(
                        &scores[cold_toks..],
                        &kv.v[l],
                        &s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        out,
                    );
                }
            }
        }
        obs::span(tr, Code::Attn, t_ph, 0);
        traced_wait(barrier, tr, Code::Attn, fp, wi);
        // Phase 6: output projection under its dist-chosen layout.
        let t_ph = obs::mark(tr);
        unsafe {
            let ctx = &st.ctx.read()[..n * qdim];
            shard.gemm(&pw.wo, sharding.wo, ctx, n, &st.attn, h, scratch, colbuf);
        }
        obs::span(tr, Code::OGemm, t_ph, 0);
        traced_wait(barrier, tr, Code::OGemm, fp, wi);
        // Phase 7: residual + MLP RMSNorm, per-row shard.
        let t_ph = obs::mark(tr);
        for r in r0..r1 {
            unsafe {
                add_inplace(
                    st.x.slice_mut(r * h, (r + 1) * h),
                    &st.attn.read()[r * h..(r + 1) * h],
                );
                rmsnorm(
                    &st.x.read()[r * h..(r + 1) * h],
                    &w.mlp_norm.data,
                    cfg.rms_eps,
                    st.xn.slice_mut(r * h, (r + 1) * h),
                );
            }
        }
        obs::span(tr, Code::Norm, t_ph, 0);
        traced_wait(barrier, tr, Code::Norm, fp, wi);
        // Phase 8: SwiGLU gate/up under their dist-chosen layouts. With
        // both replicated (the seed path) the elementwise tail runs
        // fused on the rows this worker just computed; column-sharded
        // gate/up publish the assembled full-width rows through an
        // extra barrier first, then the tail shards per token row.
        let t_ph = obs::mark(tr);
        unsafe {
            let xn = &st.xn.read()[..n * h];
            shard.gemm(&pw.w_gate, sharding.w_gate, xn, n, &st.gate, inter, scratch, colbuf);
            shard.gemm(&pw.w_up, sharding.w_up, xn, n, &st.up, inter, scratch, colbuf);
            if fused_mlp {
                let g = st.gate.slice_mut(p0 * inter, p1 * inter);
                silu_inplace(g);
                mul_inplace(g, &st.up.read()[p0 * inter..p1 * inter]);
            }
        }
        obs::span(tr, Code::MlpGemm, t_ph, 0);
        if !fused_mlp {
            traced_wait(barrier, tr, Code::MlpGemm, fp, wi);
            let t_tail = obs::mark(tr);
            for r in r0..r1 {
                unsafe {
                    let g = st.gate.slice_mut(r * inter, (r + 1) * inter);
                    silu_inplace(g);
                    mul_inplace(g, &st.up.read()[r * inter..(r + 1) * inter]);
                }
            }
            obs::span(tr, Code::MlpGemm, t_tail, 0);
        }
        traced_wait(barrier, tr, Code::MlpGemm, fp, wi);
        // Phase 9: down projection under its dist-chosen layout.
        let t_ph = obs::mark(tr);
        unsafe {
            let gate = &st.gate.read()[..n * inter];
            shard.gemm(&pw.w_down, sharding.w_down, gate, n, &st.down, h, scratch, colbuf);
        }
        obs::span(tr, Code::MlpGemm, t_ph, 0);
        traced_wait(barrier, tr, Code::MlpGemm, fp, wi);
        // Phase 10: residual, per-row shard.
        let t_ph = obs::mark(tr);
        for r in r0..r1 {
            unsafe {
                add_inplace(
                    st.x.slice_mut(r * h, (r + 1) * h),
                    &st.down.read()[r * h..(r + 1) * h],
                );
            }
        }
        obs::span(tr, Code::Norm, t_ph, 0);
        traced_wait(barrier, tr, Code::Norm, fp, wi);
    }
    // Final norm (per-row shard) + LM head (MR-panel shard).
    let t_ph = obs::mark(tr);
    for r in r0..r1 {
        unsafe {
            rmsnorm(
                &st.x.read()[r * h..(r + 1) * h],
                &weights.final_norm.data,
                cfg.rms_eps,
                st.xn.slice_mut(r * h, (r + 1) * h),
            );
        }
    }
    obs::span(tr, Code::Norm, t_ph, 0);
    traced_wait(barrier, tr, Code::Norm, fp, wi);
    let t_ph = obs::mark(tr);
    unsafe {
        let xn = &st.xn.read()[..n * h];
        shard.gemm(packed_lm_head, sharding.lm_head, xn, n, &st.logits, vocab, scratch, colbuf);
    }
    obs::span(tr, Code::LmHead, t_ph, 0);
    // Final barrier: publishes every logits shard to the controller and
    // parks the workers for the next step.
    traced_wait(barrier, tr, Code::LmHead, fp, wi);
}

/// The batched paged-attention decode engine.
pub struct BatchEngine<'w> {
    pub weights: &'w Qwen3Weights,
    packed: Vec<PackedLayer>,
    packed_lm_head: WeightMat,
    pub kv: PagedKv,
    /// Cold-tier arena (`Some` after [`BatchEngine::enable_tier`]).
    pub cold: Option<ColdKv>,
    /// GEMM shard granularity in token rows (multiple of [`MR`];
    /// default `MR`). Set from `ServePlan::panel_rows` via
    /// [`BatchEngine::set_panel_rows`] — performance only, outputs are
    /// bitwise identical at any value.
    panel_rows: usize,
    /// The dist-chosen per-matrix shard layout
    /// ([`BatchEngine::set_sharding`]; default [`ShardSpec::single`],
    /// the unsharded seed engine).
    sharding: ShardSpec,
    /// Shared failpoint plan ([`BatchEngine::set_faults`]); `None`
    /// (the default) keeps every injection hook a single untaken
    /// branch, so the no-fault hot path is unchanged.
    faults: Option<Arc<FaultPlan>>,
}

/// Controller handle of a live SPMD serve run (see [`BatchEngine::run`]):
/// issues steps to the parked persistent workers and participates as
/// worker 0.
pub struct BatchStepper<'a, 'kv> {
    weights: &'a Qwen3Weights,
    packed: &'a [PackedLayer],
    packed_lm_head: &'a WeightMat,
    kv_cell: &'a KvCell<'kv, PagedKv>,
    cold_cell: Option<&'a KvCell<'kv, ColdKv>>,
    st: &'a StepState,
    barrier: &'a SpinBarrier,
    threads: usize,
    workers: usize,
    sharding: ShardSpec,
    panel: usize,
    max_rows: usize,
    scratch: Vec<f32>,
    colbuf: Vec<f32>,
    /// The controller's event ring when the run is traced
    /// ([`BatchEngine::run_traced`]); `None` (one branch per hook, no
    /// allocation) otherwise.
    trace: Option<&'a mut Ring>,
    /// The run's failpoint plan (from [`BatchEngine::set_faults`]).
    faults: Option<&'a FaultPlan>,
}

impl BatchStepper<'_, '_> {
    /// Lanes per shard group (the run's `threads` after the
    /// row-capacity clamp). Equals [`BatchStepper::workers`] when the
    /// run is unsharded.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total workers of this run (`threads × shards`).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute the scheduler's tier ops for this iteration: all spills,
    /// then all fetches (a fetch may target a hot block a spill vacated
    /// in the same iteration, so the spill must read first). Runs on the
    /// controller while every worker is parked at the start barrier —
    /// the barrier release publishes the moved rows to the step. The
    /// two directions run in separate commit windows so a traced run
    /// attributes each its own span (`arg` = op count).
    ///
    /// Every fetch re-verifies the slot's FNV payload checksum before
    /// trusting the bytes; a mismatch — or an injected transient fetch
    /// failure — skips the copy and reports the slot in the returned
    /// list, which the driver feeds to the scheduler's swap → recompute
    /// reclassification (`ContinuousScheduler::fault_cold`) instead of
    /// serving corrupt KV. Empty on a healthy run.
    pub fn tier_ops(&mut self, ops: &[TierOp]) -> Vec<u32> {
        if ops.is_empty() {
            return Vec::new();
        }
        let cold_cell = self.cold_cell.expect("tier ops on an engine without a cold tier");
        let fp = self.faults;
        let n_spill = ops.iter().filter(|o| matches!(o, TierOp::Spill { .. })).count() as u32;
        let n_fetch = ops.len() as u32 - n_spill;
        let mut corrupted = 0u32;
        let mut failed: Vec<u32> = Vec::new();
        if n_spill > 0 {
            let t0 = obs::mark(&self.trace);
            cold_cell.commit(0, |cold| {
                self.kv_cell.commit(0, |kv| {
                    for op in ops {
                        if let TierOp::Spill { hot, cold: slot, filled } = *op {
                            cold.spill(slot, kv, hot, filled);
                            // Failpoint: flip payload bytes *after* the
                            // spill recorded its checksum, so the later
                            // verification has real damage to catch.
                            if let Some(p) = fp {
                                if p.take_corrupt() {
                                    cold.corrupt_slot(slot, &mut p.corruption_rng(slot));
                                    corrupted += 1;
                                }
                            }
                        }
                    }
                });
            });
            obs::span(&mut self.trace, Code::TierSpill, t0, n_spill);
            for _ in 0..corrupted {
                obs::instant(&mut self.trace, Code::FaultInject, 2);
            }
        }
        if n_fetch > 0 {
            let t0 = obs::mark(&self.trace);
            cold_cell.commit(0, |cold| {
                self.kv_cell.commit(0, |kv| {
                    for op in ops {
                        if let TierOp::Fetch { cold: slot, hot, .. } = *op {
                            let injected = fp.map_or(false, |p| p.take_fetch_fail());
                            if injected || !cold.verify(slot) {
                                failed.push(slot);
                            } else {
                                cold.fetch(slot, kv, hot);
                            }
                        }
                    }
                });
            });
            obs::span(&mut self.trace, Code::TierFetch, t0, n_fetch);
            for _ in &failed {
                obs::instant(&mut self.trace, Code::FaultInject, 1);
            }
        }
        failed
    }

    /// Re-verify the payload checksums of cold slots the step is about
    /// to read **in place** (the direct-read resume path bypasses
    /// fetches, so it never crosses the fetch-side verification in
    /// [`BatchStepper::tier_ops`]). Returns the slots that failed; the
    /// driver feeds them to the scheduler's swap → recompute
    /// reclassification before the step's slots are built.
    pub fn verify_cold(&mut self, slots: &[u32]) -> Vec<u32> {
        if slots.is_empty() {
            return Vec::new();
        }
        let cold_cell =
            self.cold_cell.expect("cold-slot audit on an engine without a cold tier");
        let cold = cold_cell.read();
        let failed: Vec<u32> = slots.iter().copied().filter(|&s| !cold.verify(s)).collect();
        for _ in &failed {
            obs::instant(&mut self.trace, Code::FaultInject, 2);
        }
        failed
    }

    /// Advance every slot by its span; returns the argmax token of the
    /// span's final row for slots with `sample = true`.
    pub fn step(&mut self, slots: &[StepSlot]) -> Vec<Option<usize>> {
        self.step_logits(slots, false).0
    }

    /// As [`BatchStepper::step`]; with `keep_logits` the
    /// `[total_rows * vocab]` logits buffer of the iteration (one row
    /// per span token, span-major) is returned too (white-box tests).
    pub fn step_logits(
        &mut self,
        slots: &[StepSlot],
        keep_logits: bool,
    ) -> (Vec<Option<usize>>, Vec<f32>) {
        let b = slots.len();
        if b == 0 {
            return (Vec::new(), Vec::new());
        }
        let rows_total: usize = slots.iter().map(|s| s.tokens.len()).sum();
        assert!(
            rows_total <= self.max_rows,
            "step of {rows_total} token rows exceeds run capacity {}",
            self.max_rows
        );
        // Degenerate-span hardening: a zero-token slot has no frontier
        // row to sample and would silently shift every later slot's row
        // base; a span past its block tables would commit KV through
        // unreserved (possibly foreign) blocks.
        debug_assert!(
            slots.iter().all(|s| !s.tokens.is_empty()),
            "zero-token slot span scheduled"
        );
        debug_assert!(
            {
                let bs = self.kv_cell.read().block_size;
                slots
                    .iter()
                    .all(|s| (s.cold.len() + s.table.len()) * bs >= s.pos + s.tokens.len())
            },
            "a slot's block tables do not cover its span"
        );
        // Publish this step's work descriptor. SAFETY: every worker is
        // parked at the start barrier; the release below hands them a
        // happens-before view of these writes.
        unsafe {
            let owned = self.st.slots.get_mut();
            owned.clear();
            owned.extend(slots.iter().map(|s| OwnedSlot {
                tokens: s.tokens.to_vec(),
                pos: s.pos,
                table: s.table.to_vec(),
                cold: s.cold.to_vec(),
            }));
            let rows = self.st.rows.get_mut();
            rows.clear();
            for (si, s) in slots.iter().enumerate() {
                for off in 0..s.tokens.len() {
                    rows.push((si as u32, off as u32));
                }
            }
        }
        // Advance the failpoint iteration counter before the release —
        // workers read it behind the barrier, so `Relaxed` suffices.
        if let Some(fp) = self.faults {
            fp.begin_iter();
        }
        // Release the workers into the step and join as worker 0. The
        // final barrier inside `spmd_step` publishes all logits shards.
        self.barrier.wait();
        spmd_step(
            0,
            self.workers,
            self.threads,
            self.panel,
            &self.sharding,
            self.weights,
            self.packed,
            self.packed_lm_head,
            self.kv_cell,
            self.cold_cell,
            self.st,
            self.barrier,
            &mut self.scratch,
            &mut self.colbuf,
            &mut self.trace,
            self.faults,
        );
        let vocab = self.weights.cfg.vocab;
        let logits = self.st.logits.read();
        let mut row_base = 0usize;
        let samples = slots
            .iter()
            .map(|s| {
                let last = row_base + s.tokens.len() - 1;
                row_base += s.tokens.len();
                s.sample.then(|| argmax(&logits[last * vocab..(last + 1) * vocab]))
            })
            .collect();
        (samples, if keep_logits { logits[..rows_total * vocab].to_vec() } else { Vec::new() })
    }

    /// As [`BatchStepper::step`], but returning the argmax token of
    /// **every** row of every slot's span (span-major): element `i` of
    /// a slot's vector is the model's next-token argmax after consuming
    /// `tokens[..=i]` of the span — the speculative verifier's readout.
    /// One tall GEMM step verifies all the slot's draft tokens at once;
    /// the scheduler's `commit_verified` keeps the longest causally-
    /// matched prefix.
    ///
    /// Like `step`'s frontier sampling, every argmax runs controller-
    /// side after the final barrier over logits each accumulated whole
    /// (full K, ascending) by one statically-known worker — so the
    /// result is bitwise independent of the `(threads × shards)`
    /// topology, and speculative acceptance inherits the engine's
    /// determinism guarantee for free.
    pub fn step_verify(&mut self, slots: &[StepSlot]) -> Vec<Vec<usize>> {
        let _ = self.step_logits(slots, false);
        // The logits buffer persists after the step (the workers are
        // parked behind the final barrier), so the per-row readout is a
        // plain controller-side scan.
        let vocab = self.weights.cfg.vocab;
        let logits = self.st.logits.read();
        let mut row_base = 0usize;
        slots
            .iter()
            .map(|s| {
                let rows = (0..s.tokens.len())
                    .map(|i| {
                        let r = row_base + i;
                        argmax(&logits[r * vocab..(r + 1) * vocab])
                    })
                    .collect();
                row_base += s.tokens.len();
                rows
            })
            .collect()
    }
}

impl<'w> BatchEngine<'w> {
    pub fn new(weights: &'w Qwen3Weights, num_blocks: usize, block_size: usize) -> Self {
        let cfg = &weights.cfg;
        // Pack (or group-quantize) the weight plane once at engine
        // build, per the model's `weight_quant` mode.
        let mode = cfg.weight_quant;
        let packed = weights
            .layers
            .iter()
            .map(|l| PackedLayer {
                wq: WeightMat::prepare(&l.wq, mode),
                wk: WeightMat::prepare(&l.wk, mode),
                wv: WeightMat::prepare(&l.wv, mode),
                wo: WeightMat::prepare(&l.wo, mode),
                w_gate: WeightMat::prepare(&l.w_gate, mode),
                w_up: WeightMat::prepare(&l.w_up, mode),
                w_down: WeightMat::prepare(&l.w_down, mode),
            })
            .collect();
        let kv = PagedKv::new(cfg.layers, num_blocks, block_size, cfg.kv_heads * cfg.head_dim);
        BatchEngine {
            weights,
            packed,
            packed_lm_head: WeightMat::prepare(&weights.lm_head, mode),
            kv,
            cold: None,
            panel_rows: MR,
            sharding: ShardSpec::single(),
            faults: None,
        }
    }

    /// Set the GEMM shard granularity (token rows per panel) the SPMD
    /// phases hand to [`panel_splits`]. Rounded up to the nearest
    /// multiple of [`MR`] so shard boundaries stay on packed μkernel
    /// tiles — which is why any value is bitwise-neutral. Call before
    /// [`BatchEngine::run`]; the serving coordinator does this when the
    /// config carries a `ServePlan`.
    pub fn set_panel_rows(&mut self, panel_rows: usize) {
        self.panel_rows = panel_rows.max(1).div_ceil(MR) * MR;
    }

    /// Current GEMM shard granularity in token rows.
    pub fn panel_rows(&self) -> usize {
        self.panel_rows
    }

    /// Install the dist-extracted shard layout for subsequent runs
    /// ([`ShardSpec::derive`]); [`ShardSpec::single`] restores the
    /// unsharded seed engine. Call before [`BatchEngine::run`] — the
    /// run then spawns `shards × threads` workers, with each
    /// projection GEMM executing under its matrix's chosen layout.
    /// Layout only: outputs stay bitwise identical to the unsharded
    /// engine under any spec.
    pub fn set_sharding(&mut self, sharding: ShardSpec) {
        self.sharding = sharding;
    }

    /// The installed shard layout.
    pub fn sharding(&self) -> &ShardSpec {
        &self.sharding
    }

    /// Install (or clear) the shared failpoint plan for subsequent runs
    /// ([`FaultPlan`]; the serving coordinator shares one `Arc` between
    /// the engine, the scheduler and the serve loop). The hooks sit on
    /// the phase barriers, the tier-op windows and the admission path;
    /// with `None` — the default — each hook is one untaken branch, so
    /// the no-fault hot path is unchanged.
    pub fn set_faults(&mut self, faults: Option<Arc<FaultPlan>>) {
        self.faults = faults;
    }

    /// Stored bytes of the packed/quantized weight plane (all layers +
    /// LM head) — what one batched decode iteration streams.
    pub fn weight_bytes(&self) -> usize {
        let per_layer: usize = self
            .packed
            .iter()
            .map(|p| {
                p.wq.bytes()
                    + p.wk.bytes()
                    + p.wv.bytes()
                    + p.wo.bytes()
                    + p.w_gate.bytes()
                    + p.w_up.bytes()
                    + p.w_down.bytes()
            })
            .sum();
        per_layer + self.packed_lm_head.bytes()
    }

    /// Attach a cold-tier arena of `cold_blocks` slots (call before
    /// [`BatchEngine::run`]; the serving coordinator does this when
    /// `ContinuousConfig::tiering` is set).
    pub fn enable_tier(&mut self, cold_blocks: usize, quant: KvQuant) {
        let cfg = &self.weights.cfg;
        self.cold = Some(ColdKv::new(
            cold_blocks,
            self.kv.block_size,
            cfg.layers,
            cfg.kv_heads * cfg.head_dim,
            quant,
        ));
    }

    /// Open one SPMD serve run: spawn `shards × threads - 1` persistent
    /// workers (one `thread::scope` for the whole run, not per step),
    /// hand the driver a [`BatchStepper`], and shut the workers down
    /// when it returns. `max_rows` is the step capacity in **token
    /// rows** (the scheduler's per-iteration token budget — equal to
    /// `max_batch` when `prefill_chunk` is 1); every buffer is sized to
    /// it and `threads` is clamped to `[1, max_rows]` — lanes own token
    /// rows, so counts beyond the row capacity would only produce empty
    /// shards (the same guard `Qwen3Engine::new` applies at the model's
    /// partition width). Under a sharded [`ShardSpec`] the clamped
    /// `threads` becomes the lane count of each of `shards` worker
    /// groups (see the module docs); with the default single-group spec
    /// this is exactly the seed topology.
    pub fn run<R>(
        &mut self,
        threads: usize,
        max_rows: usize,
        driver: impl FnOnce(&mut BatchStepper<'_, '_>) -> R,
    ) -> R {
        self.run_traced(threads, max_rows, None, driver).0
    }

    /// As [`BatchEngine::run`], optionally traced: with
    /// `trace = Some((epoch, capacity))` every worker (the controller
    /// included) records its phase, barrier-wait, and tier-op spans
    /// into a pre-allocated [`Ring`] of `capacity` events stamped
    /// against the shared `epoch`, and the per-worker timelines come
    /// back as a [`TraceLog`]. Tracing records timestamps only — it
    /// never touches the arithmetic, the partitions, or the barrier
    /// protocol — so a traced run computes bitwise-identical outputs
    /// (pinned by the differential tests in `rust/tests/serving.rs`).
    /// `trace = None` is the zero-cost path: every hook is one branch.
    pub fn run_traced<R>(
        &mut self,
        threads: usize,
        max_rows: usize,
        trace: Option<(Instant, usize)>,
        driver: impl FnOnce(&mut BatchStepper<'_, '_>) -> R,
    ) -> (R, Option<TraceLog>) {
        let max_rows = max_rows.max(1);
        let lanes = threads.clamp(1, max_rows);
        let mut sharding = self.sharding;
        sharding.shards = sharding.shards.max(1);
        let t = lanes * sharding.shards;
        let panel = self.panel_rows.max(MR);
        let st = StepState::new(&self.weights.cfg, max_rows);
        let barrier = SpinBarrier::new(t);
        let cmd = AtomicUsize::new(CMD_STEP);
        let weights = self.weights;
        let packed: &[PackedLayer] = &self.packed;
        let packed_lm_head = &self.packed_lm_head;
        let kv_cell = KvCell::new(&mut self.kv);
        let cold_cell = self.cold.as_mut().map(KvCell::new);
        let fault: Option<&FaultPlan> = self.faults.as_deref();
        // Pre-allocate one ring per worker before the scope opens; the
        // hot path only ever writes into its own ring through an
        // `Option<&mut Ring>` (no locks, no allocation).
        let mut rings: Vec<Ring> = match trace {
            Some((epoch, cap)) => (0..t).map(|_| Ring::with_capacity(cap, epoch)).collect(),
            None => Vec::new(),
        };
        let result = std::thread::scope(|s| {
            let mut ring_slots: Vec<Option<&mut Ring>> = rings.iter_mut().map(Some).collect();
            ring_slots.resize_with(t, || None);
            for wi in 1..t {
                let (st, barrier, cmd, kv_cell) = (&st, &barrier, &cmd, &kv_cell);
                let cold_cell = cold_cell.as_ref();
                let mut ring = ring_slots[wi].take();
                s.spawn(move || {
                    // A panicking worker poisons the barrier so the
                    // controller and its sibling workers unwind instead
                    // of spinning forever (see SpinBarrier).
                    let _poison = PoisonGuard::new(barrier);
                    let mut scratch = Vec::new();
                    let mut colbuf = Vec::new();
                    loop {
                        // Park until the controller publishes the next
                        // step (or shutdown); traced, the park span is
                        // this worker's between-steps idle time.
                        let t0 = obs::mark(&ring);
                        barrier.wait();
                        if cmd.load(Ordering::Acquire) == CMD_EXIT {
                            break;
                        }
                        obs::span(&mut ring, Code::Park, t0, 0);
                        spmd_step(
                            wi,
                            t,
                            lanes,
                            panel,
                            &sharding,
                            weights,
                            packed,
                            packed_lm_head,
                            kv_cell,
                            cold_cell,
                            st,
                            barrier,
                            &mut scratch,
                            &mut colbuf,
                            &mut ring,
                            fault,
                        );
                    }
                });
            }
            let mut stepper = BatchStepper {
                weights,
                packed,
                packed_lm_head,
                kv_cell: &kv_cell,
                cold_cell: cold_cell.as_ref(),
                st: &st,
                barrier: &barrier,
                threads: lanes,
                workers: t,
                sharding,
                panel,
                max_rows,
                scratch: Vec::new(),
                colbuf: Vec::new(),
                trace: ring_slots[0].take(),
                faults: fault,
            };
            // Workers stay parked between steps; if the driver unwinds
            // (scheduler panics, test assertions, a panic inside the
            // controller's own share of a step) they must still be made
            // to exit, or `thread::scope`'s implicit join would block
            // forever on parked/stuck workers.
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| driver(&mut stepper)));
            cmd.store(CMD_EXIT, Ordering::Release);
            match result {
                Ok(r) => {
                    // Clean shutdown: release the parked workers so they
                    // observe CMD_EXIT and break.
                    barrier.wait();
                    r
                }
                Err(payload) => {
                    // The driver unwound — workers may be parked at the
                    // start barrier or stuck at a phase barrier mid-step.
                    // Poisoning makes every wait panic, so all of them
                    // unwind instead of deadlocking the scope join; the
                    // original payload then takes precedence. This arm
                    // covers every driver-side unwind uniformly: the
                    // scheduler's own panics, the `tier_ops` commit
                    // windows (which run while all workers are parked),
                    // the controller's share of a step, and injected
                    // failpoint panics — the serve loop catches the
                    // resumed payload at its epoch boundary, audits and
                    // requeues, then restarts a fresh scope.
                    barrier.poison();
                    std::panic::resume_unwind(payload)
                }
            }
        });
        let log = (!rings.is_empty()).then(|| TraceLog {
            workers: rings
                .iter()
                .enumerate()
                .map(|(wi, r)| WorkerTrace {
                    tid: wi as u32,
                    name: if wi == 0 {
                        "worker 0 (controller)".to_string()
                    } else {
                        format!("worker {wi}")
                    },
                    events: r.events(),
                    dropped: r.dropped(),
                })
                .collect(),
        });
        (result, log)
    }

    /// Advance every slot by its span; returns the argmax token of the
    /// span's final row for slots with `sample = true`. One-shot
    /// single-threaded convenience wrapper over [`BatchEngine::run`] —
    /// serving drives `run` directly so the workers persist across
    /// steps.
    pub fn step(&mut self, slots: &[StepSlot]) -> Vec<Option<usize>> {
        self.step_logits(slots, false).0
    }

    /// As [`BatchEngine::step`]; with `keep_logits` the
    /// `[total_rows * vocab]` logits buffer of the iteration is
    /// returned too.
    pub fn step_logits(
        &mut self,
        slots: &[StepSlot],
        keep_logits: bool,
    ) -> (Vec<Option<usize>>, Vec<f32>) {
        let cap = slots.iter().map(|s| s.tokens.len()).sum::<usize>().max(1);
        self.run(1, cap, |stepper| stepper.step_logits(slots, keep_logits))
    }

    /// As [`BatchEngine::step`], returning the argmax of *every* row of
    /// every span (the speculative-decoding verify readout,
    /// [`BatchStepper::step_verify`]). One-shot single-threaded
    /// convenience wrapper — serving drives the stepper directly.
    pub fn step_verify(&mut self, slots: &[StepSlot]) -> Vec<Vec<usize>> {
        let cap = slots.iter().map(|s| s.tokens.len()).sum::<usize>().max(1);
        self.run(1, cap, |stepper| stepper.step_verify(slots))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Qwen3Engine;
    use crate::model::{Qwen3Config, Qwen3Weights};

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
    }

    #[test]
    fn paged_batch_of_one_matches_dense_engine() {
        let cfg = Qwen3Config::tiny();
        let w_dense = Qwen3Weights::random(&cfg, 99);
        let w_paged = Qwen3Weights::random(&cfg, 99);
        let mut dense = Qwen3Engine::new(w_dense, 1, 32);
        let mut be = BatchEngine::new(&w_paged, 8, 4);
        // Non-contiguous table: 3 blocks out of order.
        let table: Vec<u32> = vec![3, 0, 6];
        let tokens = [7usize, 300, 5, 42, 9, 1000];
        for (pos, tok) in tokens.iter().enumerate() {
            let dense_logits = dense.decode_step(*tok, pos);
            let slot = StepSlot::hot(std::slice::from_ref(tok), pos, &table, true);
            let (samples, paged_logits) = be.step_logits(&[slot], true);
            let diff = max_abs_diff(&dense_logits, &paged_logits);
            assert!(diff < 1e-6, "pos {pos}: paged vs dense logits differ by {diff}");
            assert_eq!(
                samples[0].unwrap(),
                crate::coordinator::argmax(&dense_logits),
                "pos {pos}: sampled token diverged"
            );
        }
    }

    #[test]
    fn chunked_span_is_bitwise_identical_to_single_token_steps() {
        // The tentpole contract: feeding a prompt as multi-token spans
        // (commit the whole span, then causal windowed attention) must
        // reproduce sequential single-token steps bit for bit at every
        // position and any worker count — including a chunk size that
        // is NOT a divisor of the block size, so spans straddle block
        // boundaries.
        let cfg = Qwen3Config::tiny();
        let w_seq = Qwen3Weights::random(&cfg, 202);
        let w_chunk = Qwen3Weights::random(&cfg, 202);
        let bs = 4usize;
        let tokens = [7usize, 300, 5, 42, 9, 1000, 77, 13, 501, 88, 2, 61];
        let table: Vec<u32> = vec![5, 1, 3];
        let mut seq_engine = BatchEngine::new(&w_seq, 8, bs);
        let mut want = Vec::new();
        for (pos, tok) in tokens.iter().enumerate() {
            let slot = StepSlot::hot(std::slice::from_ref(tok), pos, &table, true);
            want.extend(seq_engine.step_logits(&[slot], true).1);
        }
        for threads in [1usize, 2, 4] {
            for chunk in [3usize, 5, 12] {
                let mut be = BatchEngine::new(&w_chunk, 8, bs);
                let got = be.run(threads, tokens.len(), |stepper| {
                    let mut out = Vec::new();
                    let mut pos = 0usize;
                    while pos < tokens.len() {
                        let span = chunk.min(tokens.len() - pos);
                        let slot = StepSlot::hot(&tokens[pos..pos + span], pos, &table, true);
                        out.extend(stepper.step_logits(&[slot], true).1);
                        pos += span;
                    }
                    out
                });
                assert_eq!(
                    want, got,
                    "chunk {chunk} diverged from sequential steps at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn verify_rows_match_single_token_argmax_at_any_thread_count() {
        // The speculative-verify contract: `step_verify` must return,
        // for every row of a span, exactly the argmax a sequential
        // single-token run computes at that position — at any worker
        // count, with spans straddling block boundaries. This is what
        // makes greedy acceptance semantics-free: an accepted draft IS
        // the token the model would have sampled.
        let cfg = Qwen3Config::tiny();
        let w_seq = Qwen3Weights::random(&cfg, 303);
        let w_spec = Qwen3Weights::random(&cfg, 303);
        let bs = 4usize;
        let tokens = [3usize, 91, 7, 12, 404, 55, 8, 230, 17];
        let table: Vec<u32> = vec![2, 7, 0];
        let mut seq_engine = BatchEngine::new(&w_seq, 8, bs);
        let mut want = Vec::new();
        for (pos, tok) in tokens.iter().enumerate() {
            let slot = StepSlot::hot(std::slice::from_ref(tok), pos, &table, true);
            let (_, logits) = seq_engine.step_logits(&[slot], true);
            want.push(crate::coordinator::argmax(&logits));
        }
        for threads in [1usize, 2, 3] {
            let mut be = BatchEngine::new(&w_spec, 8, bs);
            let got = be.run(threads, tokens.len(), |stepper| {
                stepper.step_verify(&[StepSlot::hot(&tokens, 0, &table, true)])
            });
            assert_eq!(got.len(), 1);
            assert_eq!(
                got[0], want,
                "verify rows diverged from sequential argmax at {threads} threads"
            );
        }
    }

    #[test]
    fn ragged_batch_mixes_spans_and_single_tokens() {
        // One step may batch a prefill span with a single-token decode
        // row; every row's logits must equal its solo run bit for bit
        // (rows are arithmetic-independent under the ragged row map).
        let cfg = Qwen3Config::tiny();
        let w_a = Qwen3Weights::random(&cfg, 71);
        let w_b = Qwen3Weights::random(&cfg, 71);
        let vocab = cfg.vocab;
        let t1: Vec<u32> = vec![0, 1];
        let t2: Vec<u32> = vec![2, 3];
        let span = [11usize, 22, 33, 44];
        let lone = [500usize];
        // Solo runs.
        let mut solo = BatchEngine::new(&w_a, 16, 4);
        let (_, span_solo) = solo.step_logits(&[StepSlot::hot(&span, 0, &t1, true)], true);
        let mut solo2 = BatchEngine::new(&w_a, 16, 4);
        let (_, lone_solo) = solo2.step_logits(&[StepSlot::hot(&lone, 0, &t2, true)], true);
        // Ragged batch: the span and the single token share one step.
        let mut duo = BatchEngine::new(&w_b, 16, 4);
        let slots =
            [StepSlot::hot(&span, 0, &t1, true), StepSlot::hot(&lone, 0, &t2, true)];
        let (samples, ragged) = duo.step_logits(&slots, true);
        assert_eq!(&ragged[..span.len() * vocab], &span_solo[..]);
        assert_eq!(&ragged[span.len() * vocab..], &lone_solo[..]);
        // Sampling reads the span's FINAL row, not row 0.
        assert_eq!(
            samples[0].unwrap(),
            crate::coordinator::argmax(&span_solo[(span.len() - 1) * vocab..]),
        );
        assert_eq!(samples[1].unwrap(), crate::coordinator::argmax(&lone_solo));
    }

    #[test]
    fn batched_rows_do_not_interfere() {
        let cfg = Qwen3Config::tiny();
        let w_a = Qwen3Weights::random(&cfg, 5);
        let w_b = Qwen3Weights::random(&cfg, 5);
        let mut solo = BatchEngine::new(&w_a, 16, 4);
        let mut duo = BatchEngine::new(&w_b, 16, 4);
        let t1: Vec<u32> = vec![0, 1];
        let t2: Vec<u32> = vec![2, 3];
        let seq1 = [11usize, 22, 33];
        let seq2 = [500usize, 600, 700];
        // Solo: run seq1 alone.
        let mut solo_logits = Vec::new();
        for (pos, tok) in seq1.iter().enumerate() {
            let (_, l) =
                solo.step_logits(&[StepSlot::hot(std::slice::from_ref(tok), pos, &t1, true)], true);
            solo_logits = l;
        }
        // Duo: run seq1 batched with an unrelated seq2.
        let mut duo_logits = Vec::new();
        for pos in 0..seq1.len() {
            let slots = [
                StepSlot::hot(std::slice::from_ref(&seq1[pos]), pos, &t1, true),
                StepSlot::hot(std::slice::from_ref(&seq2[pos]), pos, &t2, true),
            ];
            let (_, l) = duo.step_logits(&slots, true);
            duo_logits = l;
        }
        let vocab = cfg.vocab;
        let diff = max_abs_diff(&solo_logits[..vocab], &duo_logits[..vocab]);
        assert!(diff < 1e-6, "batch companion changed a row's logits by {diff}");
    }

    #[test]
    fn threaded_run_is_bit_identical_to_single_thread() {
        // The persistent-worker SPMD step must reproduce the
        // single-threaded batched step bit for bit at any worker count,
        // because the static partition never changes an element's
        // accumulation order.
        let cfg = Qwen3Config::tiny();
        let w1 = Qwen3Weights::random(&cfg, 321);
        let w2 = Qwen3Weights::random(&cfg, 321);
        let nseq = 6usize;
        let steps = 5usize;
        let tables: Vec<Vec<u32>> =
            (0..nseq).map(|i| vec![2 * i as u32, 2 * i as u32 + 1]).collect();
        let run_with = |w: &Qwen3Weights, threads: usize| -> Vec<Vec<f32>> {
            let mut be = BatchEngine::new(w, 16, 4);
            be.run(threads, nseq, |stepper| {
                (0..steps)
                    .map(|pos| {
                        let toks: Vec<usize> =
                            (0..nseq).map(|i| (i * 31 + pos * 7) % cfg.vocab).collect();
                        let slots: Vec<StepSlot> = (0..nseq)
                            .map(|i| {
                                StepSlot::hot(
                                    std::slice::from_ref(&toks[i]),
                                    pos,
                                    &tables[i],
                                    true,
                                )
                            })
                            .collect();
                        stepper.step_logits(&slots, true).1
                    })
                    .collect()
            })
        };
        let want = run_with(&w1, 1);
        for t in [2usize, 4, 6] {
            let got = run_with(&w2, t);
            assert_eq!(want, got, "thread count {t} changed batched logits");
        }
    }

    #[test]
    fn dist_sharded_run_is_bit_identical_to_unsharded() {
        // The sharding tentpole contract: executing under a
        // dist-EXTRACTED ShardSpec — shards × lanes workers,
        // column-parallel GEMMs wherever the cost model chose S(1) —
        // must reproduce the unsharded engine bit for bit at every
        // (threads × shards), chunked prefill spans included.
        let cfg = Qwen3Config::tiny();
        let machine = crate::cost::MachineSpec::test_numa();
        let w_base = Qwen3Weights::random(&cfg, 4242);
        let w_shard = Qwen3Weights::random(&cfg, 4242);
        let prompt = [7usize, 300, 5, 42, 9, 1000, 77, 13];
        let table: Vec<u32> = vec![5, 1, 3];
        let chunk = 3usize;
        let run_with = |w: &Qwen3Weights, threads: usize, spec: ShardSpec| -> Vec<Vec<f32>> {
            let mut be = BatchEngine::new(w, 8, 4);
            be.set_sharding(spec);
            be.run(threads, chunk, |stepper| {
                assert_eq!(
                    stepper.workers(),
                    stepper.threads() * spec.shards,
                    "a run must spawn shards x lanes workers"
                );
                prompt
                    .chunks(chunk)
                    .scan(0usize, |pos, span| {
                        let p = *pos;
                        *pos += span.len();
                        Some(
                            stepper
                                .step_logits(&[StepSlot::hot(span, p, &table, true)], true)
                                .1,
                        )
                    })
                    .collect()
            })
        };
        let want = run_with(&w_base, 1, ShardSpec::single());
        for shards in [2usize, 4] {
            let spec = ShardSpec::derive(&cfg, &machine, shards);
            assert!(
                spec.matrices().iter().any(|(_, m)| *m == MatShard::ColumnShard),
                "dist must shard something at {shards} groups: {}",
                spec.sig()
            );
            for threads in [1usize, 2, 3] {
                let got = run_with(&w_shard, threads, spec);
                let same = want
                    .iter()
                    .flatten()
                    .map(|f| f.to_bits())
                    .eq(got.iter().flatten().map(|f| f.to_bits()));
                assert!(
                    same,
                    "shards={shards} threads={threads} diverged from the unsharded engine"
                );
            }
        }
    }

    #[test]
    fn column_sharded_quantized_run_matches_seed_bitwise() {
        // Force EVERY projection onto the column-parallel path —
        // including an uneven NR-panel split at shards = 3 — in both
        // f32 and group-quantized weight modes: the compact-block
        // writeback must leave each output element's full-K ascending
        // accumulation untouched.
        use crate::ntt::WeightQuant;
        let all_cols = |shards: usize| ShardSpec {
            shards,
            wq: MatShard::ColumnShard,
            wk: MatShard::ColumnShard,
            wv: MatShard::ColumnShard,
            wo: MatShard::ColumnShard,
            w_gate: MatShard::ColumnShard,
            w_up: MatShard::ColumnShard,
            w_down: MatShard::ColumnShard,
            lm_head: MatShard::ColumnShard,
        };
        for mode in [WeightQuant::F32, WeightQuant::Int8] {
            let cfg = Qwen3Config::tiny().with_weight_quant(mode);
            let w_base = Qwen3Weights::random(&cfg, 77);
            let w_shard = Qwen3Weights::random(&cfg, 77);
            let tokens = [3usize, 90, 512, 44, 17, 256];
            let table: Vec<u32> = vec![4, 2];
            let run_with = |w: &Qwen3Weights, threads: usize, spec: ShardSpec| -> Vec<f32> {
                let mut be = BatchEngine::new(w, 8, 4);
                be.set_sharding(spec);
                be.run(threads, 2, |stepper| {
                    let mut out = Vec::new();
                    for (pos, tok) in tokens.iter().enumerate() {
                        let slot = StepSlot::hot(std::slice::from_ref(tok), pos, &table, true);
                        out.extend(stepper.step_logits(&[slot], true).1);
                    }
                    out
                })
            };
            let want = run_with(&w_base, 1, ShardSpec::single());
            for shards in [2usize, 3] {
                for threads in [1usize, 2] {
                    let got = run_with(&w_shard, threads, all_cols(shards));
                    assert_eq!(want.len(), got.len());
                    let same = want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "mode {mode:?} shards={shards} threads={threads} diverged");
                }
            }
        }
    }

    #[test]
    fn persistent_workers_survive_varying_batches() {
        // One run, four steps with batch sizes 1 -> 2 -> 2 -> 1, driven
        // with an oversubscribed thread request (clamped to the row
        // capacity).
        let cfg = Qwen3Config::tiny();
        let w_ref = Qwen3Weights::random(&cfg, 9);
        let w_thr = Qwen3Weights::random(&cfg, 9);
        let t1: Vec<u32> = vec![0, 1];
        let t2: Vec<u32> = vec![2, 3];
        let script: Vec<Vec<(usize, usize, &[u32])>> = vec![
            vec![(11, 0, &t1)],
            vec![(22, 1, &t1), (500, 0, &t2)],
            vec![(33, 2, &t1), (600, 1, &t2)],
            vec![(700, 2, &t2)],
        ];
        let mut reference = BatchEngine::new(&w_ref, 8, 4);
        let mut want = Vec::new();
        for step in &script {
            let slots: Vec<StepSlot> = step
                .iter()
                .map(|(token, pos, table)| {
                    StepSlot::hot(std::slice::from_ref(token), *pos, table, true)
                })
                .collect();
            want.push(reference.step_logits(&slots, true).1);
        }
        let mut threaded = BatchEngine::new(&w_thr, 8, 4);
        let got = threaded.run(64, 2, |stepper| {
            assert_eq!(stepper.threads(), 2, "threads must clamp at the row capacity");
            script
                .iter()
                .map(|step| {
                    let slots: Vec<StepSlot> = step
                        .iter()
                        .map(|(token, pos, table)| {
                            StepSlot::hot(std::slice::from_ref(token), *pos, table, true)
                        })
                        .collect();
                    stepper.step_logits(&slots, true).1
                })
                .collect::<Vec<_>>()
        });
        assert_eq!(want, got, "persistent-worker run diverged from one-shot steps");
    }

    #[test]
    fn driver_panic_releases_parked_workers() {
        // A panic inside the driver must propagate out of run() — the
        // parked persistent workers are poisoned awake and the scope
        // join completes instead of deadlocking.
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 3);
        let mut be = BatchEngine::new(&w, 4, 4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            be.run(2, 2, |_stepper| panic!("driver exploded mid-run"));
        }));
        assert!(result.is_err(), "panic must propagate, not hang the scope join");
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 1);
        let mut be = BatchEngine::new(&w, 2, 4);
        assert!(be.step(&[]).is_empty());
        be.run(2, 4, |stepper| {
            assert!(stepper.step(&[]).is_empty());
        });
    }

    #[test]
    #[cfg(debug_assertions)]
    fn degenerate_spans_are_rejected() {
        // Zero-token spans and spans past the reserved block tables are
        // scheduler bugs; the engine turns them into deterministic
        // debug panics instead of silent row-base corruption / foreign
        // block writes.
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 2);
        let table: Vec<u32> = vec![0];
        let empty = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut be = BatchEngine::new(&w, 2, 4);
            be.step(&[StepSlot::hot(&[], 0, &table, false)]);
        }));
        assert!(empty.is_err(), "empty span must be rejected");
        let overrun = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut be = BatchEngine::new(&w, 2, 4);
            // Span [3, 5) needs position 4; a 1-block table covers 0..4.
            be.step(&[StepSlot::hot(&[1, 2], 3, &table, false)]);
        }));
        assert!(overrun.is_err(), "span past the block table must be rejected");
    }

    #[test]
    fn quantized_weights_match_fake_quant_oracle_bitwise() {
        // The weight-quant contract: a batched engine over group-wise
        // quantized weights (fused dequant-GEMM kernels) must produce
        // exactly the logits of a plain f32 batched engine running over
        // the *fake-quantized* weights (quantize→dequantize round trip)
        // — the quantized path changes the bytes streamed, never the
        // values FMAd or their accumulation order — at any worker count.
        use crate::ntt::WeightQuant;
        for mode in [WeightQuant::Int8, WeightQuant::Int4] {
            let cfg_q = Qwen3Config::tiny().with_weight_quant(mode);
            let w_q = Qwen3Weights::random(&cfg_q, 77);
            // Same seed, f32 config, matrices round-tripped by hand.
            let w_f = Qwen3Weights::random(&Qwen3Config::tiny(), 77).fake_quantized(mode);
            let tables: Vec<Vec<u32>> = vec![vec![0, 1], vec![2, 3]];
            let script: Vec<Vec<usize>> = vec![vec![7, 500], vec![42, 600], vec![9, 700]];
            let run = |w: &Qwen3Weights, threads: usize| -> Vec<Vec<f32>> {
                let mut be = BatchEngine::new(w, 8, 4);
                be.run(threads, 2, |stepper| {
                    script
                        .iter()
                        .enumerate()
                        .map(|(pos, toks)| {
                            let slots: Vec<StepSlot> = toks
                                .iter()
                                .enumerate()
                                .map(|(i, t)| {
                                    StepSlot::hot(
                                        std::slice::from_ref(t),
                                        pos,
                                        &tables[i],
                                        true,
                                    )
                                })
                                .collect();
                            stepper.step_logits(&slots, true).1
                        })
                        .collect()
                })
            };
            let want = run(&w_f, 1);
            for threads in [1usize, 2] {
                let got = run(&w_q, threads);
                assert_eq!(want, got, "{mode:?} fused path diverged at {threads} threads");
            }
        }
    }

    #[test]
    fn quantized_engine_streams_fewer_weight_bytes() {
        use crate::ntt::WeightQuant;
        let cfg = Qwen3Config::tiny();
        let w_f = Qwen3Weights::random(&cfg, 5);
        let w_8 = Qwen3Weights::random(&cfg.clone().with_weight_quant(WeightQuant::Int8), 5);
        let w_4 = Qwen3Weights::random(&cfg.clone().with_weight_quant(WeightQuant::Int4), 5);
        let f = BatchEngine::new(&w_f, 2, 4).weight_bytes();
        let q8 = BatchEngine::new(&w_8, 2, 4).weight_bytes();
        let q4 = BatchEngine::new(&w_4, 2, 4).weight_bytes();
        assert!(q8 * 3 < f, "int8 plane must be well under a third of f32: {q8}/{f}");
        assert!(q4 < q8, "int4 plane must be under int8: {q4}/{q8}");
    }

    #[test]
    fn f32_tier_swap_roundtrip_is_bit_identical() {
        // Decode a sequence, spill its blocks to an f32 cold tier,
        // clobber + refetch through stepper.tier_ops, and keep decoding:
        // logits must match an uninterrupted run bit for bit.
        let cfg = Qwen3Config::tiny();
        let w_ref = Qwen3Weights::random(&cfg, 27);
        let w_tier = Qwen3Weights::random(&cfg, 27);
        let table: Vec<u32> = vec![1, 3];
        let tokens = [9usize, 42, 300, 7, 15, 88];
        let mut reference = BatchEngine::new(&w_ref, 8, 4);
        let mut want = Vec::new();
        for (pos, tok) in tokens.iter().enumerate() {
            let slot = StepSlot::hot(std::slice::from_ref(tok), pos, &table, true);
            want.push(reference.step_logits(&[slot], true).1);
        }
        let mut be = BatchEngine::new(&w_tier, 8, 4);
        be.enable_tier(4, KvQuant::F32);
        let got = be.run(1, 1, |stepper| {
            let mut out = Vec::new();
            for (pos, tok) in tokens.iter().enumerate() {
                if pos == 5 {
                    // Swap out both blocks (block 1 holds 4 rows, block
                    // 3 holds one), then swap them back into *different*
                    // hot blocks — the paged indirection must not care.
                    stepper.tier_ops(&[
                        TierOp::Spill { hot: 1, cold: 0, filled: 4 },
                        TierOp::Spill { hot: 3, cold: 2, filled: 1 },
                    ]);
                    stepper.tier_ops(&[
                        TierOp::Fetch { cold: 0, hot: 6, seq: 0 },
                        TierOp::Fetch { cold: 2, hot: 0, seq: 0 },
                    ]);
                    let new_table: Vec<u32> = vec![6, 0];
                    let slot = StepSlot::hot(std::slice::from_ref(tok), pos, &new_table, true);
                    out.push(stepper.step_logits(&[slot], true).1);
                } else {
                    let slot = StepSlot::hot(std::slice::from_ref(tok), pos, &table, true);
                    out.push(stepper.step_logits(&[slot], true).1);
                }
            }
            out
        });
        assert_eq!(want, got, "f32 swap round trip changed logits");
    }

    #[test]
    fn direct_cold_read_matches_fetched_dequant() {
        // The hybrid attention path (leading blocks read in place from
        // the int8 tier) must produce exactly what a full fetch +
        // dequantize into hot blocks produces: same quantized values,
        // two different read paths.
        let cfg = Qwen3Config::tiny();
        let w_a = Qwen3Weights::random(&cfg, 63);
        let w_b = Qwen3Weights::random(&cfg, 63);
        let bs = 4usize;
        let prefix = [3usize, 19, 250, 40]; // one full block
        let tail = [77usize, 501];

        // Run A: fill block 0, spill+fetch it (quantize round trip into
        // hot), continue on the hot path.
        let mut fetched = BatchEngine::new(&w_a, 8, bs);
        fetched.enable_tier(2, KvQuant::Int8);
        let want = fetched.run(1, 1, |stepper| {
            let table: Vec<u32> = vec![0, 1];
            for (pos, tok) in prefix.iter().enumerate() {
                stepper.step(&[StepSlot::hot(std::slice::from_ref(tok), pos, &table, false)]);
            }
            stepper.tier_ops(&[TierOp::Spill { hot: 0, cold: 1, filled: bs }]);
            stepper.tier_ops(&[TierOp::Fetch { cold: 1, hot: 0, seq: 0 }]);
            let mut out = Vec::new();
            for (i, tok) in tail.iter().enumerate() {
                let pos = prefix.len() + i;
                out.push(
                    stepper
                        .step_logits(
                            &[StepSlot::hot(std::slice::from_ref(tok), pos, &table, true)],
                            true,
                        )
                        .1,
                );
            }
            out
        });

        // Run B: same prefix, spill block 0 and keep it cold — the tail
        // steps read it through the dequant-gather kernels.
        let mut direct = BatchEngine::new(&w_b, 8, bs);
        direct.enable_tier(2, KvQuant::Int8);
        let got = direct.run(1, 1, |stepper| {
            let table: Vec<u32> = vec![0, 1];
            for (pos, tok) in prefix.iter().enumerate() {
                stepper.step(&[StepSlot::hot(std::slice::from_ref(tok), pos, &table, false)]);
            }
            stepper.tier_ops(&[TierOp::Spill { hot: 0, cold: 1, filled: bs }]);
            let cold: Vec<u32> = vec![1];
            let hot_tail: Vec<u32> = vec![1];
            let mut out = Vec::new();
            for (i, tok) in tail.iter().enumerate() {
                let pos = prefix.len() + i;
                let slot = StepSlot {
                    tokens: std::slice::from_ref(tok),
                    pos,
                    table: &hot_tail,
                    cold: &cold,
                    sample: true,
                };
                out.push(stepper.step_logits(&[slot], true).1);
            }
            out
        });
        assert_eq!(want, got, "direct cold reads diverged from fetch+dequantize");
    }

    #[test]
    fn injected_worker_panic_unwinds_and_disarms() {
        // An armed failpoint panic on a non-controller worker must
        // poison the barrier and propagate out of run() instead of
        // deadlocking the scope join; the spec is one-shot, so the next
        // run on the same engine executes clean.
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 13);
        let mut be = BatchEngine::new(&w, 8, 4);
        let fp = Arc::new(FaultPlan::new().panic_at(Code::Attn, 2, Some(1)));
        be.set_faults(Some(fp.clone()));
        let table: Vec<u32> = vec![0, 1];
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            be.run(2, 2, |stepper| {
                for (pos, tok) in [7usize, 42, 9].iter().enumerate() {
                    stepper
                        .step(&[StepSlot::hot(std::slice::from_ref(tok), pos, &table, true)]);
                }
            });
        }));
        assert!(result.is_err(), "injected panic must propagate, not hang the join");
        assert_eq!(fp.injected(), 1, "exactly one fault fires");
        let samples = be.run(2, 2, |stepper| {
            stepper.step(&[StepSlot::hot(&[7usize], 0, &table, true)])
        });
        assert!(samples[0].is_some(), "disarmed plan must not re-fire on the restart");
    }

    #[test]
    fn tier_op_panic_poisons_parked_workers() {
        // `tier_ops` runs on the controller while the workers are parked
        // — a panic inside it (here: tier ops on an engine with no cold
        // tier) unwinds through the driver closure and the Err arm of
        // run_traced must poison the parked workers awake rather than
        // deadlock the scope join.
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 3);
        let mut be = BatchEngine::new(&w, 4, 4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            be.run(2, 2, |stepper| {
                stepper.tier_ops(&[TierOp::Spill { hot: 0, cold: 0, filled: 1 }]);
            });
        }));
        assert!(result.is_err(), "tier-op panic must propagate, not hang the join");
    }

    #[test]
    fn corrupted_spill_fails_verification_on_fetch() {
        // An injected payload corruption (bytes flipped after the spill
        // recorded its checksum) must be caught by both read paths: the
        // direct-read audit and the fetch-side verification, which
        // skips the copy and reports the slot instead of serving it.
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 31);
        let bs = 4usize;
        let mut be = BatchEngine::new(&w, 8, bs);
        be.enable_tier(2, KvQuant::F32);
        let fp = Arc::new(FaultPlan::new().corrupt_spill(0));
        be.set_faults(Some(fp.clone()));
        let failed = be.run(1, 1, |stepper| {
            let table: Vec<u32> = vec![0];
            for (pos, tok) in [5usize, 9, 11, 2].iter().enumerate() {
                stepper.step(&[StepSlot::hot(std::slice::from_ref(tok), pos, &table, false)]);
            }
            stepper.tier_ops(&[TierOp::Spill { hot: 0, cold: 1, filled: bs }]);
            assert_eq!(stepper.verify_cold(&[1]), vec![1], "direct-read audit missed it");
            stepper.tier_ops(&[TierOp::Fetch { cold: 1, hot: 2, seq: 0 }])
        });
        assert_eq!(failed, vec![1], "fetch must report the corrupt slot, not copy it");
        assert_eq!(fp.injected(), 1);
    }
}
