//! Batched decode over paged KV storage.
//!
//! One [`BatchEngine::step`] advances *every* scheduled sequence by one
//! position — iteration-level batching. The win over per-request decode
//! is in the weight stream: decode is memory-bound on weights, and the
//! FCFS path re-reads every projection matrix once per sequence per
//! token. Here the projections of all `B` batched rows run as one GEMM
//! over weights pre-packed at engine build ([`PackedMat`]), so the
//! weight stream is paid once per iteration instead of `B` times.
//!
//! K/V rows are gathered through per-sequence block tables
//! ([`attn_scores_paged`] / [`attn_context_paged`]) instead of
//! contiguous rows. Every kernel shares its accumulation order with the
//! dense single-sequence engine, so a batched continuous run produces
//! outputs identical to the FCFS oracle (the differential test in
//! `rust/tests/serving.rs` pins this down).

use crate::coordinator::argmax;
use crate::model::Qwen3Weights;
use crate::ntt::{
    add_inplace, attn_context_paged, attn_scores_paged, matmul_prepacked_into, mul_inplace,
    paged_row, rmsnorm, rope_inplace, silu_inplace, softmax_inplace, PackedMat, Tensor,
};

/// Paged KV arena: per layer, `num_blocks * block_size` rows of width
/// `kv_heads * head_dim`. Physical block `b` owns the same row range in
/// every layer.
pub struct PagedKv {
    pub block_size: usize,
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
}

impl PagedKv {
    pub fn new(layers: usize, num_blocks: usize, block_size: usize, width: usize) -> Self {
        let rows = num_blocks * block_size;
        PagedKv {
            block_size,
            k: (0..layers).map(|_| Tensor::zeros(&[rows, width])).collect(),
            v: (0..layers).map(|_| Tensor::zeros(&[rows, width])).collect(),
        }
    }

    /// Bytes of the whole arena (both K and V, all layers).
    pub fn arena_bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|t| t.numel() * 4).sum()
    }
}

struct PackedLayer {
    wq: PackedMat,
    wk: PackedMat,
    wv: PackedMat,
    wo: PackedMat,
    w_gate: PackedMat,
    w_up: PackedMat,
    w_down: PackedMat,
}

/// One sequence's slot in a batched iteration.
pub struct StepSlot<'t> {
    /// Token to feed at `pos`.
    pub token: usize,
    /// Logical position of `token` in the sequence.
    pub pos: usize,
    /// The sequence's block table; must cover `pos`.
    pub table: &'t [u32],
    /// Sample an output token from this row's logits (the sequence is
    /// at its frontier: last prompt token or a decode step).
    pub sample: bool,
}

/// The batched paged-attention decode engine.
pub struct BatchEngine<'w> {
    pub weights: &'w Qwen3Weights,
    packed: Vec<PackedLayer>,
    packed_lm_head: PackedMat,
    pub kv: PagedKv,
    /// Reused A-pack scratch for the per-iteration GEMMs.
    scratch: Vec<f32>,
}

impl<'w> BatchEngine<'w> {
    pub fn new(weights: &'w Qwen3Weights, num_blocks: usize, block_size: usize) -> Self {
        let cfg = &weights.cfg;
        let packed = weights
            .layers
            .iter()
            .map(|l| PackedLayer {
                wq: PackedMat::pack(&l.wq),
                wk: PackedMat::pack(&l.wk),
                wv: PackedMat::pack(&l.wv),
                wo: PackedMat::pack(&l.wo),
                w_gate: PackedMat::pack(&l.w_gate),
                w_up: PackedMat::pack(&l.w_up),
                w_down: PackedMat::pack(&l.w_down),
            })
            .collect();
        let kv = PagedKv::new(cfg.layers, num_blocks, block_size, cfg.kv_heads * cfg.head_dim);
        BatchEngine {
            weights,
            packed,
            packed_lm_head: PackedMat::pack(&weights.lm_head),
            kv,
            scratch: Vec::new(),
        }
    }

    /// Advance every slot one position; returns the argmax token for
    /// slots with `sample = true`. Also returns the full logits rows
    /// via `step_logits` for white-box tests.
    pub fn step(&mut self, slots: &[StepSlot]) -> Vec<Option<usize>> {
        let (samples, _) = self.step_logits(slots, false);
        samples
    }

    /// As [`BatchEngine::step`]; with `keep_logits` the `[B * vocab]`
    /// logits buffer of the iteration is returned too.
    pub fn step_logits(
        &mut self,
        slots: &[StepSlot],
        keep_logits: bool,
    ) -> (Vec<Option<usize>>, Vec<f32>) {
        let b = slots.len();
        if b == 0 {
            return (Vec::new(), Vec::new());
        }
        let cfg = self.weights.cfg.clone();
        let (h, hd, heads, kvh) = (cfg.hidden, cfg.head_dim, cfg.heads, cfg.kv_heads);
        let (qdim, kvdim, inter, vocab) = (heads * hd, kvh * hd, cfg.intermediate, cfg.vocab);
        let bs = self.kv.block_size;
        let group = heads / kvh;
        let inv_sqrt = 1.0 / (hd as f32).sqrt();

        for s in slots {
            debug_assert!(
                s.table.len() * bs > s.pos,
                "block table does not cover position {}",
                s.pos
            );
        }

        // Residual stream and scratch, one row per sequence.
        let mut x = vec![0.0f32; b * h];
        for (i, s) in slots.iter().enumerate() {
            x[i * h..(i + 1) * h]
                .copy_from_slice(self.weights.embedding.row(s.token % vocab));
        }
        let mut xn = vec![0.0f32; b * h];
        let mut q = vec![0.0f32; b * qdim];
        let mut kvec = vec![0.0f32; b * kvdim];
        let mut vvec = vec![0.0f32; b * kvdim];
        let mut ctx = vec![0.0f32; b * qdim];
        let mut attn = vec![0.0f32; b * h];
        let mut gate = vec![0.0f32; b * inter];
        let mut up = vec![0.0f32; b * inter];
        let mut down = vec![0.0f32; b * h];
        let mut logits = vec![0.0f32; b * vocab];

        for l in 0..cfg.layers {
            let w = &self.weights.layers[l];
            let pw = &self.packed[l];
            // Attention RMSNorm, per row.
            for i in 0..b {
                rmsnorm(
                    &x[i * h..(i + 1) * h],
                    &w.attn_norm.data,
                    cfg.rms_eps,
                    &mut xn[i * h..(i + 1) * h],
                );
            }
            // Batched QKV projections: the weight stream is read once
            // for the whole batch.
            matmul_prepacked_into(&xn, b, &pw.wq, &mut q, &mut self.scratch);
            matmul_prepacked_into(&xn, b, &pw.wk, &mut kvec, &mut self.scratch);
            matmul_prepacked_into(&xn, b, &pw.wv, &mut vvec, &mut self.scratch);
            // RoPE, per row with that row's position.
            for (i, s) in slots.iter().enumerate() {
                for head in 0..heads {
                    let o = i * qdim + head * hd;
                    rope_inplace(&mut q[o..o + hd], s.pos, cfg.rope_theta);
                }
                for head in 0..kvh {
                    let o = i * kvdim + head * hd;
                    rope_inplace(&mut kvec[o..o + hd], s.pos, cfg.rope_theta);
                }
            }
            // Commit this position's K/V through the block table.
            for (i, s) in slots.iter().enumerate() {
                let row = paged_row(s.table, bs, s.pos);
                self.kv.k[l].row_mut(row).copy_from_slice(&kvec[i * kvdim..(i + 1) * kvdim]);
                self.kv.v[l].row_mut(row).copy_from_slice(&vvec[i * kvdim..(i + 1) * kvdim]);
            }
            // Paged GQA attention, per sequence per query head.
            for (i, s) in slots.iter().enumerate() {
                let seq = s.pos + 1;
                let mut scores = vec![0.0f32; seq];
                for head in 0..heads {
                    let kvhead = head / group;
                    let qo = i * qdim + head * hd;
                    attn_scores_paged(
                        &q[qo..qo + hd],
                        &self.kv.k[l],
                        s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        inv_sqrt,
                        &mut scores,
                    );
                    softmax_inplace(&mut scores);
                    attn_context_paged(
                        &scores,
                        &self.kv.v[l],
                        s.table,
                        bs,
                        kvhead * hd,
                        hd,
                        &mut ctx[qo..qo + hd],
                    );
                }
            }
            // Output projection + residual.
            matmul_prepacked_into(&ctx, b, &pw.wo, &mut attn, &mut self.scratch);
            for i in 0..b {
                add_inplace(&mut x[i * h..(i + 1) * h], &attn[i * h..(i + 1) * h]);
            }
            // MLP (SwiGLU), batched.
            for i in 0..b {
                rmsnorm(
                    &x[i * h..(i + 1) * h],
                    &w.mlp_norm.data,
                    cfg.rms_eps,
                    &mut xn[i * h..(i + 1) * h],
                );
            }
            matmul_prepacked_into(&xn, b, &pw.w_gate, &mut gate, &mut self.scratch);
            matmul_prepacked_into(&xn, b, &pw.w_up, &mut up, &mut self.scratch);
            for i in 0..b {
                let g = &mut gate[i * inter..(i + 1) * inter];
                silu_inplace(g);
                mul_inplace(g, &up[i * inter..(i + 1) * inter]);
            }
            matmul_prepacked_into(&gate, b, &pw.w_down, &mut down, &mut self.scratch);
            for i in 0..b {
                add_inplace(&mut x[i * h..(i + 1) * h], &down[i * h..(i + 1) * h]);
            }
        }
        // Final norm + LM head.
        for i in 0..b {
            rmsnorm(
                &x[i * h..(i + 1) * h],
                &self.weights.final_norm.data,
                cfg.rms_eps,
                &mut xn[i * h..(i + 1) * h],
            );
        }
        matmul_prepacked_into(&xn, b, &self.packed_lm_head, &mut logits, &mut self.scratch);

        let samples = slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                if s.sample {
                    Some(argmax(&logits[i * vocab..(i + 1) * vocab]))
                } else {
                    None
                }
            })
            .collect();
        (samples, if keep_logits { logits } else { Vec::new() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Qwen3Engine;
    use crate::model::{Qwen3Config, Qwen3Weights};

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
    }

    #[test]
    fn paged_batch_of_one_matches_dense_engine() {
        let cfg = Qwen3Config::tiny();
        let w_dense = Qwen3Weights::random(&cfg, 99);
        let w_paged = Qwen3Weights::random(&cfg, 99);
        let mut dense = Qwen3Engine::new(w_dense, 1, 32);
        let mut be = BatchEngine::new(&w_paged, 8, 4);
        // Non-contiguous table: 3 blocks out of order.
        let table: Vec<u32> = vec![3, 0, 6];
        let tokens = [7usize, 300, 5, 42, 9, 1000];
        for (pos, &tok) in tokens.iter().enumerate() {
            let dense_logits = dense.decode_step(tok, pos);
            let slot = StepSlot { token: tok, pos, table: &table, sample: true };
            let (samples, paged_logits) = be.step_logits(&[slot], true);
            let diff = max_abs_diff(&dense_logits, &paged_logits);
            assert!(diff < 1e-6, "pos {pos}: paged vs dense logits differ by {diff}");
            assert_eq!(
                samples[0].unwrap(),
                crate::coordinator::argmax(&dense_logits),
                "pos {pos}: sampled token diverged"
            );
        }
    }

    #[test]
    fn batched_rows_do_not_interfere() {
        let cfg = Qwen3Config::tiny();
        let w_a = Qwen3Weights::random(&cfg, 5);
        let w_b = Qwen3Weights::random(&cfg, 5);
        let mut solo = BatchEngine::new(&w_a, 16, 4);
        let mut duo = BatchEngine::new(&w_b, 16, 4);
        let t1: Vec<u32> = vec![0, 1];
        let t2: Vec<u32> = vec![2, 3];
        let seq1 = [11usize, 22, 33];
        let seq2 = [500usize, 600, 700];
        // Solo: run seq1 alone.
        let mut solo_logits = Vec::new();
        for (pos, &tok) in seq1.iter().enumerate() {
            let (_, l) = solo.step_logits(
                &[StepSlot { token: tok, pos, table: &t1, sample: true }],
                true,
            );
            solo_logits = l;
        }
        // Duo: run seq1 batched with an unrelated seq2.
        let mut duo_logits = Vec::new();
        for pos in 0..seq1.len() {
            let slots = [
                StepSlot { token: seq1[pos], pos, table: &t1, sample: true },
                StepSlot { token: seq2[pos], pos, table: &t2, sample: true },
            ];
            let (_, l) = duo.step_logits(&slots, true);
            duo_logits = l;
        }
        let vocab = cfg.vocab;
        let diff = max_abs_diff(&solo_logits[..vocab], &duo_logits[..vocab]);
        assert!(diff < 1e-6, "batch companion changed a row's logits by {diff}");
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 1);
        let mut be = BatchEngine::new(&w, 2, 4);
        assert!(be.step(&[]).is_empty());
    }
}
