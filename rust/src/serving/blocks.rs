//! Paged KV-cache block pool: fixed-size blocks, per-sequence block
//! tables, free-list allocation, and refcounted prefix sharing.
//!
//! A *block* is `block_size` consecutive token positions of KV storage,
//! shared across all layers: physical block `b` owns rows
//! `[b*block_size, (b+1)*block_size)` of every layer's K and V arena.
//! A sequence maps logical positions to physical rows through its
//! [`BlockTable`]; nothing about a sequence's KV footprint is contiguous
//! or pre-reserved, so the pool admits many more sequences than a dense
//! per-request cache of the worst-case length would.
//!
//! Prefix sharing: a *full* block's contents are a pure function of the
//! tokens at positions `[0, (i+1)*block_size)` (each K/V row depends on
//! the whole prefix through attention, so the cache key is the entire
//! token prefix, not the block's own tokens). Sequences whose prompts
//! share such a prefix reference the same physical block, refcounted.
//! Only full blocks are ever shared — the active tail block is always
//! private — so no copy-on-write is needed: full blocks are immutable.

use std::collections::HashMap;

/// Free-list block pool with per-block reference counts.
#[derive(Debug)]
pub struct BlockPool {
    block_size: usize,
    refcount: Vec<u32>,
    free: Vec<u32>,
    max_in_use: usize,
}

impl BlockPool {
    pub fn new(num_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        BlockPool {
            block_size,
            refcount: vec![0; num_blocks],
            // Pop order: lowest block id first (purely cosmetic).
            free: (0..num_blocks as u32).rev().collect(),
            max_in_use: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.refcount.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.refcount.len() - self.free.len()
    }

    /// High-water mark of `blocks_in_use` over the pool's lifetime.
    pub fn max_in_use(&self) -> usize {
        self.max_in_use
    }

    /// Allocate a block with refcount 1, or `None` when the pool is dry.
    pub fn try_alloc(&mut self) -> Option<u32> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refcount[b as usize], 0);
        self.refcount[b as usize] = 1;
        self.max_in_use = self.max_in_use.max(self.blocks_in_use());
        Some(b)
    }

    /// Add a reference to an allocated block (prefix sharing).
    pub fn retain(&mut self, b: u32) {
        debug_assert!(self.refcount[b as usize] > 0, "retain of a free block");
        self.refcount[b as usize] += 1;
    }

    /// Drop a reference; returns true if the block went back on the
    /// free list.
    pub fn release(&mut self, b: u32) -> bool {
        let rc = &mut self.refcount[b as usize];
        debug_assert!(*rc > 0, "release of a free block");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(b);
            true
        } else {
            false
        }
    }

    pub fn refcount(&self, b: u32) -> u32 {
        self.refcount[b as usize]
    }

    /// Recovery-path invariant repair: force every block's refcount to
    /// `expected` and rebuild the free list to match. Used after a
    /// panicked SPMD epoch, when in-flight bookkeeping may have leaked
    /// references; never on the healthy path. Returns the audit deltas
    /// (all zero ⇔ the pool already satisfied `expected`).
    pub fn reconcile(&mut self, expected: &[u32]) -> BlockAudit {
        assert_eq!(expected.len(), self.refcount.len(), "audit must cover every block");
        let mut audit = BlockAudit::default();
        for (&want, have) in expected.iter().zip(self.refcount.iter_mut()) {
            if *have > want {
                audit.leaked_refs += (*have - want) as usize;
                if want == 0 {
                    audit.freed_blocks += 1;
                }
            } else if *have < want {
                audit.repaired_refs += (want - *have) as usize;
            }
            *have = want;
        }
        // Deterministic free order, same as `new`: lowest id pops first.
        self.free = (0..self.refcount.len() as u32)
            .rev()
            .filter(|&b| self.refcount[b as usize] == 0)
            .collect();
        audit
    }
}

/// What a refcount audit found (and repaired). All-zero means every
/// block's refcount already matched the live tables + prefix cache.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BlockAudit {
    /// References held above what live owners justify (dropped).
    pub leaked_refs: usize,
    /// Blocks returned to the free list by dropping leaked references.
    pub freed_blocks: usize,
    /// References that were *missing* (block freed while an owner still
    /// pointed at it) and were restored. Nonzero here means a real
    /// invariant break was healed, not just a leak.
    pub repaired_refs: usize,
}

impl BlockAudit {
    /// True when the audit found nothing to fix.
    pub fn clean(&self) -> bool {
        self.leaked_refs == 0 && self.freed_blocks == 0 && self.repaired_refs == 0
    }
}

/// A sequence's logical-position -> physical-block mapping.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    pub blocks: Vec<u32>,
}

impl BlockTable {
    /// Token positions this table can address.
    pub fn capacity_tokens(&self, block_size: usize) -> usize {
        self.blocks.len() * block_size
    }
}

/// One prefix-cache entry: the physical block plus its LRU stamp.
#[derive(Debug, Clone, Copy)]
struct PrefixEntry {
    block: u32,
    /// Monotone insertion/last-hit tick: eviction runs in ascending
    /// order of this stamp (deterministic LRU), never in HashMap order.
    last_touch: u64,
}

/// The block pool plus the prefix cache: the KV allocator the
/// continuous-batching scheduler talks to.
#[derive(Debug)]
pub struct KvBlockManager {
    pub pool: BlockPool,
    /// Full-block prefix -> physical block. The key is the *entire*
    /// token prefix covered by the block (see module docs). The cache
    /// holds its own reference on each entry so a cached block survives
    /// its originating sequence.
    prefix: HashMap<Vec<usize>, PrefixEntry>,
    /// LRU clock: bumped on every insert and every cache hit.
    clock: u64,
    /// Entry cap: key storage is O(prefix length) per entry, so an
    /// unbounded map would grow with every request served. At the cap,
    /// unreferenced entries are evicted; if everything is live, new
    /// registrations are skipped (sharing is an optimization, never a
    /// correctness requirement).
    max_entries: usize,
    /// Number of prompt blocks served from the cache.
    pub prefix_hits: usize,
}

impl KvBlockManager {
    pub fn new(num_blocks: usize, block_size: usize) -> Self {
        KvBlockManager {
            pool: BlockPool::new(num_blocks, block_size),
            prefix: HashMap::new(),
            clock: 0,
            // One entry per pool block is the most that can ever be
            // simultaneously useful.
            max_entries: num_blocks,
            prefix_hits: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.pool.block_size()
    }

    /// Reuse cached full blocks covering a prefix of `prompt`. Returns
    /// the (possibly empty) table of shared blocks and the number of
    /// positions they cover. Always leaves at least the final prompt
    /// token to compute, so the caller has logits to sample from.
    pub fn lookup_prefix(&mut self, prompt: &[usize]) -> (BlockTable, usize) {
        let bs = self.pool.block_size();
        let mut table = BlockTable::default();
        let mut covered = 0usize;
        while covered + bs < prompt.len() {
            let key = &prompt[..covered + bs];
            self.clock += 1;
            match self.prefix.get_mut(key) {
                Some(e) => {
                    e.last_touch = self.clock;
                    let b = e.block;
                    self.pool.retain(b);
                    table.blocks.push(b);
                    covered += bs;
                    self.prefix_hits += 1;
                }
                None => break,
            }
        }
        (table, covered)
    }

    /// Re-attach a single cached full block: `prefix` is the entire
    /// token prefix the block covers (the cache key). On a hit the
    /// block is retained (the caller now holds a reference), its LRU
    /// stamp is bumped, and the hit counter advances — the swap-in
    /// re-attach path (`ContinuousScheduler::admit_swapped`) uses this
    /// to adopt exact fp32 originals instead of fetching int8 copies.
    pub fn lookup_block(&mut self, prefix: &[usize]) -> Option<u32> {
        self.clock += 1;
        let e = self.prefix.get_mut(prefix)?;
        e.last_touch = self.clock;
        let b = e.block;
        self.pool.retain(b);
        self.prefix_hits += 1;
        Some(b)
    }

    /// Ensure `table` addresses position `pos`, allocating the next
    /// block if needed. Returns false when the pool is dry (caller
    /// preempts someone and retries).
    pub fn ensure_slot(&mut self, table: &mut BlockTable, pos: usize) -> bool {
        let bs = self.pool.block_size();
        while table.capacity_tokens(bs) <= pos {
            match self.pool.try_alloc() {
                Some(b) => table.blocks.push(b),
                None => return false,
            }
        }
        true
    }

    /// Register a just-filled full block for sharing. `prefix` is the
    /// whole token sequence covered by positions `[0, k*block_size)`
    /// where the block is `table.blocks[k-1]`. First writer wins; at
    /// the entry cap, unreferenced entries are evicted first and the
    /// registration is dropped if the cache is still full.
    pub fn register_full_block(&mut self, prefix: &[usize], block: u32) {
        debug_assert_eq!(prefix.len() % self.pool.block_size(), 0);
        if self.prefix.contains_key(prefix) {
            return;
        }
        if self.prefix.len() >= self.max_entries {
            self.evict_unused_cached();
        }
        if self.prefix.len() >= self.max_entries {
            return;
        }
        self.pool.retain(block);
        self.clock += 1;
        self.prefix.insert(prefix.to_vec(), PrefixEntry { block, last_touch: self.clock });
    }

    /// Release every block of a finished or preempted sequence.
    pub fn release_table(&mut self, table: &mut BlockTable) {
        for b in table.blocks.drain(..) {
            self.pool.release(b);
        }
    }

    /// Truncate `table` to the blocks covering its first `keep_tokens`
    /// positions, releasing every block past that prefix; returns how
    /// many blocks were released. The speculative-decode rollback
    /// primitive (`ContinuousScheduler::commit_verified` rewinds a
    /// sequence to its accepted prefix with this), also usable by any
    /// preemption edge that shortens a sequence instead of dropping it.
    ///
    /// Prefix-cache consistency: a released block that the cache
    /// registered survives via the cache's own reference — exactly like
    /// [`KvBlockManager::release_table`] at retirement. That is correct,
    /// not merely safe: full blocks are only ever registered for
    /// *committed* prefixes (commit registers boundaries as positions
    /// are accepted), so a cached block never contains rolled-back
    /// speculative rows and stays valid for future prefix hits.
    /// `keep_tokens = 0` empties the table (equivalent to
    /// `release_table`).
    pub fn truncate_table(&mut self, table: &mut BlockTable, keep_tokens: usize) -> usize {
        let bs = self.pool.block_size();
        let keep_blocks = keep_tokens.div_ceil(bs);
        let mut freed = 0;
        while table.blocks.len() > keep_blocks {
            let b = table.blocks.pop().expect("len > keep_blocks");
            self.pool.release(b);
            freed += 1;
        }
        freed
    }

    /// Under memory pressure: drop cache entries whose block no live
    /// sequence references (refcount 1 = cache only), in deterministic
    /// LRU order — least recently inserted/hit first. The order decides
    /// the free-list push order (and therefore every later allocation),
    /// so iterating the HashMap directly would make runs irreproducible.
    /// Returns how many blocks were freed.
    pub fn evict_unused_cached(&mut self) -> usize {
        let mut victims: Vec<(u64, u32)> = self
            .prefix
            .values()
            .filter(|e| self.pool.refcount(e.block) == 1)
            .map(|e| (e.last_touch, e.block))
            .collect();
        if victims.is_empty() {
            return 0;
        }
        victims.sort_unstable();
        for &(_, b) in &victims {
            self.pool.release(b);
        }
        // Released entries are now refcount 0; drop them from the map
        // (no key clones — the O(prefix-length) keys never leave it).
        let pool = &self.pool;
        self.prefix.retain(|_, e| pool.refcount(e.block) > 0);
        victims.len()
    }

    pub fn cached_blocks(&self) -> usize {
        self.prefix.len()
    }

    /// Audit every block's refcount against its justified owners — one
    /// reference per appearance in a `live` table plus one per prefix-
    /// cache entry — and repair any drift (leaked references dropped,
    /// missing references restored, free list rebuilt). The recovery
    /// step after a panicked serve epoch; on a healthy pool it returns
    /// a clean audit and changes nothing observable.
    pub fn audit_and_reclaim<'a>(
        &mut self,
        live: impl IntoIterator<Item = &'a BlockTable>,
    ) -> BlockAudit {
        let mut expected = vec![0u32; self.pool.num_blocks()];
        for t in live {
            for &b in &t.blocks {
                expected[b as usize] += 1;
            }
        }
        for e in self.prefix.values() {
            expected[e.block as usize] += 1;
        }
        self.pool.reconcile(&expected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut p = BlockPool::new(4, 8);
        assert_eq!(p.free_blocks(), 4);
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.blocks_in_use(), 2);
        assert!(p.release(a));
        assert_eq!(p.free_blocks(), 3);
        // Refcounted sharing: release drops to the free list only at 0.
        p.retain(b);
        assert!(!p.release(b));
        assert!(p.release(b));
        assert_eq!(p.free_blocks(), 4);
        assert_eq!(p.max_in_use(), 2);
    }

    #[test]
    fn pool_exhaustion() {
        let mut p = BlockPool::new(2, 4);
        assert!(p.try_alloc().is_some());
        assert!(p.try_alloc().is_some());
        assert!(p.try_alloc().is_none());
    }

    #[test]
    fn table_growth_via_manager() {
        let mut m = KvBlockManager::new(8, 4);
        let mut t = BlockTable::default();
        assert!(m.ensure_slot(&mut t, 0));
        assert_eq!(t.blocks.len(), 1);
        assert!(m.ensure_slot(&mut t, 3));
        assert_eq!(t.blocks.len(), 1, "position 3 still fits the first block");
        assert!(m.ensure_slot(&mut t, 4));
        assert_eq!(t.blocks.len(), 2);
        // Jumping ahead allocates every intermediate block.
        assert!(m.ensure_slot(&mut t, 15));
        assert_eq!(t.blocks.len(), 4);
        m.release_table(&mut t);
        assert_eq!(m.pool.free_blocks(), 8);
    }

    #[test]
    fn prefix_sharing_reuses_blocks() {
        let mut m = KvBlockManager::new(8, 4);
        let prompt: Vec<usize> = (0..9).collect(); // 2 full blocks + 1 token
        let (mut t1, covered) = m.lookup_prefix(&prompt);
        assert_eq!(covered, 0, "nothing cached yet");
        assert!(m.ensure_slot(&mut t1, 8));
        // Sequence 1 fills its first two blocks and registers them.
        m.register_full_block(&prompt[..4], t1.blocks[0]);
        m.register_full_block(&prompt[..8], t1.blocks[1]);

        let (t2, covered2) = m.lookup_prefix(&prompt);
        assert_eq!(covered2, 8, "both full blocks served from cache");
        assert_eq!(t2.blocks, t1.blocks[..2].to_vec());
        assert_eq!(m.prefix_hits, 2);
        assert_eq!(m.pool.refcount(t1.blocks[0]), 3); // seq1 + cache + seq2

        // A diverging prompt only shares the common full block.
        let mut other = prompt.clone();
        other[6] = 999;
        let (t3, covered3) = m.lookup_prefix(&other);
        assert_eq!(covered3, 4);
        assert_eq!(t3.blocks, vec![t1.blocks[0]]);
    }

    #[test]
    fn lookup_always_leaves_final_token() {
        let mut m = KvBlockManager::new(8, 4);
        let prompt: Vec<usize> = (0..8).collect(); // exactly 2 blocks
        let (mut t1, _) = m.lookup_prefix(&prompt);
        assert!(m.ensure_slot(&mut t1, 7));
        m.register_full_block(&prompt[..4], t1.blocks[0]);
        m.register_full_block(&prompt[..8], t1.blocks[1]);
        let (_, covered) = m.lookup_prefix(&prompt);
        assert_eq!(covered, 4, "the final prompt token must stay computable");
    }

    #[test]
    fn cache_eviction_is_deterministic_lru() {
        // Three cached, unreferenced blocks with distinct last-hit times:
        // eviction must release them least-recently-touched first, so the
        // free-list order (and every later allocation) is reproducible.
        let mut m = KvBlockManager::new(8, 2);
        let prompts: Vec<Vec<usize>> = (0..3).map(|i| vec![100 + i, 200 + i, 300 + i]).collect();
        let mut blocks = Vec::new();
        for p in &prompts {
            let mut t = BlockTable::default();
            assert!(m.ensure_slot(&mut t, 1));
            m.register_full_block(&p[..2], t.blocks[0]);
            blocks.push(t.blocks[0]);
            m.release_table(&mut t);
        }
        // Touch the *first* entry so it becomes most-recently-used.
        let (mut t0, covered) = m.lookup_prefix(&prompts[0]);
        assert_eq!(covered, 2);
        m.release_table(&mut t0);
        assert_eq!(m.evict_unused_cached(), 3);
        // LRU order: entries 1 and 2 (insertion order) first, then the
        // re-touched entry 0. Free list is a stack, so allocation pops in
        // reverse: blocks[0], blocks[2], blocks[1].
        assert_eq!(m.pool.try_alloc(), Some(blocks[0]));
        assert_eq!(m.pool.try_alloc(), Some(blocks[2]));
        assert_eq!(m.pool.try_alloc(), Some(blocks[1]));
    }

    #[test]
    fn lookup_block_reattaches_single_blocks() {
        let mut m = KvBlockManager::new(8, 4);
        let prompt: Vec<usize> = (0..8).collect();
        let (mut t1, _) = m.lookup_prefix(&prompt);
        assert!(m.ensure_slot(&mut t1, 7));
        m.register_full_block(&prompt[..4], t1.blocks[0]);
        m.register_full_block(&prompt[..8], t1.blocks[1]);
        let b1 = t1.blocks[1];
        m.release_table(&mut t1);
        // Second (non-leading) block re-attaches on its own: the key is
        // the whole covered prefix, not a position.
        assert_eq!(m.lookup_block(&prompt[..8]), Some(b1));
        assert_eq!(m.pool.refcount(b1), 2, "re-attach must retain");
        assert_eq!(m.prefix_hits, 1);
        assert_eq!(m.lookup_block(&prompt[..5]), None, "non-boundary prefix misses");
        // A re-attached block survives eviction (it is referenced).
        assert_eq!(m.evict_unused_cached(), 1, "only the unreferenced first block frees");
        assert_eq!(m.lookup_block(&prompt[..8]), Some(b1), "still cached while referenced");
    }

    #[test]
    fn audit_is_clean_on_a_healthy_pool() {
        let mut m = KvBlockManager::new(8, 4);
        let prompt: Vec<usize> = (0..9).collect();
        let (mut t1, _) = m.lookup_prefix(&prompt);
        assert!(m.ensure_slot(&mut t1, 8));
        m.register_full_block(&prompt[..4], t1.blocks[0]);
        let free_before = m.pool.free_blocks();
        let audit = m.audit_and_reclaim([&t1]);
        assert!(audit.clean(), "{audit:?}");
        assert_eq!(m.pool.free_blocks(), free_before);
        // The cached block still serves hits after the audit.
        m.release_table(&mut t1);
        let (_, covered) = m.lookup_prefix(&prompt);
        assert_eq!(covered, 4);
    }

    #[test]
    fn audit_reclaims_leaked_and_restores_missing_refs() {
        let mut m = KvBlockManager::new(8, 4);
        let mut t = BlockTable::default();
        assert!(m.ensure_slot(&mut t, 11)); // 3 blocks
        // Leak: drop the table's claim on its last block without
        // releasing — the audit must free it.
        let leaked = t.blocks.pop().unwrap();
        // Break the other way: free a block the table still references.
        m.pool.release(t.blocks[1]);
        let audit = m.audit_and_reclaim([&t]);
        assert_eq!(audit.leaked_refs, 1);
        assert_eq!(audit.freed_blocks, 1);
        assert_eq!(audit.repaired_refs, 1);
        assert_eq!(m.pool.refcount(leaked), 0);
        assert_eq!(m.pool.refcount(t.blocks[1]), 1, "missing ref restored");
        assert_eq!(m.pool.free_blocks(), 8 - t.blocks.len());
        // Fully recovered: a fresh audit is clean and release balances.
        assert!(m.audit_and_reclaim([&t]).clean());
        m.release_table(&mut t);
        assert_eq!(m.pool.free_blocks(), 8);
    }

    #[test]
    fn truncate_table_releases_only_past_the_kept_prefix() {
        let mut m = KvBlockManager::new(8, 4);
        let mut t = BlockTable::default();
        assert!(m.ensure_slot(&mut t, 15)); // 4 blocks, 16 positions
        assert_eq!(t.blocks.len(), 4);
        // Keeping 9 tokens needs ceil(9/4) = 3 blocks: exactly one frees.
        assert_eq!(m.truncate_table(&mut t, 9), 1);
        assert_eq!(t.blocks.len(), 3);
        assert_eq!(m.pool.free_blocks(), 8 - 3);
        // A no-op truncation (already covered) frees nothing.
        assert_eq!(m.truncate_table(&mut t, 12), 0);
        assert_eq!(t.blocks.len(), 3);
        // Keeping a partial block keeps the whole block (positions are
        // block-granular; the tail block's extra rows are overwritten
        // before they are ever read).
        assert_eq!(m.truncate_table(&mut t, 5), 1);
        assert_eq!(t.blocks.len(), 2);
        // keep 0 empties the table like release_table.
        assert_eq!(m.truncate_table(&mut t, 0), 2);
        assert!(t.blocks.is_empty());
        assert_eq!(m.pool.free_blocks(), 8);
        // The pool audit is clean after the rollbacks.
        assert!(m.audit_and_reclaim([&t]).clean());
    }

    #[test]
    fn truncate_table_keeps_cache_registrations_alive() {
        let mut m = KvBlockManager::new(8, 4);
        let prompt: Vec<usize> = (0..9).collect();
        let (mut t, _) = m.lookup_prefix(&prompt);
        assert!(m.ensure_slot(&mut t, 8)); // 3 blocks
        m.register_full_block(&prompt[..4], t.blocks[0]);
        m.register_full_block(&prompt[..8], t.blocks[1]);
        let b1 = t.blocks[1];
        // Roll back to 5 tokens: blocks 2 and 3 leave the table, but
        // block 1 was registered — the cache's own reference keeps it
        // allocated and serving hits.
        assert_eq!(m.truncate_table(&mut t, 5), 1);
        assert_eq!(t.blocks.len(), 2);
        assert_eq!(m.pool.refcount(b1), 2, "table + cache");
        assert_eq!(m.truncate_table(&mut t, 4), 1);
        assert_eq!(m.pool.refcount(b1), 1, "cache only — still alive");
        assert_eq!(m.lookup_block(&prompt[..8]), Some(b1), "registration survives");
        m.pool.release(b1); // drop the lookup's reference again
        assert!(m.audit_and_reclaim([&t]).clean());
        m.release_table(&mut t);
        assert_eq!(m.evict_unused_cached(), 2);
        assert_eq!(m.pool.free_blocks(), 8, "full round trip balances");
    }

    #[test]
    fn cache_eviction_frees_only_unreferenced() {
        let mut m = KvBlockManager::new(4, 4);
        let prompt: Vec<usize> = (0..5).collect();
        let (mut t1, _) = m.lookup_prefix(&prompt);
        assert!(m.ensure_slot(&mut t1, 4));
        m.register_full_block(&prompt[..4], t1.blocks[0]);
        // Block 0 is held by seq1 + cache: eviction must not free it.
        assert_eq!(m.evict_unused_cached(), 0);
        assert_eq!(m.cached_blocks(), 1);
        m.release_table(&mut t1);
        // Now only the cache holds it.
        assert_eq!(m.evict_unused_cached(), 1);
        assert_eq!(m.cached_blocks(), 0);
        assert_eq!(m.pool.free_blocks(), 4);
    }
}
