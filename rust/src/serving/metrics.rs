//! Serving metrics: per-request TTFT/TPOT, queue depth, pool occupancy
//! and preemption counters (extends [`crate::coordinator::ServeReport`]
//! for the continuous-batching path).

use crate::util::Stats;

/// Aggregate metrics of one continuous-batching serve run.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    /// Time-to-first-token per request, seconds (submission -> first
    /// sampled token).
    pub ttft: Stats,
    /// Time-per-output-token across decode iterations, seconds.
    pub tpot: Stats,
    /// End-to-end latency per request, seconds (submission -> finish).
    /// TTFT bounds the head of a request; this is the whole-request
    /// tail the SLO story needs.
    pub request_e2e: Stats,
    /// Queue depth sampled once per scheduler iteration.
    pub queue_depth: Stats,
    /// Running batch size sampled once per scheduler iteration.
    pub batch_size: Stats,
    /// Pool occupancy (fraction of blocks in use) per iteration.
    pub pool_occupancy: Stats,
    /// Sequences preempted back to the queue on pool exhaustion.
    pub preemptions: usize,
    /// Prompt blocks served from the prefix cache.
    pub prefix_hits: usize,
    /// High-water mark of blocks in use.
    pub peak_blocks_in_use: usize,
    /// Scheduler iterations executed.
    pub iterations: usize,
    /// Total seconds spent in iterations attributed to decode tokens —
    /// including time spent *replaying* already-sampled tokens after a
    /// recompute-preemption, so recompute waste shows up as lower decode
    /// throughput instead of hiding in wall time.
    pub decode_s: f64,
    /// Distinct decode tokens covered by `decode_s` (frontier samples;
    /// replayed positions are counted in `replay_steps` instead).
    pub decode_steps: usize,
    /// Already-sampled tokens recomputed after recompute-preemptions.
    pub replay_steps: usize,
    /// Total seconds spent in iterations attributed to prompt (prefill)
    /// rows — chunked prefill's win shows up here as higher prefill
    /// throughput, not hidden wall time.
    pub prefill_s: f64,
    /// Prompt positions computed (one per prefill row; replayed prompt
    /// positions after a recompute-preemption count again — they cost
    /// again).
    pub prefill_steps: usize,
    /// Span length of every prefilling sequence per iteration (chunked
    /// prefill's actual packing; all-1 at `prefill_chunk = 1`).
    pub chunk_size: Stats,
    /// Iterations whose step carried no prompt rows (pure decode).
    /// Their mean wall time is directly comparable to the serve plan's
    /// per-iteration decode roofline prediction.
    pub decode_only_iters: usize,
    /// Wall seconds summed over the decode-only iterations.
    pub decode_only_s: f64,
    /// Iterations whose step carried at least one prompt row.
    pub prefill_iters: usize,
    /// Wall seconds summed over the prefill-carrying iterations.
    pub prefill_iters_s: f64,
    /// Cold blocks re-attached from the prefix cache on swap-in instead
    /// of being fetched (exact fp32, zero bytes moved).
    pub swap_reattached: usize,
    /// True when the run had a cold tier configured (`tiering: Some`).
    pub tiered: bool,
    /// Preemptions resolved by swapping the victim to the cold tier.
    pub swap_preemptions: usize,
    /// Preemptions resolved by discarding KV and recomputing (the only
    /// kind that exists when tiering is off).
    pub recompute_preemptions: usize,
    /// Blocks spilled hot -> cold.
    pub spills: usize,
    /// Blocks fetched cold -> hot.
    pub fetches: usize,
    /// Payload bytes moved hot -> cold.
    pub spill_bytes: u64,
    /// Payload bytes moved cold -> hot.
    pub fetch_bytes: u64,
    /// Swap-ins that kept full blocks cold for direct dequant-gather
    /// reads instead of fetching them.
    pub cold_direct_reads: usize,
    /// Cold-tier occupancy (fraction of slots in use) per iteration.
    pub cold_occupancy: Stats,
    /// High-water mark of cold slots in use.
    pub peak_cold_in_use: usize,
    /// Simulated seconds of tier traffic under the cost model
    /// (bandwidth + latency of the machine's cold tier); advisory —
    /// never added to wall time.
    pub tier_sim_s: f64,
    /// Requests refused at submission by admission backpressure
    /// (bounded queue full, or dead on arrival past their deadline).
    pub rejected: usize,
    /// Requests cancelled — queued or running — because their
    /// deadline passed before they finished.
    pub deadline_missed: usize,
    /// Sequences rolled back to a committed KV boundary and requeued
    /// by fault recovery (epoch-restart audits and cold-tier
    /// integrity reclassifications).
    pub fault_requeued: usize,
    /// Blocks the epoch-restart audit found leaked (refcount above the
    /// surviving references) and reclaimed. Always 0 in a healthy build
    /// — recovery releases everything explicitly; non-zero means the
    /// audit caught and repaired an invariant violation.
    pub fault_leaked_blocks: usize,
    /// Cold blocks whose FNV payload checksum failed verification
    /// (fetch or direct-read audit); each one reclassified its owner
    /// swap -> recompute instead of serving corrupt KV.
    pub cold_checksum_failures: usize,
    /// `(request id, generated-token index)` of each sequence's first
    /// resume over lossy (quantized) KV: output tokens before the index
    /// are exact; divergence from the oracle is possible only at or
    /// after it. Empty for lossless (f32) tiers.
    pub swap_points: Vec<(u64, usize)>,
    /// True when the run had self-drafting speculation configured
    /// (`spec_k > 0`) — gates the spec segment of `render` like
    /// `tiered` gates the tier segment.
    pub spec_enabled: bool,
    /// Speculative verify steps committed (iterations in which a
    /// sequence carried a `[sampled, drafts..]` span).
    pub spec_steps: usize,
    /// Draft tokens proposed by the self-drafter across all spec steps.
    pub spec_drafted: usize,
    /// Draft tokens accepted (they matched the model's own argmax and
    /// were emitted without costing a weight-streaming step of their
    /// own).
    pub spec_accepted: usize,
    /// Draft tokens rejected and rolled back (their verify rows are the
    /// price of speculating; `spec_drafted == spec_accepted +
    /// spec_rejected`).
    pub spec_rejected: usize,
}

impl ServingMetrics {
    /// Decode throughput over the directly-accumulated decode seconds
    /// (never derived from `mean * count`; all the percentile calls are
    /// safe on empty series — see `Stats`).
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.decode_steps as f64 / self.decode_s
        } else {
            0.0
        }
    }

    /// Prefill throughput over the directly-accumulated prefill seconds
    /// (prompt rows per second; 0.0 when nothing prefilled or timing
    /// was too coarse to register).
    pub fn prefill_tokens_per_s(&self) -> f64 {
        if self.prefill_s > 0.0 {
            self.prefill_steps as f64 / self.prefill_s
        } else {
            0.0
        }
    }

    /// Mean wall time of a decode-only iteration (seconds; 0.0 when
    /// none ran) — the measured side of the predicted-vs-measured line
    /// in `ServeReport` (the plan predicts per-iteration decode cost).
    pub fn decode_iter_mean_s(&self) -> f64 {
        if self.decode_only_iters > 0 {
            self.decode_only_s / self.decode_only_iters as f64
        } else {
            0.0
        }
    }

    /// Mean wall time of a prefill-carrying iteration (seconds; 0.0
    /// when none ran).
    pub fn prefill_iter_mean_s(&self) -> f64 {
        if self.prefill_iters > 0 {
            self.prefill_iters_s / self.prefill_iters as f64
        } else {
            0.0
        }
    }

    /// Tokens emitted per speculative verify step (each step emits its
    /// accepted drafts plus the bonus argmax, so > 1.0 means
    /// speculation is amortizing the weight stream; exactly 1.0 means
    /// every draft was rejected). 0.0 when no spec step ran.
    pub fn accepted_tokens_per_step(&self) -> f64 {
        if self.spec_steps > 0 {
            (self.spec_steps + self.spec_accepted) as f64 / self.spec_steps as f64
        } else {
            0.0
        }
    }

    /// Fraction of proposed drafts that were accepted (0.0 when nothing
    /// was drafted).
    pub fn accept_rate(&self) -> f64 {
        if self.spec_drafted > 0 {
            self.spec_accepted as f64 / self.spec_drafted as f64
        } else {
            0.0
        }
    }

    /// The nullable spec section of `ServeReport` (`Some` iff
    /// speculation was configured, mirroring how `faults` reports):
    /// counters plus the derived rates, stamped with the `spec_k` the
    /// run used.
    pub fn spec_summary(&self, spec_k: usize) -> Option<SpecSummary> {
        (spec_k > 0).then(|| SpecSummary {
            spec_k,
            steps: self.spec_steps,
            drafted: self.spec_drafted,
            accepted: self.spec_accepted,
            rejected: self.spec_rejected,
            accept_rate: self.accept_rate(),
            accepted_tokens_per_step: self.accepted_tokens_per_step(),
        })
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "ttft p50={:.2}ms tpot p50={:.2}ms req e2e p50={:.2}ms p99={:.2}ms \
             batch mean={:.1} queue mean={:.1} \
             pool peak={} blocks preempt={} prefix_hits={} iters={}",
            self.ttft.percentile(50.0) * 1e3,
            self.tpot.percentile(50.0) * 1e3,
            self.request_e2e.percentile(50.0) * 1e3,
            self.request_e2e.p99() * 1e3,
            self.batch_size.mean(),
            self.queue_depth.mean(),
            self.peak_blocks_in_use,
            self.preemptions,
            self.prefix_hits,
            self.iterations,
        );
        if self.prefill_steps > 0 {
            s.push_str(&format!(
                " prefill={:.2} tok/s chunk mean={:.1} max={:.0}",
                self.prefill_tokens_per_s(),
                self.chunk_size.mean(),
                self.chunk_size.max(),
            ));
        }
        if self.rejected > 0 || self.deadline_missed > 0 || self.fault_requeued > 0 {
            s.push_str(&format!(
                " | robustness rejected={} deadline_missed={} requeued={}",
                self.rejected, self.deadline_missed, self.fault_requeued,
            ));
        }
        if self.spec_enabled {
            s.push_str(&format!(
                " | spec steps={} drafted={} accepted={} rejected={} accept_rate={:.2} \
                 tok/step={:.2}",
                self.spec_steps,
                self.spec_drafted,
                self.spec_accepted,
                self.spec_rejected,
                self.accept_rate(),
                self.accepted_tokens_per_step(),
            ));
        }
        if self.tiered {
            s.push_str(&format!(
                " | tier swap={} recompute={} spill={}B/{} fetch={}B/{} reattach={} direct={} \
                 cold peak={} sim={:.2}ms replay={} checksum_fail={}",
                self.swap_preemptions,
                self.recompute_preemptions,
                self.spill_bytes,
                self.spills,
                self.fetch_bytes,
                self.fetches,
                self.swap_reattached,
                self.cold_direct_reads,
                self.peak_cold_in_use,
                self.tier_sim_s * 1e3,
                self.replay_steps,
                self.cold_checksum_failures,
            ));
        }
        s
    }
}

/// The `spec` section of `ServeReport` (`serve_report.v1`): counters
/// and derived rates of a self-drafting speculative run. `None` in the
/// report when speculation is off, so the JSON shape stays stable.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecSummary {
    /// The configured max drafts per slot per iteration.
    pub spec_k: usize,
    /// Speculative verify steps committed.
    pub steps: usize,
    /// Draft tokens proposed.
    pub drafted: usize,
    /// Draft tokens accepted.
    pub accepted: usize,
    /// Draft tokens rejected and rolled back.
    pub rejected: usize,
    /// `accepted / drafted` (0.0 when nothing drafted).
    pub accept_rate: f64,
    /// Tokens emitted per spec step (> 1.0 = the weight stream is being
    /// amortized).
    pub accepted_tokens_per_step: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_render_without_nan() {
        let m = ServingMetrics::default();
        assert_eq!(m.decode_tokens_per_s(), 0.0);
        let s = m.render();
        assert!(!s.contains("NaN"), "render must survive empty series: {s}");
    }

    #[test]
    fn decode_throughput_from_accumulated_seconds() {
        let m = ServingMetrics { decode_s: 2.0, decode_steps: 100, ..Default::default() };
        assert_eq!(m.decode_tokens_per_s(), 50.0);
    }

    #[test]
    fn prefill_throughput_from_accumulated_seconds() {
        let mut m =
            ServingMetrics { prefill_s: 0.5, prefill_steps: 200, ..Default::default() };
        m.chunk_size.push(4.0);
        assert_eq!(m.prefill_tokens_per_s(), 400.0);
        let s = m.render();
        assert!(s.contains("prefill=400.00 tok/s"), "{s}");
        assert!(s.contains("chunk mean=4.0"), "{s}");
        // No prefill rows -> the segment stays out of the render.
        let idle = ServingMetrics::default();
        assert_eq!(idle.prefill_tokens_per_s(), 0.0);
        assert!(!idle.render().contains("prefill="));
    }

    #[test]
    fn request_e2e_renders_p50_and_p99() {
        let mut m = ServingMetrics::default();
        for i in 1..=100 {
            m.request_e2e.push(i as f64 * 1e-3);
        }
        let s = m.render();
        assert!(s.contains("req e2e p50=50.00ms"), "{s}");
        assert!(s.contains("p99=99.00ms"), "{s}");
    }

    #[test]
    fn iteration_mix_means() {
        let m = ServingMetrics {
            decode_only_iters: 4,
            decode_only_s: 0.2,
            prefill_iters: 2,
            prefill_iters_s: 0.5,
            ..Default::default()
        };
        assert!((m.decode_iter_mean_s() - 0.05).abs() < 1e-12);
        assert!((m.prefill_iter_mean_s() - 0.25).abs() < 1e-12);
        assert_eq!(ServingMetrics::default().decode_iter_mean_s(), 0.0);
    }

    #[test]
    fn robustness_counters_render_only_when_nonzero() {
        let calm = ServingMetrics::default();
        assert!(!calm.render().contains("robustness"), "calm runs stay quiet");
        let m = ServingMetrics {
            rejected: 2,
            deadline_missed: 1,
            fault_requeued: 3,
            ..Default::default()
        };
        let s = m.render();
        assert!(s.contains("robustness rejected=2 deadline_missed=1 requeued=3"), "{s}");
    }

    #[test]
    fn spec_rates_and_summary() {
        let m = ServingMetrics {
            spec_enabled: true,
            spec_steps: 10,
            spec_drafted: 30,
            spec_accepted: 24,
            spec_rejected: 6,
            ..Default::default()
        };
        assert!((m.accept_rate() - 0.8).abs() < 1e-12);
        assert!((m.accepted_tokens_per_step() - 3.4).abs() < 1e-12);
        let s = m.render();
        assert!(s.contains("spec steps=10 drafted=30 accepted=24 rejected=6"), "{s}");
        assert!(s.contains("accept_rate=0.80"), "{s}");
        let sum = m.spec_summary(4).expect("spec_k > 0 must produce a summary");
        assert_eq!(sum.spec_k, 4);
        assert_eq!(sum.accepted, 24);
        assert!((sum.accepted_tokens_per_step - 3.4).abs() < 1e-12);
        assert!(m.spec_summary(0).is_none(), "spec off: the report section stays null");
        // Spec-off runs keep the render segment out entirely.
        let off = ServingMetrics::default();
        assert_eq!(off.accepted_tokens_per_step(), 0.0);
        assert_eq!(off.accept_rate(), 0.0);
        assert!(!off.render().contains("spec "), "{}", off.render());
    }

    #[test]
    fn tier_counters_render_only_when_tiered() {
        let flat = ServingMetrics::default();
        assert!(!flat.render().contains("tier"), "flat pools must not render tier counters");
        let m = ServingMetrics {
            tiered: true,
            swap_preemptions: 3,
            spills: 7,
            spill_bytes: 1024,
            fetches: 7,
            fetch_bytes: 1024,
            ..Default::default()
        };
        let s = m.render();
        assert!(s.contains("tier swap=3"), "{s}");
        assert!(s.contains("spill=1024B/7"), "{s}");
    }
}
