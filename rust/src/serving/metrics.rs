//! Serving metrics: per-request TTFT/TPOT, queue depth, pool occupancy
//! and preemption counters (extends [`crate::coordinator::ServeReport`]
//! for the continuous-batching path).

use crate::util::Stats;

/// Aggregate metrics of one continuous-batching serve run.
#[derive(Debug, Default)]
pub struct ServingMetrics {
    /// Time-to-first-token per request, seconds (submission -> first
    /// sampled token).
    pub ttft: Stats,
    /// Time-per-output-token across decode iterations, seconds.
    pub tpot: Stats,
    /// Queue depth sampled once per scheduler iteration.
    pub queue_depth: Stats,
    /// Running batch size sampled once per scheduler iteration.
    pub batch_size: Stats,
    /// Pool occupancy (fraction of blocks in use) per iteration.
    pub pool_occupancy: Stats,
    /// Sequences preempted back to the queue on pool exhaustion.
    pub preemptions: usize,
    /// Prompt blocks served from the prefix cache.
    pub prefix_hits: usize,
    /// High-water mark of blocks in use.
    pub peak_blocks_in_use: usize,
    /// Scheduler iterations executed.
    pub iterations: usize,
    /// Total seconds spent in iterations attributed to decode tokens.
    pub decode_s: f64,
    /// Decode tokens covered by `decode_s`.
    pub decode_steps: usize,
}

impl ServingMetrics {
    /// Decode throughput over the directly-accumulated decode seconds
    /// (never derived from `mean * count`; all the percentile calls are
    /// safe on empty series — see `Stats`).
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.decode_steps as f64 / self.decode_s
        } else {
            0.0
        }
    }

    pub fn render(&self) -> String {
        format!(
            "ttft p50={:.2}ms tpot p50={:.2}ms batch mean={:.1} queue mean={:.1} \
             pool peak={} blocks preempt={} prefix_hits={} iters={}",
            self.ttft.percentile(50.0) * 1e3,
            self.tpot.percentile(50.0) * 1e3,
            self.batch_size.mean(),
            self.queue_depth.mean(),
            self.peak_blocks_in_use,
            self.preemptions,
            self.prefix_hits,
            self.iterations,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_render_without_nan() {
        let m = ServingMetrics::default();
        assert_eq!(m.decode_tokens_per_s(), 0.0);
        let s = m.render();
        assert!(!s.contains("NaN"), "render must survive empty series: {s}");
    }

    #[test]
    fn decode_throughput_from_accumulated_seconds() {
        let m = ServingMetrics { decode_s: 2.0, decode_steps: 100, ..Default::default() };
        assert_eq!(m.decode_tokens_per_s(), 50.0);
    }
}
