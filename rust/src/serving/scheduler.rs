//! Continuous-batching scheduler: admission control, iteration-level
//! batching of prefill + decode, and recency-based preemption-to-queue
//! when the block pool is exhausted.
//!
//! Sequence lifecycle: `Queued -> Prefill -> Decode -> Done`, with
//! `-> Preempted -> (queue front) -> Prefill` under memory pressure.
//! Every scheduler iteration advances each running sequence by exactly
//! one position — a prompt token while prefilling (chunked prefill with
//! chunk 1), the last sampled token while decoding — so prefill and
//! decode tokens share the same batched forward pass and a finished
//! sequence's slot is refilled on the very next iteration instead of at
//! batch boundaries.
//!
//! Preemption has two modes. *Recompute* (the only mode when tiering is
//! off): the victim's blocks are released (its full blocks may survive
//! in the prefix cache and be re-attached for free) and the sequence
//! re-enters the queue front; greedy decode is deterministic, so
//! recomputation reproduces the same tokens and preemption is invisible
//! in the output stream — the differential test against the FCFS oracle
//! exercises exactly this. *Swap* (`ContinuousConfig::tiering`): the
//! victim's blocks are spilled to the quantized cold tier
//! ([`crate::serving::tiered`]) and fetched back on re-admission with
//! position and sampled tokens intact — no replay — governed by the
//! swap-vs-recompute cost model. The int8 tier is lossy: a swapped-back
//! sequence is *tainted* (its blocks never enter the prefix cache) and
//! its first resume point is recorded in `ServingMetrics::swap_points`,
//! bounding where divergence from the oracle may start.

use std::collections::VecDeque;
use std::time::Instant;

use super::blocks::{BlockTable, KvBlockManager};
use super::metrics::ServingMetrics;
use super::tiered::{SwapPolicy, TierConfig, TierOp, TierState};
use crate::coordinator::Request;

/// Scheduler state of one sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    Queued,
    Prefill,
    Decode,
    Preempted,
    /// Preempted with KV resident in the cold tier (swap-based
    /// preemption): re-admission fetches instead of recomputing.
    Swapped,
    Done,
}

/// One request being served.
#[derive(Debug)]
pub struct Sequence {
    pub id: u64,
    /// Tokens fed (or about to be fed) to the model: the prompt plus
    /// every sampled token except the final one.
    pub tokens: Vec<usize>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub table: BlockTable,
    /// Next position to compute.
    pub pos: usize,
    pub generated: Vec<usize>,
    pub state: SeqState,
    /// Iteration at which the sequence last entered the running set
    /// (preemption victims are chosen by recency of admission, so the
    /// oldest work is protected).
    pub admitted_iter: u64,
    /// Cold-tier slots of the sequence's *leading* logical blocks, in
    /// logical order. While running this is the direct-read prefix (the
    /// engine's hybrid attention reads these slots in place); while
    /// `Swapped` it covers every block the sequence had.
    pub cold: Vec<u32>,
    /// Set once the sequence has attended over quantized (lossy) KV:
    /// its blocks are no longer a pure function of their token prefix
    /// and must never enter the prefix cache.
    pub tainted: bool,
    /// Generated-token index of the first lossy resume (`None` until a
    /// quantized swap-in): earlier outputs are exact.
    pub swap_in_at: Option<usize>,
    /// Lossy swap-in admitted this iteration, not yet stepped — becomes
    /// `tainted` at the next commit (a same-iteration revert clears it).
    resume_lossy: bool,
    /// The pending lossy swap-in kept full blocks cold (direct read);
    /// counted into `cold_direct_reads` when the resume actually steps,
    /// so a same-iteration revert + retry is not double-counted.
    resume_direct: bool,
    submitted: Instant,
}

impl Sequence {
    /// True when `pos` is the last fed token: sample logits here.
    pub fn at_frontier(&self) -> bool {
        self.pos + 1 == self.tokens.len()
    }

    /// Token positions held by the cold prefix.
    pub fn cold_tokens(&self, block_size: usize) -> usize {
        self.cold.len() * block_size
    }
}

/// Knobs of the continuous-batching serving path.
#[derive(Debug, Clone)]
pub struct ContinuousConfig {
    /// Token positions per KV block.
    pub block_size: usize,
    /// Physical blocks in the pool (all layers share block indices).
    pub num_blocks: usize,
    /// Maximum sequences batched per iteration.
    pub max_batch: usize,
    /// SPMD worker threads of the batched decode engine. The engine
    /// clamps to `[1, max_batch]` (workers own whole batch rows); the
    /// static partition keeps outputs token-identical at any value.
    /// Pick from the machine with [`crate::cost::MachineSpec::decode_threads`].
    pub threads: usize,
    /// Tiered KV storage (`None` = flat fp32 pool; the scheduler is then
    /// bitwise-identical to the pre-tiering behaviour, which the FCFS
    /// differential oracle enforces).
    pub tiering: Option<TierConfig>,
}

impl Default for ContinuousConfig {
    fn default() -> Self {
        ContinuousConfig {
            block_size: 16,
            num_blocks: 512,
            max_batch: 8,
            threads: 1,
            tiering: None,
        }
    }
}

impl ContinuousConfig {
    /// Size the pool from a machine's memory model: KV blocks get what
    /// is left after the weights ([`crate::cost::MachineSpec::kv_block_budget`]),
    /// further capped in proportion to the batch (64 blocks — 1024
    /// token positions at the default block size — per concurrent
    /// sequence) so a small demo on a big machine does not zero a
    /// multi-hundred-megabyte arena it will never touch.
    pub fn for_machine(
        model: &crate::model::Qwen3Config,
        machine: &crate::cost::MachineSpec,
        max_batch: usize,
    ) -> Self {
        let block_size = 16usize;
        let block_bytes = model.kv_bytes_per_token() * block_size as u64;
        let budget = machine.kv_block_budget(model.weight_bytes(), block_bytes);
        let workload_cap = (max_batch.max(1) * 64) as u64;
        ContinuousConfig {
            block_size,
            num_blocks: budget.min(workload_cap).max(1) as usize,
            max_batch,
            threads: machine.decode_threads(max_batch),
            tiering: None,
        }
    }
}

/// The continuous-batching scheduler.
pub struct ContinuousScheduler {
    pub config: ContinuousConfig,
    queue: VecDeque<Sequence>,
    running: Vec<Sequence>,
    pub kv: KvBlockManager,
    /// Cold-tier control plane (`Some` iff `config.tiering` is).
    pub tier: Option<TierState>,
    pub metrics: ServingMetrics,
    iter: u64,
    finished: Vec<Sequence>,
}

impl ContinuousScheduler {
    pub fn new(config: ContinuousConfig) -> Self {
        let kv = KvBlockManager::new(config.num_blocks, config.block_size);
        let tier = config.tiering.clone().map(TierState::new);
        let metrics = ServingMetrics { tiered: tier.is_some(), ..Default::default() };
        ContinuousScheduler {
            config,
            queue: VecDeque::new(),
            running: Vec::new(),
            kv,
            tier,
            metrics,
            iter: 0,
            finished: Vec::new(),
        }
    }

    /// Wire the model geometry into the tier's byte accounting (called
    /// by the serving coordinator; safe no-op without tiering).
    pub fn set_tier_geometry(&mut self, layers: usize, width: usize) {
        if let Some(t) = self.tier.as_mut() {
            t.set_geometry(layers, width);
        }
    }

    /// Drain the data-movement ops of the last `schedule()` call for the
    /// engine (`BatchStepper::tier_ops`), accounting byte counters and
    /// the simulated transfer cost. Must run before the step executes.
    pub fn take_tier_ops(&mut self) -> Vec<TierOp> {
        let Some(tier) = self.tier.as_mut() else { return Vec::new() };
        let ops = std::mem::take(&mut tier.pending);
        let (mut spill_bytes, mut fetch_bytes) = (0u64, 0u64);
        for op in &ops {
            match *op {
                TierOp::Spill { filled, .. } => {
                    self.metrics.spills += 1;
                    spill_bytes += tier.payload_bytes(filled);
                }
                TierOp::Fetch { cold, .. } => {
                    self.metrics.fetches += 1;
                    fetch_bytes += tier.payload_bytes(tier.filled(cold));
                }
            }
        }
        self.metrics.spill_bytes += spill_bytes;
        self.metrics.fetch_bytes += fetch_bytes;
        // One simulated transfer per direction per iteration (the ops of
        // a direction batch into one DMA), matching the cost model's
        // one-alpha-per-direction rule in `should_swap` — not one alpha
        // per block, which would overstate the latency the decision
        // model was charged.
        if let SwapPolicy::Cost(m) = &tier.config.policy {
            if spill_bytes > 0 {
                self.metrics.tier_sim_s += m.transfer_s(spill_bytes);
            }
            if fetch_bytes > 0 {
                self.metrics.tier_sim_s += m.transfer_s(fetch_bytes);
            }
        }
        ops
    }

    /// Enqueue a request (arrival time = now, for TTFT accounting).
    pub fn submit(&mut self, req: &Request) {
        let mut seq = Sequence {
            id: req.id,
            tokens: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new: req.max_new_tokens,
            table: BlockTable::default(),
            pos: 0,
            generated: Vec::new(),
            state: SeqState::Queued,
            admitted_iter: 0,
            cold: Vec::new(),
            tainted: false,
            swap_in_at: None,
            resume_lossy: false,
            resume_direct: false,
            submitted: Instant::now(),
        };
        if req.prompt.is_empty() || req.max_new_tokens == 0 {
            seq.state = SeqState::Done;
            self.finished.push(seq);
            return;
        }
        self.queue.push_back(seq);
    }

    pub fn is_done(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    pub fn running(&self) -> &[Sequence] {
        &self.running
    }

    /// Move finished sequences out (outputs in completion order).
    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }

    /// Plan one iteration: admit from the queue, guarantee every running
    /// sequence a KV slot for its next position (preempting the most
    /// recently admitted sequences if the pool runs dry), and sample the
    /// occupancy metrics. Returns the number of runnable sequences.
    pub fn schedule(&mut self) -> usize {
        self.iter += 1;
        self.admit();
        self.ensure_all_slots();
        if self.running.is_empty() && !self.queue.is_empty() {
            let head = self.queue.front().unwrap();
            panic!(
                "KV block pool too small: request {} needs ~{} blocks of {} tokens, pool has {}",
                head.id,
                (head.prompt_len + head.max_new).div_ceil(self.config.block_size),
                self.config.block_size,
                self.config.num_blocks,
            );
        }
        self.metrics.iterations += 1;
        self.metrics.queue_depth.push(self.queue.len() as f64);
        self.metrics.batch_size.push(self.running.len() as f64);
        let pool = &self.kv.pool;
        self.metrics
            .pool_occupancy
            .push(pool.blocks_in_use() as f64 / pool.num_blocks().max(1) as f64);
        if let Some(tier) = &self.tier {
            self.metrics
                .cold_occupancy
                .push(tier.in_use() as f64 / tier.slots().max(1) as f64);
            self.metrics.peak_cold_in_use = tier.max_in_use;
        }
        self.running.len()
    }

    /// Record the outcome of one batched step: `samples[i]` corresponds
    /// to `running()[i]`. `iter_s` is the wall time of the step, split
    /// evenly across slots for TPOT / decode-throughput accounting.
    pub fn commit(&mut self, samples: &[Option<usize>], iter_s: f64) {
        debug_assert_eq!(samples.len(), self.running.len());
        let bs = self.config.block_size;
        let per_token_s = if samples.is_empty() { 0.0 } else { iter_s / samples.len() as f64 };
        for (seq, sample) in self.running.iter_mut().zip(samples) {
            let pos = seq.pos;
            let is_decode = pos >= seq.prompt_len;
            // First step after a lossy swap-in: the sequence has now
            // attended over quantized KV. Taint it (its blocks are no
            // longer pure functions of their token prefix) and record
            // the first index at which outputs may diverge.
            if seq.resume_lossy {
                seq.resume_lossy = false;
                seq.tainted = true;
                if seq.resume_direct {
                    seq.resume_direct = false;
                    self.metrics.cold_direct_reads += 1;
                }
                if seq.swap_in_at.is_none() {
                    seq.swap_in_at = Some(seq.generated.len());
                    self.metrics.swap_points.push((seq.id, seq.generated.len()));
                }
            }
            if is_decode {
                // Replayed positions (recompute-preemption redoing
                // already-sampled tokens) are charged to decode time but
                // produce no new token — recompute waste shows up as
                // decode throughput, not hidden wall time.
                self.metrics.decode_s += per_token_s;
                if seq.at_frontier() {
                    self.metrics.tpot.push(per_token_s);
                    self.metrics.decode_steps += 1;
                } else {
                    self.metrics.replay_steps += 1;
                }
            }
            // The block holding `pos` just became full: publish it for
            // prefix sharing (keyed by the entire covered token prefix).
            // Tainted sequences never publish — their KV depends on
            // quantization error, not just the tokens. A cold prefix
            // implies tainted (direct reads are int8-only), so the hot
            // index below never underflows.
            if (pos + 1) % bs == 0 && !seq.tainted && seq.cold.is_empty() {
                let block = seq.table.blocks[pos / bs];
                self.kv.register_full_block(&seq.tokens[..pos + 1], block);
            }
            seq.pos += 1;
            if let Some(tok) = *sample {
                if seq.generated.is_empty() {
                    self.metrics.ttft.push(seq.submitted.elapsed().as_secs_f64());
                }
                seq.generated.push(tok);
                if seq.generated.len() < seq.max_new {
                    seq.tokens.push(tok);
                } else {
                    seq.state = SeqState::Done;
                }
            }
            if seq.state != SeqState::Done && seq.pos >= seq.prompt_len {
                seq.state = SeqState::Decode;
            }
        }
        // Retire finished sequences and free their blocks (both tiers).
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].state == SeqState::Done {
                let mut seq = self.running.remove(i);
                self.kv.release_table(&mut seq.table);
                if let Some(tier) = self.tier.as_mut() {
                    for slot in seq.cold.drain(..) {
                        tier.release(slot);
                    }
                }
                self.finished.push(seq);
            } else {
                i += 1;
            }
        }
        // This iteration's fetch ops have executed by now: their source
        // slots can finally be reused.
        if let Some(tier) = self.tier.as_mut() {
            tier.flush_releases();
        }
        self.metrics.prefix_hits = self.kv.prefix_hits;
        self.metrics.peak_blocks_in_use = self.kv.pool.max_in_use();
    }

    fn admit(&mut self) {
        // Blocks promised to sequences admitted earlier in this same
        // call: admission allocates lazily, so without this the same
        // free blocks would be counted for every admission and fresh
        // admits could immediately preempt each other.
        let mut reserved = 0usize;
        while self.running.len() < self.config.max_batch && !self.queue.is_empty() {
            // Swapped sequences re-enter through the cold tier: fetch
            // (or keep cold for direct reads), never recompute. A
            // Swapped sequence with an *empty* cold set (preempted at
            // pos 0, nothing spilled) lost no KV: it takes the fresh
            // path below — full admission control, prefix-cache lookup,
            // and no lossy-resume bookkeeping.
            let front = self.queue.front().unwrap();
            if front.state == SeqState::Swapped && !front.cold.is_empty() {
                if !self.admit_swapped(&mut reserved) {
                    break;
                }
                continue;
            }
            let mut seq = self.queue.pop_front().unwrap();
            let bs = self.config.block_size;
            let (mut shared, covered) = self.kv.lookup_prefix(&seq.tokens);
            // Admission control: room for the rest of the prompt plus
            // one decode block, so a fresh admission cannot immediately
            // preempt itself.
            let needed = (seq.tokens.len() + 1 - covered).div_ceil(bs);
            if self.kv.pool.free_blocks() < reserved + needed {
                self.kv.evict_unused_cached();
            }
            if self.kv.pool.free_blocks() < reserved + needed {
                self.kv.release_table(&mut shared);
                self.queue.push_front(seq);
                break;
            }
            reserved += needed;
            seq.table = shared;
            seq.pos = covered;
            seq.state =
                if covered >= seq.prompt_len { SeqState::Decode } else { SeqState::Prefill };
            seq.admitted_iter = self.iter;
            self.running.push(seq);
        }
    }

    /// Swap the cold queue head back in: allocate hot blocks, emit fetch
    /// ops for the engine, and resume at the preserved position (no
    /// replay). When the tier allows direct reads and enough of the
    /// sequence is full+cold, the full blocks stay cold and only the
    /// partial tail is fetched. Returns false when the pool cannot host
    /// it yet (it stays at the queue front).
    fn admit_swapped(&mut self, reserved: &mut usize) -> bool {
        let bs = self.config.block_size;
        let (total, full) = {
            let seq = self.queue.front().unwrap();
            (seq.cold.len(), seq.pos / bs)
        };
        let tier_cfg = &self.tier.as_ref().expect("swapped sequence without a tier").config;
        let frac_met = |frac: f64| full > 0 && full as f64 >= frac * total as f64;
        let keep = match tier_cfg.direct_read_min_frac {
            Some(frac) if tier_cfg.quant.lossy() && frac_met(frac) => full.min(total),
            _ => 0,
        };
        let lossy = tier_cfg.quant.lossy();
        let fetch_count = total - keep;
        // +1 headroom: the next position's block, so the admission can
        // not immediately preempt itself (same rule as the fresh path).
        let needed = fetch_count + 1;
        if self.kv.pool.free_blocks() < *reserved + needed {
            self.kv.evict_unused_cached();
        }
        if self.kv.pool.free_blocks() < *reserved + needed {
            return false;
        }
        // Unlike the lazy fresh path, the fetch targets are allocated
        // right below (they leave the free list immediately), so only
        // the +1 headroom stays reserved for later admissions.
        *reserved += 1;
        let mut seq = self.queue.pop_front().unwrap();
        let tier = self.tier.as_mut().unwrap();
        for j in keep..total {
            let slot = seq.cold[j];
            let hot = self.kv.pool.try_alloc().expect("free blocks counted above");
            seq.table.blocks.push(hot);
            tier.pending.push(TierOp::Fetch { cold: slot, hot, seq: seq.id });
            // The slot's data must survive until the engine runs the
            // fetch; it returns to the free list after the step.
            tier.release_after_ops(slot);
        }
        seq.cold.truncate(keep);
        seq.resume_lossy = lossy;
        seq.resume_direct = keep > 0;
        seq.state = if seq.pos >= seq.prompt_len { SeqState::Decode } else { SeqState::Prefill };
        seq.admitted_iter = self.iter;
        self.running.push(seq);
        true
    }

    fn ensure_all_slots(&mut self) {
        let bs = self.config.block_size;
        let mut idx = 0;
        while idx < self.running.len() {
            // The hot table covers logical blocks after the cold prefix.
            let hot_pos = self.running[idx].pos - self.running[idx].cold_tokens(bs);
            // Split borrows: table is a field of the sequence.
            let seq_table = &mut self.running[idx].table;
            if self.kv.ensure_slot(seq_table, hot_pos) {
                idx += 1;
                continue;
            }
            if self.kv.evict_unused_cached() > 0 {
                continue;
            }
            // Preempt the most recently admitted sequence (oldest work
            // is protected; vLLM-style recency victim selection).
            let victim = self
                .running
                .iter()
                .enumerate()
                .max_by_key(|(_, s)| s.admitted_iter)
                .map(|(i, _)| i)
                .expect("running cannot be empty here");
            self.preempt(victim);
            if victim < idx {
                idx -= 1;
            }
            // If victim == idx the current sequence itself was removed;
            // the loop retries whatever now occupies `idx`.
        }
    }

    fn preempt(&mut self, i: usize) {
        self.metrics.preemptions += 1;
        // A sequence swapped in *this same iteration* still has fetch
        // ops pending and its hot blocks unwritten: revert the fetches
        // (it goes back to the queue still swapped) instead of spilling
        // garbage.
        if self.revert_pending_fetches(i) {
            return;
        }
        // Swap-based preemption: spill to the cold tier and resume later
        // with position and sampled tokens intact.
        if self.should_swap(i) && self.swap_out(i) {
            return;
        }
        // Recompute: discard KV, replay from position 0 on re-admission.
        self.metrics.recompute_preemptions += 1;
        let mut seq = self.running.remove(i);
        self.kv.release_table(&mut seq.table);
        if !seq.cold.is_empty() {
            // A direct-read cold prefix dies with the recompute decision.
            let tier = self.tier.as_mut().expect("cold prefix without a tier");
            for slot in seq.cold.drain(..) {
                tier.release(slot);
            }
        }
        seq.state = SeqState::Preempted;
        seq.pos = 0;
        self.queue.push_front(seq);
    }

    /// Undo the fetches of a sequence admitted from the cold tier this
    /// iteration (the engine has not executed them yet). Its hot blocks
    /// are unwritten — release them, restore the cold table, and requeue
    /// it still swapped. Returns false when the sequence has no pending
    /// fetches (the normal preemption paths apply).
    fn revert_pending_fetches(&mut self, i: usize) -> bool {
        let id = self.running[i].id;
        let Some(tier) = self.tier.as_mut() else { return false };
        let mut slots = Vec::new();
        tier.pending.retain(|op| match *op {
            TierOp::Fetch { cold, seq, .. } if seq == id => {
                slots.push(cold);
                false
            }
            _ => true,
        });
        if slots.is_empty() {
            return false;
        }
        for &s in &slots {
            tier.cancel_release(s);
        }
        let mut seq = self.running.remove(i);
        // Fetch targets (and any extra tail block `ensure_slot` added
        // before failing) were never written: plain frees.
        self.kv.release_table(&mut seq.table);
        // `slots` is in pending order == logical order of the fetched
        // suffix, so appending restores the cold table exactly.
        seq.cold.extend(slots);
        seq.resume_lossy = false;
        seq.resume_direct = false;
        // `pos` stays where it was: the sequence is still fully swapped.
        // The event resolves through the cold tier (no KV lost, nothing
        // to recompute), so it lands in the swap bucket — the split
        // always sums to `preemptions`.
        seq.state = SeqState::Swapped;
        self.metrics.swap_preemptions += 1;
        self.queue.push_front(seq);
        true
    }

    /// The swap-vs-recompute decision for `running[i]`.
    fn should_swap(&self, i: usize) -> bool {
        let Some(tier) = &self.tier else { return false };
        match &tier.config.policy {
            SwapPolicy::Always => true,
            SwapPolicy::Never => false,
            SwapPolicy::Cost(m) => {
                let bs = self.config.block_size;
                let seq = &self.running[i];
                let cold0 = seq.cold.len();
                let bytes: u64 = (0..seq.table.blocks.len())
                    .map(|j| {
                        let filled = seq.pos.saturating_sub((cold0 + j) * bs).min(bs);
                        tier.payload_bytes(filled)
                    })
                    .sum();
                m.should_swap(bytes, bytes, seq.pos)
            }
        }
    }

    /// Spill `running[i]`'s hot blocks to the cold tier and requeue it
    /// swapped. Returns false when the cold tier cannot host it even
    /// after LRU-evicting queued swap sets (caller falls back to
    /// recompute).
    fn swap_out(&mut self, i: usize) -> bool {
        let bs = self.config.block_size;
        let (id, pos, cold0, n_hot) = {
            let s = &self.running[i];
            (s.id, s.pos, s.cold.len(), s.table.blocks.len())
        };
        // Blocks with no filled rows (a freshly allocated tail) are just
        // released, not spilled.
        let need = (0..n_hot).filter(|&j| pos.saturating_sub((cold0 + j) * bs) > 0).count();
        // LRU spill policy at the cold tier: when it is full, evict the
        // least-recently-touched swap set of a *queued* sequence (it
        // falls back to recompute); running sequences' cold prefixes are
        // never evictable.
        while self.tier.as_ref().unwrap().free_slots() < need {
            let candidates: Vec<u64> = self
                .queue
                .iter()
                .filter(|s| s.state == SeqState::Swapped && s.id != id)
                .map(|s| s.id)
                .collect();
            let Some(owner) = self.tier.as_ref().unwrap().lru_owner(&candidates) else {
                return false;
            };
            self.evict_cold_owner(owner);
        }
        let mut seq = self.running.remove(i);
        let tier = self.tier.as_mut().unwrap();
        for (j, &hot) in seq.table.blocks.iter().enumerate() {
            let filled = pos.saturating_sub((cold0 + j) * bs).min(bs);
            if filled == 0 {
                // Logical order: everything after this block is empty too.
                break;
            }
            let slot = tier.alloc(seq.id, filled).expect("free slots ensured above");
            tier.pending.push(TierOp::Spill { hot, cold: slot, filled });
            seq.cold.push(slot);
        }
        // The spill ops read the hot arena before any block allocated
        // this iteration is written (ops run ahead of the SPMD step), so
        // releasing the table now is safe.
        self.kv.release_table(&mut seq.table);
        seq.state = SeqState::Swapped;
        self.metrics.swap_preemptions += 1;
        self.queue.push_front(seq);
        true
    }

    /// Drop a queued sequence's cold swap set (LRU eviction): it loses
    /// its KV and will recompute from scratch on re-admission. The
    /// original preemption event was counted as a swap; eviction
    /// *reclassifies* that same event as a recompute, keeping
    /// `swap_preemptions + recompute_preemptions == preemptions`.
    fn evict_cold_owner(&mut self, id: u64) {
        self.tier.as_mut().expect("cold eviction without a tier").release_owned(id);
        if let Some(s) = self.queue.iter_mut().find(|s| s.id == id) {
            s.cold.clear();
            s.pos = 0;
            s.state = SeqState::Preempted;
            self.metrics.swap_preemptions = self.metrics.swap_preemptions.saturating_sub(1);
            self.metrics.recompute_preemptions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: Vec<usize>, max_new: usize) -> Request {
        Request { id, prompt, max_new_tokens: max_new }
    }

    #[test]
    fn lifecycle_queued_prefill_decode_done() {
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            block_size: 4,
            num_blocks: 8,
            max_batch: 4,
            threads: 1,
            tiering: None,
        });
        s.submit(&req(0, vec![1, 2, 3], 2));
        assert!(!s.is_done());
        assert_eq!(s.schedule(), 1);
        assert_eq!(s.running()[0].state, SeqState::Prefill);
        // Prompt tokens 0 and 1: no sample; token 2 is the frontier.
        s.commit(&[None], 0.0);
        s.schedule();
        s.commit(&[None], 0.0);
        s.schedule();
        assert!(s.running()[0].at_frontier());
        s.commit(&[Some(42)], 0.0);
        assert_eq!(s.running()[0].state, SeqState::Decode);
        assert_eq!(s.running()[0].tokens.last(), Some(&42));
        s.schedule();
        s.commit(&[Some(7)], 0.0);
        assert!(s.is_done());
        let fin = s.take_finished();
        assert_eq!(fin.len(), 1);
        assert_eq!(fin[0].generated, vec![42, 7]);
        // The sequence's block went back except the prefix-cache ref on
        // its one full block; eviction returns the pool to pristine.
        assert_eq!(s.kv.pool.free_blocks(), 7);
        assert_eq!(s.kv.evict_unused_cached(), 1);
        assert_eq!(s.kv.pool.free_blocks(), 8);
    }

    #[test]
    fn admission_respects_max_batch_and_pool() {
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            block_size: 4,
            num_blocks: 4,
            max_batch: 2,
            threads: 1,
            tiering: None,
        });
        for i in 0..3 {
            s.submit(&req(i, vec![i as usize; 5], 4));
        }
        s.schedule();
        assert_eq!(s.running().len(), 2, "max_batch caps admission");
        // Each admitted seq needs ceil(6/4) = 2 blocks; pool of 4 is
        // fully reserved, the third request stays queued.
        let d = s.metrics.queue_depth.max();
        assert!(d >= 1.0);
    }

    #[test]
    fn degenerate_requests_finish_immediately() {
        let mut s = ContinuousScheduler::new(ContinuousConfig::default());
        s.submit(&req(0, vec![], 5));
        s.submit(&req(1, vec![1, 2], 0));
        assert!(s.is_done());
        let fin = s.take_finished();
        assert_eq!(fin.len(), 2);
        assert!(fin.iter().all(|f| f.generated.is_empty()));
    }

    #[test]
    #[should_panic(expected = "KV block pool too small")]
    fn oversized_request_panics_clearly() {
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            block_size: 4,
            num_blocks: 2,
            max_batch: 2,
            threads: 1,
            tiering: None,
        });
        s.submit(&req(0, vec![1; 20], 4));
        s.schedule();
    }

    fn tiered_config(num_blocks: usize, cold_blocks: usize) -> ContinuousConfig {
        ContinuousConfig {
            block_size: 4,
            num_blocks,
            max_batch: 2,
            threads: 1,
            tiering: Some(TierConfig::new(cold_blocks)),
        }
    }

    /// Drive the scheduler without an engine: every scheduled slot
    /// "samples" a fixed token at its frontier.
    fn drive(s: &mut ContinuousScheduler, iters: usize) -> Vec<TierOp> {
        // Engineless tests still want real byte accounting.
        s.set_tier_geometry(2, 8);
        let mut all_ops = Vec::new();
        for _ in 0..iters {
            if s.is_done() {
                break;
            }
            s.schedule();
            all_ops.extend(s.take_tier_ops());
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.at_frontier().then_some(7)).collect();
            s.commit(&samples, 0.0);
        }
        all_ops
    }

    #[test]
    fn pressure_swaps_instead_of_recomputing() {
        // Two sequences needing 4 blocks each over their lifetime, pool
        // of 5: the old scheduler recompute-preempted here; with a cold
        // tier it must swap, finish both, and never replay a position.
        let mut s = ContinuousScheduler::new(tiered_config(5, 8));
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        let ops = drive(&mut s, 200);
        assert!(s.is_done(), "both requests must finish");
        let fin = s.take_finished();
        assert!(fin.iter().all(|f| f.generated.len() == 12));
        assert!(s.metrics.swap_preemptions > 0, "the tiny pool must force swaps");
        assert_eq!(s.metrics.recompute_preemptions, 0, "swap must replace recompute");
        assert_eq!(s.metrics.replay_steps, 0, "swapped sequences never replay");
        let spills = ops.iter().filter(|o| matches!(o, TierOp::Spill { .. })).count();
        let fetches = ops.iter().filter(|o| matches!(o, TierOp::Fetch { .. })).count();
        assert!(spills > 0 && fetches > 0);
        assert_eq!(s.metrics.spills, spills);
        assert_eq!(s.metrics.fetches, fetches);
        assert!(s.metrics.spill_bytes > 0 && s.metrics.fetch_bytes > 0);
        // Swapped-back int8 sequences are tainted and carry a resume point.
        assert!(!s.metrics.swap_points.is_empty());
        for f in &fin {
            if f.swap_in_at.is_some() {
                assert!(f.tainted);
            }
        }
        // All tiers drain at the end.
        assert_eq!(s.tier.as_ref().unwrap().in_use(), 0, "cold slots must be released");
    }

    #[test]
    fn swap_policy_never_falls_back_to_recompute() {
        let mut cfg = tiered_config(5, 8);
        if let Some(t) = cfg.tiering.as_mut() {
            t.policy = SwapPolicy::Never;
        }
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        let ops = drive(&mut s, 300);
        assert!(s.is_done());
        assert!(s.metrics.recompute_preemptions > 0);
        assert_eq!(s.metrics.swap_preemptions, 0);
        assert!(ops.is_empty(), "Never policy must move no bytes");
        assert!(s.metrics.replay_steps > 0, "recompute replays already-sampled tokens");
    }

    #[test]
    fn cold_tier_overflow_falls_back_to_recompute() {
        // Cold tier of 1 block cannot hold a 2-block swap set: swap_out
        // fails (no queued LRU victim to evict) and the victim
        // recomputes instead of deadlocking.
        let mut s = ContinuousScheduler::new(tiered_config(5, 1));
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        drive(&mut s, 300);
        assert!(s.is_done(), "overflow must degrade to recompute, not hang");
        assert!(s.metrics.recompute_preemptions > 0);
    }

    #[test]
    fn f32_tier_is_not_lossy_flagged() {
        let mut cfg = tiered_config(5, 8);
        if let Some(t) = cfg.tiering.as_mut() {
            t.quant = super::super::tiered::KvQuant::F32;
        }
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        drive(&mut s, 300);
        assert!(s.is_done());
        assert!(s.metrics.swap_preemptions > 0);
        assert!(s.metrics.swap_points.is_empty(), "f32 swap is lossless: no divergence points");
        assert!(s.take_finished().iter().all(|f| !f.tainted && f.swap_in_at.is_none()));
    }

    #[test]
    fn direct_read_keeps_full_blocks_cold() {
        let mut cfg = tiered_config(5, 8);
        if let Some(t) = cfg.tiering.as_mut() {
            t.direct_read_min_frac = Some(0.0);
        }
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        let ops = drive(&mut s, 300);
        assert!(s.is_done());
        assert!(s.metrics.cold_direct_reads > 0, "swap-ins must keep full blocks cold");
        let spills = ops.iter().filter(|o| matches!(o, TierOp::Spill { .. })).count();
        let fetches = ops.iter().filter(|o| matches!(o, TierOp::Fetch { .. })).count();
        assert!(fetches < spills, "direct reads must fetch less than was spilled");
        assert_eq!(s.tier.as_ref().unwrap().in_use(), 0, "cold prefix freed at finish");
    }
}
