//! Continuous-batching scheduler: admission control, iteration-level
//! batching of prefill + decode, and recency-based preemption-to-queue
//! when the block pool is exhausted.
//!
//! Sequence lifecycle: `Queued -> Prefill -> Decode -> Done`, with
//! `-> Preempted -> (queue front) -> Prefill` under memory pressure.
//! Every scheduler iteration advances each running sequence by exactly
//! one position — a prompt token while prefilling (chunked prefill with
//! chunk 1), the last sampled token while decoding — so prefill and
//! decode tokens share the same batched forward pass and a finished
//! sequence's slot is refilled on the very next iteration instead of at
//! batch boundaries.
//!
//! Preemption recomputes: the victim's blocks are released (its full
//! blocks may survive in the prefix cache and be re-attached for free)
//! and the sequence re-enters the queue front; greedy decode is
//! deterministic, so recomputation reproduces the same tokens and
//! preemption is invisible in the output stream — the differential test
//! against the FCFS oracle exercises exactly this.

use std::collections::VecDeque;
use std::time::Instant;

use super::blocks::{BlockTable, KvBlockManager};
use super::metrics::ServingMetrics;
use crate::coordinator::Request;

/// Scheduler state of one sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    Queued,
    Prefill,
    Decode,
    Preempted,
    Done,
}

/// One request being served.
#[derive(Debug)]
pub struct Sequence {
    pub id: u64,
    /// Tokens fed (or about to be fed) to the model: the prompt plus
    /// every sampled token except the final one.
    pub tokens: Vec<usize>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub table: BlockTable,
    /// Next position to compute.
    pub pos: usize,
    pub generated: Vec<usize>,
    pub state: SeqState,
    /// Iteration at which the sequence last entered the running set
    /// (preemption victims are chosen by recency of admission, so the
    /// oldest work is protected).
    pub admitted_iter: u64,
    submitted: Instant,
}

impl Sequence {
    /// True when `pos` is the last fed token: sample logits here.
    pub fn at_frontier(&self) -> bool {
        self.pos + 1 == self.tokens.len()
    }
}

/// Knobs of the continuous-batching serving path.
#[derive(Debug, Clone)]
pub struct ContinuousConfig {
    /// Token positions per KV block.
    pub block_size: usize,
    /// Physical blocks in the pool (all layers share block indices).
    pub num_blocks: usize,
    /// Maximum sequences batched per iteration.
    pub max_batch: usize,
    /// SPMD worker threads of the batched decode engine. The engine
    /// clamps to `[1, max_batch]` (workers own whole batch rows); the
    /// static partition keeps outputs token-identical at any value.
    /// Pick from the machine with [`crate::cost::MachineSpec::decode_threads`].
    pub threads: usize,
}

impl Default for ContinuousConfig {
    fn default() -> Self {
        ContinuousConfig { block_size: 16, num_blocks: 512, max_batch: 8, threads: 1 }
    }
}

impl ContinuousConfig {
    /// Size the pool from a machine's memory model: KV blocks get what
    /// is left after the weights ([`crate::cost::MachineSpec::kv_block_budget`]),
    /// further capped in proportion to the batch (64 blocks — 1024
    /// token positions at the default block size — per concurrent
    /// sequence) so a small demo on a big machine does not zero a
    /// multi-hundred-megabyte arena it will never touch.
    pub fn for_machine(
        model: &crate::model::Qwen3Config,
        machine: &crate::cost::MachineSpec,
        max_batch: usize,
    ) -> Self {
        let block_size = 16usize;
        let block_bytes = model.kv_bytes_per_token() * block_size as u64;
        let budget = machine.kv_block_budget(model.weight_bytes(), block_bytes);
        let workload_cap = (max_batch.max(1) * 64) as u64;
        ContinuousConfig {
            block_size,
            num_blocks: budget.min(workload_cap).max(1) as usize,
            max_batch,
            threads: machine.decode_threads(max_batch),
        }
    }
}

/// The continuous-batching scheduler.
pub struct ContinuousScheduler {
    pub config: ContinuousConfig,
    queue: VecDeque<Sequence>,
    running: Vec<Sequence>,
    pub kv: KvBlockManager,
    pub metrics: ServingMetrics,
    iter: u64,
    finished: Vec<Sequence>,
}

impl ContinuousScheduler {
    pub fn new(config: ContinuousConfig) -> Self {
        let kv = KvBlockManager::new(config.num_blocks, config.block_size);
        ContinuousScheduler {
            config,
            queue: VecDeque::new(),
            running: Vec::new(),
            kv,
            metrics: ServingMetrics::default(),
            iter: 0,
            finished: Vec::new(),
        }
    }

    /// Enqueue a request (arrival time = now, for TTFT accounting).
    pub fn submit(&mut self, req: &Request) {
        let mut seq = Sequence {
            id: req.id,
            tokens: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new: req.max_new_tokens,
            table: BlockTable::default(),
            pos: 0,
            generated: Vec::new(),
            state: SeqState::Queued,
            admitted_iter: 0,
            submitted: Instant::now(),
        };
        if req.prompt.is_empty() || req.max_new_tokens == 0 {
            seq.state = SeqState::Done;
            self.finished.push(seq);
            return;
        }
        self.queue.push_back(seq);
    }

    pub fn is_done(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    pub fn running(&self) -> &[Sequence] {
        &self.running
    }

    /// Move finished sequences out (outputs in completion order).
    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }

    /// Plan one iteration: admit from the queue, guarantee every running
    /// sequence a KV slot for its next position (preempting the most
    /// recently admitted sequences if the pool runs dry), and sample the
    /// occupancy metrics. Returns the number of runnable sequences.
    pub fn schedule(&mut self) -> usize {
        self.iter += 1;
        self.admit();
        self.ensure_all_slots();
        if self.running.is_empty() && !self.queue.is_empty() {
            let head = self.queue.front().unwrap();
            panic!(
                "KV block pool too small: request {} needs ~{} blocks of {} tokens, pool has {}",
                head.id,
                (head.prompt_len + head.max_new).div_ceil(self.config.block_size),
                self.config.block_size,
                self.config.num_blocks,
            );
        }
        self.metrics.iterations += 1;
        self.metrics.queue_depth.push(self.queue.len() as f64);
        self.metrics.batch_size.push(self.running.len() as f64);
        let pool = &self.kv.pool;
        self.metrics
            .pool_occupancy
            .push(pool.blocks_in_use() as f64 / pool.num_blocks().max(1) as f64);
        self.running.len()
    }

    /// Record the outcome of one batched step: `samples[i]` corresponds
    /// to `running()[i]`. `iter_s` is the wall time of the step, split
    /// evenly across slots for TPOT / decode-throughput accounting.
    pub fn commit(&mut self, samples: &[Option<usize>], iter_s: f64) {
        debug_assert_eq!(samples.len(), self.running.len());
        let bs = self.config.block_size;
        let per_token_s = if samples.is_empty() { 0.0 } else { iter_s / samples.len() as f64 };
        for (seq, sample) in self.running.iter_mut().zip(samples) {
            let pos = seq.pos;
            let is_decode = pos >= seq.prompt_len;
            if is_decode {
                self.metrics.tpot.push(per_token_s);
                self.metrics.decode_s += per_token_s;
                self.metrics.decode_steps += 1;
            }
            // The block holding `pos` just became full: publish it for
            // prefix sharing (keyed by the entire covered token prefix).
            if (pos + 1) % bs == 0 {
                let block = seq.table.blocks[pos / bs];
                self.kv.register_full_block(&seq.tokens[..pos + 1], block);
            }
            seq.pos += 1;
            if let Some(tok) = *sample {
                if seq.generated.is_empty() {
                    self.metrics.ttft.push(seq.submitted.elapsed().as_secs_f64());
                }
                seq.generated.push(tok);
                if seq.generated.len() < seq.max_new {
                    seq.tokens.push(tok);
                } else {
                    seq.state = SeqState::Done;
                }
            }
            if seq.state != SeqState::Done && seq.pos >= seq.prompt_len {
                seq.state = SeqState::Decode;
            }
        }
        // Retire finished sequences and free their blocks.
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].state == SeqState::Done {
                let mut seq = self.running.remove(i);
                self.kv.release_table(&mut seq.table);
                self.finished.push(seq);
            } else {
                i += 1;
            }
        }
        self.metrics.prefix_hits = self.kv.prefix_hits;
        self.metrics.peak_blocks_in_use = self.kv.pool.max_in_use();
    }

    fn admit(&mut self) {
        // Blocks promised to sequences admitted earlier in this same
        // call: admission allocates lazily, so without this the same
        // free blocks would be counted for every admission and fresh
        // admits could immediately preempt each other.
        let mut reserved = 0usize;
        while self.running.len() < self.config.max_batch && !self.queue.is_empty() {
            let mut seq = self.queue.pop_front().unwrap();
            let bs = self.config.block_size;
            let (mut shared, covered) = self.kv.lookup_prefix(&seq.tokens);
            // Admission control: room for the rest of the prompt plus
            // one decode block, so a fresh admission cannot immediately
            // preempt itself.
            let needed = (seq.tokens.len() + 1 - covered).div_ceil(bs);
            if self.kv.pool.free_blocks() < reserved + needed {
                self.kv.evict_unused_cached();
            }
            if self.kv.pool.free_blocks() < reserved + needed {
                self.kv.release_table(&mut shared);
                self.queue.push_front(seq);
                break;
            }
            reserved += needed;
            seq.table = shared;
            seq.pos = covered;
            seq.state =
                if covered >= seq.prompt_len { SeqState::Decode } else { SeqState::Prefill };
            seq.admitted_iter = self.iter;
            self.running.push(seq);
        }
    }

    fn ensure_all_slots(&mut self) {
        let mut idx = 0;
        while idx < self.running.len() {
            let pos = self.running[idx].pos;
            // Split borrows: table is a field of the sequence.
            let seq_table = &mut self.running[idx].table;
            if self.kv.ensure_slot(seq_table, pos) {
                idx += 1;
                continue;
            }
            if self.kv.evict_unused_cached() > 0 {
                continue;
            }
            // Preempt the most recently admitted sequence (oldest work
            // is protected; vLLM-style recency victim selection).
            let victim = self
                .running
                .iter()
                .enumerate()
                .max_by_key(|(_, s)| s.admitted_iter)
                .map(|(i, _)| i)
                .expect("running cannot be empty here");
            self.preempt(victim);
            if victim < idx {
                idx -= 1;
            }
            // If victim == idx the current sequence itself was removed;
            // the loop retries whatever now occupies `idx`.
        }
    }

    fn preempt(&mut self, i: usize) {
        let mut seq = self.running.remove(i);
        self.kv.release_table(&mut seq.table);
        seq.state = SeqState::Preempted;
        seq.pos = 0;
        self.metrics.preemptions += 1;
        self.queue.push_front(seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: Vec<usize>, max_new: usize) -> Request {
        Request { id, prompt, max_new_tokens: max_new }
    }

    #[test]
    fn lifecycle_queued_prefill_decode_done() {
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            block_size: 4,
            num_blocks: 8,
            max_batch: 4,
            threads: 1,
        });
        s.submit(&req(0, vec![1, 2, 3], 2));
        assert!(!s.is_done());
        assert_eq!(s.schedule(), 1);
        assert_eq!(s.running()[0].state, SeqState::Prefill);
        // Prompt tokens 0 and 1: no sample; token 2 is the frontier.
        s.commit(&[None], 0.0);
        s.schedule();
        s.commit(&[None], 0.0);
        s.schedule();
        assert!(s.running()[0].at_frontier());
        s.commit(&[Some(42)], 0.0);
        assert_eq!(s.running()[0].state, SeqState::Decode);
        assert_eq!(s.running()[0].tokens.last(), Some(&42));
        s.schedule();
        s.commit(&[Some(7)], 0.0);
        assert!(s.is_done());
        let fin = s.take_finished();
        assert_eq!(fin.len(), 1);
        assert_eq!(fin[0].generated, vec![42, 7]);
        // The sequence's block went back except the prefix-cache ref on
        // its one full block; eviction returns the pool to pristine.
        assert_eq!(s.kv.pool.free_blocks(), 7);
        assert_eq!(s.kv.evict_unused_cached(), 1);
        assert_eq!(s.kv.pool.free_blocks(), 8);
    }

    #[test]
    fn admission_respects_max_batch_and_pool() {
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            block_size: 4,
            num_blocks: 4,
            max_batch: 2,
            threads: 1,
        });
        for i in 0..3 {
            s.submit(&req(i, vec![i as usize; 5], 4));
        }
        s.schedule();
        assert_eq!(s.running().len(), 2, "max_batch caps admission");
        // Each admitted seq needs ceil(6/4) = 2 blocks; pool of 4 is
        // fully reserved, the third request stays queued.
        let d = s.metrics.queue_depth.max();
        assert!(d >= 1.0);
    }

    #[test]
    fn degenerate_requests_finish_immediately() {
        let mut s = ContinuousScheduler::new(ContinuousConfig::default());
        s.submit(&req(0, vec![], 5));
        s.submit(&req(1, vec![1, 2], 0));
        assert!(s.is_done());
        let fin = s.take_finished();
        assert_eq!(fin.len(), 2);
        assert!(fin.iter().all(|f| f.generated.is_empty()));
    }

    #[test]
    #[should_panic(expected = "KV block pool too small")]
    fn oversized_request_panics_clearly() {
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            block_size: 4,
            num_blocks: 2,
            max_batch: 2,
            threads: 1,
        });
        s.submit(&req(0, vec![1; 20], 4));
        s.schedule();
    }
}
