//! Continuous-batching scheduler: admission control, iteration-level
//! batching of chunked prefill + decode, and recency-based
//! preemption-to-queue when the block pool is exhausted.
//!
//! Sequence lifecycle: `Queued -> Prefill -> Decode -> Done`, with
//! `-> Preempted -> (queue front) -> Prefill` under memory pressure.
//! Every scheduler iteration advances each running sequence by a
//! **token span** packed under a per-iteration token budget
//! ([`ContinuousConfig::step_token_budget`]): decode sequences get
//! exactly one position (their last sampled token), prefilling
//! sequences get up to [`ContinuousConfig::prefill_chunk`] prompt
//! positions. With the default `prefill_chunk = 1` every span is one
//! token and the scheduler is bitwise-identical to the pre-span
//! behaviour; larger chunks change only *when* positions are computed,
//! never their values, so outputs stay token-identical at any chunk
//! size (the FCFS differential oracle pins both).
//!
//! Preemption has two modes. *Recompute* (the only mode when tiering is
//! off): the victim's blocks are released (its full blocks may survive
//! in the prefix cache and be re-attached for free) and the sequence
//! re-enters the queue front; greedy decode is deterministic, so
//! recomputation reproduces the same tokens and preemption is invisible
//! in the output stream — the differential test against the FCFS oracle
//! exercises exactly this. *Swap* (`ContinuousConfig::tiering`): the
//! victim's blocks are spilled to the quantized cold tier
//! ([`crate::serving::tiered`]) and fetched back on re-admission with
//! position and sampled tokens intact — no replay — governed by the
//! swap-vs-recompute cost model. On re-admission, full blocks whose
//! exact fp32 originals are still prefix-cache-resident are
//! **re-attached** instead of fetched (no bytes moved, no quantization
//! error re-read). The int8 tier is lossy: a sequence that actually
//! attends over quantized KV is *tainted* (its blocks never enter the
//! prefix cache) and its first resume point is recorded in
//! `ServingMetrics::swap_points`, bounding where divergence from the
//! oracle may start; a fully re-attached resume stays exact.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::blocks::{BlockTable, KvBlockManager};
use super::fault::{FaultPlan, RejectReason};
use super::metrics::ServingMetrics;
use super::spec;
use super::tiered::{SwapPolicy, TierConfig, TierOp, TierState};
use crate::coordinator::Request;
use crate::obs::{Code, Ring};

/// Scheduler state of one sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    Queued,
    Prefill,
    Decode,
    Preempted,
    /// Preempted with KV resident in the cold tier (swap-based
    /// preemption): re-admission fetches instead of recomputing.
    Swapped,
    Done,
}

/// One request being served.
#[derive(Debug)]
pub struct Sequence {
    pub id: u64,
    /// Tokens fed (or about to be fed) to the model: the prompt plus
    /// every sampled token except the final one.
    pub tokens: Vec<usize>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub table: BlockTable,
    /// Next position to compute.
    pub pos: usize,
    /// Token span planned for this iteration (`[pos, pos + span)`),
    /// set by `schedule()`; 1 for decode / chunk-1 sequences.
    pub span: usize,
    pub generated: Vec<usize>,
    pub state: SeqState,
    /// Iteration at which the sequence last entered the running set
    /// (preemption victims are chosen by recency of admission, so the
    /// oldest work is protected).
    pub admitted_iter: u64,
    /// Cold-tier slots of the sequence's *leading* logical blocks, in
    /// logical order. While running this is the direct-read prefix (the
    /// engine's hybrid attention reads these slots in place); while
    /// `Swapped` it covers every block the sequence had.
    pub cold: Vec<u32>,
    /// Set once the sequence has attended over quantized (lossy) KV:
    /// its blocks are no longer a pure function of their token prefix
    /// and must never enter the prefix cache.
    pub tainted: bool,
    /// Generated-token index of the first lossy resume (`None` until a
    /// quantized swap-in): earlier outputs are exact.
    pub swap_in_at: Option<usize>,
    /// Lossy swap-in admitted this iteration, not yet stepped — becomes
    /// `tainted` at the next commit (a same-iteration revert clears it).
    resume_lossy: bool,
    /// The pending lossy swap-in kept full blocks cold (direct read);
    /// counted into `cold_direct_reads` when the resume actually steps,
    /// so a same-iteration revert + retry is not double-counted.
    resume_direct: bool,
    /// Cold slots whose blocks were re-attached from the prefix cache
    /// at this iteration's swap-in. Their releases are deferred until
    /// the step runs, so a same-iteration revert can restore them to
    /// the cold table intact (the cache copies may be evicted before
    /// the sequence is re-admitted; the cold copies are the durable
    /// ones).
    reattached_cold: Vec<u32>,
    /// Trailing tokens of `tokens` that are *unverified drafts*
    /// (self-drafted speculation appended by `plan_spans`): the step
    /// verifies them and `commit_verified` keeps the longest matched
    /// causal prefix, truncating the rest. While drafts are planned,
    /// `span == 1 + spec_drafts` and the span still "reaches the
    /// frontier" (the final row is the speculative sample). 0 whenever
    /// the sequence is at a committed boundary.
    pub spec_drafts: usize,
    submitted: Instant,
}

impl Sequence {
    /// True when `pos` is the last fed token: sample logits here.
    pub fn at_frontier(&self) -> bool {
        self.pos + 1 == self.tokens.len()
    }

    /// True when this iteration's span reaches the sequence frontier —
    /// the engine samples from the span's final row.
    pub fn span_reaches_frontier(&self) -> bool {
        self.pos + self.span == self.tokens.len()
    }

    /// Token positions held by the cold prefix.
    pub fn cold_tokens(&self, block_size: usize) -> usize {
        self.cold.len() * block_size
    }

    /// Drop any planned-but-unverified draft tokens: the token stream
    /// and span return to the committed frontier. Every path that
    /// abandons an in-flight iteration (preemption, cold-integrity
    /// demotion, epoch recovery, deadline cancellation) strips first,
    /// and `plan_spans` strips defensively at the top — drafts never
    /// survive past the step they were planned for. Idempotent.
    pub fn strip_drafts(&mut self) {
        if self.spec_drafts > 0 {
            let real = self.tokens.len() - self.spec_drafts;
            self.tokens.truncate(real);
            self.spec_drafts = 0;
            self.span = 1;
        }
    }
}

/// Knobs of the continuous-batching serving path.
#[derive(Debug, Clone)]
pub struct ContinuousConfig {
    /// Token positions per KV block.
    pub block_size: usize,
    /// Physical blocks in the pool (all layers share block indices).
    pub num_blocks: usize,
    /// Maximum sequences batched per iteration.
    pub max_batch: usize,
    /// SPMD worker threads of the batched decode engine. The engine
    /// clamps to `[1, row_capacity]` (workers own token rows; the row
    /// capacity equals `max_batch` at `prefill_chunk = 1`); the static
    /// partition keeps outputs token-identical at any value. Pick from
    /// the machine with [`crate::cost::MachineSpec::decode_threads`].
    pub threads: usize,
    /// Max prompt positions a prefilling sequence advances per
    /// iteration. 1 (the default) is the seed one-token-per-slot
    /// behaviour, bitwise; larger values turn prompt ingestion into
    /// tall compute-bound GEMMs (chunked prefill). 0 is treated as 1.
    pub prefill_chunk: usize,
    /// Total token rows per iteration across the batch. 0 (the
    /// default) means auto: `max_batch * prefill_chunk`, i.e. every
    /// sequence can take a full chunk. The effective budget is never
    /// below the running-set size, so every running sequence always
    /// advances by at least one position.
    pub step_token_budget: usize,
    /// Tiered KV storage (`None` = flat fp32 pool; the scheduler is then
    /// bitwise-identical to the pre-tiering behaviour, which the FCFS
    /// differential oracle enforces).
    pub tiering: Option<TierConfig>,
    /// The serve plan this config was derived from (`Some` iff built by
    /// [`ContinuousConfig::autotuned`]). Pure annotation plus one knob
    /// the other fields cannot carry: the engine's GEMM panel
    /// granularity (`ServePlan::panel_rows`), wired by the coordinator
    /// into [`crate::serving::BatchEngine::set_panel_rows`]. Recorded
    /// in `ServeReport::plan`.
    pub plan: Option<crate::serving::autotune::ServePlan>,
    /// Shard the engine across cooperating worker groups under a
    /// dist-extracted per-matrix layout ([`crate::dist::ShardSpec`]).
    /// `None` = the unsharded seed engine. Layout only — outputs stay
    /// token-identical to the FCFS oracle under any spec.
    pub sharding: Option<crate::dist::ShardSpec>,
    /// Per-request completion deadline measured from submission (`None`
    /// = no deadline, the default). A request that exceeds it is
    /// cancelled wherever it is — queued or running — with its blocks
    /// fully released (both tiers) and whatever it generated so far as
    /// its partial output. `Some(ZERO)` is the degenerate dead-on-arrival
    /// deadline: every submission is rejected with
    /// [`RejectReason::DeadlineExpired`]. Wall-clock driven, so it can
    /// change *which* tokens a request gets to produce, never their
    /// values (greedy decode stays deterministic per request).
    pub deadline: Option<Duration>,
    /// Admission-queue bound: [`ContinuousScheduler::try_submit`]
    /// rejects with [`RejectReason::QueueFull`] once this many requests
    /// are waiting. 0 (the default) = unbounded, the pre-backpressure
    /// behaviour.
    pub max_queue: usize,
    /// Self-drafting speculative decoding: max draft tokens appended to
    /// a frontier decode slot per iteration ([`crate::serving::spec`]).
    /// 0 (the default) disables speculation — the scheduler is then
    /// bitwise-identical to the pre-spec behaviour. Any value keeps
    /// outputs token-identical to spec-off (greedy acceptance emits
    /// only the model's own argmax tokens); the knob is pure
    /// performance, which the FCFS differential oracle pins.
    pub spec_k: usize,
    /// Longest n-gram the self-drafter matches against the sequence's
    /// own context (longer patterns win over shorter; recency breaks
    /// ties). Only read when `spec_k > 0`; must then be >= 1.
    pub spec_ngram: usize,
}

impl Default for ContinuousConfig {
    fn default() -> Self {
        ContinuousConfig {
            block_size: 16,
            num_blocks: 512,
            max_batch: 8,
            threads: 1,
            prefill_chunk: 1,
            step_token_budget: 0,
            tiering: None,
            plan: None,
            sharding: None,
            deadline: None,
            max_queue: 0,
            spec_k: 0,
            spec_ngram: 3,
        }
    }
}

/// Builder for [`ContinuousConfig`] whose [`build`] validates the knob
/// set — the one place serving-config invariants are cross-checked
/// instead of at 30+ literal construction sites. Fields stay public on
/// the config itself (a hand-rolled literal still works); the builder
/// is the recommended front door.
///
/// [`build`]: ContinuousConfigBuilder::build
#[derive(Debug, Clone, Default)]
pub struct ContinuousConfigBuilder {
    cfg: ContinuousConfig,
}

impl ContinuousConfigBuilder {
    pub fn block_size(mut self, block_size: usize) -> Self {
        self.cfg.block_size = block_size;
        self
    }

    pub fn num_blocks(mut self, num_blocks: usize) -> Self {
        self.cfg.num_blocks = num_blocks;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.cfg.max_batch = max_batch;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    pub fn prefill_chunk(mut self, prefill_chunk: usize) -> Self {
        self.cfg.prefill_chunk = prefill_chunk;
        self
    }

    pub fn step_token_budget(mut self, step_token_budget: usize) -> Self {
        self.cfg.step_token_budget = step_token_budget;
        self
    }

    pub fn tiering(mut self, tiering: TierConfig) -> Self {
        self.cfg.tiering = Some(tiering);
        self
    }

    pub fn plan(mut self, plan: crate::serving::autotune::ServePlan) -> Self {
        self.cfg.plan = Some(plan);
        self
    }

    pub fn sharding(mut self, sharding: crate::dist::ShardSpec) -> Self {
        self.cfg.sharding = Some(sharding);
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.cfg.deadline = Some(deadline);
        self
    }

    pub fn max_queue(mut self, max_queue: usize) -> Self {
        self.cfg.max_queue = max_queue;
        self
    }

    pub fn spec_k(mut self, spec_k: usize) -> Self {
        self.cfg.spec_k = spec_k;
        self
    }

    pub fn spec_ngram(mut self, spec_ngram: usize) -> Self {
        self.cfg.spec_ngram = spec_ngram;
        self
    }

    /// Validate and return the config; `Err` names the violated rule.
    pub fn try_build(self) -> Result<ContinuousConfig, String> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }

    /// Validate and return the config, panicking on an invalid knob set
    /// (configs are built at serve setup, where misconfiguration should
    /// fail loudly, not steps later as a scheduler stall).
    pub fn build(self) -> ContinuousConfig {
        self.try_build().unwrap_or_else(|e| panic!("invalid ContinuousConfig: {e}"))
    }
}

impl ContinuousConfig {
    /// Start building a validated config from the defaults.
    pub fn builder() -> ContinuousConfigBuilder {
        ContinuousConfigBuilder::default()
    }

    /// Re-open an existing config (e.g. [`ContinuousConfig::autotuned`])
    /// as a builder to override knobs with validation on `build()`.
    pub fn to_builder(&self) -> ContinuousConfigBuilder {
        ContinuousConfigBuilder { cfg: self.clone() }
    }

    /// Check the knob invariants the scheduler and engine rely on;
    /// `Err` names the first violated rule. [`ContinuousConfigBuilder`]
    /// calls this on every `build()`; hand-rolled literals can call it
    /// directly.
    pub fn validate(&self) -> Result<(), String> {
        if self.block_size == 0 {
            return Err("block_size must be > 0 (token positions per KV block)".into());
        }
        if self.max_batch == 0 {
            return Err("max_batch must be > 0 (sequences per iteration)".into());
        }
        if self.num_blocks < self.max_batch {
            return Err(format!(
                "num_blocks ({}) must be >= max_batch ({}): every running sequence \
                 needs at least one KV block or admission can never fill the batch",
                self.num_blocks, self.max_batch
            ));
        }
        if self.step_token_budget != 0 {
            let need = self.max_batch.max(self.chunk());
            if self.step_token_budget < need {
                return Err(format!(
                    "step_token_budget ({}) must be 0 (auto) or >= \
                     max(max_batch, prefill_chunk) = {}: a smaller budget could \
                     neither advance every running sequence nor fit one full chunk",
                    self.step_token_budget, need
                ));
            }
        }
        if let Some(s) = &self.sharding {
            if s.shards == 0 {
                return Err("sharding.shards must be >= 1 (1 = unsharded)".into());
            }
        }
        if self.spec_k > 0 && self.spec_ngram == 0 {
            return Err(format!(
                "spec_ngram must be >= 1 when spec_k > 0 (got spec_k = {}): the \
                 self-drafter needs at least unigram matching to propose anything",
                self.spec_k
            ));
        }
        Ok(())
    }

    /// Effective prefill chunk (0 is hardened to 1 so no plan can emit
    /// a zero-token span).
    pub fn chunk(&self) -> usize {
        self.prefill_chunk.max(1)
    }

    /// Effective per-iteration token budget (see `step_token_budget`).
    /// The auto budget grows by `spec_k` rows per slot when speculation
    /// is on (verify rows need headroom or the default chunk-1 budget
    /// would never leave room to draft); an explicit budget is honoured
    /// as-is — drafting then takes only whatever the packing leaves.
    pub fn token_budget(&self) -> usize {
        if self.step_token_budget == 0 {
            self.max_batch.max(1) * (self.chunk() + self.spec_k)
        } else {
            self.step_token_budget.max(1)
        }
    }

    /// Engine row capacity for a serve run: the most token rows one
    /// iteration can carry (`BatchEngine::run`'s `max_rows`).
    pub fn row_capacity(&self) -> usize {
        // The budget is clamped up to the running-set size each
        // iteration, and the running set is capped at max_batch.
        self.token_budget().max(self.max_batch.max(1))
    }

    /// Size the config from a machine's memory model without running
    /// the planner — the `--autotune`-off fallback. Pool sizing goes
    /// through the planner's single source of truth
    /// ([`crate::serving::autotune::pool_sizing`]); threads keep the
    /// conservative [`crate::cost::MachineSpec::decode_threads`] clamp
    /// and prefill stays at the bitwise-seed chunk 1. Values are
    /// unchanged from the pre-planner heuristics.
    pub fn for_machine(
        model: &crate::model::Qwen3Config,
        machine: &crate::cost::MachineSpec,
        max_batch: usize,
    ) -> Self {
        let (block_size, num_blocks) =
            crate::serving::autotune::pool_sizing(model, machine, max_batch);
        ContinuousConfig {
            block_size,
            num_blocks,
            max_batch,
            threads: machine.decode_threads(max_batch),
            prefill_chunk: 1,
            step_token_budget: 0,
            tiering: None,
            plan: None,
            sharding: None,
            deadline: None,
            max_queue: 0,
            spec_k: 0,
            spec_ngram: 3,
        }
    }

    /// Derive the config from the serve-time autotune planner
    /// ([`crate::serving::autotune::plan_for`]): panel split, chunk,
    /// budget, threads and pool sizing all come from the roofline-scored
    /// plan for this `(model, machine, quant, batch)` triple, and the
    /// plan itself rides along for the report. Token-identical to any
    /// other config — the plan is pure performance.
    pub fn autotuned(
        model: &crate::model::Qwen3Config,
        machine: &crate::cost::MachineSpec,
        max_batch: usize,
    ) -> Self {
        let plan = crate::serving::autotune::plan_for(model, machine, max_batch);
        ContinuousConfig {
            block_size: plan.block_size,
            num_blocks: plan.num_blocks,
            max_batch: plan.max_batch,
            threads: plan.decode_threads,
            prefill_chunk: plan.prefill_chunk,
            step_token_budget: plan.step_token_budget,
            tiering: None,
            plan: Some(plan),
            sharding: None,
            deadline: None,
            max_queue: 0,
            spec_k: 0,
            spec_ngram: 3,
        }
    }
}

/// The continuous-batching scheduler.
pub struct ContinuousScheduler {
    pub config: ContinuousConfig,
    queue: VecDeque<Sequence>,
    running: Vec<Sequence>,
    pub kv: KvBlockManager,
    /// Cold-tier control plane (`Some` iff `config.tiering` is).
    pub tier: Option<TierState>,
    pub metrics: ServingMetrics,
    iter: u64,
    finished: Vec<Sequence>,
    /// Event ring of the scheduler track when the run is traced
    /// ([`ContinuousScheduler::set_trace`]): `schedule()` spans,
    /// whole-iteration spans, and per-request lifecycle instants.
    /// `None` (the default) records nothing — every hook is one branch.
    trace: Option<Ring>,
    /// Failpoint plan shared with the engine ([`crate::serving::fault`]).
    /// `None` (the default) keeps every injection hook a single branch.
    faults: Option<Arc<FaultPlan>>,
}

impl ContinuousScheduler {
    pub fn new(config: ContinuousConfig) -> Self {
        let kv = KvBlockManager::new(config.num_blocks, config.block_size);
        let tier = config.tiering.clone().map(TierState::new);
        let metrics = ServingMetrics {
            tiered: tier.is_some(),
            spec_enabled: config.spec_k > 0,
            ..Default::default()
        };
        ContinuousScheduler {
            config,
            queue: VecDeque::new(),
            running: Vec::new(),
            kv,
            tier,
            metrics,
            iter: 0,
            finished: Vec::new(),
            trace: None,
            faults: None,
        }
    }

    /// Share the run's failpoint plan (the same [`Arc`] the engine
    /// holds, so nth-counters are global across injection sites). The
    /// scheduler consults it only in `admit` (transient allocation
    /// failure); `None` keeps the hook a single branch.
    pub fn set_faults(&mut self, faults: Option<Arc<FaultPlan>>) {
        self.faults = faults;
    }

    /// Attach a pre-allocated event ring: the scheduler then records
    /// its decision spans and request lifecycle edges (enqueue, admit,
    /// first token, preempt, swap, finish) as the run's scheduler
    /// track. Stamp the ring against the same epoch as the engine's
    /// worker rings so the timelines merge.
    pub fn set_trace(&mut self, ring: Ring) {
        self.trace = Some(ring);
    }

    /// Detach the scheduler's event ring after the run (for the merged
    /// [`crate::obs::TraceLog`]).
    pub fn take_trace(&mut self) -> Option<Ring> {
        self.trace.take()
    }

    /// Wire the model geometry into the tier's byte accounting (called
    /// by the serving coordinator; safe no-op without tiering).
    pub fn set_tier_geometry(&mut self, layers: usize, width: usize) {
        if let Some(t) = self.tier.as_mut() {
            t.set_geometry(layers, width);
        }
    }

    /// Drain the data-movement ops of the last `schedule()` call for the
    /// engine (`BatchStepper::tier_ops`), accounting byte counters and
    /// the simulated transfer cost. Must run before the step executes.
    pub fn take_tier_ops(&mut self) -> Vec<TierOp> {
        let Some(tier) = self.tier.as_mut() else { return Vec::new() };
        let ops = std::mem::take(&mut tier.pending);
        let (mut spill_bytes, mut fetch_bytes) = (0u64, 0u64);
        for op in &ops {
            match *op {
                TierOp::Spill { filled, .. } => {
                    self.metrics.spills += 1;
                    spill_bytes += tier.payload_bytes(filled);
                }
                TierOp::Fetch { cold, .. } => {
                    self.metrics.fetches += 1;
                    fetch_bytes += tier.payload_bytes(tier.filled(cold));
                }
            }
        }
        self.metrics.spill_bytes += spill_bytes;
        self.metrics.fetch_bytes += fetch_bytes;
        // One simulated transfer per direction per iteration (the ops of
        // a direction batch into one DMA), matching the cost model's
        // one-alpha-per-direction rule in `should_swap` — not one alpha
        // per block, which would overstate the latency the decision
        // model was charged.
        if let SwapPolicy::Cost(m) = &tier.config.policy {
            if spill_bytes > 0 {
                self.metrics.tier_sim_s += m.transfer_s(spill_bytes);
            }
            if fetch_bytes > 0 {
                self.metrics.tier_sim_s += m.transfer_s(fetch_bytes);
            }
        }
        ops
    }

    fn make_seq(req: &Request) -> Sequence {
        Sequence {
            id: req.id,
            tokens: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new: req.max_new_tokens,
            table: BlockTable::default(),
            pos: 0,
            span: 1,
            generated: Vec::new(),
            state: SeqState::Queued,
            admitted_iter: 0,
            cold: Vec::new(),
            tainted: false,
            swap_in_at: None,
            resume_lossy: false,
            resume_direct: false,
            reattached_cold: Vec::new(),
            spec_drafts: 0,
            submitted: Instant::now(),
        }
    }

    /// Enqueue a request (arrival time = now, for TTFT accounting).
    /// Backpressure rejections are absorbed: the request still produces
    /// a (empty) finished output, so callers that submit blindly keep
    /// an output per request. Use [`try_submit`] to observe the reason.
    ///
    /// [`try_submit`]: ContinuousScheduler::try_submit
    pub fn submit(&mut self, req: &Request) {
        let _ = self.try_submit(req);
    }

    /// Enqueue a request, or reject it with a typed reason when
    /// admission backpressure applies: the bounded queue
    /// ([`ContinuousConfig::max_queue`]) is full, or the configured
    /// deadline is the degenerate zero budget (dead on arrival). A
    /// rejected request is retired immediately as a `Done` sequence
    /// with no output — rejection is observable in the output stream,
    /// not a silent drop.
    pub fn try_submit(&mut self, req: &Request) -> Result<(), RejectReason> {
        let reason = if self.config.max_queue > 0 && self.queue.len() >= self.config.max_queue {
            Some(RejectReason::QueueFull { limit: self.config.max_queue })
        } else if self.config.deadline.map_or(false, |d| d.is_zero()) {
            Some(RejectReason::DeadlineExpired)
        } else {
            None
        };
        if let Some(reason) = reason {
            self.metrics.rejected += 1;
            if let Some(r) = self.trace.as_mut() {
                r.instant(Code::Reject, req.id as u32);
            }
            let mut seq = Self::make_seq(req);
            seq.state = SeqState::Done;
            self.finished.push(seq);
            return Err(reason);
        }
        let mut seq = Self::make_seq(req);
        if let Some(r) = self.trace.as_mut() {
            r.instant(Code::Enqueue, req.id as u32);
        }
        if req.prompt.is_empty() || req.max_new_tokens == 0 {
            seq.state = SeqState::Done;
            self.metrics.request_e2e.push(seq.submitted.elapsed().as_secs_f64());
            if let Some(r) = self.trace.as_mut() {
                r.instant(Code::Finish, seq.id as u32);
            }
            self.finished.push(seq);
            return Ok(());
        }
        self.queue.push_back(seq);
        Ok(())
    }

    pub fn is_done(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    pub fn running(&self) -> &[Sequence] {
        &self.running
    }

    /// Move finished sequences out (outputs in completion order).
    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }

    /// Plan one iteration: admit from the queue, pack token spans under
    /// the budget, guarantee every running sequence KV slots for its
    /// span (shrinking spans, then preempting the most recently
    /// admitted sequences if the pool runs dry), and sample the
    /// occupancy metrics. Returns the number of runnable sequences.
    pub fn schedule(&mut self) -> usize {
        let t0 = self.trace.as_ref().map(|r| r.now_ns());
        self.iter += 1;
        self.cancel_expired();
        let admission_faulted = self.admit();
        self.plan_spans();
        self.ensure_all_slots();
        if !admission_faulted && self.running.is_empty() && !self.queue.is_empty() {
            let head = self.queue.front().unwrap();
            panic!(
                "KV block pool too small: request {} needs ~{} blocks of {} tokens, pool has {}",
                head.id,
                (head.prompt_len + head.max_new).div_ceil(self.config.block_size),
                self.config.block_size,
                self.config.num_blocks,
            );
        }
        self.metrics.iterations += 1;
        self.metrics.queue_depth.push(self.queue.len() as f64);
        self.metrics.batch_size.push(self.running.len() as f64);
        for seq in &self.running {
            debug_assert!(seq.span >= 1 && seq.pos + seq.span <= seq.tokens.len());
            if seq.pos < seq.prompt_len {
                self.metrics.chunk_size.push(seq.span as f64);
            }
        }
        let pool = &self.kv.pool;
        self.metrics
            .pool_occupancy
            .push(pool.blocks_in_use() as f64 / pool.num_blocks().max(1) as f64);
        if let Some(tier) = &self.tier {
            self.metrics
                .cold_occupancy
                .push(tier.in_use() as f64 / tier.slots().max(1) as f64);
            self.metrics.peak_cold_in_use = tier.max_in_use;
        }
        if let (Some(r), Some(t0)) = (self.trace.as_mut(), t0) {
            r.close(Code::Schedule, t0, self.running.len() as u32);
        }
        self.running.len()
    }

    /// Pack this iteration's token spans under the budget: every
    /// running sequence gets at least one position; leftover budget
    /// extends sequences toward their frontier, up to `prefill_chunk`,
    /// in running (admission) order — a deterministic packing, so the
    /// step shape is a pure function of scheduler state. With
    /// `spec_k > 0`, budget left over after the packing turns frontier
    /// decode slots into speculative verify spans: the self-drafter
    /// ([`crate::serving::spec`]) appends up to `spec_k` draft tokens
    /// and the span grows to `1 + drafts` — one tall verify GEMM
    /// instead of `drafts` separate weight-streaming decode steps.
    fn plan_spans(&mut self) {
        // Drafts left over from an abandoned iteration (a cold-integrity
        // fault can skip the step and its commit) are stale: planning
        // always starts from the committed token stream.
        for seq in &mut self.running {
            seq.strip_drafts();
        }
        let chunk = self.effective_chunk();
        let budget = self.config.token_budget().max(self.running.len());
        let mut extra = budget - self.running.len();
        for seq in &mut self.running {
            // Spans never cross the frontier: the frontier row samples,
            // and the sampled token is not known until the step runs.
            let to_frontier = seq.tokens.len() - seq.pos;
            let want = to_frontier.min(chunk);
            let ext = (want - 1).min(extra);
            seq.span = 1 + ext;
            extra -= ext;
        }
        if self.config.spec_k == 0 {
            return;
        }
        for seq in &mut self.running {
            if extra == 0 {
                break;
            }
            // Only frontier decode slots speculate: a replaying or
            // prefilling sequence already knows its next tokens, and a
            // frontier slot's span is exactly 1 after the packing.
            if seq.state != SeqState::Decode || !seq.at_frontier() {
                continue;
            }
            debug_assert_eq!(seq.span, 1);
            // Room under the request's token cap: a k-draft span can
            // emit up to k + 1 tokens (accepted drafts + the bonus
            // argmax after the last accept).
            let room = seq.max_new - seq.generated.len();
            let cap = self.config.spec_k.min(extra).min(room.saturating_sub(1));
            if cap == 0 {
                continue;
            }
            let drafts = spec::propose(&seq.tokens, self.config.spec_ngram, cap);
            if drafts.is_empty() {
                continue;
            }
            let n = drafts.len();
            seq.tokens.extend_from_slice(&drafts);
            seq.spec_drafts = n;
            seq.span = 1 + n;
            extra -= n;
            if let Some(r) = self.trace.as_mut() {
                r.instant(Code::Draft, n as u32);
            }
        }
    }

    /// The prefill chunk this iteration packs with. Under deadline
    /// pressure — any live request past half its budget — the chunk
    /// halves (floor 1): shorter prefill spans mean more frequent
    /// sampling opportunities for everyone, degrading throughput before
    /// anything is shed. Wall-clock driven, so it changes only *when*
    /// positions are computed, never token values.
    fn effective_chunk(&self) -> usize {
        let chunk = self.config.chunk();
        if chunk <= 1 {
            return chunk;
        }
        match self.config.deadline {
            Some(d) if !d.is_zero() => {
                let half = d / 2;
                let pressured = self
                    .running
                    .iter()
                    .chain(self.queue.iter())
                    .any(|s| s.submitted.elapsed() >= half);
                if pressured {
                    (chunk / 2).max(1)
                } else {
                    chunk
                }
            }
            _ => chunk,
        }
    }

    /// Cancel every request — queued or running — whose deadline has
    /// passed. Cancellation is a full retirement: hot blocks and cold
    /// slots are released, the sequence finishes `Done` with whatever
    /// it generated so far as its partial output, and the miss is
    /// counted in `deadline_missed`. Runs at the top of `schedule()`,
    /// where every sequence is at a committed boundary (no tier ops
    /// pending, no unread re-attaches).
    fn cancel_expired(&mut self) {
        let Some(d) = self.config.deadline else { return };
        if d.is_zero() {
            return; // dead-on-arrival is handled at submission
        }
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].submitted.elapsed() >= d {
                let seq = self.running.remove(i);
                self.cancel_deadline(seq);
            } else {
                i += 1;
            }
        }
        let mut j = 0;
        while j < self.queue.len() {
            if self.queue[j].submitted.elapsed() >= d {
                let seq = self.queue.remove(j).expect("index checked above");
                self.cancel_deadline(seq);
            } else {
                j += 1;
            }
        }
    }

    fn cancel_deadline(&mut self, mut seq: Sequence) {
        seq.strip_drafts();
        self.kv.release_table(&mut seq.table);
        if let Some(tier) = self.tier.as_mut() {
            for slot in seq.cold.drain(..) {
                tier.release(slot);
            }
        }
        seq.state = SeqState::Done;
        self.metrics.deadline_missed += 1;
        self.metrics.request_e2e.push(seq.submitted.elapsed().as_secs_f64());
        if let Some(r) = self.trace.as_mut() {
            r.instant(Code::DeadlineMiss, seq.id as u32);
            r.instant(Code::Finish, seq.id as u32);
        }
        self.finished.push(seq);
    }

    /// Record the outcome of one batched step: `samples[i]` corresponds
    /// to `running()[i]` (the argmax of its span's final row when the
    /// span reached the frontier). `iter_s` is the wall time of the
    /// step, split evenly across all token rows for TPOT / throughput
    /// accounting. Callers running with `spec_k > 0` must use
    /// [`commit_verified`] instead — speculative spans need every row's
    /// argmax, and this entry debug-asserts none are in flight.
    ///
    /// [`commit_verified`]: ContinuousScheduler::commit_verified
    pub fn commit(&mut self, samples: &[Option<usize>], iter_s: f64) {
        debug_assert!(
            self.running.iter().all(|s| s.spec_drafts == 0),
            "speculative spans must be committed through commit_verified"
        );
        self.commit_inner(samples, None, iter_s);
    }

    /// Record the outcome of one verified step: `rows[i]` holds the
    /// argmax of **every** row of `running()[i]`'s span (from
    /// [`crate::serving::BatchStepper::step_verify`]). Non-speculative
    /// sequences commit exactly as through [`commit`]: their sample is
    /// the final row's argmax when the span reached the frontier. A
    /// speculative sequence accepts the longest causal prefix of its
    /// drafts — draft `j` stands iff it equals the argmax the model
    /// produced after the previous accepted token — then emits those
    /// accepts plus the bonus argmax after the last one, and rolls the
    /// rejected suffix back out of the token stream and the KV
    /// ([`super::blocks::KvBlockManager::truncate_table`]). Every
    /// emitted token is the model's own argmax, so the output stream is
    /// token-identical to non-speculative greedy decode by construction.
    ///
    /// [`commit`]: ContinuousScheduler::commit
    pub fn commit_verified(&mut self, rows: &[Vec<usize>], iter_s: f64) {
        debug_assert_eq!(rows.len(), self.running.len());
        let samples: Vec<Option<usize>> = self
            .running
            .iter()
            .zip(rows)
            .map(|(s, r)| {
                (s.spec_drafts == 0 && s.span_reaches_frontier())
                    .then(|| *r.last().expect("a span has at least one row"))
            })
            .collect();
        self.commit_inner(&samples, Some(rows), iter_s);
    }

    fn commit_inner(&mut self, samples: &[Option<usize>], rows: Option<&[Vec<usize>]>, iter_s: f64) {
        debug_assert_eq!(samples.len(), self.running.len());
        let bs = self.config.block_size;
        let total_rows: usize = self.running.iter().map(|s| s.span).sum();
        let per_token_s = if total_rows == 0 { 0.0 } else { iter_s / total_rows as f64 };
        // Iteration-mix accounting: decode-only iterations (no prompt
        // rows in the step) measure exactly what the serve plan's
        // per-iteration decode roofline predicts, so their mean is the
        // predicted-vs-measured comparison `ServeReport` renders.
        let prefill_rows: usize = self
            .running
            .iter()
            .map(|s| s.span.min(s.prompt_len.saturating_sub(s.pos)))
            .sum();
        if total_rows > 0 {
            if prefill_rows == 0 {
                self.metrics.decode_only_iters += 1;
                self.metrics.decode_only_s += iter_s;
            } else {
                self.metrics.prefill_iters += 1;
                self.metrics.prefill_iters_s += iter_s;
            }
        }
        // The whole-iteration span, reconstructed backward from the
        // measured wall time so the driver loop needs no hooks of its
        // own (`arg` = token rows in the step).
        if let Some(r) = self.trace.as_mut() {
            let t1 = r.now_ns();
            let t0 = t1.saturating_sub((iter_s * 1e9) as u64);
            r.record(Code::Iterate, t0, t1, total_rows as u32);
        }
        for (i, (seq, sample)) in self.running.iter_mut().zip(samples).enumerate() {
            // The re-attach bookkeeping of this iteration's swap-in is
            // consumed: the blocks were actually read by the step that
            // just ran, so they count NOW (a same-iteration revert never
            // reaches here — like fetches, reverted re-attaches are
            // never counted), and the deferred cold releases flush
            // below.
            self.metrics.swap_reattached += seq.reattached_cold.len();
            seq.reattached_cold.clear();
            // First step after a lossy swap-in: the sequence has now
            // attended over quantized KV. Taint it (its blocks are no
            // longer pure functions of their token prefix) and record
            // the first index at which outputs may diverge.
            if seq.resume_lossy {
                seq.resume_lossy = false;
                seq.tainted = true;
                if seq.resume_direct {
                    seq.resume_direct = false;
                    self.metrics.cold_direct_reads += 1;
                }
                if seq.swap_in_at.is_none() {
                    seq.swap_in_at = Some(seq.generated.len());
                    self.metrics.swap_points.push((seq.id, seq.generated.len()));
                }
            }
            if seq.spec_drafts > 0 {
                let verified = &rows.expect("speculative span committed without verify rows")[i];
                debug_assert_eq!(verified.len(), seq.span);
                let d = seq.spec_drafts;
                // The committed frontier: tokens[..real] is what a
                // non-speculative scheduler would hold (real == pos + 1).
                let real = seq.tokens.len() - d;
                // Every verify row streamed through the model, accepted
                // or not — rejected rows are the cost of speculating and
                // show up as decode throughput, like replay waste.
                self.metrics.decode_s += seq.span as f64 * per_token_s;
                // Longest causal prefix: draft j stands iff it equals
                // the argmax after the previous accepted token (row j-1
                // of the verify span; row 0 is the argmax after the
                // last committed token).
                let mut a = 0usize;
                while a < d && seq.tokens[real + a] == verified[a] {
                    a += 1;
                }
                // The span emits a + 1 tokens (accepts + the bonus
                // argmax); clamp to the request's remaining room.
                // plan_spans capped d at room - 1, so a_eff == a unless
                // a raced the cap — the clamp is defensive.
                let room = seq.max_new - seq.generated.len();
                let a_eff = a.min(room.saturating_sub(1));
                // Rejected (and over-cap) drafts leave the token stream
                // and the KV: whole blocks past the accept point go back
                // to the pool; rejected rows inside the kept tail block
                // are overwritten by the next step before any read.
                seq.tokens.truncate(real + a_eff);
                seq.spec_drafts = 0;
                let keep = seq.pos + a_eff + 1;
                self.kv.truncate_table(&mut seq.table, keep - seq.cold_tokens(bs));
                if let Some(r) = self.trace.as_mut() {
                    r.instant(Code::Verify, a_eff as u32);
                    if d > a_eff {
                        r.instant(Code::Rollback, (d - a_eff) as u32);
                    }
                }
                // Accepted positions publish their full blocks exactly
                // like committed spans do (tokens[..p + 1] is final:
                // every kept draft was verified).
                for p in seq.pos..keep {
                    if (p + 1) % bs == 0 && !seq.tainted && seq.cold.is_empty() {
                        let block = seq.table.blocks[p / bs];
                        self.kv.register_full_block(&seq.tokens[..p + 1], block);
                    }
                }
                seq.pos = keep;
                self.metrics.spec_steps += 1;
                self.metrics.spec_drafted += d;
                self.metrics.spec_accepted += a_eff;
                self.metrics.spec_rejected += d - a_eff;
                for (j, &tok) in verified[..=a_eff].iter().enumerate() {
                    if seq.generated.is_empty() {
                        // Unreachable in practice (speculation requires
                        // Decode at the frontier, which implies a first
                        // token); kept for parity with the plain path.
                        self.metrics.ttft.push(seq.submitted.elapsed().as_secs_f64());
                        if let Some(r) = self.trace.as_mut() {
                            r.instant(Code::FirstToken, seq.id as u32);
                        }
                    }
                    seq.generated.push(tok);
                    self.metrics.tpot.push(per_token_s);
                    self.metrics.decode_steps += 1;
                    if j == a_eff {
                        // Only the bonus token is new to the stream —
                        // the accepts are already in `tokens` as kept
                        // drafts (and a_eff <= room - 1 guarantees they
                        // never hit the cap themselves).
                        if seq.generated.len() < seq.max_new {
                            seq.tokens.push(tok);
                        } else {
                            seq.state = SeqState::Done;
                        }
                    }
                }
                continue;
            }
            let span = seq.span;
            for off in 0..span {
                let pos = seq.pos + off;
                if pos >= seq.prompt_len {
                    // Replayed positions (recompute-preemption redoing
                    // already-sampled tokens) are charged to decode time
                    // but produce no new token — recompute waste shows up
                    // as decode throughput, not hidden wall time.
                    self.metrics.decode_s += per_token_s;
                    if pos + 1 == seq.tokens.len() {
                        self.metrics.tpot.push(per_token_s);
                        self.metrics.decode_steps += 1;
                    } else {
                        self.metrics.replay_steps += 1;
                    }
                } else {
                    self.metrics.prefill_s += per_token_s;
                    self.metrics.prefill_steps += 1;
                }
                // The block holding `pos` just became full: publish it
                // for prefix sharing (keyed by the entire covered token
                // prefix) — chunk boundaries need not align to block
                // boundaries, so every boundary inside the span
                // registers. Tainted sequences never publish — their KV
                // depends on quantization error, not just the tokens. A
                // cold prefix implies tainted (direct reads are
                // int8-only), so the hot index below never underflows.
                if (pos + 1) % bs == 0 && !seq.tainted && seq.cold.is_empty() {
                    let block = seq.table.blocks[pos / bs];
                    self.kv.register_full_block(&seq.tokens[..pos + 1], block);
                }
            }
            seq.pos += span;
            if let Some(tok) = *sample {
                if seq.generated.is_empty() {
                    self.metrics.ttft.push(seq.submitted.elapsed().as_secs_f64());
                    if let Some(r) = self.trace.as_mut() {
                        r.instant(Code::FirstToken, seq.id as u32);
                    }
                }
                seq.generated.push(tok);
                if seq.generated.len() < seq.max_new {
                    seq.tokens.push(tok);
                } else {
                    seq.state = SeqState::Done;
                }
            }
            if seq.state != SeqState::Done && seq.pos >= seq.prompt_len {
                seq.state = SeqState::Decode;
            }
        }
        // Retire finished sequences and free their blocks (both tiers).
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].state == SeqState::Done {
                let mut seq = self.running.remove(i);
                self.kv.release_table(&mut seq.table);
                if let Some(tier) = self.tier.as_mut() {
                    for slot in seq.cold.drain(..) {
                        tier.release(slot);
                    }
                }
                self.metrics.request_e2e.push(seq.submitted.elapsed().as_secs_f64());
                if let Some(r) = self.trace.as_mut() {
                    r.instant(Code::Finish, seq.id as u32);
                }
                self.finished.push(seq);
            } else {
                i += 1;
            }
        }
        // This iteration's fetch and re-attach ops have executed by now:
        // their source slots can finally be reused.
        if let Some(tier) = self.tier.as_mut() {
            tier.flush_releases();
        }
        self.metrics.prefix_hits = self.kv.prefix_hits;
        self.metrics.peak_blocks_in_use = self.kv.pool.max_in_use();
    }

    /// Returns true when an injected transient allocation failure
    /// skipped admission this iteration (the queue is retried next
    /// iteration — `schedule()` must not diagnose the empty running set
    /// as a too-small pool).
    fn admit(&mut self) -> bool {
        // Failpoint: a transient block-allocation failure defers every
        // admission by one iteration. One-shot and retried, so outputs
        // are unaffected — only admission order in time shifts.
        if self.faults.as_ref().map_or(false, |fp| fp.take_alloc_fail()) {
            if let Some(r) = self.trace.as_mut() {
                r.instant(Code::FaultInject, 3);
            }
            return true;
        }
        // Blocks promised to sequences admitted earlier in this same
        // call: admission allocates lazily, so without this the same
        // free blocks would be counted for every admission and fresh
        // admits could immediately preempt each other.
        let mut reserved = 0usize;
        while self.running.len() < self.config.max_batch && !self.queue.is_empty() {
            // Swapped sequences re-enter through the cold tier: fetch,
            // re-attach, or keep cold for direct reads — never
            // recompute. A Swapped sequence with an *empty* cold set
            // (preempted at pos 0, nothing spilled) lost no KV: it takes
            // the fresh path below — full admission control,
            // prefix-cache lookup, and no lossy-resume bookkeeping.
            let front = self.queue.front().unwrap();
            if front.state == SeqState::Swapped && !front.cold.is_empty() {
                if !self.admit_swapped(&mut reserved) {
                    break;
                }
                continue;
            }
            let mut seq = self.queue.pop_front().unwrap();
            let bs = self.config.block_size;
            let (mut shared, covered) = self.kv.lookup_prefix(&seq.tokens);
            // Admission control: room for the rest of the prompt plus
            // one decode block, so a fresh admission cannot immediately
            // preempt itself.
            let needed = (seq.tokens.len() + 1 - covered).div_ceil(bs);
            if self.kv.pool.free_blocks() < reserved + needed {
                self.kv.evict_unused_cached();
            }
            if self.kv.pool.free_blocks() < reserved + needed {
                self.kv.release_table(&mut shared);
                self.queue.push_front(seq);
                break;
            }
            reserved += needed;
            seq.table = shared;
            seq.pos = covered;
            seq.state =
                if covered >= seq.prompt_len { SeqState::Decode } else { SeqState::Prefill };
            seq.admitted_iter = self.iter;
            if let Some(r) = self.trace.as_mut() {
                r.instant(Code::Admit, seq.id as u32);
            }
            self.running.push(seq);
        }
        false
    }

    /// Swap the cold queue head back in. In order of preference per
    /// block: **re-attach** the exact fp32 original still resident in
    /// the prefix cache (no bytes moved, no quantization error —
    /// untainted sequences only, since a tainted sequence's KV is not
    /// the pure function of its tokens that the cache stores); keep the
    /// block **cold** for direct dequant-gather reads (when the tier
    /// allows it and nothing re-attached — the engine needs the cold
    /// list to be the leading logical blocks); or **fetch** it into a
    /// fresh hot block. Resumes at the preserved position — no replay.
    /// Returns false when the pool cannot host it yet (it stays at the
    /// queue front).
    fn admit_swapped(&mut self, reserved: &mut usize) -> bool {
        let bs = self.config.block_size;
        let (total, full, tainted) = {
            let seq = self.queue.front().unwrap();
            (seq.cold.len(), seq.pos / bs, seq.tainted)
        };
        // Re-attach probe: leading full blocks whose prefix keys are
        // still cached. The probe retains each hit, so a concurrent
        // eviction pass cannot free them out from under the admission.
        let mut reattach: Vec<u32> = Vec::new();
        if !tainted {
            let seq = self.queue.front().unwrap();
            for j in 0..full.min(total) {
                match self.kv.lookup_block(&seq.tokens[..(j + 1) * bs]) {
                    Some(b) => reattach.push(b),
                    None => break,
                }
            }
        }
        let r = reattach.len();
        let tier_cfg = &self.tier.as_ref().expect("swapped sequence without a tier").config;
        let frac_met = |frac: f64| full > 0 && full as f64 >= frac * total as f64;
        // Direct cold reads only when nothing re-attached: the engine
        // requires the cold list to cover the sequence's *leading*
        // logical blocks, and re-attached hot blocks now precede any
        // still-cold one.
        let keep = match tier_cfg.direct_read_min_frac {
            Some(frac) if r == 0 && tier_cfg.quant.lossy() && frac_met(frac) => full.min(total),
            _ => 0,
        };
        let lossy = tier_cfg.quant.lossy();
        let fetch_count = total - r - keep;
        // +1 headroom: the next position's block, so the admission can
        // not immediately preempt itself (same rule as the fresh path).
        let needed = fetch_count + 1;
        if self.kv.pool.free_blocks() < *reserved + needed {
            self.kv.evict_unused_cached();
        }
        if self.kv.pool.free_blocks() < *reserved + needed {
            // Undo the probe: drop the extra references (the cache still
            // holds its own) and the hit counts of an admission that
            // never happened.
            for &b in &reattach {
                self.kv.pool.release(b);
            }
            self.kv.prefix_hits -= r;
            return false;
        }
        // Unlike the lazy fresh path, the fetch targets are allocated
        // right below (they leave the free list immediately), so only
        // the +1 headroom stays reserved for later admissions.
        // (`swap_reattached` is counted at commit time, once the step
        // has actually read the blocks — a same-iteration revert must
        // not leave phantom counts.)
        *reserved += 1;
        let mut seq = self.queue.pop_front().unwrap();
        let tier = self.tier.as_mut().unwrap();
        // Re-attached blocks join the hot table in logical order. Their
        // cold copies stay allocated until the step has run (deferred
        // release), so a same-iteration revert can restore them.
        for (j, &b) in reattach.iter().enumerate() {
            seq.table.blocks.push(b);
            let slot = seq.cold[j];
            tier.release_after_ops(slot);
            seq.reattached_cold.push(slot);
        }
        for j in (r + keep)..total {
            let slot = seq.cold[j];
            let hot = self.kv.pool.try_alloc().expect("free blocks counted above");
            seq.table.blocks.push(hot);
            tier.pending.push(TierOp::Fetch { cold: slot, hot, seq: seq.id });
            // The slot's data must survive until the engine runs the
            // fetch; it returns to the free list after the step.
            tier.release_after_ops(slot);
        }
        seq.cold.drain(..r);
        seq.cold.truncate(keep);
        // A resume that re-attached everything read no quantized bytes:
        // it stays exact (no taint, no divergence point).
        seq.resume_lossy = lossy && (fetch_count > 0 || keep > 0);
        seq.resume_direct = keep > 0;
        seq.state = if seq.pos >= seq.prompt_len { SeqState::Decode } else { SeqState::Prefill };
        seq.admitted_iter = self.iter;
        if let Some(r) = self.trace.as_mut() {
            r.instant(Code::SwapIn, seq.id as u32);
        }
        self.running.push(seq);
        true
    }

    fn ensure_all_slots(&mut self) {
        let bs = self.config.block_size;
        let mut idx = 0;
        while idx < self.running.len() {
            // The hot table covers logical blocks after the cold prefix;
            // the span's final position decides the reservation.
            let (pos, span, cold_toks) = {
                let s = &self.running[idx];
                (s.pos, s.span, s.cold_tokens(bs))
            };
            // Split borrows: table is a field of the sequence.
            let seq_table = &mut self.running[idx].table;
            if self.kv.ensure_slot(seq_table, pos + span - 1 - cold_toks) {
                idx += 1;
                continue;
            }
            if self.kv.evict_unused_cached() > 0 {
                continue;
            }
            // The pool cannot cover the full span even after eviction:
            // shrink it to what the partially-extended table already
            // covers — chunked prefill degrades gracefully before
            // anyone is preempted. (At chunk 1 this never fires: a
            // failed 1-token ensure means even `pos` is uncovered.)
            let covered = self.running[idx].table.capacity_tokens(bs) + cold_toks;
            if covered > pos {
                let seq = &mut self.running[idx];
                seq.span = span.min(covered - pos);
                // A shrunken speculative span keeps only the drafts its
                // verify rows can still hold KV for.
                if seq.spec_drafts > 0 && seq.span < 1 + seq.spec_drafts {
                    let real = seq.tokens.len() - seq.spec_drafts;
                    let kept = seq.span - 1;
                    seq.tokens.truncate(real + kept);
                    seq.spec_drafts = kept;
                }
                idx += 1;
                continue;
            }
            // Preempt the most recently admitted sequence (oldest work
            // is protected; vLLM-style recency victim selection).
            let victim = self
                .running
                .iter()
                .enumerate()
                .max_by_key(|(_, s)| s.admitted_iter)
                .map(|(i, _)| i)
                .expect("running cannot be empty here");
            self.preempt(victim);
            if victim < idx {
                idx -= 1;
            }
            // If victim == idx the current sequence itself was removed;
            // the loop retries whatever now occupies `idx`. Budget freed
            // by the victim's spans is not redistributed this iteration
            // (the packing stays a pure function of the pre-preemption
            // state).
        }
    }

    fn preempt(&mut self, i: usize) {
        // A preempted speculative span will never be verified: the
        // victim leaves with its committed token stream only (all three
        // arms below reuse the committed-boundary invariants).
        self.running[i].strip_drafts();
        self.metrics.preemptions += 1;
        if let Some(r) = self.trace.as_mut() {
            r.instant(Code::Preempt, self.running[i].id as u32);
        }
        // A sequence swapped in *this same iteration* still has fetch
        // ops pending (and/or re-attached blocks unread): revert the
        // admission (it goes back to the queue still swapped) instead
        // of spilling unwritten blocks.
        if self.revert_pending_swap_in(i) {
            return;
        }
        // Swap-based preemption: spill to the cold tier and resume later
        // with position and sampled tokens intact.
        if self.should_swap(i) && self.swap_out(i) {
            return;
        }
        // Recompute: discard KV, replay from position 0 on re-admission.
        self.metrics.recompute_preemptions += 1;
        let mut seq = self.running.remove(i);
        self.kv.release_table(&mut seq.table);
        if !seq.cold.is_empty() {
            // A direct-read cold prefix dies with the recompute decision.
            let tier = self.tier.as_mut().expect("cold prefix without a tier");
            for slot in seq.cold.drain(..) {
                tier.release(slot);
            }
        }
        seq.state = SeqState::Preempted;
        seq.pos = 0;
        self.queue.push_front(seq);
    }

    /// Undo the swap-in of a sequence admitted from the cold tier this
    /// iteration (the engine has not executed its fetches, and its
    /// re-attached blocks have not been read). Fetch-target hot blocks
    /// are unwritten — release them, restore the cold table (re-attached
    /// slots first, then kept direct-read slots, then the fetched
    /// suffix, which is logical order), and requeue it still swapped.
    /// Returns false when the sequence has no pending swap-in (the
    /// normal preemption paths apply).
    fn revert_pending_swap_in(&mut self, i: usize) -> bool {
        let id = self.running[i].id;
        if self.tier.is_none() {
            return false;
        }
        let reattached = std::mem::take(&mut self.running[i].reattached_cold);
        let tier = self.tier.as_mut().unwrap();
        let mut slots = Vec::new();
        tier.pending.retain(|op| match *op {
            TierOp::Fetch { cold, seq, .. } if seq == id => {
                slots.push(cold);
                false
            }
            _ => true,
        });
        if slots.is_empty() && reattached.is_empty() {
            return false;
        }
        for &s in slots.iter().chain(&reattached) {
            tier.cancel_release(s);
        }
        // The re-attached blocks were never read: undo their hit counts
        // (same rule as the pool-full probe undo in `admit_swapped`;
        // `swap_reattached` needs no undo — it only counts at commit).
        self.kv.prefix_hits -= reattached.len();
        let mut seq = self.running.remove(i);
        // Fetch targets (and any extra tail block `ensure_slot` added
        // before failing) were never written; re-attached blocks are
        // still cache-backed. All of them leave the table with plain
        // releases.
        self.kv.release_table(&mut seq.table);
        // Logical order: re-attached prefix, kept direct-read slots
        // (only possible when nothing re-attached), fetched suffix
        // (pending order == logical order).
        let mut cold = reattached;
        cold.extend(seq.cold.drain(..));
        cold.extend(slots);
        seq.cold = cold;
        seq.resume_lossy = false;
        seq.resume_direct = false;
        // `pos` stays where it was: the sequence is still fully swapped.
        // The event resolves through the cold tier (no KV lost, nothing
        // to recompute), so it lands in the swap bucket — the split
        // always sums to `preemptions`.
        seq.state = SeqState::Swapped;
        self.metrics.swap_preemptions += 1;
        self.queue.push_front(seq);
        true
    }

    /// The swap-vs-recompute decision for `running[i]`.
    fn should_swap(&self, i: usize) -> bool {
        let Some(tier) = &self.tier else { return false };
        match &tier.config.policy {
            SwapPolicy::Always => true,
            SwapPolicy::Never => false,
            SwapPolicy::Cost(m) => {
                let bs = self.config.block_size;
                let seq = &self.running[i];
                let cold0 = seq.cold.len();
                let bytes: u64 = (0..seq.table.blocks.len())
                    .map(|j| {
                        let filled = seq.pos.saturating_sub((cold0 + j) * bs).min(bs);
                        tier.payload_bytes(filled)
                    })
                    .sum();
                m.should_swap(bytes, bytes, seq.pos)
            }
        }
    }

    /// Spill `running[i]`'s hot blocks to the cold tier and requeue it
    /// swapped. Returns false when the cold tier cannot host it even
    /// after LRU-evicting queued swap sets (caller falls back to
    /// recompute).
    fn swap_out(&mut self, i: usize) -> bool {
        let bs = self.config.block_size;
        let (id, pos, cold0, n_hot) = {
            let s = &self.running[i];
            (s.id, s.pos, s.cold.len(), s.table.blocks.len())
        };
        // Blocks with no filled rows (a freshly allocated tail) are just
        // released, not spilled.
        let need = (0..n_hot).filter(|&j| pos.saturating_sub((cold0 + j) * bs) > 0).count();
        // LRU spill policy at the cold tier: when it is full, evict the
        // least-recently-touched swap set of a *queued* sequence (it
        // falls back to recompute); running sequences' cold prefixes are
        // never evictable.
        while self.tier.as_ref().unwrap().free_slots() < need {
            let candidates: Vec<u64> = self
                .queue
                .iter()
                .filter(|s| s.state == SeqState::Swapped && s.id != id)
                .map(|s| s.id)
                .collect();
            let Some(owner) = self.tier.as_ref().unwrap().lru_owner(&candidates) else {
                return false;
            };
            self.evict_cold_owner(owner);
        }
        let mut seq = self.running.remove(i);
        let tier = self.tier.as_mut().unwrap();
        for (j, &hot) in seq.table.blocks.iter().enumerate() {
            let filled = pos.saturating_sub((cold0 + j) * bs).min(bs);
            if filled == 0 {
                // Logical order: everything after this block is empty too.
                break;
            }
            let slot = tier.alloc(seq.id, filled).expect("free slots ensured above");
            tier.pending.push(TierOp::Spill { hot, cold: slot, filled });
            seq.cold.push(slot);
        }
        // The spill ops read the hot arena before any block allocated
        // this iteration is written (ops run ahead of the SPMD step), so
        // releasing the table now is safe.
        self.kv.release_table(&mut seq.table);
        seq.state = SeqState::Swapped;
        self.metrics.swap_preemptions += 1;
        if let Some(r) = self.trace.as_mut() {
            r.instant(Code::SwapOut, seq.id as u32);
        }
        self.queue.push_front(seq);
        true
    }

    /// Drop a queued sequence's cold swap set (LRU eviction): it loses
    /// its KV and will recompute from scratch on re-admission. The
    /// original preemption event was counted as a swap; eviction
    /// *reclassifies* that same event as a recompute, keeping
    /// `swap_preemptions + recompute_preemptions == preemptions`.
    fn evict_cold_owner(&mut self, id: u64) {
        self.tier.as_mut().expect("cold eviction without a tier").release_owned(id);
        if let Some(s) = self.queue.iter_mut().find(|s| s.id == id) {
            s.cold.clear();
            s.pos = 0;
            s.state = SeqState::Preempted;
            self.metrics.swap_preemptions = self.metrics.swap_preemptions.saturating_sub(1);
            self.metrics.recompute_preemptions += 1;
        }
    }

    /// Cold slots that must pass checksum verification before this
    /// iteration's step may read them in place: the direct-read prefixes
    /// of sequences resumed *this* iteration. (Fetched slots are
    /// verified inside the fetch itself; a slot is only ever trusted
    /// after one of the two checks.) The driver feeds the list to
    /// `BatchStepper::verify_cold` and routes failures back through
    /// [`fault_cold`].
    ///
    /// [`fault_cold`]: ContinuousScheduler::fault_cold
    pub fn resume_audits(&self) -> Vec<u32> {
        let iter = self.iter;
        self.running
            .iter()
            .filter(|s| s.admitted_iter == iter && s.resume_direct)
            .flat_map(|s| s.cold.iter().copied())
            .collect()
    }

    /// Handle cold slots whose payload failed checksum verification
    /// (fetch or direct-read audit): the owning sequences cannot trust
    /// their cold KV, so each is reclassified swap -> recompute through
    /// the existing fallback — blocks released on both tiers, position
    /// rolled back to 0, requeued at the front. Never serves corrupt
    /// KV; outputs stay token-identical because recompute replays the
    /// exact committed token stream. Returns the number of sequences
    /// demoted. Must run after `take_tier_ops()` and before the step's
    /// slots are built.
    pub fn fault_cold(&mut self, bad_slots: &[u32]) -> usize {
        if bad_slots.is_empty() {
            return 0;
        }
        let Some(tier) = self.tier.as_ref() else { return 0 };
        self.metrics.cold_checksum_failures += bad_slots.len();
        let mut owners: Vec<u64> = Vec::new();
        for &slot in bad_slots {
            if let Some(id) = tier.owner_of(slot) {
                if !owners.contains(&id) {
                    owners.push(id);
                }
            }
        }
        let mut demoted = 0;
        for id in owners {
            if let Some(i) = self.running.iter().position(|s| s.id == id) {
                self.demote_to_recompute(i);
                demoted += 1;
            } else if self.queue.iter().any(|s| s.id == id) {
                // A queued swap set turned out corrupt: same
                // reclassification the LRU eviction path uses.
                self.evict_cold_owner(id);
                demoted += 1;
            }
        }
        self.metrics.fault_requeued += demoted;
        demoted
    }

    /// Reclassify `running[i]` swap -> recompute after a cold-integrity
    /// failure. Mirrors the recompute arm of `preempt`, plus the undo
    /// of a not-yet-stepped resume's bookkeeping (the step never ran,
    /// so re-attach hits must not count). Fetched slots of the aborted
    /// resume are already queued for release (`release_after_ops`) and
    /// flush at the next commit.
    fn demote_to_recompute(&mut self, i: usize) {
        if let Some(r) = self.trace.as_mut() {
            r.instant(Code::FaultInject, 2);
        }
        let mut seq = self.running.remove(i);
        seq.strip_drafts();
        self.kv.prefix_hits -= seq.reattached_cold.len();
        seq.reattached_cold.clear();
        self.kv.release_table(&mut seq.table);
        if let Some(tier) = self.tier.as_mut() {
            for slot in seq.cold.drain(..) {
                tier.release(slot);
            }
        }
        seq.resume_lossy = false;
        seq.resume_direct = false;
        seq.pos = 0;
        seq.state = SeqState::Preempted;
        self.metrics.swap_preemptions = self.metrics.swap_preemptions.saturating_sub(1);
        self.metrics.recompute_preemptions += 1;
        self.queue.push_front(seq);
    }

    /// Roll the scheduler back to its last committed boundary after an
    /// SPMD run epoch died (a worker panicked and poisoned the
    /// barrier). The interrupted step committed nothing — `pos` only
    /// advances in `commit()` — but its KV writes may be partial and
    /// its tier ops may not have run, so nothing in-flight is trusted:
    ///
    /// * every running sequence is demoted to recompute and requeued at
    ///   the front in admission order (replay of the committed token
    ///   stream is deterministic, so outputs are unchanged);
    /// * queued swap sets are stripped to recompute (the tier reset
    ///   below frees their slots);
    /// * the cold tier is cleared wholesale ([`TierState::reset`]);
    /// * hot-pool refcounts are reconciled against the surviving
    ///   references (prefix cache only, at this point) and leaked
    ///   blocks reclaimed ([`KvBlockManager::audit_and_reclaim`]).
    ///
    /// Returns the number of sequences requeued. The caller restarts a
    /// fresh SPMD scope and keeps serving.
    pub fn recover_after_panic(&mut self) -> usize {
        let mut requeued = 0;
        // Back-to-front pops + push_front keep admission order at the
        // head of the queue.
        while let Some(mut seq) = self.running.pop() {
            seq.strip_drafts();
            self.kv.prefix_hits -= seq.reattached_cold.len();
            seq.reattached_cold.clear();
            self.kv.release_table(&mut seq.table);
            seq.cold.clear(); // slots die with the tier reset below
            seq.resume_lossy = false;
            seq.resume_direct = false;
            seq.pos = 0;
            seq.state = SeqState::Preempted;
            self.metrics.preemptions += 1;
            self.metrics.recompute_preemptions += 1;
            requeued += 1;
            self.queue.push_front(seq);
        }
        for seq in self.queue.iter_mut() {
            if seq.state == SeqState::Swapped || !seq.cold.is_empty() {
                seq.cold.clear();
                seq.pos = 0;
                seq.state = SeqState::Preempted;
                self.metrics.swap_preemptions =
                    self.metrics.swap_preemptions.saturating_sub(1);
                self.metrics.recompute_preemptions += 1;
            }
        }
        if let Some(tier) = self.tier.as_mut() {
            tier.reset();
        }
        let audit = self.kv.audit_and_reclaim(std::iter::empty());
        if !audit.clean() {
            self.metrics.fault_leaked_blocks += audit.freed_blocks;
        }
        self.metrics.fault_requeued += requeued;
        if let Some(r) = self.trace.as_mut() {
            r.instant(Code::Recover, requeued as u32);
        }
        requeued
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: Vec<usize>, max_new: usize) -> Request {
        Request { id, prompt, max_new_tokens: max_new }
    }

    fn flat_config(block_size: usize, num_blocks: usize, max_batch: usize) -> ContinuousConfig {
        ContinuousConfig::builder()
            .block_size(block_size)
            .num_blocks(num_blocks)
            .max_batch(max_batch)
            .threads(1)
            .build()
    }

    #[test]
    fn lifecycle_queued_prefill_decode_done() {
        let mut s = ContinuousScheduler::new(flat_config(4, 8, 4));
        s.submit(&req(0, vec![1, 2, 3], 2));
        assert!(!s.is_done());
        assert_eq!(s.schedule(), 1);
        assert_eq!(s.running()[0].state, SeqState::Prefill);
        // Prompt tokens 0 and 1: no sample; token 2 is the frontier.
        s.commit(&[None], 0.0);
        s.schedule();
        s.commit(&[None], 0.0);
        s.schedule();
        assert!(s.running()[0].at_frontier());
        assert!(s.running()[0].span_reaches_frontier());
        s.commit(&[Some(42)], 0.0);
        assert_eq!(s.running()[0].state, SeqState::Decode);
        assert_eq!(s.running()[0].tokens.last(), Some(&42));
        s.schedule();
        s.commit(&[Some(7)], 0.0);
        assert!(s.is_done());
        let fin = s.take_finished();
        assert_eq!(fin.len(), 1);
        assert_eq!(fin[0].generated, vec![42, 7]);
        // The sequence's block went back except the prefix-cache ref on
        // its one full block; eviction returns the pool to pristine.
        assert_eq!(s.kv.pool.free_blocks(), 7);
        assert_eq!(s.kv.evict_unused_cached(), 1);
        assert_eq!(s.kv.pool.free_blocks(), 8);
    }

    #[test]
    fn chunked_prefill_packs_spans_under_budget() {
        // Chunk 4, budget 6, two 9-token prompts: the packing gives
        // every sequence one row first, then extends in running order.
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            prefill_chunk: 4,
            step_token_budget: 6,
            ..flat_config(4, 32, 4)
        });
        s.submit(&req(0, (0..9).collect(), 2));
        s.submit(&req(1, (100..109).collect(), 2));
        assert_eq!(s.schedule(), 2);
        // seq0: 1 + min(3, extra=4) = 4; seq1: 1 + min(3, extra=1) = 2.
        assert_eq!(s.running()[0].span, 4);
        assert_eq!(s.running()[1].span, 2);
        // Commit advances by the spans; block boundaries inside a span
        // register for prefix sharing (9-token prompt, block 4: the
        // first full block completes mid-span).
        s.commit(&[None, None], 0.0);
        assert_eq!(s.running()[0].pos, 4);
        assert_eq!(s.running()[1].pos, 2);
        assert!(s.kv.cached_blocks() >= 1, "in-span block boundary must register");
        // Spans never cross the frontier: at pos 8 of a 9-token prompt
        // the span is exactly 1 and it samples.
        s.schedule();
        s.commit(&[None, None], 0.0);
        s.schedule();
        assert_eq!(s.running()[0].pos, 8);
        assert_eq!(s.running()[0].span, 1);
        assert!(s.running()[0].span_reaches_frontier());
        let m = &s.metrics;
        assert!(m.chunk_size.max() >= 4.0, "chunk stats must record the packed spans");
        assert!(m.prefill_steps > 0, "prompt rows must be counted as prefill");
    }

    #[test]
    fn zero_chunk_and_budget_harden_to_seed_behaviour() {
        // prefill_chunk 0 and step_token_budget 0 must not emit
        // zero-token spans: both degrade to the chunk-1 seed packing.
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            prefill_chunk: 0,
            step_token_budget: 0,
            ..flat_config(4, 16, 2)
        });
        s.submit(&req(0, vec![1, 2, 3, 4, 5], 2));
        while !s.is_done() {
            s.schedule();
            for seq in s.running() {
                assert_eq!(seq.span, 1, "chunk 0 must harden to 1");
            }
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(9)).collect();
            s.commit(&samples, 0.0);
        }
        assert_eq!(s.take_finished()[0].generated, vec![9, 9]);
    }

    #[test]
    fn span_shrinks_to_covered_prefix_instead_of_preempting() {
        // When a multi-block span can only get some of its blocks, the
        // span must shrink to the covered prefix rather than preempt —
        // chunked prefill degrades gracefully under pool pressure.
        // Admission control's whole-prompt headroom makes this state
        // unreachable through `submit` alone, so the sequence is placed
        // directly (the branch still matters: generated-token growth in
        // multi-sequence runs drains the pool behind the reservation).
        // Deliberately below the builder's `num_blocks >= max_batch`
        // invariant (a 1-block pool): fields stay public exactly so
        // white-box tests can construct states admission would refuse.
        let mut s = ContinuousScheduler::new(ContinuousConfig {
            prefill_chunk: 8,
            num_blocks: 1,
            ..flat_config(4, 2, 2)
        });
        s.iter = 1;
        s.running.push(Sequence {
            id: 0,
            tokens: (0..12).collect(),
            prompt_len: 12,
            max_new: 4,
            table: BlockTable::default(),
            pos: 0,
            span: 1,
            generated: Vec::new(),
            state: SeqState::Prefill,
            admitted_iter: 1,
            cold: Vec::new(),
            tainted: false,
            swap_in_at: None,
            resume_lossy: false,
            resume_direct: false,
            reattached_cold: Vec::new(),
            spec_drafts: 0,
            submitted: Instant::now(),
        });
        s.plan_spans();
        assert_eq!(s.running[0].span, 8, "the plan wants a full chunk");
        s.ensure_all_slots();
        // The 1-block pool covers positions 0..4 of the 8-token span:
        // shrink to 4, keep the sequence running, preempt nobody.
        assert_eq!(s.running.len(), 1);
        assert_eq!(s.running[0].span, 4, "span must shrink to the covered prefix");
        assert_eq!(s.metrics.preemptions, 0, "shrinking must not preempt");
        s.commit(&[None], 0.0);
        assert_eq!(s.running[0].pos, 4, "the shrunken span still advances");
    }

    #[test]
    fn admission_respects_max_batch_and_pool() {
        let mut s = ContinuousScheduler::new(flat_config(4, 4, 2));
        for i in 0..3 {
            s.submit(&req(i, vec![i as usize; 5], 4));
        }
        s.schedule();
        assert_eq!(s.running().len(), 2, "max_batch caps admission");
        // Each admitted seq needs ceil(6/4) = 2 blocks; pool of 4 is
        // fully reserved, the third request stays queued.
        let d = s.metrics.queue_depth.max();
        assert!(d >= 1.0);
    }

    #[test]
    fn degenerate_requests_finish_immediately() {
        let mut s = ContinuousScheduler::new(ContinuousConfig::default());
        s.submit(&req(0, vec![], 5));
        s.submit(&req(1, vec![1, 2], 0));
        assert!(s.is_done());
        let fin = s.take_finished();
        assert_eq!(fin.len(), 2);
        assert!(fin.iter().all(|f| f.generated.is_empty()));
    }

    #[test]
    #[should_panic(expected = "KV block pool too small")]
    fn oversized_request_panics_clearly() {
        let mut s = ContinuousScheduler::new(flat_config(4, 2, 2));
        s.submit(&req(0, vec![1; 20], 4));
        s.schedule();
    }

    fn tiered_config(num_blocks: usize, cold_blocks: usize) -> ContinuousConfig {
        ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(num_blocks)
            .max_batch(2)
            .threads(1)
            .tiering(TierConfig::new(cold_blocks))
            .build()
    }

    /// Drive the scheduler without an engine: every scheduled slot
    /// "samples" a fixed token when its span reaches the frontier.
    fn drive(s: &mut ContinuousScheduler, iters: usize) -> Vec<TierOp> {
        // Engineless tests still want real byte accounting.
        s.set_tier_geometry(2, 8);
        let mut all_ops = Vec::new();
        for _ in 0..iters {
            if s.is_done() {
                break;
            }
            s.schedule();
            all_ops.extend(s.take_tier_ops());
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(7)).collect();
            s.commit(&samples, 0.0);
        }
        all_ops
    }

    #[test]
    fn pressure_swaps_instead_of_recomputing() {
        // Two sequences needing 4 blocks each over their lifetime, pool
        // of 5: the old scheduler recompute-preempted here; with a cold
        // tier it must swap, finish both, and never replay a position.
        let mut s = ContinuousScheduler::new(tiered_config(5, 8));
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        let ops = drive(&mut s, 200);
        assert!(s.is_done(), "both requests must finish");
        let fin = s.take_finished();
        assert!(fin.iter().all(|f| f.generated.len() == 12));
        assert!(s.metrics.swap_preemptions > 0, "the tiny pool must force swaps");
        assert_eq!(s.metrics.recompute_preemptions, 0, "swap must replace recompute");
        assert_eq!(s.metrics.replay_steps, 0, "swapped sequences never replay");
        let spills = ops.iter().filter(|o| matches!(o, TierOp::Spill { .. })).count();
        let fetches = ops.iter().filter(|o| matches!(o, TierOp::Fetch { .. })).count();
        assert!(spills > 0 && fetches > 0);
        assert_eq!(s.metrics.spills, spills);
        assert_eq!(s.metrics.fetches, fetches);
        assert!(s.metrics.spill_bytes > 0 && s.metrics.fetch_bytes > 0);
        // Swapped-back int8 sequences are tainted and carry a resume
        // point (this pool is so tight the prefix-cache copies are
        // evicted before any re-admission could re-attach them).
        assert!(!s.metrics.swap_points.is_empty());
        for f in &fin {
            if f.swap_in_at.is_some() {
                assert!(f.tainted);
            }
        }
        // All tiers drain at the end.
        assert_eq!(s.tier.as_ref().unwrap().in_use(), 0, "cold slots must be released");
    }

    #[test]
    fn swap_in_reattaches_cache_resident_blocks() {
        // With max_new 8 the survivor finishes within 3 blocks, so the
        // victim's registered prefix blocks stay cache-resident across
        // its swap-out (nothing ever evicts them): re-admission must
        // re-attach them (zero fetches, zero quantization error) and
        // the sequence must finish EXACT — no taint, no swap point —
        // even though the tier is lossy int8.
        let mut s = ContinuousScheduler::new(tiered_config(5, 8));
        s.submit(&req(0, vec![1, 2, 3, 4], 8));
        s.submit(&req(1, vec![5, 6, 7, 8], 8));
        let ops = drive(&mut s, 200);
        assert!(s.is_done(), "both requests must finish");
        let fin = s.take_finished();
        assert!(fin.iter().all(|f| f.generated.len() == 8));
        assert!(s.metrics.swap_preemptions > 0, "the pool must still force a swap");
        assert_eq!(s.metrics.recompute_preemptions, 0);
        assert_eq!(s.metrics.swap_reattached, 2, "both full blocks must re-attach");
        let fetches = ops.iter().filter(|o| matches!(o, TierOp::Fetch { .. })).count();
        assert_eq!(fetches, 0, "re-attach must replace every fetch");
        assert_eq!(s.metrics.fetch_bytes, 0);
        assert!(
            s.metrics.swap_points.is_empty(),
            "a fully re-attached resume reads no quantized bytes: it stays exact"
        );
        assert!(fin.iter().all(|f| !f.tainted && f.swap_in_at.is_none()));
        assert_eq!(s.tier.as_ref().unwrap().in_use(), 0, "re-attached slots must drain");
    }

    #[test]
    fn swap_policy_never_falls_back_to_recompute() {
        let mut cfg = tiered_config(5, 8);
        if let Some(t) = cfg.tiering.as_mut() {
            t.policy = SwapPolicy::Never;
        }
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        let ops = drive(&mut s, 300);
        assert!(s.is_done());
        assert!(s.metrics.recompute_preemptions > 0);
        assert_eq!(s.metrics.swap_preemptions, 0);
        assert!(ops.is_empty(), "Never policy must move no bytes");
        assert!(s.metrics.replay_steps > 0, "recompute replays already-sampled tokens");
    }

    #[test]
    fn cold_tier_overflow_falls_back_to_recompute() {
        // Cold tier of 1 block cannot hold a 2-block swap set: swap_out
        // fails (no queued LRU victim to evict) and the victim
        // recomputes instead of deadlocking.
        let mut s = ContinuousScheduler::new(tiered_config(5, 1));
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        drive(&mut s, 300);
        assert!(s.is_done(), "overflow must degrade to recompute, not hang");
        assert!(s.metrics.recompute_preemptions > 0);
    }

    #[test]
    fn f32_tier_is_not_lossy_flagged() {
        let mut cfg = tiered_config(5, 8);
        if let Some(t) = cfg.tiering.as_mut() {
            t.quant = super::super::tiered::KvQuant::F32;
        }
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        drive(&mut s, 300);
        assert!(s.is_done());
        assert!(s.metrics.swap_preemptions > 0);
        assert!(s.metrics.swap_points.is_empty(), "f32 swap is lossless: no divergence points");
        assert!(s.take_finished().iter().all(|f| !f.tainted && f.swap_in_at.is_none()));
    }

    #[test]
    fn bounded_queue_rejects_with_typed_reason() {
        let cfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(8)
            .max_batch(2)
            .max_queue(1)
            .build();
        let mut s = ContinuousScheduler::new(cfg);
        assert!(s.try_submit(&req(0, vec![1, 2], 2)).is_ok());
        assert_eq!(
            s.try_submit(&req(1, vec![3, 4], 2)),
            Err(RejectReason::QueueFull { limit: 1 })
        );
        assert_eq!(s.metrics.rejected, 1);
        let fin = s.take_finished();
        assert_eq!(fin.len(), 1, "a rejected request still yields an (empty) output");
        assert_eq!(fin[0].id, 1);
        assert!(fin[0].generated.is_empty());
        assert_eq!(fin[0].state, SeqState::Done);
    }

    #[test]
    fn zero_deadline_rejects_dead_on_arrival() {
        let cfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(8)
            .max_batch(2)
            .deadline(Duration::ZERO)
            .build();
        let mut s = ContinuousScheduler::new(cfg);
        assert_eq!(s.try_submit(&req(0, vec![1], 1)), Err(RejectReason::DeadlineExpired));
        assert_eq!(s.metrics.rejected, 1);
        assert!(s.is_done(), "the rejected request retires immediately");
    }

    #[test]
    fn expired_deadline_cancels_queued_and_running() {
        let cfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(8)
            .max_batch(1)
            .deadline(Duration::from_millis(40))
            .build();
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2], 8));
        s.submit(&req(1, vec![3, 4], 8)); // stays queued behind max_batch 1
        assert_eq!(s.schedule(), 1);
        s.commit(&[None], 0.0);
        s.schedule();
        s.commit(&[Some(7)], 0.0); // request 0 holds one token at the miss
        std::thread::sleep(Duration::from_millis(50));
        s.schedule();
        assert!(s.is_done(), "both requests must be cancelled past the deadline");
        assert_eq!(s.metrics.deadline_missed, 2);
        let fin = s.take_finished();
        assert_eq!(fin.len(), 2);
        let r0 = fin.iter().find(|f| f.id == 0).unwrap();
        assert_eq!(r0.generated, vec![7], "the partial output survives cancellation");
        assert!(fin.iter().find(|f| f.id == 1).unwrap().generated.is_empty());
        s.kv.evict_unused_cached();
        assert_eq!(s.kv.pool.free_blocks(), 8, "cancellation releases every block");
    }

    #[test]
    fn deadline_pressure_halves_prefill_chunk() {
        let cfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(32)
            .max_batch(2)
            .prefill_chunk(8)
            .deadline(Duration::from_secs(10))
            .build();
        let mut s = ContinuousScheduler::new(cfg);
        s.iter = 1;
        s.running.push(Sequence {
            id: 0,
            tokens: (0..12).collect(),
            prompt_len: 12,
            max_new: 4,
            table: BlockTable::default(),
            pos: 0,
            span: 1,
            generated: Vec::new(),
            state: SeqState::Prefill,
            admitted_iter: 1,
            cold: Vec::new(),
            tainted: false,
            swap_in_at: None,
            resume_lossy: false,
            resume_direct: false,
            reattached_cold: Vec::new(),
            spec_drafts: 0,
            submitted: Instant::now(),
        });
        s.plan_spans();
        assert_eq!(s.running[0].span, 8, "fresh request: the full chunk");
        // Age the request past half its budget (guarded: `Instant`
        // cannot go below the platform epoch on a freshly booted box).
        if let Some(aged) = Instant::now().checked_sub(Duration::from_secs(6)) {
            s.running[0].submitted = aged;
            s.plan_spans();
            assert_eq!(s.running[0].span, 4, "past half the deadline the chunk halves");
        }
    }

    #[test]
    fn injected_alloc_failure_defers_admission_one_iteration() {
        let fp = Arc::new(FaultPlan::new().fail_alloc(0));
        let mut s = ContinuousScheduler::new(flat_config(4, 8, 2));
        s.set_faults(Some(fp.clone()));
        s.submit(&req(0, vec![1, 2], 2));
        assert_eq!(s.schedule(), 0, "the first admission round hits the injected failure");
        s.commit(&[], 0.0);
        assert_eq!(s.schedule(), 1, "the failure is transient: admission retries and wins");
        assert_eq!(fp.injected(), 1);
        while !s.is_done() {
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(9)).collect();
            s.commit(&samples, 0.0);
            s.schedule();
        }
        assert_eq!(s.take_finished()[0].generated, vec![9, 9], "outputs are unaffected");
    }

    #[test]
    fn recover_after_panic_requeues_and_replays_to_the_same_tokens() {
        let mut s = ContinuousScheduler::new(flat_config(4, 16, 2));
        s.submit(&req(0, vec![1, 2, 3], 4));
        s.submit(&req(1, vec![4, 5, 6], 4));
        // Five committed iterations: three prompt positions, then two
        // decode tokens — so the rollback has decode work to replay.
        for _ in 0..5 {
            s.schedule();
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(7)).collect();
            s.commit(&samples, 0.0);
        }
        s.schedule(); // the in-flight iteration whose step "panics"
        let requeued = s.recover_after_panic();
        assert_eq!(requeued, 2, "both running sequences roll back");
        assert!(s.running().is_empty());
        assert_eq!(s.metrics.fault_requeued, 2);
        assert_eq!(s.metrics.fault_leaked_blocks, 0, "recovery releases everything itself");
        assert_eq!(s.queue.front().unwrap().id, 0, "admission order survives the rollback");
        while !s.is_done() {
            s.schedule();
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(7)).collect();
            s.commit(&samples, 0.0);
        }
        let mut fin = s.take_finished();
        fin.sort_by_key(|f| f.id);
        assert_eq!(fin.len(), 2);
        assert!(fin.iter().all(|f| f.generated == vec![7, 7, 7, 7]));
        assert!(s.metrics.replay_steps > 0, "the rollback replays committed positions");
        s.kv.evict_unused_cached();
        assert_eq!(s.kv.pool.free_blocks(), 16, "no block survives past the finishes");
    }

    #[test]
    fn checksum_failure_reclassifies_direct_read_resume_to_recompute() {
        let mut cfg = tiered_config(5, 8);
        if let Some(t) = cfg.tiering.as_mut() {
            t.direct_read_min_frac = Some(0.0);
        }
        let mut s = ContinuousScheduler::new(cfg);
        s.set_tier_geometry(2, 8);
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        let mut audited = false;
        for _ in 0..300 {
            if s.is_done() {
                break;
            }
            s.schedule();
            let _ = s.take_tier_ops();
            let audits = s.resume_audits();
            if !audited && !audits.is_empty() {
                // Pretend every audited slot failed verification.
                let demoted = s.fault_cold(&audits);
                assert!(demoted > 0, "the direct-read owner must be demoted");
                audited = true;
            }
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(7)).collect();
            s.commit(&samples, 0.0);
        }
        assert!(audited, "the scenario must produce a direct-read resume to audit");
        assert!(s.is_done(), "corruption must degrade to recompute, not hang");
        assert!(s.metrics.cold_checksum_failures > 0);
        assert!(s.metrics.recompute_preemptions > 0, "reclassified swap -> recompute");
        assert!(s.metrics.fault_requeued > 0);
        let fin = s.take_finished();
        assert!(fin.iter().all(|f| f.generated.len() == 12));
        assert_eq!(s.tier.as_ref().unwrap().in_use(), 0, "demotion releases the cold slots");
    }

    /// Drive a periodic-prompt request through prefill and one plain
    /// decode token, stopping at the first iteration whose schedule
    /// planned a speculative span (the drafter needs a repeated suffix,
    /// which the period provides immediately).
    fn spec_ready(spec_k: usize) -> ContinuousScheduler {
        let cfg = ContinuousConfig::builder()
            .block_size(2)
            .num_blocks(16)
            .max_batch(2)
            .spec_k(spec_k)
            .build();
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 1, 2, 1, 2], 8));
        loop {
            s.schedule();
            if s.running[0].spec_drafts > 0 {
                return s;
            }
            // Sampling 1 continues the period, so the next schedule
            // finds the suffix [1, 2, 1] repeated and drafts from it.
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(1)).collect();
            s.commit(&samples, 0.0);
        }
    }

    #[test]
    fn drafting_extends_frontier_decode_spans_and_accepts() {
        let mut s = spec_ready(4);
        // Context [1,2,1,2,1,2,1]: suffix [1,2,1] recurs at index 2, so
        // the drafter proposes its continuation [2,1] — verbatim.
        let seq = &s.running[0];
        assert_eq!(seq.spec_drafts, 2);
        assert_eq!(seq.span, 3, "span carries [sampled, draft_1, draft_2]");
        assert_eq!(&seq.tokens[7..], &[2, 1], "drafts ride at the token tail");
        assert!(seq.span_reaches_frontier(), "the verify span still samples");
        // The "model" keeps the period going: every draft is its argmax.
        s.commit_verified(&[vec![2, 1, 2]], 0.0);
        let seq = &s.running[0];
        assert_eq!(seq.spec_drafts, 0);
        assert_eq!(seq.generated, vec![1, 2, 1, 2], "three tokens from one step");
        assert_eq!(seq.pos, 9, "pos jumps past both accepts and the bonus");
        assert!(seq.at_frontier());
        assert_eq!(s.metrics.spec_steps, 1);
        assert_eq!(s.metrics.spec_drafted, 2);
        assert_eq!(s.metrics.spec_accepted, 2);
        assert_eq!(s.metrics.spec_rejected, 0);
        assert!((s.metrics.accepted_tokens_per_step() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn rejection_rolls_back_tokens_and_kv() {
        let mut s = spec_ready(4);
        assert_eq!(s.running[0].spec_drafts, 2);
        let blocks_before = s.running[0].table.blocks.len();
        // The model accepts draft_1 (2) but contradicts draft_2 (1 vs 9):
        // the span emits [2, 9] and everything past the accept rolls back.
        s.commit_verified(&[vec![2, 9, 7]], 0.0);
        let seq = &s.running[0];
        assert_eq!(seq.generated, vec![1, 2, 9]);
        assert_eq!(seq.tokens, vec![1, 2, 1, 2, 1, 2, 1, 2, 9]);
        assert_eq!(seq.pos, 8);
        assert!(seq.at_frontier(), "the rollback lands on a committed frontier");
        assert!(
            seq.table.blocks.len() < blocks_before,
            "whole blocks past the accept point return to the pool"
        );
        assert_eq!(s.metrics.spec_accepted, 1);
        assert_eq!(s.metrics.spec_rejected, 1);
        let audit = s.kv.audit_and_reclaim(s.running.iter().map(|q| &q.table));
        assert!(audit.clean(), "rollback leaks no blocks: {audit:?}");
        // Finish under a constant-output model (its own drafts accept).
        for _ in 0..100 {
            if s.is_done() {
                break;
            }
            s.schedule();
            let rows: Vec<Vec<usize>> =
                s.running.iter().map(|q| vec![9; q.span]).collect();
            s.commit_verified(&rows, 0.0);
        }
        assert!(s.is_done());
        assert_eq!(s.take_finished()[0].generated.len(), 8);
        s.kv.evict_unused_cached();
        assert_eq!(s.kv.pool.free_blocks(), 16, "every block returns at the finish");
    }

    #[test]
    fn preemption_strips_planned_drafts() {
        let mut s = spec_ready(4);
        assert_eq!(s.running[0].tokens.len(), 9);
        s.preempt(0);
        let victim = s.queue.front().unwrap();
        assert_eq!(victim.spec_drafts, 0);
        assert_eq!(victim.tokens, vec![1, 2, 1, 2, 1, 2, 1], "drafts leave with the span");
        assert_eq!(victim.span, 1);
        assert_eq!(victim.state, SeqState::Preempted);
        s.kv.evict_unused_cached();
        assert_eq!(s.kv.pool.free_blocks(), 16);
    }

    /// A stand-in "model" whose argmax depends only on the previous
    /// token: consistent across speculative and plain runs, converges
    /// to a fixed point (15), so self-drafting finds accepts.
    fn model_next(t: usize) -> usize {
        (t * 2 + 1) % 16
    }

    fn drive_model(s: &mut ContinuousScheduler, iters: usize) {
        for _ in 0..iters {
            if s.is_done() {
                break;
            }
            s.schedule();
            let rows: Vec<Vec<usize>> = s
                .running
                .iter()
                .map(|q| (0..q.span).map(|off| model_next(q.tokens[q.pos + off])).collect())
                .collect();
            s.commit_verified(&rows, 0.0);
        }
    }

    #[test]
    fn speculative_decode_is_token_identical_to_plain() {
        let run = |spec_k: usize| {
            let cfg = ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(32)
                .max_batch(2)
                .spec_k(spec_k)
                .build();
            let mut s = ContinuousScheduler::new(cfg);
            s.submit(&req(0, vec![1, 1, 1], 10));
            s.submit(&req(1, vec![2, 3, 2, 3], 12));
            drive_model(&mut s, 500);
            assert!(s.is_done());
            let mut fin = s.take_finished();
            fin.sort_by_key(|f| f.id);
            let outs: Vec<Vec<usize>> = fin.iter().map(|f| f.generated.clone()).collect();
            (outs, s.metrics)
        };
        let (plain, pm) = run(0);
        let (spec, sm) = run(4);
        assert_eq!(spec, plain, "speculation must be invisible in the output stream");
        assert_eq!(pm.spec_drafted, 0, "spec-off must never draft");
        assert!(sm.spec_drafted > 0, "the fixed-point tail must produce drafts");
        assert!(sm.spec_accepted > 0, "the fixed-point tail must produce accepts");
        assert!(
            sm.iterations < pm.iterations,
            "accepted drafts must finish the same work in fewer iterations"
        );
        assert!(sm.accepted_tokens_per_step() > 1.0);
    }

    #[test]
    fn spec_knobs_validate_and_widen_the_auto_budget() {
        assert!(
            ContinuousConfig::builder().spec_k(4).spec_ngram(0).try_build().is_err(),
            "spec_k > 0 with spec_ngram 0 can never draft: reject at build"
        );
        let cfg = ContinuousConfig::builder().max_batch(2).spec_k(3).build();
        assert_eq!(cfg.token_budget(), 2 * (1 + 3), "auto budget grows verify headroom");
        assert_eq!(cfg.row_capacity(), 8, "the engine must size rows for verify spans");
        let explicit =
            ContinuousConfig { step_token_budget: 4, ..cfg.clone() };
        assert_eq!(explicit.token_budget(), 4, "explicit budgets are honoured as-is");
    }

    #[test]
    fn tight_budget_and_token_cap_bound_drafting() {
        // Explicit budget of 2 with one running sequence leaves exactly
        // one row of headroom: at most one draft, whatever spec_k says.
        let cfg = ContinuousConfig::builder()
            .block_size(2)
            .num_blocks(16)
            .max_batch(1)
            .spec_k(4)
            .step_token_budget(2)
            .build();
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 1, 2, 1, 2], 8));
        loop {
            s.schedule();
            if s.running[0].spec_drafts > 0 {
                break;
            }
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(1)).collect();
            s.commit(&samples, 0.0);
        }
        assert_eq!(s.running[0].spec_drafts, 1, "the budget caps the draft, not spec_k");
        assert_eq!(s.running[0].span, 2);

        // max_new 2: after the first token one slot of room remains, so
        // a draft span could overshoot the cap — drafting must not plan.
        let cfg = ContinuousConfig::builder()
            .block_size(2)
            .num_blocks(16)
            .max_batch(1)
            .spec_k(4)
            .build();
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 1, 2, 1, 2], 2));
        while !s.is_done() {
            s.schedule();
            assert_eq!(s.running[0].spec_drafts, 0, "no room to speculate under the cap");
            let samples: Vec<Option<usize>> =
                s.running().iter().map(|q| q.span_reaches_frontier().then_some(1)).collect();
            s.commit(&samples, 0.0);
        }
        assert_eq!(s.take_finished()[0].generated, vec![1, 1]);
    }

    #[test]
    fn direct_read_keeps_full_blocks_cold() {
        let mut cfg = tiered_config(5, 8);
        if let Some(t) = cfg.tiering.as_mut() {
            t.direct_read_min_frac = Some(0.0);
        }
        let mut s = ContinuousScheduler::new(cfg);
        s.submit(&req(0, vec![1, 2, 3, 4], 12));
        s.submit(&req(1, vec![5, 6, 7, 8], 12));
        let ops = drive(&mut s, 300);
        assert!(s.is_done());
        assert!(s.metrics.cold_direct_reads > 0, "swap-ins must keep full blocks cold");
        let spills = ops.iter().filter(|o| matches!(o, TierOp::Spill { .. })).count();
        let fetches = ops.iter().filter(|o| matches!(o, TierOp::Fetch { .. })).count();
        assert!(fetches < spills, "direct reads must fetch less than was spilled");
        assert_eq!(s.tier.as_ref().unwrap().in_use(), 0, "cold prefix freed at finish");
    }
}
