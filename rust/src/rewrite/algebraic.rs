//! Small algebraic cleanups shared by all pipelines.

use crate::egraph::{ClassId, EGraph, ENode, Rewrite, Tree};
use crate::ir::{Op, UnaryKind};

/// `Neg(Neg(x)) -> x`, `Reshape(Reshape(x, s1), s2) -> Reshape(x, s2)`.
pub struct FoldSelfInverse;

impl Rewrite for FoldSelfInverse {
    fn name(&self) -> &'static str {
        "FoldSelfInverse"
    }

    fn matches(&self, eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
        let mut trees = Vec::new();
        match &node.op {
            Op::Unary(UnaryKind::Neg) => {
                for inner in &eg.class(node.children[0]).nodes {
                    if matches!(inner.op, Op::Unary(UnaryKind::Neg)) {
                        trees.push(Tree::class(inner.children[0]));
                    }
                }
            }
            Op::Reshape { shape } => {
                for inner in &eg.class(node.children[0]).nodes {
                    if matches!(inner.op, Op::Reshape { .. }) {
                        trees.push(Tree::node(
                            Op::Reshape { shape: shape.clone() },
                            vec![Tree::class(inner.children[0])],
                        ));
                    }
                }
                // Reshape to the same shape is the identity.
                if eg.class(node.children[0]).ty.shape == *shape {
                    trees.push(Tree::class(node.children[0]));
                }
            }
            _ => {}
        }
        trees
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Runner;
    use crate::ir::{DType, Graph};

    #[test]
    fn double_neg_cancels() {
        let mut g = Graph::new();
        let a = g.input("a", &[4], DType::F32);
        let n1 = g.unary(UnaryKind::Neg, a);
        let n2 = g.unary(UnaryKind::Neg, n1);
        g.mark_output(n2);
        let (mut eg, map) = crate::egraph::EGraph::from_graph(&g);
        Runner::new(&mut eg).run(&[&FoldSelfInverse]);
        assert_eq!(eg.find(map[n2.index()]), eg.find(map[a.index()]));
    }

    #[test]
    fn reshape_chain_folds() {
        let mut g = Graph::new();
        let a = g.input("a", &[4, 6], DType::F32);
        let r1 = g.reshape(a, &[24]);
        let r2 = g.reshape(r1, &[6, 4]);
        g.mark_output(r2);
        let (mut eg, map) = crate::egraph::EGraph::from_graph(&g);
        Runner::new(&mut eg).run(&[&FoldSelfInverse]);
        // r2's class must contain a direct reshape-of-a node.
        let direct = eg.class(map[r2.index()]).nodes.iter().any(|n| {
            matches!(&n.op, Op::Reshape { .. }) && eg.find(n.children[0]) == eg.find(map[a.index()])
        });
        assert!(direct);
    }

    #[test]
    fn identity_reshape_is_input() {
        let mut g = Graph::new();
        let a = g.input("a", &[4, 6], DType::F32);
        let r = g.reshape(a, &[4, 6]);
        g.mark_output(r);
        let (mut eg, map) = crate::egraph::EGraph::from_graph(&g);
        Runner::new(&mut eg).run(&[&FoldSelfInverse]);
        assert_eq!(eg.find(map[r.index()]), eg.find(map[a.index()]));
    }
}
