//! Rewrite rules and rewriting strategies.
//!
//! * [`transpose`] — Table 1: CombineBinaryLeftTrans / RightTrans,
//!   CombineUnaryTrans, FoldTwoTrans, FoldNopTrans.
//! * [`pack`] — Table 2: MetaPackOperation (exploration) and FoldNopPack
//!   (optimization), the Auto Vectorize pass (§3.1.2).
//! * [`algebraic`] — small algebraic cleanups used by all pipelines.
//! * [`greedy`] — the *destructive* sequential rewriter traditional
//!   compilers use; it exhibits the phase-ordering problem of Fig. 2 and
//!   serves as the ablation baseline.

pub mod algebraic;
pub mod greedy;
pub mod pack;
pub mod transpose;

use crate::egraph::Rewrite;

/// The full nncase rule set (Tables 1 + 2 + algebraic).
pub fn all_rules(pack_options: &pack::PackOptions) -> Vec<Box<dyn Rewrite>> {
    let mut rules = transpose_rules();
    rules.extend(pack_rules(pack_options));
    rules.push(Box::new(algebraic::FoldSelfInverse));
    rules
}

/// Table 1 rules only.
pub fn transpose_rules() -> Vec<Box<dyn Rewrite>> {
    vec![
        Box::new(transpose::CombineBinaryLeftTrans),
        Box::new(transpose::CombineBinaryRightTrans),
        Box::new(transpose::CombineUnaryTrans),
        Box::new(transpose::FoldTwoTrans),
        Box::new(transpose::FoldNopTrans),
    ]
}

/// Table 2 rules only.
pub fn pack_rules(options: &pack::PackOptions) -> Vec<Box<dyn Rewrite>> {
    vec![
        Box::new(pack::MetaPackOperation::new(options.clone())),
        Box::new(pack::FoldNopPack),
    ]
}
