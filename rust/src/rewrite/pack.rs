//! Table 2: Auto Vectorize rules (§3.1.2).
//!
//! `MetaPackOperation` generates, for each packable operator, every
//! candidate `Unpack(PackedOp(Pack(arg, lanes, axes), ...), axes)`
//! sequence in a single pass; the candidates coexist in the e-graph.
//! `FoldNopPack` cancels adjacent `Pack(Unpack(x))` pairs, which is what
//! lets a blocked layout "pass through" a chain of operators (Fig. 3)
//! instead of bouncing back to the flat layout at every boundary.

use crate::egraph::{ClassId, EGraph, ENode, Rewrite, Tree};
use crate::ir::{Op, TensorType};

/// Packing configuration: which lane shapes the target's compute units
/// want. AVX2 vector units want flat 1-D lanes (e.g. `<8>` f32); tensor
/// units (AMX-like / MXU-like) want 2-D blocks (e.g. `<16,16>`).
#[derive(Debug, Clone)]
pub struct PackOptions {
    /// 1-D lane widths for vector units.
    pub vector_lanes: Vec<usize>,
    /// 2-D block shapes for tensor units.
    pub tensor_blocks: Vec<(usize, usize)>,
}

impl Default for PackOptions {
    fn default() -> Self {
        // AVX2: 8 f32 lanes. Tensor-unit blocks: 16x16 (AMX tile-like,
        // also the MXU-aligned block the Pallas kernel uses on TPU).
        PackOptions { vector_lanes: vec![8], tensor_blocks: vec![(16, 16)] }
    }
}

fn divides(ty: &TensorType, axis: usize, lane: usize) -> bool {
    axis < ty.shape.rank() && ty.shape.0[axis] % lane == 0 && ty.shape.0[axis] >= lane
}

/// `Op(...) -> Unpack(PackedOp(Pack(arg_i, lanes, axes)...), axes)`
pub struct MetaPackOperation {
    options: PackOptions,
}

impl MetaPackOperation {
    pub fn new(options: PackOptions) -> Self {
        MetaPackOperation { options }
    }

    /// Candidate (lanes, axes) pairs for a tensor type.
    fn candidates(&self, ty: &TensorType) -> Vec<(Vec<usize>, Vec<usize>)> {
        let mut out = Vec::new();
        if ty.is_packed() {
            return out;
        }
        let r = ty.shape.rank();
        if r == 0 {
            return out;
        }
        // 1-D vector packs on the innermost axis.
        for &l in &self.options.vector_lanes {
            if divides(ty, r - 1, l) {
                out.push((vec![l], vec![r - 1]));
            }
        }
        // 2-D blocks on the last two axes.
        if r >= 2 {
            for &(bm, bn) in &self.options.tensor_blocks {
                if divides(ty, r - 2, bm) && divides(ty, r - 1, bn) {
                    out.push((vec![bm, bn], vec![r - 2, r - 1]));
                }
            }
        }
        out
    }
}

impl Rewrite for MetaPackOperation {
    fn name(&self) -> &'static str {
        "MetaPackOperation"
    }

    fn matches(&self, eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
        let mut trees = Vec::new();
        match &node.op {
            // MatMul: pack A as [M,K]<bm,bk>, B as [K,N]<bk,bn>.
            Op::MatMul => {
                let (a, b) = (node.children[0], node.children[1]);
                let (ta, tb) = (&eg.class(a).ty, &eg.class(b).ty);
                if ta.is_packed() || tb.is_packed() {
                    return trees;
                }
                let (ra, rb) = (ta.shape.rank(), tb.shape.rank());
                for &(bm, bn) in &self.options.tensor_blocks {
                    // Use a square block for K so <bm,bk> x <bk,bn> chains.
                    let bk = bn;
                    if divides(ta, ra - 2, bm)
                        && divides(ta, ra - 1, bk)
                        && divides(tb, rb - 2, bk)
                        && divides(tb, rb - 1, bn)
                    {
                        let pa = Tree::node(
                            Op::Pack { lanes: vec![bm, bk], axes: vec![ra - 2, ra - 1] },
                            vec![Tree::class(a)],
                        );
                        let pb = Tree::node(
                            Op::Pack { lanes: vec![bk, bn], axes: vec![rb - 2, rb - 1] },
                            vec![Tree::class(b)],
                        );
                        let mm = Tree::node(Op::MatMul, vec![pa, pb]);
                        // Output rank can exceed input ranks when batched;
                        // unpack axes are the last two of the output.
                        let out_ty = eg.node_type(node).expect("matmul type");
                        let ro = out_ty.shape.rank();
                        trees.push(Tree::node(Op::Unpack { axes: vec![ro - 2, ro - 1] }, vec![mm]));
                    }
                }
            }
            // Element-wise: pack with every candidate of the (sole) wide
            // input. Crucially this also fires with 2-D blocks, producing
            // the "Exp directly on blocked layout" variant of Fig. 3.
            Op::Unary(kind) => {
                let x = node.children[0];
                let tx = eg.class(x).ty.clone();
                for (lanes, axes) in self.candidates(&tx) {
                    let px = Tree::node(
                        Op::Pack { lanes: lanes.clone(), axes: axes.clone() },
                        vec![Tree::class(x)],
                    );
                    let op = Tree::node(Op::Unary(*kind), vec![px]);
                    trees.push(Tree::node(Op::Unpack { axes }, vec![op]));
                }
            }
            Op::Binary(kind) => {
                let (a, b) = (node.children[0], node.children[1]);
                let (ta, tb) = (eg.class(a).ty.clone(), eg.class(b).ty.clone());
                // Same-shape only (broadcast packing handled by scalar rhs).
                if ta.shape != tb.shape || ta.is_packed() || tb.is_packed() {
                    return trees;
                }
                for (lanes, axes) in self.candidates(&ta) {
                    let pa = Tree::node(
                        Op::Pack { lanes: lanes.clone(), axes: axes.clone() },
                        vec![Tree::class(a)],
                    );
                    let pb = Tree::node(
                        Op::Pack { lanes: lanes.clone(), axes: axes.clone() },
                        vec![Tree::class(b)],
                    );
                    let op = Tree::node(Op::Binary(*kind), vec![pa, pb]);
                    trees.push(Tree::node(Op::Unpack { axes }, vec![op]));
                }
            }
            _ => {}
        }
        trees
    }
}

/// `Pack(Unpack(x)) -> x` when lanes/axes match.
pub struct FoldNopPack;

impl Rewrite for FoldNopPack {
    fn name(&self) -> &'static str {
        "FoldNopPack"
    }

    fn matches(&self, eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
        let Op::Pack { lanes, axes } = &node.op else { return vec![] };
        let inner = node.children[0];
        let mut trees = Vec::new();
        for n in &eg.class(inner).nodes {
            if let Op::Unpack { axes: un_axes } = &n.op {
                let packed = n.children[0];
                let pty = &eg.class(packed).ty;
                if un_axes == axes && &pty.lanes == lanes && &pty.pack_axes == axes {
                    trees.push(Tree::class(packed));
                }
            }
        }
        trees
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::MachineSpec;
    use crate::egraph::{extract_wpmaxsat, roofline_cost_fn, EGraph, Runner, RunnerLimits};
    use crate::ir::{DType, Graph, UnaryKind};
    use crate::rewrite::pack_rules;

    fn saturate(g: &Graph, opts: &PackOptions) -> (EGraph, Vec<ClassId>) {
        let (mut eg, map) = EGraph::from_graph(g);
        let rules = pack_rules(opts);
        let refs: Vec<&dyn Rewrite> = rules.iter().map(|r| r.as_ref()).collect();
        Runner::new(&mut eg)
            .with_limits(RunnerLimits { max_iters: 6, max_nodes: 20_000 })
            .run(&refs);
        (eg, map)
    }

    /// Figure 3: O = MatMul(Exp(MatMul(Q, K)), V). After Auto Vectorize,
    /// the extracted graph must keep data in the blocked layout through
    /// the whole chain: exactly 3 Packs (Q, K, V), 1 Unpack (O), and a
    /// *packed* Exp in between.
    #[test]
    fn attention_pass_through_layout() {
        let mut g = Graph::new();
        let q = g.input("Q", &[64, 64], DType::F32);
        let k = g.input("K", &[64, 64], DType::F32);
        let v = g.input("V", &[64, 64], DType::F32);
        let s = g.matmul(q, k);
        let e = g.unary(UnaryKind::Exp, s);
        let o = g.matmul(e, v);
        g.mark_output(o);

        let (eg, map) = saturate(&g, &PackOptions::default());
        let machine = MachineSpec::ryzen_5900x();
        let cost = roofline_cost_fn(&machine);
        let ex = extract_wpmaxsat(&eg, &[map[o.index()]], &cost);

        let live = ex.graph.live_nodes();
        let count = |pred: &dyn Fn(&crate::ir::Op) -> bool| {
            live.iter().filter(|&&id| pred(&ex.graph.node(id).op)).count()
        };
        let n_pack = count(&|op| matches!(op, Op::Pack { .. }));
        let n_unpack = count(&|op| matches!(op, Op::Unpack { .. }));
        let packed_exp = live.iter().any(|&id| {
            let n = ex.graph.node(id);
            matches!(n.op, Op::Unary(UnaryKind::Exp)) && n.ty.is_packed()
        });
        assert_eq!(n_pack, 3, "Q, K, V each packed once:\n{}", ex.graph.dump());
        assert_eq!(n_unpack, 1, "only the output unpacks:\n{}", ex.graph.dump());
        assert!(packed_exp, "Exp must operate directly on the blocked layout");
    }

    #[test]
    fn fold_nop_pack_cancels() {
        // pack(unpack(x)) with matching lanes collapses to x.
        let mut g = Graph::new();
        let a = g.input("A", &[64, 64], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        g.mark_output(e);
        let (mut eg, map) = EGraph::from_graph(&g);
        // Manually build pack(unpack(pack(a))).
        let pa = Tree::node(
            Op::Pack { lanes: vec![16, 16], axes: vec![0, 1] },
            vec![Tree::class(map[a.index()])],
        )
        .add_to(&mut eg);
        let up = Tree::node(Op::Unpack { axes: vec![0, 1] }, vec![Tree::class(pa)]).add_to(&mut eg);
        let pup = Tree::node(
            Op::Pack { lanes: vec![16, 16], axes: vec![0, 1] },
            vec![Tree::class(up)],
        )
        .add_to(&mut eg);
        let rules = pack_rules(&PackOptions::default());
        let refs: Vec<&dyn Rewrite> = rules.iter().map(|r| r.as_ref()).collect();
        Runner::new(&mut eg).run(&refs);
        assert_eq!(eg.find(pup), eg.find(pa), "Pack(Unpack(x)) must merge with x");
    }

    #[test]
    fn meta_pack_respects_divisibility() {
        // 60 is not divisible by 16: no tensor-block candidates, but the
        // 8-lane vector pack does not fire on axis 60 % 8 != 0 either;
        // use 60x24 -> only vector lane 8 on the last axis fires.
        let mut g = Graph::new();
        let a = g.input("A", &[60, 24], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        g.mark_output(e);
        let (eg, map) = saturate(&g, &PackOptions::default());
        let class = eg.class(map[e.index()]);
        // The class has the flat exp and exactly one packed alternative
        // (unpack of vector-packed exp).
        let n_unpack = class.nodes.iter().filter(|n| matches!(n.op, Op::Unpack { .. })).count();
        assert_eq!(n_unpack, 1);
    }

    #[test]
    fn packed_variants_do_not_fire_twice() {
        let mut g = Graph::new();
        let a = g.input("A", &[64, 64], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        g.mark_output(e);
        let (eg, _) = saturate(&g, &PackOptions::default());
        // No Pack-of-Pack anywhere.
        for (_, class) in eg.classes() {
            for n in &class.nodes {
                if let Op::Pack { .. } = n.op {
                    let child_ty = &eg.class(n.children[0]).ty;
                    assert!(!child_ty.is_packed(), "pack of packed tensor leaked into egraph");
                }
            }
        }
    }
}
