//! Table 1: transpose-motion rewrite rules.
//!
//! | Rule | Signature |
//! |------|-----------|
//! | CombineBinaryLeftTrans  | `Binary(T_p(A), B) -> T_p(Binary(A, T_p⁻¹(B)))` |
//! | CombineBinaryRightTrans | `Binary(A, T_p(B)) -> T_p(Binary(T_p⁻¹(A), B))` |
//! | CombineUnaryTrans       | `Unary(T_p(A)) -> T_p(Unary(A))` |
//! | FoldTwoTrans            | `T_p2(T_p1(A)) -> T_{p1∘p2}(A)` |
//! | FoldNopTrans            | `T_identity(A) -> A` |

use crate::egraph::{ClassId, EGraph, ENode, Rewrite, Tree};
use crate::ir::{Op, Shape};

/// Inverse of a permutation.
pub fn invert_perm(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

/// Composition per Table 1's FoldTwoTrans: applying `p1` then `p2` equals
/// one transpose with `perm[i] = p1[p2[i]]`.
pub fn compose_perm(p1: &[usize], p2: &[usize]) -> Vec<usize> {
    p2.iter().map(|&i| p1[i]).collect()
}

/// Find transpose members of an e-class; returns (perm, child).
fn transposes_in(eg: &EGraph, class: ClassId) -> Vec<(Vec<usize>, ClassId)> {
    eg.class(class)
        .nodes
        .iter()
        .filter_map(|n| match &n.op {
            Op::Transpose { perm } => Some((perm.clone(), n.children[0])),
            _ => None,
        })
        .collect()
}

/// `Binary(T_p(A), B) -> T_p(Binary(A, T_p⁻¹(B)))`
pub struct CombineBinaryLeftTrans;

impl Rewrite for CombineBinaryLeftTrans {
    fn name(&self) -> &'static str {
        "CombineBinaryLeftTrans"
    }

    fn matches(&self, eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
        let Op::Binary(kind) = node.op else { return vec![] };
        let (lhs, rhs) = (node.children[0], node.children[1]);
        // Only rank-preserving same-shape binaries (no broadcasting).
        if eg.class(lhs).ty.shape != eg.class(rhs).ty.shape {
            return vec![];
        }
        transposes_in(eg, lhs)
            .into_iter()
            .map(|(perm, a)| {
                let inv = invert_perm(&perm);
                Tree::node(
                    Op::Transpose { perm: perm.clone() },
                    vec![Tree::node(
                        Op::Binary(kind),
                        vec![
                            Tree::class(a),
                            Tree::node(Op::Transpose { perm: inv }, vec![Tree::class(rhs)]),
                        ],
                    )],
                )
            })
            .collect()
    }
}

/// `Binary(A, T_p(B)) -> T_p(Binary(T_p⁻¹(A), B))`
pub struct CombineBinaryRightTrans;

impl Rewrite for CombineBinaryRightTrans {
    fn name(&self) -> &'static str {
        "CombineBinaryRightTrans"
    }

    fn matches(&self, eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
        let Op::Binary(kind) = node.op else { return vec![] };
        let (lhs, rhs) = (node.children[0], node.children[1]);
        if eg.class(lhs).ty.shape != eg.class(rhs).ty.shape {
            return vec![];
        }
        transposes_in(eg, rhs)
            .into_iter()
            .map(|(perm, b)| {
                let inv = invert_perm(&perm);
                Tree::node(
                    Op::Transpose { perm: perm.clone() },
                    vec![Tree::node(
                        Op::Binary(kind),
                        vec![
                            Tree::node(Op::Transpose { perm: inv }, vec![Tree::class(lhs)]),
                            Tree::class(b),
                        ],
                    )],
                )
            })
            .collect()
    }
}

/// `Unary(T_p(A)) -> T_p(Unary(A))`
pub struct CombineUnaryTrans;

impl Rewrite for CombineUnaryTrans {
    fn name(&self) -> &'static str {
        "CombineUnaryTrans"
    }

    fn matches(&self, eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
        let Op::Unary(kind) = node.op else { return vec![] };
        transposes_in(eg, node.children[0])
            .into_iter()
            .map(|(perm, a)| {
                Tree::node(
                    Op::Transpose { perm },
                    vec![Tree::node(Op::Unary(kind), vec![Tree::class(a)])],
                )
            })
            .collect()
    }
}

/// `T_p2(T_p1(A)) -> T_{p1[p2[i]]}(A)`
pub struct FoldTwoTrans;

impl Rewrite for FoldTwoTrans {
    fn name(&self) -> &'static str {
        "FoldTwoTrans"
    }

    fn matches(&self, eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
        let Op::Transpose { perm: p2 } = &node.op else { return vec![] };
        transposes_in(eg, node.children[0])
            .into_iter()
            .map(|(p1, a)| {
                Tree::node(Op::Transpose { perm: compose_perm(&p1, p2) }, vec![Tree::class(a)])
            })
            .collect()
    }
}

/// `T_[0,1,..,n](A) -> A`
pub struct FoldNopTrans;

impl Rewrite for FoldNopTrans {
    fn name(&self) -> &'static str {
        "FoldNopTrans"
    }

    fn matches(&self, _eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
        match &node.op {
            Op::Transpose { perm } if Shape::is_identity_perm(perm) => {
                vec![Tree::class(node.children[0])]
            }
            _ => vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{extract_greedy, EGraph, Runner};
    use crate::ir::{BinaryKind, DType, Graph, TensorType, UnaryKind};
    use crate::rewrite::transpose_rules;

    #[test]
    fn perm_helpers() {
        assert_eq!(invert_perm(&[2, 0, 1]), vec![1, 2, 0]);
        // p1 then p2 == composed
        let p1 = [1usize, 0];
        let p2 = [1usize, 0];
        assert_eq!(compose_perm(&p1, &p2), vec![0, 1]);
        // semantic check on a shape
        let s = Shape::of(&[2, 3, 4]);
        let p1 = [2usize, 0, 1];
        let p2 = [1usize, 2, 0];
        let twice = s.permute(&p1).permute(&p2);
        assert_eq!(twice, s.permute(&compose_perm(&p1, &p2)));
    }

    /// The motivating example of Fig. 2: the graph
    /// `Add(T(A), Unary(T(B)))` where the transposes can be fully
    /// eliminated only by pushing them through the binary *left* first.
    /// After saturation + extraction no transpose should survive when A
    /// and B have symmetric shapes and the output is consumed transposed.
    #[test]
    fn figure2_all_transposes_eliminated() {
        let mut g = Graph::new();
        let a = g.input("A", &[8, 8], DType::F32);
        let b = g.input("B", &[8, 8], DType::F32);
        let ta = g.transpose(a, &[1, 0]);
        let tb = g.transpose(b, &[1, 0]);
        let ub = g.unary(UnaryKind::Exp, tb);
        let sum = g.binary(BinaryKind::Add, ta, ub);
        // Consume the result transposed so the pushed-out transpose can
        // cancel: out = T(sum).
        let out = g.transpose(sum, &[1, 0]);
        g.mark_output(out);

        let (mut eg, map) = EGraph::from_graph(&g);
        let rules = transpose_rules();
        let rule_refs: Vec<&dyn crate::egraph::Rewrite> =
            rules.iter().map(|r| r.as_ref()).collect();
        let report = Runner::new(&mut eg).run(&rule_refs);
        assert!(report.saturated, "rule set must saturate: {report:?}");

        // Cost: transposes expensive, rest cheap.
        let cost = |n: &crate::egraph::ENode, _: &[&TensorType], _: &TensorType| -> u64 {
            match n.op {
                crate::ir::Op::Transpose { .. } => 1000,
                _ => 1,
            }
        };
        let ex = extract_greedy(&eg, &[map[out.index()]], &cost);
        let n_trans = ex
            .graph
            .live_nodes()
            .iter()
            .filter(|&&id| matches!(ex.graph.node(id).op, crate::ir::Op::Transpose { .. }))
            .count();
        assert_eq!(n_trans, 0, "saturation must eliminate every transpose:\n{}", ex.graph.dump());
    }

    /// The greedy suboptimal path of Fig. 2(c) keeps >= 1 transpose; the
    /// e-graph result above keeps 0. This is asserted end-to-end in
    /// rewrite::greedy tests; here we check the left-first path exists in
    /// the saturated graph.
    #[test]
    fn fold_two_then_nop() {
        let mut g = Graph::new();
        let a = g.input("A", &[4, 6], DType::F32);
        let t1 = g.transpose(a, &[1, 0]);
        let t2 = g.transpose(t1, &[1, 0]);
        g.mark_output(t2);
        let (mut eg, map) = EGraph::from_graph(&g);
        let rules = transpose_rules();
        let rule_refs: Vec<&dyn crate::egraph::Rewrite> =
            rules.iter().map(|r| r.as_ref()).collect();
        Runner::new(&mut eg).run(&rule_refs);
        // t2 must now be equivalent to a.
        assert_eq!(eg.find(map[t2.index()]), eg.find(map[a.index()]));
    }

    #[test]
    fn unary_trans_commute() {
        let mut g = Graph::new();
        let a = g.input("A", &[4, 6], DType::F32);
        let t = g.transpose(a, &[1, 0]);
        let e = g.unary(UnaryKind::Exp, t);
        g.mark_output(e);
        let (mut eg, map) = EGraph::from_graph(&g);
        let rules = transpose_rules();
        let rule_refs: Vec<&dyn crate::egraph::Rewrite> =
            rules.iter().map(|r| r.as_ref()).collect();
        Runner::new(&mut eg).run(&rule_refs);
        // The class of e must contain a Transpose node (the commuted form).
        let has_trans = eg
            .class(map[e.index()])
            .nodes
            .iter()
            .any(|n| matches!(n.op, crate::ir::Op::Transpose { .. }));
        assert!(has_trans);
    }
}
