//! The destructive, sequential rewriter — the baseline that exhibits the
//! phase-ordering problem (Fig. 2).
//!
//! Traditional term rewriting applies rules one at a time, in a fixed
//! priority order, destructively replacing the matched subgraph. Once a
//! rule fires, the alternative orderings are gone. Fig. 2(c) shows the
//! failure mode: applying `CombineBinaryRightTrans` before
//! `CombineBinaryLeftTrans` isolates one transpose and leaves a redundant
//! operator behind. We reproduce that exact behaviour here for the
//! ablation bench.

use crate::ir::{Graph, Node, NodeId, Op, Shape};

use super::transpose::{compose_perm, invert_perm};

/// Canonicalization direction of the destructive rewriter. A greedy
/// pipeline commits to one combine-binary direction (this is the
/// phase-ordering commitment of Fig. 2): `RightFirst` pushes transposes
/// found on the *right* operand (Fig. 2(c)'s suboptimal choice on the
/// example graph), `LeftFirst` pushes those on the *left* (Fig. 2(b)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GreedyOrder {
    RightFirst,
    LeftFirst,
}

/// Destructively rewrite `g` to a fixed point with the Table-1 rules in
/// the given priority order. Returns the rewritten graph and the number
/// of rule applications.
pub fn greedy_rewrite(g: &Graph, order: GreedyOrder) -> (Graph, usize) {
    let mut nodes: Vec<Node> = g.nodes.clone();
    let mut outputs: Vec<NodeId> = g.outputs.clone();
    let mut applications = 0usize;

    // Work on a mutable node vec with structural replacement: each rule
    // application appends nodes and redirects one node in place.
    let mut changed = true;
    while changed {
        changed = false;
        for idx in 0..nodes.len() {
            let node = nodes[idx].clone();
            let fired = match order {
                GreedyOrder::RightFirst => {
                    try_fold_nop(&mut nodes, idx, &node)
                        || try_fold_two(&mut nodes, idx, &node)
                        || try_binary_right(&mut nodes, idx, &node)
                        || try_unary(&mut nodes, idx, &node)
                }
                GreedyOrder::LeftFirst => {
                    try_fold_nop(&mut nodes, idx, &node)
                        || try_fold_two(&mut nodes, idx, &node)
                        || try_binary_left(&mut nodes, idx, &node)
                        || try_unary(&mut nodes, idx, &node)
                }
            };
            if fired {
                applications += 1;
                changed = true;
            }
        }
    }

    // Rebuild a clean graph (re-inferring types, dropping dead nodes).
    // Rule applications may create forward references (replaced nodes
    // point at appended ones), so emit by DFS from the outputs.
    let mut out = Graph::new();
    let mut remap: Vec<Option<NodeId>> = vec![None; nodes.len()];
    fn emit(
        nodes: &[Node],
        i: usize,
        out: &mut Graph,
        remap: &mut Vec<Option<NodeId>>,
    ) -> NodeId {
        if let Some(id) = remap[i] {
            return id;
        }
        let n = &nodes[i];
        let inputs: Vec<NodeId> =
            n.inputs.iter().map(|&x| emit(nodes, x.index(), out, remap)).collect();
        let id = match &n.op {
            Op::Input(name) => out.input(name, n.ty.shape.dims(), n.ty.dtype),
            Op::Const(name) => out.constant(name, n.ty.shape.dims(), n.ty.dtype),
            op => out.add(op.clone(), &inputs),
        };
        remap[i] = Some(id);
        id
    }
    for o in &mut outputs {
        *o = emit(&nodes, o.index(), &mut out, &mut remap);
    }
    for o in outputs {
        out.mark_output(o);
    }
    // Re-extract live subgraph only.
    let live = out.live_nodes();
    let mut clean = Graph::new();
    let mut remap2: std::collections::HashMap<NodeId, NodeId> = Default::default();
    for id in live {
        let n = out.node(id);
        let inputs: Vec<NodeId> = n.inputs.iter().map(|x| remap2[x]).collect();
        let new_id = match &n.op {
            Op::Input(name) => clean.input(name, n.ty.shape.dims(), n.ty.dtype),
            Op::Const(name) => clean.constant(name, n.ty.shape.dims(), n.ty.dtype),
            op => clean.add(op.clone(), &inputs),
        };
        remap2.insert(id, new_id);
    }
    for o in &out.outputs {
        clean.mark_output(remap2[o]);
    }
    (clean, applications)
}

fn as_transpose(nodes: &[Node], id: NodeId) -> Option<(Vec<usize>, NodeId)> {
    match &nodes[id.index()].op {
        Op::Transpose { perm } => Some((perm.clone(), nodes[id.index()].inputs[0])),
        _ => None,
    }
}

fn push_node(nodes: &mut Vec<Node>, op: Op, inputs: Vec<NodeId>) -> NodeId {
    let in_tys: Vec<&crate::ir::TensorType> =
        inputs.iter().map(|&i| &nodes[i.index()].ty).collect();
    let ty = crate::ir::infer_type(&op, &in_tys).expect("greedy rewrite type error");
    let id = NodeId(nodes.len() as u32);
    nodes.push(Node { op, inputs, ty });
    id
}

/// FoldNopTrans: replace the node in place with an identity view of its
/// input (Reshape to same shape models the no-op).
fn try_fold_nop(nodes: &mut Vec<Node>, idx: usize, node: &Node) -> bool {
    if let Op::Transpose { perm } = &node.op {
        if Shape::is_identity_perm(perm) {
            let src = node.inputs[0];
            nodes[idx] = Node {
                op: Op::Reshape { shape: nodes[src.index()].ty.shape.clone() },
                inputs: vec![src],
                ty: nodes[src.index()].ty.clone(),
            };
            return true;
        }
    }
    false
}

fn try_fold_two(nodes: &mut Vec<Node>, idx: usize, node: &Node) -> bool {
    if let Op::Transpose { perm: p2 } = &node.op {
        if let Some((p1, src)) = as_transpose(nodes, node.inputs[0]) {
            let composed = compose_perm(&p1, p2);
            let ty = nodes[src.index()].ty.clone();
            let mut out_ty = ty.clone();
            out_ty.shape = ty.shape.permute(&composed);
            nodes[idx] =
                Node { op: Op::Transpose { perm: composed }, inputs: vec![src], ty: out_ty };
            return true;
        }
    }
    false
}

fn try_unary(nodes: &mut Vec<Node>, idx: usize, node: &Node) -> bool {
    if let Op::Unary(kind) = node.op {
        if let Some((perm, src)) = as_transpose(nodes, node.inputs[0]) {
            let u = push_node(nodes, Op::Unary(kind), vec![src]);
            let out_ty = node.ty.clone();
            nodes[idx] = Node { op: Op::Transpose { perm }, inputs: vec![u], ty: out_ty };
            return true;
        }
    }
    false
}

fn try_binary_left(nodes: &mut Vec<Node>, idx: usize, node: &Node) -> bool {
    if let Op::Binary(kind) = node.op {
        let (l, r) = (node.inputs[0], node.inputs[1]);
        if nodes[l.index()].ty.shape != nodes[r.index()].ty.shape {
            return false;
        }
        if let Some((perm, a)) = as_transpose(nodes, l) {
            // Destructive: the transpose on the left is consumed; the
            // right operand gets an inverse transpose.
            let inv = invert_perm(&perm);
            let tb = push_node(nodes, Op::Transpose { perm: inv }, vec![r]);
            let bin = push_node(nodes, Op::Binary(kind), vec![a, tb]);
            let out_ty = node.ty.clone();
            nodes[idx] = Node { op: Op::Transpose { perm }, inputs: vec![bin], ty: out_ty };
            return true;
        }
    }
    false
}

fn try_binary_right(nodes: &mut Vec<Node>, idx: usize, node: &Node) -> bool {
    if let Op::Binary(kind) = node.op {
        let (l, r) = (node.inputs[0], node.inputs[1]);
        if nodes[l.index()].ty.shape != nodes[r.index()].ty.shape {
            return false;
        }
        if let Some((perm, b)) = as_transpose(nodes, r) {
            let inv = invert_perm(&perm);
            let ta = push_node(nodes, Op::Transpose { perm: inv }, vec![l]);
            let bin = push_node(nodes, Op::Binary(kind), vec![ta, b]);
            let out_ty = node.ty.clone();
            nodes[idx] = Node { op: Op::Transpose { perm }, inputs: vec![bin], ty: out_ty };
            return true;
        }
    }
    false
}

/// Count live transpose nodes (the Fig. 2 quality metric).
pub fn count_transposes(g: &Graph) -> usize {
    g.live_nodes()
        .iter()
        .filter(|&&id| matches!(g.node(id).op, Op::Transpose { .. }))
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{BinaryKind, DType, UnaryKind};

    /// Build the Fig. 2(a) graph: out = T(Add(T(A), Exp(T(B)))).
    fn figure2_graph() -> (Graph, NodeId) {
        let mut g = Graph::new();
        let a = g.input("A", &[8, 8], DType::F32);
        let b = g.input("B", &[8, 8], DType::F32);
        let ta = g.transpose(a, &[1, 0]);
        let tb = g.transpose(b, &[1, 0]);
        let ub = g.unary(UnaryKind::Exp, tb);
        let sum = g.binary(BinaryKind::Add, ta, ub);
        let out = g.transpose(sum, &[1, 0]);
        g.mark_output(out);
        (g, out)
    }

    /// Asymmetric variant where the greedy direction choice genuinely
    /// diverges: out = Add(A, Exp(T(B))). Pushing the (post-unary-commute)
    /// right transpose outward forces an un-cancellable inverse transpose
    /// onto the plain input A *and* an outer transpose — the greedy
    /// rewriter makes the graph WORSE, while left-first leaves the single
    /// original transpose in place.
    fn asymmetric_graph() -> Graph {
        let mut g = Graph::new();
        let a = g.input("A", &[8, 8], DType::F32);
        let b = g.input("B", &[8, 8], DType::F32);
        let tb = g.transpose(b, &[1, 0]);
        let ub = g.unary(UnaryKind::Exp, tb);
        let sum = g.binary(BinaryKind::Add, a, ub);
        g.mark_output(sum);
        g
    }

    #[test]
    fn greedy_left_first_eliminates_all_fig2() {
        let (g, _) = figure2_graph();
        let (left, _) = greedy_rewrite(&g, GreedyOrder::LeftFirst);
        assert_eq!(
            count_transposes(&left),
            0,
            "left-first eliminates all transposes:\n{}",
            left.dump()
        );
    }

    #[test]
    fn greedy_right_first_is_suboptimal() {
        let g = asymmetric_graph();
        let (right, _) = greedy_rewrite(&g, GreedyOrder::RightFirst);
        let (left, _) = greedy_rewrite(&g, GreedyOrder::LeftFirst);
        let (rt, lt) = (count_transposes(&right), count_transposes(&left));
        assert!(
            rt > lt,
            "right-first must leave more transposes (got right={rt}, left={lt})\n\
             right:\n{}\nleft:\n{}",
            right.dump(),
            left.dump()
        );
    }

    #[test]
    fn greedy_preserves_semantics_shape() {
        let (g, out) = figure2_graph();
        let want = g.node(out).ty.clone();
        for order in [GreedyOrder::RightFirst, GreedyOrder::LeftFirst] {
            let (h, _) = greedy_rewrite(&g, order);
            let got = &h.node(*h.outputs.last().unwrap()).ty;
            assert_eq!(got.shape, want.shape, "{order:?}");
            assert_eq!(got.dtype, want.dtype);
        }
    }

    #[test]
    fn fixed_point_terminates() {
        let (g, _) = figure2_graph();
        let (_, apps) = greedy_rewrite(&g, GreedyOrder::LeftFirst);
        assert!(apps > 0 && apps < 100);
    }
}
