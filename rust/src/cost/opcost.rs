//! Per-op FLOP and byte accounting used by the Roofline cost model.

use crate::ir::{Op, TensorType, UnaryKind};

/// FLOPs performed by `op` given input/output types. Transcendentals are
/// weighted by their polynomial cost on AVX2 (vectorized `exp` ≈ 8 FLOPs
/// per element with a degree-7 estrin polynomial + scalb).
pub fn op_flops(op: &Op, ins: &[&TensorType], out: &TensorType) -> u64 {
    let out_elems = out.numel() as u64;
    match op {
        Op::MatMul => {
            // 2 * M * N * K, batched over leading dims (logical elements,
            // so packed and flat layouts report identical FLOPs).
            let a = ins[0];
            let k_logical = {
                let r = a.shape.rank();
                let mut k = a.shape.0[r - 1];
                if a.is_packed() && a.lanes.len() == 2 {
                    k *= a.lanes[1];
                }
                k as u64
            };
            2 * out_elems * k_logical
        }
        Op::Unary(UnaryKind::Exp | UnaryKind::Log) => 8 * out_elems,
        Op::Unary(UnaryKind::Silu) => 10 * out_elems, // exp + mul + div
        Op::Unary(UnaryKind::Sqrt | UnaryKind::Rsqrt) => 4 * out_elems,
        Op::Unary(_) => out_elems,
        Op::Binary(_) => out_elems,
        Op::Reduce { .. } => ins[0].numel() as u64,
        Op::Softmax { .. } => 12 * out_elems, // max + sub + exp + sum + div
        Op::RmsNorm { .. } => 6 * out_elems,  // sq + mean + rsqrt + mul + mul
        Op::Rope { .. } => 6 * out_elems,     // 2 mul + 1 add/sub per pair, ×2
        Op::Gather => 0,
        // Pure data movement:
        Op::Transpose { .. }
        | Op::Reshape { .. }
        | Op::Slice { .. }
        | Op::Concat { .. }
        | Op::Pack { .. }
        | Op::Unpack { .. }
        | Op::Boxing { .. }
        | Op::Input(_)
        | Op::Const(_)
        | Op::Scalar(_) => 0,
    }
}

/// Bytes moved through memory by `op`: all inputs read + output written.
/// View ops are free after alias analysis (§3.3.1); `Pack`/`Unpack` and
/// `Transpose` pay a full read+write (this is exactly the conversion
/// overhead the Auto Vectorize trade-off weighs, §3.1.2).
pub fn op_bytes(op: &Op, ins: &[&TensorType], out: &TensorType) -> u64 {
    match op {
        Op::Reshape { .. } | Op::Slice { .. } | Op::Input(_) | Op::Const(_) | Op::Scalar(_) => 0,
        Op::Boxing { .. } => 0, // costed by the alpha-beta comm model instead
        _ => {
            let read: u64 = ins.iter().map(|t| t.size_bytes() as u64).sum();
            read + out.size_bytes() as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, Shape};

    fn t(dims: &[usize]) -> TensorType {
        TensorType::of(dims, DType::F32)
    }

    #[test]
    fn matmul_flops() {
        let a = t(&[128, 256]);
        let b = t(&[256, 64]);
        let out = t(&[128, 64]);
        assert_eq!(op_flops(&Op::MatMul, &[&a, &b], &out), 2 * 128 * 64 * 256);
    }

    #[test]
    fn packed_matmul_same_flops() {
        // [8,16]<16,16> x [16,4]<16,16> == logical 128x256 * 256x64.
        let mut a = t(&[8, 16]);
        a.lanes = vec![16, 16];
        a.pack_axes = vec![0, 1];
        let mut b = t(&[16, 4]);
        b.lanes = vec![16, 16];
        b.pack_axes = vec![0, 1];
        let mut out = t(&[8, 4]);
        out.lanes = vec![16, 16];
        out.pack_axes = vec![0, 1];
        assert_eq!(op_flops(&Op::MatMul, &[&a, &b], &out), 2 * 128 * 64 * 256);
    }

    #[test]
    fn views_are_free() {
        let x = t(&[64, 64]);
        let out = t(&[4096]);
        assert_eq!(op_bytes(&Op::Reshape { shape: Shape::of(&[4096]) }, &[&x], &out), 0);
        // Transpose is NOT free: it is real data movement.
        let tr = t(&[64, 64]);
        assert_eq!(
            op_bytes(&Op::Transpose { perm: vec![1, 0] }, &[&x], &tr),
            2 * 64 * 64 * 4
        );
    }

    #[test]
    fn pack_costs_movement() {
        let x = t(&[64, 64]);
        let mut packed = t(&[4, 4]);
        packed.lanes = vec![16, 16];
        packed.pack_axes = vec![0, 1];
        let b = op_bytes(&Op::Pack { lanes: vec![16, 16], axes: vec![0, 1] }, &[&x], &packed);
        assert_eq!(b, 2 * 64 * 64 * 4);
    }
}
