//! Roofline cost model (Williams et al.), the weight source for e-graph
//! extraction (§3.1.1): `time = max(flops / peak, bytes / bandwidth)`.

use super::{op_bytes, op_flops, MachineSpec};
use crate::ir::{Op, TensorType};

/// Cost of one e-node under the Roofline model, in abstract "nanoseconds"
/// (u64 so it can be used as a WPMaxSAT weight).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RooflineCost {
    pub ns: u64,
    pub flops: u64,
    pub bytes: u64,
}

/// Execution-time estimate for a kernel of `flops` FLOPs moving `bytes`
/// bytes on `machine` with `threads` threads, plus `efficiency` derating
/// of peak compute (compilers rarely reach 100% of peak).
pub fn roofline_time_s(
    flops: u64,
    bytes: u64,
    machine: &MachineSpec,
    threads: usize,
    dtype_bytes: usize,
    efficiency: f64,
) -> f64 {
    let peak = machine.peak_flops(threads, dtype_bytes) * efficiency.clamp(0.01, 1.0);
    let bw = machine.dram_bw(threads);
    let t_comp = flops as f64 / peak;
    let t_mem = bytes as f64 / bw;
    t_comp.max(t_mem)
}

/// Roofline weight of a single e-node. Packed (blocked-layout) compute
/// ops run at higher efficiency — the tensor-unit saturation the paper's
/// MetaPackOperation trades against layout-conversion cost. Pack/Unpack
/// and Transpose are pure bandwidth.
pub fn enode_cost(
    op: &Op,
    ins: &[&TensorType],
    out: &TensorType,
    machine: &MachineSpec,
) -> RooflineCost {
    let flops = op_flops(op, ins, out);
    let bytes = op_bytes(op, ins, out);
    let dtype_bytes = out.dtype.size_bytes();
    // Efficiency model: blocked layouts keep the FMA pipes fed
    // (GotoBLAS-style packing); flat matmuls thrash associativity.
    let efficiency = match op {
        Op::MatMul if out.is_packed() => 0.85,
        Op::MatMul => 0.35,
        Op::Unary(_) | Op::Binary(_) if out.is_packed() => 0.80,
        Op::Unary(_) | Op::Binary(_) => 0.60,
        _ => 0.50,
    };
    let secs = roofline_time_s(flops, bytes, machine, 1, dtype_bytes, efficiency);
    RooflineCost { ns: (secs * 1e9).ceil() as u64 + 1, flops, bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;

    fn t(dims: &[usize]) -> TensorType {
        TensorType::of(dims, DType::F32)
    }

    #[test]
    fn compute_vs_memory_bound() {
        let m = MachineSpec::ryzen_5900x();
        // Huge FLOPs, no bytes -> compute bound.
        let t1 = roofline_time_s(1_000_000_000, 0, &m, 1, 4, 1.0);
        assert!((t1 - 1e9 / 144e9).abs() < 1e-6);
        // No FLOPs, lots of bytes -> memory bound.
        let t2 = roofline_time_s(0, 24_000_000_000, &m, 1, 4, 1.0);
        assert!((t2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn packed_matmul_cheaper_than_flat() {
        let m = MachineSpec::ryzen_5900x();
        let a = t(&[512, 512]);
        let b = t(&[512, 512]);
        let flat = enode_cost(&Op::MatMul, &[&a, &b], &t(&[512, 512]), &m);

        let mut pa = t(&[32, 32]);
        pa.lanes = vec![16, 16];
        pa.pack_axes = vec![0, 1];
        let pb = pa.clone();
        let pout = pa.clone();
        let packed = enode_cost(&Op::MatMul, &[&pa, &pb], &pout, &m);
        assert!(
            packed.ns < flat.ns,
            "packed {} should beat flat {}",
            packed.ns,
            flat.ns
        );
        assert_eq!(packed.flops, flat.flops);
    }

    #[test]
    fn threads_reduce_time_until_bw_wall() {
        let m = MachineSpec::ryzen_5900x();
        let t1 = roofline_time_s(0, 1_000_000_000, &m, 1, 4, 1.0);
        let t2 = roofline_time_s(0, 1_000_000_000, &m, 2, 4, 1.0);
        let t8 = roofline_time_s(0, 1_000_000_000, &m, 8, 4, 1.0);
        assert!(t2 < t1);
        // 2T..8T are all capped by the 42 GB/s socket limit.
        assert_eq!(t2, t8);
    }
}
