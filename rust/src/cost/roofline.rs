//! Roofline cost model (Williams et al.), the weight source for e-graph
//! extraction (§3.1.1): `time = max(flops / peak, bytes / bandwidth)`.

use super::{op_bytes, op_flops, MachineSpec};
use crate::ir::{Op, TensorType};

/// Cost of one e-node under the Roofline model, in abstract "nanoseconds"
/// (u64 so it can be used as a WPMaxSAT weight).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RooflineCost {
    pub ns: u64,
    pub flops: u64,
    pub bytes: u64,
}

/// Execution-time estimate for a kernel of `flops` FLOPs moving `bytes`
/// bytes on `machine` with `threads` threads, plus `efficiency` derating
/// of peak compute (compilers rarely reach 100% of peak).
pub fn roofline_time_s(
    flops: u64,
    bytes: u64,
    machine: &MachineSpec,
    threads: usize,
    dtype_bytes: usize,
    efficiency: f64,
) -> f64 {
    let peak = machine.peak_flops(threads, dtype_bytes) * efficiency.clamp(0.01, 1.0);
    let bw = machine.dram_bw(threads);
    let t_comp = flops as f64 / peak;
    let t_mem = bytes as f64 / bw;
    t_comp.max(t_mem)
}

/// Weight-stream floor of one decode step, seconds: every decode token
/// streams the GEMM weight plane + norms once (the embedding table is
/// *not* streamed — decode gathers one row per token), so the
/// memory-bound decode throughput ceiling is
/// `1 / decode_weight_stream_s`. Priced from
/// [`crate::model::Qwen3Config::decode_stream_bytes`], which accounts
/// the GEMM matrices in the config's `weight_quant` format — group-wise
/// int8 weights cut the streamed bytes to ~¼ of f32 (int4 to ~⅛), which
/// is exactly the lever the fused dequant-GEMM kernels turn into decode
/// throughput (the llama.cpp/MNN-LLM low-bit-decode story). Compute
/// overlaps with the stream under the roofline, so this is a floor,
/// not an estimate.
pub fn decode_weight_stream_s(
    cfg: &crate::model::Qwen3Config,
    machine: &MachineSpec,
    threads: usize,
) -> f64 {
    cfg.decode_stream_bytes() as f64 / machine.dram_bw(threads)
}

/// Compute floor of one *prefill* token, seconds: a prompt position
/// costs ~`2 × params` FLOPs, and chunked prefill batches many
/// positions into one weight stream, so the prompt side is bound by
/// the FLOP roof, not the byte roof — the prefill/decode asymmetry the
/// span-based step API exploits. At `prefill_chunk = 1` prompt
/// ingestion degenerates to GEMV-shaped steps and pays
/// [`decode_weight_stream_s`] per position instead (memory-bound, and
/// on every preset a much higher floor — see the test below); the gap
/// between the two floors is the TTFT headroom chunking buys.
pub fn prefill_flops_s(
    cfg: &crate::model::Qwen3Config,
    machine: &MachineSpec,
    threads: usize,
) -> f64 {
    let flops_per_token = 2.0 * cfg.param_count() as f64;
    flops_per_token / machine.peak_flops(threads, cfg.dtype.size_bytes())
}

/// Roofline weight of a single e-node. Packed (blocked-layout) compute
/// ops run at higher efficiency — the tensor-unit saturation the paper's
/// MetaPackOperation trades against layout-conversion cost. Pack/Unpack
/// and Transpose are pure bandwidth.
pub fn enode_cost(
    op: &Op,
    ins: &[&TensorType],
    out: &TensorType,
    machine: &MachineSpec,
) -> RooflineCost {
    let flops = op_flops(op, ins, out);
    let bytes = op_bytes(op, ins, out);
    let dtype_bytes = out.dtype.size_bytes();
    // Efficiency model: blocked layouts keep the FMA pipes fed
    // (GotoBLAS-style packing); flat matmuls thrash associativity.
    let efficiency = match op {
        Op::MatMul if out.is_packed() => 0.85,
        Op::MatMul => 0.35,
        Op::Unary(_) | Op::Binary(_) if out.is_packed() => 0.80,
        Op::Unary(_) | Op::Binary(_) => 0.60,
        _ => 0.50,
    };
    let secs = roofline_time_s(flops, bytes, machine, 1, dtype_bytes, efficiency);
    RooflineCost { ns: (secs * 1e9).ceil() as u64 + 1, flops, bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;

    fn t(dims: &[usize]) -> TensorType {
        TensorType::of(dims, DType::F32)
    }

    #[test]
    fn compute_vs_memory_bound() {
        let m = MachineSpec::ryzen_5900x();
        // Huge FLOPs, no bytes -> compute bound.
        let t1 = roofline_time_s(1_000_000_000, 0, &m, 1, 4, 1.0);
        assert!((t1 - 1e9 / 144e9).abs() < 1e-6);
        // No FLOPs, lots of bytes -> memory bound.
        let t2 = roofline_time_s(0, 24_000_000_000, &m, 1, 4, 1.0);
        assert!((t2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn packed_matmul_cheaper_than_flat() {
        let m = MachineSpec::ryzen_5900x();
        let a = t(&[512, 512]);
        let b = t(&[512, 512]);
        let flat = enode_cost(&Op::MatMul, &[&a, &b], &t(&[512, 512]), &m);

        let mut pa = t(&[32, 32]);
        pa.lanes = vec![16, 16];
        pa.pack_axes = vec![0, 1];
        let pb = pa.clone();
        let pout = pa.clone();
        let packed = enode_cost(&Op::MatMul, &[&pa, &pb], &pout, &m);
        assert!(
            packed.ns < flat.ns,
            "packed {} should beat flat {}",
            packed.ns,
            flat.ns
        );
        assert_eq!(packed.flops, flat.flops);
    }

    #[test]
    fn quantized_weight_stream_lifts_the_decode_ceiling() {
        use crate::model::Qwen3Config;
        use crate::ntt::WeightQuant;
        let m = MachineSpec::ryzen_5900x();
        let f32c = Qwen3Config::qwen3_0_6b(crate::ir::DType::F32);
        let i8c = f32c.clone().with_weight_quant(WeightQuant::Int8);
        let i4c = f32c.clone().with_weight_quant(WeightQuant::Int4);
        let t_f32 = decode_weight_stream_s(&f32c, &m, 1);
        let t_i8 = decode_weight_stream_s(&i8c, &m, 1);
        let t_i4 = decode_weight_stream_s(&i4c, &m, 1);
        // The streamed plane is essentially all GEMM matrices (the
        // embedding is gathered, not streamed, and norms are tiny), so
        // int8 cuts the floor to ~1.25/4 ≈ 0.31 of f32.
        assert!(t_i8 < t_f32 / 3.0, "int8 stream floor {t_i8} vs f32 {t_f32}");
        assert!(t_i4 < t_i8, "int4 must stream less than int8");
        // Sanity: the floor prices streamed bytes, not the resident
        // footprint (which includes the embedding table).
        let want = f32c.decode_stream_bytes() as f64 / m.dram_bw(1);
        assert!((t_f32 - want).abs() < 1e-12);
        assert!(f32c.decode_stream_bytes() < f32c.weight_bytes());
    }

    #[test]
    fn chunked_prefill_compute_floor_is_below_the_decode_stream_floor() {
        use crate::model::Qwen3Config;
        let m = MachineSpec::ryzen_5900x();
        for cfg in [
            Qwen3Config::qwen3_0_6b(crate::ir::DType::F32),
            Qwen3Config::qwen3_1_7b(crate::ir::DType::F16),
            Qwen3Config::tiny(),
        ] {
            let compute = prefill_flops_s(&cfg, &m, 1);
            let stream = decode_weight_stream_s(&cfg, &m, 1);
            assert!(
                compute < stream,
                "{}: prefill compute floor {compute} must sit below the per-token weight \
                 stream {stream} — otherwise chunking buys nothing",
                cfg.name
            );
        }
        // More threads raise the FLOP roof (until the core count caps).
        let cfg = Qwen3Config::qwen3_0_6b(crate::ir::DType::F32);
        assert!(prefill_flops_s(&cfg, &m, 4) < prefill_flops_s(&cfg, &m, 1));
    }

    #[test]
    fn threads_reduce_time_until_bw_wall() {
        let m = MachineSpec::ryzen_5900x();
        let t1 = roofline_time_s(0, 1_000_000_000, &m, 1, 4, 1.0);
        let t2 = roofline_time_s(0, 1_000_000_000, &m, 2, 4, 1.0);
        let t8 = roofline_time_s(0, 1_000_000_000, &m, 8, 4, 1.0);
        assert!(t2 < t1);
        // 2T..8T are all capped by the 42 GB/s socket limit.
        assert_eq!(t2, t8);
    }
}
