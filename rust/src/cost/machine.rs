//! Machine descriptions.
//!
//! All targets are modeled through the same NUMA-style abstraction the
//! paper uses for its "compile once, adapt everywhere" story: a set of
//! cores, a cache/scratchpad hierarchy and a shared memory bandwidth.
//! The evaluation platform (AMD Ryzen 9 5900X + DDR4-3600) is a preset;
//! substitute machines (a TPU-like device for the Pallas L1 kernel) use
//! the same struct.


/// One level of on-chip memory (cache or scratchpad).
#[derive(Debug, Clone)]
pub struct CacheLevel {
    pub name: String,
    /// Capacity in bytes (per core for private levels, total for shared).
    pub size_bytes: usize,
    /// Sustained bandwidth to the next level down, GB/s per core.
    pub bw_gbps: f64,
    /// True if shared by all cores (e.g. L3), false if per-core.
    pub shared: bool,
}

/// A deployment target.
#[derive(Debug, Clone)]
pub struct MachineSpec {
    pub name: String,
    pub cores: usize,
    /// SIMD width in bits (AVX2 = 256).
    pub vector_bits: usize,
    /// FMA units per core (AVX2 Zen3 = 2 × 256-bit FMA).
    pub fma_units: usize,
    pub freq_ghz: f64,
    /// Cache hierarchy, innermost first (L1, L2, L3).
    pub caches: Vec<CacheLevel>,
    /// Sustained DRAM bandwidth achievable by a single core, GB/s.
    pub dram_bw_core_gbps: f64,
    /// Sustained DRAM bandwidth at full socket saturation, GB/s.
    pub dram_bw_total_gbps: f64,
    /// Alpha (latency) for inter-core synchronization, seconds.
    pub sync_alpha_s: f64,
    /// Inter-core (cache-to-cache / NUMA) bandwidth, GB/s.
    pub intercore_bw_gbps: f64,
    /// Total memory capacity in bytes (hard constraint for Auto
    /// Distribution, Observation 2).
    pub mem_capacity_bytes: usize,
    /// Sustained bandwidth to the cold KV storage tier (the CXL/NVMe
    /// class device of the paper's heterogeneous-storage story), GB/s.
    pub cold_bw_gbps: f64,
    /// Per-transfer latency of the cold tier, seconds.
    pub cold_alpha_s: f64,
}

impl MachineSpec {
    /// Peak f32 FLOP/s for `threads` cores: 2 (FMA) × lanes × units × freq.
    pub fn peak_flops(&self, threads: usize, dtype_bytes: usize) -> f64 {
        let lanes = self.vector_bits / (8 * dtype_bytes.max(1));
        2.0 * lanes as f64
            * self.fma_units as f64
            * self.freq_ghz
            * 1e9
            * threads.min(self.cores) as f64
    }

    /// Sustained DRAM bandwidth for `threads` cores in bytes/s. Bandwidth
    /// saturates well below core count on desktop parts — the "memory
    /// wall" that shapes Figure 10's 8T results.
    pub fn dram_bw(&self, threads: usize) -> f64 {
        let t = threads.min(self.cores) as f64;
        (self.dram_bw_core_gbps * t).min(self.dram_bw_total_gbps) * 1e9
    }

    /// KV-cache block budget of the serving subsystem: how many paged KV
    /// blocks of `block_bytes` fit after reserving `reserved_bytes`
    /// (weights + activations) out of `mem_capacity_bytes`. This is the
    /// same hard memory constraint Auto Distribution enforces per device
    /// (Observation 2), applied to the serving-side KV pool. Callers
    /// reserve `Qwen3Config::weight_bytes()`, which prices the GEMM
    /// plane at the config's `weight_quant` — quantized weights free
    /// budget for more KV blocks, the second half of the low-bit win.
    pub fn kv_block_budget(&self, reserved_bytes: u64, block_bytes: u64) -> u64 {
        if block_bytes == 0 {
            return 0;
        }
        (self.mem_capacity_bytes as u64).saturating_sub(reserved_bytes) / block_bytes
    }

    /// Worker-thread count for the SPMD batched decode path: one worker
    /// per core, capped at the batch width. Workers own whole batch
    /// rows/sequences, so threads beyond `max_batch` would only spin on
    /// barriers — and decode is bandwidth-bound, so past the DRAM
    /// saturation point extra cores buy little anyway (the "memory wall"
    /// of Figure 10); the batch cap keeps the default honest on small
    /// workloads. See docs/serving.md for the full sizing discussion.
    pub fn decode_threads(&self, max_batch: usize) -> usize {
        self.cores.min(max_batch.max(1)).max(1)
    }

    /// The evaluation platform of §4: AMD Ryzen 9 5900X, 12 cores, AVX2,
    /// 128 GB DDR4-3600 (dual channel).
    pub fn ryzen_5900x() -> Self {
        MachineSpec {
            name: "AMD Ryzen 9 5900X".into(),
            cores: 12,
            vector_bits: 256,
            fma_units: 2,
            freq_ghz: 4.5,
            caches: vec![
                CacheLevel {
                    name: "L1d".into(),
                    size_bytes: 32 << 10,
                    bw_gbps: 900.0,
                    shared: false,
                },
                CacheLevel {
                    name: "L2".into(),
                    size_bytes: 512 << 10,
                    bw_gbps: 450.0,
                    shared: false,
                },
                CacheLevel {
                    name: "L3".into(),
                    size_bytes: 64 << 20,
                    bw_gbps: 300.0,
                    shared: true,
                },
            ],
            // DDR4-3600 dual channel: 57.6 GB/s theoretical; a single Zen3
            // core sustains ~24 GB/s, the socket ~42 GB/s in practice.
            dram_bw_core_gbps: 24.0,
            dram_bw_total_gbps: 42.0,
            sync_alpha_s: 2.0e-6,
            intercore_bw_gbps: 60.0,
            mem_capacity_bytes: 128 << 30,
            // Cold KV tier: PCIe 4.0 NVMe class — ~8 GB/s streaming,
            // tens of microseconds per transfer.
            cold_bw_gbps: 8.0,
            cold_alpha_s: 25.0e-6,
        }
    }

    /// A TPU-like device used for the §Hardware-Adaptation discussion of
    /// the L1 Pallas kernel: VMEM scratchpad + MXU systolic array.
    pub fn tpu_like() -> Self {
        MachineSpec {
            name: "TPU-like (1 core, MXU + VMEM)".into(),
            cores: 1,
            vector_bits: 8 * 128 * 4, // (8,128) vregs, f32
            fma_units: 2,
            freq_ghz: 0.94,
            caches: vec![CacheLevel {
                name: "VMEM".into(),
                size_bytes: 16 << 20,
                bw_gbps: 3000.0,
                shared: false,
            }],
            dram_bw_core_gbps: 800.0,
            dram_bw_total_gbps: 800.0,
            sync_alpha_s: 1.0e-6,
            intercore_bw_gbps: 100.0,
            mem_capacity_bytes: 32 << 30,
            cold_bw_gbps: 16.0,
            cold_alpha_s: 10.0e-6,
        }
    }

    /// A small generic NUMA box used in tests (2 nodes × 2 cores).
    pub fn test_numa() -> Self {
        MachineSpec {
            name: "test-numa-2x2".into(),
            cores: 4,
            vector_bits: 256,
            fma_units: 2,
            freq_ghz: 3.0,
            caches: vec![
                CacheLevel {
                    name: "L1d".into(),
                    size_bytes: 32 << 10,
                    bw_gbps: 600.0,
                    shared: false,
                },
                CacheLevel {
                    name: "L2".into(),
                    size_bytes: 256 << 10,
                    bw_gbps: 300.0,
                    shared: false,
                },
            ],
            dram_bw_core_gbps: 10.0,
            dram_bw_total_gbps: 25.0,
            sync_alpha_s: 2.0e-6,
            intercore_bw_gbps: 30.0,
            mem_capacity_bytes: 8 << 30,
            cold_bw_gbps: 4.0,
            cold_alpha_s: 20.0e-6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_flops_scaling() {
        let m = MachineSpec::ryzen_5900x();
        // One core AVX2 f32: 2 * 8 lanes * 2 units * 4.5 GHz = 144 GFLOP/s.
        assert_eq!(m.peak_flops(1, 4), 144.0e9);
        assert_eq!(m.peak_flops(12, 4), 12.0 * 144.0e9);
        // Thread count clamps at core count.
        assert_eq!(m.peak_flops(64, 4), m.peak_flops(12, 4));
    }

    #[test]
    fn bandwidth_saturates() {
        let m = MachineSpec::ryzen_5900x();
        assert_eq!(m.dram_bw(1), 24.0e9);
        // 2 cores double, but the socket caps at 42 GB/s.
        assert_eq!(m.dram_bw(2), 42.0e9);
        assert_eq!(m.dram_bw(8), 42.0e9);
    }

    #[test]
    fn kv_block_budget_accounts_reservation() {
        let m = MachineSpec::ryzen_5900x(); // 128 GiB
        let block = 2u64 << 20; // 2 MiB blocks
        assert_eq!(m.kv_block_budget(0, block), (128u64 << 30) / (2 << 20));
        // Reserving 64 GiB of weights halves the pool.
        assert_eq!(m.kv_block_budget(64 << 30, block), (64u64 << 30) / (2 << 20));
        // Over-reservation and degenerate block size are safe.
        assert_eq!(m.kv_block_budget(u64::MAX, block), 0);
        assert_eq!(m.kv_block_budget(0, 0), 0);
    }

    #[test]
    fn decode_threads_cap_at_cores_and_batch() {
        let m = MachineSpec::ryzen_5900x(); // 12 cores
        assert_eq!(m.decode_threads(4), 4, "batch narrower than the socket");
        assert_eq!(m.decode_threads(64), 12, "cores bind on wide batches");
        assert_eq!(m.decode_threads(0), 1, "degenerate batch still gets a worker");
    }

    #[test]
    fn f16_doubles_lanes() {
        let m = MachineSpec::ryzen_5900x();
        assert_eq!(m.peak_flops(1, 2), 2.0 * m.peak_flops(1, 4));
    }

    #[test]
    fn cold_tier_is_slower_than_dram_everywhere() {
        // The tier ordering the swap cost model relies on: the cold
        // store must sit below DRAM in bandwidth and above it in
        // latency on every preset.
        for m in [
            MachineSpec::ryzen_5900x(),
            MachineSpec::tpu_like(),
            MachineSpec::test_numa(),
        ] {
            assert!(m.cold_bw_gbps > 0.0, "{}: cold tier must exist", m.name);
            assert!(
                m.cold_bw_gbps < m.dram_bw_core_gbps,
                "{}: cold tier must be slower than a single core's DRAM stream",
                m.name
            );
            assert!(m.cold_alpha_s > m.sync_alpha_s, "{}: cold latency above sync", m.name);
        }
    }
}
