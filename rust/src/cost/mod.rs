//! Cost models: Roofline (§3.1.1), alpha-beta communication (§3.1.3),
//! per-op FLOPs/bytes accounting and machine descriptions.

mod comm;
mod machine;
mod opcost;
mod roofline;

pub use comm::{collective_time_s, AlphaBeta, Collective};
pub use machine::{CacheLevel, MachineSpec};
pub use opcost::{op_bytes, op_flops};
pub use roofline::{
    decode_weight_stream_s, enode_cost, prefill_flops_s, roofline_time_s, RooflineCost,
};
